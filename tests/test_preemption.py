"""Preemptible lanes + chunked prefill (PR 4 acceptance).

Covers: lane snapshot/restore bit-identity against an uninterrupted
run (including restore into a DIFFERENT lane), the no-recompile
assertion across preempt/resume cycles (jit_cache_size), EDF-displace
semantics through the real host and engine schedulers, WFQ share
convergence under saturation, chunked-prefill token bit-identity for
dense and vlm with exactly ONE chunk compile, the typed moe chunk
guard (ssm/hybrid parity lives in tests/test_family_parity.py),
and the slot-placement invariance the preemption machinery relies on
(the apply_rope head-axis fix)."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.apps import build_fc_stack, build_hotword
from repro.apps.models import representative_dataset
from repro.core import (AllOpsResolver, LaneCheckpoint, MicroModel,
                        RaggedInterpreterPool, export, jit_cache_size)
from repro.serving import (EDFDisplacePolicy, MultiTenantHost,
                           PreemptionPolicy, Request, ServingEngine,
                           WFQDisplacePolicy, WFQPolicy, get_preemption)


@pytest.fixture(scope="module")
def resolver():
    return AllOpsResolver()


@pytest.fixture(scope="module")
def hotword():
    # stateful (SVDF) streaming model: continuation state is REAL, so a
    # checkpoint that loses a bit cannot hide
    return MicroModel(export(build_hotword(n_layers=1)))


@pytest.fixture(scope="module")
def fc_int8(resolver):
    gb = build_fc_stack()
    return MicroModel(export(
        gb, representative_dataset=representative_dataset(gb),
        quantize_int8=True))


@pytest.fixture(scope="module")
def pod_setup():
    import jax

    from repro.configs import get_config
    from repro.models import get_model

    cfg = get_config("qwen3-32b", reduced=True)
    m = get_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def _frames(model, n, seed=0):
    shape = tuple(model.tensor(model.inputs[0]).shape)
    rng = np.random.default_rng(seed)
    return [rng.normal(0, 1, shape).astype(np.float32) for _ in range(n)]


# ---------------------------------------------------------------------------
# lane checkpoint/restore: bit-identity + no recompile
# ---------------------------------------------------------------------------

def test_snapshot_restore_bit_identical_and_no_recompile(hotword,
                                                         resolver):
    """Preempt a streaming lane mid-request, run unrelated work, then
    restore — every post-resume output must be bit-identical to the
    uninterrupted run, into a DIFFERENT lane, with the masked program
    still traced exactly once."""
    frames = _frames(hotword, 4, seed=1)

    ref_pool = RaggedInterpreterPool()
    ref_pool.add_bucket("hw", hotword, resolver, lanes=3, exact=True)
    slot = ref_pool.admit("hw", uid=7)
    ref = []
    for f in frames:
        ref_pool.set_input("hw", slot, 0, f)
        ref_pool.dispatch()
        ref.append(ref_pool.output("hw", slot, 0).copy())

    pool = RaggedInterpreterPool()
    pool.add_bucket("hw", hotword, resolver, lanes=3, exact=True)
    slot = pool.admit("hw", uid=7)
    got = []
    for f in frames[:2]:
        pool.set_input("hw", slot, 0, f)
        pool.dispatch()
        got.append(pool.output("hw", slot, 0).copy())
    ckpt = pool.snapshot_lane("hw", slot)
    assert isinstance(ckpt, LaneCheckpoint)
    assert ckpt.step == 2 and ckpt.uid == 7
    assert all(isinstance(v, np.ndarray) for v in ckpt.variables)
    pool.retire("hw", slot)
    # unrelated interleaved work occupies the freed lane meanwhile
    other = _frames(hotword, 3, seed=2)
    tmp = pool.admit("hw", uid=99)
    assert tmp == slot
    for f in other:
        pool.set_input("hw", tmp, 0, f)
        pool.dispatch()
    pool.retire("hw", tmp)
    # restore into a different lane than the one snapshotted
    restored = pool.restore_lane(ckpt, slot=2)
    assert restored == 2 and restored != slot
    assert pool.lanes("hw")[2].step == 2
    for f in frames[2:]:
        pool.set_input("hw", restored, 0, f)
        pool.dispatch()
        got.append(pool.output("hw", restored, 0).copy())
    for a, b in zip(ref, got):
        assert np.array_equal(a, b)
    # THE no-recompile assertion across the whole preempt/resume cycle
    fn = pool._buckets["hw"].compiled.masked_batched(3, True)
    assert jit_cache_size(fn) == 1


def test_snapshot_restore_guards(hotword, resolver):
    pool = RaggedInterpreterPool()
    pool.add_bucket("hw", hotword, resolver, lanes=2)
    with pytest.raises(RuntimeError):
        pool.snapshot_lane("hw", 0)         # lane not active
    slot = pool.admit("hw", uid=1)
    ckpt = pool.snapshot_lane("hw", slot)
    with pytest.raises(RuntimeError):
        pool.restore_lane(ckpt, slot=slot)  # lane occupied
    pool.admit("hw", uid=2)
    with pytest.raises(RuntimeError):
        pool.restore_lane(ckpt)             # no free lane


# ---------------------------------------------------------------------------
# preemption policy semantics (unit)
# ---------------------------------------------------------------------------

class _R:
    """Bare request stub carrying only the scheduling fields."""

    def __init__(self, uid, deadline_us=None, arrival_us=0, tenant=""):
        self.uid = uid
        self.deadline_us = deadline_us
        self.arrival_us = arrival_us
        self.tenant = tenant


def test_edf_displace_picks_loosest_victim():
    pol = EDFDisplacePolicy()
    running = [_R(0, deadline_us=100), _R(1), _R(2, deadline_us=900)]
    # deadline-less best-effort is displaced first
    assert pol.victim(running, _R(9, deadline_us=50)) == 1
    # without best-effort, the latest deadline goes
    assert pol.victim(running[::2], _R(9, deadline_us=50)) == 1
    # a deadline-less candidate never displaces
    assert pol.victim(running, _R(9)) is None
    # no strict improvement -> no eviction
    assert pol.victim([_R(0, deadline_us=100)],
                      _R(9, deadline_us=100)) is None
    # margin widens the required improvement
    assert EDFDisplacePolicy(margin_us=500).victim(
        [_R(0, deadline_us=900)], _R(9, deadline_us=600)) is None


def test_wfq_displace_reads_shared_service():
    wfq = WFQPolicy(weights={"a": 1.0, "b": 1.0})
    pol = WFQDisplacePolicy(wfq, slack=1.0)
    wfq.charge("a", 5.0)
    running = [_R(0, tenant="a"), _R(1, tenant="b")]
    assert pol.victim(running, _R(9, tenant="b")) == 0
    # within slack -> no eviction
    wfq.charge("b", 4.5)
    assert pol.victim(running, _R(9, tenant="b")) is None
    with pytest.raises(TypeError):
        WFQDisplacePolicy("not-a-policy")


def test_get_preemption_resolution():
    assert get_preemption(None) is None
    assert isinstance(get_preemption("edf-displace"), EDFDisplacePolicy)
    pol = EDFDisplacePolicy(margin_us=3)
    assert get_preemption(pol) is pol
    assert isinstance(get_preemption("never"), PreemptionPolicy)
    with pytest.raises(ValueError):
        get_preemption("round-robin")


# ---------------------------------------------------------------------------
# preemption through the REAL schedulers
# ---------------------------------------------------------------------------

def test_host_preempts_monopolizer_for_tight_deadline(fc_int8, resolver):
    """Both lanes held by 6-frame best-effort monopolizers; a 1-frame
    deadline request must displace one, finish next tick, and the
    victim must still complete all its steps."""
    rng = np.random.default_rng(3)
    frame = lambda: [rng.normal(0, 1, (1, 64)).astype(np.float32)]
    host = MultiTenantHost(arena_bytes=64 << 20, policy="edf",
                           preempt="edf-displace", clock=lambda: 0)
    host.add_ragged_micro("fc", fc_int8, resolver, lanes=2,
                          bucket_lanes=False)
    for uid in (0, 1):
        host.submit_micro("fc", uid, [frame() for _ in range(6)],
                          arrival_us=0)
    host.micro_step()                       # monopolizers take the lanes
    host.submit_micro("fc", 2, [frame()], deadline_us=50, arrival_us=0)
    host.micro_step()                       # displacement + service
    res = host.micro_results["fc"]
    assert res[2].done and res[2].steps == 1
    assert res[0].preemptions + res[1].preemptions == 1
    while host.micro_step():
        pass
    assert all(r.done for r in res.values())
    assert res[0].steps == 6 and res[1].steps == 6
    # one masked program for the whole preempt/resume history
    b = host.ragged._buckets["fc"]
    assert jit_cache_size(b.compiled.masked_batched(b.lanes, b.exact)) == 1


def test_engine_preempt_resume_bit_identical_tokens(pod_setup):
    """A best-effort long request is displaced mid-decode by a tight
    deadline; both must emit exactly the tokens of their solo runs, and
    the decode step must stay traced once across the preempt/resume
    cycle."""
    cfg, m, params = pod_setup
    rng = np.random.default_rng(5)
    long_toks = rng.integers(0, cfg.vocab - 2, 40).astype(np.int32)
    tight_toks = rng.integers(0, cfg.vocab - 2, 5).astype(np.int32)

    solo = {}
    for uid, toks, budget in ((0, long_toks, 12), (1, tight_toks, 3)):
        eng = ServingEngine(m, params, max_slots=1, cache_len=64)
        eng.submit(Request(uid=uid, tokens=toks, max_new_tokens=budget))
        solo[uid] = eng.run()[uid].output

    eng = ServingEngine(m, params, max_slots=1, cache_len=64,
                        policy="edf", preempt="edf-displace",
                        prefill_chunk=8, clock=lambda: 0)
    eng.submit(Request(uid=0, tokens=long_toks, max_new_tokens=12))
    for _ in range(8):                      # chunk-prefill, then decode
        eng.step()
    assert eng.results[0].output, "long request should be decoding"
    eng.submit(Request(uid=1, tokens=tight_toks, max_new_tokens=3,
                       deadline_us=100))
    res = eng.run()
    assert res[0].preemptions == 1 and res[1].preemptions == 0
    assert res[0].output == solo[0]
    assert res[1].output == solo[1]
    assert jit_cache_size(eng._decode) == 1
    assert eng.chunk_compiles() == 1


def test_decode_is_slot_placement_invariant(pod_setup):
    """The same request must emit identical tokens from ANY slot of a
    multi-slot engine — the invariance preempt-to-a-different-slot
    restores rely on (regression test for the apply_rope head-axis
    broadcast bug that rotated every slot by slot 0's position)."""
    cfg, m, params = pod_setup
    rng = np.random.default_rng(6)
    toks = rng.integers(0, cfg.vocab - 2, 9).astype(np.int32)
    filler = rng.integers(0, cfg.vocab - 2, 17).astype(np.int32)

    eng = ServingEngine(m, params, max_slots=2, cache_len=64)
    eng.submit(Request(uid=0, tokens=toks, max_new_tokens=4))
    want = eng.run()[0].output              # slot 0, nothing else live

    eng = ServingEngine(m, params, max_slots=2, cache_len=64)
    eng.submit(Request(uid=9, tokens=filler, max_new_tokens=8))
    eng.submit(Request(uid=0, tokens=toks, max_new_tokens=4))
    res = eng.run()                         # slot 1, busy neighbour
    assert res[0].output == want


# ---------------------------------------------------------------------------
# WFQ share convergence under saturation
# ---------------------------------------------------------------------------

def test_wfq_shares_converge_to_weights(fc_int8, resolver):
    """Two tenants with weights 1:3 and saturated queues: the delivered
    service ratio must converge to the weight ratio."""
    rng = np.random.default_rng(4)
    frame = lambda: [rng.normal(0, 1, (1, 64)).astype(np.float32)]
    pol = WFQPolicy(weights={"a": 1.0, "b": 3.0})
    host = MultiTenantHost(arena_bytes=64 << 20, policy=pol,
                           clock=lambda: 0)
    host.add_ragged_micro("fc", fc_int8, resolver, lanes=2,
                          bucket_lanes=False)
    uid = 0
    for _ in range(200):                    # deep backlog: saturation
        for t in ("a", "b"):
            host.submit_micro("fc", uid, [frame()], tenant=t,
                              arrival_us=0)
            uid += 1
    for _ in range(40):
        host.micro_step()
    a, b = pol.service["a"], pol.service["b"]
    assert a + b == pytest.approx(80)       # 2 lanes x 40 ticks, all used
    assert b / a == pytest.approx(3.0, rel=0.15)
    # work conservation: an idle tenant's share spills over
    host2 = MultiTenantHost(arena_bytes=64 << 20,
                            policy=WFQPolicy(weights={"a": 1.0,
                                                      "b": 3.0}),
                            clock=lambda: 0)
    host2.add_ragged_micro("fc", fc_int8, resolver, lanes=2,
                           bucket_lanes=False)
    for i in range(6):                      # only tenant a submits
        host2.submit_micro("fc", i, [frame()], tenant="a", arrival_us=0)
    ticks = 0
    while host2.micro_step():
        ticks += 1
    assert ticks <= 4                       # b's unused share not wasted


# ---------------------------------------------------------------------------
# chunked prefill: token bit-identity, one compile, guards
# ---------------------------------------------------------------------------

def test_chunked_prefill_token_bit_identity_dense(pod_setup):
    """Mixed short/long dense prompts, chunked vs one-shot: identical
    tokens, ONE chunk program traced no matter how many chunks ran."""
    cfg, m, params = pod_setup
    rng = np.random.default_rng(2)
    prompts = {uid: rng.integers(0, cfg.vocab - 2, L).astype(np.int32)
               for uid, L in enumerate((21, 9, 30))}
    outs = {}
    for mode, kw in (("oneshot", {}), ("chunked", {"prefill_chunk": 8})):
        eng = ServingEngine(m, params, max_slots=2, cache_len=64, **kw)
        for uid, toks in prompts.items():
            eng.submit(Request(uid=uid, tokens=toks, max_new_tokens=4))
        outs[mode] = {u: r.output for u, r in eng.run().items()}
        if mode == "chunked":
            assert eng.chunk_compiles() == 1
            assert jit_cache_size(eng._prefill_chunk) == 1
    assert outs["oneshot"] == outs["chunked"]


def test_chunked_prefill_token_bit_identity_vlm():
    """Same contract for vlm: the FIRST chunk integrates the vision
    prefix through the ordinary prefill step, later chunks attend to it
    causally — tokens must match the one-shot run bit-for-bit."""
    import jax

    from repro.configs import get_config
    from repro.models import get_model

    cfg = get_config("paligemma-3b", reduced=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    cache_len = 64 + cfg.n_vision_tokens
    reqs = []
    for uid, L in enumerate((25, 18)):
        toks = rng.integers(0, cfg.vocab - 2, L).astype(np.int32)
        vis = rng.normal(0, 1, (cfg.n_vision_tokens,
                                cfg.d_vision)).astype(np.float32)
        reqs.append((uid, toks, vis))
    outs = {}
    for mode, kw in (("oneshot", {}), ("chunked", {"prefill_chunk": 8})):
        eng = ServingEngine(m, params, max_slots=2,
                            cache_len=cache_len, **kw)
        for uid, toks, vis in reqs:
            eng.submit(Request(uid=uid, tokens=toks, max_new_tokens=4,
                               extras={"vision": vis}))
        outs[mode] = {u: r.output for u, r in eng.run().items()}
        if mode == "chunked":
            assert eng.chunk_compiles() == 1
    assert outs["oneshot"] == outs["chunked"]


def test_chunked_prefill_family_gate():
    """ssm/hybrid now CHUNK (through the recurrent-state op, asserted
    for parity in tests/test_family_parity.py), so constructing a
    chunked engine for them must succeed; MoE remains out — expert
    capacity depends on the token count integrated so far, so per-chunk
    dispatch diverges from the one-shot run — and the refusal is the
    TYPED error naming family and feature."""
    import jax

    from repro.configs import get_config
    from repro.models import get_model
    from repro.serving.errors import UnsupportedFamilyError

    for name in ("mamba2-780m", "zamba2-1.2b"):
        cfg = get_config(name, reduced=True)
        m = get_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        eng = ServingEngine(m, params, max_slots=1, cache_len=32,
                            prefill_chunk=8)
        assert eng.chunk_tokens == 8 and eng._recurrent_chunk

    cfg = get_config("deepseek-moe-16b", reduced=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    with pytest.raises(UnsupportedFamilyError) as ei:
        ServingEngine(m, params, max_slots=1, cache_len=32,
                      prefill_chunk=8)
    assert "moe" in str(ei.value) and "chunked prefill" in str(ei.value)


def test_prefill_chunk_argument_validation(pod_setup):
    cfg, m, params = pod_setup
    eng = ServingEngine(m, params, max_slots=1, cache_len=64,
                        prefill_chunk=True)
    assert eng.chunk_tokens == 8            # auto size
    assert ServingEngine(m, params, max_slots=1, cache_len=64
                         ).chunk_tokens == 0   # default off
    assert ServingEngine(m, params, max_slots=1, cache_len=64,
                         prefill_chunk=0).chunk_tokens == 0  # 0 = off
    with pytest.raises(ValueError):
        ServingEngine(m, params, max_slots=1, cache_len=64,
                      prefill_chunk=-4)

    # over-cap prompts fall back to one-shot exact prefill
    rng = np.random.default_rng(8)
    toks = rng.integers(0, cfg.vocab - 2, 70).astype(np.int32)
    eng = ServingEngine(m, params, max_slots=1, cache_len=64,
                        prefill_chunk=8)
    assert not eng._chunk_eligible(
        Request(uid=0, tokens=toks, max_new_tokens=1))


# ---------------------------------------------------------------------------
# the benchmark cannot rot: end-to-end smoke (slow tier)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_preemption_benchmark_tiny_smoke():
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(
        __file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo_root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.arrival_process",
         "--preempt", "--tiny"],
        cwd=repo_root, env=env, capture_output=True, text=True,
        timeout=560)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "Preemptible lanes" in proc.stdout
    assert "engine_edf_preempt_chunk" in proc.stdout
