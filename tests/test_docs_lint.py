"""The documentation contract, enforced: every public class and module
in repro.core / repro.serving / benchmarks carries a docstring
(tools/check_docs.py), every BENCH_*.json a guide cites is committed
under benchmarks/results/, and the documents the architecture guide
promises actually exist."""

import pathlib
import sys

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "tools"))

import check_docs  # noqa: E402


def test_public_classes_have_docstrings():
    violations = check_docs.collect_violations()
    assert not violations, "\n".join(
        f"{rel}:{lineno}: {msg}" for rel, lineno, msg in violations)


def test_lint_covers_all_packages():
    files = {str(p) for p in check_docs.linted_files()}
    assert any("core/executor.py" in f for f in files)
    assert any("serving/host.py" in f for f in files)
    assert any("serving/scheduling.py" in f for f in files)
    assert any("benchmarks/arrival_process.py" in f for f in files)


def test_lint_catches_a_missing_docstring(tmp_path):
    pkg = tmp_path / "src" / "repro" / "core"
    pkg.mkdir(parents=True)
    (tmp_path / "src" / "repro" / "serving").mkdir()
    (pkg / "bad.py").write_text(
        '"""Module docstring."""\nclass Naked:\n    pass\n')
    violations = check_docs.collect_violations(root=tmp_path)
    assert violations == [
        ("src/repro/core/bad.py", 2,
         "public class Naked lacks a docstring")]


def test_bench_reference_check_catches_missing_json(tmp_path):
    docs = tmp_path / "docs"
    docs.mkdir()
    (tmp_path / "benchmarks" / "results").mkdir(parents=True)
    (tmp_path / "benchmarks" / "results" / "BENCH_real.json").write_text(
        "[]")
    (docs / "GUIDE.md").write_text(
        "see BENCH_real.json and\nBENCH_phantom.json for numbers\n")
    violations = check_docs.check_bench_references(root=tmp_path)
    assert violations == [
        ("docs/GUIDE.md", 2,
         "mentions BENCH_phantom.json but "
         "benchmarks/results/BENCH_phantom.json does not exist")]


def test_every_cited_bench_json_is_committed():
    assert check_docs.check_bench_references() == []


def test_promised_documents_exist():
    assert (REPO_ROOT / "README.md").is_file()
    assert (REPO_ROOT / "docs" / "ARCHITECTURE.md").is_file()
    guide = (REPO_ROOT / "docs" / "ARCHITECTURE.md").read_text()
    # the guide must keep pointing at the defining modules
    for anchor in ("core/executor.py", "core/arena.py",
                   "core/memory_planner.py", "serving/engine.py",
                   "serving/host.py", "serving/ops.py", "LaneState",
                   "RaggedInterpreterPool"):
        assert anchor in guide, f"ARCHITECTURE.md lost its {anchor} anchor"
