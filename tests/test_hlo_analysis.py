"""HLO static-analysis tests: the loop-aware cost model must match XLA
on loop-free programs and beat it on scans (trip-count multiplication)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze, parse_hlo


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_single_matmul_flops_exact():
    a = jnp.zeros((256, 512), jnp.bfloat16)
    b = jnp.zeros((512, 1024), jnp.bfloat16)
    cost = analyze(_hlo(lambda a, b: a @ b, a, b))
    assert cost.flops == 2 * 256 * 512 * 1024


def test_matmul_chain_flops():
    a = jnp.zeros((64, 128), jnp.float32)
    b = jnp.zeros((128, 256), jnp.float32)
    c = jnp.zeros((256, 32), jnp.float32)
    cost = analyze(_hlo(lambda a, b, c: (a @ b) @ c, a, b, c))
    want = 2 * 64 * 128 * 256 + 2 * 64 * 256 * 32
    assert cost.flops == want


def test_scan_multiplies_trip_count():
    """THE fix over compiled.cost_analysis(): x10 scan = x10 flops."""
    a = jnp.zeros((256, 512), jnp.bfloat16)
    w = jnp.zeros((10, 512, 512), jnp.bfloat16)

    def f(a, w):
        return jax.lax.scan(lambda c, wl: (c @ wl, None), a, w)[0]

    cost = analyze(_hlo(f, a, w))
    want = 10 * 2 * 256 * 512 * 512
    assert abs(cost.flops - want) / want < 0.05, (cost.flops, want)
    # and XLA's own number is ~1/10th (documenting the undercount)
    xla = jax.jit(f).lower(a, w).compile().cost_analysis()
    if isinstance(xla, (list, tuple)):     # jax 0.4.x: per-device list
        xla = xla[0]
    xla = xla["flops"]
    assert xla < want / 5


def test_nested_scan():
    a = jnp.zeros((64, 64), jnp.float32)
    w = jnp.zeros((4, 3, 64, 64), jnp.float32)

    def inner(c, wl):
        return jax.lax.scan(lambda cc, w2: (cc @ w2, None), c, wl)[0]

    def f(a, w):
        return jax.lax.scan(lambda c, wl: (inner(c, wl), None), a, w)[0]

    cost = analyze(_hlo(f, a, w))
    want = 12 * 2 * 64 * 64 * 64
    assert abs(cost.flops - want) / want < 0.1, (cost.flops, want)


def test_bytes_nonzero_and_plausible():
    a = jnp.zeros((1024, 1024), jnp.float32)
    cost = analyze(_hlo(lambda a: a + 1.0, a))
    # one elementwise op: >= read + write of 4 MiB
    assert cost.bytes_accessed >= 2 * 1024 * 1024 * 4


def test_collectives_counted_with_loop_multiplier():
    hlo = """
HloModule test

%body.1 (p.0: (s32[], f32[128])) -> (s32[], f32[128]) {
  %p.0 = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element(%p.0), index=0
  %x = f32[128] get-tuple-element(%p.0), index=1
  %ar = f32[128]{0} all-reduce(%x), to_apply=%sum.1
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[128]) tuple(%ip, %ar)
}

%cond.1 (p.1: (s32[], f32[128])) -> pred[] {
  %p.1 = (s32[], f32[128]) parameter(0)
  %i2 = s32[] get-tuple-element(%p.1), index=0
  %k = s32[] constant(7)
  ROOT %lt = pred[] compare(%i2, %k), direction=LT
}

%sum.1 (a.1: f32[], b.1: f32[]) -> f32[] {
  %a.1 = f32[] parameter(0)
  %b.1 = f32[] parameter(1)
  ROOT %s = f32[] add(%a.1, %b.1)
}

ENTRY %main.1 (arg.0: f32[128]) -> f32[128] {
  %arg.0 = f32[128] parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[128]) tuple(%zero, %arg.0)
  %w = (s32[], f32[128]) while(%t0), condition=%cond.1, body=%body.1
  ROOT %out = f32[128] get-tuple-element(%w), index=1
}
"""
    cost = analyze(hlo)
    assert cost.collective_counts["all-reduce"] == 7
    assert cost.collective_bytes["all-reduce"] == 7 * 128 * 4


def test_parse_computations():
    a = jnp.zeros((8, 8), jnp.float32)
    comps = parse_hlo(_hlo(lambda a: a @ a, a))
    assert any(c.is_entry for c in comps.values())
