"""Paged KV arena + block-table decode attention (PR 6 acceptance).

Covers: the PagedKVPool reserve/map/release accounting (garbage sink,
two-phase admission, double-release guards), bit-identity of the paged
reference attention against the contiguous reference on a scattered
physical layout, the Pallas block-table kernel against its reference
twin, end-to-end paged-vs-contiguous engine token identity for dense
and vlm, block-table checkpoint/restore into a DIFFERENT slot with no
KV copy, and the compile-once contract as slots admit, grow, preempt,
restore, and retire blocks (the block table is a traced argument, so
none of that may retrace the decode step)."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import (BlockCost, PagedKVPool, calibrate,
                        jit_cache_size, load_cached_profile,
                        profile_cache_path, profile_model_key,
                        save_cached_profile, solve_block_size)
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def pod_setup():
    import jax

    from repro.configs import get_config
    from repro.models import get_model

    cfg = get_config("qwen3-32b", reduced=True)
    m = get_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def vlm_setup():
    import jax

    from repro.configs import get_config
    from repro.models import get_model

    cfg = get_config("paligemma-3b", reduced=True)
    m = get_model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# pool accounting (unit)
# ---------------------------------------------------------------------------

def test_pool_reserve_map_release_accounting():
    pool = PagedKVPool(9, 16)
    assert pool.usable_blocks == 8
    assert pool.free_blocks() == 8
    assert pool.can_reserve(8) and not pool.can_reserve(9)
    pool.reserve(3)
    assert pool.reserved_blocks() == 3 and pool.free_blocks() == 5
    b1, b2 = pool.map_block(), pool.map_block()
    assert b1 != b2 and PagedKVPool.GARBAGE_BLOCK not in (b1, b2)
    assert pool.reserved_blocks() == 1 and pool.alloc_count == 2
    # a finished request returns its blocks AND its unspent promise
    pool.release([b1, b2], reserved=1)
    assert pool.free_blocks() == 8 and pool.reserved_blocks() == 0


def test_pool_guards():
    with pytest.raises(ValueError):
        PagedKVPool(1, 16)                  # no room for the sink
    with pytest.raises(ValueError):
        PagedKVPool(4, 0)
    pool = PagedKVPool(4, 8)
    with pytest.raises(RuntimeError):
        pool.map_block()                    # no reservation
    with pytest.raises(RuntimeError):
        pool.reserve(4)                     # only 3 usable
    pool.reserve(2)
    b = pool.map_block()
    with pytest.raises(ValueError):
        pool.release([PagedKVPool.GARBAGE_BLOCK])
    pool.release([b], reserved=1)
    with pytest.raises(ValueError):
        pool.release([b])                   # double release
    with pytest.raises(ValueError):
        pool.release([], reserved=1)        # over-cancel


# ---------------------------------------------------------------------------
# kernel twins: paged reference == contiguous reference, Pallas == ref
# ---------------------------------------------------------------------------

def _scattered_layout(rng, b=2, kh=2, h=4, c=64, bs=16, d=32):
    """A contiguous (B,KH,C,D) cache and the SAME rows scattered into a
    shuffled physical (P,KH,BS,D) pool with per-slot block tables."""
    import jax.numpy as jnp
    t = c // bs
    q = rng.normal(0, 1, (b, h, d)).astype(np.float32)
    k = rng.normal(0, 1, (b, kh, c, d)).astype(np.float32)
    v = rng.normal(0, 1, (b, kh, c, d)).astype(np.float32)
    lengths = np.array([c - 3, c // 2], np.int32)[:b]
    n_blocks = b * t + 1
    perm = rng.permutation(np.arange(1, n_blocks))    # garbage 0 kept
    tables = perm.reshape(b, t).astype(np.int32)
    k_pool = np.zeros((n_blocks, kh, bs, d), np.float32)
    v_pool = np.zeros((n_blocks, kh, bs, d), np.float32)
    for i in range(b):
        for j in range(t):
            k_pool[tables[i, j]] = k[i, :, j * bs:(j + 1) * bs]
            v_pool[tables[i, j]] = v[i, :, j * bs:(j + 1) * bs]
    return tuple(jnp.asarray(x) for x in
                 (q, k, v, k_pool, v_pool, tables, lengths))


def test_paged_ref_bit_identical_to_contiguous_ref():
    from repro.kernels.ref import (decode_attention_ref,
                                   paged_decode_attention_ref)

    rng = np.random.default_rng(0)
    q, k, v, k_pool, v_pool, tables, lengths = _scattered_layout(rng)
    want = decode_attention_ref(q, k, v, lengths)
    got = paged_decode_attention_ref(q, k_pool, v_pool, tables, lengths)
    assert np.array_equal(np.asarray(want), np.asarray(got))


def test_paged_pallas_matches_reference():
    from repro.kernels.ops import paged_decode_attention
    from repro.kernels.ref import paged_decode_attention_ref

    rng = np.random.default_rng(1)
    q, _, _, k_pool, v_pool, tables, lengths = _scattered_layout(rng)
    want = paged_decode_attention_ref(q, k_pool, v_pool, tables, lengths)
    got = paged_decode_attention(q, k_pool, v_pool, tables, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# engine: paged vs contiguous token bit-identity (dense + vlm)
# ---------------------------------------------------------------------------

def _mixed_outputs(m, params, cache_len, vocab, *, kv_block=None,
                   extras=None, seed=11):
    rng = np.random.default_rng(seed)
    kw = {"kv_block": kv_block} if kv_block else {}
    eng = ServingEngine(m, params, max_slots=2, cache_len=cache_len,
                        **kw)
    for uid, (plen, budget) in enumerate(((21, 6), (5, 8), (30, 4),
                                          (9, 5))):
        toks = rng.integers(0, vocab - 2, plen).astype(np.int32)
        ex = None if extras is None else extras(rng)
        eng.submit(Request(uid=uid, tokens=toks, max_new_tokens=budget,
                           extras=ex))
    res = eng.run()
    return eng, {u: r.output for u, r in res.items()}


def test_engine_paged_bit_identical_dense(pod_setup):
    cfg, m, params = pod_setup
    ceng, want = _mixed_outputs(m, params, 64, cfg.vocab)
    peng, got = _mixed_outputs(m, params, 64, cfg.vocab, kv_block=16)
    assert got == want
    assert jit_cache_size(peng._decode) == 1
    # all blocks returned: the pool fully drains at completion
    assert peng.pool.free_blocks() == peng.pool.usable_blocks
    assert peng.pool.reserved_blocks() == 0


def test_engine_paged_bit_identical_vlm(vlm_setup):
    cfg, m, params = vlm_setup
    cache_len = 64 + cfg.n_vision_tokens
    bs = 16 if cache_len % 16 == 0 else 8
    assert cache_len % bs == 0

    def extras(rng):
        return {"vision": rng.normal(0, 1, (cfg.n_vision_tokens,
                                            cfg.d_vision)
                                     ).astype(np.float32)}

    _, want = _mixed_outputs(m, params, cache_len, cfg.vocab,
                             extras=extras)
    peng, got = _mixed_outputs(m, params, cache_len, cfg.vocab,
                               kv_block=bs, extras=extras)
    assert got == want
    assert jit_cache_size(peng._decode) == 1


def test_paged_guards(pod_setup):
    cfg, m, params = pod_setup
    with pytest.raises(ValueError):
        ServingEngine(m, params, max_slots=1, cache_len=64,
                      kv_block=24)          # 64 % 24 != 0

    import jax

    from repro.configs import get_config
    from repro.models import get_model

    scfg = get_config("mamba2-780m", reduced=True)
    sm = get_model(scfg)
    sparams = sm.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        # recurrent state has no (KH, C, dh) rows to page
        ServingEngine(sm, sparams, max_slots=1, cache_len=32,
                      kv_block=8)


# ---------------------------------------------------------------------------
# checkpoint = block-table handoff: restore into a DIFFERENT slot,
# no KV copy, no retrace
# ---------------------------------------------------------------------------

def test_paged_checkpoint_carries_blocks_not_kv(pod_setup):
    """Preempt a paged request mid-decode: the checkpoint must pin
    block ids (cache=None — zero KV rows copied), and restoring it
    into a DIFFERENT slot must continue the run bit-identically with
    the decode step still traced exactly once."""
    cfg, m, params = pod_setup
    rng = np.random.default_rng(13)
    toks = rng.integers(0, cfg.vocab - 2, 9).astype(np.int32)
    filler = rng.integers(0, cfg.vocab - 2, 7).astype(np.int32)

    eng = ServingEngine(m, params, max_slots=2, cache_len=64,
                        kv_block=16)
    eng.submit(Request(uid=0, tokens=toks, max_new_tokens=8))
    solo = ServingEngine(m, params, max_slots=2, cache_len=64,
                         kv_block=16)
    solo.submit(Request(uid=0, tokens=toks, max_new_tokens=8))
    want = solo.run()[0].output

    for _ in range(3):                      # uid0 decoding in slot 0
        eng.step()
    assert eng.active[0] and eng.results[0].output
    ckpt = eng.snapshot_slot(0)
    assert ckpt.phase == "decode"
    assert ckpt.cache is None               # the handoff copies no KV
    assert ckpt.blocks and all(b != 0 for b in ckpt.blocks)
    blocks_before = list(ckpt.blocks)
    req0 = eng._evict(0)
    assert eng.results[0].preemptions == 1
    # slot 0 is taken by other work before uid0 comes back, so the
    # restore lands in slot 1 — a different slot than snapshotted
    eng.queue.clear()
    eng.submit(Request(uid=1, tokens=filler, max_new_tokens=8))
    eng.step()
    assert eng.active[0] and eng.slot_req[0].uid == 1
    eng._admit(req0, 1)
    assert eng.slot_req[1].uid == 0
    # same physical blocks, remapped — not copied — into the new row
    assert eng._slot_blocks[1] == blocks_before
    res = eng.run()
    assert res[0].output == want
    assert jit_cache_size(eng._decode) == 1


def test_paged_grow_shrink_never_retraces(pod_setup):
    """Slots growing into fresh blocks mid-decode and retiring them at
    completion are VALUE updates of the traced block table: one decode
    program over an entire churn of admissions."""
    cfg, m, params = pod_setup
    rng = np.random.default_rng(17)
    eng = ServingEngine(m, params, max_slots=2, cache_len=64,
                        kv_block=8)
    uid = 0
    for wave in range(3):                   # staggered lengths/budgets
        for plen, budget in ((3, 12), (19, 4)):
            toks = rng.integers(0, cfg.vocab - 2, plen).astype(np.int32)
            eng.submit(Request(uid=uid, tokens=toks,
                               max_new_tokens=budget))
            uid += 1
        eng.run()
    assert all(r.done for r in eng.results.values())
    assert jit_cache_size(eng._decode) == 1
    assert eng.pool.free_blocks() == eng.pool.usable_blocks


# ---------------------------------------------------------------------------
# cost model: block solver + profile plumbing
# ---------------------------------------------------------------------------

def test_solve_block_size_prefers_packing_then_speed():
    costs = [BlockCost(block=8, compile_us=100, step_us=30),
             BlockCost(block=16, compile_us=100, step_us=20),
             BlockCost(block=24, compile_us=100, step_us=10),  # 64%24!=0
             BlockCost(block=64, compile_us=100, step_us=5)]
    r = solve_block_size([9] * 4, costs, cache_len=64, slots=2,
                         new_tokens=8)
    # 16 rows needed/request: bs=8 -> 2 blocks, 15 usable -> 7.5 slots
    assert r.block == 8 and r.admissible_slots == 7.5
    assert r.contiguous_slots == 2 and r.mean_blocks == 2.0
    # whole-slab "blocks" degenerate to contiguous occupancy (minus
    # the garbage block): the solver never prefers them
    r2 = solve_block_size([9] * 4, [c for c in costs
                                    if c.block == 64],
                          cache_len=64, slots=2, new_tokens=8)
    assert r2.block == 64 and r2.admissible_slots == 1.0
    with pytest.raises(ValueError):
        solve_block_size([9], [BlockCost(24, 1, 1)], cache_len=64)
    with pytest.raises(ValueError):
        solve_block_size([1], costs, cache_len=64)


def _synthetic_measure(kind, size):
    """Deterministic fake timings for every measurement kind."""
    from repro.core import CompileStepTiming
    base = {"prefill": (500.0, 10.0), "chunk": (400.0, 6.0),
            "decode": (600.0, 8.0), "decode_paged": (700.0, 9.0)}
    c, s = base[kind]
    return CompileStepTiming(compile_us=c + size, step_us=s + size / 8,
                             iters=1)


def test_calibrate_solves_kv_block_and_profile_roundtrip(pod_setup,
                                                         tmp_path):
    cfg, m, params = pod_setup
    prof = calibrate(m, params, [6, 6, 22, 22], cache_len=64, seed=0,
                     decode_slots=(2,), block_candidates=(8, 16, 24),
                     measure=_synthetic_measure)
    assert prof.kv_block in (8, 16)         # 24 skipped: 64 % 24 != 0
    assert [c.block for c in prof.block_costs] == [8, 16]
    assert [c.slots for c in prof.decode_costs] == [2]
    assert prof.version == 1
    # roundtrip, including the new defaulted fields
    from repro.core import CalibrationProfile
    back = CalibrationProfile.from_json(prof.to_json())
    assert back == prof
    # a version-1 profile WITHOUT the paged fields still loads
    import json
    d = json.loads(prof.to_json())
    for key in ("kv_block", "decode_costs", "block_costs"):
        del d[key]
    old = CalibrationProfile.from_json(json.dumps(d))
    assert old.kv_block == 0 and old.block_costs == []
    # the on-disk cache: save under model_key, load it back, miss->None
    path = save_cached_profile(prof, cache_dir=tmp_path)
    assert path == profile_cache_path(prof.model_key, tmp_path)
    assert load_cached_profile(prof.model_key, tmp_path) == prof
    assert load_cached_profile("dense/nope/L64", tmp_path) is None


def test_from_profile_enables_paging(pod_setup, tmp_path):
    cfg, m, params = pod_setup
    prof = calibrate(m, params, [6, 6, 22, 22], cache_len=64, seed=0,
                     decode_slots=(2,), block_candidates=(8, 16),
                     measure=_synthetic_measure)
    assert prof.kv_block
    eng = ServingEngine.from_profile(m, params, prof, max_slots=2,
                                     cache_len=64)
    assert eng.paged and eng.kv_block == prof.kv_block
    # explicit override wins over the profile
    eng2 = ServingEngine.from_profile(m, params, prof, max_slots=2,
                                      cache_len=64, kv_block=0)
    assert not eng2.paged
    # profile=None consults the CACHE: a miss is the plain constructor
    key = profile_model_key(cfg, 64)
    assert not os.path.exists(profile_cache_path(key, tmp_path))
    eng3 = ServingEngine.from_profile(m, params, max_slots=2,
                                      cache_len=64)
    assert isinstance(eng3, ServingEngine)


# ---------------------------------------------------------------------------
# the benchmark cannot rot: end-to-end smoke (slow tier)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_paged_benchmark_tiny_smoke():
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(
        __file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo_root, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.arrival_process",
         "--paged", "--tiny"],
        cwd=repo_root, env=env, capture_output=True, text=True,
        timeout=560)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "Paged KV pool" in proc.stdout
    assert "tokens_match" in proc.stdout
