"""Executor layer: AllocationPlan/CompiledPlan extraction, batched
invoke (vmap + exact lowering), ArenaPool steady-state, serving tag
chain, and micro-model tenants in the multitenant host."""

import numpy as np
import pytest

from repro.apps import build_conv_reference, build_hotword
from repro.apps.models import representative_dataset
from repro.core import (AllOpsResolver, ArenaPool, InterpreterPool,
                        MicroInterpreter, MicroModel, OpCode,
                        SharedArenaState, export)
from repro.core.executor import (AllocationPlan, CompiledPlan,
                                 required_arena_size)
from repro.core.arena import TwoStackArena


@pytest.fixture(scope="module")
def resolver():
    return AllOpsResolver()


@pytest.fixture(scope="module")
def conv_model():
    return MicroModel(export(build_conv_reference()))


@pytest.fixture(scope="module")
def conv_model_int8():
    gb = build_conv_reference()
    return MicroModel(export(
        gb, representative_dataset=representative_dataset(gb),
        quantize_int8=True))


def _sequential_outputs(model, resolver, xs):
    size = MicroInterpreter.required_arena_size(model, resolver)
    it = MicroInterpreter(model, resolver, size)
    outs = []
    for x in xs:
        it.set_input(0, x)
        it.invoke()
        outs.append(it.output(0).copy())
    return outs


def _conv_inputs(n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.normal(0, 1, (1, 16, 16, 1)).astype(np.float32)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# batched invoke correctness
# ---------------------------------------------------------------------------

def test_batched_float_element_exact(conv_model, resolver):
    """exact lowering: one batched dispatch is bit-identical to N
    sequential single invokes, float model."""
    xs = _conv_inputs(4)
    want = _sequential_outputs(conv_model, resolver, xs)
    pool = InterpreterPool(conv_model, resolver, batch=4, exact=True)
    for lane, x in enumerate(xs):
        pool.set_input(lane, 0, x)
    pool.invoke()
    for lane in range(4):
        np.testing.assert_array_equal(pool.output(lane, 0), want[lane])


def test_batched_int8_element_exact_under_vmap(conv_model_int8, resolver):
    """int8 math is integer-exact, so even the vmapped throughput path
    must be element-exact against sequential single invokes."""
    xs = _conv_inputs(4, seed=7)
    want = _sequential_outputs(conv_model_int8, resolver, xs)
    pool = InterpreterPool(conv_model_int8, resolver, batch=4)
    for lane, x in enumerate(xs):
        pool.set_input(lane, 0, x)
    pool.invoke()
    for lane in range(4):
        np.testing.assert_array_equal(pool.output(lane, 0), want[lane])


def test_batched_float_vmap_close(conv_model, resolver):
    """vmap lowering: float reductions may be reassociated by the
    backend (batched gemm vs gemv), so we assert tight closeness — lane
    cross-talk or arena offset bugs would show up orders of magnitude
    above this tolerance."""
    xs = _conv_inputs(4, seed=3)
    want = _sequential_outputs(conv_model, resolver, xs)
    pool = InterpreterPool(conv_model, resolver, batch=4)
    for lane, x in enumerate(xs):
        pool.set_input(lane, 0, x)
    pool.invoke()
    for lane in range(4):
        np.testing.assert_allclose(pool.output(lane, 0), want[lane],
                                   atol=1e-6, rtol=1e-6)


def test_batched_variable_state_per_lane(resolver):
    """SVDF state is per-lane under batched invoke: each lane must
    evolve exactly like its own dedicated interpreter."""
    model = MicroModel(export(build_hotword(n_layers=1)))
    rng = np.random.default_rng(11)
    xs = [rng.normal(0, 1, (1, 40)).astype(np.float32) for _ in range(3)]

    # dedicated interpreters, two streaming steps each
    want = []
    for x in xs:
        size = MicroInterpreter.required_arena_size(model, resolver)
        it = MicroInterpreter(model, resolver, size)
        for _ in range(2):
            it.set_input(0, x)
            it.invoke()
        want.append(it.output(0).copy())

    pool = InterpreterPool(model, resolver, batch=3, exact=True)
    for _ in range(2):
        for lane, x in enumerate(xs):
            pool.set_input(lane, 0, x)
        pool.invoke()
    for lane in range(3):
        np.testing.assert_array_equal(pool.output(lane, 0), want[lane])


# ---------------------------------------------------------------------------
# arena pooling: the malloc-free steady state
# ---------------------------------------------------------------------------

def test_arena_pool_no_alloc_after_warmup(conv_model, resolver):
    pool = InterpreterPool(conv_model, resolver, batch=4)
    x = np.zeros((1, 16, 16, 1), np.float32)
    for lane in range(4):
        pool.set_input(lane, 0, x)
    pool.invoke()                                   # warm-up
    allocs = pool.pool.alloc_count
    [stored] = pool.pool._batched[4]          # free list: one buffer
    ptr = stored.unsafe_buffer_pointer()
    for _ in range(3):
        pool.invoke()
        [again] = pool.pool._batched[4]
        # donated dispatch hands the SAME device memory back every step
        assert again.unsafe_buffer_pointer() == ptr
    assert pool.pool.alloc_count == allocs


def test_arena_pool_shared_across_batched_tenants(conv_model, resolver):
    """One ArenaPool backs multiple batched tenants (non-concurrent),
    like the §4.5 shared arena."""
    shared = ArenaPool()
    p1 = InterpreterPool(conv_model, resolver, batch=2, pool=shared)
    p2 = InterpreterPool(conv_model, resolver, batch=2, pool=shared)
    xs = _conv_inputs(2, seed=5)
    want = _sequential_outputs(conv_model, resolver, xs)
    for pool in (p1, p2):
        for lane, x in enumerate(xs):
            pool.set_input(lane, 0, x)
    p1.invoke()
    p2.invoke()
    for lane in range(2):
        np.testing.assert_allclose(p1.output(lane, 0), want[lane],
                                   atol=1e-6, rtol=1e-6)
        np.testing.assert_array_equal(p1.output(lane, 0),
                                      p2.output(lane, 0))


def test_shared_arena_state_is_arena_pool():
    """Back-compat: SharedArenaState keeps the §4.5 take/put contract."""
    s = SharedArenaState()
    assert isinstance(s, ArenaPool)
    s.ensure(128)
    buf = s.take()
    assert buf.shape == (128,)
    s.put(buf)


# ---------------------------------------------------------------------------
# the extracted phases compose like the facade
# ---------------------------------------------------------------------------

def test_allocation_plan_freezes_arena(conv_model, resolver):
    arena = TwoStackArena(required_arena_size(conv_model, resolver))
    alloc = AllocationPlan.build(conv_model, resolver, arena)
    assert arena.frozen
    assert alloc.plan.total_bytes > 0
    assert alloc.nonpersistent_nbytes == alloc.plan.total_bytes
    with pytest.raises(RuntimeError):
        arena.allocate_persistent(16)


def test_compiled_plan_powers_facade(conv_model, resolver):
    """The facade's invoke and a hand-driven CompiledPlan agree."""
    size = required_arena_size(conv_model, resolver)
    it = MicroInterpreter(conv_model, resolver, size)
    assert isinstance(it.compiled, CompiledPlan)
    assert it.compiled.alloc is it.alloc
    x = _conv_inputs(1, seed=9)[0]
    it.set_input(0, x)
    it.invoke()
    assert it.output(0).shape == (1, 10)


def test_context_names_importable_from_interpreter():
    # the benchmarks import these through the facade module
    from repro.core.interpreter import (EvalContext, PrepareContext,
                                        MicroInterpreter as MI)
    assert EvalContext is not None and PrepareContext is not None
    assert MI is MicroInterpreter


# ---------------------------------------------------------------------------
# serving: registry tag chain + micro tenants
# ---------------------------------------------------------------------------

def test_serving_engine_resolves_through_tag_chain():
    import jax
    from repro.configs import get_config
    from repro.models import get_model
    from repro.serving import ServingEngine, Request

    cfg = get_config("qwen3-32b", reduced=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))

    eng = ServingEngine(m, params, max_slots=1, cache_len=32)
    reg = eng.resolver.resolve(OpCode.SERVING_DECODE)
    assert reg.tag == "pallas"          # vendor kernel shadows reference
    ref_eng = ServingEngine(m, params, max_slots=1, cache_len=32,
                            tags=("reference",))
    assert ref_eng.resolver.resolve(OpCode.SERVING_DECODE).tag \
        == "reference"

    prompt = np.arange(1, 6, dtype=np.int32)
    eng.submit(Request(uid=1, tokens=prompt, max_new_tokens=3))
    ref_eng.submit(Request(uid=1, tokens=prompt, max_new_tokens=3))
    assert eng.run()[1].output == ref_eng.run()[1].output


def test_pool_partial_inputs_raise(conv_model, resolver):
    """A lane with SOME but not all inputs set must fail loudly, like
    MicroInterpreter.invoke(); a lane with none is idle (zeros)."""
    model = conv_model          # single input: build a 2-input surrogate
    pool = InterpreterPool(model, resolver, batch=2)
    pool.set_input(0, 0, np.zeros((1, 16, 16, 1), np.float32))
    pool.invoke()               # lane 1 idle: allowed
    pool.clear_inputs()
    assert pool._inputs == [{}, {}]


def test_host_micro_requests_are_independent(resolver):
    """Stateful micro-model (SVDF): every run_micro request must start
    from fresh variable state, including requests in later chunks."""
    from repro.serving import MultiTenantHost

    model = MicroModel(export(build_hotword(n_layers=1)))
    rng = np.random.default_rng(21)
    xs = [rng.normal(0, 1, (1, 40)).astype(np.float32) for _ in range(5)]

    # fresh-interpreter reference, one interpreter per request
    want = []
    for x in xs:
        size = MicroInterpreter.required_arena_size(model, resolver)
        it = MicroInterpreter(model, resolver, size)
        it.set_input(0, x)
        it.invoke()
        want.append(it.output(0).copy())

    host = MultiTenantHost(arena_bytes=64 << 20)
    host.add_micro_model("hw", model, resolver, batch=2)   # 3 chunks
    got = host.run_micro("hw", [[x] for x in xs])
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=1e-6, rtol=1e-6)


def test_all_ops_resolver_excludes_serving_macro_ops():
    """Importing the serving layer (which registers pod-scale macro-ops
    in the global registry) must not change what AllOpsResolver links —
    the Table-2 code-size metric stays import-order independent."""
    import repro.serving  # noqa: F401  (registers SERVING_* ops)

    r = AllOpsResolver(tags=("pallas", "reference"))
    linked = {reg.opcode for reg in r.linked_ops}
    assert OpCode.SERVING_PREFILL not in linked
    assert OpCode.SERVING_DECODE not in linked


def test_host_micro_model_tenancy(conv_model, resolver):
    from repro.serving import MultiTenantHost

    host = MultiTenantHost(arena_bytes=64 << 20)
    tail0 = len(host.arena.tail_allocs)
    host.add_micro_model("conv", conv_model, resolver, batch=4)
    assert len(host.arena.tail_allocs) > tail0   # persistents stacked
    xs = _conv_inputs(6, seed=13)
    want = _sequential_outputs(conv_model, resolver,
                               [x for x in xs])
    got = host.run_micro("conv", [[x] for x in xs])
    assert len(got) == 6
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=1e-6, rtol=1e-6)
