"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated at REDUCED scale (2 layers,
d_model<=512, <=4 experts) and run through one train step (loss +
grads), one prefill and one decode step on CPU, asserting output shapes
and absence of NaNs.  The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation) — see launch/dryrun.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import get_model
from repro.models.lm import padded_vocab

ARCHS = list_archs()
SEQ = 32
BATCH = 2


def _bundle(arch):
    cfg = get_config(arch, reduced=True)
    return cfg, get_model(cfg)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_shapes_finite(arch):
    cfg, m = _bundle(arch)
    params = m.init(jax.random.PRNGKey(0))
    leaves = jax.tree.leaves(params)
    assert leaves, arch
    for leaf in leaves:
        assert jnp.all(jnp.isfinite(leaf.astype(jnp.float32))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg, m = _bundle(arch)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    batch = m.make_batch(rng, "train", BATCH, SEQ)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            m.loss, has_aux=True)(p, b, remat=False, data_shards=1)
        return loss, metrics, grads

    loss, metrics, grads = step(params, batch)
    assert jnp.isfinite(loss), (arch, loss)
    assert float(loss) > 0
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gnorm) and float(gnorm) > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode(arch):
    cfg, m = _bundle(arch)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    cache_len = SEQ + 8
    batch = m.make_batch(rng, "prefill", BATCH, SEQ)
    logits, cache = jax.jit(
        lambda p, b: m.prefill(p, b, cache_len=cache_len))(params, batch)
    vp = padded_vocab(cfg)
    assert logits.shape == (BATCH, vp), (arch, logits.shape)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32))), arch

    prompt_len = SEQ + (cfg.n_vision_tokens if cfg.family == "vlm" else 0)
    tok = jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)
    lengths = jnp.full((BATCH,), prompt_len, jnp.int32)
    decode = jax.jit(lambda p, c, t, l: m.decode(p, c, t, l))
    for step_i in range(3):
        logits, cache = decode(params, cache, tok[:, None], lengths)
        assert logits.shape == (BATCH, vp), arch
        assert jnp.all(jnp.isfinite(logits.astype(jnp.float32))), arch
        tok = jnp.argmax(logits[:, :cfg.vocab], axis=-1).astype(jnp.int32)
        lengths = lengths + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill_continuation(arch):
    """Teacher-forcing consistency: prefill(t0..tn) last logits must match
    decoding token t_n with cache built from prefill(t0..t_{n-1})."""
    cfg, m = _bundle(arch)
    if cfg.family in ("vlm",):
        pytest.skip("vlm prefix offsets exercised in test_prefill")
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    full = m.make_batch(rng, "prefill", BATCH, SEQ)
    cache_len = SEQ + 4
    part = {k: (v[:, :SEQ - 1] if k == "tokens" else v)
            for k, v in full.items()}
    logits_full, _ = jax.jit(
        lambda p, b: m.prefill(p, b, cache_len=cache_len))(params, full)
    _, cache = jax.jit(
        lambda p, b: m.prefill(p, b, cache_len=cache_len))(params, part)
    lengths = jnp.full((BATCH,), SEQ - 1, jnp.int32)
    logits_dec, _ = jax.jit(
        lambda p, c, t, l: m.decode(p, c, t, l))(
            params, cache, full["tokens"][:, SEQ - 1:SEQ], lengths)
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(logits_full, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    """Analytic param count must be in the ballpark the name claims."""
    cfg = get_config(arch)
    n = cfg.n_params()
    expected = {
        "phi4-mini-3.8b": 3.8e9, "mamba2-780m": 0.78e9,
        "qwen3-32b": 32.8e9, "phi3-mini-3.8b": 3.8e9,
        "deepseek-moe-16b": 16.4e9, "yi-6b": 6.1e9,
        "qwen3-moe-30b-a3b": 30.5e9, "paligemma-3b": 2.9e9,
        "whisper-large-v3": 1.55e9, "zamba2-1.2b": 1.2e9,
    }[arch]
    assert 0.6 * expected < n < 1.45 * expected, (arch, n, expected)
