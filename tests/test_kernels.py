"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes and dtypes, plus the vendor-tag swap behaviour."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels import ref as R


def _rand(rng, shape, dtype):
    if dtype == np.int8:
        return rng.integers(-128, 128, shape, dtype=np.int8)
    return rng.normal(0, 1, shape).astype(dtype)


# ---------------------------------------------------------------------------
# quant_matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [
    (1, 16, 8), (8, 64, 32), (128, 128, 128), (100, 96, 40),
    (256, 512, 64), (3, 300, 7),
])
def test_quant_matmul_shapes(m, k, n):
    rng = np.random.default_rng(m * 1000 + k + n)
    x = _rand(rng, (m, k), np.int8)
    w = _rand(rng, (k, n), np.int8)
    bias = rng.integers(-500, 500, (n,), dtype=np.int32)
    scale = rng.uniform(1e-4, 5e-3, (n,)).astype(np.float32)
    x_zp, out_zp = int(rng.integers(-10, 10)), int(rng.integers(-10, 10))
    got = ops.quant_matmul(jnp.asarray(x), jnp.asarray(w),
                           jnp.asarray(bias), x_zp, jnp.asarray(scale),
                           out_zp)
    want = R.quant_matmul_ref(jnp.asarray(x), jnp.asarray(w),
                              jnp.asarray(bias), x_zp, jnp.asarray(scale),
                              out_zp)
    diff = np.abs(np.asarray(got, np.int32) - np.asarray(want, np.int32))
    assert diff.max() <= 1                 # f32-requant vs round: ≤1 LSB


def test_quant_matmul_no_bias():
    rng = np.random.default_rng(0)
    x = _rand(rng, (16, 32), np.int8)
    w = _rand(rng, (32, 16), np.int8)
    scale = np.full((16,), 1e-3, np.float32)
    got = ops.quant_matmul(jnp.asarray(x), jnp.asarray(w), None, 0,
                           jnp.asarray(scale), 0)
    want = R.quant_matmul_ref(jnp.asarray(x), jnp.asarray(w), None, 0,
                              jnp.asarray(scale), 0)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,kh,s,d", [
    (1, 2, 2, 128, 32), (2, 4, 2, 256, 64), (1, 8, 1, 128, 64),
    (1, 4, 4, 512, 16),
])
@pytest.mark.parametrize("dtype", [np.float32, "bfloat16"])
def test_flash_attention_sweep(b, h, kh, s, d, dtype):
    rng = np.random.default_rng(b + h + s)
    if dtype == "bfloat16":
        import ml_dtypes
        dtype = ml_dtypes.bfloat16
        tol = 2e-2
    else:
        tol = 2e-5
    q = rng.normal(0, 1, (b, h, s, d)).astype(dtype)
    k = rng.normal(0, 1, (b, kh, s, d)).astype(dtype)
    v = rng.normal(0, 1, (b, kh, s, d)).astype(dtype)
    got = ops.flash_attention(jnp.asarray(q), jnp.asarray(k),
                              jnp.asarray(v), causal=True)
    want = R.mha_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                     causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 64, 128])
def test_flash_attention_sliding_window(window):
    rng = np.random.default_rng(window)
    q = rng.normal(0, 1, (1, 2, 256, 32)).astype(np.float32)
    k = rng.normal(0, 1, (1, 2, 256, 32)).astype(np.float32)
    v = rng.normal(0, 1, (1, 2, 256, 32)).astype(np.float32)
    got = ops.flash_attention(jnp.asarray(q), jnp.asarray(k),
                              jnp.asarray(v), causal=True, window=window)
    want = R.mha_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                     causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_noncausal():
    rng = np.random.default_rng(7)
    q = rng.normal(0, 1, (1, 2, 128, 32)).astype(np.float32)
    k = rng.normal(0, 1, (1, 2, 128, 32)).astype(np.float32)
    v = rng.normal(0, 1, (1, 2, 128, 32)).astype(np.float32)
    got = ops.flash_attention(jnp.asarray(q), jnp.asarray(k),
                              jnp.asarray(v), causal=False)
    want = R.mha_ref(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                     causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,kh,s,d", [
    (1, 4, 1, 256, 64), (2, 8, 2, 512, 64), (4, 4, 4, 128, 32),
])
@pytest.mark.parametrize("window", [None, 64])
def test_decode_attention_sweep(b, h, kh, s, d, window):
    rng = np.random.default_rng(b * 10 + h)
    q = rng.normal(0, 1, (b, h, d)).astype(np.float32)
    k = rng.normal(0, 1, (b, kh, s, d)).astype(np.float32)
    v = rng.normal(0, 1, (b, kh, s, d)).astype(np.float32)
    lengths = rng.integers(max(1, window or 1), s + 1, (b,)
                           ).astype(np.int32)
    got = ops.decode_attention(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), lengths, window=window)
    want = R.decode_attention_ref(jnp.asarray(q), jnp.asarray(k),
                                  jnp.asarray(v), jnp.asarray(lengths),
                                  window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_len1():
    """Degenerate cache with a single valid entry: output == v[0]."""
    rng = np.random.default_rng(3)
    q = rng.normal(0, 1, (1, 2, 16)).astype(np.float32)
    k = rng.normal(0, 1, (1, 2, 128, 16)).astype(np.float32)
    v = rng.normal(0, 1, (1, 2, 128, 16)).astype(np.float32)
    got = ops.decode_attention(jnp.asarray(q), jnp.asarray(k),
                               jnp.asarray(v), np.array([1], np.int32))
    np.testing.assert_allclose(np.asarray(got)[0], v[0, :, 0, :],
                               atol=1e-6)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,p,g,n", [
    (1, 128, 2, 16, 1, 32), (2, 256, 4, 32, 2, 64), (1, 512, 2, 64, 1, 16),
])
def test_ssd_scan_sweep(b, s, h, p, g, n):
    rng = np.random.default_rng(s + h)
    x = rng.normal(0, 1, (b, s, h, p)).astype(np.float32)
    dt = rng.uniform(0.001, 0.1, (b, s, h)).astype(np.float32)
    A = -rng.uniform(0.5, 2.0, (h,)).astype(np.float32)
    Bm = rng.normal(0, 1, (b, s, g, n)).astype(np.float32)
    Cm = rng.normal(0, 1, (b, s, g, n)).astype(np.float32)
    D = rng.normal(0, 1, (h,)).astype(np.float32)
    y, st = ops.ssd_scan(*map(jnp.asarray, (x, dt, A, Bm, Cm, D)))
    yr, sr = R.ssd_ref(*map(jnp.asarray, (x, dt, A, Bm, Cm, D)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr),
                               atol=5e-4, rtol=1e-3)


def test_ssd_scan_chunk_invariance():
    """Different chunk sizes must give identical results (the chunked dual
    form is exact, not an approximation)."""
    rng = np.random.default_rng(11)
    args = (rng.normal(0, 1, (1, 256, 2, 16)).astype(np.float32),
            rng.uniform(0.001, 0.1, (1, 256, 2)).astype(np.float32),
            -rng.uniform(0.5, 2.0, (2,)).astype(np.float32),
            rng.normal(0, 1, (1, 256, 1, 32)).astype(np.float32),
            rng.normal(0, 1, (1, 256, 1, 32)).astype(np.float32),
            None)
    y64, s64 = ops.ssd_scan(*args, chunk=64)
    y128, s128 = ops.ssd_scan(*args, chunk=128)
    np.testing.assert_allclose(np.asarray(y64), np.asarray(y128),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s64), np.asarray(s128),
                               atol=1e-4, rtol=1e-4)


def test_ssd_no_d_skip():
    rng = np.random.default_rng(13)
    x = rng.normal(0, 1, (1, 128, 2, 16)).astype(np.float32)
    dt = rng.uniform(0.001, 0.1, (1, 128, 2)).astype(np.float32)
    A = -np.ones((2,), np.float32)
    Bm = rng.normal(0, 1, (1, 128, 1, 16)).astype(np.float32)
    Cm = rng.normal(0, 1, (1, 128, 1, 16)).astype(np.float32)
    y, _ = ops.ssd_scan(*map(jnp.asarray, (x, dt, A, Bm, Cm)), None)
    yr, _ = R.ssd_ref(*map(jnp.asarray, (x, dt, A, Bm, Cm)), None)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=5e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# vendor-tag swap (§4.8): pallas kernels via the resolver
# ---------------------------------------------------------------------------

def test_pallas_tag_swaps_into_interpreter():
    from repro.apps import build_conv_reference
    from repro.apps.models import representative_dataset
    from repro.core import (AllOpsResolver, MicroInterpreter, MicroModel,
                            export)
    import repro.kernels.ops  # noqa: F401  (registers pallas tag)

    gb = build_conv_reference()
    ds = representative_dataset(gb, n=2)
    model = MicroModel(export(gb, representative_dataset=ds,
                              quantize_int8=True))
    x = np.random.default_rng(5).normal(0, 1, (1, 16, 16, 1)
                                        ).astype(np.float32)

    ref_res = AllOpsResolver(tags=("reference",))
    opt_res = AllOpsResolver(tags=("pallas", "reference"))
    fc_ref = ref_res.resolve(2)           # FULLY_CONNECTED
    fc_opt = opt_res.resolve(2)
    assert fc_ref.tag == "reference" and fc_opt.tag == "pallas"

    outs = []
    for res in (ref_res, opt_res):
        size = MicroInterpreter.required_arena_size(model, res)
        it = MicroInterpreter(model, res, size)
        it.set_input(0, x)
        it.invoke()
        outs.append(it.output(0))
    # optimized-vs-reference may differ by ≤1 LSB of the output scale
    assert np.abs(outs[0] - outs[1]).max() <= 1.5 / 256.0
