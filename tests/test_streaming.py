"""Streaming emission invariants under churn (docs/STREAMING.md).

Property tests for the per-token callback contract with REAL overlapped
engines behind a rebalancing ``ReplicaRouter`` — the full stack a
streamed token crosses in production: admission, deferred readback,
forced preemption/restore, and work-stealing queue migration all churn
while one shared event sink records every ``StreamEvent`` the fleet
emits.  For every request, whatever the churn:

  * event indices run 0, 1, 2, … strictly increasing from zero;
  * the event stream IS the accumulated output — same tokens, same
    order, callback count == emitted count (nothing dropped, nothing
    double-emitted across evict/restore or queue migration);
  * the TTFT stamp (``first_token_us``) is the first event's timestamp
    and no later inter-token stamp precedes it (monotone t_us);
  * exactly the last event carries ``final``.

Following tests/test_replica_router.py, hypothesis-driven sweeps engage
when ``hypothesis`` is installed and skip cleanly when it is not; a
seeded deterministic churn sweep covers the same invariants either way.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.executor import jit_cache_size
from repro.models import get_model
from repro.serving import ReplicaRouter, Request, ServingEngine

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAS_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAS_HYPOTHESIS, reason="hypothesis not installed")

ARCH = "qwen3-32b"
CACHE_LEN = 64
N_NEW = 3

_SETUP = {}


def _setup():
    if not _SETUP:
        cfg = get_config(ARCH, reduced=True)
        m = get_model(cfg)
        _SETUP["v"] = (cfg, m, m.init(jax.random.PRNGKey(0)))
    return _SETUP["v"]


def _force_preempt(router):
    """Evict one busy slot somewhere in the fleet (drain first — the
    quiesce-before-surgery contract)."""
    for eng in router.replicas:
        eng.drain()
        victim = next((s for s in range(eng.max_slots)
                       if eng.active[s]), None)
        if victim is not None:
            eng._evict(victim)
            return True
    return False


def _churn(ops):
    """Drive two overlapped replicas through a submit/step/preempt op
    sequence (0 = router tick, 3 = forced preempt, else submit that
    many requests), drain, and assert every streaming invariant."""
    cfg, m, params = _setup()
    engs = [ServingEngine(m, params, max_slots=2, cache_len=CACHE_LEN,
                          prefill_buckets=False, overlap=True)
            for _ in range(2)]
    router = ReplicaRouter(engs, routing="least-loaded", rebalance=True)
    events = []
    router.set_on_token(events.append)
    rng = np.random.default_rng(13)
    uid = 0
    preempted = False
    for op in ops:
        if op == 0:
            router.step()
        elif op == 3:
            preempted = _force_preempt(router) or preempted
        else:
            for _ in range(min(op, 2)):
                toks = rng.integers(0, cfg.vocab - 2,
                                    int(rng.integers(5, 12))
                                    ).astype(np.int32)
                router.submit(Request(uid=uid, tokens=toks,
                                      max_new_tokens=N_NEW))
                uid += 1
    res = router.run()
    router.drain()

    assert set(res) == set(range(uid))
    per = {}
    for ev in events:
        per.setdefault(ev.uid, []).append(ev)
    for u, r in res.items():
        assert r.done, u
        evs = per.get(u, [])
        # nothing dropped, nothing double-emitted: the event stream IS
        # the output, indices strictly increasing from 0
        assert len(evs) == len(r.output), u
        assert [e.index for e in evs] == list(range(len(evs))), u
        assert [e.token for e in evs] == r.output, u
        # TTFT stamp = first event; no inter-token stamp precedes it
        ts = [e.t_us for e in evs]
        assert ts == sorted(ts), u
        assert r.first_token_us == ts[0], u
        assert all(r.first_token_us <= t for t in ts), u
        assert [e.final for e in evs] == \
            [False] * (len(evs) - 1) + [True], u
    for eng in engs:
        assert jit_cache_size(eng._decode) == 1
    return preempted, router


def test_streaming_invariants_deterministic():
    """Seeded churn sweep (the always-on fallback): bursty submits,
    ticks, a forced mid-stream preempt, and rebalancer stealing never
    break the exactly-once ordered-emission contract."""
    # hand-picked to exercise every op: burst, tick, preempt, refill
    preempted, router = _churn([2, 0, 0, 3, 2, 0, 1, 3, 0])
    assert preempted, "churn never managed to preempt a running slot"
    assert sum(r.preemptions
               for r in router.results.values()) >= 1


if HAS_HYPOTHESIS:
    @needs_hypothesis
    @pytest.mark.slow
    @settings(max_examples=6, deadline=None)
    @given(ops=st.lists(st.integers(0, 3), min_size=2, max_size=9))
    def test_streaming_invariants_hypothesis(ops):
        """Hypothesis sweep of the same invariants over arbitrary
        admit/tick/preempt interleavings."""
        _churn(ops)
