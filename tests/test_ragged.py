"""Ragged continuation-aware batched invoke: lane admission/retirement
bit-identity, no-recompile occupancy changes, ArenaPool double
buffering, and the host's unified micro+pod scheduler."""

import numpy as np
import pytest

from repro.apps import build_conv_reference, build_fc_stack, build_hotword
from repro.apps.models import representative_dataset
from repro.core import (AllOpsResolver, ArenaPool, MicroInterpreter,
                        MicroModel, RaggedInterpreterPool, export)


@pytest.fixture(scope="module")
def resolver():
    return AllOpsResolver()


@pytest.fixture(scope="module")
def fc_int8():
    gb = build_fc_stack()
    return MicroModel(export(
        gb, representative_dataset=representative_dataset(gb),
        quantize_int8=True))


@pytest.fixture(scope="module")
def conv_int8():
    gb = build_conv_reference()
    return MicroModel(export(
        gb, representative_dataset=representative_dataset(gb),
        quantize_int8=True))


@pytest.fixture(scope="module")
def hotword():
    return MicroModel(export(build_hotword(n_layers=1)))


def _alone(model, resolver, frames):
    """Each request alone through MicroInterpreter.invoke — the
    bit-identity reference (fresh interpreter, fresh variable state)."""
    it = MicroInterpreter(
        model, resolver, MicroInterpreter.required_arena_size(model,
                                                              resolver))
    outs = []
    for f in frames:
        it.set_input(0, f)
        it.invoke()
        outs.append(it.output(0).copy())
    return outs


# ---------------------------------------------------------------------------
# the satellite requirement: retire mid-flight, stay bit-identical
# ---------------------------------------------------------------------------

def test_retire_midflight_bit_identity_int8(conv_int8, resolver):
    """Lanes retired mid-flight: remaining lanes' outputs stay
    bit-identical to running each request alone (int8 is integer-exact
    even under the vmapped throughput lowering)."""
    rng = np.random.default_rng(0)
    xs = [rng.normal(0, 1, (1, 16, 16, 1)).astype(np.float32)
          for _ in range(6)]
    want = [_alone(conv_int8, resolver, [x])[0] for x in xs]

    pool = RaggedInterpreterPool()
    pool.add_bucket("conv", conv_int8, resolver, lanes=4)
    slots = {i: pool.admit("conv", uid=i) for i in range(4)}
    for i, slot in slots.items():
        pool.set_input("conv", slot, 0, xs[i])
    pool.dispatch()
    for i, slot in slots.items():
        np.testing.assert_array_equal(pool.output("conv", slot, 0),
                                      want[i])
    # retire two lanes mid-flight, admit the remaining requests there
    for i in (0, 2):
        pool.retire("conv", slots.pop(i))
    slots[4] = pool.admit("conv", uid=4)
    slots[5] = pool.admit("conv", uid=5)
    for i, slot in slots.items():
        pool.set_input("conv", slot, 0, xs[i])
    pool.dispatch()
    for i, slot in slots.items():
        np.testing.assert_array_equal(pool.output("conv", slot, 0),
                                      want[i])


def test_ragged_streaming_continuation_bit_identity(hotword, resolver):
    """Ragged request lengths (1/2/3 frames) with per-lane continuation
    state: every lane must match its own dedicated interpreter at every
    step, including after neighbours retired mid-flight (exact lowering
    => bit-identical for float too)."""
    rng = np.random.default_rng(1)
    reqs = {uid: [rng.normal(0, 1, (1, 40)).astype(np.float32)
                  for _ in range(n)]
            for uid, n in enumerate((1, 2, 3))}
    want = {uid: _alone(hotword, resolver, frames)
            for uid, frames in reqs.items()}

    pool = RaggedInterpreterPool()
    pool.add_bucket("hw", hotword, resolver, lanes=3, exact=True)
    slots = {uid: pool.admit("hw", uid=uid) for uid in reqs}
    live = dict(slots)
    step = 0
    while live:
        for uid, slot in live.items():
            pool.set_input("hw", slot, 0, reqs[uid][step])
        pool.dispatch()
        for uid, slot in list(live.items()):
            got = pool.output("hw", slot, 0)
            np.testing.assert_array_equal(got, want[uid][step])
            if step + 1 == len(reqs[uid]):
                pool.retire("hw", slot)     # mid-flight retirement
                del live[uid]
        step += 1
    assert pool.occupancy() == 0.0


def test_lane_state_isolated_from_retired_neighbour(hotword, resolver):
    """A lane admitted into a retired slot starts from FRESH variable
    state; a surviving lane's continuation is unaffected by the churn."""
    rng = np.random.default_rng(2)
    a = [rng.normal(0, 1, (1, 40)).astype(np.float32) for _ in range(3)]
    b = rng.normal(0, 1, (1, 40)).astype(np.float32)
    c = rng.normal(0, 1, (1, 40)).astype(np.float32)

    pool = RaggedInterpreterPool()
    pool.add_bucket("hw", hotword, resolver, lanes=2, exact=True)
    sa = pool.admit("hw", uid=0)
    sb = pool.admit("hw", uid=1)
    pool.set_input("hw", sa, 0, a[0])
    pool.set_input("hw", sb, 0, b)
    pool.dispatch()
    pool.retire("hw", sb)
    sc = pool.admit("hw", uid=2)            # reuses slot sb
    assert sc == sb
    pool.set_input("hw", sa, 0, a[1])
    pool.set_input("hw", sc, 0, c)
    pool.dispatch()
    pool.set_input("hw", sa, 0, a[2])
    pool.set_input("hw", sc, 0, c)
    pool.dispatch()
    np.testing.assert_array_equal(
        pool.output("hw", sa, 0), _alone(hotword, resolver, a)[2])
    np.testing.assert_array_equal(
        pool.output("hw", sc, 0), _alone(hotword, resolver, [c, c])[1])


# ---------------------------------------------------------------------------
# occupancy changes never recompile; the lane table tracks lifecycle
# ---------------------------------------------------------------------------

def test_admission_retirement_never_recompiles(fc_int8, resolver):
    rng = np.random.default_rng(3)
    pool = RaggedInterpreterPool()
    pool.add_bucket("fc", fc_int8, resolver, lanes=4)
    bucket = pool._buckets["fc"]
    for occupancy in (1, 3, 2, 4):
        slots = [pool.admit("fc") for _ in range(occupancy)]
        for slot in slots:
            pool.set_input("fc", slot, 0,
                           rng.normal(0, 1, (1, 64)).astype(np.float32))
        pool.dispatch()
        for slot in slots:
            pool.retire("fc", slot)
    # one masked program covered every occupancy from 1..lanes
    assert len(bucket.compiled._batched) == 1
    assert bucket.dispatch_count == 4


def test_lane_table_tracks_buckets_steps_lifecycle(fc_int8, hotword,
                                                   resolver):
    rng = np.random.default_rng(4)
    pool = RaggedInterpreterPool()
    pool.add_bucket("fc", fc_int8, resolver, lanes=2)
    pool.add_bucket("hw", hotword, resolver, lanes=2, exact=True)
    assert len(pool.lane_table) == 4
    s = pool.admit("fc", uid=7)
    h = pool.admit("hw", uid=8)
    pool.set_input("fc", s, 0, rng.normal(0, 1, (1, 64)).astype(np.float32))
    pool.set_input("hw", h, 0, rng.normal(0, 1, (1, 40)).astype(np.float32))
    assert pool.dispatch() == 2             # one lane per bucket advanced
    lane = pool.lanes("fc")[s]
    assert (lane.bucket, lane.uid, lane.step, lane.active) == \
        ("fc", 7, 1, True)
    assert pool.occupancy() == 0.5
    pool.retire("fc", s)
    assert not pool.lanes("fc")[s].active
    assert pool.free_lanes("fc") == [0, 1]


def test_dispatch_atomic_across_buckets(fc_int8, hotword, resolver):
    """A staging error in ANY bucket must abort the whole dispatch with
    no lane advanced and no inputs consumed — restage and retry."""
    rng = np.random.default_rng(9)
    pool = RaggedInterpreterPool()
    pool.add_bucket("fc", fc_int8, resolver, lanes=2)
    pool.add_bucket("hw", hotword, resolver, lanes=2, exact=True)
    sf = pool.admit("fc", uid=0)
    sh = pool.admit("hw", uid=1)
    x = rng.normal(0, 1, (1, 64)).astype(np.float32)
    f = rng.normal(0, 1, (1, 40)).astype(np.float32)
    pool.set_input("fc", sf, 0, x)          # "hw" lane left unstaged
    with pytest.raises(RuntimeError):
        pool.dispatch()
    assert pool.lanes("fc")[sf].step == 0   # nothing advanced
    assert pool.lanes("hw")[sh].step == 0
    pool.set_input("hw", sh, 0, f)          # fc's staged input survived
    assert pool.dispatch() == 2
    np.testing.assert_array_equal(pool.output("fc", sf, 0),
                                  _alone(fc_int8, resolver, [x])[0])
    np.testing.assert_array_equal(pool.output("hw", sh, 0),
                                  _alone(hotword, resolver, [f])[0])


def test_ragged_pool_input_contract(fc_int8, resolver):
    pool = RaggedInterpreterPool()
    pool.add_bucket("fc", fc_int8, resolver, lanes=2)
    with pytest.raises(RuntimeError):       # inactive lane
        pool.set_input("fc", 0, 0, np.zeros((1, 64), np.float32))
    slot = pool.admit("fc")
    with pytest.raises(ValueError):         # wrong shape
        pool.set_input("fc", slot, 0, np.zeros((1, 3), np.float32))
    with pytest.raises(RuntimeError):       # active lane missing inputs
        pool.dispatch()
    pool.admit("fc")
    with pytest.raises(RuntimeError):       # bucket full
        pool.admit("fc")
    with pytest.raises(ValueError):         # duplicate bucket
        pool.add_bucket("fc", fc_int8, resolver, lanes=2)


# ---------------------------------------------------------------------------
# ArenaPool double buffering
# ---------------------------------------------------------------------------

def test_arena_pool_double_buffer_free_list():
    pool = ArenaPool(depth=2)
    pool.ensure(256)
    a = pool.take_batch(4)
    b = pool.take_batch(4)              # second in-flight buffer
    assert pool.alloc_count == 2
    pool.put_batch(a)
    pool.put_batch(b)
    # steady state: the same two physical buffers cycle, no new allocs
    for _ in range(3):
        x = pool.take_batch(4)
        y = pool.take_batch(4)
        pool.put_batch(x)
        pool.put_batch(y)
    assert pool.alloc_count == 2
    # the free list never holds more than `depth` buffers
    c = pool._alloc((4, pool.nbytes))
    pool.put_batch(c)
    assert len(pool._batched[4]) == 2


def test_ragged_dispatch_steady_state_allocs(fc_int8, resolver):
    """After warm-up, repeated ragged waves draw from the pooled
    (donated) buffers only."""
    rng = np.random.default_rng(5)
    pool = RaggedInterpreterPool()
    pool.add_bucket("fc", fc_int8, resolver, lanes=4)
    slots = [pool.admit("fc") for _ in range(2)]

    def wave():
        for slot in slots:
            pool.set_input("fc", slot, 0,
                           rng.normal(0, 1, (1, 64)).astype(np.float32))
        pool.dispatch()
        pool.outputs("fc", 0)

    wave()                                  # warm-up
    allocs = pool.pool.alloc_count
    for _ in range(4):
        wave()
    assert pool.pool.alloc_count == allocs


# ---------------------------------------------------------------------------
# the host's unified scheduler
# ---------------------------------------------------------------------------

def test_host_ragged_micro_bit_identity(fc_int8, hotword, resolver):
    """Mixed int8-FC + streaming-SVDF micro tenants drain through
    run_all with more requests than lanes; every result is bit-identical
    to running that request alone through MicroInterpreter.invoke."""
    from repro.serving import MultiTenantHost

    rng = np.random.default_rng(6)
    host = MultiTenantHost(arena_bytes=64 << 20)
    host.add_ragged_micro("fc", fc_int8, resolver, lanes=2)
    host.add_ragged_micro("hw", hotword, resolver, lanes=2, exact=True)

    fc_reqs = {i: [rng.normal(0, 1, (1, 64)).astype(np.float32)]
               for i in range(5)}
    hw_reqs = {i: [rng.normal(0, 1, (1, 40)).astype(np.float32)
                   for _ in range(n)]
               for i, n in enumerate((2, 1, 3))}
    for uid, frames in fc_reqs.items():
        host.submit_micro("fc", uid, [[f] for f in frames])
    for uid, frames in hw_reqs.items():
        host.submit_micro("hw", uid, [[f] for f in frames])
    host.run_all()

    for uid, frames in fc_reqs.items():
        res = host.micro_results["fc"][uid]
        assert res.done and res.steps == len(frames)
        np.testing.assert_array_equal(
            res.outputs[-1], _alone(fc_int8, resolver, frames)[-1])
    for uid, frames in hw_reqs.items():
        res = host.micro_results["hw"][uid]
        assert res.done and res.steps == len(frames)
        for got, want in zip(res.outputs,
                             _alone(hotword, resolver, frames)):
            np.testing.assert_array_equal(got, want)


def test_host_mixed_micro_pod_one_scheduler(fc_int8, resolver):
    """An int8 FC micro tenant and a pod-scale ServingEngine tenant in
    ONE host, drained by ONE run_all: the engine's tokens match a
    solo-engine run and the micro results stay bit-identical."""
    import jax
    from repro.configs import get_config
    from repro.models import get_model
    from repro.serving import MultiTenantHost, Request, ServingEngine

    cfg = get_config("qwen3-32b", reduced=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    prompt = np.arange(1, 6, dtype=np.int32)

    solo = ServingEngine(m, params, max_slots=1, cache_len=32)
    solo.submit(Request(uid=1, tokens=prompt, max_new_tokens=3))
    want_tokens = solo.run()[1].output

    rng = np.random.default_rng(7)
    host = MultiTenantHost(arena_bytes=256 << 20)
    host.add_model("lm", m, params, max_slots=1, cache_len=32)
    host.add_ragged_micro("fc", fc_int8, resolver, lanes=2)
    xs = [rng.normal(0, 1, (1, 64)).astype(np.float32) for _ in range(3)]
    for uid, x in enumerate(xs):
        host.submit_micro("fc", uid, [[x]])
    host.submit("lm", Request(uid=1, tokens=prompt, max_new_tokens=3))
    results = host.run_all()

    assert results["lm"][1].output == want_tokens
    for uid, x in enumerate(xs):
        res = host.micro_results["fc"][uid]
        assert res.done
        np.testing.assert_array_equal(
            res.outputs[0], _alone(fc_int8, resolver, [x])[0])


@pytest.mark.slow
def test_ragged_half_occupancy_beats_sequential(fc_int8, resolver):
    """The acceptance throughput bar, conservatively: at 50% occupancy
    of a 16-lane bucket the per-request dispatch cost must undercut a
    sequential single invoke by >= 2x (benchmark measures ~6x)."""
    import time

    rng = np.random.default_rng(8)
    xs = [rng.normal(0, 1, (1, 64)).astype(np.float32) for _ in range(8)]

    it = MicroInterpreter(
        fc_int8, resolver,
        MicroInterpreter.required_arena_size(fc_int8, resolver))

    def sequential():
        it.set_input(0, xs[0])
        it.invoke()
        it.output(0)

    pool = RaggedInterpreterPool()
    pool.add_bucket("fc", fc_int8, resolver, lanes=16)
    slots = [pool.admit("fc") for _ in range(8)]

    def wave():
        for i, slot in enumerate(slots):
            pool.set_input("fc", slot, 0, xs[i])
        pool.dispatch()
        pool.outputs("fc", 0)

    def median(fn, iters=30):
        fn(), fn()                          # warm-up / compile
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        return sorted(ts)[iters // 2]

    t_seq = median(sequential)
    per_req = median(wave) / len(slots)
    assert t_seq / per_req >= 2.0, \
        f"ragged 50% occupancy only {t_seq / per_req:.2f}x vs sequential"
