"""Serving-engine + multitenancy tests (paper §4.1/§4.5 semantics at
pod scale)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import get_model
from repro.serving import MultiTenantHost, Request, ServingEngine


def _engine(arch="qwen3-32b", **kw):
    cfg = get_config(arch, reduced=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params, ServingEngine(m, params, **kw)


def _greedy_reference(cfg, m, params, prompt, n_new):
    """Oracle: full re-prefill per generated token (O(n^2) but exact)."""
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        batch = {"tokens": jnp.asarray(np.array(toks, np.int32)[None, :-1])}
        _, cache = m.prefill(params, batch, cache_len=len(toks) + 1)
        lengths = jnp.asarray([len(toks) - 1], jnp.int32)
        logits, _ = m.decode(params, cache,
                             jnp.asarray([[toks[-1]]], jnp.int32), lengths)
        nxt = int(jnp.argmax(logits[0, :cfg.vocab]))
        out.append(nxt)
        toks.append(nxt)
    return out


@pytest.mark.parametrize("arch", ["qwen3-32b", "mamba2-780m",
                                  "zamba2-1.2b"])
def test_engine_matches_reference(arch):
    cfg, m, params, eng = _engine(arch, max_slots=2, cache_len=64)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab - 2, 9).astype(np.int32)
    eng.submit(Request(uid=1, tokens=prompt, max_new_tokens=5))
    results = eng.run()
    got = results[1].output[:5]
    want = _greedy_reference(cfg, m, params, prompt, 5)
    assert got == want, (arch, got, want)


def test_continuous_batching_two_requests():
    cfg, m, params, eng = _engine(max_slots=2, cache_len=64)
    rng = np.random.default_rng(1)
    for uid in (1, 2, 3):           # 3 requests, 2 slots: queueing
        eng.submit(Request(uid=uid,
                           tokens=rng.integers(0, cfg.vocab - 2,
                                               5 + uid).astype(np.int32),
                           max_new_tokens=4))
    results = eng.run()
    assert set(results) == {1, 2, 3}
    for uid, res in results.items():
        assert res.done and len(res.output) >= 4, (uid, res)


def test_isolation_between_slots():
    """A second tenant in another slot must not change slot-1 output."""
    cfg, m, params, eng1 = _engine(max_slots=2, cache_len=64)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab - 2, 8).astype(np.int32)
    eng1.submit(Request(uid=1, tokens=prompt, max_new_tokens=5))
    alone = eng1.run()[1].output

    _, _, _, eng2 = _engine(max_slots=2, cache_len=64)
    eng2.params = params
    eng2.submit(Request(uid=1, tokens=prompt, max_new_tokens=5))
    eng2.submit(Request(uid=2,
                        tokens=rng.integers(0, cfg.vocab - 2,
                                            6).astype(np.int32),
                        max_new_tokens=5))
    together = eng2.run()[1].output
    assert alone == together


def test_multitenant_host_arena_accounting():
    host = MultiTenantHost(arena_bytes=256 << 20)
    outputs = {}
    for name, arch in (("lm", "qwen3-32b"), ("ssm", "mamba2-780m")):
        cfg = get_config(arch, reduced=True)
        m = get_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        host.add_model(name, m, params, max_slots=1, cache_len=32)
        rng = np.random.default_rng(3)
        prompt = rng.integers(0, cfg.vocab - 2, 6).astype(np.int32)
        host.submit(name, Request(uid=1, tokens=prompt, max_new_tokens=3))
        outputs[name] = (cfg, m, params, prompt)
    results = host.run_all()
    # persistent sections stacked: two tenants' KV both allocated
    usage = host.usage()
    assert usage.persistent > 0
    assert len(host.arena.tail_allocs) >= 2
    # outputs match single-tenant reference
    for name, (cfg, m, params, prompt) in outputs.items():
        want = _greedy_reference(cfg, m, params, prompt, 3)
        assert results[name][1].output[:3] == want, name


def test_no_allocation_growth_during_decode():
    """C3 at pod scale: the arena must not grow after engine init."""
    cfg, m, params, eng = _engine(max_slots=1, cache_len=64)
    tail0 = eng.arena.usage().persistent
    rng = np.random.default_rng(4)
    eng.submit(Request(uid=1,
                       tokens=rng.integers(0, cfg.vocab - 2,
                                           8).astype(np.int32),
                       max_new_tokens=6))
    eng.run()
    assert eng.arena.usage().persistent == tail0
