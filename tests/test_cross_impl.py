"""Cross-implementation consistency: the pod path's pure-jnp math vs
the kernel library's oracles/kernels (two independent implementations
of the same algorithms must agree)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_pod_ssd_matches_kernel_oracle():
    """models.ssm.ssd_chunked (grouped-head pod path) vs
    kernels.ref.ssd_ref (per-head sequential oracle)."""
    from repro.kernels import ref as R
    from repro.models.ssm import ssd_chunked

    rng = np.random.default_rng(0)
    b, s, h, p, g, n = 2, 64, 4, 8, 1, 16
    x = jnp.asarray(rng.normal(0, 1, (b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, h)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.normal(0, 1, (b, s, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(0, 1, (b, s, g, n)), jnp.float32)

    y_pod, st_pod = ssd_chunked(x, dt, A, B, C, chunk=16)
    y_ref, st_ref = R.ssd_ref(x, dt, A, B, C, None)
    np.testing.assert_allclose(np.asarray(y_pod), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)
    # final states agree too (pod layout (B,G,gh,P,N) vs ref (B,H,P,N))
    np.testing.assert_allclose(
        np.asarray(st_pod.reshape(st_ref.shape)), np.asarray(st_ref),
        rtol=2e-3, atol=2e-3)


def test_pod_chunked_attention_matches_kernel_oracle():
    """models.lm.chunked_attention vs kernels.ref.mha_ref."""
    from repro.configs import get_config
    from repro.kernels import ref as R
    from repro.models.lm import chunked_attention

    cfg = get_config("yi-6b", reduced=True)
    rng = np.random.default_rng(1)
    b, s, h, kh, dh = 2, 64, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, kh, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, kh, dh)), jnp.float32)
    got = chunked_attention(q, k, v, cfg, chunk=16)
    # oracle layout: (B,H,S,D), GQA by repeat
    g = h // kh
    want = R.mha_ref(q.transpose(0, 2, 1, 3),
                     jnp.repeat(k, g, axis=2).transpose(0, 2, 1, 3),
                     jnp.repeat(v, g, axis=2).transpose(0, 2, 1, 3),
                     causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_attention_prefill_pallas_backend_matches_reference():
    """models.attention backend='pallas' (interpret mode) vs reference."""
    from repro.configs import get_config
    from repro.models.attention import attention_prefill, init_attention

    cfg = get_config("phi3-mini-3.8b", reduced=True)
    key = jax.random.PRNGKey(0)
    p = init_attention(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (2, 32, cfg.d_model), jnp.float32)
    ref = attention_prefill(p, cfg, x, backend="reference")
    pal = attention_prefill(p, cfg, x, backend="pallas")
    np.testing.assert_allclose(np.asarray(pal), np.asarray(ref),
                               rtol=2e-2, atol=2e-3)
