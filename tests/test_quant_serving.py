"""Quantized pod serving (docs/QUANTIZATION.md): the SERVING_*_Q tag
chain, the int8/int4 weight layout, and the int8 per-head-scale KV
cache.

Three layers of gate:

  * **primitive properties** — int4 pack/unpack round-trips exactly
    for every value in [-8, 7] (jnp and the np export twins agree),
    and the per-head KV scale quantization bounds each element's
    error by half a quantization step (all-zero vectors exact).
    Following tests/test_streaming.py, hypothesis sweeps engage when
    installed; seeded deterministic sweeps cover the same properties
    either way.
  * **accuracy** — a quantized engine's logits track the fp engine
    within the DOCUMENTED per-family tolerance
    (benchmarks/quantized_decode.py carries the same table).
    Quantized serving is tolerance-gated, never bit-gated, against
    fp: rounding weight values is a semantics change, deliberately.
  * **self-identity** — what IS bit-gated: a quantized engine against
    itself across admit/preempt/restore (the compile-once contract's
    quantized leg, ``jit_cache_size == 1`` throughout), and the paged
    quantized engine against the contiguous one (paging stays a
    layout change under quantization).

Families outside the quantized matrix refuse with the same typed
errors as every other fast path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.executor import jit_cache_size
from repro.core.quantize import (dequantize_kv_heads, pack_int4,
                                 pack_int4_np, quantize_kv_heads,
                                 unpack_int4, unpack_int4_np)
from repro.models import get_model
from repro.serving import Request, ServingEngine, UnsupportedFamilyError

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAS_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAS_HYPOTHESIS, reason="hypothesis not installed")

ARCHS = {"dense": "qwen3-32b", "moe": "deepseek-moe-16b",
         "vlm": "paligemma-3b", "ssm": "mamba2-780m",
         "hybrid": "zamba2-1.2b", "audio": "whisper-large-v3"}
CACHE_LEN = 32
PROMPT_LEN = 6
N_NEW = 6
# documented max-abs logit tolerance vs the fp engine — the same
# numbers benchmarks/quantized_decode.py asserts (moe loosest: weight
# rounding can flip discrete expert routing; vlm amplifies embedding
# error through its sqrt(d_model) scale)
TOLERANCE = {
    "dense": {"int8": 0.5, "int4": 2.0},
    "moe": {"int8": 2.5, "int4": 4.0},
    "vlm": {"int8": 1.5, "int4": 4.0},
}

_SETUP = {}


def _setup(family):
    if family not in _SETUP:
        cfg = get_config(ARCHS[family], reduced=True)
        m = get_model(cfg)
        _SETUP[family] = (cfg, m, m.init(jax.random.PRNGKey(0)))
    return _SETUP[family]


# ---------------------------------------------------------------------------
# primitive properties: int4 packing
# ---------------------------------------------------------------------------

def _assert_int4_roundtrip(q):
    packed = pack_int4(q)
    assert packed.shape == (*q.shape[:-1], q.shape[-1] // 2)
    assert packed.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed)), q)
    # np export twins agree with the jnp pair byte-for-byte
    packed_np = pack_int4_np(q)
    np.testing.assert_array_equal(np.asarray(packed), packed_np)
    np.testing.assert_array_equal(unpack_int4_np(packed_np), q)


def test_int4_roundtrip_deterministic():
    rng = np.random.default_rng(7)
    for shape in ((2,), (4, 6), (3, 2, 8), (1, 16)):
        q = rng.integers(-8, 8, shape).astype(np.int8)
        _assert_int4_roundtrip(q)
    # every representable value, in both nibble positions
    q = np.array([[v, w] for v in range(-8, 8)
                  for w in range(-8, 8)], np.int8)
    _assert_int4_roundtrip(q)


def test_int4_odd_last_axis_refused():
    with pytest.raises(ValueError, match="even last axis"):
        pack_int4(np.zeros((2, 3), np.int8))


if HAS_HYPOTHESIS:
    @needs_hypothesis
    @settings(max_examples=40, deadline=None)
    @given(vals=st.lists(st.integers(-8, 7), min_size=2, max_size=32),
           lead=st.integers(1, 3))
    def test_int4_roundtrip_hypothesis(vals, lead):
        vals = vals[:len(vals) // 2 * 2]
        q = np.tile(np.asarray(vals, np.int8), (lead, 1))
        _assert_int4_roundtrip(q)


# ---------------------------------------------------------------------------
# primitive properties: per-head KV scale quantization
# ---------------------------------------------------------------------------

def _assert_kv_quant_bound(x):
    q, scales = quantize_kv_heads(x)
    assert q.dtype == jnp.int8
    assert scales.shape == x.shape[:-1]
    dq = np.asarray(dequantize_kv_heads(q, scales))
    # symmetric rounding: each element is off by at most half a step
    bound = np.asarray(scales)[..., None] * 0.5 + 1e-6
    assert np.all(np.abs(dq - np.asarray(x, np.float32)) <= bound)


def test_kv_head_quant_deterministic():
    rng = np.random.default_rng(3)
    for shape in ((4,), (2, 3, 8), (2, 1, 2, 4, 16)):
        _assert_kv_quant_bound(rng.normal(0, 2, shape)
                               .astype(np.float32))
    # all-zero head vectors dequantize EXACTLY (scale 1.0, q 0) — an
    # empty quantized cache is still an empty cache
    z = np.zeros((2, 3, 8), np.float32)
    q, scales = quantize_kv_heads(z)
    np.testing.assert_array_equal(np.asarray(q), 0)
    np.testing.assert_array_equal(np.asarray(scales), 1.0)
    np.testing.assert_array_equal(
        np.asarray(dequantize_kv_heads(q, scales)), 0.0)


if HAS_HYPOTHESIS:
    @needs_hypothesis
    @settings(max_examples=40, deadline=None)
    @given(vals=st.lists(
        st.floats(-1e4, 1e4, allow_nan=False, width=32),
        min_size=1, max_size=24),
        heads=st.integers(1, 4))
    def test_kv_head_quant_hypothesis(vals, heads):
        x = np.tile(np.asarray(vals, np.float32), (heads, 1))
        _assert_kv_quant_bound(x)


# ---------------------------------------------------------------------------
# engine-level helpers
# ---------------------------------------------------------------------------

def _engine(family, wd, kd, **kw):
    cfg, m, params = _setup(family)
    kw.setdefault("max_slots", 2)
    kw.setdefault("cache_len", CACHE_LEN)
    kw.setdefault("prefill_buckets", False)
    return ServingEngine(m, params, weight_dtype=wd, kv_dtype=kd, **kw)


def _vision(cfg, rng):
    return {"vision": rng.normal(
        0, 1, (cfg.n_vision_tokens, cfg.d_vision)).astype(np.float32)}


def _serve(family, wd, kd, *, evict=False, **kw):
    """Serve 4 seeded requests; optionally force a mid-run eviction.
    Returns ({uid: tokens}, engine)."""
    cfg, _, _ = _setup(family)
    eng = _engine(family, wd, kd, **kw)
    rng = np.random.default_rng(5)
    extras = _vision(cfg, rng) if cfg.family == "vlm" else None
    for uid in range(4):
        toks = rng.integers(0, cfg.vocab - 2,
                            PROMPT_LEN).astype(np.int32)
        eng.submit(Request(uid=uid, tokens=toks, max_new_tokens=N_NEW,
                           extras=extras))
    steps, more, evicted = 0, True, False
    while more:
        more = eng.step()
        steps += 1
        assert steps < 400, (family, wd, kd, "did not converge")
        if evict and not evicted and steps >= 3:
            victim = next((s for s in range(eng.max_slots)
                           if eng.active[s]), None)
            if victim is not None:
                eng._evict(victim)
                evicted = True
    assert not evict or evicted, (family, "nothing running to evict")
    return {u: list(eng.results[u].output) for u in range(4)}, eng


def _logit_err(family, wd, kd, steps=4):
    """Max abs logit error, quantized vs fp engine, over one prefill
    plus ``steps`` decode steps fed the same fp-argmax token stream."""
    cfg, _, _ = _setup(family)
    rng = np.random.default_rng(9)
    toks = rng.integers(0, cfg.vocab - 2, PROMPT_LEN).astype(np.int32)
    feng = _engine(family, None, None, max_slots=1)
    qeng = _engine(family, wd, kd, max_slots=1)
    batch = {"tokens": jnp.asarray(toks[:-1][None])}
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(
            _vision(cfg, rng)["vision"][None])
    lf, cf = feng._prefill((feng.params, batch))
    lq, cq = qeng._prefill((qeng.params, batch))
    err = float(jnp.max(jnp.abs(lf[..., :cfg.vocab]
                                - lq[..., :cfg.vocab])))
    pos = PROMPT_LEN - 1 + (cfg.n_vision_tokens
                            if cfg.family == "vlm" else 0)
    cur = int(toks[-1])
    for _ in range(steps):
        curs = jnp.asarray([[cur]], jnp.int32)
        lens = jnp.asarray([pos], jnp.int32)
        lf, cf = feng._decode((feng.params, cf, curs, lens))
        lq, cq = qeng._decode((qeng.params, cq, curs, lens))
        err = max(err, float(jnp.max(jnp.abs(
            lf[:, :cfg.vocab] - lq[:, :cfg.vocab]))))
        cur = int(jnp.argmax(lf[0, :cfg.vocab]))
        pos += 1
    return err


# ---------------------------------------------------------------------------
# accuracy: quantized vs fp, tolerance-gated per family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wd", ("int8", "int4"))
def test_dense_logit_tolerance(wd):
    err = _logit_err("dense", wd, "int8")
    assert 0 < err <= TOLERANCE["dense"][wd], (wd, err)


@pytest.mark.slow
@pytest.mark.parametrize("family", ("moe", "vlm"))
@pytest.mark.parametrize("wd", ("int8", "int4"))
def test_family_logit_tolerance(family, wd):
    err = _logit_err(family, wd, "int8")
    assert 0 < err <= TOLERANCE[family][wd], (family, wd, err)


# ---------------------------------------------------------------------------
# self-identity: the bit-exact contracts quantization must keep
# ---------------------------------------------------------------------------

def test_quantized_preempt_restore_identity():
    """The acceptance gate: int8/int8 decode is bit-identical to
    itself across admit/preempt/restore, with exactly one decode
    program throughout."""
    base, e0 = _serve("dense", "int8", "int8")
    again, e1 = _serve("dense", "int8", "int8", evict=True)
    assert base == again
    assert jit_cache_size(e0._decode) == 1
    assert jit_cache_size(e1._decode) == 1
    assert e1.results[0].preemptions + sum(
        e1.results[u].preemptions for u in range(4)) >= 1
    # quantization shrank the resident footprint (weights AND KV)
    fp, ef = _serve("dense", None, None)
    assert ef.param_bytes / e0.param_bytes >= 1.5
    assert ef.kv_bytes / e0.kv_bytes >= 1.5


def test_paged_quantized_matches_contiguous():
    """Paging stays a LAYOUT change under quantization: the paged
    int8/int8 engine decodes the contiguous engine's exact tokens
    (block-table kernel dequant included) from one compiled program."""
    contig, _ = _serve("dense", "int8", "int8")
    paged, eng = _serve("dense", "int8", "int8", evict=True,
                        kv_block=8, kv_pool_blocks=2 * 4 + 1)
    assert paged == contig
    assert jit_cache_size(eng._decode) == 1


@pytest.mark.parametrize("wd,kd", (("int8", None), (None, "int8"),
                                   ("int4", "int8")))
def test_quantized_axes_compose_independently(wd, kd):
    """Each quantization axis works alone and combined: weight-only,
    KV-only, and int4+int8 engines all keep the self-identity and
    compile-once contracts."""
    base, e0 = _serve("dense", wd, kd)
    again, e1 = _serve("dense", wd, kd, evict=True)
    assert base == again
    assert jit_cache_size(e0._decode) == 1
    assert jit_cache_size(e1._decode) == 1


# ---------------------------------------------------------------------------
# typed refusals
# ---------------------------------------------------------------------------

def test_unsupported_quantization_raises_typed_errors():
    cfg, m, params = _setup("audio")
    with pytest.raises(UnsupportedFamilyError, match="quantized"):
        ServingEngine(m, params, cache_len=CACHE_LEN,
                      weight_dtype="int8")
    scfg, sm, sparams = _setup("ssm")
    with pytest.raises(UnsupportedFamilyError, match="int8 KV"):
        ServingEngine(sm, sparams, cache_len=CACHE_LEN,
                      weight_dtype="int8", kv_dtype="int8")
    dcfg, dm, dparams = _setup("dense")
    with pytest.raises(ValueError, match="weight_dtype"):
        ServingEngine(dm, dparams, cache_len=CACHE_LEN,
                      weight_dtype="int2")
    with pytest.raises(ValueError, match="kv_dtype"):
        ServingEngine(dm, dparams, cache_len=CACHE_LEN,
                      kv_dtype="int4")
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServingEngine(dm, dparams, cache_len=CACHE_LEN,
                      weight_dtype="int8", prefill_chunk=8)
    with pytest.raises(ValueError, match="mesh"):
        ServingEngine(dm, dparams, cache_len=CACHE_LEN,
                      weight_dtype="int8", mesh=object())
