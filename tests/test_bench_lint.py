"""The bench-result contract, enforced: every committed
``benchmarks/results/BENCH_*.json`` is the exact layout
``benchmarks.common.save_result`` writes (meta block + flat rows of
finite scalars), so a broken writer — or a hand-edited artifact — can
never land silently (tools/check_bench.py, also a CI job)."""

import json
import pathlib
import sys

import pytest

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO_ROOT / "tools"))
sys.path.insert(0, str(REPO_ROOT))      # for `benchmarks.common`

import check_bench  # noqa: E402


def _scaffold(tmp_path, name, payload) -> pathlib.Path:
    results = tmp_path / "benchmarks" / "results"
    results.mkdir(parents=True, exist_ok=True)
    p = results / name
    p.write_text(payload if isinstance(payload, str)
                 else json.dumps(payload))
    return p


GOOD_META = {"schema": 1, "jax": "0.4.37", "backend": "cpu", "seed": 0,
             "created_utc": "2026-01-01T00:00:00Z"}


def test_committed_results_are_valid():
    violations = check_bench.collect_violations()
    assert not violations, "\n".join(
        f"{rel}: {msg}" for rel, msg in violations)


def test_save_result_layout_passes_the_lint(tmp_path, monkeypatch):
    """What save_result writes is what check_bench accepts — the
    writer and the linter cannot drift apart."""
    from benchmarks import common
    monkeypatch.setattr(common, "RESULTS_DIR",
                        str(tmp_path / "benchmarks" / "results"))
    path = common.save_result("roundtrip", [{"x": 1, "ok": True}],
                              seed=7)
    assert path.endswith("BENCH_roundtrip.json")
    assert check_bench.check_result(pathlib.Path(path),
                                    root=tmp_path) == []
    loaded = common.load_result(path)
    assert loaded["rows"] == [{"x": 1, "ok": True}]
    assert loaded["meta"]["seed"] == 7
    for key in check_bench.REQUIRED_META:
        assert key in loaded["meta"], key


def test_lint_catches_legacy_bare_list(tmp_path):
    _scaffold(tmp_path, "BENCH_old.json", [{"x": 1}])
    (rel, msg), = check_bench.collect_violations(root=tmp_path)
    assert rel == "benchmarks/results/BENCH_old.json"
    assert "meta" in msg


def test_lint_catches_missing_meta_key_and_bad_schema(tmp_path):
    meta = dict(GOOD_META, schema=99)
    del meta["seed"]
    _scaffold(tmp_path, "BENCH_m.json",
              {"meta": meta, "rows": [{"x": 1}]})
    msgs = [m for _, m in check_bench.collect_violations(root=tmp_path)]
    assert any("'seed'" in m for m in msgs)
    assert any("schema" in m for m in msgs)


def test_lint_catches_non_finite_numbers(tmp_path):
    # json.dumps emits bare NaN/Infinity by default — exactly the
    # artifact a naive percentile bug would commit
    _scaffold(tmp_path, "BENCH_nan.json", json.dumps(
        {"meta": GOOD_META, "rows": [{"p95_us": float("nan")},
                                     {"p99_us": float("inf")}]}))
    msgs = [m for _, m in check_bench.collect_violations(root=tmp_path)]
    assert len(msgs) == 2 and all("non-finite" in m for m in msgs)


def test_lint_catches_empty_rows_and_nested_values(tmp_path):
    _scaffold(tmp_path, "BENCH_empty.json",
              {"meta": GOOD_META, "rows": []})
    _scaffold(tmp_path, "BENCH_nested.json",
              {"meta": GOOD_META, "rows": [{"x": {"nested": 1}}]})
    msgs = [m for _, m in check_bench.collect_violations(root=tmp_path)]
    assert any("non-empty list" in m for m in msgs)
    assert any("unsupported type" in m for m in msgs)


def test_tiny_runner_refuses_an_empty_selection():
    """`benchmarks.run --tiny <name>` where the named benchmark has no
    tiny mode must exit non-zero — a smoke gate that runs nothing must
    not read as green."""
    from benchmarks import run as bench_run
    with pytest.raises(SystemExit, match="tiny"):
        bench_run.main(["--tiny", "kernel_speedup"])


def test_lint_catches_invalid_json_and_empty_dir(tmp_path):
    _scaffold(tmp_path, "BENCH_broken.json", "{not json")
    msgs = [m for _, m in check_bench.collect_violations(root=tmp_path)]
    assert any("invalid JSON" in m for m in msgs)
    empty = tmp_path / "other"
    (empty / "benchmarks" / "results").mkdir(parents=True)
    (rel, msg), = check_bench.collect_violations(root=empty)
    assert "no BENCH_" in msg
