"""Dry-run machinery integration test on a small placeholder mesh.

Runs in a subprocess (XLA device count must be set before jax init, and
the main test process must keep seeing 1 device).  Uses REDUCED configs
on a 2x4 mesh — same code path as the production dry-run, minutes
cheaper."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import get_model
    from repro.launch.dryrun import build_step
    from repro.launch.shapes import InputShape
    from repro.launch.hlo_analysis import analyze

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    out = {}
    for arch, shape in [
        ("qwen3-32b", InputShape("t", 64, 4, "train")),
        ("mamba2-780m", InputShape("t", 64, 4, "train")),
        ("deepseek-moe-16b", InputShape("p", 64, 4, "prefill")),
        ("zamba2-1.2b", InputShape("d", 64, 4, "decode")),
        ("whisper-large-v3", InputShape("d", 64, 4, "decode")),
        ("paligemma-3b", InputShape("p", 64, 4, "prefill")),
    ]:
        cfg = get_config(arch, reduced=True)
        bundle = get_model(cfg)
        fn, args, shards = build_step(bundle, shape, mesh)
        with mesh:
            compiled = jax.jit(fn, in_shardings=shards).lower(*args) \\
                .compile()
        hc = analyze(compiled.as_text())
        out[arch + ":" + shape.mode] = {
            "flops": hc.flops,
            "collective_bytes": hc.total_collective_bytes,
        }
    print("RESULT" + json.dumps(out))
""")


@pytest.mark.slow
def test_dryrun_reduced_configs_on_8dev_mesh():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    assert len(out) == 6
    for key, v in out.items():
        assert v["flops"] > 0, key
        # every mode on a >1-chip mesh must communicate something
        assert v["collective_bytes"] > 0, key
