"""Cross-family conformance matrix (family-parity acceptance).

Every seed config family is driven through every serving fast path it
supports — exact-length, bucketed, chunked, checkpointed (a forced
mid-run preempt/restore cycle), paged where the cache layout allows,
sharded (the same forced preempt/restore cycle on a 2-device
``("data", "model")`` mesh, params and KV partitioned over the
``model`` axis), and streaming (overlapped decode with per-token
``StreamEvent`` callbacks, plus the same forced preempt/restore
cycle) — and each run's decoded tokens must be IDENTICAL to
that family's exact-length baseline:

  * dense/vlm: length-masked decode hides bucket/chunk padding;
  * moe: capacity-stable masked dispatch (``lm.moe_dispatch``) makes
    bucket padding invisible to expert routing;
  * ssm/hybrid: the recurrent-state chunk op
    (``SERVING_PREFILL_CHUNK_STATE``) carries (conv, SSD) state across
    chunk boundaries with the padded tail an exact state no-op;
  * every family: checkpoint/restore replays bit-identically because
    the decode step is a pure function of the restored slot state
    (``extract_slot_state`` / ``insert_slot_state``).

Alongside token identity, every run asserts the compile-once
invariant: ONE decode program, ONE chunk program, one prefill program
per bucket (not per length) — and a preempt/restore cycle traces
NOTHING new (``jit_cache_size`` never grows across admit → evict →
restore).

Combinations a family does NOT support must refuse with the typed
``UnsupportedFamilyError`` naming family, feature, and the supported
set — asserted for every remaining guard.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.executor import BucketTable, jit_cache_size
from repro.launch.mesh import make_serving_mesh
from repro.models import get_model
from repro.serving import Request, ServingEngine, UnsupportedFamilyError

ARCHS = {
    "dense": "qwen3-32b",
    "moe": "deepseek-moe-16b",
    "ssm": "mamba2-780m",
    "hybrid": "zamba2-1.2b",
    "vlm": "paligemma-3b",
    "audio": "whisper-large-v3",
}

# the conformance matrix: which fast paths each family supports.
# "exact" is the baseline every other mode is compared against;
# "checkpointed" is exact + a forced mid-run evict/restore.
MATRIX = {
    "dense": ("exact", "bucketed", "chunked", "checkpointed", "paged",
              "sharded", "streaming"),
    "moe": ("exact", "bucketed", "checkpointed", "paged", "sharded",
            "streaming"),
    "ssm": ("exact", "chunked", "checkpointed", "sharded", "streaming"),
    "hybrid": ("exact", "chunked", "checkpointed", "sharded",
               "streaming"),
    "vlm": ("exact", "bucketed", "chunked", "checkpointed", "paged",
            "sharded", "streaming"),
    "audio": ("exact", "checkpointed"),
}

# modes that force a mid-run evict/restore cycle while running;
# streaming joins so the exactly-once callback contract is proven
# ACROSS preemption, not just on the happy path
_EVICT_MODES = ("checkpointed", "sharded", "streaming")

# the sharded column needs a real 2-device mesh; tier-1 runs on one
# CPU device, so these cells only light up under
# XLA_FLAGS=--xla_force_host_platform_device_count=2 (CI slow tier)
_SHARDED_SKIP = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="sharded matrix needs >=2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")

PROMPT_LENS = (21, 13, 30, 9)
N_NEW = 6
CACHE_LEN = 64
CHUNK = 8
KV_BLOCK = 8

_SETUP = {}


def _setup(family):
    """(cfg, bundle, params, requests) for a family — cached so the
    matrix re-uses one weight init per family across modes."""
    if family not in _SETUP:
        cfg = get_config(ARCHS[family], reduced=True)
        m = get_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(5)
        reqs = []
        for uid, n in enumerate(PROMPT_LENS):
            toks = rng.integers(0, cfg.vocab - 2, n).astype(np.int32)
            extras = None
            if family == "vlm":
                extras = {"vision": rng.normal(
                    0, 1, (cfg.n_vision_tokens, cfg.d_vision)
                ).astype(np.float32)}
            elif family == "audio":
                extras = {"frames": rng.normal(
                    0, 1, (cfg.n_audio_ctx, cfg.d_model)
                ).astype(np.float32)}
            reqs.append((uid, toks, extras))
        _SETUP[family] = (cfg, m, params, reqs)
    return _SETUP[family]


def _cache_len(cfg):
    # a vlm's vision prefix occupies cache rows in front of the prompt
    return CACHE_LEN + (cfg.n_vision_tokens if cfg.family == "vlm"
                        else 0)


_MODE_KW = {
    "exact": {"prefill_buckets": False},
    "checkpointed": {"prefill_buckets": False},
    "bucketed": {"prefill_buckets": True},
    "chunked": {"prefill_buckets": False, "prefill_chunk": CHUNK},
    "paged": {"prefill_buckets": False, "kv_block": KV_BLOCK},
    # the mesh itself is built lazily in _run (needs >=2 devices)
    "sharded": {"prefill_buckets": False},
    # overlapped decode + per-token StreamEvent callbacks (the
    # on_token sink is wired per-run in _run)
    "streaming": {"prefill_buckets": False, "overlap": True},
}


def _run(family, mode):
    """Run the family's request set through one matrix mode; returns
    ({uid: tokens}, engine)."""
    cfg, m, params, reqs = _setup(family)
    kw = dict(_MODE_KW[mode])
    if mode == "sharded":
        kw["mesh"] = make_serving_mesh(2)
    events = []
    if mode == "streaming":
        kw["on_token"] = events.append
    eng = ServingEngine(m, params, max_slots=2,
                        cache_len=_cache_len(cfg), **kw)
    for uid, toks, extras in reqs:
        eng.submit(Request(uid=uid, tokens=toks, max_new_tokens=N_NEW,
                           extras=extras))
    evicted = False
    traced_at_evict = None
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 500, f"{family}/{mode} did not converge"
        if mode in _EVICT_MODES and not evicted and steps >= 3:
            # forced preemption: checkpoint whichever slot is busy,
            # re-queue it, and record the trace counts the later
            # restore must not grow.  The overlapped engine must be
            # quiesced first — a pending readback may retire the slot
            # we are about to pick (the drain-before-surgery contract
            # every checkpoint path follows internally).
            if mode == "streaming":
                eng.drain()
            victim = next((s for s in range(eng.max_slots)
                           if eng.active[s] or s in eng._chunking),
                          None)
            if victim is not None:
                eng._evict(victim)
                evicted = True
                traced_at_evict = (eng.prefill_compiles(),
                                   jit_cache_size(eng._decode))
    outs = {uid: eng.results[uid].output for uid, _, _ in reqs}
    # ---- compile-once invariants, every mode ------------------------
    assert jit_cache_size(eng._decode) == 1, (family, mode)
    if mode == "chunked":
        assert eng.chunk_compiles() == 1, (family, mode)
        # the only one-shot prefill shape is the chunk-ineligible short
        # prompt (and dense/vlm's fixed-shape first chunk shares it);
        # recurrent families push EVERY chunk through the chunk op
        assert eng.prefill_compiles() <= 1, (family, mode)
    if mode == "bucketed":
        hit = {eng.bucket_table.fit(n - 1) for n in PROMPT_LENS}
        assert eng.prefill_compiles() == len(hit), (family, mode)
        assert eng.prefill_compiles() < len(set(PROMPT_LENS))
    if mode in _EVICT_MODES:
        assert evicted, f"{family}: nothing was running to evict"
        assert eng.results[0].preemptions \
            + sum(eng.results[u].preemptions for u, _, _ in reqs) >= 1
        # restore traced nothing: counts frozen at eviction time may
        # grow only by NOT-YET-ADMITTED prompts' prefills, never by
        # the restore itself — decode stays at exactly one program.
        # On a mesh this additionally proves the pinning discipline:
        # evict pulls KV to host, restore re-commits it to the cache
        # sharding, and neither placement round-trip retraces.
        assert jit_cache_size(eng._decode) == traced_at_evict[1] == 1
    if mode == "streaming":
        # callback ordering contract (docs/STREAMING.md): per request,
        # indices run 0..n-1 in emission order, the streamed tokens ARE
        # the accumulated output (each exactly once — across the forced
        # evict/restore above), exactly the last event is final, and
        # timestamps never run backwards
        per = {}
        for ev in events:
            per.setdefault(ev.uid, []).append(ev)
        assert sorted(per) == sorted(outs), (family, sorted(per))
        for uid, evs in per.items():
            assert [e.index for e in evs] == list(range(len(evs))), uid
            assert [e.token for e in evs] == outs[uid], (family, uid)
            assert [e.final for e in evs] == \
                [False] * (len(evs) - 1) + [True], (family, uid)
            ts = [e.t_us for e in evs]
            assert ts == sorted(ts), (family, uid)
    return outs, eng


@pytest.mark.slow
@pytest.mark.parametrize("family,mode", [
    pytest.param(fam, mode,
                 marks=(_SHARDED_SKIP,) if mode == "sharded" else ())
    for fam, modes in MATRIX.items() for mode in modes
    if mode != "exact"])
def test_family_mode_matches_exact_baseline(family, mode):
    """THE matrix: every supported (family, fast-path) cell decodes the
    exact same tokens as that family's exact-length baseline."""
    base, _ = _run(family, "exact")
    got, _ = _run(family, mode)
    assert got == base, (family, mode, got, base)


@pytest.mark.slow
@pytest.mark.parametrize("family", sorted(MATRIX))
def test_family_exact_baseline_is_nontrivial(family):
    """The baseline itself decodes full budgets (no silent empty
    outputs making the matrix vacuous) with one decode program."""
    base, eng = _run(family, "exact")
    for uid, toks in base.items():
        assert len(toks) >= 1, (family, uid)
    assert jit_cache_size(eng._decode) == 1


def test_checkpoint_state_roundtrip_recurrent():
    """The state-extraction hook carries SSM/hybrid recurrent state
    bit-exactly: extract a decoding slot's state, zero the slot, insert
    the copy back into a DIFFERENT slot, and the pytrees match leaf for
    leaf (conv window, SSD state, and hybrid's shared-attn KV)."""
    for family in ("ssm", "hybrid"):
        cfg, m, params, reqs = _setup(family)
        eng = ServingEngine(m, params, max_slots=2,
                            cache_len=_cache_len(cfg),
                            prefill_buckets=False)
        uid, toks, extras = reqs[0]
        eng.submit(Request(uid=uid, tokens=toks, max_new_tokens=N_NEW,
                           extras=extras))
        for _ in range(3):
            eng.step()
        state = eng.extract_slot_state(0)
        eng.insert_slot_state(1, jax.tree.map(np.asarray, state))
        back = eng.extract_slot_state(1)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
            assert a.dtype == b.dtype
            assert np.array_equal(a, b)


def test_unsupported_combinations_raise_typed_errors():
    """Every remaining family×feature hole refuses with the typed
    UnsupportedFamilyError naming family, feature, and supported set —
    no bare ValueError guards left on the engine fast paths."""
    cases = [
        ("ssm", {"prefill_buckets": BucketTable()}, "bucketed prefill"),
        ("hybrid", {"prefill_buckets": BucketTable()},
         "bucketed prefill"),
        ("moe", {"prefill_chunk": CHUNK}, "chunked prefill"),
        ("audio", {"prefill_chunk": CHUNK}, "chunked prefill"),
        ("ssm", {"kv_block": KV_BLOCK}, "paged KV"),
        ("hybrid", {"kv_block": KV_BLOCK}, "paged KV"),
        ("audio", {"kv_block": KV_BLOCK}, "paged KV"),
        # a model=1 mesh exists on any device count, and the family
        # gate fires before any sharding is computed — so the audio
        # refusal is asserted even in the single-device tier
        ("audio", {"mesh": make_serving_mesh(1)},
         "mesh-sharded serving"),
        # audio's encoder-decoder path is not qualified for deferred
        # readback (see STREAMING_FAMILIES), so overlap refuses typed
        ("audio", {"overlap": True}, "overlapped (async) decode"),
    ]
    for family, kw, feature in cases:
        cfg, m, params, _ = _setup(family)
        with pytest.raises(UnsupportedFamilyError) as ei:
            ServingEngine(m, params, max_slots=1,
                          cache_len=_cache_len(cfg), **kw)
        msg = str(ei.value)
        assert cfg.family in msg and feature in msg, (family, kw, msg)
        assert ei.value.supported, (family, kw)
        # the typed error still satisfies old except ValueError callers
        assert isinstance(ei.value, ValueError)


def test_moe_chunked_also_refused_when_paged():
    """MoE's chunk refusal holds on the paged engine too (the paged
    chunk op's prepare() re-checks the gate)."""
    cfg, m, params, _ = _setup("moe")
    with pytest.raises(UnsupportedFamilyError):
        ServingEngine(m, params, max_slots=1, cache_len=_cache_len(cfg),
                      prefill_chunk=CHUNK, kv_block=KV_BLOCK)


# ---------------------------------------------------------------------------
# quantized conformance cells (PR 10, docs/QUANTIZATION.md)
# ---------------------------------------------------------------------------

# which quantization layout each family serves: lm-path families take
# the full int8 weight + int8 KV pair; recurrent families are
# weight-only (their conv/SSD state is not a (KH, C, dh) KV ring).
# audio is outside WEIGHT_QUANT_FAMILIES — its refusal is asserted in
# tests/test_quant_serving.py.
_QUANT_KW = {
    "dense": {"weight_dtype": "int8", "kv_dtype": "int8"},
    "moe": {"weight_dtype": "int8", "kv_dtype": "int8"},
    "vlm": {"weight_dtype": "int8", "kv_dtype": "int8"},
    "ssm": {"weight_dtype": "int8"},
    "hybrid": {"weight_dtype": "int8"},
}


def _run_quantized(family, *, evict):
    """The family's request set through its quantized engine; returns
    ({uid: tokens}).  Deliberately NOT compared against the exact fp
    baseline — quantized decode is tolerance-gated against fp
    (tests/test_quant_serving.py), and bit-gated only against itself."""
    cfg, m, params, reqs = _setup(family)
    eng = ServingEngine(m, params, max_slots=2,
                        cache_len=_cache_len(cfg),
                        prefill_buckets=False, **_QUANT_KW[family])
    for uid, toks, extras in reqs:
        eng.submit(Request(uid=uid, tokens=toks, max_new_tokens=N_NEW,
                           extras=extras))
    evicted = False
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 500, f"{family}/quantized did not converge"
        if evict and not evicted and steps >= 3:
            victim = next((s for s in range(eng.max_slots)
                           if eng.active[s]), None)
            if victim is not None:
                eng._evict(victim)
                evicted = True
    assert jit_cache_size(eng._decode) == 1, (family, "quantized")
    assert not evict or evicted, (family, "nothing running to evict")
    return {uid: eng.results[uid].output for uid, _, _ in reqs}


@pytest.mark.slow
@pytest.mark.parametrize("family", sorted(_QUANT_KW))
def test_family_quantized_preempt_restore_identity(family):
    """The quantized column of the conformance matrix: every family
    the SERVING_*_Q ops serve decodes bit-identical tokens with and
    without a forced mid-run evict/restore, from one compiled decode
    program — the compile-once contract survives quantization for the
    whole family matrix."""
    base = _run_quantized(family, evict=False)
    got = _run_quantized(family, evict=True)
    assert got == base, (family, got, base)
    # and the cells are non-trivial: every request decoded its budget
    assert all(len(t) == N_NEW for t in base.values()), (family, base)
