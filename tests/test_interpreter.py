"""MicroInterpreter behaviour (paper §4.1–4.5)."""

import numpy as np
import pytest

from repro.apps import build_conv_reference, build_hotword
from repro.apps.models import representative_dataset
from repro.core import (AllOpsResolver, ArenaOverflowError, GraphBuilder,
                        GreedyMemoryPlanner, LinearMemoryPlanner,
                        MicroInterpreter, MicroModel,
                        MicroMutableOpResolver, OpCode, OpResolutionError,
                        SharedArenaState, export)


@pytest.fixture(scope="module")
def conv_model():
    return MicroModel(export(build_conv_reference()))


@pytest.fixture(scope="module")
def resolver():
    return AllOpsResolver()


def _run(model, resolver, x, **kw):
    size = MicroInterpreter.required_arena_size(model, resolver)
    it = MicroInterpreter(model, resolver, size, **kw)
    it.set_input(0, x)
    it.invoke()
    return it


def test_invoke_matches_repeatedly(conv_model, resolver):
    x = np.random.default_rng(0).normal(0, 1, (1, 16, 16, 1)
                                        ).astype(np.float32)
    it = _run(conv_model, resolver, x)
    first = it.output(0)
    assert first.shape == (1, 10)
    assert np.isfinite(first).all()
    np.testing.assert_allclose(first.sum(), 1.0, rtol=1e-5)
    it.set_input(0, x)
    it.invoke()
    np.testing.assert_array_equal(it.output(0), first)


def test_arena_too_small_raises(conv_model, resolver):
    with pytest.raises(ArenaOverflowError):
        MicroInterpreter(conv_model, resolver, 512)


def test_unregistered_op_raises(conv_model):
    r = MicroMutableOpResolver().add_many(
        [OpCode.CONV_2D, OpCode.MAX_POOL_2D])   # missing FC etc.
    with pytest.raises(OpResolutionError):
        MicroInterpreter(conv_model, r, 1 << 20)


def test_selective_resolver_smaller_than_all_ops(conv_model):
    minimal = MicroMutableOpResolver().add_many(
        [OpCode.CONV_2D, OpCode.MAX_POOL_2D, OpCode.MEAN,
         OpCode.FULLY_CONNECTED, OpCode.SOFTMAX])
    assert minimal.code_nbytes() < AllOpsResolver().code_nbytes()
    x = np.zeros((1, 16, 16, 1), np.float32)
    it = _run(conv_model, minimal, x)
    assert it.output(0).shape == (1, 10)


def test_planner_choice_changes_bytes_not_results(conv_model, resolver):
    x = np.random.default_rng(1).normal(0, 1, (1, 16, 16, 1)
                                        ).astype(np.float32)
    def run_with(planner):
        it = MicroInterpreter(conv_model, resolver, 1 << 20,
                              planner=planner)
        it.set_input(0, x)
        it.invoke()
        return it

    it_ffd = run_with(GreedyMemoryPlanner())
    it_lin = run_with(LinearMemoryPlanner())
    np.testing.assert_array_equal(it_ffd.output(0), it_lin.output(0))
    assert (it_ffd.arena_used_bytes()["nonpersistent"]
            <= it_lin.arena_used_bytes()["nonpersistent"])


def test_offline_plan_used_and_matches(resolver):
    gb = build_conv_reference()
    blob = export(gb, offline_plan=True)
    model = MicroModel(blob)
    assert "OfflineMemoryAllocation" in model.metadata
    x = np.random.default_rng(2).normal(0, 1, (1, 16, 16, 1)
                                        ).astype(np.float32)
    it = _run(model, resolver, x)
    assert it.planner_name == "offline"
    it2 = _run(model, resolver, x, prefer_offline_plan=False)
    assert it2.planner_name == "greedy_ffd"
    np.testing.assert_array_equal(it.output(0), it2.output(0))


def test_no_allocation_after_init(conv_model, resolver):
    """The arena is frozen after init; invoke must not allocate from it."""
    x = np.zeros((1, 16, 16, 1), np.float32)
    it = _run(conv_model, resolver, x)
    assert it.arena.frozen
    before = it.arena_used_bytes()
    for _ in range(3):
        it.set_input(0, x)
        it.invoke()
    assert it.arena_used_bytes() == before


def test_variable_tensors_persist_and_reset(resolver):
    """SVDF state is a persistent (interpreter-lifetime) variable tensor:
    streaming the same frame twice gives different outputs (state moved),
    and reset_variable_tensors() restores the initial response."""
    model = MicroModel(export(build_hotword(n_layers=1)))
    size = MicroInterpreter.required_arena_size(model, resolver)
    it = MicroInterpreter(model, resolver, size)
    x = np.random.default_rng(3).normal(0, 1, (1, 40)).astype(np.float32)
    it.set_input(0, x)
    it.invoke()
    first = it.output(0)
    it.set_input(0, x)
    it.invoke()
    second = it.output(0)
    assert not np.array_equal(first, second)
    it.reset_variable_tensors()
    it.set_input(0, x)
    it.invoke()
    np.testing.assert_allclose(it.output(0), first, rtol=1e-5, atol=1e-6)


def test_int8_model_close_to_float(resolver):
    gb = build_conv_reference()
    x = np.random.default_rng(4).normal(0, 1, (1, 16, 16, 1)
                                        ).astype(np.float32)
    mf = MicroModel(export(gb))
    itf = _run(mf, resolver, x)
    want = itf.output(0)
    ds = representative_dataset(gb)
    mq = MicroModel(export(gb, representative_dataset=ds,
                           quantize_int8=True))
    itq = _run(mq, resolver, x)
    got = itq.output(0)
    assert np.abs(got - want).max() < 0.1
    assert got.argmax() == want.argmax()


def test_multitenancy_shared_arena(resolver):
    """§4.5: two models in one arena — persistent stacks, nonpersistent is
    the max of the two, results identical to private-arena runs."""
    m1 = MicroModel(export(build_conv_reference()))
    m2 = MicroModel(export(build_hotword(n_layers=1)))
    x1 = np.random.default_rng(5).normal(0, 1, (1, 16, 16, 1)
                                         ).astype(np.float32)
    x2 = np.random.default_rng(6).normal(0, 1, (1, 40)).astype(np.float32)

    # private runs
    p1 = _run(m1, resolver, x1)
    p2 = _run(m2, resolver, x2)

    # shared arena
    total = (p1.arena_used_bytes()["total"]
             + p2.arena_used_bytes()["total"] + 4096)
    it1 = MicroInterpreter(m1, resolver, total)
    it2 = MicroInterpreter(m2, resolver, 0, parent=it1)
    it1.set_input(0, x1)
    it1.invoke()
    it2.set_input(0, x2)
    it2.invoke()
    np.testing.assert_array_equal(it1.output(0), p1.output(0))
    np.testing.assert_array_equal(it2.output(0), p2.output(0))

    shared_usage = it1.arena.usage()
    np1 = p1.arena_used_bytes()["nonpersistent"]
    np2 = p2.arena_used_bytes()["nonpersistent"]
    assert shared_usage.nonpersistent == max(np1, np2)   # Figure 5
    pp1 = p1.arena_used_bytes()["persistent"]
    pp2 = p2.arena_used_bytes()["persistent"]
    assert shared_usage.persistent >= pp1 + pp2 - 32     # stacks (±align)


def test_interleaved_multitenant_invokes(resolver):
    """Models alternate invocations sharing one nonpersistent buffer."""
    m1 = MicroModel(export(build_conv_reference()))
    m2 = MicroModel(export(build_hotword(n_layers=1)))
    it1 = MicroInterpreter(m1, resolver, 1 << 22)
    it2 = MicroInterpreter(m2, resolver, 0, parent=it1)
    x1 = np.zeros((1, 16, 16, 1), np.float32)
    x2 = np.zeros((1, 40), np.float32)
    outs = []
    for _ in range(2):
        it1.set_input(0, x1)
        it1.invoke()
        outs.append(it1.output(0).copy())
        it2.set_input(0, x2)
        it2.invoke()
    np.testing.assert_array_equal(outs[0], outs[1])
