"""End-to-end behaviour tests: the paper's three evaluation models run
through the complete pipeline (build → export passes → µFB → interpreter),
in float and INT8, including the Figure-1 workflow with training-op
stripping and constant folding."""

import numpy as np
import pytest

from repro.apps import build_conv_reference, build_hotword, build_vww
from repro.apps.models import representative_dataset
from repro.core import (AllOpsResolver, GraphBuilder, MicroInterpreter,
                        MicroModel, export, fold_constants,
                        strip_training_ops)
from repro.core.schema import OpCode, model_to_source


@pytest.fixture(scope="module")
def resolver():
    return AllOpsResolver()


def _invoke(model, resolver, *xs):
    size = MicroInterpreter.required_arena_size(model, resolver)
    it = MicroInterpreter(model, resolver, size)
    for i, x in enumerate(xs):
        it.set_input(i, x)
    it.invoke()
    return it


@pytest.mark.parametrize("build,shape", [
    (build_conv_reference, (1, 16, 16, 1)),
    (build_hotword, (1, 40)),
    (build_vww, (1, 96, 96, 1)),
])
def test_paper_model_float_e2e(resolver, build, shape):
    gb = build()
    model = MicroModel(export(gb))
    x = np.random.default_rng(0).normal(0, 1, shape).astype(np.float32)
    it = _invoke(model, resolver, x)
    out = it.output(0)
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out.sum(axis=-1), 1.0, rtol=1e-4)


@pytest.mark.parametrize("build,shape", [
    (build_conv_reference, (1, 16, 16, 1)),
    (build_vww, (1, 96, 96, 1)),
])
def test_paper_model_int8_e2e(resolver, build, shape):
    gb = build()
    x = np.random.default_rng(1).normal(0, 1, shape).astype(np.float32)
    want = _invoke(MicroModel(export(gb)), resolver, x).output(0)
    ds = representative_dataset(gb, n=4)
    mq = MicroModel(export(gb, representative_dataset=ds,
                           quantize_int8=True))
    got = _invoke(mq, resolver, x).output(0)
    assert np.abs(got - want).max() < 0.12
    assert got.argmax() == want.argmax()


def test_dropout_stripped_and_constants_folded(resolver):
    rng = np.random.default_rng(2)
    gb = GraphBuilder("traindebris")
    x = gb.input("x", (1, 8))
    # const subgraph: w = a + b should fold into one const
    a = gb.const(rng.normal(0, 1, (4, 8)).astype(np.float32), "a")
    b = gb.const(rng.normal(0, 1, (4, 8)).astype(np.float32), "b")
    w = gb.add(a, b)
    h = gb.fully_connected(x, w)
    h = gb.dropout(h, rate=0.5)
    h = gb.identity(h)
    gb.mark_output(gb.softmax(h))
    n_ops_before = len(gb.ops)
    model = MicroModel(export(gb))
    opcodes = [op.opcode for op in model.operators]
    assert OpCode.DROPOUT not in opcodes
    assert OpCode.IDENTITY not in opcodes
    assert OpCode.ADD not in opcodes               # folded
    assert len(opcodes) == n_ops_before - 3
    xin = rng.normal(0, 1, (1, 8)).astype(np.float32)
    it = _invoke(model, resolver, xin)
    # semantics preserved: softmax(x @ (a+b)^T)
    import jax
    import jax.numpy as jnp
    want = np.asarray(jax.nn.softmax(
        jnp.asarray(xin) @ jnp.asarray(
            model.const_data(model.operators[0].inputs[1])).T))
    np.testing.assert_allclose(it.output(0), want, rtol=1e-5, atol=1e-6)


def test_model_embeds_as_source_and_runs(resolver):
    """§4.3.1: model → 'C array' source → import → run."""
    blob = export(build_conv_reference())
    ns: dict = {}
    exec(model_to_source(blob), ns)
    model = MicroModel(ns["g_model"])
    x = np.zeros((1, 16, 16, 1), np.float32)
    it = _invoke(model, resolver, x)
    assert it.output(0).shape == (1, 10)


def test_vww_int8_blob_much_smaller_than_float():
    gb = build_vww()
    float_blob = export(gb)
    ds = representative_dataset(gb, n=2)
    q_blob = export(gb, representative_dataset=ds, quantize_int8=True)
    assert len(q_blob) < 0.35 * len(float_blob)    # ~4x weight shrink


def test_interpreter_overhead_structure(resolver):
    """The paper's central claim (§5.2): the interpreter adds negligible
    overhead vs executing the same math directly.  Structurally, our
    invoke is ONE jitted call — dispatch happens at trace time — so the
    number of device computations equals one, same as a hand-fused fn."""
    model = MicroModel(export(build_conv_reference()))
    it = _invoke(model, resolver,
                 np.zeros((1, 16, 16, 1), np.float32))
    assert it._invoke_count == 1
    assert hasattr(it, "_jitted")
