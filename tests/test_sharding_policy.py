"""Sharding-policy validation for every assigned architecture on the
production mesh shape — divisibility of every sharded dim, for params,
batches and caches, without touching real devices (AbstractMesh)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.distributed.sharding import (cache_sharding, make_policy,
                                        param_spec)
from repro.models import get_model
from repro.models.registry import SDS


def _mesh(multi_pod=False):
    # jax 0.4.x AbstractMesh takes ((name, size), ...) pairs; the
    # (sizes, names) two-argument form arrived in later releases
    if multi_pod:
        return AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))
    return AbstractMesh((("data", 16), ("model", 16)))


def _check_divisible(tree_specs, tree_vals, mesh, label):
    flat_s = jax.tree.leaves(tree_specs,
                             is_leaf=lambda x: isinstance(x, P))
    flat_v = jax.tree.leaves(tree_vals)
    assert len(flat_s) == len(flat_v), label
    for spec, val in zip(flat_s, flat_v):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= dict(zip(mesh.axis_names, mesh.axis_sizes))[a]
            assert val.shape[dim] % size == 0, \
                (label, spec, val.shape, dim, ax)


@pytest.mark.parametrize("arch", list_archs())
@pytest.mark.parametrize("multi_pod", [False, True])
def test_param_specs_divisible(arch, multi_pod):
    cfg = get_config(arch)
    bundle = get_model(cfg)
    mesh = _mesh(multi_pod)
    params = jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))
    pol = make_policy(cfg, mesh)
    spec = param_spec(cfg, pol, params)
    _check_divisible(spec, params, mesh, arch)


@pytest.mark.parametrize("arch", list_archs())
def test_cache_specs_divisible(arch):
    cfg = get_config(arch)
    bundle = get_model(cfg)
    mesh = _mesh()
    for batch, cl in ((128, 32768), (1, cfg.sliding_window or 8192)):
        cache = jax.eval_shape(
            lambda: bundle.empty_cache(batch, cl, cfg.jnp_dtype()))
        shards = cache_sharding(cfg, mesh, cache, batch)
        specs = jax.tree.map(lambda s: s.spec, shards)
        _check_divisible(specs, cache, mesh, f"{arch}:cache{batch}")


def test_heads_fallback_policy():
    mesh = _mesh()
    for arch, want in (("qwen3-32b", "heads"), ("phi4-mini-3.8b",
                                                "replicated"),
                       ("paligemma-3b", "replicated"),
                       ("whisper-large-v3", "replicated"),
                       ("phi3-mini-3.8b", "heads")):
        pol = make_policy(get_config(arch), mesh)
        assert pol.attn_mode == want, (arch, pol.attn_mode)


def test_kv_cache_mode_policy():
    mesh = _mesh()
    for arch, want in (("phi3-mini-3.8b", "kv_heads"),
                       ("qwen3-32b", "sequence"),
                       ("yi-6b", "sequence"),
                       ("deepseek-moe-16b", "kv_heads")):
        pol = make_policy(get_config(arch), mesh)
        assert pol.kv_cache_mode == want, (arch, pol.kv_cache_mode)
