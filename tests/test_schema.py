"""µFB serialization: round trip, zero-copy, source embedding."""

import numpy as np
import pytest

from repro.core.schema import (MicroModel, OpCode, OpDef, QuantParams,
                               TensorDef, TensorFlags, model_to_source,
                               serialize_model)


def _toy_blob():
    tensors = [
        TensorDef("x", (1, 4), "float32", TensorFlags.IS_MODEL_INPUT),
        TensorDef("w", (3, 4), "float32"),
        TensorDef("y", (1, 3), "float32", TensorFlags.IS_MODEL_OUTPUT),
        TensorDef("wq", (3, 4), "int8", 0,
                  QuantParams(0.0, 0, np.array([0.1, 0.2, 0.3], np.float32),
                              0)),
    ]
    ops = [OpDef(OpCode.FULLY_CONNECTED, (0, 1, -1), (2,),
                 {"activation": "relu"})]
    w = np.arange(12, dtype=np.float32).reshape(3, 4)
    wq = (np.arange(12) % 5).astype(np.int8).reshape(3, 4)
    return serialize_model(tensors, ops, [0], [2], {1: w, 3: wq},
                           {"note": b"hello"}), w, wq


def test_roundtrip():
    blob, w, wq = _toy_blob()
    m = MicroModel(blob)
    assert m.inputs == (0,) and m.outputs == (2,)
    assert [t.name for t in m.tensors] == ["x", "w", "y", "wq"]
    assert m.tensors[1].is_const and not m.tensors[0].is_const
    assert m.operators[0].opcode == OpCode.FULLY_CONNECTED
    assert m.operators[0].inputs == (0, 1, -1)
    assert m.operators[0].params == {"activation": "relu"}
    assert m.metadata["note"] == b"hello"
    np.testing.assert_array_equal(m.const_data(1), w)
    np.testing.assert_array_equal(m.const_data(3), wq)
    np.testing.assert_allclose(m.tensors[3].quant.channel_scales,
                               [0.1, 0.2, 0.3], rtol=1e-6)


def test_zero_copy_views():
    blob, w, _ = _toy_blob()
    m = MicroModel(blob)
    view = m.const_data(1)
    # a frombuffer view over the blob: read-only and non-owning
    assert not view.flags.owndata
    assert not view.flags.writeable


def test_const_data_alignment():
    blob, _, _ = _toy_blob()
    m = MicroModel(blob)
    for i, t in enumerate(m.tensors):
        if t.is_const:
            assert t.buffer_offset % 16 == 0


def test_bad_magic_rejected():
    blob, _, _ = _toy_blob()
    with pytest.raises(ValueError):
        MicroModel(b"XXXX" + blob[4:])


def test_truncated_blob_rejected():
    blob, _, _ = _toy_blob()
    with pytest.raises(ValueError):
        MicroModel(blob[:-8])


def test_model_to_source_roundtrip():
    blob, w, _ = _toy_blob()
    src = model_to_source(blob, "g_model")
    ns: dict = {}
    exec(src, ns)
    assert ns["g_model_len"] == len(blob)
    m = MicroModel(ns["g_model"])
    np.testing.assert_array_equal(m.const_data(1), w)


def test_nonconst_tensor_data_access_raises():
    blob, _, _ = _toy_blob()
    m = MicroModel(blob)
    with pytest.raises(ValueError):
        m.const_data(0)
