"""ReplicaRouter invariants (serving/router.py, docs/ARCHITECTURE.md §9).

Four router guarantees, property-tested:

  * **no request lost or duplicated** — under arbitrary submit/step
    churn every uid finishes with exactly one ``RequestResult`` held
    at exactly one replica, and ``routed`` always agrees with where
    the result actually lives.
  * **locality stickiness** — a uid whose continuation state (slot
    checkpoint) is parked at a replica is routed home by
    ``LocalityRouting`` and is NEVER migrated off by the rebalancer,
    regardless of load imbalance.
  * **work conservation** — after rebalancing, no replica has
    admission capacity it cannot fill while another queues movable
    (checkpoint-free) surplus.
  * **policy swaps never retrace** — swapping the routing policy
    mid-serve leaves every replica's jit cache frozen (real engines).

The structural properties run against a lightweight fake replica that
mirrors exactly the engine surface the router touches (queue, results,
active, _chunking, _ckpt, max_slots, submit, step) so churn sweeps are
cheap; token-parity and retrace checks run against real reduced-config
engines.  Hypothesis-driven sweeps engage when ``hypothesis`` is
installed and skip cleanly when it is not — a seeded deterministic
churn sweep covers the same invariants either way.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.executor import jit_cache_size
from repro.models import get_model
from repro.serving import (LocalityRouting, ReplicaLoad, ReplicaRouter,
                           Request, RequestResult, ServingEngine,
                           get_routing)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAS_HYPOTHESIS = True
except ImportError:                                  # pragma: no cover
    HAS_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAS_HYPOTHESIS, reason="hypothesis not installed")


# ---------------------------------------------------------------------
# fake replica: the exact engine surface ReplicaRouter touches
# ---------------------------------------------------------------------

class FakeReplica:
    """Engine stand-in with the router-facing surface of ServingEngine:
    FIFO admission into ``max_slots`` slots, one token per active slot
    per step.  ``output`` records which replica emitted each token so
    stickiness violations show up as mixed-provenance outputs."""

    def __init__(self, rid, max_slots=2):
        self.rid = rid
        self.max_slots = max_slots
        self.queue = []
        self.results = {}
        self.active = np.zeros((max_slots,), bool)
        self.slot_budget = np.zeros((max_slots,), np.int64)
        self._chunking = {}
        self._ckpt = {}
        self._slot = {}          # slot -> [uid, tokens_remaining]

    def submit(self, req):
        """Mirror ServingEngine.submit: queue + results entry."""
        self.queue.append(req)
        self.results[req.uid] = RequestResult(uid=req.uid,
                                              prompt_len=len(req.tokens))

    def step(self):
        """Admit FIFO into free slots, emit one token per active slot,
        retire exhausted budgets.  Returns True while work remains."""
        for s in range(self.max_slots):
            if not self.active[s] and self.queue:
                req = self.queue.pop(0)
                self.active[s] = True
                self._slot[s] = [req.uid, req.max_new_tokens]
                self.slot_budget[s] = req.max_new_tokens
        for s, ent in list(self._slot.items()):
            uid, rem = ent
            self.results[uid].output.append(self.rid)
            ent[1] -= 1
            self.slot_budget[s] = ent[1]
            if ent[1] == 0:
                self.results[uid].done = True
                self.active[s] = False
                del self._slot[s]
        return bool(self.queue) or bool(self._slot)


def _req(uid, n_new=3):
    return Request(uid=uid, tokens=np.zeros((4,), np.int32),
                   max_new_tokens=n_new)


def _churn(n_replicas, ops):
    """Drive a router through a submit/step op sequence, drain it, and
    assert the no-loss/no-duplication and bookkeeping invariants."""
    router = ReplicaRouter([FakeReplica(i) for i in range(n_replicas)],
                           routing="least-loaded")
    uid = 0
    submitted = set()
    for op in ops:
        if op == 0:
            router.step()
        else:
            for _ in range(op):
                router.submit(_req(uid))
                submitted.add(uid)
                uid += 1
    router.run()
    res = router.results
    # every uid finished exactly once, nowhere twice
    assert set(res) == submitted
    assert all(res[u].done for u in submitted)
    total = sum(len(r.results) for r in router.replicas)
    assert total == len(submitted), "a uid is duplicated across replicas"
    # routed agrees with where each result actually lives
    for u in submitted:
        i = router.routed[u]
        assert u in router.replicas[i].results
    # stickiness of emission: once a request starts at a replica, every
    # token it ever emits comes from that replica
    for u in submitted:
        assert len(set(res[u].output)) == 1, (u, res[u].output)
    return router


def test_no_request_lost_or_duplicated_deterministic():
    """Seeded churn sweep: bursty submits interleaved with steps across
    1–4 replicas never lose or duplicate a request."""
    rng = np.random.default_rng(11)
    for n in (1, 2, 3, 4):
        for _ in range(5):
            ops = rng.integers(0, 4, rng.integers(3, 20)).tolist()
            router = _churn(n, ops)
            assert router.migrations >= 0


if HAS_HYPOTHESIS:
    @needs_hypothesis
    @settings(max_examples=60, deadline=None)
    @given(n=st.integers(1, 4),
           ops=st.lists(st.integers(0, 4), min_size=1, max_size=25))
    def test_no_request_lost_or_duplicated_hypothesis(n, ops):
        """Hypothesis sweep of the same churn invariants."""
        _churn(n, ops)

    @needs_hypothesis
    @settings(max_examples=60, deadline=None)
    @given(queues=st.lists(st.integers(0, 6), min_size=2, max_size=5),
           busy=st.lists(st.integers(0, 2), min_size=2, max_size=5))
    def test_work_conservation_hypothesis(queues, busy):
        """After rebalance, no replica has unfillable capacity while
        another queues movable surplus — for arbitrary load shapes."""
        n = min(len(queues), len(busy))
        reps = [FakeReplica(i) for i in range(n)]
        uid = 0
        for i, r in enumerate(reps):
            for s in range(min(busy[i], r.max_slots)):
                r.submit(_req(uid)); uid += 1
            r.step()                     # admit the busy ones
            for _ in range(queues[i]):
                r.submit(_req(uid)); uid += 1
        router = ReplicaRouter(reps)
        for r in reps:                   # adopt pre-submitted uids
            for q in list(r.results):
                router.routed[q] = r.rid
        router._rebalance()
        _assert_conserved(router)


def _assert_conserved(router):
    """No replica needs work while another has movable surplus."""
    loads = router.loads()
    free = [max(0, l.slots - l.active) for l in loads]
    need = [max(0, f - l.queued) for f, l in zip(free, loads)]
    surplus = []
    for i, (f, l) in enumerate(zip(free, loads)):
        movable = sum(1 for q in router.replicas[i].queue
                      if q.uid not in router.replicas[i]._ckpt)
        surplus.append(max(0, min(l.queued, movable) - f))
    assert not (any(need) and any(surplus)), (need, surplus)


def test_work_conservation_deterministic():
    """An idle replica steals queued work from a loaded one before the
    next tick; the starved replica never sits empty while its peer
    queues checkpoint-free surplus."""
    a, b = FakeReplica(0), FakeReplica(1)
    router = ReplicaRouter([a, b], routing="round-robin")
    # force-load replica 0: 6 requests all submitted directly
    for uid in range(6):
        a.submit(_req(uid))
        router.routed[uid] = 0
    router.step()
    _assert_conserved(router)
    assert router.migrations >= 1
    assert len(b.results) >= 1
    res = router.run()
    assert set(res) == set(range(6))
    assert all(r.done for r in res.values())
    # no duplication after the steal
    assert sum(len(r.results) for r in router.replicas) == 6


def test_locality_routing_sends_continuations_home():
    """LocalityRouting overrides load: a uid with a parked checkpoint
    at replica 1 routes there even when replica 0 is idle."""
    a, b = FakeReplica(0), FakeReplica(1)
    router = ReplicaRouter([a, b], routing="locality")
    b._ckpt[7] = object()            # continuation state parked at 1
    # replica 1 is also the BUSIER one — locality must still win
    for uid in range(4):
        b.submit(_req(uid))
        router.routed[uid] = 1
    assert router.submit(_req(7)) == 1
    # stateless uids still load-balance to the idle replica
    assert router.submit(_req(8)) == 0


def test_rebalancer_never_migrates_checkpointed_work():
    """Stickiness is a ROUTER guarantee: even under maximal imbalance
    the rebalancer moves only checkpoint-free requests."""
    a, b = FakeReplica(0), FakeReplica(1)
    router = ReplicaRouter([a, b])
    for uid in range(5):
        a.submit(_req(uid))
        router.routed[uid] = 0
    a._ckpt[3] = object()            # uid 3 has state at replica 0
    a._ckpt[4] = object()
    router._rebalance()
    assert 3 in a.results and 4 in a.results
    assert router.routed[3] == 0 and router.routed[4] == 0
    # movable uids DID migrate (the imbalance was real)
    assert router.migrations >= 1


def test_routing_registry_and_errors():
    """get_routing: None → round-robin default, instances pass through,
    unknown names fail loudly listing the registry."""
    assert get_routing(None).name == "round-robin"
    pol = LocalityRouting()
    assert get_routing(pol) is pol
    assert get_routing("least-loaded").name == "least-loaded"
    with pytest.raises(ValueError, match="least-loaded"):
        get_routing("nope")
    with pytest.raises(ValueError):
        ReplicaRouter([])
    # duplicate in-flight submit refused
    router = ReplicaRouter([FakeReplica(0)])
    router.submit(_req(1))
    with pytest.raises(ValueError, match="already routed"):
        router.submit(_req(1))


def test_replica_load_snapshot_shape():
    """ReplicaLoad reports exactly the host bookkeeping the policies
    key on: depth sums queued+active and backlog sums the remaining
    token budgets (queued requests at full budget, active slots at
    their slot_budget remainder)."""
    a = FakeReplica(0)
    for uid in range(3):
        a.submit(_req(uid))     # 3 tokens each
    a.step()                    # 2 admitted, each emitted 1 of 3
    (load,) = ReplicaRouter([a]).loads()
    assert load.slots == 2 and load.active == 2 and load.queued == 1
    assert load.depth == 3
    assert load.backlog == 3 + 2 + 2


def test_least_loaded_routes_by_token_backlog_not_count():
    """A replica holding one 16-token monopolizer is MORE loaded than
    one holding two 3-token requests: least-loaded must key on backlog,
    where count-based join-the-shortest-queue would pick wrong."""
    a, b = FakeReplica(0), FakeReplica(1)
    router = ReplicaRouter([a, b], routing="least-loaded")
    a.submit(_req(0, n_new=16))          # depth 1, backlog 16
    b.submit(_req(1))
    b.submit(_req(2))                    # depth 2, backlog 6
    router.routed.update({0: 0, 1: 1, 2: 1})
    assert router.submit(_req(3)) == 1


# ---------------------------------------------------------------------
# real engines: token parity across policies, swap never retraces
# ---------------------------------------------------------------------

def _real_setup():
    cfg = get_config("qwen3-32b", reduced=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    reqs = [Request(uid=i,
                    tokens=rng.integers(0, cfg.vocab - 2,
                                        5 + (i % 3) * 7).astype(np.int32),
                    max_new_tokens=4)
            for i in range(6)]
    return m, params, reqs


@pytest.mark.slow
def test_routed_tokens_match_single_engine_every_policy():
    """Routing is placement, not semantics: every policy decodes the
    same tokens as one unrouted engine, with one decode program per
    replica, and a mid-serve policy swap traces nothing new."""
    m, params, reqs = _real_setup()
    e0 = ServingEngine(m, params, max_slots=2, cache_len=64,
                       prefill_buckets=False)
    for r in reqs:
        e0.submit(r)
    base = {u: tuple(res.output) for u, res in e0.run().items()}
    for routing in ("round-robin", "least-loaded", "locality"):
        engs = [ServingEngine(m, params, max_slots=2, cache_len=64,
                              prefill_buckets=False) for _ in range(2)]
        router = ReplicaRouter(engs, routing=routing)
        for r in reqs:
            router.submit(r)
        res = router.run()
        assert {u: tuple(x.output) for u, x in res.items()} == base, \
            routing
        for e in engs:
            assert jit_cache_size(e._decode) == 1, routing


@pytest.mark.slow
def test_policy_swap_mid_serve_never_retraces():
    """Swap round-robin → least-loaded → locality while requests are in
    flight: every replica's decode cache stays frozen at one program
    and the merged results still match the unrouted baseline."""
    m, params, reqs = _real_setup()
    e0 = ServingEngine(m, params, max_slots=2, cache_len=64,
                       prefill_buckets=False)
    for r in reqs:
        e0.submit(r)
    base = {u: tuple(res.output) for u, res in e0.run().items()}
    engs = [ServingEngine(m, params, max_slots=2, cache_len=64,
                          prefill_buckets=False) for _ in range(2)]
    router = ReplicaRouter(engs, routing="round-robin")
    for r in reqs[:3]:
        router.submit(r)
    for _ in range(2):
        router.step()
    before = [jit_cache_size(e._decode) for e in engs]
    router.set_routing("least-loaded")
    for r in reqs[3:5]:
        router.submit(r)
    for _ in range(2):
        router.step()
    router.set_routing(LocalityRouting())
    router.submit(reqs[5])
    res = router.run()
    after = [jit_cache_size(e._decode) for e in engs]
    assert before == after == [1, 1]
    assert {u: tuple(x.output) for u, x in res.items()} == base
