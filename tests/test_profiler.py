"""MicroProfiler (§5.4) — per-op attribution identifies the bottleneck
operator and its eager totals are consistent."""

import numpy as np

from repro.apps import build_conv_reference, build_vww
from repro.core import AllOpsResolver, MicroInterpreter, MicroModel, export
from repro.core.profiler import MicroProfiler


def _interp(gb):
    resolver = AllOpsResolver()
    model = MicroModel(export(gb))
    size = MicroInterpreter.required_arena_size(model, resolver)
    return MicroInterpreter(model, resolver, size)


def test_profile_conv_reference():
    gb = build_conv_reference()
    interp = _interp(gb)
    rng = np.random.default_rng(0)
    xs = [rng.normal(0, 1, gb.tensors[t].shape).astype(np.float32)
          for t in gb.inputs]
    rep = MicroProfiler.profile(interp, xs, warmup=1, iters=3)
    assert len(rep.per_op) == len(interp._op_plans)
    assert rep.eager_total_us > 0 and rep.fused_total_us > 0
    assert all(p.wall_us >= 0 for p in rep.per_op)
    # conv model: convolutions must dominate (the paper's premise that
    # linear algebra dominates run time)
    assert rep.bottleneck() in ("CONV_2D", "FULLY_CONNECTED",
                                "DEPTHWISE_CONV_2D")
    text = rep.render()
    assert "bottlenecks first" in text and "CONV_2D" in text


def test_profile_vww_bottleneck_is_conv():
    gb = build_vww()
    interp = _interp(gb)
    rng = np.random.default_rng(1)
    xs = [rng.normal(0, 1, gb.tensors[t].shape).astype(np.float32)
          for t in gb.inputs]
    rep = MicroProfiler.profile(interp, xs, warmup=1, iters=2)
    by_type = rep.by_op_type()
    conv_us = sum(v for k, v in by_type.items() if "CONV" in k)
    assert conv_us > 0.5 * rep.eager_total_us, by_type
