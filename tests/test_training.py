"""Training substrate tests: optimizer math, loss descent, grad accum,
checkpoint round-trip, data pipeline invariants."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import PackedLMDataset
from repro.models import get_model
from repro.training import (adamw_init, adamw_update, clip_by_global_norm,
                            cosine_schedule)
from repro.training.trainer import (TrainState, init_train_state,
                                    make_train_step)


def test_adamw_matches_reference_math():
    """One AdamW step vs a hand-rolled numpy reference."""
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]], jnp.float32)}
    g = {"w": jnp.asarray([[0.1, -0.2], [0.3, 0.4]], jnp.float32)}
    st = adamw_init(p)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.1
    new_p, new_st = adamw_update(g, st, p, lr=lr, b1=b1, b2=b2, eps=eps,
                                 weight_decay=wd)
    gn = np.asarray(g["w"])
    m = (1 - b1) * gn
    v = (1 - b2) * gn ** 2
    mhat = m / (1 - b1)
    vhat = v / (1 - b2)
    want = np.asarray(p["w"]) - lr * (mhat / (np.sqrt(vhat) + eps)
                                      + wd * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-6)
    assert int(new_st.step) == 1


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 20.0, rtol=1e-5)
    got = np.linalg.norm(np.asarray(clipped["a"]))
    np.testing.assert_allclose(got, 1.0, rtol=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr(jnp.asarray(100))) < float(lr(jnp.asarray(50)))
    assert float(lr(jnp.asarray(100))) >= 1e-4 - 1e-9   # floor


def test_loss_decreases_on_markov_data():
    """Markov source has learnable structure: 30 steps must cut loss."""
    cfg = get_config("yi-6b", reduced=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    state = init_train_state(params)
    step = jax.jit(make_train_step(m.loss, lr=3e-3, remat=False,
                                   data_shards=1))
    ds = PackedLMDataset(cfg, batch=8, seq=32, seed=0)
    losses = []
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["ce_loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]


def test_grad_accum_equivalence():
    """grad_accum=2 must equal a single big-batch step (linear loss)."""
    cfg = get_config("yi-6b", reduced=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    ds = PackedLMDataset(cfg, batch=4, seq=16, seed=1)
    batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}

    s_big = jax.jit(make_train_step(m.loss, lr=1e-3, remat=False,
                                    data_shards=1))(
        init_train_state(params), batch)
    s_acc = jax.jit(make_train_step(m.loss, lr=1e-3, grad_accum=2,
                                    remat=False, data_shards=1))(
        init_train_state(params), batch)
    # losses close (not identical: per-microbatch mask renorm)
    assert abs(float(s_big[1]["loss"]) - float(s_acc[1]["loss"])) < 0.1


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("qwen3-32b", reduced=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(2))
    state = init_train_state(params)
    out = save_checkpoint(str(tmp_path), 7, state)
    assert os.path.exists(os.path.join(out, "manifest.json"))
    like = jax.tree.map(lambda x: x, state)
    restored = restore_checkpoint(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_packed_dataset_invariants():
    cfg = get_config("yi-6b", reduced=True)
    ds = PackedLMDataset(cfg, batch=4, seq=64, seed=3)
    eos = cfg.vocab - 1
    for _ in range(3):
        b = ds.next_batch()
        assert b["tokens"].shape == (4, 64)
        assert b["labels"].shape == (4, 64)
        assert b["tokens"].min() >= 0 and b["tokens"].max() < cfg.vocab
        # label after EOS is masked
        assert (b["labels"][b["tokens"] == eos] == -1).all()
        # determinism: same seed -> same stream
    ds2 = PackedLMDataset(cfg, batch=4, seq=64, seed=3)
    b1 = PackedLMDataset(cfg, batch=4, seq=64, seed=3).next_batch()
    b2 = ds2.next_batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
