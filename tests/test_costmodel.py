"""The calibration cost model's contract (repro.core.costmodel):
calibration is deterministic given a seed and a measurement function,
profiles round-trip through their versioned JSON into identical engine
configurations, the solver's bucket/chunk choices follow the measured
cost landscape, engines fall back to today's hand-picked defaults when
no profile exists, and ``BucketTable`` holds up at its edges (over-cap
sizes, single-level tables, min==max, profile-vs-hand construction)."""

import numpy as np
import pytest

from repro.core import (BucketCost, BucketTable, CalibrationProfile,
                        ChunkCost, CompileStepTiming, DecodeCost,
                        LaneCost, calibrate, profile_model_key, solve,
                        solve_lanes, solve_replicas)


class _Cfg:
    family = "dense"
    arch_id = "toy"
    vocab = 32


class _Bundle:
    cfg = _Cfg()


def synthetic_measure(compile_us=2000.0, step_per_tok=2.0,
                      chunk_overhead=1.2):
    """A deterministic stand-in for EngineMeasurer: compile cost is
    flat, step cost linear in the padded length, chunk steps carry a
    small per-dispatch overhead factor."""
    def measure(kind, size):
        if kind == "prefill":
            return CompileStepTiming(
                compile_us=compile_us + step_per_tok * size,
                step_us=step_per_tok * size, iters=5)
        return CompileStepTiming(
            compile_us=compile_us + chunk_overhead * step_per_tok * size,
            step_us=chunk_overhead * step_per_tok * size, iters=5)
    return measure


LENGTHS = [5] * 8 + [9] * 6 + [17] * 4 + [41] * 2


# ---------------------------------------------------------------------------
# BucketTable edges (profile-constructed tables included)
# ---------------------------------------------------------------------------

def test_bucket_table_default_is_pow2_ladder():
    t = BucketTable(min_bucket=8, max_bucket=64)
    assert t.levels == [8, 16, 32, 64]
    assert t.fit(1) == 8 and t.fit(9) == 16 and t.fit(64) == 64


def test_bucket_table_over_cap_prompt():
    t = BucketTable(min_bucket=8, max_bucket=64)
    assert t.fit(65) is None            # probe records nothing
    assert t.hits == {}
    with pytest.raises(ValueError):     # commit stays loud
        t.bucket(65)


def test_bucket_table_single_element():
    t = BucketTable.from_levels([32])
    assert t.min_bucket == t.max_bucket == 32
    assert t.fit(1) == 32 and t.fit(32) == 32 and t.fit(33) is None
    assert t.bucket(7) == 32 and t.hits == {32: 1}


def test_bucket_table_min_equals_max():
    t = BucketTable(min_bucket=16, max_bucket=16)
    assert t.levels == [16]
    assert t == BucketTable.from_levels([16])


def test_bucket_table_granularity():
    t = BucketTable(min_bucket=4, max_bucket=64, granularity=4)
    assert t.levels == [4, 16, 64]
    with pytest.raises(ValueError):
        BucketTable(min_bucket=4, max_bucket=64, granularity=1)
    with pytest.raises(ValueError):     # silently truncating 2.9 -> 2
        BucketTable(min_bucket=4, max_bucket=64, granularity=2.9)


def test_bucket_table_rejects_bad_levels():
    for bad in ([], [8, 8], [16, 8], [0, 8]):
        with pytest.raises(ValueError):
            BucketTable.from_levels(bad)
    with pytest.raises(ValueError):     # contradictory mixed forms
        BucketTable(min_bucket=8, max_bucket=64, levels=[4, 8])


def test_bucket_table_is_hashable_consistently_with_eq():
    a = BucketTable(min_bucket=8, max_bucket=64)
    b = BucketTable.from_levels([8, 16, 32, 64])
    assert a == b and hash(a) == hash(b)
    assert len({a, b}) == 1             # usable as dict/set member


def test_profile_table_matches_hand_constructed_bit_identically():
    """A table rebuilt from a profile spec behaves IDENTICALLY to the
    hand-constructed one on every size — same levels, same fits, same
    hit accounting."""
    hand = BucketTable.from_levels([8, 24, 48])
    rebuilt = BucketTable.from_spec(hand.spec())
    assert rebuilt == hand and rebuilt.levels == [8, 24, 48]
    for n in range(1, 49):
        assert rebuilt.fit(n) == hand.fit(n), n
        assert rebuilt.bucket(n) == hand.bucket(n), n
    assert rebuilt.hits == hand.hits
    # pow2 default expressed as levels == pow2 default expressed as args
    assert BucketTable(8, 64) == BucketTable.from_levels([8, 16, 32, 64])


# ---------------------------------------------------------------------------
# calibration determinism + profile round-trip
# ---------------------------------------------------------------------------

def test_calibration_is_deterministic():
    kw = dict(cache_len=64, seed=3, measure=synthetic_measure(),
              chunk_candidates=(0, 8))
    a = calibrate(_Bundle(), None, LENGTHS, **kw)
    b = calibrate(_Bundle(), None, LENGTHS, **kw)
    assert a.to_json() == b.to_json()   # byte-identical profiles
    assert a.model_key == profile_model_key(_Cfg(), 64)
    # nothing volatile may be stored: the meta block is version info
    assert set(a.meta) == {"jax", "backend"}


def test_profile_round_trip(tmp_path):
    p = calibrate(_Bundle(), None, LENGTHS, cache_len=64, seed=0,
                  measure=synthetic_measure())
    path = p.save(str(tmp_path / "profile.json"))
    q = CalibrationProfile.load(path)
    assert q.to_json() == p.to_json()
    assert q.bucket_table() == p.bucket_table()
    assert q.prefill_chunk == p.prefill_chunk
    assert q.bucket_costs == p.bucket_costs
    assert q.chunk_costs == p.chunk_costs


def test_profile_version_guard(tmp_path):
    p = calibrate(_Bundle(), None, LENGTHS, cache_len=64,
                  measure=synthetic_measure())
    bad = p.to_json().replace('"version": 1', '"version": 99')
    with pytest.raises(ValueError, match="version"):
        CalibrationProfile.from_json(bad)


def test_calibrate_family_gate():
    """Calibration now covers every family with a bucketed OR chunked
    fast path to size (ssm/hybrid gained chunked prefill), so an ssm
    bundle calibrates fine; a family with neither (audio) refuses with
    the typed UnsupportedFamilyError."""
    from repro.serving.errors import UnsupportedFamilyError

    class SsmCfg:
        family = "ssm"
        arch_id = "s"
        vocab = 8

    class SsmBundle:
        cfg = SsmCfg()

    prof = calibrate(SsmBundle(), None, LENGTHS, cache_len=64,
                     measure=synthetic_measure())
    assert prof.bucket_levels

    class AudioCfg:
        family = "audio"
        arch_id = "a"
        vocab = 8

    class AudioBundle:
        cfg = AudioCfg()

    with pytest.raises(UnsupportedFamilyError, match="audio"):
        calibrate(AudioBundle(), None, LENGTHS, cache_len=64,
                  measure=synthetic_measure())


# ---------------------------------------------------------------------------
# solver semantics on synthetic cost landscapes
# ---------------------------------------------------------------------------

def _costs(lengths, measure):
    bc = [BucketCost(length=L, compile_us=measure("prefill", L).compile_us,
                     step_us=measure("prefill", L).step_us)
          for L in lengths]
    return bc


def test_solver_merges_buckets_when_compile_dominates():
    """Huge compile cost, flat step cost: one level covering the max
    length beats a finer ladder — the table collapses."""
    m = synthetic_measure(compile_us=1e6, step_per_tok=1.0)
    r = solve(LENGTHS, _costs([8, 16, 32, 64], m), [], cache_len=64)
    assert r.levels == [64] and r.predicted_compiles == 1


def test_solver_keeps_fine_buckets_when_padding_dominates():
    """Free compiles, costly padding: every measured level that saves
    padding for some request is worth tracing (level 32 serves no
    length in this mix, so it — and only it — is dropped)."""
    m = synthetic_measure(compile_us=0.0, step_per_tok=100.0)
    r = solve(LENGTHS, _costs([8, 16, 32, 64], m), [], cache_len=64)
    assert r.levels == [8, 16, 64] and r.predicted_compiles == 3


def test_solver_objective_counts_trace_overhead_once_per_level():
    m = synthetic_measure(compile_us=500.0, step_per_tok=1.0)
    r = solve([9, 9, 9], _costs([8, 16], m), [], cache_len=64)
    # 3 requests pad (9-1=8 tokens) into level 8: 3 steps + 1 compile
    assert r.levels == [8]
    assert r.expected_us == pytest.approx(3 * 8.0 + 500.0)


def test_head_of_line_bound_forces_chunking():
    """A dispatch bound below the big bucket's step cost excludes it;
    the solver must reach for chunked prefill to stay feasible."""
    m = synthetic_measure(compile_us=100.0, step_per_tok=10.0,
                          chunk_overhead=2.0)
    bc = _costs([8, 16, 32, 64], m)
    cc = [ChunkCost(chunk=8, compile_us=m("chunk", 8).compile_us,
                    step_us=m("chunk", 8).step_us)]
    free = solve(LENGTHS, bc, cc, cache_len=64)
    bound = solve(LENGTHS, bc, cc, cache_len=64, max_dispatch_us=200.0)
    assert free.chunk == 0              # serial optimum never chunks
    assert bound.chunk == 8 and bound.feasible
    assert bound.max_dispatch_us <= 200.0


def test_solver_chunk_fit_counts_vlm_vision_tokens():
    """Chunk eligibility must mirror ``ServingEngine._chunk_eligible``,
    vision prefix included: a chunked prompt that fits a dense cache
    can overflow a vlm cache whose prefix occupies rows."""
    m = synthetic_measure(compile_us=2000.0, step_per_tok=2.0,
                          chunk_overhead=0.9)
    bc = _costs([56], m)
    cc = [ChunkCost(chunk=8, compile_us=m("chunk", 8).compile_us,
                    step_us=m("chunk", 8).step_us)]
    reqs = [57] * 20                    # enough to amortize the chunk
    dense = solve(reqs, bc, cc, cache_len=64, vis_tokens=0)
    vlm = solve(reqs, bc, cc, cache_len=64, vis_tokens=16)
    assert dense.chunk == 8             # 56 chunked rows fit 64
    assert vlm.chunk == 0               # 16 + 56 > 64: engine would
    assert vlm.levels == [56]           # one-shot it, so must the model


def test_first_chunk_prefill_trace_dedupes_against_hit_bucket():
    """The engine's first chunk runs through the ordinary prefill
    program at (1, chunk); when unchunked requests also hit that
    bucket level the jit cache dedupes the trace, so the solver must
    count ONE prefill program, not two — and when nothing else hits
    it, the extra trace (and its overhead) must be charged."""
    m = synthetic_measure(compile_us=50.0, step_per_tok=10.0,
                          chunk_overhead=0.5)
    bc = _costs([8, 64], m)
    cc = [ChunkCost(chunk=8, compile_us=m("chunk", 8).compile_us,
                    step_us=m("chunk", 8).step_us)]
    # short requests hit level 8; long ones chunk with chunk=8:
    # the (1, 8) prefill trace is shared -> 1 prefill program total
    shared = solve([5] * 10 + [41] * 10, bc, cc, cache_len=64)
    assert shared.chunk == 8 and shared.levels == [8]
    assert shared.predicted_compiles == 1
    # all requests chunk: the first-chunk trace is the ONLY prefill
    # program, and its trace overhead is in the objective
    alone = solve([41] * 10, bc, cc, cache_len=64)
    assert alone.chunk == 8
    assert alone.predicted_compiles == 1
    first = next(c for c in bc if c.length == 8)
    cc8 = cc[0]
    want = (10 * (first.step_us + 4 * cc8.step_us)
            + cc8.trace_overhead_us + first.trace_overhead_us)
    assert alone.expected_us == pytest.approx(want)


def test_explicit_candidates_beyond_room_fail_loudly():
    """Candidate levels the engine could never use (over the cache
    room) must raise, not silently produce an unusable profile."""
    class VlmCfg:
        family = "vlm"
        arch_id = "v"
        vocab = 8
        n_vision_tokens = 48

    class VlmBundle:
        cfg = VlmCfg()

    with pytest.raises(ValueError, match="cache room"):
        calibrate(VlmBundle(), None, LENGTHS, cache_len=64,
                  candidate_levels=(32, 64),    # room is only 16
                  measure=synthetic_measure())


def test_infeasible_bound_is_flagged_not_hidden():
    m = synthetic_measure(compile_us=0.0, step_per_tok=10.0)
    r = solve([41], _costs([64], m), [], cache_len=64,
              max_dispatch_us=1.0)
    assert not r.feasible               # least-bad config, loud flag


def test_default_comparison_is_priced_from_measurements():
    """default_expected_us must count EVERY request at the default
    table's measured level — the default pow2 levels this workload
    hits are measured even when the solver's explicit candidates skip
    them (and they stay out of the solved table)."""
    p = calibrate(_Bundle(), None, [25] * 4, cache_len=64, seed=0,
                  candidate_levels=(40, 64),
                  measure=synthetic_measure(compile_us=2000.0,
                                            step_per_tok=2.0))
    # default: plen 24 -> pow2 level 32 (measured: step 64, trace 2000)
    assert 32 in {c.length for c in p.bucket_costs}
    assert p.default_expected_us == pytest.approx(4 * 64.0 + 2000.0)
    # ...but 32 was never offered to the solver
    assert all(l in (40, 64) for l in p.bucket_levels)


def test_calibrate_keeps_a_capacity_guard_level():
    """A prompt longer than anything in the calibration workload must
    still bucket (one compile), not silently fall back to exact-length
    retrace-per-length: the solved table always keeps its largest
    measured candidate as a guard level."""
    p = calibrate(_Bundle(), None, [9] * 10, cache_len=64, seed=0,
                  candidate_levels=(8, 16, 64),
                  measure=synthetic_measure(compile_us=1e6))
    assert p.bucket_levels[-1] == 64    # guard, even though the
    t = p.bucket_table()                # workload never needs it
    assert t.fit(63) == 64
    # the guard is free: only the workload's hit level is predicted
    assert p.predicted_compiles == 1


def test_default_measurer_builds_vlm_prefill_batches():
    """calibrate() admits vlm (it is a BUCKETED family), so the
    default EngineMeasurer must synthesize the vision prefix a vlm
    prefill batch requires instead of KeyError-ing on it."""
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models import get_model
    cfg = get_config("paligemma-3b", reduced=True)
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    p = calibrate(bundle, params, [6] * 4, cache_len=64, seed=0,
                  candidate_levels=(8,), chunk_candidates=(), iters=1)
    assert p.model_key == profile_model_key(cfg, 64)
    assert p.bucket_levels == [8]
    assert all(c.step_us > 0 for c in p.bucket_costs)


def test_single_token_prompts_need_no_calibration():
    with pytest.raises(ValueError, match="multi-token"):
        calibrate(_Bundle(), None, [1, 1], cache_len=64,
                  measure=synthetic_measure())


# ---------------------------------------------------------------------------
# batched-dispatch calibration: lane widths and replica counts
# ---------------------------------------------------------------------------

def lane_measure(fixed_us=80.0, per_lane_us=10.0, compile_us=3000.0):
    """Deterministic pooled-dispatch cost stand-in: every dispatch
    pays a fixed overhead plus a per-lane term (sublinear batching —
    what makes widening lanes worthwhile)."""
    def measure(kind, size):
        assert kind == "micro", kind
        step = fixed_us + per_lane_us * size
        return CompileStepTiming(compile_us=compile_us + step,
                                 step_us=step, iters=5)
    return measure


def test_lane_solver_amortizes_fixed_dispatch_overhead():
    """Steady demand of 8 concurrent jobs: one 8-wide dispatch (160µs)
    beats eight 1-wide ones (8×90µs) — the fixed overhead dominates —
    while a head-of-line bound under the wide step forces narrow."""
    costs = [LaneCost(lanes=B, compile_us=0.0, step_us=80.0 + 10.0 * B)
             for B in (1, 2, 4, 8)]
    wide = solve_lanes([8] * 10, costs)
    assert wide.lanes == 8 and wide.feasible
    bound = solve_lanes([8] * 10, costs, max_dispatch_us=110.0)
    assert bound.lanes == 2 and bound.feasible
    assert bound.max_dispatch_us <= 110.0
    # a bound under every candidate: least-bad, flagged infeasible
    hopeless = solve_lanes([8] * 10, costs, max_dispatch_us=10.0)
    assert not hopeless.feasible and hopeless.lanes == 1


def test_lane_solver_counts_padding_waste():
    """Demand of 1 job per tick: an 8-wide pool pays the full wide
    dispatch for one job every tick, so width 1 wins even though it
    is worse per-lane at full occupancy."""
    costs = [LaneCost(lanes=B, compile_us=0.0, step_us=80.0 + 10.0 * B)
             for B in (1, 8)]
    r = solve_lanes([1] * 20, costs)
    assert r.lanes == 1


def test_lane_solver_rejects_empty_inputs():
    costs = [LaneCost(lanes=1, compile_us=0.0, step_us=1.0)]
    with pytest.raises(ValueError, match="micro jobs"):
        solve_lanes([0, 0], costs)
    with pytest.raises(ValueError, match="LaneCost"):
        solve_lanes([1], [])


def test_replica_solver_sizes_for_throughput_target():
    """One measured decode dispatch sizes the replica set: 2 slots per
    100µs = 0.02 tok/µs per replica, so a 0.05 tok/µs target needs 4
    replicas from a (1,2,4,8) ladder; an unreachable target returns
    the largest candidate flagged infeasible."""
    d = DecodeCost(slots=2, compile_us=5000.0, step_us=100.0)
    r = solve_replicas(0.05, d)
    assert r.replicas == 4 and r.feasible
    assert r.tokens_per_us == pytest.approx(0.08)
    bad = solve_replicas(1.0, d, candidates=(1, 2))
    assert bad.replicas == 2 and not bad.feasible
    with pytest.raises(ValueError, match="positive"):
        solve_replicas(0.0, d)
    with pytest.raises(ValueError, match="positive count"):
        solve_replicas(0.1, d, candidates=())


def test_lane_and_replica_calibration_deterministic_round_trip(tmp_path):
    """The batched-dispatch extension keeps the profile contract: same
    seed + same measurements → byte-identical profiles, and the lane/
    replica fields survive save → load bit-exactly."""
    def measure(kind, size):
        if kind == "micro":
            return lane_measure()(kind, size)
        return synthetic_measure()(kind, size)
    kw = dict(cache_len=64, seed=7, measure=measure,
              lane_candidates=(1, 2, 4), lane_demand=[4, 4, 1],
              decode_slots=(2,), replica_candidates=(1, 2, 4),
              target_tokens_per_us=0.01)
    a = calibrate(_Bundle(), None, LENGTHS, **kw)
    b = calibrate(_Bundle(), None, LENGTHS, **kw)
    assert a.to_json() == b.to_json()
    assert a.micro_lanes in (1, 2, 4) and a.micro_lanes > 0
    assert len(a.lane_costs) == 3
    assert a.replicas >= 1 and len(a.replica_costs) == 3
    q = CalibrationProfile.load(a.save(str(tmp_path / "p.json")))
    assert q.to_json() == a.to_json()
    assert q.lane_costs == a.lane_costs
    assert q.replica_costs == a.replica_costs
    assert q.micro_lanes == a.micro_lanes
    assert q.replicas == a.replicas


def test_profile_without_batched_dispatch_fields_still_loads():
    """Profiles written before the batched-dispatch extension (no
    lane/replica keys) load unchanged with the not-calibrated
    defaults — the same rule the paged extension follows."""
    import json
    p = calibrate(_Bundle(), None, LENGTHS, cache_len=64,
                  measure=synthetic_measure())
    d = json.loads(p.to_json())
    for k in ("micro_lanes", "lane_costs", "replicas",
              "replica_costs"):
        del d[k]
    q = CalibrationProfile.from_json(json.dumps(d))
    assert q.micro_lanes == 0 and q.lane_costs == []
    assert q.replicas == 0 and q.replica_costs == []
    assert q.bucket_levels == p.bucket_levels


def test_lane_calibration_requires_micro_or_injected_measure():
    """The default EngineMeasurer cannot price micro dispatches, so
    asking for lanes without a (model, resolver) pair or an injected
    measure must fail loudly, not KeyError later."""
    with pytest.raises(ValueError, match="micro="):
        calibrate(_Bundle(), None, LENGTHS, cache_len=64,
                  lane_candidates=(1, 2))


def test_replica_calibration_requires_measured_decode():
    with pytest.raises(ValueError, match="decode_slots"):
        calibrate(_Bundle(), None, LENGTHS, cache_len=64,
                  measure=synthetic_measure(),
                  replica_candidates=(1, 2))


def test_micro_measurer_prices_real_pooled_dispatch():
    """MicroMeasurer times a REAL InterpreterPool.invoke at each lane
    width: timings are positive and the batch axis is the shape the
    width is keyed on."""
    from repro.apps import build_conv_reference
    from repro.core import (AllOpsResolver, MicroMeasurer, MicroModel,
                            export)
    model = MicroModel(export(build_conv_reference()))
    m = MicroMeasurer(model, AllOpsResolver(), seed=0, iters=1)
    for lanes in (1, 2):
        t = m("micro", lanes)
        assert t.compile_us > 0 and t.step_us > 0
    with pytest.raises(ValueError, match="micro"):
        m("prefill", 8)


# ---------------------------------------------------------------------------
# engine / host plumbing: profile in, defaults as fallback
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lm():
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models import get_model
    cfg = get_config("qwen3-32b", reduced=True)
    bundle = get_model(cfg)
    return bundle, bundle.init(jax.random.PRNGKey(0))


def _profile_for(bundle, **kw):
    kw.setdefault("cache_len", 64)
    kw.setdefault("measure", synthetic_measure())
    kw.setdefault("candidate_levels", (8, 16, 40, 64))
    return calibrate(bundle, None, LENGTHS, **kw)


def test_from_profile_configures_the_engine(lm, tmp_path):
    """save → load → from_profile lands the exact solved config on the
    engine: same table (bit-identical levels), same chunk size."""
    from repro.serving import ServingEngine
    bundle, params = lm
    prof = _profile_for(bundle)
    loaded = CalibrationProfile.load(
        prof.save(str(tmp_path / "p.json")))
    eng = ServingEngine.from_profile(bundle, params, loaded,
                                     max_slots=2)
    assert eng.cache_len == prof.cache_len
    assert eng.bucket_table == prof.bucket_table()
    assert eng.bucket_table.levels == prof.bucket_levels
    assert eng.chunk_tokens == prof.prefill_chunk
    # explicit overrides beat the profile
    eng2 = ServingEngine.from_profile(bundle, params, loaded,
                                      max_slots=2,
                                      prefill_buckets=False)
    assert eng2.bucket_table is None


def test_from_profile_rejects_foreign_model(lm):
    from repro.serving import ServingEngine
    bundle, params = lm
    prof = _profile_for(bundle)
    prof.model_key = "dense/someone-else/L64"
    with pytest.raises(ValueError, match="calibrated for"):
        ServingEngine.from_profile(bundle, params, prof, max_slots=2)
    # a different cache_len is a different cost landscape too
    prof2 = _profile_for(bundle)
    with pytest.raises(ValueError, match="calibrated for"):
        ServingEngine.from_profile(bundle, params, prof2, max_slots=2,
                                   cache_len=32)


def test_from_profile_rejects_foreign_backend(lm):
    """Costs are hardware facts: a profile measured on another backend
    is refused like a foreign model_key."""
    from repro.serving import ServingEngine
    bundle, params = lm
    prof = _profile_for(bundle)
    assert prof.matches_backend()       # stamped with the live backend
    prof.meta["backend"] = "tpu"
    assert not prof.matches_backend()
    with pytest.raises(ValueError, match="backend"):
        ServingEngine.from_profile(bundle, params, prof, max_slots=2)


def test_no_profile_fallback_is_todays_default(lm):
    """Without a profile nothing changes: the engine auto-builds the
    hand-picked pow2 ladder, chunking stays off, and the host hands
    every tenant the shared default table."""
    from repro.serving import MultiTenantHost, ServingEngine
    bundle, params = lm
    eng = ServingEngine(bundle, params, max_slots=2, cache_len=64)
    assert eng.bucket_table == BucketTable(min_bucket=8, max_bucket=64)
    assert eng.chunk_tokens == 0
    host = MultiTenantHost(arena_bytes=64 << 20)
    assert host.profile is None
    heng = host.add_model("lm", bundle, params, cache_len=64)
    assert heng.bucket_table is host.prompt_buckets
    assert heng.bucket_table == BucketTable(min_bucket=8,
                                            max_bucket=4096)
    assert heng.chunk_tokens == 0


def test_host_shares_one_profile_across_tenants(lm):
    from repro.serving import MultiTenantHost
    bundle, params = lm
    prof = _profile_for(bundle)
    host = MultiTenantHost(arena_bytes=128 << 20, profile=prof)
    a = host.add_model("a", bundle, params, cache_len=64)
    b = host.add_model("b", bundle, params, cache_len=64)
    assert a.bucket_table is host.prompt_buckets
    assert b.bucket_table is host.prompt_buckets      # ONE shared table
    assert a.bucket_table == prof.bucket_table()
    assert a.chunk_tokens == prof.prefill_chunk
    assert b.chunk_tokens == prof.prefill_chunk


@pytest.mark.slow
def test_real_calibration_beats_defaults_and_stays_bit_identical(lm):
    """The acceptance loop end to end with REAL measurements: the
    autotuned engine traces fewer prefill programs than the default on
    a clustered length mix, with bit-identical decoded tokens."""
    from repro.serving import Request, ServingEngine
    bundle, params = lm
    lengths = [5] * 6 + [7] * 4 + [9] * 4 + [41] * 2
    prof = calibrate(bundle, params, lengths, cache_len=64, seed=0,
                     candidate_levels=(8, 16, 40, 64),
                     chunk_candidates=(0, 8))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, bundle.cfg.vocab - 2, L).astype(np.int32)
               for L in lengths]

    def run(eng):
        for uid, toks in enumerate(prompts):
            eng.submit(Request(uid=uid, tokens=toks, max_new_tokens=3))
        eng.run()
        return {u: r.output for u, r in eng.results.items()}

    default = ServingEngine(bundle, params, max_slots=2, cache_len=64)
    tuned = ServingEngine.from_profile(bundle, params, prof,
                                       max_slots=2)
    out_default = run(default)
    out_tuned = run(tuned)
    assert out_tuned == out_default                   # bit-identical
    assert tuned.prefill_compiles() < default.prefill_compiles()
    assert tuned.prefill_compiles() == prof.predicted_compiles
