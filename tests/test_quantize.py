"""Quantization math: bit-faithfulness of the gemmlowp fixed-point path."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import quantize as Q


def test_quantize_multiplier_decomposition():
    for m in [0.25, 0.5, 0.9999, 1.0, 1.5, 1e-3, 0.0003]:
        q, s = Q.quantize_multiplier(m)
        approx = q / (1 << 31) * (2.0 ** s)
        assert abs(approx - m) / m < 1e-6


def test_quantize_multiplier_zero():
    assert Q.quantize_multiplier(0.0) == (0, 0)


def test_quantize_multiplier_negative_raises():
    with pytest.raises(ValueError):
        Q.quantize_multiplier(-0.5)


def test_choose_quant_params_covers_range():
    s, z = Q.choose_quant_params(-6.0, 6.0)
    assert Q.INT8_MIN <= z <= Q.INT8_MAX
    lo = (Q.INT8_MIN - z) * s
    hi = (Q.INT8_MAX - z) * s
    # covers [rmin, rmax] to within one quantization step (zp nudging)
    assert lo <= -6.0 + s and hi >= 6.0 - s


def test_choose_quant_params_straddles_zero():
    s, z = Q.choose_quant_params(2.0, 6.0)   # must widen to include 0
    lo = (Q.INT8_MIN - z) * s
    hi = (Q.INT8_MAX - z) * s
    assert lo <= 0.0 <= hi                    # zero exactly representable
    assert abs((z - z) * s) == 0.0


def test_per_channel_weight_quantization_roundtrip():
    rng = np.random.default_rng(0)
    w = rng.normal(0, 0.3, (16, 3, 3, 8)).astype(np.float32)
    qw, scales = Q.quantize_weights_per_channel(w, axis=0)
    assert qw.dtype == np.int8 and scales.shape == (16,)
    deq = qw.astype(np.float32) * scales[:, None, None, None]
    err = np.abs(deq - w).max()
    assert err <= scales.max() * 0.5 + 1e-7   # within half an LSB per chan


@settings(max_examples=300, deadline=None)
@given(
    st.integers(min_value=-(2 ** 28), max_value=2 ** 28),   # accumulator
    st.floats(min_value=1e-6, max_value=0.9999),            # real multiplier
)
def test_property_fixed_point_requant_within_1lsb(acc, real_mult):
    """TFLM's int-only requant must match float scaling within 1 LSB."""
    mult, shift = Q.quantize_multiplier(real_mult)
    got = Q.multiply_by_quantized_multiplier_np(
        np.array([acc], np.int32), mult, shift)[0]
    want = acc * real_mult
    assert abs(got - want) <= 1.0 + abs(want) * 1e-6


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(min_value=-(2 ** 20), max_value=2 ** 20),
                min_size=1, max_size=32),
       st.floats(min_value=1e-4, max_value=0.999))
def test_property_jnp_matches_numpy_requant(accs, real_mult):
    """The traced (jnp) requant path is bit-identical to the numpy twin."""
    import jax.numpy as jnp

    mult, shift = Q.quantize_multiplier(real_mult)
    a = np.asarray(accs, np.int32)
    want = Q.multiply_by_quantized_multiplier_np(a, mult, shift)
    with Q.x64_scope():
        got = np.asarray(Q.multiply_by_quantized_multiplier(
            jnp.asarray(a), mult, shift))
    np.testing.assert_array_equal(got, want)


def test_requantize_np_saturates():
    out = Q.requantize_np(np.array([10 ** 9], np.int32), 1 << 30, 1, 0)
    assert out[0] == Q.INT8_MAX
    out = Q.requantize_np(np.array([-10 ** 9], np.int32), 1 << 30, 1, 0)
    assert out[0] == Q.INT8_MIN


def test_bias_quantization():
    b = np.array([0.5, -0.25], np.float32)
    bq = Q.quantize_bias(b, 0.02, np.array([0.01, 0.01]))
    np.testing.assert_array_equal(bq, [2500, -1250])
