"""Two-stack arena allocator invariants (paper §4.4.1, Figure 3)."""

import pytest

from repro.core.arena import (ArenaOverflowError, TwoStackArena, align_up)


def test_head_and_tail_grow_toward_each_other():
    a = TwoStackArena(1024)
    h1 = a.allocate_nonpersistent(100, "h1")
    t1 = a.allocate_persistent(100, "t1")
    h2 = a.allocate_nonpersistent(50, "h2")
    t2 = a.allocate_persistent(50, "t2")
    assert h1 == 0
    assert h2 >= h1 + 100
    assert t1 > h2 + 50
    assert t2 < t1
    assert t1 + 100 <= 1024
    # alignment
    assert h1 % 16 == 0 and h2 % 16 == 0
    assert t1 % 16 == 0 and t2 % 16 == 0


def test_crossing_stacks_raises():
    a = TwoStackArena(256)
    a.allocate_nonpersistent(128)
    a.allocate_persistent(64)
    with pytest.raises(ArenaOverflowError):
        a.allocate_persistent(128)


def test_exact_accounting():
    a = TwoStackArena(4096)
    a.allocate_nonpersistent(100)
    a.allocate_persistent(200)
    u = a.usage()
    assert u.nonpersistent == 100
    # persistent is tail_used: size - tail; tail = align_down(4096-200)=3888
    assert u.persistent == 4096 - 3888 == 208
    assert u.total == u.persistent + u.nonpersistent


def test_temp_region_between_stacks():
    a = TwoStackArena(1024)
    a.allocate_nonpersistent(64)
    off = a.allocate_temp(128)
    assert off >= 64
    assert a.usage().temp_high_water >= 128
    a.reset_temp()
    assert a.free_bytes == a._tail - a._head


def test_temp_overflow_raises():
    a = TwoStackArena(256)
    a.allocate_nonpersistent(100)
    a.allocate_persistent(100)
    with pytest.raises(ArenaOverflowError):
        a.allocate_temp(100)


def test_no_allocation_after_freeze():
    a = TwoStackArena(1024)
    a.allocate_nonpersistent(64)
    a.freeze()
    with pytest.raises(RuntimeError):
        a.allocate_nonpersistent(1)
    with pytest.raises(RuntimeError):
        a.allocate_persistent(1)


def test_freeze_with_outstanding_temp_raises():
    a = TwoStackArena(1024)
    a.allocate_temp(64)
    with pytest.raises(RuntimeError):
        a.freeze()


def test_multitenant_fork_stacks_persistent_and_shares_head():
    a = TwoStackArena(4096)
    a.allocate_persistent(256, "m1")
    a.allocate_nonpersistent(512, "m1_plan")
    child = a.fork_tenant()
    # child persistents stack BELOW parent's tail
    t = child.allocate_persistent(128, "m2")
    assert t + 128 <= a._tail
    # child head restarts at 0 (shared nonpersistent region, Figure 5)
    h = child.allocate_nonpersistent(256, "m2_plan")
    assert h == 0
    a.absorb_tenant(child)
    u = a.usage()
    # nonpersistent requirement = max(tenants), not sum
    assert u.nonpersistent == 512
    assert u.persistent >= 256 + 128
