"""Memory planner invariants (paper §4.4.2, Figure 4) incl. property tests."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.memory_planner import (BufferRequest, GreedyMemoryPlanner,
                                       LinearMemoryPlanner, MemoryPlan,
                                       OfflineMemoryPlanner,
                                       lifetimes_from_graph)


def _reqs(tuples):
    return [BufferRequest(nb, f, l, f"b{i}")
            for i, (nb, f, l) in enumerate(tuples)]


def test_ffd_reuses_disjoint_lifetimes():
    reqs = _reqs([(1024, 0, 1), (1024, 2, 3), (1024, 4, 5)])
    plan = GreedyMemoryPlanner().plan(reqs)
    plan.validate()
    assert plan.total_bytes == 1024        # all three share one slot
    linear = LinearMemoryPlanner().plan(reqs)
    assert linear.total_bytes == 3 * 1024


def test_ffd_keeps_live_buffers_apart():
    reqs = _reqs([(100, 0, 5), (100, 0, 5), (100, 0, 5)])
    plan = GreedyMemoryPlanner().plan(reqs)
    plan.validate()
    assert plan.total_bytes >= 300


def test_ffd_figure4_example():
    # overlapping chain: A feeds B feeds C; A dies when B is born etc.
    reqs = _reqs([(4096, 0, 1), (2048, 1, 2), (4096, 2, 3)])
    plan = GreedyMemoryPlanner().plan(reqs)
    plan.validate()
    # A and C can share; B must coexist with both
    assert plan.total_bytes <= 4096 + 2048 + 16


def test_validate_catches_overlap():
    reqs = _reqs([(100, 0, 2), (100, 1, 3)])
    bad = MemoryPlan([0, 50], 150, reqs)
    with pytest.raises(AssertionError):
        bad.validate()


def test_offline_plan_roundtrip():
    reqs = _reqs([(512, 0, 1), (256, 1, 2), (512, 2, 3)])
    plan = GreedyMemoryPlanner().plan(reqs)
    md = plan.to_metadata()
    offline = OfflineMemoryPlanner(md)
    replay = offline.plan(reqs)
    assert replay.offsets == plan.offsets
    assert replay.total_bytes == plan.total_bytes


def test_offline_plan_length_mismatch_raises():
    plan = GreedyMemoryPlanner().plan(_reqs([(512, 0, 1)]))
    offline = OfflineMemoryPlanner(plan.to_metadata())
    with pytest.raises(ValueError):
        offline.plan(_reqs([(512, 0, 1), (128, 0, 0)]))


def test_lifetimes_from_graph():
    # op0: in=t0 out=t1 ; op1: in=t1 out=t2 ; op2: in=t1,t2 out=t3
    reqs, ids = lifetimes_from_graph(
        3,
        op_inputs=[[0], [1], [1, 2]],
        op_outputs=[[1], [2], [3]],
        tensor_nbytes={0: 16, 1: 16, 2: 16, 3: 16},
        graph_inputs=[0],
        graph_outputs=[3],
    )
    by_id = dict(zip(ids, reqs))
    assert by_id[0].first_use == 0 and by_id[0].last_use == 0
    assert by_id[1].first_use == 0 and by_id[1].last_use == 2
    assert by_id[2].first_use == 1 and by_id[2].last_use == 2
    assert by_id[3].first_use == 2 and by_id[3].last_use == 2


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

buffer_st = st.tuples(
    st.integers(min_value=0, max_value=4096),      # nbytes
    st.integers(min_value=0, max_value=20),        # first
    st.integers(min_value=0, max_value=20),        # duration
)


@settings(max_examples=200, deadline=None)
@given(st.lists(buffer_st, min_size=1, max_size=24))
def test_property_ffd_valid_and_never_worse_than_linear(raw):
    reqs = [BufferRequest(nb, f, f + d, f"b{i}")
            for i, (nb, f, d) in enumerate(raw)]
    ffd = GreedyMemoryPlanner().plan(reqs)
    ffd.validate()                     # no time+space overlap, in bounds
    linear = LinearMemoryPlanner().plan(reqs)
    # ≤ linear modulo one alignment pad (FFD places big-first, which can
    # cost one align_up over linear's packing order)
    assert ffd.total_bytes <= linear.total_bytes + 15


@settings(max_examples=100, deadline=None)
@given(st.lists(buffer_st, min_size=1, max_size=16))
def test_property_ffd_at_least_peak_demand(raw):
    """Plan size can never be below the peak concurrent demand."""
    reqs = [BufferRequest(nb, f, f + d, f"b{i}")
            for i, (nb, f, d) in enumerate(raw)]
    plan = GreedyMemoryPlanner().plan(reqs)
    peak = 0
    for t in range(0, 45):
        live = sum(r.nbytes for r in reqs
                   if r.first_use <= t <= r.last_use)
        peak = max(peak, live)
    assert plan.total_bytes >= peak


@settings(max_examples=100, deadline=None)
@given(st.lists(buffer_st, min_size=1, max_size=16))
def test_property_offsets_aligned(raw):
    reqs = [BufferRequest(nb, f, f + d, f"b{i}")
            for i, (nb, f, d) in enumerate(raw)]
    plan = GreedyMemoryPlanner().plan(reqs)
    for off in plan.offsets:
        assert off % 16 == 0
