"""Latency-aware scheduling + bucketed prefill (PR 3 acceptance).

Covers: EDF admission ordering under contention (micro lanes AND pod
slots), the priority-aging starvation bound, bucketed-prefill
bit-identity against exact-length compiles, the no-retrace assertion
across mixed prompt lengths in one bucket (via the jit_cache_size
trace-count hook), BucketTable semantics, shared lane buckets, and a
slow end-to-end smoke of the arrival-process benchmark."""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.apps import build_fc_stack
from repro.apps.models import representative_dataset
from repro.core import (AllOpsResolver, BucketTable, MicroModel,
                        RaggedInterpreterPool, export, jit_cache_size)
from repro.serving import (EDFPolicy, FIFOPolicy, MicroRequest,
                           MultiTenantHost, PriorityPolicy, Request,
                           get_policy)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def resolver():
    return AllOpsResolver()


@pytest.fixture(scope="module")
def fc_int8():
    gb = build_fc_stack()
    return MicroModel(export(
        gb, representative_dataset=representative_dataset(gb),
        quantize_int8=True))


def _micro(uid, deadline_us=None, priority=0, arrival_us=0):
    return MicroRequest(uid=uid, frames=[[np.zeros((1, 64), np.float32)]],
                        priority=priority, deadline_us=deadline_us,
                        arrival_us=arrival_us)


# ---------------------------------------------------------------------------
# policy semantics (unit)
# ---------------------------------------------------------------------------

def test_fifo_is_arrival_order():
    q = [_micro(0), _micro(1), _micro(2)]
    pol = FIFOPolicy()
    assert [pol.pop(q).uid for _ in range(3)] == [0, 1, 2]


def test_edf_orders_by_deadline_fifo_among_deadline_less():
    q = [_micro(0, deadline_us=None), _micro(1, deadline_us=300),
         _micro(2, deadline_us=100), _micro(3, deadline_us=None),
         _micro(4, deadline_us=200)]
    pol = EDFPolicy()
    # deadlined requests first (earliest first); best-effort after, FIFO
    assert [pol.pop(q).uid for _ in range(5)] == [2, 4, 1, 0, 3]


def test_priority_starvation_bound():
    """A class-p request is admitted after at most p*age_us of
    continuous fresher higher-class pressure — the aging bound."""
    pol = PriorityPolicy(age_us=100)
    low = _micro(0, priority=3, arrival_us=0)
    for now in (0, 100, 299):               # below the 300 µs bound
        fresh = _micro(1, priority=0, arrival_us=now)
        assert pol.select([low, fresh], now) == 1, now
    # at exactly p*age_us the aged request ties and wins on arrival
    fresh = _micro(1, priority=0, arrival_us=300)
    assert pol.select([low, fresh], 300) == 0
    fresh = _micro(1, priority=0, arrival_us=400)
    assert pol.select([low, fresh], 400) == 0


def test_get_policy_resolution():
    assert isinstance(get_policy(None), FIFOPolicy)
    assert isinstance(get_policy("edf"), EDFPolicy)
    pol = PriorityPolicy(age_us=7)
    assert get_policy(pol) is pol
    with pytest.raises(ValueError):
        get_policy("shortest-job-first")


def test_bucket_table_semantics():
    t = BucketTable(min_bucket=8, max_bucket=64)
    assert [t.bucket(n) for n in (1, 8, 9, 16, 17, 64)] == \
        [8, 8, 16, 16, 32, 64]
    assert t.buckets() == [8, 16, 32, 64]
    assert t.hits[8] == 2 and t.hits[16] == 2
    # fit() probes without recording; bucket() over max is loud
    assert t.fit(65) is None
    assert t.fit(9) == 16 and t.hits[16] == 2
    with pytest.raises(ValueError):
        t.bucket(65)                        # over max: loud, like arena
    with pytest.raises(ValueError):
        t.bucket(0)
    with pytest.raises(ValueError):
        BucketTable(min_bucket=16, max_bucket=8)


# ---------------------------------------------------------------------------
# EDF under contention through the REAL schedulers
# ---------------------------------------------------------------------------

def test_micro_edf_admission_order_under_contention(fc_int8, resolver):
    """Four same-instant requests, two lanes, EDF: the two earliest
    deadlines are served in wave 1, the others in wave 2."""
    rng = np.random.default_rng(0)
    host = MultiTenantHost(arena_bytes=64 << 20, policy="edf",
                           clock=lambda: 0)
    host.add_ragged_micro("fc", fc_int8, resolver, lanes=2)
    deadlines = {0: 400, 1: 100, 2: 300, 3: 200}
    for uid, d in deadlines.items():
        host.submit_micro("fc", uid,
                          [[rng.normal(0, 1, (1, 64)).astype(np.float32)]],
                          deadline_us=d, arrival_us=0)
    waves, seen = [], set()
    while True:
        pending = host.micro_step()
        done = {uid for uid, r in host.micro_results["fc"].items()
                if r.done}
        if done - seen:
            waves.append(done - seen)
            seen |= done
        if not pending:
            break
    assert waves[0] == {1, 3}               # deadlines 100 and 200 first
    assert seen == {0, 1, 2, 3}


def test_engine_edf_admission_order_under_contention():
    import jax

    from repro.configs import get_config
    from repro.models import get_model
    from repro.serving import ServingEngine

    cfg = get_config("qwen3-32b", reduced=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServingEngine(m, params, max_slots=1, cache_len=32,
                        policy="edf", clock=lambda: 0)
    rng = np.random.default_rng(1)
    for uid, d in ((1, 900), (2, 100), (3, 500)):
        eng.submit(Request(uid=uid,
                           tokens=rng.integers(0, cfg.vocab - 2,
                                               5).astype(np.int32),
                           max_new_tokens=2, deadline_us=d,
                           arrival_us=0))
    eng.step()
    # the single slot went to the tightest deadline, not FIFO order
    assert eng.slot_req[0].uid == 2
    results = eng.run()
    assert all(r.done for r in results.values())


# ---------------------------------------------------------------------------
# bucketed prefill: bit-identity + the no-retrace assertion
# ---------------------------------------------------------------------------

def test_bucketed_prefill_bit_identity_and_single_compile():
    """Mixed prompt lengths 5/7/9 share ONE power-of-two bucket: the
    bucketed engine must trace exactly one prefill program (trace-count
    hook) and emit tokens bit-identical to the exact-length engine,
    which traces one program per distinct length."""
    import jax

    from repro.configs import get_config
    from repro.models import get_model
    from repro.serving import ServingEngine

    cfg = get_config("qwen3-32b", reduced=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    lengths = (5, 7, 9)                     # tokens[:-1] = 4/6/8 -> all 8
    prompts = {uid: rng.integers(0, cfg.vocab - 2, L).astype(np.int32)
               for uid, L in enumerate(lengths)}
    outs = {}
    for mode in ("exact", "bucketed"):
        eng = ServingEngine(m, params, max_slots=2, cache_len=64,
                            prefill_buckets=False if mode == "exact"
                            else None)
        for uid, toks in prompts.items():
            eng.submit(Request(uid=uid, tokens=toks, max_new_tokens=3))
        res = eng.run()
        outs[mode] = {uid: r.output for uid, r in res.items()}
        if mode == "exact":
            assert eng.bucket_table is None
            assert eng.prefill_compiles() == len(set(lengths))
        else:
            assert eng.bucket_table.buckets() == [8]
            # THE no-retrace assertion: one bucket, one traced program
            assert eng.prefill_compiles() == 1
            assert jit_cache_size(eng._prefill) == 1
    assert outs["exact"] == outs["bucketed"]


def test_bucketing_guarded_for_state_polluting_families():
    """SSM prefill integrates every input position into recurrent
    state, so the engine must refuse bucketed prefill there and
    auto-disable it by default."""
    import jax

    from repro.configs import get_config
    from repro.models import get_model
    from repro.serving import ServingEngine

    cfg = get_config("mamba2-780m", reduced=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServingEngine(m, params, max_slots=1, cache_len=32)
    assert eng.bucket_table is None         # auto: off for ssm
    with pytest.raises(ValueError):
        ServingEngine(m, params, max_slots=1, cache_len=32,
                      prefill_buckets=BucketTable())


def test_prefill_buckets_argument_validation():
    """True means auto, a tiny cache auto-disables instead of crashing
    at construction, and a non-BucketTable value fails loudly."""
    import jax

    from repro.configs import get_config
    from repro.models import get_model
    from repro.serving import ServingEngine

    cfg = get_config("qwen3-32b", reduced=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServingEngine(m, params, max_slots=1, cache_len=32,
                        prefill_buckets=True)
    assert eng.bucket_table is not None
    tiny = ServingEngine(m, params, max_slots=1, cache_len=4)
    assert tiny.bucket_table is None        # no room for min bucket
    with pytest.raises(TypeError):
        ServingEngine(m, params, max_slots=1, cache_len=32,
                      prefill_buckets="8,16,32")


def test_shared_lane_buckets_share_arena_pool_free_lists(fc_int8,
                                                         resolver):
    """Two model buckets with lane counts 3 and 4 quantized through one
    BucketTable both compile for B=4 and draw the SAME stacked-buffer
    free list from the shared ArenaPool."""
    rng = np.random.default_rng(3)
    table = BucketTable(min_bucket=2, max_bucket=64)
    pool = RaggedInterpreterPool()
    pool.add_bucket("a", fc_int8, resolver, lanes=3, lane_buckets=table)
    pool.add_bucket("b", fc_int8, resolver, lanes=4, lane_buckets=table)
    assert len(pool.lanes("a")) == 4 and len(pool.lanes("b")) == 4
    for name in ("a", "b"):
        slot = pool.admit(name)
        pool.set_input(name, slot, 0,
                       rng.normal(0, 1, (1, 64)).astype(np.float32))
    pool.dispatch()
    # one (4, nbytes) free list serves both buckets
    assert list(pool.pool._batched) == [4]


# ---------------------------------------------------------------------------
# the benchmark cannot rot: end-to-end smoke (slow tier)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_arrival_process_benchmark_tiny_smoke():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.arrival_process", "--tiny"],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True,
        timeout=560)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "Arrival-process completion latency" in proc.stdout
    assert "prefill_bucketed" in proc.stdout
