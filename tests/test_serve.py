"""StreamingServer lifecycle + mid-stream preemption regression
(launch/serve.py, docs/STREAMING.md).

The server wraps ONE engine on a dedicated loop thread: start →
submit/stream → shutdown.  These tests pin the lifecycle contract
(double start refused, duplicate uids refused, submit-after-shutdown
refused, shutdown unblocks abandoned streams), prove the streamed
tokens are bit-identical to a synchronous batch run of the same
workload, and regression-test the exactly-once emission contract when
a request is preempted and restored MID-STREAM — both via a forced
engine-level evict and via the EDF displacement policy running under
the live server loop.
"""

import queue

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.executor import jit_cache_size
from repro.launch.serve import StreamingServer
from repro.models import get_model
from repro.serving import Request, ServingEngine

ARCH = "qwen3-32b"
CACHE_LEN = 64
N_NEW = 6

_SETUP = {}


def _setup():
    if not _SETUP:
        cfg = get_config(ARCH, reduced=True)
        m = get_model(cfg)
        _SETUP["v"] = (cfg, m, m.init(jax.random.PRNGKey(0)))
    return _SETUP["v"]


def _mk_engine(**kw):
    cfg, m, params = _setup()
    kw.setdefault("max_slots", 2)
    kw.setdefault("cache_len", CACHE_LEN)
    kw.setdefault("prefill_buckets", False)
    return ServingEngine(m, params, **kw)


def _prompts(n, seed=7):
    cfg, _, _ = _setup()
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab - 2,
                         int(rng.integers(6, 14))).astype(np.int32)
            for _ in range(n)]


def _events_by_uid(events):
    per = {}
    for ev in events:
        per.setdefault(ev.uid, []).append(ev)
    return per


def _assert_exactly_once(evs, expect_tokens, uid):
    """The callback ordering contract for one request's event list."""
    assert [e.index for e in evs] == list(range(len(evs))), uid
    assert [e.token for e in evs] == list(expect_tokens), uid
    assert [e.final for e in evs] == \
        [False] * (len(evs) - 1) + [True], uid
    ts = [e.t_us for e in evs]
    assert ts == sorted(ts), uid


def test_server_lifecycle():
    """start → submit → stream → shutdown, with every misuse refused
    loudly: double start, duplicate uid, submit after shutdown."""
    server = StreamingServer(_mk_engine(overlap=True)).start()
    assert server.running
    with pytest.raises(RuntimeError):
        server.start()
    prompt = _prompts(1)[0]
    uid = server.submit(prompt, max_new_tokens=N_NEW)
    with pytest.raises(ValueError):
        server.submit(prompt, max_new_tokens=N_NEW, uid=uid)
    evs = list(server.stream(uid))
    assert len(evs) == N_NEW
    _assert_exactly_once(evs, server.result(uid).output, uid)
    assert server.result(uid).done
    server.shutdown()
    assert not server.running
    with pytest.raises(RuntimeError):
        server.submit(prompt)
    server.shutdown()  # idempotent


def test_streamed_tokens_match_sync_batch():
    """The overlapped server streams the SAME tokens a synchronous
    batch engine decodes for the same workload, with the overlap
    engine's decode still a single jitted program."""
    prompts = _prompts(4)
    sync = _mk_engine(overlap=False)
    for uid, toks in enumerate(prompts):
        sync.submit(Request(uid=uid, tokens=toks, max_new_tokens=N_NEW))
    base = {uid: res.output for uid, res in sync.run().items()}

    eng = _mk_engine(overlap=True)
    server = StreamingServer(eng).start()
    uids = [server.submit(toks, max_new_tokens=N_NEW, uid=uid)
            for uid, toks in enumerate(prompts)]
    streamed = {uid: [ev.token for ev in server.stream(uid)]
                for uid in uids}
    server.shutdown()
    assert streamed == base
    assert jit_cache_size(eng._decode) == 1


def test_shutdown_unblocks_unfinished_stream():
    """A consumer waiting on a request the server will never finish
    gets a RuntimeError at shutdown, not a hang."""
    server = StreamingServer(_mk_engine(overlap=True)).start()
    uid = server.submit(_prompts(1)[0], max_new_tokens=50)
    server.shutdown()
    res = server.result(uid)
    if res is not None and res.done:
        pytest.skip("request finished before shutdown landed")
    with pytest.raises(RuntimeError, match="shut down"):
        list(server.stream(uid, timeout=5.0))


def test_stream_timeout_raises_empty():
    """stream() surfaces a stalled request as queue.Empty after its
    timeout instead of blocking forever."""
    server = StreamingServer(_mk_engine(overlap=True)).start()
    with server._lock:
        server._streams[99] = queue.Queue()  # uid the engine never saw
    with pytest.raises(queue.Empty):
        next(iter(server.stream(99, timeout=0.05)))
    server.shutdown()


def test_midstream_forced_evict_no_dup_no_drop():
    """THE preemption regression: a request evicted and restored while
    its stream is live must emit every token exactly once — no
    re-emission of the pre-evict prefix, no dropped tail — and match
    the never-preempted sync baseline bit for bit."""
    prompts = _prompts(4)
    sync = _mk_engine(overlap=False)
    for uid, toks in enumerate(prompts):
        sync.submit(Request(uid=uid, tokens=toks, max_new_tokens=N_NEW))
    base = {uid: res.output for uid, res in sync.run().items()}

    events = []
    eng = _mk_engine(overlap=True, on_token=events.append)
    for uid, toks in enumerate(prompts):
        eng.submit(Request(uid=uid, tokens=toks, max_new_tokens=N_NEW))
    evicted = False
    steps = 0
    while eng.step():
        steps += 1
        assert steps < 500
        if not evicted and steps >= 3:
            eng.drain()  # quiesce before checkpoint surgery
            victim = next((s for s in range(eng.max_slots)
                           if eng.active[s]), None)
            if victim is not None:
                eng._evict(victim)
                evicted = True
    assert evicted
    assert sum(r.preemptions for r in eng.results.values()) >= 1
    outs = {uid: res.output for uid, res in eng.results.items()}
    assert outs == base
    per = _events_by_uid(events)
    assert sorted(per) == sorted(outs)
    for uid, evs in per.items():
        _assert_exactly_once(evs, outs[uid], uid)
    assert jit_cache_size(eng._decode) == 1


def test_midstream_displacement_under_live_server():
    """The same exactly-once guarantee through the displacement policy
    with the server loop running: a tight-deadline arrival displaces
    the lone running request mid-stream, and both streams still see
    contiguous indices and their full budgets."""
    events = []
    eng = _mk_engine(overlap=True, max_slots=1, policy="edf",
                     preempt="edf-displace")
    server = StreamingServer(eng)
    # the server claimed on_token; tee every event into our collector
    # on its way to the per-uid stream queues
    fanout = eng.on_token
    eng.on_token = lambda ev: (events.append(ev), fanout(ev))
    server.start()
    p0, p1 = _prompts(2)
    uid0 = server.submit(p0, max_new_tokens=10)  # no deadline: displaceable
    g0 = server.stream(uid0)
    next(g0)  # wait until uid0 is decoding mid-stream
    uid1 = server.submit(p1, max_new_tokens=4, uid=101,
                         deadline_us=1)  # urgent: forces displacement
    t1 = [ev.token for ev in server.stream(uid1)]
    t0_rest = [ev.token for ev in g0]
    server.shutdown()
    res0, res1 = server.result(uid0), server.result(uid1)
    assert res0.done and res1.done
    assert res0.preemptions >= 1, "displacement never fired"
    assert len(t1) == 4 and t1 == res1.output
    assert len(t0_rest) == 9  # the 10-token budget minus next(g0)
    per = _events_by_uid(events)
    _assert_exactly_once(per[uid0], res0.output, uid0)
    _assert_exactly_once(per[uid1], res1.output, uid1)
