"""shard_map expert-parallel MoE vs the GSPMD einsum-dispatch path.

With a dropless capacity factor the two implementations compute the
same math (same routing, same experts), so outputs must agree.  Runs
in a subprocess with 8 placeholder devices (2 data x 4 model)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_config
    from repro.models import lm as L
    from repro.distributed.act_sharding import activation_sharding

    cfg = get_config("qwen3-moe-30b-a3b", reduced=True)
    cfg = dataclasses.replace(cfg, n_experts=8, top_k=2,
                              capacity_factor=8.0)   # dropless
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    key = jax.random.PRNGKey(0)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    p = {
        "router": jax.random.normal(key, (d, e), jnp.float32) * 0.1,
        "experts": {
            "wi": jax.random.normal(key, (e, d, f)) * 0.05,
            "wg": jax.random.normal(jax.random.fold_in(key, 1),
                                    (e, d, f)) * 0.05,
            "wo": jax.random.normal(jax.random.fold_in(key, 2),
                                    (e, f, d)) * 0.05,
        },
    }
    x = jax.random.normal(jax.random.fold_in(key, 3), (4, 16, d))

    # reference: GSPMD path, single group (no ctx -> ep not applicable)
    y_ref, aux_ref = jax.jit(
        lambda p, x: L.moe_block(p, cfg, x, data_shards=1))(p, x)

    # EP path under the mesh ctx
    def ep(p, x):
        with activation_sharding(mesh, batch_divisible=True,
                                 seq_divisible=True,
                                 experts_divisible=True):
            from repro.models.moe_ep import ep_applicable, moe_block_ep
            assert ep_applicable(cfg, x.shape[0], x.shape[1])
            return moe_block_ep(p, cfg, x)

    with mesh:
        y_ep, aux_ep = jax.jit(ep)(p, x)

    err = float(jnp.max(jnp.abs(y_ref - y_ep)))
    rel = err / float(jnp.max(jnp.abs(y_ref)))
    print("RESULT" + json.dumps({"max_err": err, "rel": rel,
                                 "aux_ref": float(aux_ref),
                                 "aux_ep": float(aux_ep)}))
""")


@pytest.mark.slow
def test_moe_ep_matches_gspmd_path():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT")][0]
    out = json.loads(line[len("RESULT"):])
    # expert outputs must agree to numerical precision (dropless)
    assert out["rel"] < 1e-4, out
    # aux load-balance is a per-device density estimator under EP vs a
    # global one under GSPMD — same scale, not bit-equal
    assert abs(out["aux_ref"] - out["aux_ep"]) < 0.6, out
