"""Property tests on the MoE capacity-dispatch invariants (hypothesis).

Invariants:
  * every kept (token, k) pair lands in the queue slot of the expert it
    was routed to, at a position < capacity;
  * no expert receives more than `capacity` tokens;
  * combine weights are the normalized top-k router probabilities for
    kept slots and 0 for dropped/dummy slots;
  * with a dropless capacity factor nothing is dropped and the block
    output equals the dense mixture of the same experts.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models.common import ModelConfig
from repro.models.lm import moe_capacity, moe_dispatch


def _cfg(e, k, cf):
    base = get_config("qwen3-moe-30b-a3b", reduced=True)
    return dataclasses.replace(base, n_experts=e, top_k=k,
                               capacity_factor=cf)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8]),
       st.sampled_from([1, 2]), st.integers(8, 64))
def test_dispatch_invariants(seed, e, k, t):
    cfg = _cfg(e, k, 1.25)
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(0, 1, (1, t, e)), jnp.float32)
    cap = moe_capacity(cfg, t)
    dispatch, combine, aux = jax.jit(
        lambda l: moe_dispatch(l, cfg, cap))(logits)
    dispatch = np.asarray(dispatch)[0]          # (E*C,)
    combine = np.asarray(combine)[0]
    assert dispatch.shape == (e * cap,)
    # capacity respected: each expert's queue has exactly `cap` slots
    per_expert = dispatch.reshape(e, cap)
    for ei in range(e):
        kept = per_expert[ei][per_expert[ei] < t]
        assert len(kept) <= cap
        # every kept token actually routed to this expert (top-k)
        probs = np.asarray(jax.nn.softmax(logits[0], axis=-1))
        for tok in kept:
            topk = np.argsort(probs[tok])[-k:]
            assert ei in topk, (ei, tok, topk)
    # dummy slots have zero combine weight
    assert (combine[dispatch == t] == 0).all()
    # kept combine weights are positive and <= 1
    kept_w = combine[dispatch < t]
    assert (kept_w >= 0).all() and (kept_w <= 1 + 1e-6).all()
    assert np.isfinite(float(aux)) and float(aux) > 0


def test_dropless_matches_dense_mixture():
    """capacity_factor high enough -> block output == explicit dense
    top-k mixture computed with plain numpy-style einsums."""
    cfg = _cfg(4, 2, 16.0)
    key = jax.random.PRNGKey(0)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    p = {"router": jax.random.normal(key, (d, e), jnp.float32) * 0.1,
         "experts": {
             "wi": jax.random.normal(key, (e, d, f)) * 0.05,
             "wg": jax.random.normal(jax.random.fold_in(key, 1),
                                     (e, d, f)) * 0.05,
             "wo": jax.random.normal(jax.random.fold_in(key, 2),
                                     (e, f, d)) * 0.05}}
    x = jax.random.normal(jax.random.fold_in(key, 3), (2, 8, d))
    from repro.models.lm import moe_block
    y, aux = jax.jit(lambda p, x: moe_block(p, cfg, x, data_shards=1)
                     )(p, x)

    # dense reference: run every expert on every token, weight by the
    # renormalized top-k probabilities
    xt = x.reshape(-1, d)
    probs = jax.nn.softmax(xt.astype(jnp.float32) @ p["router"], -1)
    top_w, top_ids = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    hid = jnp.einsum("td,edf->tef", xt, p["experts"]["wi"])
    gate = jnp.einsum("td,edf->tef", xt, p["experts"]["wg"])
    out_e = jnp.einsum("tef,efd->ted", jax.nn.silu(gate) * hid,
                       p["experts"]["wo"])
    mask = jax.nn.one_hot(top_ids, e).sum(1)        # (T,E) 0/1
    w_full = jnp.zeros_like(probs)
    for kk in range(cfg.top_k):
        w_full = w_full + jax.nn.one_hot(top_ids[:, kk], e) \
            * top_w[:, kk:kk + 1]
    want = jnp.einsum("te,ted->td", w_full, out_e).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-3)
