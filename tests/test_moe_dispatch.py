"""Property tests on the MoE capacity-dispatch invariants (hypothesis).

Invariants:
  * every kept (token, k) pair lands in the queue slot of the expert it
    was routed to, at a position < capacity;
  * no expert receives more than `capacity` tokens;
  * combine weights are the normalized top-k router probabilities for
    kept slots and 0 for dropped/dummy slots;
  * with a dropless capacity factor nothing is dropped and the block
    output equals the dense mixture of the same experts.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models.common import ModelConfig
from repro.models.lm import moe_capacity, moe_dispatch


def _cfg(e, k, cf):
    base = get_config("qwen3-moe-30b-a3b", reduced=True)
    return dataclasses.replace(base, n_experts=e, top_k=k,
                               capacity_factor=cf)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8]),
       st.sampled_from([1, 2]), st.integers(8, 64))
def test_dispatch_invariants(seed, e, k, t):
    cfg = _cfg(e, k, 1.25)
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(0, 1, (1, t, e)), jnp.float32)
    cap = moe_capacity(cfg, t)
    dispatch, combine, aux = jax.jit(
        lambda l: moe_dispatch(l, cfg, cap))(logits)
    dispatch = np.asarray(dispatch)[0]          # (E*C,)
    combine = np.asarray(combine)[0]
    assert dispatch.shape == (e * cap,)
    # capacity respected: each expert's queue has exactly `cap` slots
    per_expert = dispatch.reshape(e, cap)
    for ei in range(e):
        kept = per_expert[ei][per_expert[ei] < t]
        assert len(kept) <= cap
        # every kept token actually routed to this expert (top-k)
        probs = np.asarray(jax.nn.softmax(logits[0], axis=-1))
        for tok in kept:
            topk = np.argsort(probs[tok])[-k:]
            assert ei in topk, (ei, tok, topk)
    # dummy slots have zero combine weight
    assert (combine[dispatch == t] == 0).all()
    # kept combine weights are positive and <= 1
    kept_w = combine[dispatch < t]
    assert (kept_w >= 0).all() and (kept_w <= 1 + 1e-6).all()
    assert np.isfinite(float(aux)) and float(aux) > 0


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.sampled_from([4, 8]),
       st.sampled_from([1, 2]), st.sampled_from([16, 32]),
       st.data())
def test_capacity_stable_padding_never_leaks(seed, e, k, bucket, data):
    """Capacity-stable bucketed dispatch (serving's bucketed-MoE
    prefill): for a random true length m within a bucket, the masked
    dispatch over the PADDED tokens (capacity from the bucket shape,
    ``n_valid``/``eff_capacity`` from m) must (a) never dispatch a
    padded token to any expert and (b) dispatch exactly the same
    (expert, queue-position, token, weight) set as the unpadded run —
    so the downstream expert FFN + combine is bit-identical."""
    m = data.draw(st.integers(2, bucket - 1))
    cfg = _cfg(e, k, 1.25)
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(0, 1, (1, bucket, e)), jnp.float32)
    cap_pad = moe_capacity(cfg, bucket)
    cap_m = moe_capacity(cfg, m)
    d_pad, c_pad, _ = jax.jit(lambda l: moe_dispatch(
        l, cfg, cap_pad, n_valid=jnp.int32(m),
        eff_capacity=jnp.int32(cap_m)))(logits)
    d_ref, c_ref, _ = jax.jit(lambda l: moe_dispatch(
        l, cfg, cap_m))(logits[:, :m])
    d_pad = np.asarray(d_pad)[0].reshape(e, cap_pad)
    c_pad = np.asarray(c_pad)[0].reshape(e, cap_pad)
    d_ref = np.asarray(d_ref)[0].reshape(e, cap_m)
    c_ref = np.asarray(c_ref)[0].reshape(e, cap_m)
    # (a) padded tokens never leak into any expert queue (dummy slots
    # carry the out-of-range sentinel: `bucket` here, `m` in the ref)
    kept = d_pad[d_pad < bucket]
    assert (kept < m).all(), kept
    # beyond the effective capacity every slot is a dummy
    assert (d_pad[:, cap_m:] == bucket).all()
    assert (c_pad[:, cap_m:] == 0).all()
    # (b) the kept prefix of each expert queue matches the unpadded
    # dispatch slot for slot — token ids and combine weights
    ref_tok = np.where(d_ref < m, d_ref, -1)
    pad_tok = np.where(d_pad[:, :cap_m] < bucket, d_pad[:, :cap_m], -1)
    np.testing.assert_array_equal(pad_tok, ref_tok)
    np.testing.assert_array_equal(c_pad[:, :cap_m], c_ref)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1), st.data())
def test_capacity_stable_block_output_bit_identical(seed, data):
    """End to end through moe_block: the masked run over padded tokens
    emits BIT-IDENTICAL outputs for the real rows — same expert set,
    same queue positions, same expert-major combine order, so even the
    float summation order is preserved."""
    from repro.models.lm import moe_block
    m = data.draw(st.integers(2, 15))
    bucket = 16
    cfg = _cfg(4, 2, 1.25)
    key = jax.random.PRNGKey(seed % (2**31 - 1))
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    p = {"router": jax.random.normal(key, (d, e), jnp.float32) * 0.1,
         "experts": {
             "wi": jax.random.normal(key, (e, d, f)) * 0.05,
             "wg": jax.random.normal(jax.random.fold_in(key, 1),
                                     (e, d, f)) * 0.05,
             "wo": jax.random.normal(jax.random.fold_in(key, 2),
                                     (e, f, d)) * 0.05}}
    x = jax.random.normal(jax.random.fold_in(key, 3), (1, bucket, d))
    y_pad, _ = jax.jit(lambda p, x: moe_block(
        p, cfg, x, data_shards=1, n_valid=jnp.int32(m),
        eff_capacity=jnp.int32(moe_capacity(cfg, m))))(p, x)
    y_ref, _ = jax.jit(lambda p, x: moe_block(
        p, cfg, x, data_shards=1))(p, x[:, :m])
    np.testing.assert_array_equal(np.asarray(y_pad)[:, :m],
                                  np.asarray(y_ref))


def test_dropless_matches_dense_mixture():
    """capacity_factor high enough -> block output == explicit dense
    top-k mixture computed with plain numpy-style einsums."""
    cfg = _cfg(4, 2, 16.0)
    key = jax.random.PRNGKey(0)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    p = {"router": jax.random.normal(key, (d, e), jnp.float32) * 0.1,
         "experts": {
             "wi": jax.random.normal(key, (e, d, f)) * 0.05,
             "wg": jax.random.normal(jax.random.fold_in(key, 1),
                                     (e, d, f)) * 0.05,
             "wo": jax.random.normal(jax.random.fold_in(key, 2),
                                     (e, f, d)) * 0.05}}
    x = jax.random.normal(jax.random.fold_in(key, 3), (2, 8, d))
    from repro.models.lm import moe_block
    y, aux = jax.jit(lambda p, x: moe_block(p, cfg, x, data_shards=1)
                     )(p, x)

    # dense reference: run every expert on every token, weight by the
    # renormalized top-k probabilities
    xt = x.reshape(-1, d)
    probs = jax.nn.softmax(xt.astype(jnp.float32) @ p["router"], -1)
    top_w, top_ids = jax.lax.top_k(probs, cfg.top_k)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    hid = jnp.einsum("td,edf->tef", xt, p["experts"]["wi"])
    gate = jnp.einsum("td,edf->tef", xt, p["experts"]["wg"])
    out_e = jnp.einsum("tef,efd->ted", jax.nn.silu(gate) * hid,
                       p["experts"]["wo"])
    mask = jax.nn.one_hot(top_ids, e).sum(1)        # (T,E) 0/1
    w_full = jnp.zeros_like(probs)
    for kk in range(cfg.top_k):
        w_full = w_full + jax.nn.one_hot(top_ids[:, kk], e) \
            * top_w[:, kk:kk + 1]
    want = jnp.einsum("te,ted->td", w_full, out_e).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-3)
