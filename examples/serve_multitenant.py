"""End-to-end serving driver (the paper is an inference framework, so
this is the flagship example): host THREE models of different families
— a dense GQA transformer, a Mamba2 SSM and a Zamba2 hybrid — in ONE
shared arena (paper §4.5), stream a batched request workload through
continuous-batching engines, and report per-request latency plus the
arena accounting.

Run: PYTHONPATH=src python examples/serve_multitenant.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import get_model
from repro.serving import MultiTenantHost, Request

TENANTS = (("chat-lm", "qwen3-32b"),
           ("draft-ssm", "mamba2-780m"),
           ("hybrid", "zamba2-1.2b"))

host = MultiTenantHost(arena_bytes=512 << 20)
rng = np.random.default_rng(0)

print("=== admitting tenants (persistent KV sections stack) ===")
for name, arch in TENANTS:
    cfg = get_config(arch, reduced=True)
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(hash(name) % 2**31))
    host.add_model(name, bundle, params, max_slots=2, cache_len=96)
    u = host.usage()
    print(f"  + {name:10s} ({arch}): persistent={u.persistent >> 10} KiB")

print("\n=== submitting workload (4 requests x 3 tenants) ===")
uid = 0
for name, arch in TENANTS:
    cfg = get_config(arch, reduced=True)
    for _ in range(4):
        plen = int(rng.integers(4, 14))
        host.submit(name, Request(
            uid=uid,
            tokens=rng.integers(0, cfg.vocab - 2, plen).astype(np.int32),
            max_new_tokens=8))
        uid += 1

t0 = time.time()
results = host.run_all()
wall = time.time() - t0

total = 0
for name, _ in TENANTS:
    for u, res in sorted(results[name].items()):
        total += len(res.output)
        print(f"  {name:10s} req {u:2d}: prompt={res.prompt_len:2d} "
              f"-> {len(res.output)} tokens "
              f"(prefill {res.prefill_s * 1e3:6.1f} ms, "
              f"decode {res.decode_s * 1e3:6.1f} ms)")

u = host.usage()
print(f"\n{total} tokens in {wall:.2f}s ({total / wall:.1f} tok/s)  |  "
      f"arena: persistent={u.persistent >> 10} KiB (stacked), "
      f"capacity={u.capacity >> 20} MiB")
print("serve_multitenant OK")
