"""Interactive streaming client: tokens arrive one at a time, the
moment the host learns them, instead of at request completion
(docs/STREAMING.md, ROADMAP item 3).

A ``StreamingServer`` (launch/serve.py) drives an OVERLAPPED engine —
decode step i+1 is dispatched before step i's tokens are read back, so
host delivery rides in the device's shadow — on a background thread,
while this client plays three chat sessions against it concurrently:
each consumer thread iterates ``server.stream(uid)`` and renders its
tokens live with per-token latency.  The printed per-request TTFT /
ITL lines are the same metrics BENCH_streaming.json sweeps.

Run: PYTHONPATH=src python examples/streaming_client.py
"""

import threading
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import get_model
from repro.launch.serve import StreamingServer
from repro.serving import ServingEngine

ARCH = "qwen3-32b"
PROMPTS = {"alice": 12, "bob": 7, "carol": 9}   # prompt lengths

cfg = get_config(ARCH, reduced=True)
bundle = get_model(cfg)
params = bundle.init(jax.random.PRNGKey(0))
eng = ServingEngine(bundle, params, max_slots=2, cache_len=96,
                    overlap=True)
server = StreamingServer(eng).start()
print(f"=== streaming server up: {ARCH} (reduced), 2 slots, "
      f"overlapped decode ===")

rng = np.random.default_rng(0)
lock = threading.Lock()


def chat(name: str, plen: int) -> None:
    prompt = rng.integers(1, cfg.vocab - 2, plen).astype(np.int32)
    t_sub = time.monotonic()
    uid = server.submit(prompt, max_new_tokens=12)
    last = t_sub
    for ev in server.stream(uid):
        now = time.monotonic()
        gap_ms = (now - last) * 1e3
        last = now
        tag = "TTFT" if ev.index == 0 else "itl "
        with lock:
            print(f"  [{name:5s}] token {ev.index:2d} = {ev.token:4d}  "
                  f"({tag} {gap_ms:7.1f} ms)"
                  f"{'   <final>' if ev.final else ''}")


threads = [threading.Thread(target=chat, args=(n, p), name=n)
           for n, p in PROMPTS.items()]
for t in threads:
    t.start()
for t in threads:
    t.join()

print("\n=== transcripts (exactly the streamed tokens, in order) ===")
for uid in sorted(server.engine.results):
    res = server.engine.results[uid]
    print(f"  uid {uid}: {len(res.output)} tokens  "
          f"preemptions={res.preemptions}  {res.output}")
server.shutdown()
print("server drained and stopped.")
