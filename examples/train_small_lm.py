"""Train a small LM end to end: synthetic Markov corpus -> packed
batches -> AdamW train loop -> checkpoint -> restore -> greedy decode
through the serving engine.  Exercises the full training substrate on
CPU in under two minutes.

Run: PYTHONPATH=src python examples/train_small_lm.py
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import PackedLMDataset
from repro.models import get_model
from repro.serving import Request, ServingEngine
from repro.training.trainer import init_train_state, make_train_step

cfg = get_config("yi-6b", reduced=True)
bundle = get_model(cfg)
params = bundle.init(jax.random.PRNGKey(0))
state = init_train_state(params)
n_params = sum(p.size for p in jax.tree.leaves(params))
print(f"arch={cfg.arch_id}  params={n_params / 1e6:.2f}M")

ds = PackedLMDataset(cfg, batch=8, seq=32, seed=0)
step = jax.jit(make_train_step(bundle.loss, lr=3e-3, max_grad_norm=5.0,
                               remat=False, data_shards=1))

print("=== training 80 steps on the Markov corpus ===")
first_loss = None
for i in range(80):
    batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
    state, metrics = step(state, batch)
    if first_loss is None:
        first_loss = float(metrics["ce_loss"])
    if i % 20 == 0 or i == 79:
        print(f"  step {i:3d}  loss={float(metrics['ce_loss']):.4f}  "
              f"gnorm={float(metrics['grad_norm']):.3f}")
final_loss = float(metrics["ce_loss"])
assert final_loss < first_loss - 0.5, "training did not descend"

with tempfile.TemporaryDirectory() as tmp:
    print("=== checkpoint round-trip ===")
    save_checkpoint(tmp, 60, state)
    restored = restore_checkpoint(tmp, 60, state)
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print("  restored == saved")

print("=== serving the trained model ===")
eng = ServingEngine(bundle, restored.params, max_slots=2, cache_len=64)
rng = np.random.default_rng(1)
eng.submit(Request(uid=0, tokens=rng.integers(
    0, cfg.vocab - 2, 8).astype(np.int32), max_new_tokens=10))
out = eng.run()[0].output
print(f"  generated: {out}")
print("train_small_lm OK")
