"""Quickstart: the paper's four-step application flow (§4.1), end to
end on the conv reference model.

  1. build an OpResolver (links only the ops the model needs),
  2. supply a fixed-size arena,
  3. create the interpreter (ALL allocation happens here),
  4. set inputs -> invoke -> read outputs.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.apps import build_conv_reference
from repro.core import (MicroInterpreter, MicroModel,
                        MicroMutableOpResolver, export)
from repro.core.schema import OpCode

# --- export: TF-Lite-style toolchain (Figure 1) -------------------------
gb = build_conv_reference()
blob = export(gb)                       # µFB single-blob serialization
model = MicroModel(blob)
print(f"model blob: {len(blob)} bytes "
      f"({len(model.operators)} ops, {len(model.tensors)} tensors)")

# --- step 1: OpResolver — link exactly what the model uses --------------
resolver = MicroMutableOpResolver()
for op in (OpCode.CONV_2D, OpCode.MAX_POOL_2D, OpCode.MEAN,
           OpCode.FULLY_CONNECTED, OpCode.SOFTMAX, OpCode.RESHAPE):
    resolver.add(op)

# --- step 2+3: arena + interpreter (init-time allocation only) ----------
arena_size = MicroInterpreter.required_arena_size(model, resolver)
print(f"planned arena: {arena_size} bytes")
interp = MicroInterpreter(model, resolver, arena_size)
print(interp.memory_report())

# --- step 4: invoke ------------------------------------------------------
rng = np.random.default_rng(0)
x = rng.normal(0, 1, interp.input_spec(0).shape).astype(np.float32)
interp.set_input(0, x)
interp.invoke()
probs = interp.output(0)
print("class probabilities:", np.round(probs.ravel(), 3))
assert abs(float(probs.sum()) - 1.0) < 1e-3
print("quickstart OK")
