"""The Figure-1 model-export workflow: build a training graph (with
dropout), strip training ops, fold constants, calibrate on a
representative dataset, quantize to INT8, and compare float vs INT8
accuracy and arena footprint — then compile the model blob to a C-style
source array (the no-filesystem deployment path, §4.3.1).

Run: PYTHONPATH=src python examples/export_and_quantize.py
"""

import numpy as np

from repro.apps import build_vww
from repro.apps.models import representative_dataset
from repro.core import (AllOpsResolver, MicroInterpreter, MicroModel,
                        export)
from repro.core.schema import model_to_source

resolver = AllOpsResolver()
gb = build_vww()
ds = representative_dataset(gb, n=8)

print("=== export: float vs INT8 (post-training quantization) ===")
float_blob = export(gb)
q_blob = export(build_vww(), representative_dataset=ds,
                quantize_int8=True)
print(f"  float blob: {len(float_blob) / 1024:.1f} KiB")
print(f"  int8 blob:  {len(q_blob) / 1024:.1f} KiB "
      f"({len(float_blob) / len(q_blob):.2f}x smaller)")

fm, qm = MicroModel(float_blob), MicroModel(q_blob)
rng = np.random.default_rng(0)
x = rng.normal(0, 1, (1, 96, 96, 1)).astype(np.float32)

outs = {}
for tag, model in (("float", fm), ("int8", qm)):
    size = MicroInterpreter.required_arena_size(model, resolver)
    it = MicroInterpreter(model, resolver, size)
    it.set_input(0, x)
    it.invoke()
    outs[tag] = it.output(0)
    used = it.arena_used_bytes()
    print(f"  {tag:5s}: arena={size / 1024:.1f} KiB "
          f"(persistent {used['persistent'] / 1024:.1f}, "
          f"nonpersistent {used['nonpersistent'] / 1024:.1f})")

err = float(np.max(np.abs(outs["float"] - outs["int8"])))
print(f"  max |float - int8| on softmax outputs: {err:.4f}")
assert err < 0.25, "quantization error too large"

print("=== compile blob to a C array (no file system on target) ===")
src = model_to_source(q_blob, "vww_model")
print("  " + src.splitlines()[0])
print(f"  {len(src.splitlines())} lines, deployable as a .c file")
print("export_and_quantize OK")
