"""Quantized pod decode: int8/int4 weights + int8 KV vs the fp engine
at matched batch (docs/QUANTIZATION.md).

Decode is memory-bound, so the quantization win shows up on two axes:

  * **HBM footprint** — the resident bytes a serving engine pins
    (quantized weight tree + KV arena), which is what bounds how many
    replicas/tenants fit a device;
  * **decoded-token fidelity** — the quantized engine is NOT
    bit-identical to fp (that is the documented contract: a per-family
    logit tolerance, gated in tests/test_quant_serving.py), but it
    must be bit-identical to ITSELF across admit/preempt/restore —
    the ``tokens_match`` column replays each quantized run with a
    forced mid-run eviction and asserts token identity.

Rows: one per (family, weight_dtype, kv_dtype) cell — ``fp32`` rows
are the unquantized baseline at the same slot count, so footprint
reductions read straight off the table.  ``tokens_per_s`` prices one
warm fused decode dispatch at ``SLOTS`` concurrent slots (CPU
interpret-mode Pallas: the number is a layout-overhead proxy, not
hardware throughput — same caveat as kernel_speedup).  Emits
``BENCH_quantized_decode.json`` unless ``tiny``.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from .common import block, print_table, save_result, time_call

SEED = 11
ARCHS = {"dense": "qwen3-32b", "moe": "deepseek-moe-16b",
         "vlm": "paligemma-3b"}
# (weight_dtype, kv_dtype) cells; "fp32" = that axis unquantized
MODES = (("fp32", "fp32"), ("int8", "int8"), ("int4", "int8"))
CACHE_LEN = 32
SLOTS = 4
PROMPT_LEN = 6
N_NEW = 6
ERR_STEPS = 4
# documented max-abs logit tolerance vs the fp engine, per family ×
# weight dtype (the accuracy gate in tests/test_quant_serving.py uses
# the same numbers; docs/QUANTIZATION.md explains the spread: moe is
# loosest because weight rounding can flip discrete expert routing,
# vlm amplifies embedding error through its sqrt(d_model) scale)
TOLERANCE = {
    "dense": {"int8": 0.5, "int4": 2.0},
    "moe": {"int8": 2.5, "int4": 4.0},
    "vlm": {"int8": 1.5, "int4": 4.0},
}


def _setup(family: str):
    import jax

    from repro.configs import get_config
    from repro.models import get_model

    cfg = get_config(ARCHS[family], reduced=True)
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    return cfg, bundle, params


def _engine(bundle, params, wd: str, kd: str, *, slots: int):
    from repro.serving import ServingEngine

    return ServingEngine(
        bundle, params, max_slots=slots, cache_len=CACHE_LEN,
        prefill_buckets=False,
        weight_dtype=None if wd == "fp32" else wd,
        kv_dtype=None if kd == "fp32" else kd)


def _prefill_batch(cfg, rng, toks):
    import jax.numpy as jnp

    batch = {"tokens": jnp.asarray(np.asarray(toks)[None])}
    if cfg.family == "vlm":
        batch["vision"] = jnp.asarray(rng.normal(
            0, 1, (1, cfg.n_vision_tokens, cfg.d_vision)
        ).astype(np.float32))
    return batch


def _logit_err(cfg, bundle, params, wd: str, kd: str) -> float:
    """Max abs logit error of the quantized engine vs the fp engine
    over one prefill plus ``ERR_STEPS`` decode steps, both fed the
    SAME (fp-argmax) token stream so the states stay comparable."""
    import jax.numpy as jnp

    rng = np.random.default_rng(SEED)
    toks = rng.integers(0, cfg.vocab - 2, PROMPT_LEN).astype(np.int32)
    feng = _engine(bundle, params, "fp32", "fp32", slots=1)
    qeng = _engine(bundle, params, wd, kd, slots=1)
    batch = _prefill_batch(cfg, rng, toks[:-1])
    lf, cf = feng._prefill((feng.params, batch))
    lq, cq = qeng._prefill((qeng.params, batch))
    err = float(jnp.max(jnp.abs(lf[..., :cfg.vocab]
                                - lq[..., :cfg.vocab])))
    vis = cfg.n_vision_tokens if cfg.family == "vlm" else 0
    pos = PROMPT_LEN - 1 + vis
    cur = int(toks[-1])
    for _ in range(ERR_STEPS):
        curs = jnp.asarray([[cur]], jnp.int32)
        lens = jnp.asarray([pos], jnp.int32)
        lf, cf = feng._decode((feng.params, cf, curs, lens))
        lq, cq = qeng._decode((qeng.params, cq, curs, lens))
        err = max(err, float(jnp.max(jnp.abs(
            lf[:, :cfg.vocab] - lq[:, :cfg.vocab]))))
        cur = int(jnp.argmax(lf[0, :cfg.vocab]))
        pos += 1
    return err


def _serve(cfg, bundle, params, wd: str, kd: str,
           evict_at: Optional[int]) -> List[List[int]]:
    """Serve 4 requests through a 2-slot quantized engine, optionally
    forcing an eviction mid-run — the preempt/restore replay leg of
    ``tokens_match``."""
    from repro.serving import Request

    eng = _engine(bundle, params, wd, kd, slots=2)
    rng = np.random.default_rng(SEED + 1)
    extras = None
    if cfg.family == "vlm":
        extras = {"vision": rng.normal(
            0, 1, (cfg.n_vision_tokens, cfg.d_vision)
        ).astype(np.float32)}
    for uid in range(4):
        toks = rng.integers(0, cfg.vocab - 2,
                            PROMPT_LEN).astype(np.int32)
        eng.submit(Request(uid=uid, tokens=toks, max_new_tokens=N_NEW,
                           extras=extras))
    steps, more = 0, True
    while more:
        more = eng.step()
        steps += 1
        if evict_at is not None and steps == evict_at:
            victims = [s for s in range(eng.max_slots)
                       if eng.active[s]]
            if victims:
                eng._evict(victims[0])
        if steps > 400:
            raise RuntimeError("serving loop did not converge")
    return [list(eng.results[u].output) for u in range(4)]


def _decode_rate(eng) -> float:
    """Warm tokens/s of one fused decode dispatch at full occupancy."""
    import jax.numpy as jnp

    b = eng.max_slots
    cur = jnp.zeros((b, 1), jnp.int32)
    lens = jnp.full((b,), CACHE_LEN // 2, jnp.int32)
    t = time_call(
        lambda: block(eng._decode((eng.params, eng.cache, cur, lens))),
        warmup=2, iters=5)
    return b / t


def run(tiny: bool = False) -> List[Dict]:
    families = ("dense",) if tiny else tuple(ARCHS)
    modes = MODES[:2] if tiny else MODES
    rows: List[Dict] = []
    for family in families:
        cfg, bundle, params = _setup(family)
        fp_hbm: Optional[int] = None
        for wd, kd in modes:
            eng = _engine(bundle, params, wd, kd, slots=SLOTS)
            hbm = int(eng.param_bytes + eng.kv_bytes)
            if wd == "fp32":
                fp_hbm = hbm
            rate = _decode_rate(eng)
            err = (0.0 if wd == "fp32"
                   else _logit_err(cfg, bundle, params, wd, kd))
            tol = 0.0 if wd == "fp32" else TOLERANCE[family][wd]
            assert err <= tol, (family, wd, kd, err, tol)
            straight = _serve(cfg, bundle, params, wd, kd, None)
            evicted = _serve(cfg, bundle, params, wd, kd, 3)
            match = straight == evicted
            assert match, (family, wd, kd,
                           "quantized decode must be bit-identical "
                           "to itself across preempt/restore")
            rows.append({
                "family": family, "weight_dtype": wd, "kv_dtype": kd,
                "tokens_per_s": round(rate, 1), "hbm_bytes": hbm,
                "hbm_reduction": round(fp_hbm / hbm, 2),
                "max_abs_logit_err": round(err, 4),
                "tokens_match": bool(match),
            })
    # the headline claim: int8 weights + int8 KV must shrink the
    # resident footprint by at least 1.5x vs fp at the same batch
    for family in families:
        fam = [r for r in rows if r["family"] == family]
        i8 = next(r for r in fam if r["weight_dtype"] == "int8")
        assert i8["hbm_reduction"] >= 1.5, (family, i8)
    print_table("Quantized pod decode vs fp at matched batch "
                f"({SLOTS} slots, cache_len {CACHE_LEN})", rows)
    if not tiny:
        save_result("BENCH_quantized_decode", rows, seed=SEED)
    return rows


if __name__ == "__main__":
    run()
