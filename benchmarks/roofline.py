"""§Roofline report: aggregates the dry-run JSONs into the per-
(arch x shape x mesh) roofline table — three terms in seconds, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs useful fraction, and a one-line
what-would-move-it-down note.

Reads benchmarks/results/dryrun/*.json (produced by repro.launch.dryrun)
— no compilation happens here."""

from __future__ import annotations

import glob
import json
import os

from .common import print_table, save_result

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def _advice(r: dict) -> str:
    dom = r["roofline"]["dominant"]
    mode = r["mode"]
    frac = r["roofline"]["useful_flops_fraction"]
    if dom == "collective_s":
        return "overlap/shrink collectives (reshard or fuse)"
    if dom == "memory_s":
        if mode in ("train", "prefill"):
            return "fuse attention (Pallas flash) to kill S^2 HBM traffic"
        return "shard/shrink KV reads (window or seq-parallel cache)"
    if frac < 0.5:
        return "remove redundant compute (replicated attention / remat)"
    return "near compute roofline; improve MXU utilization"


def load_rows(mesh: str = None, include_iters: bool = False) -> list:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        base = os.path.basename(path)
        if not include_iters and "__iter" in base:
            continue
        if base.endswith(".err"):
            continue
        with open(path) as f:
            r = json.load(f)
        if mesh and r["mesh"] != mesh:
            continue
        rl = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
            "compute_s": f"{rl['compute_s']:.3g}",
            "memory_s": f"{rl['memory_s']:.3g}",
            "collective_s": f"{rl['collective_s']:.3g}",
            "dominant": rl["dominant"].replace("_s", ""),
            "useful_frac": f"{rl['useful_flops_fraction']:.3f}",
            "temp_GiB": f"{r['memory']['temp_bytes'] / 2**30:.1f}",
            "fix": _advice(r),
        })
    return rows


def run() -> list:
    rows = load_rows(mesh="16x16")
    print_table("Roofline (single-pod 16x16, per device)", rows)
    multi = load_rows(mesh="2x16x16")
    if multi:
        print_table("Roofline (multi-pod 2x16x16)", multi)
    save_result("roofline", rows + multi, seed=None)
    missing = 40 - len(rows)
    if missing > 0:
        print(f"\n[note] {missing} single-pod baselines not yet present "
              f"(run tools/sweep_dryrun.sh)")
    return rows + multi


if __name__ == "__main__":
    run()
