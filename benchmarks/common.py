"""Shared benchmark helpers: wall-time measurement with warmup, CSV
emission, result persistence.

Result files are the repo's committed evidence, so ``save_result``
stamps every one with a uniform metadata block (schema version, jax
version, backend, seed, creation time) — two results are comparable
exactly when their meta agrees on everything except ``created_utc``,
which is informational only and excluded from comparisons.
``tools/check_bench.py`` schema-validates every committed
``BENCH_*.json`` against this layout so a broken writer can never land
silently."""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# bump when the result-file layout changes; tools/check_bench.py
# refuses layouts it does not understand
RESULT_SCHEMA = 1
# meta keys that must agree for two results to be comparable;
# created_utc is deliberately NOT here (wall clock is informational)
COMPARABLE_META = ("schema", "jax", "backend", "seed")


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 10,
              **kw) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn(*args, **kw)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def block(x):
    import jax
    jax.block_until_ready(x)
    return x


def result_meta(seed: Optional[int] = None) -> Dict:
    """The uniform metadata block every result file carries: schema
    version, jax version, backend, the benchmark's seed, and the
    (comparison-exempt) creation timestamp."""
    import jax
    return {
        "schema": RESULT_SCHEMA,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "seed": seed,
        "created_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                     time.gmtime()),
    }


def save_result(name: str, rows: List[Dict],
                seed: Optional[int] = None) -> str:
    """Persist benchmark rows under ``benchmarks/results/`` with the
    uniform ``BENCH_<name>.json`` naming — the prefix is added here so
    every benchmark lands consistently (and the docs lint, which
    verifies each cited BENCH_*.json exists, covers them all).  Rows
    are wrapped with the ``result_meta`` block; ``tools/check_bench.py``
    (run from the fast test tier and CI) validates the layout."""
    if not name.startswith("BENCH_"):
        name = "BENCH_" + name
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump({"meta": result_meta(seed), "rows": rows}, f,
                  indent=1)
        f.write("\n")
    return path


def load_result(path: str) -> Dict:
    """Read a result file written by ``save_result`` (meta + rows)."""
    with open(path) as f:
        return json.load(f)


def print_table(title: str, rows: List[Dict]) -> None:
    print(f"\n== {title} ==")
    if not rows:
        print("(no rows)")
        return
    cols = list(rows[0])
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))
