"""Shared benchmark helpers: wall-time measurement with warmup, CSV
emission, result persistence."""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def time_call(fn: Callable, *args, warmup: int = 2, iters: int = 10,
              **kw) -> float:
    """Median wall seconds per call."""
    for _ in range(warmup):
        fn(*args, **kw)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args, **kw)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]


def block(x):
    import jax
    jax.block_until_ready(x)
    return x


def save_result(name: str, rows: List[Dict]) -> str:
    """Persist benchmark rows under ``benchmarks/results/`` with the
    uniform ``BENCH_<name>.json`` naming — the prefix is added here so
    every benchmark lands consistently (and the docs lint, which
    verifies each cited BENCH_*.json exists, covers them all)."""
    if not name.startswith("BENCH_"):
        name = "BENCH_" + name
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name + ".json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    return path


def print_table(title: str, rows: List[Dict]) -> None:
    print(f"\n== {title} ==")
    if not rows:
        print("(no rows)")
        return
    cols = list(rows[0])
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(str(r.get(c, "")).ljust(widths[c]) for c in cols))
