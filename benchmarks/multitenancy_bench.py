"""Figure 5 / §4.5 reproduction: multitenant arena sharing.

Measures arena bytes for N models hosted in ONE shared arena vs N
private arenas.  The paper's claim: persistent sections stack, the
nonpersistent section is max() not sum() — so a shared arena beats
private arenas by roughly the sum of the smaller tenants' nonpersistent
sections.  Shown on the micro path (interpreters) and at pod scale
(ServingEngine KV arenas)."""

from __future__ import annotations

import numpy as np

from repro.apps import build_conv_reference, build_hotword, build_vww
from repro.core import (AllOpsResolver, MicroInterpreter, MicroModel,
                        SharedArenaState, export)

from .common import print_table, save_result


def micro_multitenancy() -> dict:
    resolver = AllOpsResolver()
    models = {n: MicroModel(export(b()))
              for n, b in (("conv", build_conv_reference),
                           ("hotword", build_hotword),
                           ("vww", build_vww))}
    private = 0
    sizes = {}
    for n, m in models.items():
        sizes[n] = MicroInterpreter.required_arena_size(m, resolver)
        private += sizes[n]
    # shared arena: persistent stacks, nonpersistent = max
    pers, nonpers = 0, 0
    for n, m in models.items():
        it = MicroInterpreter(m, resolver, sizes[n])
        used = it.arena_used_bytes()
        pers += used["persistent"]
        nonpers = max(nonpers, used["nonpersistent"])
    shared = pers + nonpers
    return {"scope": "micro (3 models, float)",
            "private_kB": round(private / 1024, 1),
            "shared_kB": round(shared / 1024, 1),
            "saving": f"{100 * (1 - shared / private):.1f}%"}


def pod_multitenancy() -> dict:
    import jax
    from repro.configs import get_config
    from repro.models import get_model
    from repro.serving import MultiTenantHost

    host = MultiTenantHost(arena_bytes=512 << 20)
    private = 0
    for name, arch in (("lm", "qwen3-32b"), ("ssm", "mamba2-780m"),
                       ("hybrid", "zamba2-1.2b")):
        cfg = get_config(arch, reduced=True)
        m = get_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        eng = host.add_model(name, m, params, max_slots=2, cache_len=64)
        # a private deployment would replicate the scratch headroom
        private += host.arena.usage().persistent // len(host.engines) \
            + host._scratch_high
    usage = host.usage()
    shared = usage.persistent + host._scratch_high
    return {"scope": "pod serving (3 tenants KV)",
            "private_kB": round(private / 1024, 1),
            "shared_kB": round(shared / 1024, 1),
            "saving": f"{100 * (1 - shared / max(private, 1)):.1f}%"}


def run() -> list:
    rows = [micro_multitenancy(), pod_multitenancy()]
    print_table("Multitenant arena sharing (Fig. 5 analogue)", rows)
    save_result("multitenancy_bench", rows, seed=0)
    return rows


if __name__ == "__main__":
    run()
