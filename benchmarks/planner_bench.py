"""Figure 4 / §4.4.2 reproduction: memory-planner compaction.

Compares, for each evaluation model (and a synthetic stress set):
  * naive linear allocation (no reuse — Fig 4a),
  * greedy first-fit-decreasing (Fig 4b),
  * the offline planner round-tripped through model metadata,
and at pod scale: planning the KV arenas of a multitenant serving host
with the same FFD planner (the 'same algorithm, 6 orders of magnitude
up' claim from DESIGN.md).
"""

from __future__ import annotations

import numpy as np

from repro.apps import build_conv_reference, build_hotword, build_vww
from repro.core import (AllOpsResolver, GreedyMemoryPlanner,
                        LinearMemoryPlanner, MicroInterpreter, MicroModel,
                        export)
from repro.core.memory_planner import BufferRequest

from .common import print_table, save_result


def plan_sizes(name: str, gb) -> dict:
    resolver = AllOpsResolver()
    model = MicroModel(export(gb))
    naive = MicroInterpreter(model, resolver,
                             1 << 28, planner=LinearMemoryPlanner())
    greedy = MicroInterpreter(model, resolver,
                              1 << 28, planner=GreedyMemoryPlanner())
    nb = naive.memory_plan().total_bytes
    gb_ = greedy.memory_plan().total_bytes
    return {"model": name, "naive_kB": round(nb / 1024, 1),
            "ffd_kB": round(gb_ / 1024, 1),
            "compaction": f"{nb / max(gb_, 1):.2f}x"}


def kv_arena_plan() -> dict:
    """Pod-scale reuse: plan per-layer KV + scratch lifetimes for a
    serving step with the same FFD planner."""
    n_layers, b, kh, c, dh = 32, 8, 8, 4096, 128
    kv = 2 * b * kh * c * dh * 2                    # k+v bf16, per layer
    reqs = []
    # KV caches live forever (whole step): lifetime [0, 2L]
    for li in range(n_layers):
        reqs.append(BufferRequest(nbytes=kv, first_use=0,
                                  last_use=2 * n_layers, tag=f"kv{li}"))
    # per-layer activation scratch: only alive during its layer
    for li in range(n_layers):
        reqs.append(BufferRequest(nbytes=b * 4096 * 2, first_use=li,
                                  last_use=li + 1, tag=f"act{li}"))
    naive = sum(r.nbytes for r in reqs)
    plan = GreedyMemoryPlanner().plan(reqs)
    return {"model": "serving-kv-arena (32L pod)",
            "naive_kB": round(naive / 1024, 1),
            "ffd_kB": round(plan.total_bytes / 1024, 1),
            "compaction": f"{naive / plan.total_bytes:.2f}x"}


def run() -> list:
    rows = [plan_sizes("conv_reference", build_conv_reference()),
            plan_sizes("hotword", build_hotword()),
            plan_sizes("vww", build_vww()),
            kv_arena_plan()]
    print_table("Memory-planner compaction (Fig. 4 analogue)", rows)
    save_result("planner_bench", rows, seed=None)
    return rows


if __name__ == "__main__":
    run()
