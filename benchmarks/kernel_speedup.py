"""Figure 6 speedup reproduction: reference kernels vs optimized
kernels (the CMSIS-NN / Cadence analogue).

Hardware adaptation note: the paper swaps scalar C loops for vendor
SIMD libraries on-device.  Here the 'vendor library' for the CPU host
is XLA itself, and for the TPU target it is the Pallas kernels.  We
report:

  * float reference interpreter vs INT8 quantized interpreter — the
    quantization speedup/size story (§3.3);
  * python-loop reference op vs XLA-fused op for the conv hot spot —
    the reference-vs-optimized-kernel axis the paper measures (their
    reference kernels are also 'designed for readability');
  * Pallas kernels: validated vs ref.py oracles (interpret mode runs
    the kernel body in Python on CPU, so wall-time there is NOT the
    TPU story — we report correctness + the structural tiling facts
    instead, and leave cycle claims to the roofline).
"""

from __future__ import annotations

import numpy as np

from repro.apps import build_vww, build_hotword
from repro.apps.models import representative_dataset
from repro.core import (AllOpsResolver, MicroInterpreter, MicroModel,
                        export)

from .common import print_table, save_result, time_call


def _interp(gb, quantize: bool):
    resolver = AllOpsResolver()
    kwargs = {}
    if quantize:
        kwargs = dict(representative_dataset=representative_dataset(gb),
                      quantize_int8=True)
    model = MicroModel(export(gb, **kwargs))
    size = MicroInterpreter.required_arena_size(model, resolver)
    it = MicroInterpreter(model, resolver, size)
    rng = np.random.default_rng(0)
    xs = [rng.normal(0, 1, gb.tensors[t].shape).astype(np.float32)
          for t in gb.inputs]

    def call():
        for i, x in enumerate(xs):
            it.set_input(i, x)
        it.invoke()
        it.output(0)
    return call


def quantization_speedup() -> list:
    rows = []
    from repro.apps import build_conv_reference
    for name, builder in (("conv_reference", build_conv_reference),
                          ("vww", build_vww)):
        gb = builder()
        t_f = time_call(_interp(gb, False), iters=10)
        t_q = time_call(_interp(builder(), True), iters=10)
        rows.append({"model": name,
                     "float_us": round(t_f * 1e6, 1),
                     "int8_us": round(t_q * 1e6, 1),
                     "speedup": f"{t_f / t_q:.2f}x"})
    return rows


def pallas_validation() -> list:
    """Correctness of each Pallas kernel vs its jnp oracle (interpret
    mode), plus the tiling facts that matter on the MXU."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import (decode_attention, flash_attention,
                               quant_matmul, ssd_scan)
    from repro.kernels import ref as R

    rng = np.random.default_rng(1)
    rows = []

    # flash attention
    q = jnp.asarray(rng.normal(0, 1, (2, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (2, 4, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (2, 4, 256, 64)), jnp.float32)
    got = flash_attention(q, k, v, causal=True)
    want = R.mha_ref(q, k, v, causal=True)
    err = float(jnp.max(jnp.abs(got - want)))
    rows.append({"kernel": "flash_attention", "shape": "2x4x256x64",
                 "max_err": f"{err:.2e}", "block": "128x128 VMEM",
                 "status": "ok" if err < 1e-3 else "FAIL"})

    # quant matmul
    xq = jnp.asarray(rng.integers(-127, 127, (64, 128)), jnp.int8)
    wq = jnp.asarray(rng.integers(-127, 127, (128, 96)), jnp.int8)
    scale = jnp.full((96,), 0.02, jnp.float32)
    got = quant_matmul(xq, wq, None, 3, scale, -5)
    want = R.quant_matmul_ref(xq, wq, None, 3, scale, -5)
    err = int(jnp.max(jnp.abs(got.astype(jnp.int32)
                              - want.astype(jnp.int32))))
    rows.append({"kernel": "quant_matmul", "shape": "64x128x96 int8",
                 "max_err": str(err), "block": "MXU 128-mult",
                 "status": "ok" if err <= 1 else "FAIL"})
    return rows


def run() -> list:
    rows = quantization_speedup()
    print_table("Reference vs optimized (Fig. 6 speedup analogue)", rows)
    vrows = pallas_validation()
    print_table("Pallas kernels vs jnp oracles (interpret mode)", vrows)
    save_result("kernel_speedup", rows + vrows, seed=0)
    return rows + vrows


if __name__ == "__main__":
    run()
