"""Benchmark orchestrator — one entry per paper table/figure:

  interpreter_overhead   Fig. 6  total vs calculation cycles
  batched_invoke         batched-invoke throughput sweep (B ∈ {1,4,16})
  ragged_invoke          masked ragged dispatch vs lockstep/sequential
                         at occupancy 25/50/75/100%
  arrival_process        Poisson arrivals: completion latency + SLO,
                         lockstep FIFO vs ragged FIFO vs ragged EDF,
                         plus bucketed-prefill compile counts
  preemption             heavy-tail mix: EDF alone vs EDF + preemptible
                         lanes, and the pod engine with preemption +
                         chunked prefill (docs/PREEMPTION.md)
  memory_overhead        Tab. 2  persistent/nonpersistent arena split
  planner_bench          Fig. 4  naive vs FFD memory compaction
  kernel_speedup         Fig. 6  reference vs optimized kernels
  multitenancy_bench     Fig. 5  shared-arena savings
  roofline               §Roofline table from the dry-run artifacts

``python -m benchmarks.run [names...]`` — default: all.  A benchmark
that raises does NOT silently truncate the run: the remaining
benchmarks still execute, every failure is reported with its
traceback, and the process exits non-zero."""

from __future__ import annotations

import sys
import time
import traceback


def main(argv=None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    from . import (arrival_process, interpreter_overhead, kernel_speedup,
                   memory_overhead, multitenancy_bench, planner_bench,
                   ragged_invoke, roofline)

    benches = {
        "interpreter_overhead": interpreter_overhead.run,
        "batched_invoke": interpreter_overhead.run_batched,
        "ragged_invoke": ragged_invoke.run,
        "arrival_process": arrival_process.run,
        "preemption": arrival_process.run_preempt,
        "memory_overhead": memory_overhead.run,
        "planner_bench": planner_bench.run,
        "kernel_speedup": kernel_speedup.run,
        "multitenancy_bench": multitenancy_bench.run,
        "roofline": roofline.run,
    }
    names = argv or list(benches)
    unknown = [n for n in names if n not in benches]
    if unknown:
        raise SystemExit(f"unknown benchmark(s) {unknown}; "
                         f"have {list(benches)}")
    t0 = time.time()
    failures = []
    for name in names:
        try:
            benches[name]()
        except Exception:
            failures.append(name)
            print(f"\nFAILED {name}:\n{traceback.format_exc()}",
                  file=sys.stderr)
    dt = time.time() - t0
    if failures:
        raise SystemExit(
            f"{len(failures)}/{len(names)} benchmark(s) FAILED "
            f"({', '.join(failures)}) in {dt:.1f}s")
    print(f"\nall {len(names)} benchmarks done in {dt:.1f}s")


if __name__ == "__main__":
    main()
