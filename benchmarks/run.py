"""Benchmark orchestrator — one entry per paper table/figure:

  interpreter_overhead   Fig. 6  total vs calculation cycles
  batched_invoke         batched-invoke throughput sweep (B ∈ {1,4,16})
  ragged_invoke          masked ragged dispatch vs lockstep/sequential
                         at occupancy 25/50/75/100%
  arrival_process        Poisson arrivals: completion latency + SLO,
                         lockstep FIFO vs ragged FIFO vs ragged EDF,
                         plus bucketed-prefill compile counts
  preemption             heavy-tail mix: EDF alone vs EDF + preemptible
                         lanes, and the pod engine with preemption +
                         chunked prefill (docs/PREEMPTION.md)
  paged_kv               paged KV pool vs contiguous slabs at the same
                         HBM budget: peak occupancy + token
                         bit-identity (docs/ARCHITECTURE.md §8)
  replica_sweep          replica count × routing policy over the PR-4
                         arrival mix: throughput, p99, SLO + token
                         bit-identity (docs/ARCHITECTURE.md §9)
  streaming              TTFT/ITL percentiles from per-token
                         StreamEvents, sync vs overlapped decode over
                         the family matrix + wall-clock cost-model
                         validation (docs/STREAMING.md)
  autotune               calibration-driven bucket/chunk config vs the
                         hand-picked defaults: compile counts + p95
                         arrival-process latency (docs/SCHEDULING.md)
  quantized_decode       int8/int4 weight + int8 KV decode vs fp at
                         matched batch: tokens/s, HBM footprint,
                         logit error, preempt/restore token identity
                         (docs/QUANTIZATION.md)
  memory_overhead        Tab. 2  persistent/nonpersistent arena split
  planner_bench          Fig. 4  naive vs FFD memory compaction
  kernel_speedup         Fig. 6  reference vs optimized kernels
  multitenancy_bench     Fig. 5  shared-arena savings
  roofline               §Roofline table from the dry-run artifacts

``python -m benchmarks.run [--tiny] [names...]`` — default: all.  A
benchmark that raises does NOT silently truncate the run: the
remaining benchmarks still execute, every failure is reported with its
traceback, and the process exits non-zero.  ``--tiny`` runs each
requested benchmark that supports it in its seconds-scale smoke mode
(no JSON written) and skips the ones that do not — the CI pipeline's
benchmark smoke job."""

from __future__ import annotations

import inspect
import sys
import time
import traceback


def main(argv=None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    tiny = "--tiny" in argv
    argv = [a for a in argv if a != "--tiny"]
    from . import (arrival_process, autotune, interpreter_overhead,
                   kernel_speedup, memory_overhead, multitenancy_bench,
                   planner_bench, quantized_decode, ragged_invoke,
                   roofline)

    benches = {
        "interpreter_overhead": interpreter_overhead.run,
        "batched_invoke": interpreter_overhead.run_batched,
        "ragged_invoke": ragged_invoke.run,
        "arrival_process": arrival_process.run,
        "preemption": arrival_process.run_preempt,
        "paged_kv": arrival_process.run_paged,
        "replica_sweep": arrival_process.run_replicas,
        "streaming": arrival_process.run_stream,
        "autotune": autotune.run,
        "quantized_decode": quantized_decode.run,
        "memory_overhead": memory_overhead.run,
        "planner_bench": planner_bench.run,
        "kernel_speedup": kernel_speedup.run,
        "multitenancy_bench": multitenancy_bench.run,
        "roofline": roofline.run,
    }
    names = argv or list(benches)
    unknown = [n for n in names if n not in benches]
    if unknown:
        raise SystemExit(f"unknown benchmark(s) {unknown}; "
                         f"have {list(benches)}")
    t0 = time.time()
    failures = []
    timings = []
    skipped = []
    ran = 0
    for name in names:
        fn = benches[name]
        kw = {}
        if tiny:
            if "tiny" not in inspect.signature(fn).parameters:
                print(f"skipping {name} (no --tiny mode)")
                skipped.append(name)
                continue
            kw["tiny"] = True
        ran += 1
        t1 = time.time()
        try:
            fn(**kw)
        except Exception:
            failures.append(name)
            print(f"\nFAILED {name}:\n{traceback.format_exc()}",
                  file=sys.stderr)
        finally:
            timings.append((name, time.time() - t1))
    dt = time.time() - t0
    # per-benchmark wall time, so a smoke-job regression in one
    # benchmark (e.g. the streaming wall-clock leg) is visible from
    # the log instead of hiding inside the aggregate
    for name, t in timings:
        flag = "  [FAILED]" if name in failures else ""
        print(f"  {name:22s} {t:7.1f}s{flag}")
    if skipped:
        # the smoke job's coverage gap, stated once at the end: these
        # benchmarks have no seconds-scale mode, so --tiny never runs
        # them and only the full (cron / release) run covers them
        print(f"  not covered by --tiny ({len(skipped)}): "
              f"{', '.join(skipped)}")
    if failures:
        raise SystemExit(
            f"{len(failures)}/{ran} benchmark(s) FAILED "
            f"({', '.join(failures)}) in {dt:.1f}s")
    if ran == 0 and argv:
        # an explicitly named selection that ran nothing is a broken
        # gate, not a green one
        raise SystemExit(
            f"--tiny ran none of {argv}: no requested benchmark has "
            f"a tiny mode")
    print(f"\nall {ran} benchmark(s) done in {dt:.1f}s")


if __name__ == "__main__":
    main()
