"""Benchmark orchestrator — one entry per paper table/figure:

  interpreter_overhead   Fig. 6  total vs calculation cycles
  batched_invoke         batched-invoke throughput sweep (B ∈ {1,4,16})
  ragged_invoke          masked ragged dispatch vs lockstep/sequential
                         at occupancy 25/50/75/100%
  memory_overhead        Tab. 2  persistent/nonpersistent arena split
  planner_bench          Fig. 4  naive vs FFD memory compaction
  kernel_speedup         Fig. 6  reference vs optimized kernels
  multitenancy_bench     Fig. 5  shared-arena savings
  roofline               §Roofline table from the dry-run artifacts

``python -m benchmarks.run [names...]`` — default: all."""

from __future__ import annotations

import sys
import time


def main(argv=None) -> None:
    argv = list(sys.argv[1:] if argv is None else argv)
    from . import (interpreter_overhead, kernel_speedup, memory_overhead,
                   multitenancy_bench, planner_bench, ragged_invoke,
                   roofline)

    benches = {
        "interpreter_overhead": interpreter_overhead.run,
        "batched_invoke": interpreter_overhead.run_batched,
        "ragged_invoke": ragged_invoke.run,
        "memory_overhead": memory_overhead.run,
        "planner_bench": planner_bench.run,
        "kernel_speedup": kernel_speedup.run,
        "multitenancy_bench": multitenancy_bench.run,
        "roofline": roofline.run,
    }
    names = argv or list(benches)
    t0 = time.time()
    for name in names:
        if name not in benches:
            raise SystemExit(f"unknown benchmark {name!r}; "
                             f"have {list(benches)}")
        benches[name]()
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
