"""Table 2 reproduction: persistent / nonpersistent / total arena memory
for the paper's three evaluation models.

The paper reports (Sparkfun Edge, INT8): conv_reference 1.29k/7.75k,
Google Hotword 12.12k/0.68k, VWW 26.5k/55.3k — the claim to reproduce
is the SHAPE of the split (conv nets dominated by activation
(nonpersistent) memory, keyword models by persistent state/metadata),
and that the planner keeps totals small.
"""

from __future__ import annotations

import numpy as np

from repro.apps import build_conv_reference, build_hotword, build_vww
from repro.apps.models import representative_dataset
from repro.core import (AllOpsResolver, MicroInterpreter, MicroModel,
                        export)

from .common import print_table, save_result


def measure(name: str, gb, quantize: bool = True) -> dict:
    resolver = AllOpsResolver()
    kwargs = {}
    if quantize:
        kwargs = dict(representative_dataset=representative_dataset(gb),
                      quantize_int8=True)
    model = MicroModel(export(gb, **kwargs))
    size = MicroInterpreter.required_arena_size(model, resolver)
    interp = MicroInterpreter(model, resolver, size)
    used = interp.arena_used_bytes()
    return {
        "model": name,
        "persistent_kB": round(used["persistent"] / 1024, 2),
        "nonpersistent_kB": round(used["nonpersistent"] / 1024, 2),
        "total_kB": round((used["persistent"] + used["nonpersistent"])
                          / 1024, 2),
    }


def run() -> list:
    rows = [measure("conv_reference", build_conv_reference()),
            measure("hotword", build_hotword(), quantize=False),
            measure("vww", build_vww())]
    print_table("Arena memory split (Table 2 analogue, INT8)", rows)
    save_result("memory_overhead", rows, seed=None)
    return rows


if __name__ == "__main__":
    run()
