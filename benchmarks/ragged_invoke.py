"""Ragged-invoke throughput: masked per-bucket dispatch vs lockstep
batch vs sequential single invokes, swept over lane occupancy.

The lockstep ``InterpreterPool`` only helps when B identical requests
arrive together; a fragmented workload (the serving norm) leaves lanes
empty or forces head-of-line waiting.  ``RaggedInterpreterPool`` keeps
one compiled masked program per bucket and admits/retires lanes between
dispatches, so the question this benchmark answers is: at occupancy
25/50/75/100%, what does ONE masked dispatch cost per *active* request,
compared to

  * ``sequential`` — each request alone through MicroInterpreter.invoke
    (the B=1 paper path), and
  * ``lockstep``  — a full-B InterpreterPool dispatch amortized over
    the same number of live requests (idle lanes still run, and a
    lockstep pool cannot retire them).

Emits ``BENCH_ragged_invoke.json`` (same flat-row shape as
``BENCH_batched_invoke.json``) via ``python -m benchmarks.run
ragged_invoke``.
"""

from __future__ import annotations

import numpy as np

from repro.apps import build_conv_reference, build_fc_stack
from repro.apps.models import representative_dataset
from repro.core import (AllOpsResolver, InterpreterPool, MicroInterpreter,
                        MicroModel, RaggedInterpreterPool, export)

from .common import print_table, save_result, time_call

LANES = 16
OCCUPANCIES = (0.25, 0.5, 0.75, 1.0)


def _build(gb, quantize: bool) -> MicroModel:
    kwargs = {}
    if quantize:
        kwargs = dict(representative_dataset=representative_dataset(gb),
                      quantize_int8=True)
    return MicroModel(export(gb, **kwargs))


def bench_ragged(name: str, gb, quantize: bool, lanes: int = LANES,
                 occupancies=OCCUPANCIES) -> list:
    resolver = AllOpsResolver()
    model = _build(gb, quantize)
    label = name + (" int8" if quantize else " float")
    in_shapes = [gb.tensors[t].shape for t in gb.inputs]
    rng = np.random.default_rng(0)
    xs = [[rng.normal(0, 1, s).astype(np.float32) for s in in_shapes]
          for _ in range(lanes)]

    # sequential baseline: one request alone, the paper's B=1 path
    size = MicroInterpreter.required_arena_size(model, resolver)
    interp = MicroInterpreter(model, resolver, size)

    def sequential_one():
        for pos, x in enumerate(xs[0]):
            interp.set_input(pos, x)
        interp.invoke()
        interp.output(0)

    t_seq = time_call(sequential_one, iters=20)

    # lockstep baseline: the full-B pool has no way to shrink a wave
    lock = InterpreterPool(model, resolver, batch=lanes)

    def lockstep_wave():
        for lane in range(lanes):
            for pos, x in enumerate(xs[lane]):
                lock.set_input(lane, pos, x)
        lock.invoke()
        lock.outputs(0)

    t_lock = time_call(lockstep_wave, iters=20)

    ragged = RaggedInterpreterPool()
    ragged.add_bucket(name, model, resolver, lanes)

    rows = []
    for occ in occupancies:
        k = max(1, round(lanes * occ))
        slots = [ragged.admit(name) for _ in range(k)]

        def wave():
            for i, slot in enumerate(slots):
                for pos, x in enumerate(xs[i]):
                    ragged.set_input(name, slot, pos, x)
            ragged.dispatch()
            ragged.outputs(name, 0)

        t_ragged = time_call(wave, iters=20)
        for slot in slots:
            ragged.retire(name, slot)
        per_req = t_ragged / k
        rows.append({
            "model": label,
            "lanes": lanes,
            "occupancy_pct": int(round(100 * occ)),
            "active": k,
            "us_per_req_ragged": round(per_req * 1e6, 1),
            "us_per_req_sequential": round(t_seq * 1e6, 1),
            "us_per_req_lockstep": round(t_lock / k * 1e6, 1),
            "speedup_vs_sequential": round(t_seq / per_req, 2),
            "speedup_vs_lockstep": round((t_lock / k) / per_req, 2),
        })
    return rows


def run() -> list:
    rows = []
    for name, builder, quantize in (
            ("conv_reference", build_conv_reference, True),
            ("fc_stack", build_fc_stack, True),
            ("conv_reference", build_conv_reference, False)):
        rows.extend(bench_ragged(name, builder(), quantize))
    print_table("Ragged invoke throughput (masked dispatch, occupancy "
                "sweep)", rows)
    save_result("BENCH_ragged_invoke", rows, seed=0)
    return rows


if __name__ == "__main__":
    run()
