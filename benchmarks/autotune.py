"""Autotune benchmark: calibration-driven serving config vs the
hand-picked defaults.

``repro.core.costmodel.calibrate`` measures, on the REAL compiled
serving steps, each candidate bucket's (compile, padded-step) cost and
each candidate chunk size's step cost, then solves for the bucket
table and ``prefill_chunk`` that minimize the workload's expected
prefill latency.  This benchmark shows what that buys on the PR-3/PR-4
arrival process (``benchmarks.arrival_process`` supplies the workload
generator and the virtual clock):

  * **config section** — the solved layout next to the default pow2
    ladder: bucket levels, chunk size, prefill compiles actually
    traced (``ServingEngine.prefill_compiles`` vs the profile's
    ``predicted_compiles``), total padded prefill tokens, and a
    ``tokens_match_default`` bit asserting the autotuned engine's
    decoded tokens are BIT-IDENTICAL to the default engine's (padding
    is invisible to the length-masked decode, so tuning the table can
    never change the output);
  * **latency section** — p50/p95 completion latency and deadline-SLO
    attainment for the same Poisson arrival process served by each
    config, on a virtual clock that charges each engine step what
    calibration MEASURED it to cost — including the one-time compile
    stall the first hit of every bucket pays, which is exactly the
    cost the solver trades against padding waste.

Emits ``BENCH_autotune.json`` via ``python -m benchmarks.run
autotune``; ``--tiny`` runs a seconds-scale smoke (no JSON written)
used by the CI pipeline.  How to read the rows: docs/SCHEDULING.md
("Cost model & calibration").
"""

from __future__ import annotations

import sys
from typing import Dict, List, Optional, Set

import numpy as np

from .arrival_process import SEED, VirtualClock, _engine_workload
from .common import print_table, save_result

CACHE_LEN = 64
SLOTS = 2
N_REQUESTS = 40
N_CALIB = 200          # length samples the profile is solved against
# candidate levels: the default pow2 ladder PLUS the workload's own
# lengths, so both configs' padded lengths have measured costs
CANDIDATES = (8, 16, 32, 40, 64)
CHUNKS = (0, 8)


# the full family matrix (family parity, PR 7): every family with a
# bucketed or chunked fast path to size is calibrated and served.
# dense/moe solve bucket tables (moe via capacity-stable masked
# dispatch); ssm/hybrid solve only the chunk size (their prefill stays
# exact-length, so their chunk candidates are the whole search space).
FAMILIES = (("dense", "qwen3-32b"), ("ssm", "mamba2-780m"),
            ("hybrid", "zamba2-1.2b"), ("moe", "deepseek-moe-16b"))


def _build(arch: str = "qwen3-32b"):
    import jax

    from repro.configs import get_config
    from repro.models import get_model

    cfg = get_config(arch, reduced=True)
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    return bundle, params


def _measure_decode_us(bundle, params) -> float:
    """Warm cost of one fused decode step — the virtual clock's decode
    tick (its compile is warmed out: both configs pay it identically
    at engine start, before any request arrives)."""
    import jax.numpy as jnp

    from repro.core.profiler import measure_compile_and_step
    from repro.serving import ServingEngine

    eng = ServingEngine(bundle, params, max_slots=SLOTS,
                        cache_len=CACHE_LEN, prefill_buckets=False)
    cache = bundle.empty_cache(SLOTS, CACHE_LEN, bundle.cfg.jnp_dtype())
    cur = jnp.zeros((SLOTS, 1), jnp.int32)
    lens = jnp.asarray([8] * SLOTS, jnp.int32)
    t = measure_compile_and_step(
        lambda: eng._decode((params, cache, cur, lens)), iters=5)
    return t.step_us


class _CostClock:
    """Charges each engine step what calibration measured: warm step
    cost per padded prefill length / chunk / decode, plus the COLD
    compile cost the first time a prefill length is traced — the
    virtual-clock analogue of ``jit``'s per-signature cache."""

    def __init__(self, profile, decode_us: float, chunk: int):
        self.by_len = {c.length: c for c in profile.bucket_costs}
        self.chunk_cost = next(
            (c for c in profile.chunk_costs if c.chunk == chunk), None)
        self.decode_us = decode_us
        self.seen: Set[int] = set()
        self.chunk_seen = False
        self.compile_stall_us = 0.0

    def _prefill(self, L: int) -> float:
        c = self.by_len.get(L)
        if c is None:                       # off-candidate length:
            ref = min(self.by_len.values(),  # nearest measured level
                      key=lambda r: abs(r.length - L))
            cold, warm = ref.compile_us, ref.step_us * L / ref.length
        else:
            cold, warm = c.compile_us, c.step_us
        if L not in self.seen:
            self.seen.add(L)
            self.compile_stall_us += cold - warm
            return cold
        return warm

    def step_cost(self, last_step: Dict) -> float:
        dt = 0.0
        for L in last_step["prefill_tokens"]:
            dt += self._prefill(L)
        if last_step["chunks"]:
            cc = self.chunk_cost
            dt += last_step["chunks"] * cc.step_us
            if not self.chunk_seen:
                self.chunk_seen = True
                self.compile_stall_us += cc.trace_overhead_us
                dt += cc.trace_overhead_us
        if last_step["decoded"]:
            dt += self.decode_us
        return dt


def _padded_len(eng, prompt_len: int) -> int:
    """How many prefill tokens a prompt of this length actually costs
    under the engine's config: its chunked total, its bucket, or its
    exact length — the padding-waste metric the config rows report.
    Eligibility is asked of the ENGINE's own predicates
    (``_chunk_eligible``, ``_vis``) so this metric cannot drift from
    what the engine actually dispatches."""
    from repro.serving import Request

    m = prompt_len - 1
    if m < 1:
        return 0
    probe = Request(uid=-1, tokens=np.zeros(prompt_len, np.int32))
    if eng._chunk_eligible(probe):
        return -(-m // eng.chunk_tokens) * eng.chunk_tokens
    if eng.bucket_table is not None:
        b = eng.bucket_table.fit(m)
        if b is not None and b <= eng.cache_len - eng._vis():
            return b
    return m


def _sim(bundle, params, wl, profile, decode_us: float,
         tuned: bool) -> Dict:
    """Serve the arrival process with REAL dispatches; account latency
    on the measured-cost virtual clock.  Returns completion times,
    decoded tokens, and the engine's observability counters."""
    from repro.serving import Request, ServingEngine

    clock = VirtualClock()
    if tuned:
        eng = ServingEngine.from_profile(
            bundle, params, profile, max_slots=SLOTS, policy="edf",
            clock=clock)
    else:
        eng = ServingEngine(bundle, params, max_slots=SLOTS,
                            cache_len=CACHE_LEN, policy="edf",
                            clock=clock)
    cost = _CostClock(profile, decode_us, eng.chunk_tokens)
    n = len(wl["arrivals"])
    done_at = np.full(n, np.nan)
    nxt = 0
    while True:
        while nxt < n and wl["arrivals"][nxt] <= clock.now_us:
            d = wl["deadlines"][nxt]
            eng.submit(Request(
                uid=nxt, tokens=wl["prompts"][nxt],
                max_new_tokens=int(wl["budgets"][nxt]),
                deadline_us=None if np.isinf(d) else int(d),
                arrival_us=int(wl["arrivals"][nxt])))
            nxt += 1
        more = eng.step()
        clock.now_us += max(cost.step_cost(eng.last_step), 1.0)
        for uid, res in eng.results.items():
            if res.done and np.isnan(done_at[uid]):
                done_at[uid] = clock.now_us
        if not more:
            if nxt >= n:
                break
            clock.now_us = max(clock.now_us, wl["arrivals"][nxt])
    padded = sum(_padded_len(eng, len(p)) for p in wl["prompts"])
    return {"done_at": done_at,
            "tokens": {u: r.output for u, r in eng.results.items()},
            "prefill_compiles": eng.prefill_compiles(),
            "chunk_compiles": eng.chunk_compiles(),
            "levels": (eng.bucket_table.levels
                       if eng.bucket_table else []),
            "chunk": eng.chunk_tokens,
            "compile_stall_us": cost.compile_stall_us,
            "padded_tokens": padded}


def _latency_row(mode: str, family: str, wl, sim: Dict) -> Dict:
    lat = sim["done_at"] - wl["arrivals"]
    assert not np.isnan(lat).any(), \
        f"{family}/{mode}: unfinished requests"
    dl = ~wl["mono"]
    p50, p95 = np.percentile(lat, (50, 95))
    slo = float((sim["done_at"][dl] <= wl["deadlines"][dl]).mean())
    return {
        "section": "latency", "mode": mode, "family": family,
        "n_requests": len(lat),
        "p50_us": round(float(p50), 1),
        "p95_us": round(float(p95), 1),
        "deadline_slo_pct": round(100 * slo, 1),
        "compile_stall_us": round(sim["compile_stall_us"], 1),
    }


def _family_rows(family: str, arch: str, tiny: bool):
    """Calibrate one family, then serve the identical arrival process
    with its default and autotuned configs; returns (config rows,
    latency rows)."""
    from repro.core import calibrate

    bundle, params = _build(arch)
    vocab = bundle.cfg.vocab
    n = 12 if tiny else N_REQUESTS
    n_calib = 40 if tiny else N_CALIB
    # moe has no chunked fast path (typed UnsupportedFamilyError), so
    # its chunk search space is {0}; every other family here sweeps the
    # usual candidates
    chunks = (0,) if family == "moe" else CHUNKS

    # 1. the length model: the SAME 80/20 short/long mix the PR-4
    # arrival process serves (costs are placeholders — only the
    # lengths feed calibration)
    cwl = _engine_workload(np.random.default_rng(SEED + 4), n_calib,
                           vocab, 1.0, 1.0)
    lengths = [len(p) for p in cwl["prompts"]]
    profile = calibrate(bundle, params, lengths, cache_len=CACHE_LEN,
                        seed=SEED, candidate_levels=CANDIDATES,
                        chunk_candidates=chunks)
    decode_us = _measure_decode_us(bundle, params)

    # 2. the served workload: measured costs set arrivals & deadlines.
    # The PR-4 generator spaces arrivals by decode cost alone; here the
    # horizon additionally amortizes the DEFAULT config's one-time
    # compile stalls, so the process outlives the cold start and SLO
    # attainment reflects how quickly each config gets warm — not just
    # that both start cold.
    short_us = next(c.step_us for c in profile.bucket_costs
                    if c.length == 8)
    wl = _engine_workload(np.random.default_rng(SEED + 5), n, vocab,
                          decode_us, short_us)
    by_len = {c.length: c for c in profile.bucket_costs}
    from repro.core import BucketTable
    default_tbl = BucketTable(min_bucket=8, max_bucket=CACHE_LEN)
    default_hit = {default_tbl.fit(max(len(p) - 1, 1))
                   for p in wl["prompts"] if len(p) > 1}
    stall = sum(by_len[l].trace_overhead_us
                for l in default_hit if l in by_len)
    spacing = stall / n + 3.0 * decode_us
    rng = np.random.default_rng(SEED + 6)
    wl["arrivals"] = np.cumsum(rng.exponential(spacing, n))
    service = short_us + 4 * decode_us
    wl["deadlines"] = np.where(
        wl["mono"], np.inf, wl["arrivals"] + 4.0 * service)

    sims = {"default": _sim(bundle, params, wl, profile, decode_us,
                            tuned=False),
            "autotuned": _sim(bundle, params, wl, profile, decode_us,
                              tuned=True)}
    match = sims["autotuned"]["tokens"] == sims["default"]["tokens"]
    assert match, \
        f"{family}: autotuned config changed the decoded tokens"

    rows: List[Dict] = []
    for mode, sim in sims.items():
        rows.append({
            "section": "config", "mode": mode, "family": family,
            "bucket_levels": ",".join(map(str, sim["levels"])),
            "prefill_chunk": sim["chunk"],
            "prefill_compiles": sim["prefill_compiles"],
            # the profile's compile prediction assumes its bucket table
            # is applied — only true of an autotuned bucketed family
            "predicted_compiles": (
                profile.predicted_compiles
                if mode == "autotuned" and sim["levels"] else -1),
            "padded_tokens": sim["padded_tokens"],
            "tokens_match_default": bool(match),
        })
    lrows = [_latency_row(mode, family, wl, sim)
             for mode, sim in sims.items()]
    return rows, lrows


def run(tiny: bool = False) -> List[Dict]:
    """Calibrate and serve every family in the matrix with its default
    and autotuned configs; emit ``BENCH_autotune.json`` unless
    ``tiny``."""
    rows: List[Dict] = []
    lrows: List[Dict] = []
    for family, arch in FAMILIES:
        r, l = _family_rows(family, arch, tiny)
        rows += r
        lrows += l
    print_table("Autotuned vs default config, full family matrix "
                "(solved bucket table + chunk; compile counts)", rows)
    print_table("Arrival-process completion latency on measured costs "
                "(cold compile stalls included)", lrows)
    all_rows = rows + lrows
    if not tiny:
        save_result("BENCH_autotune", all_rows, seed=SEED)
    return all_rows


if __name__ == "__main__":
    run(tiny="--tiny" in sys.argv[1:])
