"""Arrival-process serving benchmark: Poisson arrivals, ragged
continuation lengths, lockstep-FIFO vs ragged-FIFO vs ragged-EDF.

Throughput benchmarks (``batched_invoke``, ``ragged_invoke``) measure
the cost of one dispatch; this one measures what a *user* feels —
completion latency under a live arrival process — and what an operator
sells — SLO attainment.  Requests arrive by a deterministic-seed
Poisson process with ragged continuation lengths (1..6 frames) and a
per-request deadline; three disciplines serve the identical workload:

  * ``lockstep_fifo`` — an ``InterpreterPool`` wave admits up to B
    queued requests FIFO and must run ALL of them to the LONGEST
    request's length before admitting again (a lockstep pool cannot
    retire a lane mid-wave): the head-of-line blocking baseline;
  * ``ragged_fifo``  — the real ``MultiTenantHost`` micro scheduler
    (``micro_step``): lanes admit/retire between waves, FIFO order;
  * ``ragged_edf``   — same host, ``EDFPolicy``: the free lane goes to
    the queued request whose deadline expires soonest.

Dispatches are REAL (the actual compiled programs run every tick);
latency is accounted on a **virtual clock** that advances by the warm
measured cost of one dispatch per tick, and the host's scheduling
policies read that same clock — so the reported p50/p95/p99 completion
latencies and SLO attainment are deterministic given the seed, up to
the single measured dispatch constant.  A second section reports the
bucketed-prefill compile counts (``ServingEngine.prefill_compiles``)
for mixed prompt lengths, bucketed vs exact-length.

Emits ``BENCH_arrival_process.json`` via ``python -m benchmarks.run
arrival_process``; ``python -m benchmarks.arrival_process --tiny``
runs a seconds-scale end-to-end smoke (no JSON written) used by the
slow test tier.  How to read the rows: docs/SCHEDULING.md.

``--preempt`` runs the PREEMPTION benchmark instead (also registered
as ``preemption`` in ``benchmarks.run`` → ``BENCH_preemption.json``):
a heavy-tail mix — a few 6-frame best-effort monopolizers among
1-frame deadline-class requests — served with PR-3 EDF admission alone
vs EDF + EDF-displace preemption over checkpointable lanes, plus the
pod-engine analogue where a long-prompt monopolizer is tamed by
preemption + chunked prefill.  The chunked engine's configuration
comes from the calibration-profile CACHE
(``benchmarks/results/profiles/``, via ``ServingEngine.from_profile``)
rather than hand constants — calibrated once and persisted on the
first full run.  How to read those rows: docs/PREEMPTION.md.

``--paged`` runs the PAGED-KV occupancy benchmark (registered as
``paged_kv`` → ``BENCH_paged_kv.json``): the identical short-request
flood served by a contiguous engine (whole cache_len KV slabs, slots
bounded by HBM) and a paged engine given the SAME HBM budget as a
shared block pool — admissible concurrency is bounded by blocks
actually needed, not worst-case slabs, and the decoded tokens must
stay bit-identical.  How to read those rows: docs/ARCHITECTURE.md §8.

``--replicas`` runs the REPLICA-ROUTING sweep (registered as
``replica_sweep`` → ``BENCH_replica_sweep.json``): the PR-4 engine
arrival mix (short deadline class + long monopolizers) served by 1, 2
and 4 ``ServingEngine`` replicas behind a ``ReplicaRouter``, swept
over a routing-policy LADDER: load-blind round-robin and load-aware
least-loaded route at admission time only, while locality adds the
router's stickiness-aware work-stealing rebalancer (the rebalancer is
the locality mechanism — it moves only checkpoint-free work, so it
needs ``home_of`` bookkeeping to be safe).  Dispatches are real; the
virtual clock advances by the MAX of the
replicas' per-tick measured costs (replicas run in parallel on
disjoint device sets), so throughput and p99 vs replica count are
deterministic given the seed — and every config's decoded tokens must
be bit-identical to the single-replica baseline (routing is
placement, never semantics).  How to read those rows:
docs/ARCHITECTURE.md §9.

``--stream`` runs the STREAMING benchmark (registered as ``streaming``
→ ``BENCH_streaming.json``): time-to-first-token and
inter-token-latency percentiles from per-token ``StreamEvent``
timestamps, synchronous vs OVERLAPPED decode (``overlap=True``:
readback deferred one step, docs/STREAMING.md), swept over the family
matrix on the virtual clock — a sync tick costs ``decode + host``
(the device step, then the blocking readback + sampling), an overlap
tick ``max(decode, host)`` (the host settles step i-1 while step i
computes) — plus a WALL-CLOCK section on the dense flagship that
serves the same saturated workload on real time and reports the
observed ITL ratio next to the cost model's prediction, validating
the virtual model against real dispatch overlap.  Tokens must stay
bit-identical between the modes in every row (asserted, and again by
the family-parity ``streaming`` column).  How to read those rows:
docs/STREAMING.md.
"""

from __future__ import annotations

import sys
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.apps import build_fc_stack
from repro.apps.models import representative_dataset
from repro.core import (AllOpsResolver, InterpreterPool, MicroModel,
                        RaggedInterpreterPool, export)
from repro.serving import MultiTenantHost, get_policy

from .common import print_table, save_result, time_call

SEED = 0
LANES = 16
N_REQUESTS = 160
OCCUPANCIES = (0.25, 0.5, 0.75, 0.9)
FRAME_LO, FRAME_HI = 1, 6          # frames per request, inclusive
SLO_FACTOR = 4.0                   # deadline = arrival + frames*D*factor
IN_SHAPE = (1, 64)                 # fc_stack input

# --preempt section: heavy-tail mix over few lanes (monopolization)
PREEMPT_LANES = 4
PREEMPT_N = 120
MONO_FRAC = 0.25                   # 6-frame best-effort monopolizers
PREEMPT_OCC = 0.75
TIGHT_SLO_TICKS = 3.0              # deadline-class: arrival + 3 ticks


class VirtualClock:
    """The benchmark's µs clock: a mutable ``now_us`` the simulation
    advances by one measured dispatch cost per tick.  Passed as the
    host's ``clock`` so admission policies (EDF deadlines, aging) run
    on simulated time — deterministic latency accounting over real
    dispatches."""

    def __init__(self) -> None:
        self.now_us = 0.0

    def __call__(self) -> int:
        return int(self.now_us)


def _build_model() -> MicroModel:
    gb = build_fc_stack()
    return MicroModel(export(
        gb, representative_dataset=representative_dataset(gb),
        quantize_int8=True))


def _workload(rng: np.random.Generator, n: int, lanes: int,
              occupancy: float, dispatch_us: float) -> Dict[str, np.ndarray]:
    """Poisson arrivals sized so offered load = ``occupancy`` of the
    pool's service capacity, ragged frame counts, per-request inputs
    and deadlines.  Deterministic for a given seed."""
    frames = rng.integers(FRAME_LO, FRAME_HI + 1, n)
    mean_frames = (FRAME_LO + FRAME_HI) / 2
    rate = occupancy * lanes / (mean_frames * dispatch_us)  # req per µs
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
    deadlines = arrivals + frames * dispatch_us * SLO_FACTOR
    inputs = [[rng.normal(0, 1, IN_SHAPE).astype(np.float32)
               for _ in range(k)] for k in frames]
    return {"frames": frames, "arrivals": arrivals,
            "deadlines": deadlines, "inputs": inputs}


# ---------------------------------------------------------------------------
# the three disciplines (identical workload in, completion times out)
# ---------------------------------------------------------------------------

def _sim_ragged(model, resolver, wl, lanes: int, dispatch_us: float,
                policy_name: str) -> np.ndarray:
    """Drive the REAL MultiTenantHost micro scheduler tick by tick on
    the virtual clock; returns per-request completion times (µs)."""
    clock = VirtualClock()
    host = MultiTenantHost(arena_bytes=64 << 20,
                           policy=get_policy(policy_name), clock=clock)
    host.add_ragged_micro("m", model, resolver, lanes=lanes)
    n = len(wl["arrivals"])
    done_at = np.full(n, np.nan)
    nxt = 0
    while True:
        while nxt < n and wl["arrivals"][nxt] <= clock.now_us:
            host.submit_micro(
                "m", nxt, [[x] for x in wl["inputs"][nxt]],
                deadline_us=int(wl["deadlines"][nxt]),
                arrival_us=int(wl["arrivals"][nxt]))
            nxt += 1
        if not host._micro_pending():
            if nxt >= n:
                break
            clock.now_us = wl["arrivals"][nxt]   # idle: jump to arrival
            continue
        host.micro_step()
        clock.now_us += dispatch_us
        for uid, res in host.micro_results["m"].items():
            if res.done and np.isnan(done_at[uid]):
                done_at[uid] = clock.now_us
    return done_at


def _sim_lockstep(model, resolver, wl, lanes: int,
                  dispatch_us: float) -> np.ndarray:
    """FIFO lockstep baseline: admit up to ``lanes`` queued requests,
    run the whole wave to the longest request's frame count (idle lanes
    re-dispatch — a lockstep pool cannot retire them), then admit the
    next wave.  A request completes when its own last frame runs; the
    *wave* still blocks admission until the longest one finishes."""
    pool = InterpreterPool(model, resolver, batch=lanes)
    n = len(wl["arrivals"])
    done_at = np.full(n, np.nan)
    queue: List[int] = []
    t, nxt = 0.0, 0
    while nxt < n or queue:
        while nxt < n and wl["arrivals"][nxt] <= t:
            queue.append(nxt)
            nxt += 1
        if not queue:
            t = wl["arrivals"][nxt]
            continue
        chunk = queue[:lanes]
        del queue[:lanes]
        wave = int(max(wl["frames"][u] for u in chunk))
        pool.reset_variable_tensors()
        for step in range(wave):
            pool.clear_inputs()
            for lane, uid in enumerate(chunk):
                k = min(step, wl["frames"][uid] - 1)
                pool.set_input(lane, 0, wl["inputs"][uid][k])
            pool.invoke()                       # real dispatch
            for uid in chunk:
                if wl["frames"][uid] == step + 1:
                    done_at[uid] = t + (step + 1) * dispatch_us
        t += wave * dispatch_us
    return done_at


def _measure_dispatch_us(model, resolver, lanes: int,
                         rng: np.random.Generator) -> Dict[str, float]:
    """Warm median cost of one dispatch for each discipline — the
    virtual clock's tick lengths."""
    xs = [rng.normal(0, 1, IN_SHAPE).astype(np.float32)
          for _ in range(lanes)]
    lock = InterpreterPool(model, resolver, batch=lanes)

    def lock_wave():
        lock.clear_inputs()
        for lane in range(lanes):
            lock.set_input(lane, 0, xs[lane])
        lock.invoke()
        lock.outputs(0)

    ragged = RaggedInterpreterPool()
    ragged.add_bucket("m", model, resolver, lanes=lanes)
    slots = [ragged.admit("m") for _ in range(max(1, lanes // 2))]

    def ragged_wave():
        for i, slot in enumerate(slots):
            ragged.set_input("m", slot, 0, xs[i])
        ragged.dispatch()
        ragged.outputs("m", 0)

    return {"lockstep": time_call(lock_wave, iters=20) * 1e6,
            "ragged": time_call(ragged_wave, iters=20) * 1e6}


def _latency_row(mode: str, lanes: int, occ: float, wl,
                 done_at: np.ndarray, dispatch_us: float) -> Dict:
    lat = done_at - wl["arrivals"]
    assert not np.isnan(lat).any(), f"{mode}: unfinished requests"
    slo = float((done_at <= wl["deadlines"]).mean())
    p50, p95, p99 = np.percentile(lat, (50, 95, 99))
    return {
        "mode": mode,
        "lanes": lanes,
        "occupancy_pct": int(round(100 * occ)),
        "n_requests": len(lat),
        "dispatch_us": round(dispatch_us, 1),
        "p50_us": round(float(p50), 1),
        "p95_us": round(float(p95), 1),
        "p99_us": round(float(p99), 1),
        "slo_attainment_pct": round(100 * slo, 1),
    }


# ---------------------------------------------------------------------------
# section 2: bucketed-prefill compile counts (the other half of PR 3)
# ---------------------------------------------------------------------------

def bench_prefill_buckets(lengths: Sequence[int] = (5, 7, 9, 12, 16, 17)
                          ) -> List[Dict]:
    """Mixed prompt lengths through a reduced dense ServingEngine:
    prefill compile count and total prefill seconds, exact-length vs
    bucketed (outputs are bit-identical — tests/test_scheduling.py)."""
    import jax

    from repro.configs import get_config
    from repro.models import get_model
    from repro.serving import Request, ServingEngine

    cfg = get_config("qwen3-32b", reduced=True)
    m = get_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(SEED)
    prompts = [rng.integers(0, cfg.vocab - 2, L).astype(np.int32)
               for L in lengths]
    rows = []
    for mode, buckets in (("exact", False), ("bucketed", None)):
        eng = ServingEngine(m, params, max_slots=2, cache_len=64,
                            prefill_buckets=buckets)
        for uid, toks in enumerate(prompts):
            eng.submit(Request(uid=uid, tokens=toks, max_new_tokens=2))
        eng.run()
        prefill_s = sum(r.prefill_s for r in eng.results.values())
        rows.append({
            "mode": f"prefill_{mode}",
            "prompt_lengths": len(prompts),
            "prefill_compiles": eng.prefill_compiles(),
            "buckets_hit": (len(eng.bucket_table.buckets())
                            if eng.bucket_table else 0),
            "total_prefill_s": round(prefill_s, 3),
        })
    return rows


# ---------------------------------------------------------------------------
# section 3 (--preempt): preemptible lanes under a heavy-tail mix
# ---------------------------------------------------------------------------

def _heavy_tail_workload(rng: np.random.Generator, n: int, lanes: int,
                         occupancy: float, dispatch_us: float) -> Dict:
    """The monopolizer mix: mostly 1-frame requests with a TIGHT
    deadline (arrival + TIGHT_SLO_TICKS dispatches), a MONO_FRAC tail
    of 6-frame best-effort streams (no deadline) that hold a lane for
    6 ticks unless preempted."""
    mono = rng.random(n) < MONO_FRAC
    frames = np.where(mono, FRAME_HI, 1)
    rate = occupancy * lanes / (float(frames.mean()) * dispatch_us)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, n))
    deadlines = np.where(
        mono, np.inf, arrivals + TIGHT_SLO_TICKS * dispatch_us)
    inputs = [[rng.normal(0, 1, IN_SHAPE).astype(np.float32)
               for _ in range(k)] for k in frames]
    return {"mono": mono, "frames": frames, "arrivals": arrivals,
            "deadlines": deadlines, "inputs": inputs}


def _sim_preempt(model, resolver, wl, lanes: int, dispatch_us: float,
                 preempt: Optional[str]) -> Dict[str, np.ndarray]:
    """Same tick loop as ``_sim_ragged`` with an optional preemption
    policy on the host; also returns per-request preemption counts."""
    clock = VirtualClock()
    host = MultiTenantHost(arena_bytes=64 << 20,
                           policy=get_policy("edf"), preempt=preempt,
                           clock=clock)
    host.add_ragged_micro("m", model, resolver, lanes=lanes,
                          bucket_lanes=False)
    n = len(wl["arrivals"])
    done_at = np.full(n, np.nan)
    nxt = 0
    while True:
        while nxt < n and wl["arrivals"][nxt] <= clock.now_us:
            d = wl["deadlines"][nxt]
            host.submit_micro(
                "m", nxt, [[x] for x in wl["inputs"][nxt]],
                deadline_us=None if np.isinf(d) else int(d),
                arrival_us=int(wl["arrivals"][nxt]))
            nxt += 1
        if not host._micro_pending():
            if nxt >= n:
                break
            clock.now_us = wl["arrivals"][nxt]
            continue
        host.micro_step()
        clock.now_us += dispatch_us
        for uid, res in host.micro_results["m"].items():
            if res.done and np.isnan(done_at[uid]):
                done_at[uid] = clock.now_us
    preemptions = np.array(
        [host.micro_results["m"][u].preemptions for u in range(n)])
    return {"done_at": done_at, "preemptions": preemptions}


def _preempt_row(mode: str, wl, sim: Dict, dispatch_us: float) -> Dict:
    lat = sim["done_at"] - wl["arrivals"]
    assert not np.isnan(lat).any(), f"{mode}: unfinished requests"
    dl = ~wl["mono"]                       # the deadline class
    p50, p99 = np.percentile(lat[dl], (50, 99))
    slo = float((sim["done_at"][dl] <= wl["deadlines"][dl]).mean())
    return {
        "mode": mode,
        "family": "fc-int8",       # the micro-lane rows' "model family"
        "lanes": PREEMPT_LANES,
        "n_deadline": int(dl.sum()),
        "n_monopolizers": int(wl["mono"].sum()),
        "dispatch_us": round(dispatch_us, 1),
        "deadline_p50_us": round(float(p50), 1),
        "deadline_p99_us": round(float(p99), 1),
        "deadline_slo_pct": round(100 * slo, 1),
        "mono_p99_us": round(float(np.percentile(lat[wl["mono"]], 99)),
                             1),
        "preemptions": int(sim["preemptions"].sum()),
    }


# ---------------------------------------------------------------------------
# section 4 (--preempt): pod engine, long-prompt monopolizer vs
# preemption + chunked prefill
# ---------------------------------------------------------------------------

def _engine_workload(rng: np.random.Generator, n: int, vocab: int,
                     decode_us: float, prefill_short_us: float,
                     arrival_scale: float = 1.0) -> Dict:
    """80% short deadline-class requests (5-token prompt, 4 new
    tokens), 20% long best-effort monopolizers (41-token prompt, 16 new
    tokens) whose one-shot prefill stalls every other slot.
    ``arrival_scale`` scales the mean inter-arrival gap (1.0 = the
    single-engine intensity; the replica sweep halves it so the
    offered load still exercises a multi-replica pod)."""
    mono = rng.random(n) < 0.2
    plens = np.where(mono, 41, 5)
    budgets = np.where(mono, 16, 4)
    service = prefill_short_us + 4 * decode_us   # deadline-class cost
    arrivals = np.cumsum(
        rng.exponential(arrival_scale * 3.0 * decode_us, n))
    deadlines = np.where(mono, np.inf, arrivals + 4.0 * service)
    prompts = [rng.integers(0, vocab - 2, L).astype(np.int32)
               for L in plens]
    return {"mono": mono, "prompts": prompts, "budgets": budgets,
            "arrivals": arrivals, "deadlines": deadlines}


def _autotuned_profile(bundle, params, tiny: bool):
    """The calibration profile the pod-engine sections run from: the
    on-disk cache when present (``benchmarks/results/profiles/``), else
    — on a full run only — a fresh calibration pass, persisted into the
    cache for every later run.  Tiny (CI smoke) never calibrates: a
    cache miss there just means hand defaults, keeping the smoke
    seconds-scale."""
    from repro.core import (calibrate, load_cached_profile,
                            profile_model_key, save_cached_profile)
    prof = load_cached_profile(profile_model_key(bundle.cfg, 64))
    if prof is not None or tiny:
        return prof
    # the engine workload's prompt mix: 80% short (5), 20% long (41)
    prof = calibrate(bundle, params, [5] * 8 + [41] * 2,
                     cache_len=64, seed=SEED, iters=3,
                     decode_slots=(2,), block_candidates=(8, 16, 32))
    save_cached_profile(prof)
    return prof


def _measure_engine_costs(bundle, params, chunk: int) -> Dict:
    """Warm per-dispatch costs of the engine's three step kinds —
    decode, one-shot prefill per padded length, one chunk — the
    virtual clock's tick vocabulary.  Family-generic: the cache leaf
    synced on is whatever the bundle's pytree holds (KV rings or
    recurrent state), the chunk dispatch follows the family's chunk-op
    signature, and ``chunk=0`` (moe: no chunked prefill) skips the
    chunk measurement entirely."""
    import jax
    import jax.numpy as jnp

    from repro.serving import ServingEngine

    def _sync(x):
        return jax.tree.leaves(x)[0].block_until_ready()

    eng = ServingEngine(bundle, params, max_slots=2, cache_len=64,
                        prefill_chunk=chunk or None)
    rng = np.random.default_rng(SEED)
    costs: Dict = {}
    for L in sorted({chunk or 8, 8, 64}):
        toks = jnp.asarray(rng.integers(
            0, bundle.cfg.vocab - 2, L).astype(np.int32)[None])
        costs[("prefill", L)] = time_call(
            lambda t=toks: _sync(
                eng._prefill((params, {"tokens": t}))[1]),
            warmup=1, iters=5) * 1e6
    if chunk:
        cache1 = bundle.empty_cache(1, 64, bundle.cfg.jnp_dtype())
        toks = jnp.asarray(rng.integers(
            0, bundle.cfg.vocab - 2, chunk).astype(np.int32)[None])
        if eng._recurrent_chunk:
            args = (params, cache1, toks, jnp.int32(8),
                    jnp.int32(chunk))
        else:
            args = (params, cache1, toks, jnp.int32(8))
        costs["chunk"] = time_call(
            lambda: _sync(eng._prefill_chunk(args)),
            warmup=1, iters=5) * 1e6
    cur = jnp.zeros((2, 1), jnp.int32)
    lens = jnp.asarray([8, 8], jnp.int32)
    cache2 = bundle.empty_cache(2, 64, bundle.cfg.jnp_dtype())
    costs["decode"] = time_call(
        lambda: _sync(eng._decode((params, cache2, cur, lens))[0]),
        warmup=1, iters=5) * 1e6
    return costs


def _sim_engine(bundle, params, wl, mode: str, costs: Dict,
                chunk: int, profile=None) -> np.ndarray:
    """Drive a REAL ServingEngine tick by tick on the virtual clock,
    advancing it by the measured cost of what each step actually did
    (``ServingEngine.last_step``).  Returns completion times (µs).
    The chunked mode constructs its engine through ``from_profile``
    when a calibration profile is available, so the benchmark runs the
    autotuned configuration (bucket table, solved kv_block) rather
    than hand constants."""
    from repro.serving import Request, ServingEngine

    kw: Dict = {}
    if "preempt" in mode:
        kw["preempt"] = "edf-displace"
    if "chunk" in mode:
        kw["prefill_chunk"] = chunk
    if "bucket" in mode:
        # the moe fast-path mode: capacity-stable bucketed prefill in
        # place of chunking (moe cannot chunk); its siblings run
        # exact-length so the contrast isolates bucketing
        kw["prefill_buckets"] = True
    elif bundle.cfg.family == "moe":
        kw["prefill_buckets"] = False
    clock = VirtualClock()
    if "chunk" in mode and profile is not None:
        # prefill_buckets pinned to the engine default so this mode
        # differs from its siblings only in chunking (+ the profile's
        # solved kv_block) — the bucket-table comparison has its own
        # benchmark (autotune)
        eng = ServingEngine.from_profile(
            bundle, params, profile, max_slots=2, cache_len=64,
            policy="edf", clock=clock, prefill_buckets=None, **kw)
    else:
        eng = ServingEngine(bundle, params, max_slots=2, cache_len=64,
                            policy="edf", clock=clock, **kw)
    n = len(wl["arrivals"])
    done_at = np.full(n, np.nan)
    nxt = 0
    while True:
        while nxt < n and wl["arrivals"][nxt] <= clock.now_us:
            d = wl["deadlines"][nxt]
            eng.submit(Request(
                uid=nxt, tokens=wl["prompts"][nxt],
                max_new_tokens=int(wl["budgets"][nxt]),
                deadline_us=None if np.isinf(d) else int(d),
                arrival_us=int(wl["arrivals"][nxt])))
            nxt += 1
        more = eng.step()
        ev = eng.last_step
        dt = ev["chunks"] * costs.get("chunk", 0.0)
        if ev["decoded"]:
            dt += costs["decode"]
        for L in ev["prefill_tokens"]:
            cost = costs.get(("prefill", L))
            if cost is None:               # interpolate on tokens
                cost = costs[("prefill", 64)] * (L / 64.0)
            dt += cost
        clock.now_us += max(dt, 1.0)
        for uid, res in eng.results.items():
            if res.done and np.isnan(done_at[uid]):
                done_at[uid] = clock.now_us
        if not more:
            if nxt >= n:
                break
            clock.now_us = max(clock.now_us, wl["arrivals"][nxt])
    return done_at


def _engine_row(mode: str, family: str, wl,
                done_at: np.ndarray) -> Dict:
    lat = done_at - wl["arrivals"]
    assert not np.isnan(lat).any(), f"{family}/{mode}: unfinished " \
        "requests"
    dl = ~wl["mono"]
    p50, p99 = np.percentile(lat[dl], (50, 99))
    slo = float((done_at[dl] <= wl["deadlines"][dl]).mean())
    return {
        "mode": mode,
        "family": family,
        "slots": 2,
        "n_deadline": int(dl.sum()),
        "n_monopolizers": int(wl["mono"].sum()),
        "deadline_p50_us": round(float(p50), 1),
        "deadline_p99_us": round(float(p99), 1),
        "deadline_slo_pct": round(100 * slo, 1),
        "mono_p99_us": round(float(np.percentile(lat[wl["mono"]], 99)),
                             1),
    }


def run_preempt(tiny: bool = False) -> List[Dict]:
    """The --preempt benchmark: heavy-tail micro mix (EDF vs
    EDF+preemption over checkpointable lanes) plus the pod-engine
    monopolizer (EDF vs +preemption vs +preemption+chunked prefill).
    Emits ``BENCH_preemption.json`` unless ``tiny``."""
    lanes = PREEMPT_LANES
    n = 32 if tiny else PREEMPT_N
    resolver = AllOpsResolver()
    model = _build_model()
    rng = np.random.default_rng(SEED)
    cost = _measure_dispatch_us(model, resolver, lanes, rng)

    wl = _heavy_tail_workload(np.random.default_rng(SEED + 2), n, lanes,
                              PREEMPT_OCC, cost["ragged"])
    rows: List[Dict] = []
    for mode, preempt in (("edf", None),
                          ("edf_preempt", "edf-displace")):
        sim = _sim_preempt(model, resolver, wl, lanes, cost["ragged"],
                           preempt)
        rows.append(_preempt_row(mode, wl, sim, cost["ragged"]))
    print_table("Preemptible lanes (heavy-tail mix: 1-frame deadline "
                "class + 6-frame best-effort monopolizers)", rows)

    # pod engine: long-prompt monopolizer, swept over the FULL family
    # matrix — every family whose fast paths the engine now supports
    # runs the same workload shape (family parity, PR 7).  The third
    # mode is the family's long-prompt fast path: chunked prefill for
    # chunkable families, capacity-stable bucketed prefill for moe
    # (which cannot chunk).
    import jax

    from repro.configs import get_config
    from repro.models import get_model

    erows: List[Dict] = []
    families = [("dense", "qwen3-32b"), ("ssm", "mamba2-780m"),
                ("hybrid", "zamba2-1.2b"), ("moe", "deepseek-moe-16b")]
    for family, arch in families:
        cfg = get_config(arch, reduced=True)
        bundle = get_model(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        # the calibration-profile path (cache or fresh calibration) is
        # the dense flagship's; other families run the hand default so
        # one full sweep stays minutes-scale
        prof = (_autotuned_profile(bundle, params, tiny)
                if family == "dense" else None)
        # the hand default (8) survives only as the cache-miss
        # fallback — and when the solver decided chunking off (this
        # section exists to show the long-prompt fast path, so it
        # stays on here); moe: chunk=0, its fast path is bucketing
        if family == "moe":
            chunk = 0
        else:
            chunk = (int(prof.prefill_chunk)
                     if prof is not None and prof.prefill_chunk else 8)
        costs = _measure_engine_costs(bundle, params, chunk)
        ewl = _engine_workload(
            np.random.default_rng(SEED + 3),
            (12 if family == "dense" else 8) if tiny
            else (40 if family == "dense" else 24),
            cfg.vocab, costs["decode"], costs[("prefill", 8)])
        fast = ("engine_edf_preempt_bucket" if family == "moe"
                else "engine_edf_preempt_chunk")
        for mode in ("engine_edf", "engine_edf_preempt", fast):
            done = _sim_engine(bundle, params, ewl, mode, costs, chunk,
                               profile=prof)
            erows.append(_engine_row(mode, family, ewl, done))
    print_table("Pod engine (short deadline class + long-prompt "
                "best-effort monopolizers), full family matrix", erows)

    all_rows = rows + erows
    if not tiny:
        save_result("BENCH_preemption", all_rows, seed=SEED)
    return all_rows


# ---------------------------------------------------------------------------
# section 5 (--paged): paged KV pool vs contiguous slabs at the same
# HBM budget — occupancy AND bit-identity
# ---------------------------------------------------------------------------

PAGED_CONTIG_SLOTS = 2       # the HBM budget: 2 whole cache_len slabs
PAGED_CACHE_LEN = 64
PAGED_SLOT_CAP = 8           # keep the paged decode batch modest


def run_paged(tiny: bool = False) -> List[Dict]:
    """The paged-KV occupancy benchmark: a flood of short requests
    (each needing a fraction of cache_len) served by a contiguous
    engine — admission bounded by whole-slab slots — and by a paged
    engine whose pool holds the SAME number of KV rows carved into
    blocks.  Reports peak concurrent occupancy, the HBM spent, and
    whether the decoded tokens stayed bit-identical (they must: the
    paged path is a layout change, never a semantics change).  The
    block size comes from the calibration-profile cache when one was
    solved (``profile.kv_block``), else the hand default 16.  Emits
    ``BENCH_paged_kv.json`` unless ``tiny``."""
    import jax

    from repro.configs import get_config
    from repro.core import load_cached_profile, profile_model_key
    from repro.models import get_model
    from repro.serving import Request, ServingEngine

    cfg = get_config("qwen3-32b", reduced=True)
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    cache_len = PAGED_CACHE_LEN
    prof = load_cached_profile(profile_model_key(cfg, cache_len))
    bs = (int(prof.kv_block) if prof is not None and prof.kv_block
          and cache_len % prof.kv_block == 0 else 16)
    budget_rows = PAGED_CONTIG_SLOTS * cache_len
    pool_blocks = budget_rows // bs          # same rows, one is garbage
    plen, budget = 5, 4                      # 8 KV rows per request
    blocks_per_req = -(-(plen - 1 + budget) // bs)
    paged_slots = min((pool_blocks - 1) // blocks_per_req,
                      PAGED_SLOT_CAP)
    n = 8 if tiny else 24
    rng = np.random.default_rng(SEED + 4)
    prompts = [rng.integers(0, cfg.vocab - 2, plen).astype(np.int32)
               for _ in range(n)]

    def _serve(paged: bool):
        if paged:
            eng = ServingEngine(bundle, params, max_slots=paged_slots,
                                cache_len=cache_len, policy="fifo",
                                kv_block=bs, kv_pool_blocks=pool_blocks)
        else:
            eng = ServingEngine(bundle, params,
                                max_slots=PAGED_CONTIG_SLOTS,
                                cache_len=cache_len, policy="fifo")
        for uid, toks in enumerate(prompts):
            eng.submit(Request(uid=uid, tokens=toks,
                               max_new_tokens=budget))
        peak = steps = 0
        while True:
            more = eng.step()
            steps += 1
            peak = max(peak, int(eng.active.sum()))
            if not more:
                break
        outs = [list(eng.results[u].output) for u in range(n)]
        return eng, peak, steps, outs

    ceng, cpeak, csteps, couts = _serve(paged=False)
    peng, ppeak, psteps, pouts = _serve(paged=True)
    match = pouts == couts
    assert match, "paged decode diverged from contiguous — layout " \
                  "changes must never change tokens"
    gain = round(ppeak / max(cpeak, 1), 2)
    rows = [
        {"mode": "contiguous", "hbm_bytes": int(ceng.kv_bytes),
         "kv_block": 0, "max_slots": PAGED_CONTIG_SLOTS,
         "n_requests": n, "peak_concurrent": cpeak, "steps": csteps,
         "occupancy_gain": 1.0, "tokens_match": True},
        {"mode": "paged", "hbm_bytes": int(peng.kv_bytes),
         "kv_block": bs, "max_slots": paged_slots,
         "n_requests": n, "peak_concurrent": ppeak, "steps": psteps,
         "occupancy_gain": gain, "tokens_match": bool(match)},
    ]
    print_table("Paged KV pool vs contiguous slabs "
                f"(same HBM budget: {budget_rows} KV rows)", rows)
    if not tiny:
        save_result("BENCH_paged_kv", rows, seed=SEED)
    return rows


# ---------------------------------------------------------------------------
# section 6 (--replicas): data-parallel replica routing sweep
# ---------------------------------------------------------------------------

REPLICA_COUNTS = (1, 2, 4)
REPLICA_POLICIES = ("round-robin", "least-loaded", "locality")


def _sim_replicas(bundle, params, wl, n_replicas: int, routing: str,
                  costs: Dict) -> Dict:
    """Serve the engine arrival mix through ``n_replicas`` REAL engine
    replicas behind a ``ReplicaRouter``: per tick the router routes
    arrivals, every replica advances one engine step, and the virtual
    clock moves by the MAX of the replicas' measured step costs
    (replicas run in parallel — the tick is as long as the slowest
    replica's dispatch).  The policies form a ladder: round-robin and
    least-loaded are admission-time-only placement, while locality
    additionally runs the router's stickiness-aware rebalancer each
    tick (``rebalance=True``) — work stealing that never touches
    checkpointed requests.  Returns completion times, total decoded
    tokens, makespan, and per-uid outputs for the bit-identity
    check."""
    from repro.serving import ReplicaRouter, Request, ServingEngine

    clock = VirtualClock()
    engs = [ServingEngine(bundle, params, max_slots=2, cache_len=64,
                          policy="edf", clock=clock)
            for _ in range(n_replicas)]
    router = ReplicaRouter(engs, routing=routing,
                           rebalance=(routing == "locality"))
    n = len(wl["arrivals"])
    done_at = np.full(n, np.nan)
    nxt = 0
    while True:
        while nxt < n and wl["arrivals"][nxt] <= clock.now_us:
            d = wl["deadlines"][nxt]
            router.submit(Request(
                uid=nxt, tokens=wl["prompts"][nxt],
                max_new_tokens=int(wl["budgets"][nxt]),
                deadline_us=None if np.isinf(d) else int(d),
                arrival_us=int(wl["arrivals"][nxt])))
            nxt += 1
        if router.rebalance and len(engs) > 1:
            router._rebalance()
        more = False
        dt = 0.0
        for eng in engs:
            if eng.step():
                more = True
            ev = eng.last_step
            d_r = 0.0
            if ev["decoded"]:
                d_r += costs["decode"]
            for L in ev["prefill_tokens"]:
                cost = costs.get(("prefill", L))
                if cost is None:
                    cost = costs[("prefill", 64)] * (L / 64.0)
                d_r += cost
            dt = max(dt, d_r)
        clock.now_us += max(dt, 1.0)
        for uid, res in router.results.items():
            if res.done and np.isnan(done_at[uid]):
                done_at[uid] = clock.now_us
        if not more:
            if nxt >= n:
                break
            clock.now_us = max(clock.now_us, wl["arrivals"][nxt])
    outs = {u: list(r.output) for u, r in router.results.items()}
    tokens = sum(len(o) for o in outs.values())
    return {"done_at": done_at, "outputs": outs, "tokens": tokens,
            "makespan_us": clock.now_us}


def _replica_row(replicas: int, policy: str, wl, sim: Dict,
                 base_outputs: Dict) -> Dict:
    """One sweep row.  The latency percentiles are over the DEADLINE
    class only (like BENCH_preemption's deadline_p50/p99): the
    monopolizers are best-effort and their completion latency is
    dominated by their own 16-token service time, which no routing
    policy can change — folding them in would bury the queueing delay
    routing actually controls."""
    lat = sim["done_at"] - wl["arrivals"]
    assert not np.isnan(lat).any(), \
        f"replicas={replicas}/{policy}: unfinished requests"
    dl = ~wl["mono"]
    slo = float((sim["done_at"][dl] <= wl["deadlines"][dl]).mean())
    return {
        "replicas": replicas,
        "policy": policy,
        "n_requests": len(lat),
        "throughput": round(
            sim["tokens"] / (sim["makespan_us"] * 1e-6), 1),
        "p50_us": round(float(np.percentile(lat[dl], 50)), 1),
        "p99_us": round(float(np.percentile(lat[dl], 99)), 1),
        "slo": round(100 * slo, 1),
        "tokens_match": sim["outputs"] == base_outputs,
    }


def run_replicas(tiny: bool = False) -> List[Dict]:
    """The --replicas benchmark: replica count × routing policy over
    the PR-4 engine arrival mix.  Emits ``BENCH_replica_sweep.json``
    unless ``tiny``."""
    import jax

    from repro.configs import get_config
    from repro.models import get_model

    cfg = get_config("qwen3-32b", reduced=True)
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    costs = _measure_engine_costs(bundle, params, chunk=0)
    n = 16 if tiny else 60
    # half the single-engine inter-arrival gap: the sweep provisions
    # up to 4 replicas, and an arrival process a single engine can
    # absorb leaves a 4-replica pod idle enough that every routing
    # policy looks the same — the composition (80/20 mix, budgets,
    # deadlines) is untouched
    wl = _engine_workload(np.random.default_rng(SEED + 5), n,
                          cfg.vocab, costs["decode"],
                          costs[("prefill", 8)], arrival_scale=0.5)
    counts = (1, 2) if tiny else REPLICA_COUNTS
    # the single-replica round-robin run IS the exact baseline every
    # other config's tokens are checked against
    base = _sim_replicas(bundle, params, wl, 1, "round-robin", costs)
    rows = [_replica_row(1, "round-robin", wl, base, base["outputs"])]
    for r in counts:
        for policy in REPLICA_POLICIES:
            if r == 1 and policy == "round-robin":
                continue            # already the baseline row
            sim = _sim_replicas(bundle, params, wl, r, policy, costs)
            rows.append(_replica_row(r, policy, wl, sim,
                                     base["outputs"]))
    assert all(row["tokens_match"] for row in rows), \
        "routing changed decoded tokens — placement must never " \
        "change semantics"
    print_table("Replica routing sweep (PR-4 arrival mix, "
                "replicas × policy)", rows)
    if not tiny:
        save_result("BENCH_replica_sweep", rows, seed=SEED)
    return rows


# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# section 7 (--stream): TTFT/ITL percentiles, sync vs overlapped decode
# ---------------------------------------------------------------------------

STREAM_FAMILIES_SWEEP = (("dense", "qwen3-32b"), ("ssm", "mamba2-780m"),
                         ("hybrid", "zamba2-1.2b"),
                         ("moe", "deepseek-moe-16b"))
STREAM_ARRIVAL_SCALE = 0.5     # pod intensity: keeps both slots busy
WALL_STREAM_BUDGET = 24        # wall section: tokens per request


def _measure_host_us(bundle, params, eng) -> float:
    """Warm cost of the sync loop's per-tick HOST leg: the blocking
    logits readback plus greedy sampling — exactly what the overlapped
    loop hides under the next device step.  Measured on a logits
    buffer that is already device-ready so the device compute itself
    (``costs['decode']``) is not double-counted."""
    import jax
    import jax.numpy as jnp

    cur = jnp.zeros((2, 1), jnp.int32)
    lens = jnp.asarray([8, 8], jnp.int32)
    cache2 = bundle.empty_cache(2, 64, bundle.cfg.jnp_dtype())
    logits, _ = eng._decode((params, cache2, cur, lens))
    jax.block_until_ready(logits)
    return time_call(lambda: eng._sample(logits, 0.0),
                     warmup=2, iters=20) * 1e6


def _sim_stream(bundle, params, wl, overlap: bool,
                costs: Dict) -> Dict:
    """Serve ``wl`` on a REAL engine over the virtual clock with
    per-token StreamEvents collected, sync or overlapped.  The tick
    costs encode the overlap: a sync tick pays ``decode + host`` in
    sequence; an overlapped tick pays ``max(decode, host)`` because
    the host leg (previous step's readback + sampling + emission) runs
    while the device executes the dispatched step.  Returns the event
    stream, outputs, and the mean decode-tick occupancy."""
    from repro.serving import Request, ServingEngine

    events: List = []
    clock = VirtualClock()
    eng = ServingEngine(bundle, params, max_slots=2, cache_len=64,
                        policy="edf", clock=clock,
                        prefill_buckets=False, overlap=overlap,
                        on_token=events.append)
    n = len(wl["arrivals"])
    nxt = 0
    occ: List[float] = []
    while True:
        while nxt < n and wl["arrivals"][nxt] <= clock.now_us:
            d = wl["deadlines"][nxt]
            eng.submit(Request(
                uid=nxt, tokens=wl["prompts"][nxt],
                max_new_tokens=int(wl["budgets"][nxt]),
                deadline_us=None if np.isinf(d) else int(d),
                arrival_us=int(wl["arrivals"][nxt])))
            nxt += 1
        more = eng.step()
        ev = eng.last_step
        dec = costs["decode"] if ev["decoded"] else 0.0
        host = costs["host"] if ev["processed"] else 0.0
        dt = max(dec, host) if overlap else dec + host
        for L in ev["prefill_tokens"]:
            cost = costs.get(("prefill", L))
            if cost is None:
                cost = costs[("prefill", 64)] * (L / 64.0)
            dt += cost
        clock.now_us += max(dt, 1.0)
        if ev["decoded"]:
            occ.append(float(eng.active.sum() + len(eng._chunking))
                       / eng.max_slots)
        if not more:
            if nxt >= n:
                break
            clock.now_us = max(clock.now_us, wl["arrivals"][nxt])
    outs = [list(eng.results[u].output) for u in range(n)]
    return {"events": events, "outs": outs,
            "occupancy": float(np.mean(occ)) if occ else 0.0}


def _stream_metrics(events, arrivals) -> Dict:
    """TTFT (first event stamp − arrival) and ITL (gaps between a
    request's consecutive event stamps) percentiles from one event
    stream."""
    per: Dict[int, List] = {}
    for e in events:
        per.setdefault(e.uid, []).append(e)
    ttft = [seq[0].t_us - arrivals[uid] for uid, seq in per.items()]
    itl = [b.t_us - a.t_us for seq in per.values()
           for a, b in zip(seq, seq[1:])]
    t50, t95 = np.percentile(ttft, (50, 95))
    i50, i95 = np.percentile(itl, (50, 95)) if itl else (0.0, 0.0)
    return {"ttft_p50_us": round(float(t50), 1),
            "ttft_p95_us": round(float(t95), 1),
            "itl_p50_us": round(float(i50), 1),
            "itl_p95_us": round(float(i95), 1)}


def _wall_stream(bundle, params, overlap: bool, n: int) -> Dict:
    """The wall-clock leg: the same saturated decode workload served
    on REAL time (the engine's default µs clock), events stamped as
    the host learns each token.  A warmup request is served first so
    compile time never pollutes the percentiles; occupancy is sampled
    per step like the virtual leg."""
    from repro.serving import Request, ServingEngine

    events: List = []
    eng = ServingEngine(bundle, params, max_slots=2, cache_len=64,
                        prefill_buckets=False, overlap=overlap,
                        on_token=events.append)
    rng = np.random.default_rng(SEED + 5)
    prompts = [rng.integers(0, bundle.cfg.vocab - 2, 5).astype(np.int32)
               for _ in range(n)]
    eng.submit(Request(uid=10_000, tokens=prompts[0].copy(),
                       max_new_tokens=4))
    eng.run()
    events.clear()                      # warmup over: compiles are paid
    arr = {}
    for uid, toks in enumerate(prompts):
        req = Request(uid=uid, tokens=toks,
                      max_new_tokens=WALL_STREAM_BUDGET)
        eng.submit(req)                 # arrival stamped at submit
        arr[uid] = req.arrival_us
    occ: List[float] = []
    while eng.step():
        if eng.last_step["decoded"]:
            occ.append(float(eng.active.sum()) / eng.max_slots)
    eng.drain()
    outs = [list(eng.results[u].output) for u in range(n)]
    return {"events": [e for e in events if e.uid < 10_000],
            "outs": outs, "arrivals": arr,
            "occupancy": float(np.mean(occ)) if occ else 0.0}


def run_stream(tiny: bool = False) -> List[Dict]:
    """The --stream benchmark: TTFT/ITL percentiles from per-token
    StreamEvents, sync vs overlapped decode over the family matrix on
    the virtual clock, plus the dense wall-clock validation leg.
    Tokens must be bit-identical between modes in every comparison.
    Emits ``BENCH_streaming.json`` unless ``tiny``."""
    import jax

    from repro.configs import get_config
    from repro.models import get_model

    rows: List[Dict] = []
    for family, arch in STREAM_FAMILIES_SWEEP:
        cfg = get_config(arch, reduced=True)
        bundle = get_model(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        costs = _measure_engine_costs(bundle, params, 0)
        from repro.serving import ServingEngine
        probe = ServingEngine(bundle, params, max_slots=2,
                              cache_len=64, prefill_buckets=False)
        costs["host"] = _measure_host_us(bundle, params, probe)
        wl = _engine_workload(
            np.random.default_rng(SEED + 6), 8 if tiny else 32,
            cfg.vocab, costs["decode"], costs[("prefill", 8)],
            arrival_scale=STREAM_ARRIVAL_SCALE)
        sims = {m: _sim_stream(bundle, params, wl, m == "overlap",
                               costs)
                for m in ("sync", "overlap")}
        match = sims["sync"]["outs"] == sims["overlap"]["outs"]
        assert match, f"{family}: overlapped decode changed tokens"
        for mode, sim in sims.items():
            rows.append({
                "family": family, "mode": mode, "clock": "virtual",
                "n_requests": len(wl["arrivals"]),
                "occupancy_pct": round(100 * sim["occupancy"], 1),
                "decode_us": round(costs["decode"], 1),
                "host_us": round(costs["host"], 1),
                **_stream_metrics(sim["events"], wl["arrivals"]),
                "tokens_match": bool(match)})
    print_table("Streaming TTFT/ITL, sync vs overlapped decode "
                "(virtual clock, family matrix)", rows)

    # wall-clock validation: dense flagship, saturated slots, real time
    cfg = get_config("qwen3-32b", reduced=True)
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    costs = _measure_engine_costs(bundle, params, 0)
    from repro.serving import ServingEngine
    probe = ServingEngine(bundle, params, max_slots=2, cache_len=64,
                          prefill_buckets=False)
    costs["host"] = _measure_host_us(bundle, params, probe)
    predicted = (costs["decode"] + costs["host"]) \
        / max(costs["decode"], costs["host"])
    n = 4 if tiny else 8
    walls = {m: _wall_stream(bundle, params, m == "overlap", n)
             for m in ("sync", "overlap")}
    match = walls["sync"]["outs"] == walls["overlap"]["outs"]
    assert match, "wall-clock overlapped decode changed tokens"
    wmet = {m: _stream_metrics(w["events"], w["arrivals"])
            for m, w in walls.items()}
    observed = wmet["sync"]["itl_p50_us"] \
        / max(wmet["overlap"]["itl_p50_us"], 1e-9)
    wrows = []
    for mode, w in walls.items():
        wrows.append({
            "family": "dense", "mode": mode, "clock": "wall",
            "n_requests": n,
            "occupancy_pct": round(100 * w["occupancy"], 1),
            "decode_us": round(costs["decode"], 1),
            "host_us": round(costs["host"], 1),
            **wmet[mode],
            "predicted_itl_ratio": round(float(predicted), 3),
            "observed_itl_ratio": round(float(observed), 3),
            "tokens_match": bool(match)})
    print_table("Wall-clock validation (dense, saturated slots): "
                f"cost model predicts sync/overlap ITL "
                f"{predicted:.3f}x", wrows)
    all_rows = rows + wrows
    if not tiny:
        save_result("BENCH_streaming", all_rows, seed=SEED)
    return all_rows


def run(tiny: bool = False) -> List[Dict]:
    lanes = 4 if tiny else LANES
    n = 24 if tiny else N_REQUESTS
    occupancies = (0.5,) if tiny else OCCUPANCIES
    resolver = AllOpsResolver()
    model = _build_model()
    rng = np.random.default_rng(SEED)
    cost = _measure_dispatch_us(model, resolver, lanes, rng)

    rows: List[Dict] = []
    for occ in occupancies:
        wl = _workload(np.random.default_rng(SEED + 1), n, lanes, occ,
                       cost["ragged"])
        done = _sim_lockstep(model, resolver, wl, lanes,
                             cost["lockstep"])
        rows.append(_latency_row("lockstep_fifo", lanes, occ, wl, done,
                                 cost["lockstep"]))
        for policy in ("fifo", "edf"):
            done = _sim_ragged(model, resolver, wl, lanes,
                               cost["ragged"], policy)
            rows.append(_latency_row(f"ragged_{policy}", lanes, occ, wl,
                                     done, cost["ragged"]))
    print_table("Arrival-process completion latency "
                "(Poisson arrivals, ragged 1..6-frame requests)", rows)

    prefill_rows = bench_prefill_buckets(
        lengths=(5, 7, 9) if tiny else (5, 7, 9, 12, 16, 17))
    print_table("Bucketed prefill (mixed prompt lengths, one engine)",
                prefill_rows)
    all_rows = rows + prefill_rows
    if not tiny:
        save_result("BENCH_arrival_process", all_rows, seed=SEED)
    return all_rows


if __name__ == "__main__":
    if "--preempt" in sys.argv[1:]:
        run_preempt(tiny="--tiny" in sys.argv[1:])
    elif "--paged" in sys.argv[1:]:
        run_paged(tiny="--tiny" in sys.argv[1:])
    elif "--replicas" in sys.argv[1:]:
        run_replicas(tiny="--tiny" in sys.argv[1:])
    elif "--stream" in sys.argv[1:]:
        run_stream(tiny="--tiny" in sys.argv[1:])
    else:
        run(tiny="--tiny" in sys.argv[1:])
