"""Figure 6 reproduction: interpreter overhead = total time − pure
calculation time.

The paper measures Total Cycles vs Calculation Cycles on Cortex-M4 /
HiFi Mini; here "calculation" is the identical math executed as one
fused jit function built directly from the graph (no interpreter
dispatch, no arena bookkeeping), and "total" is MicroInterpreter.invoke.
The paper's claim to reproduce: overhead <0.1% for conv-heavy models
(VWW), low single-digit % for tiny models (Hotword).
"""

from __future__ import annotations

import numpy as np

from repro.apps import build_conv_reference, build_hotword, build_vww
from repro.apps.models import representative_dataset
from repro.core import (AllOpsResolver, InterpreterPool, MicroInterpreter,
                        MicroModel, export)

from .common import print_table, save_result, time_call


def _fused_fn(model, resolver):
    """The same graph as one pure-dataflow jit'd function — the
    'calculation only' baseline (what generated code would execute:
    no arena slicing, no interpreter structure, just the op math)."""
    import jax
    import jax.numpy as jnp
    from repro.core.interpreter import EvalContext, PrepareContext, \
        MicroInterpreter

    # borrow the interpreter's prepare pass to get op_data, then drop it
    size = MicroInterpreter.required_arena_size(model, resolver)
    it = MicroInterpreter(model, resolver, size)
    plans = it._op_plans
    consts = {t: jnp.asarray(v) for t, v in it._const_map.items()} \
        if hasattr(it, "_const_map") else None

    def run(*xs):
        env = {}
        for pos, tid in enumerate(model.inputs):
            env[tid] = xs[pos]
        var_env = {t: jnp.zeros(model.tensors[t].shape,
                                jnp.float32)
                   for t in it._var_pos}
        for opp in plans:
            op = opp.op
            vals = []
            for t in op.inputs:
                if t < 0:
                    vals.append(None)
                elif t in it._const_pos:
                    vals.append(it._consts[it._const_pos[t]])
                elif t in var_env and t not in env:
                    vals.append(var_env[t])
                else:
                    vals.append(env[t])
            outs = opp.registration.eval(opp.eval_ctx, op, vals)
            for t, v in zip(op.outputs, outs[:len(op.outputs)]):
                env[t] = v
            for t, v in zip(opp.prep.variable_updates,
                            outs[len(op.outputs):]):
                var_env[t] = v
        return tuple(env[t] for t in model.outputs)

    from repro.core import quantize as Q

    def wrapped(*xs):
        with Q.x64_scope():
            return jax.jit(run)(*xs)
    return wrapped


def bench_model(name: str, gb, quantize: bool) -> dict:
    resolver = AllOpsResolver()
    kwargs = {}
    if quantize:
        kwargs = dict(representative_dataset=representative_dataset(gb),
                      quantize_int8=True)
    model = MicroModel(export(gb, **kwargs))
    size = MicroInterpreter.required_arena_size(model, resolver)
    interp = MicroInterpreter(model, resolver, size)

    rng = np.random.default_rng(0)
    xs = [rng.normal(0, 1, gb.tensors[t].shape).astype(np.float32)
          for t in gb.inputs]

    def total():
        for i, x in enumerate(xs):
            interp.set_input(i, x)
        interp.invoke()
        interp.output(0)

    fused = _fused_fn(model, resolver)
    import jax
    jxs = [np.asarray(x) for x in xs]

    def calc():
        jax.block_until_ready(fused(*jxs))

    t_total = time_call(total, iters=20)
    t_calc = time_call(calc, iters=20)
    overhead = max(t_total - t_calc, 0.0)
    return {
        "model": name + (" int8" if quantize else " float"),
        "total_us": round(t_total * 1e6, 1),
        "calc_us": round(t_calc * 1e6, 1),
        "overhead_pct": round(100 * overhead / t_total, 2),
    }


def bench_batched(name: str, gb, quantize: bool,
                  batches=(1, 4, 16)) -> list:
    """Batched-invoke throughput sweep: per-request dispatch time of ONE
    vmapped dispatch advancing B lanes vs B sequential single invokes.
    The interpreter's per-invoke cost is dominated by host dispatch for
    tiny models — exactly what the batch axis amortizes."""
    resolver = AllOpsResolver()
    kwargs = {}
    if quantize:
        kwargs = dict(representative_dataset=representative_dataset(gb),
                      quantize_int8=True)
    model = MicroModel(export(gb, **kwargs))
    size = MicroInterpreter.required_arena_size(model, resolver)
    interp = MicroInterpreter(model, resolver, size)

    rng = np.random.default_rng(0)
    max_b = max(batches)
    xs = [[rng.normal(0, 1, gb.tensors[t].shape).astype(np.float32)
           for t in gb.inputs] for _ in range(max_b)]

    def sequential_one():
        for pos, x in enumerate(xs[0]):
            interp.set_input(pos, x)
        interp.invoke()
        interp.output(0)

    t_seq = time_call(sequential_one, iters=20)

    rows = []
    for b in batches:
        pool = InterpreterPool(model, resolver, batch=b)

        def batched():
            for lane in range(b):
                for pos, x in enumerate(xs[lane]):
                    pool.set_input(lane, pos, x)
            pool.invoke()
            pool.outputs(0)

        t_b = time_call(batched, iters=20)
        per_req = t_b / b
        rows.append({
            "model": name + (" int8" if quantize else " float"),
            "batch": b,
            "us_per_req_batched": round(per_req * 1e6, 1),
            "us_per_req_sequential": round(t_seq * 1e6, 1),
            "speedup": round(t_seq / per_req, 2),
        })
    return rows


def run_batched() -> list:
    rows = []
    for name, builder, quants in (
            ("conv_reference", build_conv_reference, (False, True)),
            ("hotword", build_hotword, (False,))):   # SVDF: float only
        for quantize in quants:
            rows.extend(bench_batched(name, builder(), quantize))
    print_table("Batched invoke throughput (B-lane vmapped dispatch)",
                rows)
    save_result("BENCH_batched_invoke", rows, seed=0)
    return rows


def run() -> list:
    rows = []
    for name, builder, quants in (
            ("conv_reference", build_conv_reference, (False, True)),
            ("hotword", build_hotword, (False,)),   # SVDF: float only
            ("vww", build_vww, (False, True))):
        for quantize in quants:
            rows.append(bench_model(name, builder(), quantize))
    print_table("Interpreter overhead (Fig. 6 analogue)", rows)
    save_result("interpreter_overhead", rows, seed=0)
    return rows


if __name__ == "__main__":
    run()
    run_batched()
