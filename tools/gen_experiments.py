"""Regenerate the §Dry-run and §Roofline markdown tables in
EXPERIMENTS.md from benchmarks/results/dryrun/*.json.

Usage: PYTHONPATH=src python tools/gen_experiments.py   (prints tables)
"""

import glob
import json
import os

DRY = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                   "results", "dryrun")


def rows(mesh):
    out = []
    for p in sorted(glob.glob(os.path.join(DRY, "*.json"))):
        if "__iter" in p:
            continue
        d = json.load(open(p))
        if d["mesh"] != mesh:
            continue
        out.append(d)
    return out


def fmt(x, digits=3):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x:.2e}"
    return f"{x:.{digits}g}"


def dryrun_table(mesh):
    print(f"\n### Mesh {mesh}\n")
    print("| arch | shape | mode | compile_s | args_MiB/dev | "
          "temp_GiB/dev | flops/dev | coll_GB/dev | top collective |")
    print("|---|---|---|---|---|---|---|---|---|")
    for d in rows(mesh):
        c = d["collectives"]
        top = max(c["bytes"], key=lambda k: c["bytes"][k])
        tops = f"{top} ({c['bytes'][top] / 1e9:.2f} GB x" \
               f"{int(c['counts'][top])})" if c["bytes"][top] else "-"
        print(f"| {d['arch']} | {d['shape']} | {d['mode']} "
              f"| {d['compile_s']} "
              f"| {d['memory']['argument_bytes'] / 2**20:.0f} "
              f"| {d['memory']['temp_bytes'] / 2**30:.1f} "
              f"| {fmt(d['cost']['flops_per_device'])} "
              f"| {d['collectives']['total_bytes_per_device'] / 1e9:.2f} "
              f"| {tops} |")


def roofline_table(mesh):
    print(f"\n### Roofline, mesh {mesh} (seconds per step, per device)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | "
          "dominant | MODEL_FLOPS | useful_frac |")
    print("|---|---|---|---|---|---|---|---|")
    for d in rows(mesh):
        r = d["roofline"]
        print(f"| {d['arch']} | {d['shape']} | {fmt(r['compute_s'])} "
              f"| {fmt(r['memory_s'])} | {fmt(r['collective_s'])} "
              f"| **{r['dominant'].replace('_s', '')}** "
              f"| {r['model_flops']:.3g} "
              f"| {r['useful_flops_fraction']:.3f} |")


if __name__ == "__main__":
    print("## §Dry-run")
    dryrun_table("16x16")
    dryrun_table("2x16x16")
    print("\n## §Roofline")
    roofline_table("16x16")
