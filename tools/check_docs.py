"""Docs lint: every public class (and module) in ``repro.core``,
``repro.serving`` (including the scheduling policies),
``benchmarks/``, and ``tools/`` must carry a docstring, and every
benchmark artifact the docs mention must exist.

The architecture and scheduling guides (docs/ARCHITECTURE.md,
docs/SCHEDULING.md) point readers at defining classes and at committed
``BENCH_*.json`` result files; this check keeps both kinds of pointer
from rotting.  It is pure-AST / pure-filesystem — nothing is imported —
so it is safe to run anywhere, and it is wired into the test suite
(tests/test_docs_lint.py) so a violation fails CI.

Checks:

  1. **docstrings** — each module and each public module-level class in
     the linted packages carries a docstring.  A class is *public* when
     its name does not start with an underscore; classes nested inside
     functions (test fixtures, closures) are exempt.  For the files in
     ``METHOD_LINTED`` (the scheduling policy vocabulary) the contract
     is stricter: every public METHOD of a public class must carry a
     docstring too — a policy's ``key``/``victim`` semantics ARE its
     documentation, so a silent method there is a rotted guide.
  2. **benchmark references** — every ``BENCH_<name>.json`` mentioned
     in the *living* documents — ``README.md``, ``ROADMAP.md``, and
     ``docs/*.md`` — exists under ``benchmarks/results/`` (so the
     numbers a guide cites are actually committed next to it).
     ``CHANGES.md`` is exempt: it is an append-only history whose old
     entries may legitimately name retired artifacts.
  3. **no orphaned guides** — every document under ``docs/`` is
     mentioned (by name) from ``README.md`` or ``docs/ARCHITECTURE.md``,
     so a guide cannot silently fall out of the reading path.

Usage::

    python tools/check_docs.py            # lint, exit 1 on violations
    python tools/check_docs.py --list     # print the files scanned
"""

from __future__ import annotations

import ast
import re
import sys
from pathlib import Path
from typing import Iterator, List, Tuple

REPO_ROOT = Path(__file__).resolve().parent.parent
LINTED_PACKAGES = ("src/repro/core", "src/repro/serving", "benchmarks",
                   "tools")
# files whose public-class METHODS must also carry docstrings (the
# scheduling/preemption policy vocabulary — key()/victim() semantics)
METHOD_LINTED = ("src/repro/serving/scheduling.py",)
RESULTS_DIR = "benchmarks/results"
BENCH_REF = re.compile(r"\bBENCH_[A-Za-z0-9_]+\.json\b")
# documents every guide must be reachable from (by name mention)
DOC_ROOTS = ("README.md", "docs/ARCHITECTURE.md")


def linted_files(root: Path = REPO_ROOT) -> List[Path]:
    """The Python files the docs contract covers, sorted for stable
    output."""
    files: List[Path] = []
    for pkg in LINTED_PACKAGES:
        files.extend(sorted((root / pkg).glob("*.py")))
    return files


def doc_files(root: Path = REPO_ROOT) -> List[Path]:
    """The markdown files whose BENCH_*.json references are checked."""
    files = [p for p in (root / "docs").glob("*.md")]
    for name in ("README.md", "ROADMAP.md"):
        p = root / name
        if p.is_file():
            files.append(p)
    return sorted(files)


def _module_level_classes(tree: ast.Module) -> Iterator[ast.ClassDef]:
    for node in tree.body:
        if isinstance(node, ast.ClassDef):
            yield node


def check_file(path: Path, root: Path = REPO_ROOT) -> List[Tuple[str, int, str]]:
    """Docstring violations in one file as (relative_path, lineno,
    message)."""
    rel = str(path.relative_to(root))
    tree = ast.parse(path.read_text(), filename=rel)
    out: List[Tuple[str, int, str]] = []
    if ast.get_docstring(tree) is None:
        out.append((rel, 1, "module lacks a docstring"))
    lint_methods = rel.replace("\\", "/") in METHOD_LINTED
    for node in _module_level_classes(tree):
        if node.name.startswith("_"):
            continue
        if ast.get_docstring(node) is None:
            out.append((rel, node.lineno,
                        f"public class {node.name} lacks a docstring"))
        if not lint_methods:
            continue
        for sub in node.body:
            if not isinstance(sub, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)):
                continue
            if sub.name.startswith("_"):
                continue
            if ast.get_docstring(sub) is None:
                out.append((rel, sub.lineno,
                            f"public method {node.name}.{sub.name} "
                            f"lacks a docstring"))
    return out


def check_bench_references(root: Path = REPO_ROOT
                           ) -> List[Tuple[str, int, str]]:
    """Violations for BENCH_*.json files mentioned in docs but missing
    from benchmarks/results/."""
    out: List[Tuple[str, int, str]] = []
    results = root / RESULTS_DIR
    for doc in doc_files(root):
        rel = str(doc.relative_to(root))
        for lineno, line in enumerate(doc.read_text().splitlines(), 1):
            for name in BENCH_REF.findall(line):
                if not (results / name).is_file():
                    out.append((rel, lineno,
                                f"mentions {name} but "
                                f"{RESULTS_DIR}/{name} does not exist"))
    return out


def check_orphaned_docs(root: Path = REPO_ROOT
                        ) -> List[Tuple[str, int, str]]:
    """Violations for guides under ``docs/`` that no DOC_ROOT document
    mentions — an unreachable guide is a rotting guide."""
    reachable_text = ""
    for name in DOC_ROOTS:
        p = root / name
        if p.is_file():
            reachable_text += p.read_text()
    out: List[Tuple[str, int, str]] = []
    for doc in sorted((root / "docs").glob("*.md")):
        rel = str(doc.relative_to(root))
        if rel.replace("\\", "/") in DOC_ROOTS:
            continue                        # a root is reachable by fiat
        if doc.name not in reachable_text:
            out.append((rel, 1,
                        f"orphaned guide: {doc.name} is not linked "
                        f"from any of {DOC_ROOTS}"))
    return out


def collect_violations(root: Path = REPO_ROOT) -> List[Tuple[str, int, str]]:
    """All docstring + benchmark-reference + orphaned-guide violations."""
    out: List[Tuple[str, int, str]] = []
    for path in linted_files(root):
        out.extend(check_file(path, root))
    out.extend(check_bench_references(root))
    out.extend(check_orphaned_docs(root))
    return out


def main(argv: List[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if "--list" in argv:
        for path in linted_files() + doc_files():
            print(path.relative_to(REPO_ROOT))
        return 0
    violations = collect_violations()
    for rel, lineno, msg in violations:
        print(f"{rel}:{lineno}: {msg}")
    if violations:
        print(f"\n{len(violations)} docs violation(s); see "
              f"docs/ARCHITECTURE.md for the documentation contract")
        return 1
    print(f"docs lint OK ({len(linted_files())} source files, "
          f"{len(doc_files())} documents)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
