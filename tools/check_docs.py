"""Docs lint: every public class (and module) in ``repro.core`` and
``repro.serving`` must carry a docstring.

The architecture guide (docs/ARCHITECTURE.md) points readers at the
defining classes; this check keeps those pointers from rotting into
undocumented code.  It is pure-AST — nothing is imported — so it is
safe to run anywhere, and it is wired into the test suite
(tests/test_docs_lint.py) so a missing docstring fails CI.

Usage::

    python tools/check_docs.py            # lint, exit 1 on violations
    python tools/check_docs.py --list     # print the files scanned

A class is *public* when its name does not start with an underscore.
Nested classes inside functions (test fixtures, closures) are exempt:
only module-level classes are part of the documented surface.
"""

from __future__ import annotations

import ast
import sys
from pathlib import Path
from typing import Iterator, List, Tuple

REPO_ROOT = Path(__file__).resolve().parent.parent
LINTED_PACKAGES = ("src/repro/core", "src/repro/serving")


def linted_files(root: Path = REPO_ROOT) -> List[Path]:
    """The Python files the docs contract covers, sorted for stable
    output."""
    files: List[Path] = []
    for pkg in LINTED_PACKAGES:
        files.extend(sorted((root / pkg).glob("*.py")))
    return files


def _module_level_classes(tree: ast.Module) -> Iterator[ast.ClassDef]:
    for node in tree.body:
        if isinstance(node, ast.ClassDef):
            yield node


def check_file(path: Path, root: Path = REPO_ROOT) -> List[Tuple[str, int, str]]:
    """Violations in one file as (relative_path, lineno, message)."""
    rel = str(path.relative_to(root))
    tree = ast.parse(path.read_text(), filename=rel)
    out: List[Tuple[str, int, str]] = []
    if ast.get_docstring(tree) is None:
        out.append((rel, 1, "module lacks a docstring"))
    for node in _module_level_classes(tree):
        if node.name.startswith("_"):
            continue
        if ast.get_docstring(node) is None:
            out.append((rel, node.lineno,
                        f"public class {node.name} lacks a docstring"))
    return out


def collect_violations(root: Path = REPO_ROOT) -> List[Tuple[str, int, str]]:
    """All docstring violations under the linted packages."""
    out: List[Tuple[str, int, str]] = []
    for path in linted_files(root):
        out.extend(check_file(path, root))
    return out


def main(argv: List[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if "--list" in argv:
        for path in linted_files():
            print(path.relative_to(REPO_ROOT))
        return 0
    violations = collect_violations()
    for rel, lineno, msg in violations:
        print(f"{rel}:{lineno}: {msg}")
    if violations:
        print(f"\n{len(violations)} docstring violation(s); see "
              f"docs/ARCHITECTURE.md for the documentation contract")
        return 1
    print(f"docs lint OK ({len(linted_files())} files)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
