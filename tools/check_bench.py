"""Bench-result lint: schema-validate every committed benchmark result
file so a broken ``save_result`` (or a hand-edited artifact) can never
land silently.

Every ``benchmarks/results/BENCH_*.json`` must be the layout
``benchmarks.common.save_result`` writes:

  * a top-level object with exactly a ``meta`` block and a ``rows``
    list;
  * ``meta`` carries the uniform metadata block — ``schema`` (a
    version this linter understands), ``jax``, ``backend``, ``seed``,
    and ``created_utc`` (wall clock, informational: present but
    exempt from comparisons, per ``benchmarks.common.COMPARABLE_META``);
  * ``rows`` is a non-empty list of flat objects whose values are
    strings, booleans, null, or FINITE numbers — a NaN or Infinity
    that sneaks into a percentile is a measurement bug, and JSON
    emitters that tolerate them produce files other parsers reject.

Like ``tools/check_docs.py`` this is pure-filesystem (nothing is
imported from the package), runs from the fast test tier
(tests/test_bench_lint.py) and from CI, and exits 1 on any violation.

Usage::

    python tools/check_bench.py           # lint, exit 1 on violations
    python tools/check_bench.py --list    # print the files scanned
"""

from __future__ import annotations

import json
import math
import sys
from pathlib import Path
from typing import List, Tuple

REPO_ROOT = Path(__file__).resolve().parent.parent
RESULTS_DIR = "benchmarks/results"
# accepted layout versions (benchmarks.common.RESULT_SCHEMA values)
KNOWN_SCHEMAS = (1,)
# the uniform metadata block save_result stamps
REQUIRED_META = ("schema", "jax", "backend", "seed", "created_utc")
# per-benchmark row keys that must be present in EVERY row of that
# file — the columns a reader (or a regression gate) depends on; files
# not listed here are held only to the generic flat-scalar layout
REQUIRED_ROW_KEYS = {
    "BENCH_paged_kv.json": ("mode", "hbm_bytes", "kv_block",
                            "max_slots", "peak_concurrent",
                            "occupancy_gain", "tokens_match"),
    # family parity (PR 7): every preemption / autotune row is tagged
    # with the model family it was measured on, so readers can slice
    # the full family matrix and a family can never silently drop out
    "BENCH_preemption.json": ("mode", "family", "deadline_p50_us",
                              "deadline_p99_us", "deadline_slo_pct",
                              "mono_p99_us"),
    "BENCH_autotune.json": ("section", "mode", "family"),
    # replica routing (PR 8): every sweep row pins the replica count
    # and policy it was measured at, the latency/throughput columns
    # the regression gate reads, and the token bit-identity flag
    "BENCH_replica_sweep.json": ("replicas", "policy", "throughput",
                                 "p99_us", "slo", "tokens_match"),
    # streaming (PR 9): every row pins the family and decode mode
    # (sync vs overlap) it was measured at, the TTFT/ITL percentile
    # columns the regression gate reads, and the bit-identity flag
    # tying the overlapped stream back to the sync baseline
    "BENCH_streaming.json": ("family", "mode", "ttft_p95_us",
                             "itl_p95_us", "tokens_match"),
    # quantized serving (PR 10): every row pins the family and the
    # precision pair it was measured at, the throughput/footprint
    # columns the regression gate reads, the logit error against the
    # fp engine, and the preempt/restore self-identity flag
    "BENCH_quantized_decode.json": ("family", "weight_dtype",
                                    "kv_dtype", "tokens_per_s",
                                    "hbm_bytes", "max_abs_logit_err",
                                    "tokens_match"),
}

Violation = Tuple[str, str]


def result_files(root: Path = REPO_ROOT) -> List[Path]:
    """The committed result files the lint covers, sorted."""
    return sorted((root / RESULTS_DIR).glob("BENCH_*.json"))


def _check_scalar(key: str, value: object) -> List[str]:
    if isinstance(value, bool) or value is None:
        return []
    if isinstance(value, (int, float)):
        if not math.isfinite(value):
            return [f"row value {key!r} is non-finite ({value!r})"]
        return []
    if isinstance(value, str):
        return []
    return [f"row value {key!r} has unsupported type "
            f"{type(value).__name__} (rows must stay flat scalars)"]


def check_result(path: Path, root: Path = REPO_ROOT) -> List[Violation]:
    """All schema violations in one result file."""
    rel = str(path.relative_to(root))
    try:
        data = json.loads(path.read_text())
    except ValueError as e:
        return [(rel, f"invalid JSON: {e}")]
    out: List[Violation] = []
    if not isinstance(data, dict) or set(data) != {"meta", "rows"}:
        return [(rel, "top level must be an object with exactly "
                      "{'meta', 'rows'} (the save_result layout)")]
    meta, rows = data["meta"], data["rows"]
    if not isinstance(meta, dict):
        out.append((rel, "meta must be an object"))
    else:
        for key in REQUIRED_META:
            if key not in meta:
                out.append((rel, f"meta lacks required key {key!r}"))
        if meta.get("schema") not in KNOWN_SCHEMAS:
            out.append((rel, f"meta.schema {meta.get('schema')!r} is "
                             f"not a known layout {KNOWN_SCHEMAS}"))
        for key in ("jax", "backend"):
            if key in meta and not isinstance(meta[key], str):
                out.append((rel, f"meta.{key} must be a string"))
    if not isinstance(rows, list) or not rows:
        out.append((rel, "rows must be a non-empty list"))
        return out
    required = REQUIRED_ROW_KEYS.get(path.name, ())
    for i, row in enumerate(rows):
        if not isinstance(row, dict) or not row:
            out.append((rel, f"rows[{i}] must be a non-empty object"))
            continue
        for key in required:
            if key not in row:
                out.append((rel, f"rows[{i}] lacks required key "
                                 f"{key!r}"))
        for key, value in row.items():
            out.extend((rel, f"rows[{i}]: {msg}")
                       for msg in _check_scalar(key, value))
    return out


def collect_violations(root: Path = REPO_ROOT) -> List[Violation]:
    """All violations across every committed result file (plus one
    when there are no result files at all — an empty results dir means
    the benchmarks stopped persisting, which is itself a failure)."""
    files = result_files(root)
    if not files:
        return [(RESULTS_DIR, "no BENCH_*.json result files found")]
    out: List[Violation] = []
    for path in files:
        out.extend(check_result(path, root))
    return out


def main(argv: List[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if "--list" in argv:
        for path in result_files():
            print(path.relative_to(REPO_ROOT))
        return 0
    violations = collect_violations()
    for rel, msg in violations:
        print(f"{rel}: {msg}")
    if violations:
        print(f"\n{len(violations)} bench-result violation(s); the "
              f"expected layout is documented in benchmarks/common.py")
        return 1
    print(f"bench lint OK ({len(result_files())} result files)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
