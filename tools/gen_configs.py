"""One-shot generator for src/repro/configs/<arch>.py files."""
import os

HEADER = '''"""{title}  {cite}

Auto-structured config: CONFIG is the exact assigned architecture;
REDUCED is the same family at smoke-test scale (2 layers, d_model<=512,
<=4 experts) for CPU tests.
"""

from repro.models.common import ModelConfig

'''

ARCHS = {
    "phi4_mini_3_8b": dict(
        title="Phi-4-mini 3.8B [dense]", cite="[arXiv:2412.08905]",
        CONFIG=dict(arch_id="phi4-mini-3.8b", family="dense", n_layers=32,
                    d_model=3072, n_heads=24, n_kv_heads=8, d_ff=8192,
                    vocab=200064, act="silu", sliding_window=8192,
                    source="arXiv:2412.08905"),
        REDUCED=dict(arch_id="phi4-mini-3.8b-smoke", family="dense",
                     n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
                     d_ff=512, vocab=512, act="silu", sliding_window=64,
                     dtype="float32", source="arXiv:2412.08905")),
    "mamba2_780m": dict(
        title="Mamba2-780m [ssm] — SSD (state-space duality)",
        cite="[arXiv:2405.21060]",
        CONFIG=dict(arch_id="mamba2-780m", family="ssm", n_layers=48,
                    d_model=1536, n_heads=0, n_kv_heads=0, d_ff=0,
                    vocab=50280, ssm_state=128, ssm_head_dim=64,
                    ssm_expand=2, ssm_conv=4, ssm_groups=1,
                    tie_embeddings=True, source="arXiv:2405.21060"),
        REDUCED=dict(arch_id="mamba2-780m-smoke", family="ssm",
                     n_layers=2, d_model=256, n_heads=0, n_kv_heads=0,
                     d_ff=0, vocab=512, ssm_state=16, ssm_head_dim=32,
                     ssm_expand=2, ssm_conv=4, ssm_groups=1,
                     tie_embeddings=True, dtype="float32",
                     source="arXiv:2405.21060")),
    "qwen3_32b": dict(
        title="Qwen3-32B [dense] — qk_norm, GQA", cite="[hf:Qwen/Qwen3-8B]",
        CONFIG=dict(arch_id="qwen3-32b", family="dense", n_layers=64,
                    d_model=5120, n_heads=64, n_kv_heads=8, d_ff=25600,
                    vocab=151936, head_dim=128, qk_norm=True, act="silu",
                    rope_base=1000000.0, sliding_window=8192,
                    source="hf:Qwen/Qwen3-8B"),
        REDUCED=dict(arch_id="qwen3-32b-smoke", family="dense", n_layers=2,
                     d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
                     vocab=512, head_dim=64, qk_norm=True, act="silu",
                     dtype="float32", source="hf:Qwen/Qwen3-8B")),
    "phi3_mini_3_8b": dict(
        title="Phi-3-mini 3.8B [dense]", cite="[arXiv:2404.14219]",
        CONFIG=dict(arch_id="phi3-mini-3.8b", family="dense", n_layers=32,
                    d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
                    vocab=32064, act="silu", sliding_window=8192,
                    source="arXiv:2404.14219"),
        REDUCED=dict(arch_id="phi3-mini-3.8b-smoke", family="dense",
                     n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
                     d_ff=512, vocab=512, act="silu", dtype="float32",
                     source="arXiv:2404.14219")),
    "deepseek_moe_16b": dict(
        title="DeepSeekMoE-16B [moe] — 2 shared + 64 routed top-6, "
              "fine-grained", cite="[arXiv:2401.06066]",
        CONFIG=dict(arch_id="deepseek-moe-16b", family="moe", n_layers=28,
                    d_model=2048, n_heads=16, n_kv_heads=16, d_ff=0,
                    vocab=102400, n_experts=64, top_k=6,
                    n_shared_experts=2, moe_d_ff=1408,
                    first_layer_dense_ff=10944, act="silu",
                    sliding_window=8192, source="arXiv:2401.06066"),
        REDUCED=dict(arch_id="deepseek-moe-16b-smoke", family="moe",
                     n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
                     d_ff=0, vocab=512, n_experts=4, top_k=2,
                     n_shared_experts=1, moe_d_ff=128,
                     first_layer_dense_ff=512, act="silu",
                     capacity_factor=8.0, dtype="float32", source="arXiv:2401.06066")),
    "yi_6b": dict(
        title="Yi-6B [dense] — llama-arch GQA", cite="[arXiv:2403.04652]",
        CONFIG=dict(arch_id="yi-6b", family="dense", n_layers=32,
                    d_model=4096, n_heads=32, n_kv_heads=4, d_ff=11008,
                    vocab=64000, act="silu", rope_base=5000000.0,
                    sliding_window=8192, source="arXiv:2403.04652"),
        REDUCED=dict(arch_id="yi-6b-smoke", family="dense", n_layers=2,
                     d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
                     vocab=512, act="silu", dtype="float32",
                     source="arXiv:2403.04652")),
    "qwen3_moe_30b_a3b": dict(
        title="Qwen3-30B-A3B [moe] — 128 experts top-8",
        cite="[hf:Qwen/Qwen3-30B-A3B]",
        CONFIG=dict(arch_id="qwen3-moe-30b-a3b", family="moe", n_layers=48,
                    d_model=2048, n_heads=32, n_kv_heads=4, d_ff=0,
                    vocab=151936, head_dim=128, qk_norm=True,
                    n_experts=128, top_k=8, moe_d_ff=768, act="silu",
                    rope_base=1000000.0, sliding_window=8192,
                    source="hf:Qwen/Qwen3-30B-A3B"),
        REDUCED=dict(arch_id="qwen3-moe-30b-a3b-smoke", family="moe",
                     n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
                     d_ff=0, vocab=512, head_dim=64, qk_norm=True,
                     n_experts=4, top_k=2, moe_d_ff=128, act="silu",
                     capacity_factor=8.0, dtype="float32", source="hf:Qwen/Qwen3-30B-A3B")),
    "paligemma_3b": dict(
        title="PaliGemma-3B [vlm] — SigLIP + Gemma (ViT stubbed)",
        cite="[arXiv:2407.07726]",
        CONFIG=dict(arch_id="paligemma-3b", family="vlm", n_layers=18,
                    d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
                    vocab=257216, head_dim=256, act="geglu",
                    tie_embeddings=True, n_vision_tokens=256,
                    d_vision=1152, prefix_lm=True, sliding_window=8192,
                    source="arXiv:2407.07726"),
        REDUCED=dict(arch_id="paligemma-3b-smoke", family="vlm",
                     n_layers=2, d_model=256, n_heads=4, n_kv_heads=1,
                     d_ff=512, vocab=512, head_dim=64, act="geglu",
                     tie_embeddings=True, n_vision_tokens=16,
                     d_vision=64, prefix_lm=True, dtype="float32",
                     source="arXiv:2407.07726")),
    "whisper_large_v3": dict(
        title="Whisper-large-v3 [audio] — enc-dec; conv frontend stubbed",
        cite="[arXiv:2212.04356]",
        CONFIG=dict(arch_id="whisper-large-v3", family="audio",
                    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
                    d_ff=5120, vocab=51866, act="gelu", rope_base=0.0,
                    n_encoder_layers=32, n_audio_ctx=1500,
                    tie_embeddings=True, sliding_window=8192,
                    source="arXiv:2212.04356"),
        REDUCED=dict(arch_id="whisper-large-v3-smoke", family="audio",
                     n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                     d_ff=256, vocab=512, act="gelu", rope_base=0.0,
                     n_encoder_layers=2, n_audio_ctx=32,
                     tie_embeddings=True, dtype="float32",
                     source="arXiv:2212.04356")),
    "zamba2_1_2b": dict(
        title="Zamba2-1.2B [hybrid] — Mamba2 + shared attn blocks",
        cite="[arXiv:2411.15242]",
        CONFIG=dict(arch_id="zamba2-1.2b", family="hybrid", n_layers=38,
                    d_model=2048, n_heads=32, n_kv_heads=32, d_ff=8192,
                    vocab=32000, ssm_state=64, ssm_head_dim=64,
                    ssm_expand=2, ssm_conv=4, ssm_groups=1,
                    shared_attn_every=6, act="gelu",
                    source="arXiv:2411.15242"),
        REDUCED=dict(arch_id="zamba2-1.2b-smoke", family="hybrid",
                     n_layers=4, d_model=256, n_heads=4, n_kv_heads=4,
                     d_ff=512, vocab=512, ssm_state=16, ssm_head_dim=32,
                     ssm_expand=2, ssm_conv=4, ssm_groups=1,
                     shared_attn_every=2, act="gelu", dtype="float32",
                     source="arXiv:2411.15242")),
}


def fmt(d):
    items = ",\n    ".join(f"{k}={v!r}" for k, v in d.items())
    return f"ModelConfig(\n    {items},\n)"


def main():
    base = os.path.join(os.path.dirname(__file__), "..",
                        "src", "repro", "configs")
    os.makedirs(base, exist_ok=True)
    for mod, spec in ARCHS.items():
        body = HEADER.format(title=spec["title"], cite=spec["cite"])
        body += "CONFIG = " + fmt(spec["CONFIG"]) + "\n\n"
        body += "REDUCED = " + fmt(spec["REDUCED"]) + "\n"
        with open(os.path.join(base, mod + ".py"), "w") as f:
            f.write(body)
        print("wrote", mod)


if __name__ == "__main__":
    main()
