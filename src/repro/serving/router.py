"""Data-parallel replica routing — the second axis of ROADMAP item 2.

A ``ReplicaRouter`` sits ABOVE engine replicas the way an engine sits
above its slots: N ``ServingEngine`` instances of ONE model (each
possibly mesh-sharded over its own ``model`` axis — the two
parallelism axes compose) serve one arrival stream, and the router
decides WHICH replica each request is submitted to via a pluggable
``RoutingPolicy`` (serving/scheduling.py): round-robin, least-loaded,
or locality-aware.

Invariants the router maintains (property-tested in
tests/test_replica_router.py):

  * **no request lost or duplicated** — every submitted uid lives at
    exactly one replica at any moment (``routed`` maps uid → replica
    index and is updated atomically with every queue move), and every
    uid finishes with exactly one ``RequestResult``.
  * **locality stickiness** — a request whose continuation state (KV
    rows, slot checkpoint, half-run chunked prefill) is parked at a
    replica is NEVER migrated off it: an engine checkpoint is host
    memory at that replica, and the request's partial ``output`` has
    already been emitted there — re-running it elsewhere would both
    strand the checkpoint and double-emit tokens.  Stickiness is a
    ROUTER guarantee, independent of policy: load-blind policies only
    lose performance, never correctness.
  * **work conservation** — before each tick the router rebalances:
    no replica sits with an idle slot while another replica queues
    unstarted (checkpoint-free) work it cannot admit this tick.
    Rebalancing moves host queue entries only.
  * **policy swaps never retrace** — routing is host-side Python over
    ``ReplicaLoad`` snapshots; replacing the policy mid-serve touches
    no traced value, so every replica's jit cache is frozen across the
    swap (the same contract as admission/preemption policies).

The router is deliberately engine-shaped: ``submit`` / ``step`` /
``run`` / ``results`` mirror ``ServingEngine``, so
``MultiTenantHost.run_all`` drives routed tenants and plain engines
through one loop.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Union

from .engine import Request, RequestResult, ServingEngine
from .scheduling import (ReplicaLoad, RoutingPolicy, get_routing)


class ReplicaRouter:
    """Load-balance one model's arrivals over engine replicas."""

    def __init__(self, replicas: Sequence[ServingEngine], *,
                 routing: Union[str, RoutingPolicy, None] = None,
                 rebalance: bool = True):
        if not replicas:
            raise ValueError("ReplicaRouter needs at least one replica")
        self.replicas: List[ServingEngine] = list(replicas)
        self.routing: RoutingPolicy = get_routing(routing)
        self.rebalance = bool(rebalance)
        # uid -> replica index currently holding the request; the
        # single source of truth the no-loss/no-duplication invariant
        # hangs on (updated atomically with every submit/migration)
        self.routed: Dict[int, int] = {}
        self.migrations = 0

    # ------------------------------------------------------------------

    def loads(self) -> List[ReplicaLoad]:
        """Per-replica ``ReplicaLoad`` snapshots from host bookkeeping
        (queue length, busy slots, slot count, remaining-token
        backlog) — what routing policies key on.  Never touches a
        device buffer: queue entries carry their full budget, active
        slots their ``slot_budget`` remainder, and mid-chunked-prefill
        slots their full budget (the prompt is not done yet)."""
        out = []
        for e in self.replicas:
            backlog = sum(int(r.max_new_tokens) for r in e.queue)
            backlog += int(e.slot_budget[e.active].sum())
            backlog += sum(int(cs.req.max_new_tokens)
                           for cs in e._chunking.values())
            out.append(ReplicaLoad(
                queued=len(e.queue),
                active=int(e.active.sum()) + len(e._chunking),
                slots=e.max_slots, backlog=backlog))
        return out

    def home_of(self, uid: int) -> Optional[int]:
        """Index of the replica holding ``uid``'s continuation state
        (a parked ``SlotCheckpoint``), or None for a stateless uid —
        what locality-aware routing sends requests home to."""
        for i, eng in enumerate(self.replicas):
            if uid in eng._ckpt:
                return i
        return None

    def replica_of(self, uid: int) -> Optional[int]:
        """Index of the replica currently holding ``uid`` (queued,
        running, or finished there), or None if never submitted."""
        return self.routed.get(uid)

    def set_routing(self, policy: Union[str, RoutingPolicy]) -> None:
        """Swap the routing policy mid-serve.  Routing is host-side
        Python over load snapshots, so the swap touches no traced
        value: every replica's jit cache is frozen across it (asserted
        in tests/test_replica_router.py)."""
        self.routing = get_routing(policy)

    def set_on_token(self, cb) -> None:
        """Point every replica's per-token streaming callback at one
        sink (docs/STREAMING.md).  The router's existing invariants
        already make routed streams exactly-once: a uid lives at one
        replica, work-stealing moves only UNSTARTED (checkpoint-free,
        zero-tokens-emitted) requests, and checkpoint stickiness keeps
        a mid-stream continuation at the replica that holds its
        emitted prefix — so per-uid event indices stay 0, 1, 2, …
        whichever replicas the fleet shuffles around it."""
        for eng in self.replicas:
            eng.on_token = cb

    def drain(self) -> None:
        """Settle every replica's in-flight overlapped step (see
        ``ServingEngine.drain``) — a fleet-wide quiesce point for
        checkpoint surgery or shutdown."""
        for eng in self.replicas:
            eng.drain()

    # ------------------------------------------------------------------

    def submit(self, req: Request) -> int:
        """Route ``req`` to a replica and submit it there; returns the
        replica index.  A uid may live at exactly one replica, so
        re-submitting an unfinished uid is refused loudly."""
        if req.uid in self.routed:
            res = self.results.get(req.uid)
            if res is None or not res.done:
                raise ValueError(
                    f"request uid {req.uid} is already routed to "
                    f"replica {self.routed[req.uid]} and not done")
        i = self.routing.route(self.loads(), req,
                               home=self.home_of(req.uid))
        if not 0 <= i < len(self.replicas):
            raise ValueError(
                f"routing policy {self.routing.name!r} returned "
                f"replica {i}, have {len(self.replicas)}")
        self.replicas[i].submit(req)
        self.routed[req.uid] = i
        return i

    def _movable(self, eng: ServingEngine, req: Request) -> bool:
        """May ``req`` leave ``eng``'s queue?  Only checkpoint-free
        (unstarted) requests move — continuation state is host memory
        at its replica, so checkpointed work is sticky by correctness,
        not preference."""
        return req.uid not in eng._ckpt

    def _rebalance(self) -> None:
        """Work conservation: while some replica has admission capacity
        it cannot fill from its own queue and another queues more
        unstarted work than it can admit this tick, migrate one movable
        request from the deepest-surplus donor to the neediest
        recipient (most recently arrived first — the work-stealing
        order that leaves the donor's imminent admissions alone).
        Pure host queue surgery: the request's ``RequestResult`` moves
        with it and ``routed`` is updated in the same step."""
        while True:
            loads = self.loads()
            free = [max(0, l.slots - l.active) for l in loads]
            need = [max(0, f - l.queued) for f, l in zip(free, loads)]
            surplus = [max(0, l.queued - f) for f, l in zip(free, loads)]
            donors = sorted((i for i in range(len(loads)) if surplus[i]),
                            key=lambda i: -surplus[i])
            recips = sorted((i for i in range(len(loads)) if need[i]),
                            key=lambda i: -need[i])
            moved = False
            for d in donors:
                donor = self.replicas[d]
                idx = next((k for k in reversed(range(len(donor.queue)))
                            if self._movable(donor, donor.queue[k])),
                           None)
                if idx is None:
                    continue
                for r in recips:
                    if r == d:
                        continue
                    req = donor.queue.pop(idx)
                    res = donor.results.pop(req.uid)
                    self.replicas[r].queue.append(req)
                    self.replicas[r].results[req.uid] = res
                    self.routed[req.uid] = r
                    self.migrations += 1
                    moved = True
                    break
                if moved:
                    break
            if not moved:
                return

    def step(self) -> bool:
        """One router tick: rebalance queued work across replicas, then
        advance EVERY replica one engine step (on real hardware the
        replicas run in parallel on disjoint device sets; here they are
        time-multiplexed like host tenants).  Returns True while any
        replica has work."""
        if self.rebalance and len(self.replicas) > 1:
            self._rebalance()
        pending = False
        for eng in self.replicas:
            if eng.step():
                pending = True
        return pending

    def run(self, max_steps: int = 10_000) -> Dict[int, RequestResult]:
        """Drive ``step`` until every replica drains; returns the
        merged results."""
        steps = 0
        while self.step():
            steps += 1
            if steps > max_steps:
                raise RuntimeError("replica routing did not converge")
        return self.results

    @property
    def results(self) -> Dict[int, RequestResult]:
        """Merged uid → ``RequestResult`` view across replicas (uids
        are router-unique, so the merge cannot collide)."""
        out: Dict[int, RequestResult] = {}
        for eng in self.replicas:
            out.update(eng.results)
        return out
