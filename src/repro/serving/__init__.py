"""Serving substrate: batched prefill/decode engine with KV arenas
planned by the TFLM memory planner, multitenant hosting,
registry-resolved serving kernels (ops), and pluggable latency-aware
admission policies (scheduling)."""

from . import ops  # registers the reference serving macro-kernels
from .engine import (BUCKETED_FAMILIES, DEFAULT_TAGS, Request,
                     RequestResult, ServingEngine, default_clock)
from .host import MicroRequest, MicroRequestResult, MultiTenantHost
from .scheduling import (EDFPolicy, FIFOPolicy, PriorityPolicy,
                         SchedulingPolicy, get_policy)

__all__ = ["BUCKETED_FAMILIES", "DEFAULT_TAGS", "Request",
           "RequestResult", "ServingEngine", "default_clock",
           "MicroRequest", "MicroRequestResult", "MultiTenantHost",
           "EDFPolicy", "FIFOPolicy", "PriorityPolicy",
           "SchedulingPolicy", "get_policy", "ops"]
