"""Serving substrate: batched prefill/decode engine with KV arenas
planned by the TFLM memory planner, multitenant hosting,
registry-resolved serving kernels (ops), pluggable latency-aware
admission policies, preemptive scheduling over checkpointable
slots/lanes (scheduling, docs/PREEMPTION.md), and data-parallel
replica routing above mesh-sharded engines (router,
docs/ARCHITECTURE.md §9)."""

from . import ops  # registers the reference serving macro-kernels
from .engine import (BUCKETED_FAMILIES, CHUNKED_FAMILIES, DEFAULT_TAGS,
                     PAGED_FAMILIES, RECURRENT_FAMILIES,
                     SHARDED_FAMILIES, STREAMING_FAMILIES, Request,
                     RequestResult, ServingEngine, SlotCheckpoint,
                     StreamEvent, default_clock)
from .errors import UnsupportedFamilyError
from .host import MicroRequest, MicroRequestResult, MultiTenantHost
from .router import ReplicaRouter
from .scheduling import (EDFDisplacePolicy, EDFPolicy, FIFOPolicy,
                         LeastLoadedRouting, LocalityRouting,
                         PreemptionPolicy, PriorityPolicy, ReplicaLoad,
                         RoundRobinRouting, RoutingPolicy,
                         SchedulingPolicy, WFQDisplacePolicy, WFQPolicy,
                         get_policy, get_preemption, get_routing)

__all__ = ["BUCKETED_FAMILIES", "CHUNKED_FAMILIES", "DEFAULT_TAGS",
           "PAGED_FAMILIES", "RECURRENT_FAMILIES", "SHARDED_FAMILIES",
           "STREAMING_FAMILIES", "Request", "RequestResult",
           "ServingEngine", "SlotCheckpoint", "StreamEvent",
           "UnsupportedFamilyError", "default_clock",
           "MicroRequest", "MicroRequestResult", "MultiTenantHost",
           "ReplicaRouter", "EDFDisplacePolicy", "EDFPolicy",
           "FIFOPolicy", "LeastLoadedRouting", "LocalityRouting",
           "PreemptionPolicy", "PriorityPolicy", "ReplicaLoad",
           "RoundRobinRouting", "RoutingPolicy", "SchedulingPolicy",
           "WFQDisplacePolicy", "WFQPolicy", "get_policy",
           "get_preemption", "get_routing", "ops"]
