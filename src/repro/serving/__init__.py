"""Serving substrate: batched prefill/decode engine with KV arenas
planned by the TFLM memory planner, multitenant hosting,
registry-resolved serving kernels (ops), pluggable latency-aware
admission policies, and preemptive scheduling over checkpointable
slots/lanes (scheduling, docs/PREEMPTION.md)."""

from . import ops  # registers the reference serving macro-kernels
from .engine import (BUCKETED_FAMILIES, CHUNKED_FAMILIES, DEFAULT_TAGS,
                     PAGED_FAMILIES, RECURRENT_FAMILIES, Request,
                     RequestResult, ServingEngine, SlotCheckpoint,
                     default_clock)
from .errors import UnsupportedFamilyError
from .host import MicroRequest, MicroRequestResult, MultiTenantHost
from .scheduling import (EDFDisplacePolicy, EDFPolicy, FIFOPolicy,
                         PreemptionPolicy, PriorityPolicy,
                         SchedulingPolicy, WFQDisplacePolicy, WFQPolicy,
                         get_policy, get_preemption)

__all__ = ["BUCKETED_FAMILIES", "CHUNKED_FAMILIES", "DEFAULT_TAGS",
           "PAGED_FAMILIES", "RECURRENT_FAMILIES", "Request",
           "RequestResult", "ServingEngine", "SlotCheckpoint",
           "UnsupportedFamilyError", "default_clock",
           "MicroRequest", "MicroRequestResult", "MultiTenantHost",
           "EDFDisplacePolicy", "EDFPolicy", "FIFOPolicy",
           "PreemptionPolicy", "PriorityPolicy", "SchedulingPolicy",
           "WFQDisplacePolicy", "WFQPolicy", "get_policy",
           "get_preemption", "ops"]
