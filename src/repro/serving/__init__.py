"""Serving substrate: batched prefill/decode engine with KV arenas
planned by the TFLM memory planner, multitenant hosting."""

from .engine import Request, RequestResult, ServingEngine
from .host import MultiTenantHost

__all__ = ["Request", "RequestResult", "ServingEngine", "MultiTenantHost"]
