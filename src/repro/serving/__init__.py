"""Serving substrate: batched prefill/decode engine with KV arenas
planned by the TFLM memory planner, multitenant hosting, and
registry-resolved serving kernels (ops)."""

from . import ops  # registers the reference serving macro-kernels
from .engine import DEFAULT_TAGS, Request, RequestResult, ServingEngine
from .host import MicroRequest, MicroRequestResult, MultiTenantHost

__all__ = ["DEFAULT_TAGS", "Request", "RequestResult", "ServingEngine",
           "MicroRequest", "MicroRequestResult", "MultiTenantHost", "ops"]
