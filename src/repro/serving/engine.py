"""Batched serving engine — the pod-scale analogue of the TF Micro
invoke loop (paper §4.1), with the same allocation discipline:

  * ALL buffers (decode slots, KV cache, sampling state) are created at
    engine construction — nothing allocates inside the serving loop
    (the paper's "no allocation after init" invariant, C3);
  * cache capacity is budgeted through the SAME TwoStackArena +
    memory-planner machinery the micro interpreter uses: KV is a
    persistent (interpreter-lifetime) allocation, prefill scratch is a
    function-lifetime head allocation released between requests;
  * continuous batching: fixed decode slots, requests admitted as slots
    free up, one fused decode step advances every active slot;
  * the compiled prefill/decode steps resolve through the op registry
    tag chain (``("pallas", "reference")`` by default, §4.7–4.8) —
    vendor-optimized serving kernels shadow the reference ones per-op
    with no engine changes, exactly like the micro interpreter's
    ``TAGS=`` build mechanism.

Compile-once invariants (what callers may rely on):

  * **traced once** — the decode step is jitted at engine construction
    with the resolved registration's eval, context, and OpDef bound;
    the prefill step is jitted once per prompt-length *bucket* when
    bucketing is active (the default for dense/vlm/moe) and once per
    distinct prompt length otherwise.  Model family, cache layout,
    slot count, and window are baked in then.
  * **donated** — nothing in this engine: the KV cache and sampling
    state are carried functionally (cache in, cache out) so a step can
    be replayed; the ARENA accounts capacity (KV is an
    interpreter-lifetime tail allocation) but does not back device
    buffers here.
  * **may vary per call** — token values, per-slot lengths, and which
    slots are live.  Admitting a request writes ONLY slot bookkeeping
    and cache rows; it never retraces, which is what keeps continuous
    batching allocation-free inside the loop.

Four host-side degrees of freedom ride on top (docs/SCHEDULING.md,
docs/PREEMPTION.md):

  * **admission order is policy-driven** — a ``SchedulingPolicy``
    (FIFO / priority-with-aging / EDF over ``Request.deadline_us`` /
    per-tenant WFQ) picks which queued request takes a free slot.
    Policies reorder the Python queue only; masks, shapes, and
    programs are untouched, so changing policy never recompiles.
  * **bucketed prefill** — prompt lengths are quantized to power-of-two
    buckets (``BucketTable``): the prompt is right-padded to its bucket
    and the prefill step compiles once per *bucket*, not per *length*.
    Safe for families whose decode masks the KV cache by per-slot
    length AND whose prefill math is per-position (dense/vlm): padded
    rows are positionally masked to -1e30 before softmax, so decoded
    tokens are bit-identical to the exact-length path (asserted in
    tests/test_scheduling.py).  MoE buckets too, via capacity-stable
    masked dispatch: expert capacity is computed from the BUCKET shape
    while traced ``n_valid``/``moe_cap`` scalars mask routing to
    exactly what the true length dispatches (``lm.moe_dispatch``) —
    one compile per bucket, bit-identical expert routing.  SSM and
    hybrid keep exact-length (or CHUNKED, below) prefill: their
    recurrent state integrates every input position, masked or not,
    so forcing a bucket table onto them raises
    ``UnsupportedFamilyError``.
  * **chunked prefill** (``prefill_chunk=``) — a long prompt advances
    ONE fixed-size chunk per engine step instead of running its whole
    prefill inside the admission path, so prefill no longer
    monopolizes the engine between decode steps.  Dense/vlm chunk
    through ``SERVING_PREFILL_CHUNK`` (start offset a traced scalar →
    one compiled chunk program total); ssm/hybrid chunk through
    ``SERVING_PREFILL_CHUNK_STATE``, which carries the recurrent
    (conv, SSD) state — plus hybrid's shared-attn KV — as a traced
    argument: a chunk boundary is just a state checkpoint, and the
    padded tail of the final chunk is an exact state no-op
    (dt masked to zero).  MoE cannot chunk (expert capacity depends
    on the token count integrated so far) and raises the typed error.
  * **preemption** (``preempt=``) — when every slot is busy and the
    queue holds a tighter request, a ``PreemptionPolicy`` picks a
    running victim; its continuation state (KV rows + slot
    bookkeeping, or its half-filled chunked-prefill cache) is
    checkpointed HOST-SIDE into a ``SlotCheckpoint``, the request is
    re-queued, and the urgent one takes the slot.  Restoring later is
    bit-identical (decode is a pure function of the restored state)
    and, like every scheduling decision, touches no traced value — so
    preempt/resume cycles never recompile.

A fifth degree of freedom changes the KV layout itself
(docs/ARCHITECTURE.md §8):

  * **paged KV** (``kv_block=``) — instead of one contiguous
    ``cache_len`` ring per slot, KV lives in fixed-size blocks inside
    a shared physical pool sized independently of ``max_slots``
    (``kv_pool_blocks=``), and each slot holds a row of a traced
    ``(max_slots, cache_len // kv_block)`` block table.  Slots map
    blocks ON DEMAND as they decode (a two-phase reserve/map contract
    on ``PagedKVPool`` makes mid-decode growth infallible), so
    admission is bounded by blocks actually in use, not worst-case
    slot length — more concurrent sequences at the same HBM budget.
    Checkpoint/restore becomes a block-table handoff: evicting a slot
    moves its block IDS into the checkpoint and zeroes its table row;
    restoring writes them into the new slot's row — no KV rows are
    copied either way, and since the table is a traced argument,
    admit/retire/grow/restore never recompile.  Gated to families
    with the dense (KH, C, dh) ring layout (dense/moe/vlm).

A sixth overlaps the host with the device (docs/STREAMING.md):

  * **overlapped decode** (``overlap=True``) — readback is deferred
    ONE step: the engine dispatches decode step i+1 (its input token
    a device future from a tiny jitted argmax) before blocking on
    step i's tokens, so sampling/bookkeeping for step i runs while
    step i+1 computes on device.  The decode program itself is the
    same single traced table entry sync mode runs, and the tokens are
    bit-identical (asserted per family by the conformance matrix's
    ``streaming`` column).  Per-token delivery rides on ``on_token``:
    a ``StreamEvent`` per emitted token, in order and exactly once —
    across preemption/restore too, because every snapshot path drains
    the in-flight step first.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arena import TwoStackArena, align_up
from repro.core.executor import (BucketTable, InflightStep, PagedKVPool,
                                 pin_tree)
from repro.core.op_resolver import MicroMutableOpResolver
from repro.core.schema import OpCode, OpDef
from repro.kernels import ops as _vendor_kernels  # registers tag="pallas"
from repro.models.common import ModelConfig
from repro.models.registry import ModelBundle

from . import ops as serving_ops  # registers tag="reference" serving ops
from .errors import UnsupportedFamilyError
from .scheduling import (PreemptionPolicy, SchedulingPolicy,
                         get_policy, get_preemption)

DEFAULT_TAGS = ("pallas", "reference")

# families each fast path supports — the per-family safety arguments
# live in docs/SCHEDULING.md §2 and docs/PREEMPTION.md §4.
#
# BUCKETED: decode masks the KV cache by per-slot length, so
# right-padded (bucketed) prefill is bit-identical to exact-length
# prefill.  "moe" qualifies via capacity-stable masked dispatch
# (lm.moe_dispatch: capacity from the bucket SHAPE, routing masked to
# the true length's).  NOT "ssm"/"hybrid": recurrent state integrates
# every position, masked or not.
BUCKETED_FAMILIES = ("dense", "vlm", "moe")
# CHUNKED: dense/vlm via the KV-offset chunk op; ssm/hybrid via the
# recurrent-state chunk op (carried state is a traced argument).  NOT
# "moe": expert capacity depends on the token count integrated so
# far, so per-chunk dispatch diverges from the one-shot run.
CHUNKED_FAMILIES = ("dense", "vlm", "ssm", "hybrid")
# chunk through SERVING_PREFILL_CHUNK_STATE (carried recurrent state)
RECURRENT_FAMILIES = ("ssm", "hybrid")
# PAGED: needs the dense (KH, C, dh) ring layout
PAGED_FAMILIES = ("dense", "moe", "vlm")
# SHARDED: families whose param/cache trees the sharding policy
# (distributed/sharding.py) partitions over a serving mesh's ``model``
# axis — heads/FFN/experts for attention families, the SSD head dim
# for recurrent ones.  NOT "audio": the encoder-decoder serving path
# (cross-KV staging at admission) has not been partition-qualified.
SHARDED_FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm")
# STREAMING: families qualified for the overlapped (async) decode loop
# (``overlap=True``): readback deferred one step, greedy sampling
# moved onto the device so the next step's tokens are a device future.
# NOT "audio": the encoder-decoder serving path (cross-KV staged at
# admission) has not been qualified for deferred readback.
STREAMING_FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm")


def default_clock() -> int:
    """Host time in µs — the clock policies age/deadline against.
    Engines and hosts accept a ``clock`` override so the arrival
    benchmark can drive the same scheduling code on virtual time."""
    return time.monotonic_ns() // 1000


@dataclasses.dataclass
class Request:
    """One pod-scale generation request: a prompt plus decode budget,
    and the scheduling fields admission policies key on (``priority``:
    lower admits first; ``deadline_us``: absolute host µs for EDF;
    ``arrival_us``: stamped at submit() when not provided)."""

    uid: int
    tokens: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0            # 0 = greedy
    extras: Optional[Dict[str, np.ndarray]] = None   # vision / frames
    priority: int = 0                   # lower = more urgent
    deadline_us: Optional[int] = None   # absolute host time, EDF key
    arrival_us: Optional[int] = None    # stamped at submit()
    tenant: str = ""                    # WFQ quota label


@dataclasses.dataclass
class RequestResult:
    """Accumulated outcome of a Request: emitted tokens and timings.
    ``preemptions`` counts how many times the request was evicted from
    a slot and later resumed (0 = ran uninterrupted).
    ``first_token_us`` is the engine-clock stamp of the first emitted
    token — ``first_token_us - arrival_us`` is the request's TTFT."""

    uid: int
    prompt_len: int
    output: List[int] = dataclasses.field(default_factory=list)
    prefill_s: float = 0.0
    decode_s: float = 0.0
    done: bool = False
    preemptions: int = 0
    first_token_us: Optional[int] = None


@dataclasses.dataclass
class StreamEvent:
    """One streamed token, delivered through the engine's ``on_token``
    callback the moment the host learns it (docs/STREAMING.md).

    The ordering contract callers may rely on: per ``uid``, events
    arrive with ``index`` counting 0, 1, 2, … with no gaps and no
    repeats — across preemption/restore and replica routing included —
    and ``token == results[uid].output[index]`` always.  ``final`` is
    True on exactly the request's last event.  ``t_us`` is the engine
    clock at emission (virtual µs under a virtual clock, host µs
    otherwise), so TTFT/ITL fall straight out of the event stream."""

    uid: int
    index: int      # position in the request's output (0-based)
    token: int
    t_us: int       # engine clock at emission
    final: bool     # True on the request's last token


@dataclasses.dataclass
class SlotCheckpoint:
    """A preempted pod request's continuation state, host-side
    (docs/PREEMPTION.md) — the engine analogue of the ragged pool's
    ``LaneCheckpoint``.

    ``phase`` records where the request was interrupted: ``"decode"``
    checkpoints the slot's KV rows plus the (length, next token,
    remaining budget) triple the jitted decode step is a pure function
    of — restoring them replays the run bit-identically; ``"prefill"``
    checkpoints a chunked prefill in flight (its batch=1 cache and how
    many prompt tokens it has integrated).  Values are np copies: a
    checkpoint pins host memory only, never a device buffer, and
    nothing traced is captured — restore can never recompile.

    On a PAGED engine (``kv_block=``) the checkpoint carries no KV at
    all: ``cache`` is None and ``blocks`` pins the slot's physical
    block ids (plus its unspent worst-case ``reserved`` count) — the
    KV rows stay in the shared pool untouched, and restore just writes
    the ids into the new slot's block-table row (a value update of a
    traced argument: no copy, no retrace)."""

    phase: str                          # "decode" | "prefill"
    cache: Any                          # batch=1 cache pytree (np leaves)
    length: int = 0                     # absolute position (decode)
    cur_token: int = 0                  # next token to feed (decode)
    budget: int = 0                     # remaining new tokens (decode)
    done_tokens: int = 0                # prompt tokens integrated (prefill)
    blocks: Optional[List[int]] = None  # paged: pinned physical block ids
    reserved: int = 0                   # paged: unspent reservation


@dataclasses.dataclass
class _ChunkState:
    """A slot mid-chunked-prefill: the request, its private batch=1
    cache, and how many prompt tokens have been integrated so far."""

    req: Request
    cache1: Any
    done: int


def _cache_bytes(tree: Any) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


class ServingEngine:
    """One model, ``max_slots`` concurrent sequences."""

    def __init__(self, bundle: ModelBundle, params: Any, *,
                 max_slots: int = 4, cache_len: int = 256,
                 arena: Optional[TwoStackArena] = None,
                 arena_bytes: Optional[int] = None, seed: int = 0,
                 tags: Sequence[str] = DEFAULT_TAGS,
                 policy: Any = None, clock=None,
                 prefill_buckets: Any = None,
                 prefill_chunk: Any = None, preempt: Any = None,
                 kv_block: Any = None,
                 kv_pool_blocks: Optional[int] = None,
                 weight_dtype: Any = None, kv_dtype: Any = None,
                 mesh: Any = None,
                 overlap: bool = False, on_token: Any = None):
        self.bundle = bundle
        self.cfg = bundle.cfg
        self.params = params
        self.max_slots = max_slots
        self.cache_len = cache_len
        self.policy: SchedulingPolicy = get_policy(policy)
        self.preempt: Optional[PreemptionPolicy] = get_preemption(preempt)
        self.clock = clock if clock is not None else default_clock
        # overlap: defer readback one step — dispatch decode i+1 before
        # blocking on decode i's tokens (docs/STREAMING.md).  Greedy
        # sampling moves onto the device (a tiny separate jitted argmax,
        # bit-identical to the host path) so the next step's cur_tokens
        # is a device future and the host never sits in the dispatch
        # chain.  on_token: per-token StreamEvent callback, fired in
        # order and exactly once in BOTH modes (sync engines stream too;
        # overlap just delivers each token one step later while the
        # device keeps busy).
        self.overlap = bool(overlap)
        if self.overlap and self.cfg.family not in STREAMING_FAMILIES:
            raise UnsupportedFamilyError(
                self.cfg.family, "overlapped (async) decode",
                supported=STREAMING_FAMILIES)
        self.on_token = on_token
        self._inflight: Optional[InflightStep] = None
        # prefill_buckets: None/True = auto (on for length-masked-
        # decode families, when the cache can hold at least the
        # smallest bucket), False = off, or a (shared) BucketTable
        self.bucket_table: Optional[BucketTable] = None
        if prefill_buckets is None or prefill_buckets is True:
            if self.cfg.family in BUCKETED_FAMILIES and cache_len >= 8:
                self.bucket_table = BucketTable(min_bucket=8,
                                                max_bucket=cache_len)
        elif prefill_buckets is not False:
            if not isinstance(prefill_buckets, BucketTable):
                raise TypeError(
                    f"prefill_buckets must be a BucketTable, True, "
                    f"False, or None, got {prefill_buckets!r}")
            if self.cfg.family not in BUCKETED_FAMILIES:
                raise UnsupportedFamilyError(
                    self.cfg.family, "bucketed prefill",
                    supported=BUCKETED_FAMILIES)
            self.bucket_table = prefill_buckets
        # capacity-stable MoE bucketing: every bucketed-moe prefill
        # batch carries traced n_valid/moe_cap scalars (lm.moe_dispatch)
        self._moe_masked = (self.cfg.family == "moe"
                            and self.bucket_table is not None)
        # prefill_chunk: None/False/0 = off, True = auto size (the
        # bucket table's min bucket, 8 when bucketing is off), int =
        # that many tokens per chunk.  dense/vlm chunk at a traced KV
        # offset; ssm/hybrid chunk through the recurrent-state op;
        # moe cannot chunk (capacity depends on tokens integrated).
        self.chunk_tokens = 0
        self._recurrent_chunk = False
        if prefill_chunk:
            if self.cfg.family not in CHUNKED_FAMILIES:
                raise UnsupportedFamilyError(
                    self.cfg.family, "chunked prefill",
                    supported=CHUNKED_FAMILIES)
            self._recurrent_chunk = self.cfg.family in RECURRENT_FAMILIES
            if prefill_chunk is True:
                self.chunk_tokens = (self.bucket_table.min_bucket
                                     if self.bucket_table else 8)
            else:
                if int(prefill_chunk) < 1:
                    raise ValueError(
                        f"prefill_chunk must be >= 1, got {prefill_chunk}")
                self.chunk_tokens = int(prefill_chunk)
        # weight_dtype / kv_dtype: quantized serving (docs/
        # QUANTIZATION.md).  "int8"/"int4" weights quantize ONCE here —
        # the resident tree stays quantized for the engine's lifetime
        # and the SERVING_*_Q ops dequantize per layer INSIDE the traced
        # step; kv_dtype="int8" builds the int8 + per-head-scale cache
        # layout (ring or paged pool alike).  Composes with bucketed
        # prefill and paging; NOT with chunked prefill (the chunk ops
        # write fp KV rows) or mesh sharding (quantized marker dicts
        # are not partition-qualified) — typed refusals at init, like
        # every other family gate.
        self.weight_dtype = weight_dtype
        self.kv_dtype = kv_dtype
        self.quantized = bool(weight_dtype or kv_dtype)
        if self.quantized:
            from repro.models.lm_quant import (KV_DTYPES, WEIGHT_DTYPES,
                                               quantize_lm_params)
            if weight_dtype is not None \
                    and weight_dtype not in WEIGHT_DTYPES:
                raise ValueError(
                    f"weight_dtype must be one of {WEIGHT_DTYPES} or "
                    f"None, got {weight_dtype!r}")
            if kv_dtype is not None and kv_dtype not in KV_DTYPES:
                raise ValueError(
                    f"kv_dtype must be one of {KV_DTYPES} or None, "
                    f"got {kv_dtype!r}")
            if self.cfg.family not in serving_ops.WEIGHT_QUANT_FAMILIES:
                raise UnsupportedFamilyError(
                    self.cfg.family, "quantized serving (SERVING_*_Q)",
                    supported=serving_ops.WEIGHT_QUANT_FAMILIES)
            if kv_dtype and self.cfg.family not in \
                    serving_ops.KV_QUANT_FAMILIES:
                raise UnsupportedFamilyError(
                    self.cfg.family,
                    "int8 KV cache (requires a dense (KH, C, dh) "
                    "cache layout)",
                    supported=serving_ops.KV_QUANT_FAMILIES)
            if self.chunk_tokens:
                raise ValueError(
                    "prefill_chunk does not compose with quantized "
                    "serving (the chunk ops write fp KV rows)")
            if mesh is not None:
                raise ValueError(
                    "mesh does not compose with quantized serving "
                    "(quantized marker dicts are not "
                    "partition-qualified)")
            if weight_dtype:
                self.params = params = quantize_lm_params(
                    params, self.cfg, weight_dtype)
        # resident weight bytes — with kv_bytes below, the benchmark's
        # HBM-footprint hook (quantized engines report the QUANTIZED
        # tree: int8/int4 payloads + f32 scales)
        self.param_bytes = _cache_bytes(self.params)
        dtype = self.cfg.jnp_dtype()
        # kv_block: None/0 = contiguous per-slot rings (the default);
        # int = paged mode with that block size.  kv_pool_blocks sizes
        # the shared physical pool (default: enough for every slot at
        # full length + the garbage block — same bytes as contiguous;
        # the occupancy win comes from passing LESS than that).
        self.kv_block = int(kv_block) if kv_block else 0
        self.paged = bool(self.kv_block)
        if self.paged:
            if self.cfg.family not in PAGED_FAMILIES:
                raise UnsupportedFamilyError(
                    self.cfg.family,
                    "paged KV (requires a dense (KH, C, dh) cache "
                    "layout)", supported=PAGED_FAMILIES)
            if cache_len % self.kv_block:
                raise ValueError(
                    f"kv_block must divide cache_len, got "
                    f"{self.kv_block} vs {cache_len}")
            self.n_table = cache_len // self.kv_block

        # --- arena accounting (C3/C4): KV is interpreter-lifetime ----
        if self.paged:
            n_blocks = (int(kv_pool_blocks) if kv_pool_blocks
                        else max_slots * self.n_table + 1)
            self.kv_pool = self._empty_cache(n_blocks, self.kv_block)
            self.pool = PagedKVPool(n_blocks, self.kv_block)
            self.block_tables = jnp.zeros((max_slots, self.n_table),
                                          jnp.int32)
            self._slot_blocks: List[List[int]] = [
                [] for _ in range(max_slots)]
            self._slot_reserved: List[int] = [0] * max_slots
            kv_bytes = _cache_bytes(self.kv_pool)
            cache = None
        else:
            cache = self._empty_cache(max_slots, cache_len)
            kv_bytes = _cache_bytes(cache)
        self.kv_bytes = kv_bytes
        if arena is None:
            arena = TwoStackArena(arena_bytes or align_up(
                kv_bytes + (64 << 10)) * 2)
        self.arena = arena
        self.kv_offset = arena.allocate_persistent(kv_bytes, tag="kv_cache")
        self.cache = cache

        # --- mesh sharding (tensor/expert parallel in the engine) -----
        # mesh: None = single-device (the default); a jax Mesh with a
        # ``model`` axis shards the weights and the KV arena (the
        # contiguous rings OR the paged pool) through the repo-wide
        # sharding policy (distributed/sharding.py), while every traced
        # bookkeeping value — block tables, lengths, current tokens —
        # pins fully-replicated.  Values still change every step;
        # PLACEMENTS never do (``pin_tree`` after each eager update),
        # so admit/preempt/restore keep the compile-once contract on a
        # mesh exactly as on one device (docs/ARCHITECTURE.md §9).
        self.mesh = mesh
        self._shard = None
        if mesh is not None:
            if self.cfg.family not in SHARDED_FAMILIES:
                raise UnsupportedFamilyError(
                    self.cfg.family, "mesh-sharded serving",
                    supported=SHARDED_FAMILIES)
            from repro.distributed.sharding import engine_shardings
            c1_shape = jax.eval_shape(
                lambda: bundle.empty_cache(1, cache_len, dtype))
            self._shard = engine_shardings(
                self.cfg, mesh, params,
                self.kv_pool if self.paged else self.cache,
                global_batch=(self.pool.n_blocks if self.paged
                              else max_slots),
                cache1_tree=c1_shape)
            self.params = jax.device_put(params, self._shard["params"])
            if self.paged:
                self.kv_pool = jax.device_put(self.kv_pool,
                                              self._shard["cache"])
                self.block_tables = self._pin_repl(self.block_tables)
            else:
                self.cache = jax.device_put(self.cache,
                                            self._shard["cache"])

        # --- slot bookkeeping (host side, fixed size) -----------------
        self.slot_req: List[Optional[RequestResult]] = [None] * max_slots
        self.slot_meta: List[Optional[Request]] = [None] * max_slots
        self.slot_budget = np.zeros(max_slots, np.int64)
        self.lengths = self._pin_repl(jnp.zeros((max_slots,), jnp.int32))
        self.cur_tokens = self._pin_repl(
            jnp.zeros((max_slots, 1), jnp.int32))
        self.active = np.zeros(max_slots, bool)
        # host mirror of `lengths` — the overlap loop grows paged block
        # tables at DISPATCH time (the device value is still a future
        # then), and the sync loop keeps it in step for free
        self._len_host = np.zeros(max_slots, np.int64)
        self.rng = np.random.default_rng(seed)
        self.queue: List[Request] = []
        self.results: Dict[int, RequestResult] = {}
        # preemption / chunked-prefill state (host side)
        self._chunking: Dict[int, _ChunkState] = {}
        self._ckpt: Dict[int, SlotCheckpoint] = {}
        # what the last step() did — the benchmark's virtual-clock cost
        # hook: prefill token counts, chunk dispatches, decode dispatch
        self.last_step: Dict[str, Any] = {"prefill_tokens": [],
                                          "chunks": 0, "decoded": False,
                                          "processed": 0}

        # --- compiled steps (init-time, like interpreter prepare) -----
        # Resolve prefill/decode through the op registry tag chain: the
        # serving analogue of MicroMutableOpResolver.add() at model load.
        # prepare() runs once here (it may bake family decisions into
        # op_data); eval is jitted with context and op bound, so the
        # traced step is a pure function of (params, cache, tokens, ...).
        prefill_code = OpCode.SERVING_PREFILL
        decode_code = (OpCode.SERVING_DECODE_PAGED if self.paged
                       else OpCode.SERVING_DECODE)
        qparams: Dict[str, Any] = {}
        if self.quantized:
            # two opcodes cover the whole quantized matrix: paged-ness,
            # KV quant, and the weight dtype ride OpDef.params (baked
            # into op_data at prepare) — still one compiled program per
            # engine, and per-opcode tag fallback works unchanged
            prefill_code = OpCode.SERVING_PREFILL_Q
            decode_code = OpCode.SERVING_DECODE_Q
            qparams = {"paged": self.paged, "kv_q": bool(kv_dtype),
                       "weight_dtype": weight_dtype}
        if self.paged:
            chunk_code = OpCode.SERVING_PREFILL_CHUNK_PAGED
        elif self._recurrent_chunk:
            chunk_code = OpCode.SERVING_PREFILL_CHUNK_STATE
        else:
            chunk_code = OpCode.SERVING_PREFILL_CHUNK
        opcodes = [prefill_code, decode_code]
        if self.chunk_tokens:
            opcodes.append(chunk_code)
        self.resolver = MicroMutableOpResolver(tags).add_many(opcodes)
        window = self.cfg.sliding_window
        self._prefill_op = OpDef(prefill_code, (), (),
                                 params={"cache_len": cache_len,
                                         "window": window, **qparams})
        self._decode_op = OpDef(decode_code, (), (),
                                params={"window": window, **qparams})
        prefill_reg = self.resolver.resolve(prefill_code)
        decode_reg = self.resolver.resolve(decode_code)
        pctx = serving_ops.ServingContext(bundle)
        prefill_ctx = serving_ops.ServingContext(
            bundle, prefill_reg.prepare(pctx, self._prefill_op).op_data)
        decode_ctx = serving_ops.ServingContext(
            bundle, decode_reg.prepare(pctx, self._decode_op).op_data)
        self._decode = jax.jit(functools.partial(
            decode_reg.eval, decode_ctx, self._decode_op))
        # overlap mode's device-side greedy sampler: its OWN tiny jitted
        # program (the decode program stays byte-for-byte the one sync
        # mode runs, so jit_cache_size(self._decode) == 1 holds either
        # way), replicating the host `_sample(logits, 0.0)` math —
        # slice to the true vocab, cast to f32, argmax with first-max
        # tie-break — so streamed tokens are bit-identical to sync.
        vocab = self.cfg.vocab
        self._argmax = jax.jit(
            lambda lg: jnp.argmax(lg[:, :vocab].astype(jnp.float32),
                                  axis=-1).astype(jnp.int32))
        # prefill jits once per prompt-length BUCKET when bucket_table
        # is set (BUCKETED_FAMILIES only: decode masks KV by length,
        # so padding is invisible, and moe additionally carries the
        # capacity-stable n_valid/moe_cap scalars); exact-length
        # otherwise — see the BUCKETED_FAMILIES comment for why
        # ssm/hybrid are out
        self._prefill = jax.jit(functools.partial(
            prefill_reg.eval, prefill_ctx, self._prefill_op))
        # the chunk step: fixed (1, chunk_tokens) token shape, start
        # offset a TRACED scalar — one compiled program serves every
        # chunk of every prompt (prepare() re-checks the family gate)
        self._prefill_chunk = None
        if self.chunk_tokens:
            chunk_op = OpDef(chunk_code, (), (),
                             params={"window": window})
            chunk_reg = self.resolver.resolve(chunk_code)
            chunk_ctx = serving_ops.ServingContext(
                bundle, chunk_reg.prepare(pctx, chunk_op).op_data)
            self._prefill_chunk = jax.jit(functools.partial(
                chunk_reg.eval, chunk_ctx, chunk_op))

    @classmethod
    def from_profile(cls, bundle: ModelBundle, params: Any,
                     profile: Any = None, **kw) -> "ServingEngine":
        """Construct an engine from a ``CalibrationProfile``
        (``repro.core.costmodel``) instead of hand-picked constants:
        the profile's solved bucket levels become the engine's
        ``BucketTable`` and its solved ``prefill_chunk`` the chunk
        size, with no re-measurement.  ``cache_len`` defaults to the
        capacity the profile was calibrated at.

        The profile must match this model + cache capacity
        (``profile.matches``) AND the running backend
        (``profile.matches_backend``) — a profile measured on another
        model or another piece of hardware is someone else's cost
        landscape and is refused loudly.  Explicit keyword overrides
        win over the profile (pass
        ``prefill_buckets=``/``prefill_chunk=`` to pin them), and a
        missing profile is simply the ordinary constructor: the
        no-profile fallback is today's defaults.

        With ``profile=None`` the profile CACHE is consulted: a
        profile previously saved under
        ``benchmarks/results/profiles/`` for this model + cache_len
        (``save_cached_profile``) is loaded and applied; no cached
        profile — or one measured on another backend — quietly falls
        back to the ordinary constructor (a cache miss is not an
        error, unlike an explicitly passed stale profile)."""
        if profile is None:
            from repro.core.costmodel import (load_cached_profile,
                                              profile_model_key)
            key = profile_model_key(bundle.cfg, kw.get("cache_len", 256))
            profile = load_cached_profile(key)
            if profile is not None and not profile.matches_backend():
                profile = None
            if profile is None:
                return cls(bundle, params, **kw)
        kw.setdefault("cache_len", profile.cache_len)
        if not profile.matches(bundle.cfg, kw["cache_len"]):
            from repro.core.costmodel import profile_model_key
            raise ValueError(
                f"profile was calibrated for {profile.model_key!r}, "
                f"not {profile_model_key(bundle.cfg, kw['cache_len'])!r}"
                f" — re-calibrate (or share deliberately through "
                f"MultiTenantHost(profile=...))")
        if not profile.matches_backend():
            import jax
            raise ValueError(
                f"profile was measured on backend "
                f"{profile.meta.get('backend')!r}, but this process "
                f"runs on {jax.default_backend()!r} — costs are "
                f"hardware facts; re-calibrate on this backend")
        # each solved knob applies only where the family supports the
        # fast path it drives (a profile calibrated on a bucketing
        # family must not force buckets onto an ssm engine)
        if bundle.cfg.family in BUCKETED_FAMILIES:
            kw.setdefault("prefill_buckets", profile.bucket_table())
        if bundle.cfg.family in CHUNKED_FAMILIES:
            kw.setdefault("prefill_chunk", profile.prefill_chunk or None)
        if getattr(profile, "kv_block", 0) \
                and bundle.cfg.family in PAGED_FAMILIES:
            kw.setdefault("kv_block", profile.kv_block)
        return cls(bundle, params, **kw)

    def prefill_compiles(self) -> int:
        """How many distinct prefill programs were traced — the
        trace-count hook.  With bucketing on, this is the number of
        buckets HIT, independent of how many prompt lengths arrived."""
        from repro.core.executor import jit_cache_size
        return jit_cache_size(self._prefill)

    def chunk_compiles(self) -> int:
        """How many distinct chunk-prefill programs were traced — must
        stay 1 however many prompts/chunks ran (the start offset is a
        traced argument, never a shape)."""
        from repro.core.executor import jit_cache_size
        return (jit_cache_size(self._prefill_chunk)
                if self._prefill_chunk is not None else 0)

    # -- mesh placement pins (compile-once on a mesh) -------------------

    def _pin_repl(self, x: Any) -> Any:
        """Pin a traced bookkeeping array (block table, lengths,
        current tokens) fully-replicated on the engine's mesh —
        identity on a single-device engine.  See ``pin_tree``."""
        return pin_tree(x, self._shard["repl"] if self._shard else None)

    def _pin_kv(self, tree: Any) -> Any:
        """Pin the slot KV arena (contiguous cache or paged pool) back
        onto its init-time mesh sharding after an eager host-side
        update, so the jitted decode step sees one placement forever."""
        return pin_tree(tree, self._shard["cache"] if self._shard
                        else None)

    def _pin_c1(self, tree: Any) -> Any:
        """Pin a batch=1 prefill/chunk cache to its mesh sharding so a
        chunk state keeps one placement from first chunk through
        activation (one chunk program total, sharded or not)."""
        return pin_tree(tree, self._shard["cache1"] if self._shard
                        else None)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.arrival_us is None:
            req.arrival_us = self.clock()
        self.queue.append(req)
        self.results[req.uid] = RequestResult(uid=req.uid,
                                              prompt_len=len(req.tokens))

    def insert_slot_state(self, slot: int, new_cache: Any) -> None:
        """Place a prefilled (batch=1) state pytree into slot ``slot``
        — the pod-engine state-INSERTION hook, inverse of
        ``extract_slot_state``.  Pytree-generic: for dense/vlm/moe the
        leaves are KV rings, for ssm/hybrid they are the recurrent
        conv window + SSD state (plus the hybrid shared-attn KV), all
        with batch on axis 1 — so a checkpointed request restores into
        ANY slot, any family, without retracing (the slot index is a
        host-side dynamic_update_slice start, never a shape)."""
        def ins(full, one):
            # batch dim differs per leaf family; find the axis whose size
            # is max_slots and the matching axis of size 1 in `one`
            for ax in range(full.ndim):
                if full.shape[ax] == self.max_slots \
                        and one.shape[ax] == 1:
                    idx = [slice(None)] * full.ndim
                    start = [0] * full.ndim
                    start[ax] = slot
                    return jax.lax.dynamic_update_slice(
                        full, one.astype(full.dtype), tuple(start))
            raise ValueError((full.shape, one.shape))
        self.cache = self._pin_kv(jax.tree.map(ins, self.cache,
                                               new_cache))

    def _padded_prompt(self, tokens: np.ndarray) -> np.ndarray:
        """Right-pad the prefill prompt to its power-of-two bucket so
        the prefill step compiles once per bucket.  Padded positions
        produce KV rows the length-masked decode can never attend to
        (and the first decode steps overwrite them ring-slot by ring
        slot), so the decoded tokens are bit-identical to exact-length
        prefill.  Prompts longer than the largest bucket that fits the
        cache fall back to exact length (the ring-wrap case)."""
        s = len(tokens)
        room = self.cache_len - (self.cfg.n_vision_tokens
                                 if self.cfg.family == "vlm" else 0)
        padded = self.bucket_table.fit(s)
        if padded is None or padded > room:
            return tokens                   # over-cap: exact length
        self.bucket_table.bucket(s)         # committed: count the hit
        if padded == s:
            return tokens                   # already bucket-shaped
        return np.concatenate(
            [tokens, np.zeros(padded - s, tokens.dtype)])

    def _vis(self) -> int:
        """Cache positions the vision prefix occupies (vlm only)."""
        return (self.cfg.n_vision_tokens
                if self.cfg.family == "vlm" else 0)

    def _empty_cache(self, batch: int, length: int) -> Any:
        """A fresh cache/pool tree in the ENGINE'S KV layout: the one
        hook that keeps an int8-KV engine's empty trees in the
        quantized ``{k, v, k_scale, v_scale}`` layout everywhere a fp
        engine would call ``bundle.empty_cache`` (slot arena, paged
        pool, the single-token-prompt prefill cache)."""
        tree = self.bundle.empty_cache(batch, length,
                                       self.cfg.jnp_dtype())
        if self.kv_dtype:
            from repro.models.lm_quant import quantize_cache
            tree = quantize_cache(tree)
        return tree

    # -- paged KV: block accounting (docs/ARCHITECTURE.md §8) -----------

    def _blocks_needed(self, req: Request) -> int:
        """Worst-case blocks for ``req``: prompt + full decode budget,
        capped at the ring capacity.  Reserved (not mapped) at
        admission so on-demand growth can never fail mid-decode."""
        rows = min(self._vis() + len(req.tokens) - 1 + req.max_new_tokens,
                   self.cache_len)
        return max(1, -(-rows // self.kv_block))

    def _paged_admissible(self, req: Request) -> bool:
        """Can ``req`` take a slot right now?  A checkpointed request's
        resources are already pinned in its checkpoint; a fresh one
        needs its worst case reservable from the pool."""
        return (req.uid in self._ckpt
                or self.pool.can_reserve(self._blocks_needed(req)))

    def _ensure_blocks(self, slot: int, upto_pos: int) -> None:
        """Map blocks (debiting the slot's reservation) until the
        slot's table covers cache position ``upto_pos``.  Host-side
        bookkeeping only — ``_sync_table_row`` publishes the row to
        the traced block table (a VALUE update — never retraces)."""
        blocks = self._slot_blocks[slot]
        while (len(blocks) * self.kv_block <= upto_pos
               and len(blocks) < self.n_table):
            phys = self.pool.map_block()
            self._slot_reserved[slot] -= 1
            blocks.append(phys)

    def _table_row(self, slot: int) -> jnp.ndarray:
        """The slot's block table row, from host bookkeeping: mapped
        blocks in logical order, garbage block for the unmapped tail."""
        row = np.zeros(self.n_table, np.int32)
        blocks = self._slot_blocks[slot]
        row[:len(blocks)] = blocks
        return jnp.asarray(row)

    def _sync_table_row(self, slot: int) -> None:
        """Publish the slot's row into the DECODE block table.  Only a
        decoding slot's row may be live there: the fused decode step
        ring-writes EVERY slot row unconditionally, so a slot that is
        inactive or mid-chunked-prefill keeps its decode row pointed at
        the garbage block (its chunk dispatches carry ``_table_row``
        directly) or stale decode writes would corrupt its blocks."""
        self.block_tables = self._pin_repl(
            self.block_tables.at[slot].set(self._table_row(slot)))

    def _scatter_slot_cache(self, slot: int, cache1: Any) -> None:
        """Scatter a contiguous batch=1 cache into the slot's mapped
        blocks (one-shot prefill lands contiguous, then pages in).
        Unmapped table entries point at the garbage block, so the tail
        of the scatter is harmlessly absorbed there."""
        row = self._table_row(slot)
        t, bs = self.n_table, self.kv_block

        def sc(pool, one):
            if pool.ndim == 4:      # per-head KV scales (int8 KV pool)
                l, _, kh, _ = pool.shape
                src = one[:, 0].reshape(l, kh, t, bs).transpose(
                    0, 2, 1, 3)
                return pool.at[:, row].set(jnp.asarray(src, pool.dtype))
            l, _, kh, _, dh = pool.shape
            src = one[:, 0].reshape(l, kh, t, bs, dh).transpose(
                0, 2, 1, 3, 4)
            return pool.at[:, row].set(jnp.asarray(src, pool.dtype))

        self.kv_pool = self._pin_kv(jax.tree.map(sc, self.kv_pool,
                                                 cache1))

    def _release_slot_blocks(self, slot: int) -> None:
        """Return a finished slot's blocks + unspent reservation to the
        pool and point its table row back at the garbage block."""
        self.pool.release(self._slot_blocks[slot],
                          reserved=max(self._slot_reserved[slot], 0))
        self._slot_blocks[slot] = []
        self._slot_reserved[slot] = 0
        self.block_tables = self._pin_repl(
            self.block_tables.at[slot].set(0))

    def _activate_slot(self, req: Request, slot: int,
                       cache1: Any = None, *,
                       length: Optional[int] = None,
                       cur_token: Optional[int] = None,
                       budget: Optional[int] = None) -> None:
        """Hand a prefilled (or restored) request to the decode loop:
        write its cache rows and the slot bookkeeping the jitted decode
        step keys on.  The keyword overrides are the restore path — a
        resumed request continues from its checkpointed (length, next
        token, remaining budget) instead of a fresh prompt."""
        last_pos = (len(req.tokens) - 1 + self._vis()
                    if length is None else length)
        if self.paged:
            # cover everything written so far PLUS the position the
            # next decode step will write (last_pos % capacity), then
            # go live in the decode block table
            self._ensure_blocks(slot, min(last_pos, self.cache_len - 1))
            self._sync_table_row(slot)
        if cache1 is not None:
            if self.paged:
                self._scatter_slot_cache(slot, cache1)
            else:
                self.insert_slot_state(slot, cache1)
        self.slot_req[slot] = self.results[req.uid]
        self.slot_meta[slot] = req
        self.slot_budget[slot] = (req.max_new_tokens if budget is None
                                  else budget)
        self.active[slot] = True
        self._len_host[slot] = last_pos
        self.lengths = self.lengths.at[slot].set(last_pos)
        self.cur_tokens = self.cur_tokens.at[slot, 0].set(
            int(req.tokens[-1]) if cur_token is None else cur_token)

    def _prefill_one(self, req: Request, slot: int) -> None:
        """Prefill tokens[:-1], then hand the LAST prompt token to the
        decode loop: the first decode step integrates it (KV write /
        SSD state update) and emits the first new token — one uniform
        decode path for every family, no double-integration for SSM."""
        t0 = time.perf_counter()
        n = len(req.tokens)
        if n >= 2:
            m = n - 1
            prompt = np.asarray(req.tokens[:-1])
            if self.bucket_table is not None:
                prompt = self._padded_prompt(prompt)
            batch = {"tokens": jnp.asarray(prompt[None])}
            if self._moe_masked:
                # capacity-stable bucketed-MoE scalars: capacity is a
                # function of the BUCKET shape inside the trace, while
                # these traced values mask dispatch to exactly what the
                # true length m routes (lm.moe_dispatch).  Emitted even
                # on the over-cap exact-length fallback (where they
                # degenerate to unmasked semantics) so every prefill of
                # a given shape shares one trace signature.
                from repro.models.lm import moe_capacity
                batch["n_valid"] = jnp.int32(m)
                batch["moe_cap"] = jnp.int32(moe_capacity(self.cfg, m))
            if req.extras:
                for k, v in req.extras.items():
                    batch[k] = jnp.asarray(v[None])
            _, cache1 = self._prefill((self.params, batch))
            self.last_step["prefill_tokens"].append(len(prompt))
            self.policy.charge(req.tenant, 1.0)
        else:   # single-token prompt: slot starts from a fresh cache
            cache1 = self._empty_cache(1, self.cache_len)
        self.results[req.uid].prefill_s += time.perf_counter() - t0
        self._activate_slot(req, slot, cache1)

    # -- chunked prefill (one chunk per engine step) --------------------

    def _chunk_eligible(self, req: Request) -> bool:
        """Chunk when chunking is on, the prompt spans more than one
        chunk, and the padded last chunk still fits the cache without
        ring wrap (past that, fall back to one-shot exact prefill —
        the same over-cap fallback as ``_padded_prompt``)."""
        if not self.chunk_tokens:
            return False
        m = len(req.tokens) - 1
        if m <= self.chunk_tokens:
            return False
        n_chunks = -(-m // self.chunk_tokens)
        return self._vis() + n_chunks * self.chunk_tokens <= self.cache_len

    def _start_chunked(self, req: Request, slot: int) -> None:
        """Admit a long prompt into a slot in PREFILLING state: run the
        FIRST chunk through the ordinary prefill step (fixed
        (1, chunk_tokens) shape — for vlm this is also what integrates
        the vision prefix), park the batch=1 cache in a ``_ChunkState``,
        and let subsequent ``step()`` calls advance one chunk each.

        Recurrent families (ssm/hybrid) skip the one-shot prefill step
        entirely: the carried-state chunk op is seeded with an EMPTY
        cache (zero conv window ≡ the zero left-padding ``_causal_conv``
        assumes, zero SSD state ≡ no history) and EVERY chunk — the
        first included — goes through the single compiled
        SERVING_PREFILL_CHUNK_STATE program, so a chunked ssm/hybrid
        engine traces zero prefill programs."""
        if self._recurrent_chunk:
            cache1 = self._pin_c1(self.bundle.empty_cache(
                1, self.cache_len, self.cfg.jnp_dtype()))
            self._chunking[slot] = _ChunkState(req, cache1, 0)
            self._advance_chunk(slot)
            return
        t0 = time.perf_counter()
        first = np.asarray(req.tokens[:self.chunk_tokens])
        batch = {"tokens": jnp.asarray(first[None])}
        if req.extras:
            for k, v in req.extras.items():
                batch[k] = jnp.asarray(v[None])
        _, cache1 = self._prefill((self.params, batch))
        self.last_step["prefill_tokens"].append(len(first))
        self.policy.charge(req.tenant, 1.0)
        if self.paged:
            # page the first chunk into the pool now; later chunks
            # write the pool directly through the paged chunk op
            self._ensure_blocks(
                slot, min(self._vis() + len(first) - 1,
                          self.cache_len - 1))
            self._scatter_slot_cache(slot, cache1)
            cache1 = None
        else:
            cache1 = self._pin_c1(cache1)
        self._chunking[slot] = _ChunkState(req, cache1, len(first))
        self.results[req.uid].prefill_s += time.perf_counter() - t0

    def _advance_chunk(self, slot: int) -> None:
        """Advance a PREFILLING slot by ONE chunk — one jitted chunk
        dispatch with a traced start offset; the final partial chunk is
        right-padded (its garbage rows sit beyond the prompt length, so
        the length-masked decode can never attend to them and the first
        decode steps overwrite them slot by slot).  When the last
        prompt token's predecessor lands, the slot flips to decoding."""
        cs = self._chunking[slot]
        res = self.results[cs.req.uid]
        t0 = time.perf_counter()
        prompt = np.asarray(cs.req.tokens[:-1])
        tok = prompt[cs.done:cs.done + self.chunk_tokens]
        real = len(tok)
        if real < self.chunk_tokens:
            tok = np.concatenate(
                [tok, np.zeros(self.chunk_tokens - real, tok.dtype)])
        start = cs.done + self._vis()
        if self.paged:
            self._ensure_blocks(
                slot, min(start + self.chunk_tokens - 1,
                          self.cache_len - 1))
            self.kv_pool = self._pin_kv(self._prefill_chunk(
                (self.params, self.kv_pool, self._table_row(slot),
                 jnp.asarray(tok[None]), jnp.int32(start))))
        elif self._recurrent_chunk:
            # carried-state dispatch: the chunk's true token count rides
            # along as a traced scalar — the padded tail of the final
            # chunk is an exact state no-op (dt masked to zero), so one
            # compiled program serves full and partial chunks alike
            cs.cache1 = self._pin_c1(self._prefill_chunk(
                (self.params, cs.cache1, jnp.asarray(tok[None]),
                 jnp.int32(start), jnp.int32(real))))
        else:
            cs.cache1 = self._pin_c1(self._prefill_chunk(
                (self.params, cs.cache1, jnp.asarray(tok[None]),
                 jnp.int32(start))))
        cs.done += real
        self.last_step["chunks"] += 1
        self.policy.charge(cs.req.tenant, 1.0)
        res.prefill_s += time.perf_counter() - t0
        if cs.done >= len(prompt):
            del self._chunking[slot]
            self._activate_slot(cs.req, slot, cs.cache1)

    # -- preemption: slot checkpoint / evict / restore ------------------

    def extract_slot_state(self, slot: int) -> Any:
        """Slot ``slot``'s model state as a batch=1 pytree of np copies
        — the pod-engine state-EXTRACTION hook ``SlotCheckpoint`` (and
        the host's ``LaneCheckpoint``) carry.  Pytree-generic over the
        family's cache: KV rings for dense/vlm/moe, the recurrent conv
        window + SSD state (f32, exactly as the decode step left them)
        for ssm/hybrid — so restoring via ``insert_slot_state`` resumes
        bit-identically for every family."""
        self.drain()
        def ext(full):
            axes = [ax for ax in range(full.ndim)
                    if full.shape[ax] == self.max_slots]
            if not axes:
                raise ValueError((full.shape, self.max_slots))
            ax = 1 if 1 in axes else axes[0]   # batch is axis 1 for
            idx = [slice(None)] * full.ndim    # every current family
            idx[ax] = slice(slot, slot + 1)
            return np.asarray(full[tuple(idx)])
        return jax.tree.map(ext, self.cache)

    def snapshot_slot(self, slot: int) -> SlotCheckpoint:
        """Capture a running slot's continuation state host-side: the
        chunked-prefill cache + progress for a PREFILLING slot, the KV
        rows + (length, next token, budget) triple for a DECODING one.
        The slot itself is untouched — pair with ``_evict``.  On an
        overlapped engine the in-flight step is drained first, so the
        captured (length, token, budget) triple is always
        post-emission-consistent."""
        self.drain()
        if slot in self._chunking:
            cs = self._chunking[slot]
            if self.paged:
                return SlotCheckpoint(
                    phase="prefill", cache=None, done_tokens=cs.done,
                    blocks=list(self._slot_blocks[slot]),
                    reserved=self._slot_reserved[slot])
            return SlotCheckpoint(
                phase="prefill",
                cache=jax.tree.map(np.asarray, cs.cache1),
                done_tokens=cs.done)
        if not self.active[slot]:
            raise RuntimeError(f"slot {slot} is not running")
        if self.paged:
            # no KV copy: the rows stay in the pool, the checkpoint
            # pins the block ids (checkpoint-as-table-handoff)
            return SlotCheckpoint(
                phase="decode", cache=None,
                length=int(self.lengths[slot]),
                cur_token=int(self.cur_tokens[slot, 0]),
                budget=int(self.slot_budget[slot]),
                blocks=list(self._slot_blocks[slot]),
                reserved=self._slot_reserved[slot])
        return SlotCheckpoint(
            phase="decode", cache=self.extract_slot_state(slot),
            length=int(self.lengths[slot]),
            cur_token=int(self.cur_tokens[slot, 0]),
            budget=int(self.slot_budget[slot]))

    def _evict(self, slot: int) -> Request:
        """Preempt the request running in ``slot``: checkpoint it,
        free the slot, and put the request back on the queue (its
        checkpoint is picked up at re-admission).  Drains any in-flight
        overlapped step first — callers picking a victim must choose
        AFTER the drain (a pending retirement may have freed it)."""
        self.drain()
        if slot in self._chunking:
            req = self._chunking[slot].req
            ckpt = self.snapshot_slot(slot)
            del self._chunking[slot]
        else:
            req = self.slot_meta[slot]
            assert req is not None, f"slot {slot} has no request"
            ckpt = self.snapshot_slot(slot)
            self.active[slot] = False
            self.slot_req[slot] = None
            self.slot_meta[slot] = None
        if self.paged:
            # block ownership moved to the checkpoint: detach the slot
            # (table row back to the garbage block) without releasing
            self._slot_blocks[slot] = []
            self._slot_reserved[slot] = 0
            self.block_tables = self._pin_repl(
                self.block_tables.at[slot].set(0))
        self._ckpt[req.uid] = ckpt
        self.results[req.uid].preemptions += 1
        self.queue.append(req)
        return req

    def _restore_slot(self, req: Request, slot: int,
                      ckpt: SlotCheckpoint) -> None:
        """Re-admit a checkpointed request: a PREFILLING checkpoint
        resumes its chunk loop, a DECODING one re-enters the decode
        loop at exactly the captured state — the jitted decode step is
        a pure function of (cache, token, length), so the continuation
        is bit-identical to the uninterrupted run."""
        if self.paged:
            # block-table handoff: the pinned ids attach to the NEW
            # slot — the KV rows never moved.  A resumed decode goes
            # live in the decode table via _activate_slot's sync; a
            # resumed chunked prefill keeps its decode row on the
            # garbage block (chunk dispatches carry the row directly)
            self._slot_blocks[slot] = list(ckpt.blocks or [])
            self._slot_reserved[slot] = ckpt.reserved
            if ckpt.phase == "prefill":
                self._chunking[slot] = _ChunkState(req, None,
                                                   ckpt.done_tokens)
            else:
                self._activate_slot(req, slot, None, length=ckpt.length,
                                    cur_token=ckpt.cur_token,
                                    budget=ckpt.budget)
            return
        if ckpt.phase == "prefill":
            cache1 = self._pin_c1(jax.tree.map(jnp.asarray, ckpt.cache))
            self._chunking[slot] = _ChunkState(req, cache1,
                                               ckpt.done_tokens)
        else:
            self.insert_slot_state(slot, jax.tree.map(jnp.asarray,
                                                      ckpt.cache))
            self._activate_slot(req, slot, None, length=ckpt.length,
                                cur_token=ckpt.cur_token,
                                budget=ckpt.budget)

    def _admit(self, req: Request, slot: int) -> None:
        """Route an admission: restore a checkpointed request, start a
        chunked prefill for a long prompt, or prefill one-shot.  On a
        paged engine a FRESH admission reserves its worst-case block
        count up front (the caller checked ``_paged_admissible``), so
        every later ``map_block`` is infallible."""
        ckpt = self._ckpt.pop(req.uid, None)
        if ckpt is not None:
            self._restore_slot(req, slot, ckpt)
            return
        if self.paged:
            need = self._blocks_needed(req)
            self.pool.reserve(need)
            self._slot_reserved[slot] = need
        if self._chunk_eligible(req):
            self._start_chunked(req, slot)
        else:
            self._prefill_one(req, slot)

    def _sample(self, logits, temperature: float) -> np.ndarray:
        logits = np.asarray(logits[:, :self.cfg.vocab], np.float32)
        if temperature <= 0:
            return logits.argmax(-1)
        z = logits / temperature
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([self.rng.choice(len(row), p=row) for row in p])

    # -- streaming + overlapped decode (docs/STREAMING.md) --------------

    def _emit(self, res: RequestResult, tok: int, final: bool) -> None:
        """Append + stream one token — the single place a token becomes
        visible, in both modes, so the output list, the TTFT stamp, and
        the ``on_token`` StreamEvent agree by construction (in order,
        exactly once; preemption/restore cannot double-emit because
        every snapshot path drains first and so captures
        post-emission state)."""
        res.output.append(tok)
        self.last_step["processed"] += 1
        now = self.clock()
        if res.first_token_us is None:
            res.first_token_us = now
        if self.on_token is not None:
            self.on_token(StreamEvent(uid=res.uid,
                                      index=len(res.output) - 1,
                                      token=tok, t_us=now, final=final))

    def drain(self) -> None:
        """Settle the overlapped loop's in-flight decode step, if any:
        block on its tokens and run its host bookkeeping (emission,
        retirement, budget/quota charges).  Public because anything
        doing checkpoint surgery from outside — tests, the router's
        work-stealing, a server shutting down — must see consistent
        slot state first; every internal snapshot/evict path calls it.
        No-op on a sync engine or when nothing is in flight."""
        step, self._inflight = self._inflight, None
        if step is not None:
            self._finish_inflight(step)

    def _finish_inflight(self, step: InflightStep) -> None:
        """Host half of a dispatched decode step: fetch its tokens (the
        deferred ``block_until_ready``) and interpret them against the
        DISPATCH-TIME slot snapshot.  A slot that retired after the
        dispatch (eos/budget is learned one step late) is skipped: its
        extra dispatched decode was wasted device work whose KV writes
        are invisible — overwritten before the slot's next activation,
        or absorbed by the paged garbage block — and whose token is
        dropped here, never emitted."""
        t0 = time.perf_counter()
        toks = step.host_fetch()
        wait = time.perf_counter() - t0
        eos = self.cfg.vocab - 1
        for slot, res, req in step.slots:
            if res.done or self.slot_req[slot] is not res:
                continue    # retired between dispatch and readback
            res.decode_s += step.dispatch_s + wait
            self.policy.charge(req.tenant, 1.0)
            tok = int(toks[slot])
            self.slot_budget[slot] -= 1
            done = self.slot_budget[slot] <= 0 or tok == eos
            self._emit(res, tok, final=done)
            if done:
                res.done = True
                self.active[slot] = False
                self.slot_req[slot] = None
                self.slot_meta[slot] = None
                if self.paged:
                    self._release_slot_blocks(slot)

    def _dispatch_overlapped(self) -> None:
        """Dispatch one fused decode step WITHOUT reading it back, then
        settle the PREVIOUS step while the device works (PR 4's
        double-buffered chunk prefill, generalized to decode).  The
        device-side argmax feeds ``cur_tokens`` as a device future, so
        step i+1's inputs never pass through the host and the only
        blocking transfer — the previous step's tokens — overlaps the
        device executing this one."""
        pend = {s for s, _, _ in self._inflight.slots} \
            if self._inflight is not None else set()
        if self.paged:
            # grow at DISPATCH time from the host length mirror (the
            # device lengths are still a future here): map the block
            # this step's ring write lands in.  Slots whose budget is
            # spent once the in-flight step lands are skipped — their
            # write is absorbed by the garbage block, and mapping it
            # would overdraw the admission-time reservation.
            for slot in range(self.max_slots):
                if not self.active[slot]:
                    continue
                if self.slot_budget[slot] \
                        - (1 if slot in pend else 0) <= 0:
                    continue
                before = len(self._slot_blocks[slot])
                self._ensure_blocks(
                    slot, int(self._len_host[slot]) % self.cache_len)
                if len(self._slot_blocks[slot]) != before:
                    self._sync_table_row(slot)
        t0 = time.perf_counter()
        if self.paged:
            logits, kv_pool = self._decode(
                (self.params, self.kv_pool, self.block_tables,
                 self.cur_tokens, self.lengths))
            self.kv_pool = self._pin_kv(kv_pool)
        else:
            logits, cache = self._decode(
                (self.params, self.cache, self.cur_tokens, self.lengths))
            self.cache = self._pin_kv(cache)
        toks = self._argmax(logits)
        self.lengths = self.lengths + 1
        self._len_host += 1
        self.cur_tokens = self._pin_repl(toks[:, None])
        self.last_step["decoded"] = True
        prev, self._inflight = self._inflight, InflightStep(
            tokens=toks,
            slots=[(s, self.slot_req[s], self.slot_meta[s])
                   for s in range(self.max_slots) if self.active[s]],
            dispatch_s=time.perf_counter() - t0)
        if prev is not None:
            self._finish_inflight(prev)

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """One engine tick: advance chunked prefills by ONE chunk each,
        admit (policy order, displacing a running victim when the
        preemption policy says so), then one fused decode step over the
        active slots.  Returns True if work remains.

        The queue is re-keyed at every free slot, so a deadline that
        became urgent while other requests decoded is picked up here;
        with chunking on, a long prompt's prefill is interleaved
        through these ticks instead of monopolizing the engine."""
        self.last_step = {"prefill_tokens": [], "chunks": 0,
                          "decoded": False, "processed": 0}
        for slot in list(self._chunking):
            self._advance_chunk(slot)
        if self.queue:
            if self.overlap and self.preempt is not None:
                # settle the in-flight step before any admission or
                # displacement decision: a victim's checkpoint must
                # capture post-emission state, and a retirement still
                # in flight may free the slot the queue needs
                self.drain()
            now = self.clock()
            for slot in range(self.max_slots):
                if self.queue and not self.active[slot] \
                        and slot not in self._chunking:
                    if self.paged:
                        # admission control: the policy's pick only
                        # takes the slot if its worst case fits the
                        # pool's free blocks (restores are pre-pinned)
                        ci = self.policy.select(self.queue, now)
                        if not self._paged_admissible(self.queue[ci]):
                            break
                        self._admit(self.queue.pop(ci), slot)
                    else:
                        self._admit(self.policy.pop(self.queue, now),
                                    slot)
            # displacement: every slot busy, queue still holding work —
            # let the preemption policy evict a running victim for the
            # queue's policy-first candidate (strict-improvement
            # contract bounds this loop by the slot count)
            if self.preempt is not None:
                for _ in range(self.max_slots):
                    if not self.queue:
                        break
                    running = ([(s, self._chunking[s].req)
                                for s in sorted(self._chunking)]
                               + [(s, self.slot_meta[s])
                                  for s in range(self.max_slots)
                                  if self.active[s]])
                    if not running:
                        break
                    ci = self.policy.select(self.queue, now)
                    cand = self.queue[ci]
                    vi = self.preempt.victim([r for _, r in running],
                                             cand, now)
                    if vi is None:
                        break
                    if self.paged and not self._paged_admissible(cand):
                        break   # evicting frees no blocks (they pin
                        # to the checkpoint), so check BEFORE evicting
                    self.queue.pop(ci)
                    slot = running[vi][0]
                    self._evict(slot)
                    self._admit(cand, slot)
        if self.overlap and self.active.any() \
                and self._inflight is not None:
            pend = {s for s, _, _ in self._inflight.slots}
            if all(self.slot_budget[s] - (1 if s in pend else 0) <= 0
                   for s in range(self.max_slots) if self.active[s]):
                # every active slot's budget is spent once the
                # in-flight step lands: drain instead of dispatching
                # a step whose every token would be dropped
                self.drain()
        if not self.active.any():
            self.drain()
            return bool(self.active.any() or self.queue
                        or self._chunking)
        if self.overlap:
            self._dispatch_overlapped()
            return bool(self.active.any() or self.queue
                        or self._chunking or self._inflight is not None)
        t0 = time.perf_counter()
        if self.paged:
            logits, kv_pool = self._decode(
                (self.params, self.kv_pool, self.block_tables,
                 self.cur_tokens, self.lengths))
            self.kv_pool = self._pin_kv(kv_pool)
        else:
            logits, cache = self._decode(
                (self.params, self.cache, self.cur_tokens, self.lengths))
            self.cache = self._pin_kv(cache)
        dt = time.perf_counter() - t0
        self.last_step["decoded"] = True
        toks = self._sample(logits, 0.0)
        self.lengths = self.lengths + 1
        self._len_host += 1
        lens_host = np.asarray(self.lengths)
        new_cur = np.array(self.cur_tokens)    # writable host copy
        eos = self.cfg.vocab - 1
        for slot in range(self.max_slots):
            if not self.active[slot]:
                continue
            res = self.slot_req[slot]
            res.decode_s += dt
            self.policy.charge(self.slot_meta[slot].tenant, 1.0)
            tok = int(toks[slot])
            self.slot_budget[slot] -= 1
            new_cur[slot, 0] = tok
            done = self.slot_budget[slot] <= 0 or tok == eos
            self._emit(res, tok, final=done)
            if done:
                res.done = True
                self.active[slot] = False
                self.slot_req[slot] = None
                self.slot_meta[slot] = None
                if self.paged:
                    self._release_slot_blocks(slot)
            elif self.paged:
                # grow on demand: map the block the NEXT decode step's
                # ring write lands in (covered by the reservation)
                before = len(self._slot_blocks[slot])
                self._ensure_blocks(
                    slot, int(lens_host[slot]) % self.cache_len)
                if len(self._slot_blocks[slot]) != before:
                    self._sync_table_row(slot)
        self.cur_tokens = self._pin_repl(jnp.asarray(new_cur))
        return bool(self.active.any() or self.queue or self._chunking)

    def run(self, max_steps: int = 10_000) -> Dict[int, RequestResult]:
        steps = 0
        while self.step():
            steps += 1
            if steps > max_steps:
                raise RuntimeError("serving loop did not converge")
        return self.results
