"""Batched serving engine — the pod-scale analogue of the TF Micro
invoke loop (paper §4.1), with the same allocation discipline:

  * ALL buffers (decode slots, KV cache, sampling state) are created at
    engine construction — nothing allocates inside the serving loop
    (the paper's "no allocation after init" invariant, C3);
  * cache capacity is budgeted through the SAME TwoStackArena +
    memory-planner machinery the micro interpreter uses: KV is a
    persistent (interpreter-lifetime) allocation, prefill scratch is a
    function-lifetime head allocation released between requests;
  * continuous batching: fixed decode slots, requests admitted as slots
    free up, one fused decode step advances every active slot;
  * the compiled prefill/decode steps resolve through the op registry
    tag chain (``("pallas", "reference")`` by default, §4.7–4.8) —
    vendor-optimized serving kernels shadow the reference ones per-op
    with no engine changes, exactly like the micro interpreter's
    ``TAGS=`` build mechanism.

Compile-once invariants (what callers may rely on):

  * **traced once** — the decode step is jitted at engine construction
    with the resolved registration's eval, context, and OpDef bound;
    the prefill step is jitted once per prompt-length *bucket* when
    bucketing is active (the default for dense/vlm) and once per
    distinct prompt length otherwise.  Model family, cache layout,
    slot count, and window are baked in then.
  * **donated** — nothing in this engine: the KV cache and sampling
    state are carried functionally (cache in, cache out) so a step can
    be replayed; the ARENA accounts capacity (KV is an
    interpreter-lifetime tail allocation) but does not back device
    buffers here.
  * **may vary per call** — token values, per-slot lengths, and which
    slots are live.  Admitting a request writes ONLY slot bookkeeping
    and cache rows; it never retraces, which is what keeps continuous
    batching allocation-free inside the loop.

Two host-side degrees of freedom ride on top (docs/SCHEDULING.md):

  * **admission order is policy-driven** — a ``SchedulingPolicy``
    (FIFO / priority-with-aging / EDF over ``Request.deadline_us``)
    picks which queued request takes a free slot.  Policies reorder the
    Python queue only; masks, shapes, and programs are untouched, so
    changing policy never recompiles.
  * **bucketed prefill** — prompt lengths are quantized to power-of-two
    buckets (``BucketTable``): the prompt is right-padded to its bucket
    and the prefill step compiles once per *bucket*, not per *length*.
    Safe for families whose decode masks the KV cache by per-slot
    length AND whose prefill math is per-position (dense/vlm): padded
    rows are positionally masked to -1e30 before softmax, so decoded
    tokens are bit-identical to the exact-length path (asserted in
    tests/test_scheduling.py).  SSM and hybrid families keep
    exact-length prefill — their recurrent state integrates every
    input position, masked or not — and so does MoE, whose expert
    capacity is a function of the token count (padding could retain a
    token the exact-length run's capacity would drop).
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arena import TwoStackArena, align_up
from repro.core.executor import BucketTable
from repro.core.op_resolver import MicroMutableOpResolver
from repro.core.schema import OpCode, OpDef
from repro.kernels import ops as _vendor_kernels  # registers tag="pallas"
from repro.models.common import ModelConfig
from repro.models.registry import ModelBundle

from . import ops as serving_ops  # registers tag="reference" serving ops
from .scheduling import SchedulingPolicy, get_policy

DEFAULT_TAGS = ("pallas", "reference")

# families whose decode masks the KV cache by per-slot length, making
# right-padded (bucketed) prefill bit-identical to exact-length
# prefill.  NOT "moe": expert capacity is computed from the token
# count, so padding could keep a token the exact-length run drops.
# NOT "ssm"/"hybrid": recurrent state integrates every position.
BUCKETED_FAMILIES = ("dense", "vlm")


def default_clock() -> int:
    """Host time in µs — the clock policies age/deadline against.
    Engines and hosts accept a ``clock`` override so the arrival
    benchmark can drive the same scheduling code on virtual time."""
    return time.monotonic_ns() // 1000


@dataclasses.dataclass
class Request:
    """One pod-scale generation request: a prompt plus decode budget,
    and the scheduling fields admission policies key on (``priority``:
    lower admits first; ``deadline_us``: absolute host µs for EDF;
    ``arrival_us``: stamped at submit() when not provided)."""

    uid: int
    tokens: np.ndarray                  # (prompt_len,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0            # 0 = greedy
    extras: Optional[Dict[str, np.ndarray]] = None   # vision / frames
    priority: int = 0                   # lower = more urgent
    deadline_us: Optional[int] = None   # absolute host time, EDF key
    arrival_us: Optional[int] = None    # stamped at submit()


@dataclasses.dataclass
class RequestResult:
    """Accumulated outcome of a Request: emitted tokens and timings."""

    uid: int
    prompt_len: int
    output: List[int] = dataclasses.field(default_factory=list)
    prefill_s: float = 0.0
    decode_s: float = 0.0
    done: bool = False


def _cache_bytes(tree: Any) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


class ServingEngine:
    """One model, ``max_slots`` concurrent sequences."""

    def __init__(self, bundle: ModelBundle, params: Any, *,
                 max_slots: int = 4, cache_len: int = 256,
                 arena: Optional[TwoStackArena] = None,
                 arena_bytes: Optional[int] = None, seed: int = 0,
                 tags: Sequence[str] = DEFAULT_TAGS,
                 policy: Any = None, clock=None,
                 prefill_buckets: Any = None):
        self.bundle = bundle
        self.cfg = bundle.cfg
        self.params = params
        self.max_slots = max_slots
        self.cache_len = cache_len
        self.policy: SchedulingPolicy = get_policy(policy)
        self.clock = clock if clock is not None else default_clock
        # prefill_buckets: None/True = auto (on for length-masked-
        # decode families, when the cache can hold at least the
        # smallest bucket), False = off, or a (shared) BucketTable
        self.bucket_table: Optional[BucketTable] = None
        if prefill_buckets is None or prefill_buckets is True:
            if self.cfg.family in BUCKETED_FAMILIES and cache_len >= 8:
                self.bucket_table = BucketTable(min_bucket=8,
                                                max_bucket=cache_len)
        elif prefill_buckets is not False:
            if not isinstance(prefill_buckets, BucketTable):
                raise TypeError(
                    f"prefill_buckets must be a BucketTable, True, "
                    f"False, or None, got {prefill_buckets!r}")
            if self.cfg.family not in BUCKETED_FAMILIES:
                raise ValueError(
                    f"bucketed prefill is only bit-safe for "
                    f"{BUCKETED_FAMILIES} families, not "
                    f"{self.cfg.family!r}")
            self.bucket_table = prefill_buckets
        dtype = self.cfg.jnp_dtype()

        # --- arena accounting (C3/C4): KV is interpreter-lifetime ----
        cache = bundle.empty_cache(max_slots, cache_len, dtype)
        kv_bytes = _cache_bytes(cache)
        if arena is None:
            arena = TwoStackArena(arena_bytes or align_up(
                kv_bytes + (64 << 10)) * 2)
        self.arena = arena
        self.kv_offset = arena.allocate_persistent(kv_bytes, tag="kv_cache")
        self.cache = cache

        # --- slot bookkeeping (host side, fixed size) -----------------
        self.slot_req: List[Optional[RequestResult]] = [None] * max_slots
        self.slot_budget = np.zeros(max_slots, np.int64)
        self.lengths = jnp.zeros((max_slots,), jnp.int32)
        self.cur_tokens = jnp.zeros((max_slots, 1), jnp.int32)
        self.active = np.zeros(max_slots, bool)
        self.rng = np.random.default_rng(seed)
        self.queue: List[Request] = []
        self.results: Dict[int, RequestResult] = {}

        # --- compiled steps (init-time, like interpreter prepare) -----
        # Resolve prefill/decode through the op registry tag chain: the
        # serving analogue of MicroMutableOpResolver.add() at model load.
        # prepare() runs once here (it may bake family decisions into
        # op_data); eval is jitted with context and op bound, so the
        # traced step is a pure function of (params, cache, tokens, ...).
        self.resolver = MicroMutableOpResolver(tags).add_many(
            [OpCode.SERVING_PREFILL, OpCode.SERVING_DECODE])
        window = self.cfg.sliding_window
        self._prefill_op = OpDef(OpCode.SERVING_PREFILL, (), (),
                                 params={"cache_len": cache_len,
                                         "window": window})
        self._decode_op = OpDef(OpCode.SERVING_DECODE, (), (),
                                params={"window": window})
        prefill_reg = self.resolver.resolve(OpCode.SERVING_PREFILL)
        decode_reg = self.resolver.resolve(OpCode.SERVING_DECODE)
        pctx = serving_ops.ServingContext(bundle)
        prefill_ctx = serving_ops.ServingContext(
            bundle, prefill_reg.prepare(pctx, self._prefill_op).op_data)
        decode_ctx = serving_ops.ServingContext(
            bundle, decode_reg.prepare(pctx, self._decode_op).op_data)
        self._decode = jax.jit(functools.partial(
            decode_reg.eval, decode_ctx, self._decode_op))
        # prefill jits once per prompt-length BUCKET when bucket_table
        # is set (BUCKETED_FAMILIES only: decode masks KV by length,
        # so padding is invisible); exact-length otherwise — see the
        # BUCKETED_FAMILIES comment for why moe/ssm/hybrid are out
        self._prefill = jax.jit(functools.partial(
            prefill_reg.eval, prefill_ctx, self._prefill_op))

    def prefill_compiles(self) -> int:
        """How many distinct prefill programs were traced — the
        trace-count hook.  With bucketing on, this is the number of
        buckets HIT, independent of how many prompt lengths arrived."""
        from repro.core.executor import jit_cache_size
        return jit_cache_size(self._prefill)

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.arrival_us is None:
            req.arrival_us = self.clock()
        self.queue.append(req)
        self.results[req.uid] = RequestResult(uid=req.uid,
                                              prompt_len=len(req.tokens))

    def _insert_cache(self, slot: int, new_cache: Any) -> None:
        """Place a prefilled (batch=1) cache into slot ``slot``."""
        def ins(full, one):
            # batch dim differs per leaf family; find the axis whose size
            # is max_slots and the matching axis of size 1 in `one`
            for ax in range(full.ndim):
                if full.shape[ax] == self.max_slots \
                        and one.shape[ax] == 1:
                    idx = [slice(None)] * full.ndim
                    start = [0] * full.ndim
                    start[ax] = slot
                    return jax.lax.dynamic_update_slice(
                        full, one.astype(full.dtype), tuple(start))
            raise ValueError((full.shape, one.shape))
        self.cache = jax.tree.map(ins, self.cache, new_cache)

    def _padded_prompt(self, tokens: np.ndarray) -> np.ndarray:
        """Right-pad the prefill prompt to its power-of-two bucket so
        the prefill step compiles once per bucket.  Padded positions
        produce KV rows the length-masked decode can never attend to
        (and the first decode steps overwrite them ring-slot by ring
        slot), so the decoded tokens are bit-identical to exact-length
        prefill.  Prompts longer than the largest bucket that fits the
        cache fall back to exact length (the ring-wrap case)."""
        s = len(tokens)
        room = self.cache_len - (self.cfg.n_vision_tokens
                                 if self.cfg.family == "vlm" else 0)
        padded = self.bucket_table.fit(s)
        if padded is None or padded > room:
            return tokens                   # over-cap: exact length
        self.bucket_table.bucket(s)         # committed: count the hit
        if padded == s:
            return tokens                   # already bucket-shaped
        return np.concatenate(
            [tokens, np.zeros(padded - s, tokens.dtype)])

    def _prefill_one(self, req: Request, slot: int) -> None:
        """Prefill tokens[:-1], then hand the LAST prompt token to the
        decode loop: the first decode step integrates it (KV write /
        SSD state update) and emits the first new token — one uniform
        decode path for every family, no double-integration for SSM."""
        t0 = time.perf_counter()
        n = len(req.tokens)
        if n >= 2:
            prompt = np.asarray(req.tokens[:-1])
            if self.bucket_table is not None:
                prompt = self._padded_prompt(prompt)
            batch = {"tokens": jnp.asarray(prompt[None])}
            if req.extras:
                for k, v in req.extras.items():
                    batch[k] = jnp.asarray(v[None])
            _, cache1 = self._prefill((self.params, batch))
        else:   # single-token prompt: slot starts from a fresh cache
            cache1 = self.bundle.empty_cache(1, self.cache_len,
                                             self.cfg.jnp_dtype())
        self._insert_cache(slot, cache1)
        res = self.results[req.uid]
        res.prefill_s = time.perf_counter() - t0
        last_pos = n - 1 + (self.cfg.n_vision_tokens
                            if self.cfg.family == "vlm" else 0)
        self.slot_req[slot] = res
        self.slot_budget[slot] = req.max_new_tokens
        self.active[slot] = True
        self.lengths = self.lengths.at[slot].set(last_pos)
        self.cur_tokens = self.cur_tokens.at[slot, 0].set(
            int(req.tokens[-1]))

    def _sample(self, logits, temperature: float) -> np.ndarray:
        logits = np.asarray(logits[:, :self.cfg.vocab], np.float32)
        if temperature <= 0:
            return logits.argmax(-1)
        z = logits / temperature
        z = z - z.max(-1, keepdims=True)
        p = np.exp(z)
        p /= p.sum(-1, keepdims=True)
        return np.array([self.rng.choice(len(row), p=row) for row in p])

    # ------------------------------------------------------------------
    def step(self) -> bool:
        """Admit + one decode step.  Returns True if work remains.
        Admission order is the engine's scheduling policy — the queue
        is re-keyed at every free slot, so a deadline that became
        urgent while other requests decoded is picked up here."""
        if self.queue and not self.active.all():
            now = self.clock()
            for slot in range(self.max_slots):
                if not self.active[slot] and self.queue:
                    self._prefill_one(self.policy.pop(self.queue, now),
                                      slot)
        if not self.active.any():
            return bool(self.queue)
        t0 = time.perf_counter()
        logits, self.cache = self._decode(
            (self.params, self.cache, self.cur_tokens, self.lengths))
        dt = time.perf_counter() - t0
        toks = self._sample(logits, 0.0)
        self.lengths = self.lengths + 1
        new_cur = np.array(self.cur_tokens)    # writable host copy
        eos = self.cfg.vocab - 1
        for slot in range(self.max_slots):
            if not self.active[slot]:
                continue
            res = self.slot_req[slot]
            res.decode_s += dt
            tok = int(toks[slot])
            res.output.append(tok)
            self.slot_budget[slot] -= 1
            new_cur[slot, 0] = tok
            if self.slot_budget[slot] <= 0 or tok == eos:
                res.done = True
                self.active[slot] = False
                self.slot_req[slot] = None
        self.cur_tokens = jnp.asarray(new_cur)
        return bool(self.active.any() or self.queue)

    def run(self, max_steps: int = 10_000) -> Dict[int, RequestResult]:
        steps = 0
        while self.step():
            steps += 1
            if steps > max_steps:
                raise RuntimeError("serving loop did not converge")
        return self.results
