"""Typed errors for family/feature gating in the serving layer.

The engine's fast paths (bucketed prefill, chunked prefill, paged KV)
are family-aware rather than family-excluded, but a few combinations
stay genuinely unsupported (e.g. chunked prefill for MoE, whose
expert capacity depends on the token count integrated so far, and
bucketed prefill for SSM/hybrid, whose recurrent state integrates
every input position).  Those guards raise ``UnsupportedFamilyError``
— a ``ValueError`` subclass so pre-existing ``except ValueError``
call sites keep working — naming the family, the feature, and the
families that DO support it, instead of a free-text message a caller
cannot dispatch on.

This module sits below both ``serving.engine`` and ``serving.ops``
(and is imported lazily from ``kernels.ops``, which layers beneath
the serving package) so every guard site can share one type without
import cycles.
"""

from __future__ import annotations

from typing import Sequence


class UnsupportedFamilyError(ValueError):
    """A serving fast path was requested for a model family that
    cannot support it.

    Attributes: ``family`` (the offending config family), ``feature``
    (the fast path that was requested), ``supported`` (the families
    the feature is available for).  Subclasses ``ValueError`` so the
    pre-typed guard contract (``pytest.raises(ValueError)``) is
    unchanged.
    """

    def __init__(self, family: str, feature: str,
                 supported: Sequence[str] = ()):
        self.family = str(family)
        self.feature = str(feature)
        self.supported = tuple(supported)
        msg = f"family {self.family!r} does not support {self.feature}"
        if self.supported:
            msg += f" (supported families: {', '.join(self.supported)})"
        super().__init__(msg)
