"""Serving macro-kernels: prefill and decode as registry ops.

The pod-scale engine used to build its compiled steps as ad-hoc
``jax.jit(lambda ...)`` closures, bypassing the vendor-tag kernel
registry entirely — so a platform shipping optimized serving kernels
(§4.7–4.8) could never reach the serving path.  This module registers
the *reference* implementations of two macro-ops:

  * ``OpCode.SERVING_PREFILL`` — one prompt through the model, emitting
    the last-token logits and a populated KV/state cache;
  * ``OpCode.SERVING_DECODE``  — one fused decode step advancing every
    active slot.

Both simply delegate to the family bundle's ``prefill``/``decode`` —
the readable pure-jnp path, the serving analogue of the paper's
reference kernels.  A vendor library (see ``repro.kernels.ops``)
registers ``tag="pallas"`` implementations of the same opcodes;
``ServingEngine`` resolves through the tag priority chain
(``("pallas", "reference")``) so optimized kernels shadow these per-op
and fall back when a family has no optimized path — the exact
``TAGS="cmsis-nn"`` build mechanism, now applied at pod scale.

The contract mirrors the micro C-API: ``prepare(ctx, op)`` runs once at
engine init (it may inspect the model family and bake decisions into
``op_data``); ``eval(ctx, op, inputs)`` runs inside the jitted step and
must be a pure function of ``inputs``.
"""

from __future__ import annotations

from typing import Any, Optional

from repro.core.op_resolver import PrepareResult, register_op
from repro.core.schema import OpCode

from .errors import UnsupportedFamilyError

# families each fast path supports (the engine mirrors these; see
# docs/SCHEDULING.md §2 and docs/PREEMPTION.md §4 for the safety
# arguments per family)
CHUNKED_FAMILIES = ("dense", "vlm", "ssm", "hybrid")
RECURRENT_FAMILIES = ("ssm", "hybrid")
PAGED_FAMILIES = ("dense", "moe", "vlm")
# quantized serving (docs/QUANTIZATION.md): weight-only quantization
# works wherever the bundle's decode accepts a params tree (everything
# but audio, whose serving path is the micro pipeline); the int8 KV
# cache additionally needs the dense (KH, C, dh) ring layout
WEIGHT_QUANT_FAMILIES = ("dense", "moe", "vlm", "ssm", "hybrid")
KV_QUANT_FAMILIES = ("dense", "moe", "vlm")


class ServingContext:
    """Pod-scale Prepare/EvalContext analogue: hands the kernel the model
    bundle (family, config, reference step functions) instead of tensor
    specs, plus the ``op_data`` its prepare() baked at init."""

    def __init__(self, bundle: Any, op_data: Any = None):
        self.bundle = bundle
        self.op_data = op_data


@register_op(OpCode.SERVING_PREFILL, tag="reference")
class RefServingPrefill:
    """Reference prefill macro-kernel: one prompt through the family
    bundle's pure-jnp ``prefill``, emitting last-token logits + cache."""

    @staticmethod
    def prepare(ctx: ServingContext, op) -> PrepareResult:
        return PrepareResult(output_specs=[])

    @staticmethod
    def eval(ctx: ServingContext, op, inputs):
        params, batch = inputs
        return ctx.bundle.prefill(params, batch,
                                  cache_len=op.params["cache_len"],
                                  window=op.params.get("window"))


@register_op(OpCode.SERVING_DECODE, tag="reference")
class RefServingDecode:
    """Reference decode macro-kernel: one fused step advancing every
    active slot via the family bundle's pure-jnp ``decode``."""

    @staticmethod
    def prepare(ctx: ServingContext, op) -> PrepareResult:
        return PrepareResult(output_specs=[])

    @staticmethod
    def eval(ctx: ServingContext, op, inputs):
        params, cache, tokens, lengths = inputs
        return ctx.bundle.decode(params, cache, tokens, lengths,
                                 window=op.params.get("window"))


@register_op(OpCode.SERVING_PREFILL_CHUNK, tag="reference")
class RefServingPrefillChunk:
    """Reference chunked-prefill macro-kernel: one prompt CHUNK at a
    traced start offset through ``lm_prefill_chunk``, updating the
    request's cache in place (no logits — the engine hands the last
    prompt token to decode).

    prepare() bakes the family decision into ``op_data``: dense runs
    the plain backbone, vlm adds Gemma's sqrt(d_model) embedding scale
    (its vision prefix was integrated by the FIRST chunk, which goes
    through the ordinary SERVING_PREFILL path).  Recurrent families
    (ssm/hybrid) chunk through SERVING_PREFILL_CHUNK_STATE instead —
    this KV-offset variant assumes a dense ring cache — and MoE
    cannot chunk at all (expert capacity depends on the token count
    integrated so far), so prepare() raises the typed
    ``UnsupportedFamilyError`` for both (docs/PREEMPTION.md §4)."""

    @staticmethod
    def prepare(ctx: ServingContext, op) -> PrepareResult:
        import math

        family = ctx.bundle.cfg.family
        if family == "vlm":
            scale: Optional[float] = math.sqrt(ctx.bundle.cfg.d_model)
        elif family == "dense":
            scale = None
        else:
            raise UnsupportedFamilyError(
                family, "KV-offset chunked prefill "
                        "(SERVING_PREFILL_CHUNK)",
                supported=("dense", "vlm"))
        return PrepareResult(output_specs=[], op_data={"scale": scale})

    @staticmethod
    def eval(ctx: ServingContext, op, inputs):
        from repro.models.lm import lm_prefill_chunk

        params, cache, tokens, start = inputs
        return lm_prefill_chunk(params, ctx.bundle.cfg, cache, tokens,
                                start, window=op.params.get("window"),
                                embed_scale=ctx.op_data["scale"])


def _paged_family_scale(cfg) -> Optional[float]:
    """Shared family gate for the paged serving ops: paged KV needs the
    dense (KH, C, dh) ring layout, so ssm/hybrid/audio are out."""
    import math

    if cfg.family == "vlm":
        return math.sqrt(cfg.d_model)
    if cfg.family in ("dense", "moe"):
        return None
    raise UnsupportedFamilyError(
        cfg.family, "paged KV (requires a dense (KH, C, dh) cache "
                    "layout)", supported=PAGED_FAMILIES)


@register_op(OpCode.SERVING_DECODE_PAGED, tag="reference")
class RefServingDecodePaged:
    """Reference paged decode macro-kernel: one fused step over the
    shared physical block pool, with each slot's KV placement given by
    its row of the traced block-table argument.  Delegates to
    ``lm_decode_paged``, whose attention gathers a slot's blocks back
    to a contiguous view and runs the contiguous reference einsums —
    the bit-identity oracle for the pallas-tagged twin."""

    @staticmethod
    def prepare(ctx: ServingContext, op) -> PrepareResult:
        return PrepareResult(
            output_specs=[],
            op_data={"scale": _paged_family_scale(ctx.bundle.cfg)})

    @staticmethod
    def eval(ctx: ServingContext, op, inputs):
        from repro.models.lm import lm_decode_paged

        params, pool, tables, tokens, lengths = inputs
        return lm_decode_paged(params, ctx.bundle.cfg, pool, tables,
                               tokens, lengths,
                               embed_scale=ctx.op_data["scale"])


@register_op(OpCode.SERVING_PREFILL_CHUNK_PAGED, tag="reference")
class RefServingPrefillChunkPaged:
    """Reference paged chunked-prefill macro-kernel: gathers ONE slot's
    blocks to a contiguous batch=1 cache, runs the exact contiguous
    chunk math (``lm_prefill_chunk``), and scatters back — so chunked
    prefill into a paged pool stays token-identical to the contiguous
    chunked path.  Same dense/vlm bit-safety gate as the contiguous
    chunk op (moe routing depends on token count, so unlike decode it
    cannot chunk even though its cache layout is paged-compatible)."""

    @staticmethod
    def prepare(ctx: ServingContext, op) -> PrepareResult:
        family = ctx.bundle.cfg.family
        if family not in ("dense", "vlm"):
            raise UnsupportedFamilyError(
                family, "paged chunked prefill "
                        "(SERVING_PREFILL_CHUNK_PAGED)",
                supported=("dense", "vlm"))
        return PrepareResult(
            output_specs=[],
            op_data={"scale": _paged_family_scale(ctx.bundle.cfg)})

    @staticmethod
    def eval(ctx: ServingContext, op, inputs):
        from repro.models.lm import lm_prefill_chunk_paged

        params, pool, table_row, tokens, start = inputs
        return lm_prefill_chunk_paged(
            params, ctx.bundle.cfg, pool, table_row, tokens, start,
            window=op.params.get("window"),
            embed_scale=ctx.op_data["scale"])


@register_op(OpCode.SERVING_PREFILL_CHUNK_STATE, tag="reference")
class RefServingPrefillChunkState:
    """Reference recurrent-state chunked-prefill macro-kernel: one
    right-padded prompt chunk through ``ssm_prefill_chunk`` /
    ``hybrid_prefill_chunk``, carrying the batch=1 recurrent cache
    (conv window + SSD state, plus shared-attn KV for hybrid) as a
    traced argument — a chunk boundary is just a state checkpoint.

    Inputs are ``(params, cache, tokens, start, n_real)`` with
    ``start`` (the chunk's absolute position, used only by hybrid's
    shared attention) and ``n_real`` (the chunk's true token count;
    the padded tail is an exact state no-op) both TRACED scalars, so
    one compiled program serves every chunk of every prompt.  Only the
    recurrent families resolve here; everything else keeps the
    KV-offset SERVING_PREFILL_CHUNK op."""

    @staticmethod
    def prepare(ctx: ServingContext, op) -> PrepareResult:
        family = ctx.bundle.cfg.family
        if family not in RECURRENT_FAMILIES:
            raise UnsupportedFamilyError(
                family, "recurrent-state chunked prefill "
                        "(SERVING_PREFILL_CHUNK_STATE)",
                supported=RECURRENT_FAMILIES)
        return PrepareResult(output_specs=[], op_data={"family": family})

    @staticmethod
    def eval(ctx: ServingContext, op, inputs):
        from repro.models.hybrid import hybrid_prefill_chunk
        from repro.models.ssm import ssm_prefill_chunk

        params, cache, tokens, start, n_real = inputs
        cfg = ctx.bundle.cfg
        if ctx.op_data["family"] == "hybrid":
            return hybrid_prefill_chunk(params, cfg, cache, tokens,
                                        start, n_real,
                                        window=op.params.get("window"))
        return ssm_prefill_chunk(params, cfg, cache, tokens, n_real)


# ---------------------------------------------------------------------------
# quantized serving macro-ops (docs/QUANTIZATION.md)
# ---------------------------------------------------------------------------

def _quant_family_gate(cfg, op) -> dict:
    """Shared prepare() gate for the quantized serving ops: bakes the
    quantization layout (weight dtype, KV quant, paged-ness, vlm embed
    scale) into op_data and raises the typed refusal for families the
    layout cannot serve."""
    import math

    family = cfg.family
    kv_q = bool(op.params.get("kv_q"))
    paged = bool(op.params.get("paged"))
    if family not in WEIGHT_QUANT_FAMILIES:
        raise UnsupportedFamilyError(
            family, "quantized serving (SERVING_*_Q)",
            supported=WEIGHT_QUANT_FAMILIES)
    if kv_q and family not in KV_QUANT_FAMILIES:
        raise UnsupportedFamilyError(
            family, "int8 KV cache (requires a dense (KH, C, dh) "
                    "cache layout)", supported=KV_QUANT_FAMILIES)
    if paged:
        _paged_family_scale(cfg)       # same typed refusal as unquantized
    scale = math.sqrt(cfg.d_model) if family == "vlm" else None
    return {"kv_q": kv_q, "paged": paged, "scale": scale,
            "lm_path": family in KV_QUANT_FAMILIES,
            "weight_dtype": op.params.get("weight_dtype", "int8")}


@register_op(OpCode.SERVING_PREFILL_Q, tag="reference")
class RefServingPrefillQ:
    """Reference quantized prefill: dequantize the weight tree and run
    the family bundle's fp ``prefill`` (prefill is compute-bound — the
    quantization win is decode-side HBM traffic, so prefill pays one
    transient dequant instead of a second quantized codepath), then,
    when the engine serves an int8 KV cache, quantize the populated
    cache on the way out — the SAME ``quantize_kv_heads`` the decode
    step applies to new tokens, so prefill-then-decode stays exactly
    the cache decode would have built."""

    @staticmethod
    def prepare(ctx: ServingContext, op) -> PrepareResult:
        return PrepareResult(output_specs=[],
                             op_data=_quant_family_gate(ctx.bundle.cfg, op))

    @staticmethod
    def eval(ctx: ServingContext, op, inputs):
        from repro.models.lm_quant import dequant_params, quantize_cache

        params, batch = inputs
        fp = dequant_params(params, ctx.bundle.cfg.jnp_dtype())
        logits, cache = ctx.bundle.prefill(
            fp, batch, cache_len=op.params["cache_len"],
            window=op.params.get("window"))
        if ctx.op_data["kv_q"]:
            cache = quantize_cache(cache)
        return logits, cache


@register_op(OpCode.SERVING_DECODE_Q, tag="reference")
class RefServingDecodeQ:
    """Reference quantized decode: one fused step over the int8/int4
    weight tree.  LM-path families (dense/moe/vlm) run
    ``lm_decode_q``/``lm_decode_paged_q`` — weights dequantize per
    layer INSIDE the scan body, so at most one layer's float weights
    exist at a time and the resident params stay quantized; recurrent
    families (weight-only mode) dequantize the tree and delegate to
    the bundle's fp ``decode``.  The paged-ness and KV-quant layout
    ride ``op.params`` — two opcodes cover the whole quantized matrix,
    one compiled program per engine either way."""

    @staticmethod
    def prepare(ctx: ServingContext, op) -> PrepareResult:
        return PrepareResult(output_specs=[],
                             op_data=_quant_family_gate(ctx.bundle.cfg, op))

    @staticmethod
    def eval(ctx: ServingContext, op, inputs):
        from repro.models.lm_quant import (dequant_params, lm_decode_q,
                                           lm_decode_paged_q)

        cfg = ctx.bundle.cfg
        od = ctx.op_data
        if od["paged"]:
            params, pool, tables, tokens, lengths = inputs
            return lm_decode_paged_q(params, cfg, pool, tables, tokens,
                                     lengths, embed_scale=od["scale"],
                                     kv_q=od["kv_q"])
        params, cache, tokens, lengths = inputs
        if od["lm_path"]:
            return lm_decode_q(params, cfg, cache, tokens, lengths,
                               embed_scale=od["scale"], kv_q=od["kv_q"])
        fp = dequant_params(params, cfg.jnp_dtype())
        return ctx.bundle.decode(fp, cache, tokens, lengths,
                                 window=op.params.get("window"))
