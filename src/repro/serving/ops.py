"""Serving macro-kernels: prefill and decode as registry ops.

The pod-scale engine used to build its compiled steps as ad-hoc
``jax.jit(lambda ...)`` closures, bypassing the vendor-tag kernel
registry entirely — so a platform shipping optimized serving kernels
(§4.7–4.8) could never reach the serving path.  This module registers
the *reference* implementations of two macro-ops:

  * ``OpCode.SERVING_PREFILL`` — one prompt through the model, emitting
    the last-token logits and a populated KV/state cache;
  * ``OpCode.SERVING_DECODE``  — one fused decode step advancing every
    active slot.

Both simply delegate to the family bundle's ``prefill``/``decode`` —
the readable pure-jnp path, the serving analogue of the paper's
reference kernels.  A vendor library (see ``repro.kernels.ops``)
registers ``tag="pallas"`` implementations of the same opcodes;
``ServingEngine`` resolves through the tag priority chain
(``("pallas", "reference")``) so optimized kernels shadow these per-op
and fall back when a family has no optimized path — the exact
``TAGS="cmsis-nn"`` build mechanism, now applied at pod scale.

The contract mirrors the micro C-API: ``prepare(ctx, op)`` runs once at
engine init (it may inspect the model family and bake decisions into
``op_data``); ``eval(ctx, op, inputs)`` runs inside the jitted step and
must be a pure function of ``inputs``.
"""

from __future__ import annotations

from typing import Any, Optional

from repro.core.op_resolver import PrepareResult, register_op
from repro.core.schema import OpCode


class ServingContext:
    """Pod-scale Prepare/EvalContext analogue: hands the kernel the model
    bundle (family, config, reference step functions) instead of tensor
    specs, plus the ``op_data`` its prepare() baked at init."""

    def __init__(self, bundle: Any, op_data: Any = None):
        self.bundle = bundle
        self.op_data = op_data


@register_op(OpCode.SERVING_PREFILL, tag="reference")
class RefServingPrefill:
    """Reference prefill macro-kernel: one prompt through the family
    bundle's pure-jnp ``prefill``, emitting last-token logits + cache."""

    @staticmethod
    def prepare(ctx: ServingContext, op) -> PrepareResult:
        return PrepareResult(output_specs=[])

    @staticmethod
    def eval(ctx: ServingContext, op, inputs):
        params, batch = inputs
        return ctx.bundle.prefill(params, batch,
                                  cache_len=op.params["cache_len"],
                                  window=op.params.get("window"))


@register_op(OpCode.SERVING_DECODE, tag="reference")
class RefServingDecode:
    """Reference decode macro-kernel: one fused step advancing every
    active slot via the family bundle's pure-jnp ``decode``."""

    @staticmethod
    def prepare(ctx: ServingContext, op) -> PrepareResult:
        return PrepareResult(output_specs=[])

    @staticmethod
    def eval(ctx: ServingContext, op, inputs):
        params, cache, tokens, lengths = inputs
        return ctx.bundle.decode(params, cache, tokens, lengths,
                                 window=op.params.get("window"))
