"""Multitenant model hosting (paper §4.5, Figure 5) at pod scale.

Several ``ServingEngine`` instances share ONE TwoStackArena exactly the
way TF Micro lets multiple interpreters share one arena:

  * each model's KV cache is an interpreter-lifetime (tail/persistent)
    allocation — persistent sections STACK per tenant;
  * prefill/decode scratch is function-lifetime (head) — the
    nonpersistent section is sized to the LARGEST requirement across
    tenants and is reused because tenants run non-concurrently;
  * admission fails loudly (ArenaOverflowError) when the stacks would
    cross — the paper's capacity-error semantics.

Micro-models are first-class tenants too: ``add_micro_model`` admits a
µFB model served by an ``InterpreterPool`` — its persistents stack in
the same shared arena as the engines' KV caches, and every micro tenant
draws pooled nonpersistent buffers from one ``ArenaPool``, so B
requests advance per jitted dispatch (batch-granularity serving).
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core.arena import TwoStackArena, align_up
from repro.core.executor import ArenaPool, InterpreterPool
from repro.core.op_resolver import MicroMutableOpResolver
from repro.core.schema import MicroModel
from repro.models.registry import ModelBundle

from .engine import Request, RequestResult, ServingEngine


def _scratch_bytes(bundle: ModelBundle, max_prompt: int) -> int:
    """Head-section budget: activation scratch for the largest prefill."""
    cfg = bundle.cfg
    dt = 2 if cfg.dtype == "bfloat16" else 4
    # hidden + attention transients for one prompt (engine batch=1)
    return align_up(max_prompt * cfg.d_model * dt * 8)


class MultiTenantHost:
    """One arena, many models — never running concurrently."""

    def __init__(self, arena_bytes: int):
        self.arena = TwoStackArena(arena_bytes)
        self.engines: Dict[str, ServingEngine] = {}
        self.micro: Dict[str, InterpreterPool] = {}
        self._micro_pool = ArenaPool()
        self._scratch_high = 0

    def add_model(self, name: str, bundle: ModelBundle, params: Any, *,
                  max_slots: int = 2, cache_len: int = 128,
                  max_prompt: int = 64) -> ServingEngine:
        """Admit a tenant: its KV cache stacks persistently; the shared
        nonpersistent (head) section grows to the max requirement."""
        eng = ServingEngine(bundle, params, max_slots=max_slots,
                            cache_len=cache_len, arena=self.arena)
        scratch = _scratch_bytes(bundle, max_prompt)
        if scratch > self._scratch_high:
            # grow the shared head-section reservation to the new max
            self.arena.allocate_temp(scratch - self._scratch_high)
            self.arena.reset_temp()
            self._scratch_high = scratch
        self.engines[name] = eng
        return eng

    def add_micro_model(self, name: str, model: MicroModel,
                        resolver: MicroMutableOpResolver, *,
                        batch: int = 1) -> InterpreterPool:
        """Admit a µFB micro-model tenant served at batch granularity:
        its persistents stack in the shared arena under the engines' KV
        caches, and its pooled nonpersistent buffers come from the one
        ArenaPool all micro tenants share (they run non-concurrently)."""
        pool = InterpreterPool(model, resolver, batch,
                               host_arena=self.arena,
                               pool=self._micro_pool)
        self.micro[name] = pool
        return pool

    def run_micro(self, name: str,
                  requests: Sequence[Sequence[np.ndarray]]
                  ) -> List[np.ndarray]:
        """Serve ``requests`` (each a per-input list of arrays) through
        the named micro tenant, B lanes per jitted dispatch; returns the
        first output of each request in order.

        Requests are INDEPENDENT: inputs and variable-tensor state are
        reset between chunks, so a stateful model (e.g. SVDF) sees every
        request from its initial state.  Streaming tenants that need
        state carried across invocations should drive the
        InterpreterPool directly."""
        pool = self.micro[name]
        out: List[np.ndarray] = []
        for start in range(0, len(requests), pool.batch):
            chunk = requests[start:start + pool.batch]
            pool.clear_inputs()
            pool.reset_variable_tensors()
            for lane, req in enumerate(chunk):
                for pos, arr in enumerate(req):
                    pool.set_input(lane, pos, arr)
            pool.invoke()
            out.extend(pool.output(lane, 0) for lane in range(len(chunk)))
        return out

    def submit(self, name: str, req: Request) -> None:
        self.engines[name].submit(req)

    def run_all(self) -> Dict[str, Dict[int, RequestResult]]:
        """Round-robin the tenants until all queues drain (tenants are
        time-multiplexed — TF Micro's 'not concurrently' contract)."""
        out = {}
        pending = True
        while pending:
            pending = False
            for name, eng in self.engines.items():
                if eng.step():
                    pending = True
        for name, eng in self.engines.items():
            out[name] = eng.results
        return out

    def usage(self):
        return self.arena.usage()
