"""Multitenant model hosting (paper §4.5, Figure 5) at pod scale.

Several ``ServingEngine`` instances share ONE TwoStackArena exactly the
way TF Micro lets multiple interpreters share one arena:

  * each model's KV cache is an interpreter-lifetime (tail/persistent)
    allocation — persistent sections STACK per tenant;
  * prefill/decode scratch is function-lifetime (head) — the
    nonpersistent section is sized to the LARGEST requirement across
    tenants and is reused because tenants run non-concurrently;
  * admission fails loudly (ArenaOverflowError) when the stacks would
    cross — the paper's capacity-error semantics.

Micro-models are first-class tenants too, in two flavours:

  * ``add_micro_model`` — lockstep batch granularity: an
    ``InterpreterPool`` advances B identical lanes per jitted dispatch
    (``run_micro`` chunks a request list);
  * ``add_ragged_micro`` + ``submit_micro`` — request granularity: the
    tenant becomes a bucket of ONE shared ``RaggedInterpreterPool``.
    Requests are streams of frames; lanes are admitted as they free up,
    carry per-request continuation state across waves, and retire
    mid-flight without recompiling — so the micro path (e.g. the int8
    FC/SVDF families) and the pod engines drain through ONE scheduler,
    ``run_all``.

Scheduling (docs/SCHEDULING.md): the host owns ONE ``SchedulingPolicy``
(FIFO / priority-with-aging / EDF / per-tenant WFQ) and ONE ``clock``;
every engine it creates and every ragged micro queue admits through
them, so a deadline set on a pod ``Request`` and one set on a
``MicroRequest`` compete under the same rules.  It also owns the shared
``BucketTable`` pair: prompt-length buckets (engines compile prefill
once per bucket, and the bucket boundaries agree across tenants) and
lane-count buckets (ragged micro buckets round their lane counts so
nearby tenants share ``ArenaPool`` free lists).

Preemption (docs/PREEMPTION.md): give the host a ``PreemptionPolicy``
(``preempt="edf-displace"`` or a ``WFQDisplacePolicy``) and
``micro_step`` may EVICT a running lane when admission alone cannot
serve an urgent queued request: the victim's continuation state is
snapshotted host-side (``RaggedInterpreterPool.snapshot_lane``), the
lane retired, the victim re-queued; when the policy re-keys it to the
front of a free lane again, ``restore_lane`` resumes it bit-identically
from its checkpoint.  Preemption is lane-table surgery between
dispatches — the masked programs and their traced masks are untouched,
so preempt/resume cycles never recompile.

Compile-once invariants this module maintains:

  * **traced once** — each engine's prefill/decode step and each micro
    bucket's masked batched body are compiled at ``add_*`` time (tenant
    admission), never inside the serving loop.  Scheduling decisions
    (admission order) are host-side Python over the queues; they can
    never invalidate a trace.
  * **donated** — micro arena buffers and variable stacks cycle through
    the shared ``ArenaPool``; engine caches are carried functionally
    through the jitted decode step.
  * **may vary per call** — request content (tokens, frames), slot/lane
    occupancy masks, and step counters.  Admitting a TENANT (a new
    model) is the only act that allocates or compiles; admitting a
    REQUEST only flips lane-table state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core.arena import TwoStackArena, align_up
from repro.core.executor import (ArenaPool, BucketTable, InterpreterPool,
                                 LaneCheckpoint, RaggedInterpreterPool)
from repro.core.op_resolver import MicroMutableOpResolver
from repro.core.schema import MicroModel
from repro.models.registry import ModelBundle

from .engine import (BUCKETED_FAMILIES, CHUNKED_FAMILIES, Request,
                     RequestResult, ServingEngine, default_clock)
from .router import ReplicaRouter
from .scheduling import (PreemptionPolicy, SchedulingPolicy, get_policy,
                         get_preemption)


@dataclasses.dataclass
class MicroRequest:
    """A request-granularity micro-model job: ``frames[t]`` holds the
    per-input-position arrays the model consumes on its t-th invocation
    (one entry → single-shot; several → a streaming continuation).
    Carries the same scheduling fields as the pod ``Request`` so one
    policy orders both tenancies; ``tenant`` (defaulted to the micro
    tenant's name at submit) is the WFQ quota label."""

    uid: int
    frames: List[List[np.ndarray]]
    priority: int = 0                   # lower = more urgent
    deadline_us: Optional[int] = None   # absolute host time, EDF key
    arrival_us: Optional[int] = None    # stamped at submit_micro()
    tenant: str = ""                    # WFQ quota label


@dataclasses.dataclass
class MicroRequestResult:
    """Per-request outcome of the ragged micro path: output 0 after
    every completed step, plus the step count at completion and how
    many times the request was preempted (0 = ran uninterrupted)."""

    uid: int
    outputs: List[np.ndarray] = dataclasses.field(default_factory=list)
    steps: int = 0
    done: bool = False
    preemptions: int = 0


def _scratch_bytes(bundle: ModelBundle, max_prompt: int) -> int:
    """Head-section budget: activation scratch for the largest prefill."""
    cfg = bundle.cfg
    dt = 2 if cfg.dtype == "bfloat16" else 4
    # hidden + attention transients for one prompt (engine batch=1)
    return align_up(max_prompt * cfg.d_model * dt * 8)


class MultiTenantHost:
    """One arena, many models — never running concurrently."""

    def __init__(self, arena_bytes: int, *, policy: Any = None,
                 clock=None, preempt: Any = None, profile: Any = None,
                 on_token: Any = None):
        self.arena = TwoStackArena(arena_bytes)
        self.engines: Dict[str, ServingEngine] = {}
        self.routers: Dict[str, ReplicaRouter] = {}
        self.micro: Dict[str, InterpreterPool] = {}
        self._micro_pool = ArenaPool()
        self.ragged = RaggedInterpreterPool(pool=self._micro_pool)
        self._micro_queue: Dict[str, List[MicroRequest]] = {}
        self._micro_inflight: Dict[str, Dict[int, MicroRequest]] = {}
        self.micro_results: Dict[str, Dict[int, MicroRequestResult]] = {}
        self._micro_ckpt: Dict[str, Dict[int, LaneCheckpoint]] = {}
        self._scratch_high = 0
        self.policy: SchedulingPolicy = get_policy(policy)
        self.preempt: Optional[PreemptionPolicy] = get_preemption(preempt)
        self.clock = clock if clock is not None else default_clock
        # one host-wide streaming sink: every tenant engine's per-token
        # StreamEvents (docs/STREAMING.md) funnel through it — uids are
        # caller-assigned, so a multi-tenant consumer demuxes by uid
        self.on_token = on_token
        # the shared bucket tables: one for prompt lengths (engines
        # agree on prefill bucket boundaries), one for ragged lane
        # counts (nearby tenants share ArenaPool free lists).  With a
        # CalibrationProfile the prompt table is the profile's SOLVED
        # layout, deliberately shared across every tenant (engines of
        # other models reuse the layout, not the measurements); with
        # no profile, it is today's hand-picked pow2 default.
        self.profile = profile
        if profile is not None:
            self.prompt_buckets = profile.bucket_table()
        else:
            self.prompt_buckets = BucketTable(min_bucket=8,
                                              max_bucket=4096)
        self.lane_buckets = BucketTable(min_bucket=2, max_bucket=1024)

    def _make_engine(self, bundle: ModelBundle, params: Any, *,
                     max_slots: int, cache_len: int, max_prompt: int,
                     mesh: Any = None, overlap: bool = False,
                     weight_dtype: Any = None, kv_dtype: Any = None
                     ) -> ServingEngine:
        """Build one tenant engine wired to the host's shared arena,
        policy, clock, preemption, profile, streaming sink, and
        prompt-bucket table (family permitting), growing the shared
        scratch reservation to the new maximum — the construction path
        ``add_model`` and every ``add_replicated_model`` replica go
        through."""
        bucketable = bundle.cfg.family in BUCKETED_FAMILIES
        chunkable = bundle.cfg.family in CHUNKED_FAMILIES
        buckets = self.prompt_buckets if bucketable else False
        chunk = (self.profile.prefill_chunk or None
                 if self.profile is not None and chunkable else None)
        eng = ServingEngine(bundle, params, max_slots=max_slots,
                            cache_len=cache_len, arena=self.arena,
                            policy=self.policy, clock=self.clock,
                            prefill_buckets=buckets,
                            prefill_chunk=chunk,
                            preempt=self.preempt, mesh=mesh,
                            overlap=overlap, on_token=self.on_token,
                            weight_dtype=weight_dtype, kv_dtype=kv_dtype)
        scratch = _scratch_bytes(bundle, max_prompt)
        if scratch > self._scratch_high:
            # grow the shared head-section reservation to the new max
            self.arena.allocate_temp(scratch - self._scratch_high)
            self.arena.reset_temp()
            self._scratch_high = scratch
        return eng

    def add_model(self, name: str, bundle: ModelBundle, params: Any, *,
                  max_slots: int = 2, cache_len: int = 128,
                  max_prompt: int = 64, mesh: Any = None,
                  overlap: bool = False, weight_dtype: Any = None,
                  kv_dtype: Any = None) -> ServingEngine:
        """Admit a tenant: its KV cache stacks persistently; the shared
        nonpersistent (head) section grows to the max requirement.  The
        engine admits through the host's policy/clock and buckets its
        prefill lengths through the host's shared prompt table (when
        its family supports bucketing).  ``mesh`` shards the tenant's
        weights and KV arena over the mesh's ``model`` axis
        (docs/ARCHITECTURE.md §9); ``overlap`` runs the tenant's decode
        loop with deferred readback (docs/STREAMING.md), streaming
        per-token events to the host's ``on_token`` sink;
        ``weight_dtype``/``kv_dtype`` serve the tenant quantized
        (docs/QUANTIZATION.md) — per tenant, so fp and quantized
        tenants of one host share the arena and the scheduler."""
        if name in self.engines or name in self.routers:
            raise ValueError(f"tenant {name!r} already exists")
        eng = self._make_engine(bundle, params, max_slots=max_slots,
                                cache_len=cache_len,
                                max_prompt=max_prompt, mesh=mesh,
                                overlap=overlap,
                                weight_dtype=weight_dtype,
                                kv_dtype=kv_dtype)
        self.engines[name] = eng
        return eng

    def add_replicated_model(self, name: str, bundle: ModelBundle,
                             params: Any, *, replicas: int = 2,
                             routing: Any = None, max_slots: int = 2,
                             cache_len: int = 128, max_prompt: int = 64,
                             mesh: Any = None, overlap: bool = False,
                             weight_dtype: Any = None,
                             kv_dtype: Any = None) -> ReplicaRouter:
        """Admit a tenant served by ``replicas`` engine replicas behind
        a ``ReplicaRouter`` — the data-parallel axis of ROADMAP item 2.
        Each replica is a full engine tenant of the shared arena (its
        KV stacks persistently like any other tenant's) sharing the
        host's policy/clock/preemption, and arrivals submitted via
        ``submit(name, …)`` are load-balanced across them by the
        ``routing`` policy (round-robin / least-loaded / locality).
        ``mesh`` shards EVERY replica over its own ``model`` axis —
        replica data-parallelism and in-engine tensor/expert
        parallelism compose."""
        if name in self.engines or name in self.routers:
            raise ValueError(f"tenant {name!r} already exists")
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        engs = [self._make_engine(bundle, params, max_slots=max_slots,
                                  cache_len=cache_len,
                                  max_prompt=max_prompt, mesh=mesh,
                                  overlap=overlap,
                                  weight_dtype=weight_dtype,
                                  kv_dtype=kv_dtype)
                for _ in range(replicas)]
        router = ReplicaRouter(engs, routing=routing)
        self.routers[name] = router
        return router

    def add_micro_model(self, name: str, model: MicroModel,
                        resolver: MicroMutableOpResolver, *,
                        batch: int = 1) -> InterpreterPool:
        """Admit a µFB micro-model tenant served at batch granularity:
        its persistents stack in the shared arena under the engines' KV
        caches, and its pooled nonpersistent buffers come from the one
        ArenaPool all micro tenants share (they run non-concurrently)."""
        pool = InterpreterPool(model, resolver, batch,
                               host_arena=self.arena,
                               pool=self._micro_pool)
        self.micro[name] = pool
        return pool

    def add_ragged_micro(self, name: str, model: MicroModel,
                         resolver: MicroMutableOpResolver, *,
                         lanes: int = 4, exact: bool = False,
                         bucket_lanes: bool = True) -> None:
        """Admit a request-granularity micro tenant: a bucket of the
        host's shared RaggedInterpreterPool.  Persistents stack in the
        shared arena like every other tenant; all planning and
        compilation happens HERE — ``submit_micro`` and the scheduler
        only touch the lane table.

        ``bucket_lanes`` (default True) rounds ``lanes`` up through the
        host's shared lane BucketTable so nearby tenants reuse the same
        stacked ``ArenaPool`` buffers — the extra lanes are real (wider
        dispatch, more per-lane arena state, more admissible requests);
        pass False to get exactly ``lanes``."""
        self.ragged.add_bucket(name, model, resolver, lanes,
                               host_arena=self.arena, exact=exact,
                               lane_buckets=(self.lane_buckets
                                             if bucket_lanes else None))
        self._micro_queue[name] = []
        self._micro_inflight[name] = {}
        self._micro_ckpt[name] = {}
        self.micro_results[name] = {}

    def submit_micro(self, name: str, uid: int,
                     frames: Sequence[Sequence[np.ndarray]], *,
                     priority: int = 0,
                     deadline_us: Optional[int] = None,
                     arrival_us: Optional[int] = None,
                     tenant: Optional[str] = None) -> None:
        """Queue a micro request: ``frames[t]`` are the input arrays for
        the request's t-th invocation (len 1 = single shot, more = a
        streaming continuation across waves).  ``priority`` /
        ``deadline_us`` feed the host's scheduling policy; ``tenant``
        (default: the micro tenant's name) is the WFQ quota label."""
        frames = [list(f) for f in frames]
        if not frames:
            raise ValueError("a micro request needs at least one frame")
        if arrival_us is None:
            arrival_us = self.clock()
        self._micro_queue[name].append(
            MicroRequest(uid, frames, priority=priority,
                         deadline_us=deadline_us, arrival_us=arrival_us,
                         tenant=tenant if tenant is not None else name))
        self.micro_results[name][uid] = MicroRequestResult(uid=uid)

    def _micro_pending(self) -> bool:
        return any(self._micro_queue.values()) \
            or any(self._micro_inflight.values())

    def _admit_micro(self, name: str, req: MicroRequest) -> int:
        """Claim a lane for ``req``: a fresh ``admit`` for a new
        request, ``restore_lane`` for one that carries a preemption
        checkpoint — the continuation resumes at its snapshotted step
        with its snapshotted variable state, bit-identically."""
        ckpt = self._micro_ckpt[name].pop(req.uid, None)
        if ckpt is not None:
            return self.ragged.restore_lane(ckpt)
        return self.ragged.admit(name, uid=req.uid)

    def _preempt_micro(self, name: str, now: int) -> bool:
        """Try ONE displacement for tenant ``name``: ask the preemption
        policy whether the queue's policy-first candidate may evict a
        running lane; if so, snapshot + retire the victim, re-queue it,
        and admit the candidate into the freed lane.  Returns True when
        a displacement happened (the caller loops — each one strictly
        improves the running set, so the loop is bounded)."""
        queue = self._micro_queue[name]
        inflight = self._micro_inflight[name]
        if not queue or not inflight or self.preempt is None:
            return False
        slots = sorted(inflight)
        ci = self.policy.select(queue, now)
        cand = queue[ci]
        vi = self.preempt.victim([inflight[s] for s in slots], cand, now)
        if vi is None:
            return False
        queue.pop(ci)
        slot = slots[vi]
        victim = inflight.pop(slot)
        self._micro_ckpt[name][victim.uid] = \
            self.ragged.snapshot_lane(name, slot)
        self.ragged.retire(name, slot)
        self.micro_results[name][victim.uid].preemptions += 1
        queue.append(victim)
        inflight[self._admit_micro(name, cand)] = cand
        return True

    def micro_step(self) -> bool:
        """One scheduler tick of the ragged micro path: admit queued
        requests into free lanes IN POLICY ORDER (restoring preempted
        continuations from their checkpoints), let the preemption
        policy displace running best-effort lanes for urgent queued
        work, stage every active lane's next frame, advance all buckets
        with ONE masked dispatch each, then retire lanes whose requests
        finished.  Returns True if work remains."""
        now = self.clock() if any(self._micro_queue.values()) else 0
        for name, queue in self._micro_queue.items():
            inflight = self._micro_inflight[name]
            while queue and self.ragged.free_lanes(name):
                req = self.policy.pop(queue, now)
                inflight[self._admit_micro(name, req)] = req
            for _ in range(len(inflight)):
                if not self._preempt_micro(name, now):
                    break
            for slot, req in inflight.items():
                step = self.ragged.lanes(name)[slot].step
                for pos, arr in enumerate(req.frames[step]):
                    self.ragged.set_input(name, slot, pos, arr)
        if not self.ragged.dispatch():
            return self._micro_pending()
        for name, inflight in self._micro_inflight.items():
            for slot in list(inflight):
                req = inflight[slot]
                lane = self.ragged.lanes(name)[slot]
                res = self.micro_results[name][req.uid]
                self.policy.charge(req.tenant, 1.0)
                # copy: output() returns a view into the whole wave's
                # stacked host array — holding it would pin lanes x the
                # needed memory for the life of the result
                res.outputs.append(self.ragged.output(name, slot, 0).copy())
                res.steps = lane.step
                if lane.step >= len(req.frames):
                    res.done = True
                    self.ragged.retire(name, slot)
                    del inflight[slot]
        return self._micro_pending()

    def run_micro(self, name: str,
                  requests: Sequence[Sequence[np.ndarray]]
                  ) -> List[np.ndarray]:
        """Serve ``requests`` (each a per-input list of arrays) through
        the named micro tenant, B lanes per jitted dispatch; returns the
        first output of each request in order.

        Requests are INDEPENDENT: inputs and variable-tensor state are
        reset between chunks, so a stateful model (e.g. SVDF) sees every
        request from its initial state.  Streaming tenants that need
        state carried across invocations should drive the
        InterpreterPool directly."""
        pool = self.micro[name]
        out: List[np.ndarray] = []
        for start in range(0, len(requests), pool.batch):
            chunk = requests[start:start + pool.batch]
            pool.clear_inputs()
            pool.reset_variable_tensors()
            for lane, req in enumerate(chunk):
                for pos, arr in enumerate(req):
                    pool.set_input(lane, pos, arr)
            pool.invoke()
            out.extend(pool.output(lane, 0) for lane in range(len(chunk)))
        return out

    def submit(self, name: str, req: Request) -> None:
        """Queue ``req`` for pod tenant ``name`` — directly on its
        engine, or through its ``ReplicaRouter`` when the tenant was
        admitted with ``add_replicated_model``."""
        if name in self.routers:
            self.routers[name].submit(req)
        else:
            self.engines[name].submit(req)

    def run_all(self) -> Dict[str, Dict[int, RequestResult]]:
        """THE scheduler: round-robin every tenant — pod engines AND
        ragged micro buckets — until all queues drain (tenants are
        time-multiplexed — TF Micro's 'not concurrently' contract).
        WITHIN a tenant, the free slot/lane goes to whichever queued
        request the host's scheduling policy keys first (FIFO by
        default; priority/EDF reorder admission without recompiling).
        One tick = one decode step per engine with work plus one masked
        dispatch per micro bucket with active lanes, so mixed micro+pod
        tenancy advances through a single loop.  Every tick with work
        pending makes progress (admission happens whenever a slot or
        lane is free), so the loop terminates when the work does."""
        out = {}
        pending = True
        while pending:
            pending = False
            for name, eng in self.engines.items():
                if eng.step():
                    pending = True
            for name, router in self.routers.items():
                if router.step():
                    pending = True
            if self._micro_queue and self.micro_step():
                pending = True
        for name, eng in self.engines.items():
            out[name] = eng.results
        for name, router in self.routers.items():
            out[name] = router.results
        return out

    def usage(self):
        return self.arena.usage()
