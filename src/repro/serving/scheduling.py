"""Latency-aware scheduling policies for the serving host.

The paper's invoke loop is a fixed program; WHICH request enters a free
slot or lane next is the one degree of freedom left to the host.  This
module makes that degree of freedom pluggable without ever touching the
traced programs — the third leg (after batching and raggedness) of the
compile-once serving story:

  * **policy decisions are host-side** — a policy reorders the Python
    queue between dispatches.  It never sees (and cannot change) traced
    state: lane masks, slot counts, and step shapes stay exactly what
    ``CompiledPlan``/``ServingEngine`` compiled at init.  Swapping FIFO
    for EDF at runtime therefore never recompiles anything.
  * **masks stay traced arguments** — admission under ANY policy still
    just flips a lane-table bit / writes slot bookkeeping; the active
    mask reaches the program as a traced argument, same as PR 2.

Three policies (semantics spelled out in docs/SCHEDULING.md):

  * ``FIFOPolicy`` — arrival order; the round-robin-across-tenants
    baseline the host always had.
  * ``PriorityPolicy`` — lower ``priority`` admits first, with an
    *aging* bound: a request's effective priority improves by one class
    per ``age_us`` waited, so starvation under a saturating stream of
    higher classes is bounded by ``(class gap) x age_us``.
  * ``EDFPolicy`` — earliest ``deadline_us`` first; deadline-less
    requests order after all deadlined ones, FIFO among themselves.

All policies break ties by arrival order (the submission sequence
number), so equal-key requests never reorder — FIFO is the fixed point.

``now_us`` flows in from the caller (engine/host ``clock``), which is
what lets the arrival-process benchmark drive the same policies on a
virtual clock for deterministic latency accounting.
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple, Union

_INF = float("inf")


# Policies only read three optional request attributes — ``priority``,
# ``deadline_us``, ``arrival_us`` — so pod ``Request`` and micro
# ``MicroRequest`` schedule through the identical code path.
def _arrival(req, default: float = 0.0) -> float:
    a = getattr(req, "arrival_us", None)
    return default if a is None else a


class SchedulingPolicy:
    """Base policy: an admission-order key over queued requests.

    Subclasses implement ``key(req, now_us)`` — smaller admits first.
    ``select``/``pop`` are shared: a stable argmin over the queue, so
    every policy inherits FIFO tie-breaking for equal keys.  Policies
    hold no per-request state and never touch traced values, so one
    instance may be shared by every tenant of a host.
    """

    name = "fifo"

    def key(self, req, now_us: int) -> Tuple:
        """Admission key for ``req`` at host time ``now_us`` (µs);
        smaller admits earlier.  Must be cheap — it runs per queued
        request per admission decision."""
        return ()

    def select(self, queue: Sequence, now_us: int = 0) -> Optional[int]:
        """Index of the request to admit next, or None when empty.
        Stable: among equal keys the earliest-queued index wins."""
        best, best_key = None, None
        for i, req in enumerate(queue):
            k = self.key(req, now_us)
            if best is None or k < best_key:
                best, best_key = i, k
        return best

    def pop(self, queue: List, now_us: int = 0):
        """Remove and return the next request to admit (policy order)."""
        i = self.select(queue, now_us)
        if i is None:
            raise IndexError("pop from an empty queue")
        return queue.pop(i)


class FIFOPolicy(SchedulingPolicy):
    """Arrival order — the baseline.  ``select`` short-circuits to the
    queue head (no O(queue) key scan per admission)."""

    name = "fifo"

    def select(self, queue: Sequence, now_us: int = 0) -> Optional[int]:
        return 0 if queue else None


class PriorityPolicy(SchedulingPolicy):
    """Strict priority classes with an aging starvation bound.

    ``req.priority`` (default 0) orders admission: lower is more
    urgent.  A waiting request's *effective* priority improves by one
    class per ``age_us`` of queue wait, so a class-p request is
    admitted after at most ``p x age_us`` of continuous higher-class
    pressure — starvation is bounded, not merely unlikely (asserted in
    tests/test_scheduling.py)."""

    name = "priority"

    def __init__(self, age_us: int = 1_000_000):
        if age_us < 1:
            raise ValueError("age_us must be >= 1")
        self.age_us = int(age_us)

    def key(self, req, now_us: int) -> Tuple:
        prio = getattr(req, "priority", 0) or 0
        waited = max(0.0, now_us - _arrival(req, default=now_us))
        return (prio - waited / self.age_us, _arrival(req))


class EDFPolicy(SchedulingPolicy):
    """Earliest-deadline-first on ``req.deadline_us`` (absolute µs).

    The classic latency-SLO policy: under contention the request whose
    deadline expires soonest takes the free lane.  Requests without a
    deadline sort after every deadlined request and FIFO among
    themselves, so best-effort traffic fills leftover capacity."""

    name = "edf"

    def key(self, req, now_us: int) -> Tuple:
        d = getattr(req, "deadline_us", None)
        return (d if d is not None else _INF, _arrival(req))


_POLICIES = {p.name: p for p in (FIFOPolicy, PriorityPolicy, EDFPolicy)}


def get_policy(policy: Union[str, SchedulingPolicy, None]
               ) -> SchedulingPolicy:
    """Resolve a policy argument: an instance passes through, a name
    (``"fifo"``/``"priority"``/``"edf"``) constructs the default
    instance, None means FIFO."""
    if policy is None:
        return FIFOPolicy()
    if isinstance(policy, SchedulingPolicy):
        return policy
    try:
        return _POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown scheduling policy {policy!r}; "
                         f"have {sorted(_POLICIES)}") from None
