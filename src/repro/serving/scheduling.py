"""Latency-aware scheduling policies for the serving host.

The paper's invoke loop is a fixed program; WHICH request enters a free
slot or lane next is the one degree of freedom left to the host.  This
module makes that degree of freedom pluggable without ever touching the
traced programs — the third leg (after batching and raggedness) of the
compile-once serving story:

  * **policy decisions are host-side** — a policy reorders the Python
    queue between dispatches.  It never sees (and cannot change) traced
    state: lane masks, slot counts, and step shapes stay exactly what
    ``CompiledPlan``/``ServingEngine`` compiled at init.  Swapping FIFO
    for EDF at runtime therefore never recompiles anything.
  * **masks stay traced arguments** — admission under ANY policy still
    just flips a lane-table bit / writes slot bookkeeping; the active
    mask reaches the program as a traced argument, same as PR 2.

Four admission policies (semantics in docs/SCHEDULING.md):

  * ``FIFOPolicy`` — arrival order; the round-robin-across-tenants
    baseline the host always had.
  * ``PriorityPolicy`` — lower ``priority`` admits first, with an
    *aging* bound: a request's effective priority improves by one class
    per ``age_us`` waited, so starvation under a saturating stream of
    higher classes is bounded by ``(class gap) x age_us``.
  * ``EDFPolicy`` — earliest ``deadline_us`` first; deadline-less
    requests order after all deadlined ones, FIFO among themselves.
  * ``WFQPolicy`` — weighted-fair queueing ACROSS tenants on top of any
    inner policy: the free slot goes to the tenant furthest below its
    weighted service share, the inner policy orders within a tenant.

All policies break ties by arrival order (the submission sequence
number), so equal-key requests never reorder — FIFO is the fixed point.

``now_us`` flows in from the caller (engine/host ``clock``), which is
what lets the arrival-process benchmark drive the same policies on a
virtual clock for deterministic latency accounting.

**Preemption** (docs/PREEMPTION.md) is the second, sharper degree of
freedom: once admission alone cannot help (every slot busy, a tight
deadline waiting), a ``PreemptionPolicy`` may pick a RUNNING victim to
evict.  The caller checkpoints the victim's continuation state
(``RaggedInterpreterPool.snapshot_lane`` / the engine's slot
checkpoint), re-queues it, and admits the urgent request into the freed
slot; the victim resumes later bit-identically.  Like admission,
preemption is pure host-side queue/lane-table surgery — the decision
layer here never touches a traced value, so preempt/resume cycles
never recompile (asserted via ``jit_cache_size`` in
tests/test_preemption.py).

**Routing** (docs/ARCHITECTURE.md §9) is the third degree of freedom,
one level up: with several engine REPLICAS of one model, a
``RoutingPolicy`` decides which replica a fresh arrival is submitted
to (round-robin / least-loaded / locality-aware), before that
replica's ``SchedulingPolicy`` orders its queue.  Routing decisions
are load-snapshot Python like everything else here — swapping the
router's policy mid-serve never touches a traced value
(tests/test_replica_router.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

_INF = float("inf")


# Policies only read four optional request attributes — ``priority``,
# ``deadline_us``, ``arrival_us``, ``tenant`` — so pod ``Request`` and
# micro ``MicroRequest`` schedule through the identical code path.
def _arrival(req, default: float = 0.0) -> float:
    a = getattr(req, "arrival_us", None)
    return default if a is None else a


def _deadline(req) -> float:
    d = getattr(req, "deadline_us", None)
    return _INF if d is None else d


def _tenant(req) -> str:
    return getattr(req, "tenant", "") or ""


class SchedulingPolicy:
    """Base policy: an admission-order key over queued requests.

    Subclasses implement ``key(req, now_us)`` — smaller admits first.
    ``select``/``pop`` are shared: a stable argmin over the queue, so
    every policy inherits FIFO tie-breaking for equal keys.  Policies
    hold no per-request state and never touch traced values, so one
    instance may be shared by every tenant of a host.
    """

    name = "fifo"

    def key(self, req, now_us: int) -> Tuple:
        """Admission key for ``req`` at host time ``now_us`` (µs);
        smaller admits earlier.  Must be cheap — it runs per queued
        request per admission decision."""
        return ()

    def select(self, queue: Sequence, now_us: int = 0) -> Optional[int]:
        """Index of the request to admit next, or None when empty.
        Stable: among equal keys the earliest-queued index wins."""
        best, best_key = None, None
        for i, req in enumerate(queue):
            k = self.key(req, now_us)
            if best is None or k < best_key:
                best, best_key = i, k
        return best

    def pop(self, queue: List, now_us: int = 0):
        """Remove and return the next request to admit (policy order)."""
        i = self.select(queue, now_us)
        if i is None:
            raise IndexError("pop from an empty queue")
        return queue.pop(i)

    def charge(self, tenant: str, units: float = 1.0) -> None:
        """Account ``units`` of service delivered to ``tenant``.

        A no-op for memoryless policies; ``WFQPolicy`` overrides it to
        integrate per-tenant service.  Engines and the host call it
        once per slot/lane advanced per dispatch, so a fair-share
        policy sees the real service distribution regardless of which
        surface (pod engine or ragged micro bucket) delivered it."""

    def served(self, tenant: str) -> float:
        """Normalized service delivered to ``tenant`` so far (0 for
        memoryless policies — only ``WFQPolicy`` integrates it)."""
        return 0.0


class FIFOPolicy(SchedulingPolicy):
    """Arrival order — the baseline.  ``select`` short-circuits to the
    queue head (no O(queue) key scan per admission)."""

    name = "fifo"

    def select(self, queue: Sequence, now_us: int = 0) -> Optional[int]:
        """Queue head, unconditionally — FIFO needs no key scan."""
        return 0 if queue else None


class PriorityPolicy(SchedulingPolicy):
    """Strict priority classes with an aging starvation bound.

    ``req.priority`` (default 0) orders admission: lower is more
    urgent.  A waiting request's *effective* priority improves by one
    class per ``age_us`` of queue wait, so a class-p request is
    admitted after at most ``p x age_us`` of continuous higher-class
    pressure — starvation is bounded, not merely unlikely (asserted in
    tests/test_scheduling.py)."""

    name = "priority"

    def __init__(self, age_us: int = 1_000_000):
        if age_us < 1:
            raise ValueError("age_us must be >= 1")
        self.age_us = int(age_us)

    def key(self, req, now_us: int) -> Tuple:
        """Effective (aged) priority, ties broken by arrival."""
        prio = getattr(req, "priority", 0) or 0
        waited = max(0.0, now_us - _arrival(req, default=now_us))
        return (prio - waited / self.age_us, _arrival(req))


class EDFPolicy(SchedulingPolicy):
    """Earliest-deadline-first on ``req.deadline_us`` (absolute µs).

    The classic latency-SLO policy: under contention the request whose
    deadline expires soonest takes the free lane.  Requests without a
    deadline sort after every deadlined request and FIFO among
    themselves, so best-effort traffic fills leftover capacity."""

    name = "edf"

    def key(self, req, now_us: int) -> Tuple:
        """Absolute deadline (∞ when deadline-less), ties by arrival."""
        return (_deadline(req), _arrival(req))


class WFQPolicy(SchedulingPolicy):
    """Weighted-fair queueing ACROSS tenants, any policy WITHIN one.

    Each request carries a ``tenant`` label; each tenant has a weight
    (``weights[tenant]``, default 1.0).  The policy integrates service
    per tenant via ``charge`` — one unit per slot/lane-dispatch the
    tenant consumed — and admits from the tenant with the LOWEST
    normalized service ``service / weight``.  Under saturation every
    tenant's share of dispatches therefore converges to its weight
    fraction (asserted in tests/test_preemption.py), and an idle
    tenant's unused share spills to the others instead of going to
    waste — work-conserving, like classic WFQ.

    Within a tenant (and between tenants at equal normalized service)
    the ``inner`` policy orders requests — quotas stack ON TOP of
    FIFO/priority/EDF semantics rather than replacing them.  Service
    state is host-side floats; like every policy here it cannot touch
    a traced value, so re-weighting at runtime never recompiles."""

    name = "wfq"

    def __init__(self, weights: Optional[Dict[str, float]] = None,
                 inner: Union[str, SchedulingPolicy, None] = None):
        self.weights = dict(weights or {})
        for t, w in self.weights.items():
            if w <= 0:
                raise ValueError(f"tenant {t!r}: weight must be > 0")
        self.inner = get_policy(inner)
        self.service: Dict[str, float] = {}

    def weight(self, tenant: str) -> float:
        """``tenant``'s configured weight (1.0 when unlisted)."""
        return float(self.weights.get(tenant, 1.0))

    def charge(self, tenant: str, units: float = 1.0) -> None:
        """Integrate ``units`` of delivered service for ``tenant``."""
        self.service[tenant] = self.service.get(tenant, 0.0) + units

    def served(self, tenant: str) -> float:
        """Weight-normalized service: ``service[tenant] / weight``."""
        return self.service.get(tenant, 0.0) / self.weight(tenant)

    def key(self, req, now_us: int) -> Tuple:
        """(normalized tenant service, inner-policy key, arrival)."""
        return ((self.served(_tenant(req)),)
                + tuple(self.inner.key(req, now_us))
                + (_arrival(req),))


# ---------------------------------------------------------------------------
# preemption policies (docs/PREEMPTION.md)
# ---------------------------------------------------------------------------

class PreemptionPolicy:
    """Decides whether an urgent queued request may EVICT a running one.

    Consulted by ``ServingEngine.step`` and ``MultiTenantHost.micro_step``
    only after plain admission failed (no free slot/lane while the queue
    is non-empty).  ``victim(running, candidate, now_us)`` returns the
    index of the running request to evict, or None to let the candidate
    wait.  The CALLER then performs the mechanics: checkpoint the
    victim's continuation state, retire its lane/slot, re-queue it, and
    admit the candidate — so a policy here is pure decision logic and,
    like admission policies, can never touch a traced value.

    Contract for subclasses: only return a victim the candidate
    STRICTLY beats under the policy's own order.  That makes each
    preemption an improvement of the running set, bounds preemptions
    per tick by the slot count, and guarantees the evicted request —
    whose key is now the worse one — cannot immediately displace its
    displacer (no thrash)."""

    name = "never"

    def victim(self, running: Sequence, candidate,
               now_us: int = 0) -> Optional[int]:
        """Index into ``running`` of the request to evict for
        ``candidate``, or None to keep all running requests."""
        return None


class EDFDisplacePolicy(PreemptionPolicy):
    """Evict the loosest-deadline running request for a tighter one.

    The victim is the running request with the LATEST deadline
    (deadline-less best-effort sorts last, so it is displaced first);
    preemption happens only when the candidate's deadline is more than
    ``margin_us`` tighter than the victim's.  A deadline-less candidate
    never preempts anything.  Pairs naturally with ``EDFPolicy``
    admission: admission gets urgent work to the FRONT of the queue,
    displacement gets it INTO a slot when the queue's front would
    otherwise wait behind a long best-effort run — the head-of-line
    fix for checkpointable lanes."""

    name = "edf-displace"

    def __init__(self, margin_us: int = 0):
        if margin_us < 0:
            raise ValueError("margin_us must be >= 0")
        self.margin_us = int(margin_us)

    def victim(self, running: Sequence, candidate,
               now_us: int = 0) -> Optional[int]:
        """Latest-deadline running index, when the candidate's deadline
        is more than ``margin_us`` tighter; else None."""
        cd = getattr(candidate, "deadline_us", None)
        if cd is None or not running:
            return None
        worst = max(range(len(running)),
                    key=lambda i: (_deadline(running[i]),
                                   -_arrival(running[i])))
        if cd + self.margin_us < _deadline(running[worst]):
            return worst
        return None


class WFQDisplacePolicy(PreemptionPolicy):
    """Weighted-fair-per-tenant preemption: evict the most over-served
    tenant's running request for an under-served tenant's.

    Reads the shared ``WFQPolicy`` service integrals: the victim is the
    running request whose tenant has the HIGHEST normalized service;
    preemption happens only when that exceeds the candidate tenant's by
    more than ``slack`` dispatch-units (hysteresis — without it two
    tenants at equal share would evict each other every tick).  With
    checkpointable lanes this turns WFQ from a long-run average into a
    per-tick guarantee: a quota violator is displaced MID-REQUEST, not
    merely passed over at its next admission."""

    name = "wfq-displace"

    def __init__(self, policy: WFQPolicy, slack: float = 1.0):
        if not isinstance(policy, WFQPolicy):
            raise TypeError(f"WFQDisplacePolicy needs the shared "
                            f"WFQPolicy instance, got {policy!r}")
        if slack < 0:
            raise ValueError("slack must be >= 0")
        self.policy = policy
        self.slack = float(slack)

    def victim(self, running: Sequence, candidate,
               now_us: int = 0) -> Optional[int]:
        """Most over-served tenant's running index, when it beats the
        candidate tenant's normalized service by > ``slack``."""
        if not running:
            return None
        cand = self.policy.served(_tenant(candidate))
        worst = max(range(len(running)),
                    key=lambda i: (self.policy.served(
                        _tenant(running[i])), -_arrival(running[i])))
        if self.policy.served(_tenant(running[worst])) > cand + self.slack:
            return worst
        return None


_POLICIES = {p.name: p for p in (FIFOPolicy, PriorityPolicy, EDFPolicy,
                                 WFQPolicy)}


def get_policy(policy: Union[str, SchedulingPolicy, None]
               ) -> SchedulingPolicy:
    """Resolve a policy argument: an instance passes through, a name
    (``"fifo"``/``"priority"``/``"edf"``/``"wfq"``) constructs the
    default instance, None means FIFO."""
    if policy is None:
        return FIFOPolicy()
    if isinstance(policy, SchedulingPolicy):
        return policy
    try:
        return _POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown scheduling policy {policy!r}; "
                         f"have {sorted(_POLICIES)}") from None


# ---------------------------------------------------------------------------
# routing policies (docs/ARCHITECTURE.md §9, docs/SCHEDULING.md §6)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplicaLoad:
    """One replica's host-visible load snapshot at route time: queued
    requests, busy slots (decoding + mid-chunked-prefill), the
    replica's total slot count, and the remaining-token ``backlog``.
    Built by ``ReplicaRouter.loads()`` from plain host bookkeeping —
    reading it never synchronizes a device."""

    queued: int
    active: int
    slots: int
    # remaining decode tokens across queued + active + mid-prefill
    # requests — the COST-aware load key.  Request count is blind to
    # heterogeneous service times (a 16-token monopolizer weighs the
    # same as a 4-token deadline request), which is exactly how
    # join-the-shortest-queue degenerates to round-robin on a
    # heavy-tail mix; token backlog sees the difference.
    backlog: int = 0

    @property
    def depth(self) -> int:
        """Total outstanding request count at the replica (queued +
        active) — the tiebreak load key behind ``backlog``."""
        return self.queued + self.active


class RoutingPolicy:
    """Decides WHICH engine replica a fresh arrival is submitted to —
    the route-time sibling of ``SchedulingPolicy`` (which decides
    admission order WITHIN a replica's queue).  Same contract: a
    routing decision is host-side Python over load snapshots; it never
    sees a traced value, so swapping routing policies at runtime
    (``ReplicaRouter.set_routing``) never recompiles anything.

    Subclasses implement ``route(loads, req, home)`` returning the
    replica index to submit to.  ``home`` is the index of the replica
    holding the request's preemption checkpoint/KV, or None for a
    fresh request: policies MAY ignore it (round-robin does — that is
    exactly its p99 penalty), but the ``ReplicaRouter`` itself never
    migrates checkpointed work regardless of policy, so ignoring
    ``home`` costs performance, never correctness."""

    name = "round-robin"

    def route(self, loads: Sequence[ReplicaLoad], req,
              home: Optional[int] = None) -> int:
        """Replica index for ``req`` given per-replica ``loads``;
        ``home`` names the replica holding its checkpoint (or None)."""
        raise NotImplementedError


class RoundRobinRouting(RoutingPolicy):
    """Cycle through replicas in submission order — the load-blind
    baseline.  Under heterogeneous service times (a long monopolizer
    on one replica) it keeps feeding the busy replica while others
    idle, which is the queueing delay the load-aware policies beat
    (BENCH_replica_sweep.json)."""

    name = "round-robin"

    def __init__(self) -> None:
        self._next = 0

    def route(self, loads: Sequence[ReplicaLoad], req,
              home: Optional[int] = None) -> int:
        """The next replica in cyclic order, ignoring load and home."""
        i = self._next % len(loads)
        self._next += 1
        return i


class LeastLoadedRouting(RoutingPolicy):
    """Route to the replica with the smallest remaining-token
    ``backlog`` (ties broken by request depth, then replica index) —
    join-the-shortest-WORKLOAD rather than shortest queue, because a
    count-based key cannot tell a monopolizer from a deadline-class
    request.  Load-aware but locality-blind: it reads only the
    snapshot, never ``home``."""

    name = "least-loaded"

    def route(self, loads: Sequence[ReplicaLoad], req,
              home: Optional[int] = None) -> int:
        """Index of the least-backlogged replica (stable on ties)."""
        return min(range(len(loads)),
                   key=lambda i: (loads[i].backlog, loads[i].depth, i))


class LocalityRouting(RoutingPolicy):
    """Least-loaded with continuation stickiness: a request whose
    KV/checkpoint is parked at a replica (``home``) goes HOME —
    re-prefilling elsewhere would pay the full prompt again and strand
    the checkpoint — and only fresh requests load-balance through the
    ``inner`` policy (least-loaded by default)."""

    name = "locality"

    def __init__(self, inner: Union[str, RoutingPolicy, None] = None):
        self.inner = get_routing(inner if inner is not None
                                 else "least-loaded")

    def route(self, loads: Sequence[ReplicaLoad], req,
              home: Optional[int] = None) -> int:
        """``home`` when the request has one, else the inner policy."""
        if home is not None:
            return home
        return self.inner.route(loads, req, None)


_ROUTING = {p.name: p for p in (RoundRobinRouting, LeastLoadedRouting,
                                LocalityRouting)}


def get_routing(policy: Union[str, RoutingPolicy, None]) -> RoutingPolicy:
    """Resolve a routing argument: an instance passes through, a name
    (``"round-robin"``/``"least-loaded"``/``"locality"``) constructs
    the default instance, None means round-robin (the baseline, like
    FIFO for admission)."""
    if policy is None:
        return RoundRobinRouting()
    if isinstance(policy, RoutingPolicy):
        return policy
    try:
        return _ROUTING[policy]()
    except KeyError:
        raise ValueError(f"unknown routing policy {policy!r}; "
                         f"have {sorted(_ROUTING)}") from None


_PREEMPTION = {p.name: p for p in (PreemptionPolicy, EDFDisplacePolicy)}


def get_preemption(policy: Union[str, PreemptionPolicy, None]
                   ) -> Optional[PreemptionPolicy]:
    """Resolve a preemption argument: None disables preemption, an
    instance passes through, a name (``"edf-displace"``/``"never"``)
    constructs the default instance.  ``WFQDisplacePolicy`` has no name
    here because it needs the shared ``WFQPolicy`` instance."""
    if policy is None:
        return None
    if isinstance(policy, PreemptionPolicy):
        return policy
    try:
        return _PREEMPTION[policy]()
    except KeyError:
        raise ValueError(f"unknown preemption policy {policy!r}; "
                         f"have {sorted(_PREEMPTION)}") from None
