"""Sharding policy: ModelConfig × mesh -> PartitionSpec trees.

Axes (DESIGN.md §6):
  * ``data`` (and ``pod`` when multi-pod) shard the batch and — FSDP
    style — the d_model dimension of the weights, so optimizer state
    scales with the full chip count (ZeRO-3 analogue).
  * ``model`` shards heads / FFN hidden / experts / vocab (Megatron).

Head-sharding fallback chain (not every assigned arch has
n_heads % 16 == 0 — phi4 has 24 heads, paligemma 8, whisper 20):
  1. n_heads % model == 0      -> shard the head axis (Megatron);
  2. head_dim % model == 0     -> shard head_dim (RoPE still lowers:
     GSPMD inserts collective-permutes for the rotate-half);
  3. otherwise                 -> replicate attention over ``model``
     (FFN still sharded); recorded per-arch in EXPERIMENTS.md.

KV caches: kv-head axis sharded on ``model`` when divisible, else the
*sequence* axis of the cache is sharded on ``model`` (flash-decoding
style partial-attention; GSPMD inserts the logsumexp-combine
collectives).  Batch shards on (pod, data) when divisible, else
replicates (long_500k's batch=1 — the hillclimb reclaims those chips
via sequence parallelism).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ModelConfig


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1


def _data_size(mesh: Mesh) -> int:
    return int(np.prod([_axis_size(mesh, a) for a in ("pod", "data")]))


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Resolved per-(cfg, mesh) sharding decisions."""
    mesh: Mesh
    cfg: ModelConfig
    attn_mode: str          # "heads" | "head_dim" | "replicated"
    kv_cache_mode: str      # "kv_heads" | "sequence"
    fsdp: bool              # shard d_model dim of weights over data

    @property
    def batch_axes(self):
        return data_axes(self.mesh)


def make_policy(cfg: ModelConfig, mesh: Mesh, *,
                fsdp: bool = True,
                attn_fallback: str = "replicated") -> ShardingPolicy:
    """``attn_fallback`` for heads-indivisible archs (phi4: 24 heads,
    paligemma: 8, whisper: 20 over model=16): "replicated" keeps
    attention data-parallel only (weights replicated over ``model``) —
    measured far better than "head_dim" (sharding the contraction dim
    makes GSPMD replicate the batch and all-reduce full S^2 logits; see
    EXPERIMENTS.md §Perf iteration 0)."""
    m = _axis_size(mesh, "model")
    if cfg.n_heads and cfg.n_heads % m == 0:
        attn = "heads"
    elif cfg.n_heads and cfg.dh % m == 0 and attn_fallback == "head_dim":
        attn = "head_dim"
    else:
        attn = "replicated"
    kv = "kv_heads" if (cfg.n_kv_heads and cfg.n_kv_heads % m == 0) \
        else "sequence"
    return ShardingPolicy(mesh=mesh, cfg=cfg, attn_mode=attn,
                          kv_cache_mode=kv, fsdp=fsdp)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

def _dm(pol: ShardingPolicy):
    """Axis for the d_model dim of weight matrices (FSDP over data)."""
    if not pol.fsdp:
        return None
    d = pol.cfg.d_model
    if d % _data_size(pol.mesh) == 0:
        return pol.batch_axes
    return None


def _div(n: int, mesh: Mesh, axis: str) -> bool:
    return n % _axis_size(mesh, axis) == 0


def _attn_spec(pol: ShardingPolicy, lead) -> Dict[str, P]:
    """wq (…,D,H,dh) wk/wv (…,D,KH,dh) wo (…,H,dh,D)."""
    cfg, mesh = pol.cfg, pol.mesh
    dm = _dm(pol)
    if pol.attn_mode == "heads":
        h_ax, dh_ax = "model", None
        kv_h_ax = "model" if _div(cfg.n_kv_heads, mesh, "model") else None
        kv_dh_ax = None
    elif pol.attn_mode == "head_dim":
        h_ax, dh_ax = None, "model"
        kv_h_ax, kv_dh_ax = None, "model"
    else:
        h_ax = dh_ax = kv_h_ax = kv_dh_ax = None
    spec = {
        "wq": P(*lead, dm, h_ax, dh_ax),
        "wk": P(*lead, dm, kv_h_ax, kv_dh_ax),
        "wv": P(*lead, dm, kv_h_ax, kv_dh_ax),
        "wo": P(*lead, h_ax, dh_ax, dm),
    }
    if cfg.qk_norm:
        spec["q_norm"] = P(*lead, None)
        spec["k_norm"] = P(*lead, None)
    return spec


def _mlp_spec(pol: ShardingPolicy, lead, f: int) -> Dict[str, P]:
    dm = _dm(pol)
    f_ax = "model" if _div(f, pol.mesh, "model") else None
    spec = {"wi": P(*lead, dm, f_ax), "wo": P(*lead, f_ax, dm)}
    if pol.cfg.act in ("silu", "geglu"):
        spec["wg"] = P(*lead, dm, f_ax)
    return spec


def _moe_spec(pol: ShardingPolicy, lead) -> Dict[str, Any]:
    cfg = pol.cfg
    e_ax = "model" if _div(cfg.n_experts, pol.mesh, "model") else None
    dm = _dm(pol)
    expert = {"wi": P(*lead, e_ax, dm, None),
              "wo": P(*lead, e_ax, None, dm)}
    if cfg.act in ("silu", "geglu"):
        expert["wg"] = P(*lead, e_ax, dm, None)
    spec = {"router": P(*lead, dm, None), "experts": expert}
    if cfg.n_shared_experts:
        spec["shared"] = _mlp_spec(pol, lead,
                                   cfg.n_shared_experts * cfg.moe_d_ff)
    return spec


def _ssm_spec(pol: ShardingPolicy, lead) -> Dict[str, P]:
    """Mamba2 block: shard the inner (head) dim on ``model``."""
    cfg = pol.cfg
    dm = _dm(pol)
    # in_proj output dim mixes z|xBC|dt — shardable only if every section
    # divides; the conservative choice is model-sharding the output dim
    # when d_in_proj divides (it packs per-head blocks).
    din = 2 * cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state \
        + cfg.ssm_heads
    i_ax = None           # packed projection: keep unsharded output dim
    di_ax = "model" if _div(cfg.d_inner, pol.mesh, "model") else None
    return {
        "in_proj": P(*lead, dm, i_ax),
        "conv_w": P(*lead, None, None),
        "conv_b": P(*lead, None),
        "dt_bias": P(*lead, None),
        "A_log": P(*lead, None),
        "D": P(*lead, None),
        "norm": P(*lead, di_ax),
        "out_proj": P(*lead, di_ax, dm),
        "ln": P(*lead, None),
    }


def _vocab_spec(pol: ShardingPolicy) -> P:
    return P("model", _dm(pol))


def param_spec(cfg: ModelConfig, pol: ShardingPolicy,
               params_tree: Any) -> Any:
    """Build a PartitionSpec tree with the same structure as params."""
    spec: Dict[str, Any] = {}
    if "embed" in params_tree:
        spec["embed"] = _vocab_spec(pol)
    if "lm_head" in params_tree:
        spec["lm_head"] = P(_dm(pol), "model")
    if "final_norm" in params_tree:
        spec["final_norm"] = P(None)
    if "projector" in params_tree:
        spec["projector"] = P(None, _dm(pol))
    lead = (None,)   # stacked layer dim

    def block_spec(block_tree, lead):
        out = {}
        for k in block_tree:
            if k == "attn":
                out[k] = _attn_spec(pol, lead)
            elif k == "xattn":
                out[k] = _attn_spec(pol, lead)
            elif k == "mlp":
                f = (cfg.first_layer_dense_ff
                     if lead == (None,) and "moe" in block_tree
                     else cfg.d_ff)
                out[k] = _mlp_spec(pol, lead, f)
            elif k == "moe":
                out[k] = _moe_spec(pol, lead)
            elif k in ("in_proj", "conv_w", "conv_b", "dt_bias", "A_log",
                       "D", "norm", "out_proj", "ln"):
                pass  # handled as a unit below
            else:
                out[k] = P(*([None] * 1), None) if False else None
        return out

    for top in ("blocks", "first_block", "shared", "encoder", "decoder"):
        if top not in params_tree:
            continue
        sub = params_tree[top]
        lead_t = () if top == "shared" else (None,)
        if "in_proj" in sub:                      # mamba2 block stack
            spec[top] = _ssm_spec(pol, lead_t)
        else:
            s: Dict[str, Any] = {}
            for k, v in sub.items():
                if k in ("attn", "xattn"):
                    at = _attn_spec(pol, lead_t)
                    # encdec attn carries biases
                    for bk in ("bq", "bv", "bo"):
                        if bk in v:
                            at[bk] = (P(*lead_t, None, None) if bk != "bo"
                                      else P(*lead_t, None))
                    s[k] = at
                elif k == "mlp":
                    f = (cfg.first_layer_dense_ff
                         if top == "first_block" else cfg.d_ff)
                    ms = _mlp_spec(pol, lead_t, f)
                    for bk in ("bi", "bo"):
                        if bk in v:
                            ms[bk] = P(*lead_t,
                                       ms["wi"][-1] if bk == "bi" else None)
                    s[k] = ms
                elif k == "moe":
                    s[k] = _moe_spec(pol, lead_t)
                else:                             # norms / biases
                    nd = jax.tree.leaves(v)[0].ndim if not hasattr(
                        v, "ndim") else v.ndim
                    s[k] = P(*([None] * nd))
            spec[top] = s
    for k in ("dec_pos", "enc_final_g", "enc_final_b", "final_g",
              "final_b"):
        if k in params_tree:
            nd = params_tree[k].ndim
            spec[k] = P(*([None] * nd))
    return spec


# ---------------------------------------------------------------------------
# public API: NamedSharding trees
# ---------------------------------------------------------------------------

def param_sharding(cfg: ModelConfig, mesh: Mesh, params_tree: Any, *,
                   fsdp: bool = True) -> Any:
    pol = make_policy(cfg, mesh, fsdp=fsdp)
    spec = param_spec(cfg, pol, params_tree)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                        is_leaf=lambda x: isinstance(x, P))


def batch_sharding(cfg: ModelConfig, mesh: Mesh, batch_tree: Dict,
                   global_batch: int) -> Dict:
    """Shard the batch dim over (pod, data) when divisible."""
    axes = data_axes(mesh)
    dsz = _data_size(mesh)
    b_ax = axes if (global_batch % dsz == 0 and dsz > 1) else ()
    out = {}
    for k, v in batch_tree.items():
        nd = v.ndim
        spec = [None] * nd
        if nd >= 1:
            spec[0] = b_ax if b_ax else None
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def cache_sharding(cfg: ModelConfig, mesh: Mesh, cache_tree: Any,
                   global_batch: int) -> Any:
    """KV/SSD cache sharding.  Dense k/v: (L,B,KH,C,dh); ssm state:
    (L,B,G,gh,P,N); conv: (L,B,K-1,Ci); hybrid attn_k: (apps,B,KH,C,dh);
    cross_k: (L,B,KH,T,dh)."""
    pol = make_policy(cfg, mesh)
    axes = data_axes(mesh)
    dsz = _data_size(mesh)
    b_ax = axes if (global_batch % dsz == 0 and dsz > 1) else None

    def spec_for(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        nd = leaf.ndim
        if name in ("k", "v", "attn_k", "attn_v", "cross_k", "cross_v"):
            if pol.kv_cache_mode == "kv_heads":
                return P(None, b_ax, "model", None, None)
            # sequence sharding — only when the seq axis divides (the
            # whisper cross-KV T=1500 does not; replicate it instead)
            if leaf.shape[3] % _axis_size(mesh, "model") == 0:
                return P(None, b_ax, None, "model", None)
            return P(None, b_ax, None, None, None)
        if name == "state":     # (L,B,G,gh,P,N): shard heads on model
            gh = leaf.shape[3]
            gh_ax = "model" if _div(gh, mesh, "model") else None
            return P(None, b_ax, None, gh_ax, None, None)
        if name == "conv":      # (L,B,K-1,Ci)
            return P(None, b_ax, None, None)
        return P(*([None] * nd))

    # jax 0.4.x spells this jax.tree_util.tree_map_with_path; the
    # jax.tree.map_with_path alias only exists in later releases
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_for(path, leaf)),
        cache_tree)


def replicated(mesh: Mesh) -> NamedSharding:
    """The fully-replicated NamedSharding on ``mesh`` — what every
    traced bookkeeping value (block tables, active lengths, current
    tokens) is pinned to on a sharded engine: their VALUES change every
    step, but their placement never does, so the jit cache sees one
    stable signature."""
    return NamedSharding(mesh, P())


def engine_shardings(cfg: ModelConfig, mesh: Mesh, params_tree: Any,
                     cache_tree: Any, *, global_batch: int,
                     cache1_tree: Any = None) -> Dict[str, Any]:
    """NamedSharding trees for a ``ServingEngine``'s traced state on
    ``mesh`` — the single entry point the serving layer shards through
    (docs/ARCHITECTURE.md §9).

    Returns a dict with:

      * ``"params"`` — the Megatron-style weight shardings
        (``param_sharding``), FSDP off: a serving mesh replicates
        weights over ``data`` (replicas are separate engines) and
        shards heads / FFN / experts / vocab over ``model``;
      * ``"cache"`` — the KV arena sharding (``cache_sharding``).  For
        a contiguous engine ``cache_tree`` is the ``(L, max_slots, …)``
        ring tree; for a PAGED engine it is the ``PagedKVPool`` leaf
        tree ``(L, n_blocks, KH, bs, dh)``, which shards through the
        same per-leaf rules (the block axis sits where batch does and
        replicates on a data=1 serving mesh, kv-heads shard on
        ``model`` when divisible);
      * ``"cache1"`` (when ``cache1_tree`` is given) — the batch=1
        chunked-prefill cache sharding, so a chunk state keeps one
        placement from first chunk to activation;
      * ``"repl"`` — the fully-replicated sharding for traced
        bookkeeping (block tables, lengths, current tokens).

    Shapes may be ``jax.ShapeDtypeStruct`` leaves (``jax.eval_shape``)
    — only ``.shape``/``.ndim`` are read."""
    out = {
        "params": param_sharding(cfg, mesh, params_tree, fsdp=False),
        "cache": cache_sharding(cfg, mesh, cache_tree, global_batch),
        "repl": replicated(mesh),
    }
    if cache1_tree is not None:
        out["cache1"] = cache_sharding(cfg, mesh, cache1_tree, 1)
    return out
