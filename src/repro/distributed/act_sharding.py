"""Activation-sharding context: explicit with_sharding_constraint hints
inside model code, active only when a mesh policy is installed (no-op in
single-device smoke tests).

Why: with FSDP-sharded weights (d_model dim on ``data``) GSPMD may
legally satisfy an einsum by REPLICATING the batch and sharding the
contraction — batch-replicated activations then get saved as remat
residuals (measured: phi4 train_4k temp 312 GiB/device).  Pinning
activations to P((pod, data), None, ...) forces the all-gather onto the
weights instead (the FSDP schedule) and keeps residuals batch-sharded.

This module deliberately imports nothing from repro.models (no cycles).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


def _current():
    return getattr(_STATE, "ctx", None)


class ActivationCtx:
    def __init__(self, mesh: Mesh, *, batch_divisible: bool,
                 logit_axis: Optional[str] = "model",
                 heads_divisible: bool = False,
                 seq_divisible: bool = False,
                 experts_divisible: bool = False):
        self.mesh = mesh
        self.batch_axes: Tuple[str, ...] = tuple(
            a for a in ("pod", "data") if a in mesh.axis_names)
        self.batch_divisible = batch_divisible
        self.logit_axis = (logit_axis if logit_axis in mesh.axis_names
                           else None)
        self.heads_divisible = heads_divisible and \
            "model" in mesh.axis_names
        self.seq_divisible = seq_divisible and "model" in mesh.axis_names
        self.experts_divisible = experts_divisible and \
            "model" in mesh.axis_names

    def batch_spec(self):
        return self.batch_axes if (self.batch_divisible
                                   and self.batch_axes) else None


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, *, batch_divisible: bool,
                        logit_axis: Optional[str] = "model",
                        heads_divisible: bool = False,
                        seq_divisible: bool = False,
                        experts_divisible: bool = False):
    prev = _current()
    _STATE.ctx = ActivationCtx(mesh, batch_divisible=batch_divisible,
                               logit_axis=logit_axis,
                               heads_divisible=heads_divisible,
                               seq_divisible=seq_divisible,
                               experts_divisible=experts_divisible)
    try:
        yield _STATE.ctx
    finally:
        _STATE.ctx = prev


def shard_act(x, *trailing):
    """Constrain a (B, ...) activation: batch on (pod, data), trailing
    dims per the given axis names (None = unsharded).  No-op without an
    active context."""
    ctx = _current()
    if ctx is None:
        return x
    spec = [ctx.batch_spec()] + list(trailing) \
        + [None] * (x.ndim - 1 - len(trailing))
    spec = [s if (s is None or isinstance(s, tuple)
                  or s in ctx.mesh.axis_names) else None for s in spec]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


def shard_logits(x):
    """(B, S, V) with V on the model axis."""
    ctx = _current()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh,
                         P(ctx.batch_spec(), None, ctx.logit_axis)))


def shard_seq(x):
    """Sequence parallelism (Korthikanti et al.): pin a (B,S,D) layer-
    boundary activation with S on the ``model`` axis.  Shrinks the
    remat residual stack msz-fold and turns wgrad contractions into
    partial sums; GSPMD inserts the gather before attention/MLP matmuls
    and the scatter after.  Falls back to batch-only sharding when the
    sequence doesn't divide."""
    ctx = _current()
    if ctx is None:
        return x
    if not ctx.seq_divisible:
        return shard_act(x)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(ctx.batch_spec(), "model",
                                     *([None] * (x.ndim - 2)))))


def shard_expert(x):
    """Expert-parallel dispatch tensor (G, E, C, ...) — groups on the
    data axes, experts on ``model``.  Pinning these prevents GSPMD's
    'involuntary full rematerialization' fallback on the MoE
    gather/scatter (measured: f32 expert activations were being
    all-reduced — §Perf C2)."""
    ctx = _current()
    if ctx is None or not ctx.experts_divisible:
        return x
    spec = [ctx.batch_spec(), "model"] + [None] * (x.ndim - 2)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


def gather_expert_weights(w):
    """Pin (E, D, F) expert weights to experts-on-model ONLY — i.e.
    explicitly all-gather the FSDP (data-sharded) D dim before the
    expert einsums.  Without this GSPMD keeps D sharded and partial-
    sums ACTIVATION-sized (G,E,C,F) tensors over data in the backward
    (measured 740 GB/device of f32 all-reduce — §Perf C3); the weight
    gather is ~75 MB/layer instead."""
    ctx = _current()
    if ctx is None or not ctx.experts_divisible:
        return w
    spec = ["model"] + [None] * (w.ndim - 1)
    return jax.lax.with_sharding_constraint(
        w, NamedSharding(ctx.mesh, P(*spec)))


def shard_group(x):
    """(G, T, ...) grouped-token tensor: groups on the data axes."""
    ctx = _current()
    if ctx is None:
        return x
    spec = [ctx.batch_spec()] + [None] * (x.ndim - 1)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


def shard_heads(x, head_axis_index: int = 2):
    """Pin a (B, ..., H, ...) attention activation with the flat-head
    dim on ``model`` (only when n_heads divides the axis — the caller
    signals that via heads_divisible at context creation)."""
    ctx = _current()
    if ctx is None or not ctx.heads_divisible:
        return x
    spec = [None] * x.ndim
    spec[0] = ctx.batch_spec()
    spec[head_axis_index] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


def shard_kv(x):
    """K/V (B,S,H,dh) inside attention: heads on ``model`` when they
    divide; otherwise SEQUENCE on ``model`` (flash-decoding-style
    partial attention — the softmax reductions over the sharded S
    become small (B,H,q) all-reduces, and the per-device logits shrink
    msz-fold).  This is the §Perf B2 lever for heads-indivisible archs
    (paligemma 8H, whisper 20H, phi4 24H over model=16)."""
    ctx = _current()
    if ctx is None:
        return x
    if ctx.heads_divisible:
        return shard_heads(x)
    if ctx.seq_divisible:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(ctx.mesh,
                             P(ctx.batch_spec(), "model", None, None)))
    return x
