"""Distribution layer: mesh-aware sharding specs for params, batches
and caches (pjit/GSPMD)."""

from .sharding import (batch_sharding, cache_sharding, data_axes,
                       param_sharding, ShardingPolicy)

__all__ = ["batch_sharding", "cache_sharding", "data_axes",
           "param_sharding", "ShardingPolicy"]
