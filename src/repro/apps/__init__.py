"""Reference TinyML applications from the paper's evaluation (§5.1):

* ``conv_reference`` — "an even smaller reference convolution model
  containing just two convolution layers, a max-pooling layer, a dense
  layer, and an activation layer" (§5.3, Table 2),
* ``hotword`` — a Google-Hotword-class keyword-spotting model (SVDF
  stack; the paper uses scrambled weights, we use seeded random ones),
* ``vww`` — a Visual-Wake-Words-class person-detection MobileNet-v1
  (Chowdhery et al. 2019) at 96×96×1.
"""

from .models import (build_conv_reference, build_fc_stack, build_hotword,
                     build_vww, paper_models)

__all__ = ["build_conv_reference", "build_fc_stack", "build_hotword",
           "build_vww", "paper_models"]
