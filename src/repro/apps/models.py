"""Builders for the paper's three evaluation models (§5)."""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.core.graph_builder import GraphBuilder


def build_conv_reference(seed: int = 0) -> GraphBuilder:
    """§5.3: two conv layers, one max-pool, one dense, one activation."""
    rng = np.random.default_rng(seed)
    gb = GraphBuilder("conv_reference")
    x = gb.input("image", (1, 16, 16, 1))
    w1 = gb.const(rng.normal(0, 0.4, (8, 3, 3, 1)).astype(np.float32), "w1")
    b1 = gb.const(rng.normal(0, 0.05, (8,)).astype(np.float32), "b1")
    h = gb.conv2d(x, w1, b1, stride=1, padding="SAME", activation="relu")
    h = gb.max_pool2d(h, k=2)
    w2 = gb.const(rng.normal(0, 0.4, (16, 3, 3, 8)).astype(np.float32), "w2")
    b2 = gb.const(np.zeros(16, np.float32), "b2")
    h = gb.conv2d(h, w2, b2, stride=2, padding="SAME", activation="relu")
    h = gb.mean(h, axes=[1, 2])
    wd = gb.const(rng.normal(0, 0.4, (10, 16)).astype(np.float32), "wd")
    bd = gb.const(np.zeros(10, np.float32), "bd")
    h = gb.fully_connected(h, wd, bd)
    gb.mark_output(gb.softmax(h))
    return gb


def build_hotword(seed: int = 1, features: int = 40, units: int = 64,
                  memory: int = 8, rank: int = 1,
                  n_layers: int = 3, n_classes: int = 4) -> GraphBuilder:
    """A Google-Hotword-class SVDF keyword spotter.

    The production model is proprietary ("we use a version with scrambled
    weights and biases" — §5.1); this reproduces its published shape: a
    stack of SVDF layers over streaming audio features, topped by a
    softmax over keyword classes (cf. Zhang et al. 2017 / TFLM's
    keyword_benchmark).
    """
    rng = np.random.default_rng(seed)
    gb = GraphBuilder("hotword")
    x = gb.input("features", (1, features))
    h = x
    dim = features
    for li in range(n_layers):
        nf = units * rank
        wf = gb.const(rng.normal(0, 1 / np.sqrt(dim),
                                 (nf, dim)).astype(np.float32), f"wf{li}")
        wt = gb.const(rng.normal(0, 1 / np.sqrt(memory),
                                 (nf, memory)).astype(np.float32), f"wt{li}")
        bias = gb.const(np.zeros(units, np.float32), f"b{li}")
        state = gb.variable(f"svdf_state{li}", (1, nf * memory))
        h = gb.svdf(h, wf, wt, bias, state, rank=rank, activation="relu")
        dim = units
    wd = gb.const(rng.normal(0, 1 / np.sqrt(dim),
                             (n_classes, dim)).astype(np.float32), "w_out")
    bd = gb.const(np.zeros(n_classes, np.float32), "b_out")
    h = gb.fully_connected(h, wd, bd)
    gb.mark_output(gb.softmax(h))
    return gb


def _dw_separable(gb: GraphBuilder, rng, h, in_ch: int, out_ch: int,
                  stride: int, idx: int):
    wdw = gb.const(rng.normal(0, 0.3, (1, 3, 3, in_ch)).astype(np.float32),
                   f"dw{idx}")
    bdw = gb.const(np.zeros(in_ch, np.float32), f"dwb{idx}")
    h = gb.depthwise_conv2d(h, wdw, bdw, stride=stride, padding="SAME",
                            activation="relu6")
    wpw = gb.const(
        rng.normal(0, np.sqrt(2.0 / in_ch),
                   (out_ch, 1, 1, in_ch)).astype(np.float32), f"pw{idx}")
    bpw = gb.const(np.zeros(out_ch, np.float32), f"pwb{idx}")
    return gb.conv2d(h, wpw, bpw, stride=1, padding="SAME",
                     activation="relu6")


def build_vww(seed: int = 2, width: float = 0.25,
              resolution: int = 96) -> GraphBuilder:
    """Visual-Wake-Words person detector: MobileNet-v1 0.25x @ 96×96×1
    (Chowdhery et al. 2019 — the model TFLM benchmarks in Figure 6)."""
    rng = np.random.default_rng(seed)

    def c(ch: int) -> int:
        return max(8, int(ch * width + 0.5) // 8 * 8)

    gb = GraphBuilder("vww_mobilenet")
    x = gb.input("image", (1, resolution, resolution, 1))
    w0 = gb.const(rng.normal(0, 0.3, (c(32), 3, 3, 1)).astype(np.float32),
                  "conv0")
    b0 = gb.const(np.zeros(c(32), np.float32), "conv0b")
    h = gb.conv2d(x, w0, b0, stride=2, padding="SAME", activation="relu6")
    plan = [  # (out_ch, stride) — MobileNet-v1 body
        (64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
        (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2),
        (1024, 1),
    ]
    in_ch = c(32)
    for i, (oc, s) in enumerate(plan):
        h = _dw_separable(gb, rng, h, in_ch, c(oc), s, i)
        in_ch = c(oc)
    h = gb.mean(h, axes=[1, 2])
    wd = gb.const(rng.normal(0, 1 / np.sqrt(in_ch),
                             (2, in_ch)).astype(np.float32), "fc")
    bd = gb.const(np.zeros(2, np.float32), "fcb")
    h = gb.fully_connected(h, wd, bd)
    gb.mark_output(gb.softmax(h))
    return gb


def build_fc_stack(seed: int = 3, features: int = 64,
                   hidden: int = 32, n_layers: int = 2,
                   n_classes: int = 8) -> GraphBuilder:
    """A pure fully-connected classifier — the int8 "FC family" the
    serving host routes at request granularity.  Stateless, so every
    request is a single-frame continuation; quantized int8 it is
    integer-exact, which makes it the bit-identity workhorse for the
    ragged micro path."""
    rng = np.random.default_rng(seed)
    gb = GraphBuilder("fc_stack")
    h = gb.input("features", (1, features))
    dim = features
    for li in range(n_layers):
        w = gb.const(rng.normal(0, 1 / np.sqrt(dim),
                                (hidden, dim)).astype(np.float32), f"w{li}")
        b = gb.const(rng.normal(0, 0.05, (hidden,)).astype(np.float32),
                     f"b{li}")
        h = gb.fully_connected(h, w, b, activation="relu")
        dim = hidden
    wo = gb.const(rng.normal(0, 1 / np.sqrt(dim),
                             (n_classes, dim)).astype(np.float32), "w_out")
    bo = gb.const(np.zeros(n_classes, np.float32), "b_out")
    gb.mark_output(gb.softmax(gb.fully_connected(h, wo, bo)))
    return gb


def paper_models() -> Dict[str, GraphBuilder]:
    return {
        "conv_reference": build_conv_reference(),
        "hotword": build_hotword(),
        "vww": build_vww(),
    }


def representative_dataset(gb: GraphBuilder, n: int = 8, seed: int = 9):
    rng = np.random.default_rng(seed)
    shapes = [gb.tensors[t].shape for t in gb.inputs]
    return [tuple(rng.normal(0, 1, s).astype(np.float32) for s in shapes)
            for _ in range(n)]
