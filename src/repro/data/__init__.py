"""Data substrate: seeded synthetic token pipeline with packing."""

from .pipeline import PackedLMDataset, SyntheticTokenSource, make_batches

__all__ = ["PackedLMDataset", "SyntheticTokenSource", "make_batches"]
