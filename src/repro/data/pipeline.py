"""Synthetic-but-structured data pipeline.

The container is offline, so the corpus is generated: a seeded Markov
token source (so the LM loss actually decreases — uniform random tokens
have no learnable signal), packed into fixed-length documents with EOS
separators, exactly the shape a production loader would emit.

Family-aware batching: VLM batches add a vision-embedding stub, audio
batches add frame embeddings — matching ``ModelBundle.batch_shapes``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.common import ModelConfig


class SyntheticTokenSource:
    """Order-1 Markov chain over the vocab: learnable structure."""

    def __init__(self, vocab: int, seed: int = 0, branching: int = 8):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        self.branching = branching
        # sparse transition table: each token can be followed by
        # ``branching`` successors (deterministic given the seed)
        table_rng = np.random.default_rng(seed + 1)
        self.successors = table_rng.integers(
            0, vocab, (vocab, branching), dtype=np.int32)

    def document(self, length: int) -> np.ndarray:
        out = np.empty(length, np.int32)
        tok = int(self.rng.integers(0, self.vocab))
        for i in range(length):
            out[i] = tok
            tok = int(self.successors[tok,
                                      self.rng.integers(0, self.branching)])
        return out


@dataclasses.dataclass
class PackedLMDataset:
    """Packs variable-length documents into (batch, seq) token blocks
    with next-token labels; EOS = vocab-1 separates documents; label -1
    masks the position after EOS (no cross-document prediction)."""

    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0

    def __post_init__(self):
        self.source = SyntheticTokenSource(self.cfg.vocab - 1,
                                           seed=self.seed)
        self.doc_rng = np.random.default_rng(self.seed + 2)
        self._buffer = np.empty(0, np.int32)

    def _fill(self, n: int):
        chunks = [self._buffer]
        total = len(self._buffer)
        eos = self.cfg.vocab - 1
        while total < n:
            dl = int(self.doc_rng.integers(self.seq // 4, self.seq))
            doc = self.source.document(dl)
            chunks.extend([doc, np.array([eos], np.int32)])
            total += dl + 1
        self._buffer = np.concatenate(chunks)

    def next_batch(self) -> Dict[str, np.ndarray]:
        need = self.batch * (self.seq + 1)
        self._fill(need)
        flat = self._buffer[:need]
        self._buffer = self._buffer[need:]
        block = flat.reshape(self.batch, self.seq + 1)
        tokens = block[:, :-1].copy()
        labels = block[:, 1:].astype(np.int32).copy()
        eos = self.cfg.vocab - 1
        labels[tokens == eos] = -1         # don't predict across docs
        out = {"tokens": tokens, "labels": labels}
        cfg = self.cfg
        if cfg.family == "vlm":
            out["vision"] = self.doc_rng.normal(
                0, 1, (self.batch, cfg.n_vision_tokens, cfg.d_vision)
            ).astype(np.float32)
        elif cfg.family == "audio":
            out["frames"] = self.doc_rng.normal(
                0, 0.1, (self.batch, cfg.n_audio_ctx, cfg.d_model)
            ).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


def make_batches(cfg: ModelConfig, batch: int, seq: int, n: int,
                 seed: int = 0):
    ds = PackedLMDataset(cfg, batch, seq, seed)
    return [ds.next_batch() for _ in range(n)]
