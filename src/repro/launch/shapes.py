"""Assigned input shapes + per-(arch, shape) dry-run specs.

  train_4k       seq_len=  4,096  global_batch= 256  (training)
  prefill_32k    seq_len= 32,768  global_batch=  32  (inference-prefill)
  decode_32k     seq_len= 32,768  global_batch= 128  (inference-decode)
  long_500k      seq_len=524,288  global_batch=   1  (long-context-decode)

Decode shapes lower ``serve_step`` (one new token against a cache of
seq_len), not ``train_step``.  long_500k needs sub-quadratic attention:
SSM/hybrid run natively (O(1) state); every attention arch here carries
a sliding-window decode variant (window=8192 ring cache), so all 10
archs lower long_500k — the window is the cache length.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.registry import ModelBundle

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                   # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def cache_len_for(cfg: ModelConfig, shape: InputShape) -> int:
    """Decode-cache capacity: full context for decode_32k; the sliding
    window for attention archs at long_500k (ring buffer)."""
    if shape.name == "long_500k" and cfg.sliding_window:
        return cfg.sliding_window
    return shape.seq_len


def _cache_shapes(bundle: ModelBundle, batch: int, cache_len: int) -> Any:
    """ShapeDtypeStruct tree for the cache — eval_shape, no allocation."""
    return jax.eval_shape(
        lambda: bundle.empty_cache(batch, cache_len,
                                   bundle.cfg.jnp_dtype()))


def input_specs(bundle: ModelBundle, shape: InputShape) -> Dict[str, Any]:
    """All abstract inputs for one (arch, shape) dry-run.

    train  -> {batch}
    prefill-> {batch}
    decode -> {cache, tokens, lengths}
    """
    cfg = bundle.cfg
    b, s = shape.global_batch, shape.seq_len
    if shape.mode in ("train", "prefill"):
        return {"batch": bundle.batch_shapes(shape.mode, b, s)}
    cl = cache_len_for(cfg, shape)
    toks = bundle.batch_shapes("decode", b, s)
    return {"cache": _cache_shapes(bundle, b, cl),
            "tokens": toks["tokens"], "lengths": toks["lengths"]}
