"""Training driver: ``python -m repro.launch.train --arch yi-6b
--reduced --steps 50``.

On the CPU container this runs REDUCED configs on a 1x1 mesh with the
production axis names; on real hardware the same code takes the
16x16 (or 2x16x16) mesh and full configs — the sharding specs are the
ones validated by the dry-run.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config, list_archs
from repro.data import PackedLMDataset
from repro.distributed.sharding import batch_sharding, param_sharding
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import get_model
from repro.training.trainer import (init_train_state, make_train_step,
                                    train_state_sharding)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="yi-6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    bundle = get_model(cfg)
    mesh = (make_production_mesh(multi_pod=args.multi_pod)
            if args.production_mesh else make_host_mesh())
    dsz = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                       if a in mesh.axis_names]))

    params = bundle.init(jax.random.PRNGKey(args.seed))
    state = init_train_state(params)
    p_shard = param_sharding(cfg, mesh, params)
    s_shard = train_state_sharding(p_shard, mesh)
    state = jax.tree.map(jax.device_put, state, s_shard)

    ds = PackedLMDataset(cfg, args.batch, args.seq, seed=args.seed)
    step_fn = make_train_step(bundle.loss, lr=args.lr,
                              grad_accum=args.grad_accum,
                              remat=not args.reduced, data_shards=dsz)
    b_shard = batch_sharding(cfg, mesh, {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype)
        for k, v in ds.next_batch().items()}, args.batch)
    jit_step = jax.jit(step_fn, in_shardings=(s_shard, b_shard))

    with mesh:
        t0 = time.time()
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
            state, metrics = jit_step(state, batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss={float(metrics['loss']):.4f}  "
                      f"gnorm={float(metrics['grad_norm']):.3f}  "
                      f"({time.time() - t0:.1f}s)")
            if args.ckpt_dir and args.ckpt_every \
                    and (i + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, i + 1, state)
    print(json.dumps({"final_loss": float(metrics["loss"]),
                      "steps": args.steps,
                      "wall_s": round(time.time() - t0, 1)}))


if __name__ == "__main__":
    main()
