"""Production mesh construction (MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.  Callers that need the 512-placeholder-device
mesh must set XLA_FLAGS before jax initializes (see dryrun.py lines
1–2).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: 16x16 = 256 chips per pod; 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh with the production axis names, for smoke
    tests and examples on CPU."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants (per chip) for the roofline model
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
