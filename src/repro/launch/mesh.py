"""Production mesh construction (MULTI-POD DRY-RUN step 1).

A FUNCTION, not a module-level constant — importing this module never
touches jax device state.  Callers that need the 512-placeholder-device
mesh must set XLA_FLAGS before jax initializes (see dryrun.py lines
1–2).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: 16x16 = 256 chips per pod; 2 pods multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh with the production axis names, for smoke
    tests and examples on CPU."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_serving_mesh(model: int = 1):
    """A ``(data=1, model=N)`` mesh for ONE sharded serving engine:
    tensor/expert parallelism over ``model``, no data axis — replica
    data-parallelism lives ABOVE the engine in ``ReplicaRouter``
    (docs/ARCHITECTURE.md §9), so each replica gets its own serving
    mesh rather than a slice of a shared data axis.

    Benchmarkable on CPU: set
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax
    initializes and ``make_serving_mesh(N)`` builds an N-way model
    mesh from the forced host devices — the same GSPMD programs that
    run on an N-chip pod."""
    if model < 1:
        raise ValueError(f"model axis must be >= 1, got {model}")
    if model > len(jax.devices()):
        raise ValueError(
            f"make_serving_mesh({model}): only {len(jax.devices())} "
            f"devices visible — set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={model} before "
            f"jax initializes to emulate a CPU mesh")
    return jax.make_mesh((1, model), ("data", "model"))


# TPU v5e hardware constants (per chip) for the roofline model
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # B/s
ICI_BW = 50e9                   # B/s per link
