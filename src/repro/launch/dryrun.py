import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) combination, lower and
compile the real step function (train_step / prefill_step / serve_step)
under pjit on the production mesh, then record:

  * memory_analysis()      — bytes per device (proves it fits),
  * cost_analysis()        — per-device HLO FLOPs / bytes accessed,
  * collective bytes       — parsed from the post-SPMD HLO text
                             (all-gather / all-reduce / reduce-scatter /
                              all-to-all / collective-permute),
  * the derived roofline terms (§Roofline).

Results are written as JSON to benchmarks/results/dryrun/ so the
roofline report and EXPERIMENTS.md are regenerable without recompiling.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k \
      [--multi-pod] [--all] [--fsdp/--no-fsdp] [--out DIR]
"""

import argparse
import json
import re
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, list_archs
from repro.distributed.sharding import (batch_sharding, cache_sharding,
                                        param_sharding)
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.shapes import SHAPES, cache_len_for, input_specs
from repro.models import get_model
from repro.training.optimizer import adamw_init
from repro.training.trainer import (TrainState, make_train_step,
                                    train_state_sharding)

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str):
    """Per-collective-kind result bytes (per device, post-SPMD)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.search(r"=\s*(.+?)\s+(" + "|".join(_COLLECTIVES)
                      + r")(-start|-done)?\(", ls)
        if not m:
            continue
        if m.group(3) == "-done":
            continue          # avoid double count of async pairs
        kind = m.group(2)
        out[kind] += _shape_bytes(m.group(1))
        counts[kind] += 1
    return out, counts


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def _abstract_params(bundle):
    return jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))


def build_step(bundle, shape, mesh, *, fsdp: bool = True,
               grad_accum: int = 1):
    """Returns (fn, abstract_args, in_shardings)."""
    from repro.distributed.act_sharding import activation_sharding
    cfg = bundle.cfg
    params_sds = _abstract_params(bundle)
    p_shard = param_sharding(cfg, mesh, params_sds, fsdp=fsdp)
    specs = input_specs(bundle, shape)
    dsz = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                       if a in mesh.axis_names]))
    bdiv = shape.global_batch % dsz == 0

    msz = mesh.shape.get("model", 1)
    hdiv = bool(cfg.n_heads) and cfg.n_heads % msz == 0
    sdiv = shape.mode in ("train", "prefill") \
        and shape.seq_len % msz == 0
    ediv = bool(cfg.n_experts) and cfg.n_experts % msz == 0

    def with_ctx(fn):
        def wrapped(*a, **kw):
            with activation_sharding(mesh, batch_divisible=bdiv,
                                     heads_divisible=hdiv,
                                     seq_divisible=sdiv,
                                     experts_divisible=ediv):
                return fn(*a, **kw)
        return wrapped

    if shape.mode == "train":
        step = with_ctx(make_train_step(bundle.loss, lr=1e-4, remat=True,
                                        grad_accum=grad_accum,
                                        data_shards=dsz))
        opt_sds = jax.eval_shape(adamw_init, params_sds)
        state_sds = TrainState(params=params_sds, opt=opt_sds)
        state_shard = train_state_sharding(p_shard, mesh)
        b_shard = batch_sharding(cfg, mesh, specs["batch"],
                                 shape.global_batch)
        return step, (state_sds, specs["batch"]), (state_shard, b_shard)

    if shape.mode == "prefill":
        cl = cache_len_for(cfg, shape)

        def prefill_step(params, batch):
            return bundle.prefill(params, batch, cache_len=cl,
                                  window=cfg.sliding_window,
                                  data_shards=dsz)

        b_shard = batch_sharding(cfg, mesh, specs["batch"],
                                 shape.global_batch)
        return with_ctx(prefill_step), (params_sds, specs["batch"]), \
            (p_shard, b_shard)

    # decode
    def serve_step(params, cache, tokens, lengths):
        return bundle.decode(params, cache, tokens, lengths,
                             window=cfg.sliding_window, data_shards=dsz)

    c_shard = cache_sharding(cfg, mesh, specs["cache"],
                             shape.global_batch)
    tl_shard = batch_sharding(cfg, mesh,
                              {"tokens": specs["tokens"],
                               "lengths": specs["lengths"]},
                              shape.global_batch)
    args = (params_sds, specs["cache"], specs["tokens"], specs["lengths"])
    shards = (p_shard, c_shard, tl_shard["tokens"], tl_shard["lengths"])
    return with_ctx(serve_step), args, shards


# ---------------------------------------------------------------------------
# one dry-run
# ---------------------------------------------------------------------------

def run_one(arch: str, shape_name: str, multi_pod: bool, *,
            fsdp: bool = True, grad_accum: int = 1,
            verbose: bool = True) -> dict:
    cfg = get_config(arch)
    bundle = get_model(cfg)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    fn, args, shards = build_step(bundle, shape, mesh, fsdp=fsdp,
                                  grad_accum=grad_accum)

    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, in_shardings=shards).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()

    # loop-aware static analysis (XLA's cost_analysis counts a scan body
    # once — see hlo_analysis.py; raw numbers kept for comparison)
    from repro.launch.hlo_analysis import analyze
    hc = analyze(hlo)
    coll = hc.collective_bytes
    coll_counts = hc.collective_counts
    flops_dev = float(hc.flops)
    bytes_dev = float(hc.bytes_accessed)
    coll_dev = float(hc.total_collective_bytes)
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS_BF16,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_dev / ICI_BW,
    }
    dominant = max(terms, key=terms.get)

    # MODEL_FLOPS: 6·N·D for train; 2·N·D for a forward pass (prefill);
    # 2·N_active per generated token for decode
    n_params = cfg.n_params()
    n_active = cfg.n_active_params()
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode"
                                   else 1)
    mult = 6 if shape.mode == "train" else 2
    model_flops = mult * n_active * tokens
    model_flops_dev = model_flops / n_chips
    useful = model_flops_dev / flops_dev if flops_dev else 0.0

    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": shape.mode, "n_chips": n_chips, "fsdp": fsdp,
        "grad_accum": grad_accum,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "cost": {"flops_per_device": flops_dev,
                 "bytes_per_device": bytes_dev,
                 "xla_raw_flops": float(cost.get("flops", 0.0)),
                 "xla_raw_bytes": float(cost.get("bytes accessed", 0.0))},
        "collectives": {"bytes": coll, "counts": coll_counts,
                        "total_bytes_per_device": coll_dev},
        "roofline": dict(terms, dominant=dominant,
                         model_flops=model_flops,
                         model_flops_per_device=model_flops_dev,
                         useful_flops_fraction=useful),
    }
    if verbose:
        print(json.dumps(result, indent=1))
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) for the chosen mesh")
    ap.add_argument("--no-fsdp", dest="fsdp", action="store_false")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args(argv)

    combos = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                combos.append((a, s))
    else:
        if not (args.arch and args.shape):
            ap.error("need --arch and --shape (or --all)")
        combos = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in combos:
        tag = f"{arch}__{shape}__{'2x16x16' if args.multi_pod else '16x16'}"
        if not args.fsdp:
            tag += "__nofsdp"
        if args.grad_accum > 1:
            tag += f"__ga{args.grad_accum}"
        path = os.path.join(args.out, tag + ".json")
        try:
            res = run_one(arch, shape, args.multi_pod, fsdp=args.fsdp,
                          grad_accum=args.grad_accum)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            print(f"[ok] {tag}: compile={res['compile_s']}s "
                  f"dominant={res['roofline']['dominant']}")
        except Exception as e:                          # noqa: BLE001
            failures.append((tag, repr(e)))
            with open(path + ".err", "w") as f:
                f.write(traceback.format_exc())
            print(f"[FAIL] {tag}: {e!r}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
