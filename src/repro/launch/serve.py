"""Serving driver: ``python -m repro.launch.serve --arch qwen3-32b
--reduced --requests 8``.

Batched-request serving through the ServingEngine (continuous batching,
arena-planned KV).  The paper is an inference framework, so this is the
end-to-end driver: submit a workload of prompts, stream them through
fixed decode slots, report latency/throughput stats.

Two modes:

  * default — batch: submit everything, ``eng.run()``, print per-request
    latency and the throughput summary at the end.
  * ``--stream`` — interactive: a ``StreamingServer`` drives the engine
    (overlapped decode where the family supports it) on a background
    thread and every token is printed the moment the host learns it,
    with per-request TTFT / mean-ITL lines (docs/STREAMING.md).  This
    is the minimal serving front-end ``examples/streaming_client.py``
    builds its interactive demo on.
"""

from __future__ import annotations

import argparse
import json
import queue
import threading
import time
from typing import Any, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import get_model
from repro.serving import (Request, ServingEngine, STREAMING_FAMILIES,
                           StreamEvent)


class StreamingServer:
    """Minimal streaming front-end over one ``ServingEngine``:
    ``start()`` → ``submit()`` / ``stream()`` → ``shutdown()``.

    The engine runs on ONE dedicated background thread (engines are
    not thread-safe; the thread owns every engine call).  ``submit``
    hands prompts over a lock-protected inbox the loop drains before
    each engine tick, and the engine's ``on_token`` callback — firing
    on the loop thread — fans each ``StreamEvent`` out to a per-uid
    ``queue.Queue`` as it is emitted.  Consumers iterate ``stream(uid)``
    from any thread and see that request's tokens in order, exactly
    once, ending with the ``final`` event; the engine's own emission
    contract (docs/STREAMING.md) guarantees that holds across
    preemption and restore.

    ``shutdown()`` stops the loop after settling any in-flight
    overlapped step (``engine.drain()``), then unblocks every open
    stream with a ``None`` sentinel so no consumer hangs on a request
    the server will never finish."""

    def __init__(self, engine: ServingEngine, *, idle_s: float = 0.001):
        self.engine = engine
        engine.on_token = self._on_token
        self._idle_s = idle_s
        self._inbox: List[Request] = []
        self._lock = threading.Lock()
        self._streams: Dict[int, "queue.Queue"] = {}
        self._next_uid = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    @property
    def running(self) -> bool:
        """True between ``start()`` and ``shutdown()``."""
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "StreamingServer":
        """Spawn the engine loop thread (idempotent error: a second
        start while running is refused)."""
        if self.running:
            raise RuntimeError("server already running")
        self._stop.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="serving-loop", daemon=True)
        self._thread.start()
        return self

    def submit(self, tokens: np.ndarray, *, max_new_tokens: int = 16,
               uid: Optional[int] = None, **req_kw: Any) -> int:
        """Enqueue one prompt; returns the uid to ``stream()`` on.
        Extra keywords (priority, deadline_us, tenant, extras, …) pass
        through to ``Request``."""
        if not self.running:
            raise RuntimeError("server is not running")
        with self._lock:
            if uid is None:
                uid = self._next_uid
            self._next_uid = max(self._next_uid, uid + 1)
            if uid in self._streams:
                raise ValueError(f"uid {uid} already submitted")
            self._streams[uid] = queue.Queue()
            self._inbox.append(Request(
                uid=uid, tokens=np.asarray(tokens, np.int32),
                max_new_tokens=max_new_tokens, **req_kw))
        return uid

    def stream(self, uid: int, *,
               timeout: float = 60.0) -> Iterator[StreamEvent]:
        """Yield ``uid``'s StreamEvents in order until its ``final``
        token.  Raises ``queue.Empty`` if no token arrives within
        ``timeout`` seconds, and ``RuntimeError`` if the server shuts
        down with the request unfinished."""
        q = self._streams[uid]
        while True:
            ev = q.get(timeout=timeout)
            if ev is None:
                raise RuntimeError(
                    f"server shut down before request {uid} finished")
            yield ev
            if ev.final:
                return

    def result(self, uid: int):
        """The accumulated ``RequestResult`` for ``uid`` (None until
        the engine has seen the submission)."""
        return self.engine.results.get(uid)

    def shutdown(self, timeout: float = 30.0) -> None:
        """Stop the loop thread, drain any in-flight step, and unblock
        every open stream.  Safe to call twice."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=timeout)
            self._thread = None
        self.engine.drain()
        with self._lock:
            for uid, q in self._streams.items():
                res = self.engine.results.get(uid)
                if res is None or not res.done:
                    q.put(None)

    # -- loop thread ----------------------------------------------------

    def _on_token(self, ev: StreamEvent) -> None:
        self._streams[ev.uid].put(ev)

    def _loop(self) -> None:
        while not self._stop.is_set():
            with self._lock:
                pending, self._inbox = self._inbox, []
            for req in pending:
                self.engine.submit(req)
            if not self.engine.step():
                # idle: engine fully drained — nap until new work lands
                self._stop.wait(self._idle_s)
        self.engine.drain()


def _build_engine(args) -> ServingEngine:
    """One engine from the CLI knobs; ``--stream`` turns overlapped
    decode on for families the async loop supports."""
    cfg = get_config(args.arch, reduced=args.reduced)
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(args.seed))
    overlap = args.stream and cfg.family in STREAMING_FAMILIES
    return ServingEngine(bundle, params, max_slots=args.slots,
                         cache_len=args.cache_len, seed=args.seed,
                         overlap=overlap)


def _workload(cfg, args) -> List[Dict[str, Any]]:
    """The demo prompt mix: random prompts (plus the vision/audio
    extras multimodal families need)."""
    rng = np.random.default_rng(args.seed)
    reqs = []
    for uid in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2,
                                args.prompt_len + 1))
        extras = None
        if cfg.family == "vlm":
            extras = {"vision": rng.normal(
                0, 1, (cfg.n_vision_tokens, cfg.d_vision)
            ).astype(np.float32)}
        elif cfg.family == "audio":
            extras = {"frames": rng.normal(
                0, 0.1, (cfg.n_audio_ctx, cfg.d_model)
            ).astype(np.float32)}
        reqs.append(dict(
            uid=uid,
            tokens=rng.integers(0, cfg.vocab - 2, plen).astype(np.int32),
            max_new_tokens=args.max_new, extras=extras))
    return reqs


def _serve_stream(eng: ServingEngine, cfg, args) -> None:
    """``--stream`` mode: per-token delivery through StreamingServer,
    TTFT / mean-ITL per request."""
    from repro.serving import default_clock
    server = StreamingServer(eng).start()
    t0 = time.time()
    uids, t_sub = [], {}
    for r in _workload(cfg, args):
        t_sub[r["uid"]] = default_clock()
        uids.append(server.submit(
            r["tokens"], max_new_tokens=r["max_new_tokens"],
            uid=r["uid"], extras=r["extras"]))
    total = 0
    for uid in uids:
        stamps = []
        toks = []
        for ev in server.stream(uid):
            stamps.append(ev.t_us)
            toks.append(ev.token)
        total += len(toks)
        ttft_ms = (stamps[0] - t_sub[uid]) / 1e3
        itl = np.diff(stamps) / 1e3 if len(stamps) > 1 else np.zeros(1)
        print(f"  req {uid}: new={len(toks)}  ttft={ttft_ms:.2f}ms  "
              f"itl_mean={float(itl.mean()):.2f}ms  "
              f"tokens={toks[:8]}{'...' if len(toks) > 8 else ''}")
    wall = time.time() - t0
    server.shutdown()
    print(json.dumps({
        "mode": "stream", "overlap": eng.overlap,
        "wall_s": round(wall, 3), "tokens_generated": total,
        "tok_per_s": round(total / wall, 2),
    }))


def _serve_batch(eng: ServingEngine, cfg, args) -> None:
    """Default mode: submit everything, run to completion, print the
    per-request table and throughput summary."""
    t0 = time.time()
    for r in _workload(cfg, args):
        eng.submit(Request(**r))
    results = eng.run()
    wall = time.time() - t0

    total_new = sum(len(r.output) for r in results.values())
    for uid in sorted(results):
        r = results[uid]
        print(f"  req {uid}: prompt={r.prompt_len}  new={len(r.output)}  "
              f"prefill={r.prefill_s * 1e3:.1f}ms  "
              f"decode={r.decode_s * 1e3:.1f}ms  "
              f"tokens={r.output[:8]}{'...' if len(r.output) > 8 else ''}")
    print(json.dumps({
        "wall_s": round(wall, 3),
        "tokens_generated": total_new,
        "tok_per_s": round(total_new / wall, 2),
        "arena_persistent_bytes": eng.arena.usage().persistent,
    }))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-32b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--stream", action="store_true",
                    help="per-token streaming through StreamingServer "
                         "(overlapped decode where supported)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    eng = _build_engine(args)
    print(f"arch={cfg.arch_id}  requests={args.requests}  "
          f"slots={args.slots}  mode={'stream' if args.stream else 'batch'}")
    if args.stream:
        _serve_stream(eng, cfg, args)
    else:
        _serve_batch(eng, cfg, args)


if __name__ == "__main__":
    main()
