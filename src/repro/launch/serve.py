"""Serving driver: ``python -m repro.launch.serve --arch qwen3-32b
--reduced --requests 8``.

Batched-request serving through the ServingEngine (continuous batching,
arena-planned KV).  The paper is an inference framework, so this is the
end-to-end driver: submit a workload of prompts, stream them through
fixed decode slots, report latency/throughput stats.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, list_archs
from repro.models import get_model
from repro.serving import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen3-32b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    bundle = get_model(cfg)
    params = bundle.init(jax.random.PRNGKey(args.seed))
    eng = ServingEngine(bundle, params, max_slots=args.slots,
                        cache_len=args.cache_len, seed=args.seed)

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for uid in range(args.requests):
        plen = int(rng.integers(args.prompt_len // 2,
                                args.prompt_len + 1))
        extras = None
        if cfg.family == "vlm":
            extras = {"vision": rng.normal(
                0, 1, (cfg.n_vision_tokens, cfg.d_vision)
            ).astype(np.float32)}
        elif cfg.family == "audio":
            extras = {"frames": rng.normal(
                0, 0.1, (cfg.n_audio_ctx, cfg.d_model)
            ).astype(np.float32)}
        eng.submit(Request(
            uid=uid,
            tokens=rng.integers(0, cfg.vocab - 2, plen).astype(np.int32),
            max_new_tokens=args.max_new, extras=extras))
    results = eng.run()
    wall = time.time() - t0

    total_new = sum(len(r.output) for r in results.values())
    print(f"arch={cfg.arch_id}  requests={args.requests}  "
          f"slots={args.slots}")
    for uid in sorted(results):
        r = results[uid]
        print(f"  req {uid}: prompt={r.prompt_len}  new={len(r.output)}  "
              f"prefill={r.prefill_s * 1e3:.1f}ms  "
              f"decode={r.decode_s * 1e3:.1f}ms  "
          f"tokens={r.output[:8]}{'...' if len(r.output) > 8 else ''}")
    print(json.dumps({
        "wall_s": round(wall, 3),
        "tokens_generated": total_new,
        "tok_per_s": round(total_new / wall, 2),
        "arena_persistent_bytes": eng.arena.usage().persistent,
    }))


if __name__ == "__main__":
    main()
