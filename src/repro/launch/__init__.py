"""Launch layer: production mesh, input shapes, dry-run, drivers."""
