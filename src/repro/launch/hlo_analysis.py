"""Static cost analysis over post-SPMD optimized HLO text.

Why this exists: ``compiled.cost_analysis()`` counts a ``while`` body
ONCE, ignoring the trip count — a 64-layer ``lax.scan`` transformer is
undercounted ~64x (verified against a 10-step scan of matmuls).  The
roofline must be honest, so we re-derive the three terms from the HLO
call graph with loop multipliers:

  * computations are parsed into blocks; ``while`` instructions carry
    ``body=`` / ``condition=`` references, and the trip count is read
    from the loop-bound constant in the condition computation;
  * FLOPs: every ``dot`` contributes 2 * numel(result) * K, where K is
    the product of the lhs contracting dims (exact — matches XLA's
    number for non-loop programs); convolutions contribute
    2 * numel(result) * prod(kernel_spatial) * C_in;
  * bytes: per top-level instruction, operands + result (the same
    convention XLA's own 'bytes accessed' uses); fusion bodies are not
    double-counted (their operands/results are HBM traffic, their
    internals are registers/VMEM);
  * collective bytes: result bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, times the loop
    multiplier of the computation they sit in.

Everything is per-DEVICE (the HLO is the post-partitioning module).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4,
                "s32": 4, "u32": 4, "bf16": 2, "f16": 2, "s16": 2,
                "u16": 2, "s8": 1, "u8": 1, "pred": 1,
                "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+"
                       r"([a-z][a-z0-9\-]*)\((.*)")


def _shape_list(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _shape_list(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _numel(text: str) -> int:
    total = 0
    for _, dims in _shape_list(text):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    result_type: str
    op: str
    rest: str           # text after the opening paren (args + attrs)
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: List[Instr]
    shapes: Dict[str, str]          # instr/param name -> result type text


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(name=m.group(2),
                                  is_entry=bool(m.group(1)),
                                  instrs=[], shapes={})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rtype, op, rest = m.groups()
        cur.shapes[name] = rtype
        cur.instrs.append(Instr(name, rtype, op, rest,
                                is_root=line.lstrip().startswith("ROOT")))
    return comps


_CALLEE_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)="
    r"(?:\{([^}]*)\}|%?([\w.\-]+))")


def _callees(instr: Instr) -> List[Tuple[str, str]]:
    """[(kind, callee_name)] where kind is the attribute name."""
    out = []
    for m in re.finditer(r"(calls|to_apply|body|condition|"
                         r"branch_computations)="
                         r"(?:\{([^}]*)\}|%?([\w.\-]+))", instr.rest):
        attr, group_list, single = m.groups()
        names = ([n.strip().lstrip("%") for n in group_list.split(",")]
                 if group_list else [single])
        for n in names:
            out.append((attr, n))
    return out


def _trip_count(cond: Computation) -> int:
    """Loop bound: the max integer constant appearing in the condition
    computation (jax scans lower to `lt(i, K)`)."""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.op + "(" + ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _build_multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    entry = next((c for c in comps.values() if c.is_entry), None)
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    if entry is None:
        return mult

    import collections
    stack = [(entry.name, 1.0)]
    seen_depth = collections.Counter()
    while stack:
        name, m = stack.pop()
        if name not in comps:
            continue
        mult[name] += m
        seen_depth[name] += 1
        if seen_depth[name] > 10_000:      # cycle guard
            continue
        comp = comps[name]
        for ins in comp.instrs:
            for kind, callee in _callees(ins):
                if callee not in comps:
                    continue
                if kind == "body":
                    cond_name = next((c for k, c in _callees(ins)
                                      if k == "condition"), None)
                    trips = (_trip_count(comps[cond_name])
                             if cond_name in comps else 1)
                    stack.append((callee, m * trips))
                elif kind == "condition":
                    continue               # negligible
                else:
                    stack.append((callee, m))
    return mult


_FUSION_KINDS = ("fusion",)


def _dot_flops(ins: Instr, comp: Computation) -> float:
    """2 * numel(result) * K (K = product of lhs contracting dims)."""
    result_n = _numel(ins.result_type)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rest)
    if not m:
        return 2.0 * result_n            # degenerate
    cdims = [int(d) for d in m.group(1).split(",") if d]
    args = ins.rest.split(")")[0]
    first_arg = args.split(",")[0].strip().lstrip("%")
    lhs_type = comp.shapes.get(first_arg, "")
    shapes = _shape_list(lhs_type)
    if not shapes and "[" in first_arg:
        shapes = _shape_list(first_arg)   # inline-typed operand
    if not shapes:
        # operand shape inline in args, e.g. "bf16[8,16]{1,0} %foo"
        shapes = _shape_list(args)
    if not shapes:
        return 2.0 * result_n
    dims = shapes[0][1]
    k = 1
    for c in cdims:
        if c < len(dims):
            k *= dims[c]
    return 2.0 * result_n * k


def _conv_flops(ins: Instr, comp: Computation) -> float:
    result_n = _numel(ins.result_type)
    m = re.search(r"window=\{size=([0-9x]+)", ins.rest)
    spatial = 1
    if m:
        for d in m.group(1).split("x"):
            spatial *= int(d)
    args = ins.rest.split(")")[0]
    names = [a.strip().lstrip("%") for a in args.split(",")]
    cin = 1
    if len(names) >= 2:
        rhs_type = comp.shapes.get(names[1], "")
        sh = _shape_list(rhs_type)
        if sh:
            # kernel layout: spatial... x Cin x Cout (heuristic: use
            # total kernel elements / Cout where Cout = result feature)
            kn = 1
            for d in sh[0][1]:
                kn *= d
            res_sh = _shape_list(ins.result_type)
            cout = res_sh[0][1][-1] if res_sh and res_sh[0][1] else 1
            return 2.0 * result_n * (kn / max(cout, 1))
    return 2.0 * result_n * spatial * cin


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes_accessed: float
    collective_bytes: Dict[str, float]
    collective_counts: Dict[str, float]

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(hlo_text: str) -> HloCost:
    comps = parse_hlo(hlo_text)
    mult = _build_multipliers(comps)

    # which computations are fusion bodies? (skip their bytes, keep dots)
    fusion_bodies = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op in _FUSION_KINDS:
                for kind, callee in _callees(ins):
                    if kind in ("calls", "to_apply"):
                        fusion_bodies.add(callee)

    # fusions whose root is a dynamic-update-slice alias their big
    # operand in place: actual HBM traffic is the update slice, not the
    # whole buffer (XLA buffer assignment aliases input 0 to the
    # output).  Same for a bare dynamic-update-slice instruction.
    dus_fusions = set()
    for name in fusion_bodies:
        if name in comps:
            for ins in comps[name].instrs:
                if ins.is_root and ins.op == "dynamic-update-slice":
                    dus_fusions.add(name)

    flops = 0.0
    nbytes = 0.0
    coll_b = {k: 0.0 for k in COLLECTIVES}
    coll_n = {k: 0.0 for k in COLLECTIVES}
    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        in_fusion = comp.name in fusion_bodies
        for ins in comp.instrs:
            base = ins.op
            if base.endswith("-start"):
                base = base[:-6]
            if base in COLLECTIVES:
                coll_b[base] += m * _shape_bytes(ins.result_type)
                coll_n[base] += m
                continue
            if base.endswith("-done"):
                continue
            if base == "dot":
                flops += m * _dot_flops(ins, comp)
            elif base == "convolution":
                flops += m * _conv_flops(ins, comp)
            if not in_fusion and base not in ("parameter", "constant",
                                              "tuple", "get-tuple-element",
                                              "bitcast"):
                if base == "dynamic-slice":
                    # reads only the slice, not the whole operand
                    nbytes += m * 2 * _shape_bytes(ins.result_type)
                    continue
                aliased = base == "dynamic-update-slice" or (
                    base == "fusion" and any(
                        c in dus_fusions for _, c in _callees(ins)))
                rbytes = _shape_bytes(ins.result_type)
                args = ins.rest.split(")")[0]
                opb = _shape_bytes(args)          # inline-typed operands
                for a in args.split(","):
                    nm = a.strip().lstrip("%")
                    if nm in comp.shapes:
                        b = _shape_bytes(comp.shapes[nm])
                        if aliased and b == rbytes:
                            continue              # in-place alias
                        opb += b
                if aliased:
                    rbytes = opb                  # write ≈ the slice
                nbytes += m * (rbytes + opb)
    return HloCost(flops=flops, bytes_accessed=nbytes,
                   collective_bytes=coll_b, collective_counts=coll_n)
