"""Training substrate: AdamW, LR schedules, TrainState, train-step
factory (remat + grad clipping + pjit shardings)."""

from .optimizer import (AdamWState, adamw_init, adamw_update,
                        cosine_schedule, clip_by_global_norm)
from .trainer import TrainState, make_train_step, train_state_sharding

__all__ = ["AdamWState", "adamw_init", "adamw_update", "cosine_schedule",
           "clip_by_global_norm", "TrainState", "make_train_step",
           "train_state_sharding"]
