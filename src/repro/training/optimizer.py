"""AdamW + schedules as pure pytree transforms (no external deps).

Optimizer moments are kept in float32 regardless of param dtype
(bf16-safe); the update is computed in f32 and cast back.  State
sharding follows the param sharding leaf-for-leaf, so ZeRO-style
FSDP partitioning of m/v falls out of the same PartitionSpec tree.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray          # () int32
    mu: Any                    # f32 pytree like params
    nu: Any                    # f32 pytree like params


def adamw_init(params: Any) -> AdamWState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def clip_by_global_norm(grads: Any, max_norm: float):
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gnorm


def adamw_update(grads: Any, state: AdamWState, params: Any, *,
                 lr, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1):
    """Returns (new_params, new_state).  ``lr`` is a scalar or a
    callable step -> scalar."""
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else lr

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if p.ndim >= 2:                      # decay matrices only
            delta = delta + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    flat_p = tdef.flatten_up_to(params)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor)
                         * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(s < warmup, warm, cos)
    return lr
