"""TrainState + train-step factory.

``make_train_step`` builds a jit-able (state, batch) -> (state, metrics)
function with gradient clipping, AdamW, and optional grad accumulation;
``train_state_sharding`` maps the param sharding tree onto the optimizer
moments so pjit partitions m/v identically (ZeRO-3 over the fsdp'd
dims).
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from .optimizer import AdamWState, adamw_init, adamw_update, \
    clip_by_global_norm


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def init_train_state(params: Any) -> TrainState:
    return TrainState(params=params, opt=adamw_init(params))


def make_train_step(loss_fn: Callable, *, lr=3e-4, max_grad_norm=1.0,
                    grad_accum: int = 1, weight_decay: float = 0.1,
                    **loss_kwargs) -> Callable:
    """loss_fn(params, batch, **loss_kwargs) -> (loss, metrics)."""

    def single(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, **loss_kwargs)
        return loss, metrics, grads

    def step(state: TrainState, batch) -> tuple:
        if grad_accum > 1:
            def micro(carry, mb):
                loss_acc, grads_acc = carry
                loss, _, grads = single(state.params, mb)
                grads_acc = jax.tree.map(jnp.add, grads_acc, grads)
                return (loss_acc + loss, grads_acc), None

            micro_batches = jax.tree.map(
                lambda x: x.reshape(grad_accum, x.shape[0] // grad_accum,
                                    *x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.float32(0), zeros), micro_batches)
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            metrics = {"ce_loss": loss}
        else:
            loss, metrics, grads = single(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        new_params, new_opt = adamw_update(
            grads, state.opt, state.params, lr=lr,
            weight_decay=weight_decay)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                       step=new_opt.step)
        return TrainState(params=new_params, opt=new_opt), metrics

    return step


def train_state_sharding(param_sharding: Any, mesh) -> Any:
    """TrainState sharding tree: opt moments mirror the params."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    scalar = NamedSharding(mesh, P())
    return TrainState(
        params=param_sharding,
        opt=AdamWState(step=scalar, mu=param_sharding,
                       nu=param_sharding))
