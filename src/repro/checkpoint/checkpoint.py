"""Sharded npz checkpointing.

Layout: <dir>/<step>/
  manifest.json      — flat-key -> {shape, dtype, file}
  shard_<i>.npz      — leaves, chunked so no single npz exceeds ~1 GB

Leaves are addressed by their flattened pytree key-path, so restore is
order-independent and tolerates added/removed leaves (strict=False).
Arrays are pulled to host (fully addressable) before save; restore
optionally device_puts onto a provided sharding tree.
"""

from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_SHARD_BYTES = 1 << 30


def _flat_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    out = os.path.join(ckpt_dir, str(step))
    os.makedirs(out, exist_ok=True)
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    manifest, shard, shard_bytes, shard_idx = {}, {}, 0, 0

    def flush():
        nonlocal shard, shard_bytes, shard_idx
        if shard:
            np.savez(os.path.join(out, f"shard_{shard_idx}.npz"), **shard)
            shard, shard_bytes = {}, 0
            shard_idx += 1

    for path, leaf in flat:
        key = _flat_key(path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == jnp.bfloat16:
            arr = arr.view(np.uint16)
            dtype = "bfloat16"
        else:
            dtype = str(arr.dtype)
        safe = re.sub(r"[^A-Za-z0-9_]", "__", key)
        manifest[key] = {"shape": list(arr.shape), "dtype": dtype,
                         "file": f"shard_{shard_idx}.npz", "entry": safe}
        shard[safe] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= _SHARD_BYTES:
            flush()
    flush()
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return out


def restore_checkpoint(ckpt_dir: str, step: int, like: Any,
                       shardings: Optional[Any] = None,
                       strict: bool = True) -> Any:
    src = os.path.join(ckpt_dir, str(step))
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)
    cache = {}

    def load(key, leaf):
        if key not in manifest:
            if strict:
                raise KeyError(f"checkpoint missing {key}")
            return leaf
        meta = manifest[key]
        fn = meta["file"]
        if fn not in cache:
            cache[fn] = np.load(os.path.join(src, fn))
        arr = cache[fn][meta["entry"]]
        if meta["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        return arr.reshape(meta["shape"])

    flat = jax.tree_util.tree_flatten_with_path(like)
    leaves = [load(_flat_key(p), leaf) for p, leaf in flat[0]]
    restored = jax.tree_util.tree_unflatten(flat[1], leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    return restored
