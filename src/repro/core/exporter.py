"""Exporter — the Figure-1 conversion pipeline.

Takes a GraphBuilder model (the "trained TensorFlow model" stand-in) and
produces a deployable µFB blob, applying the passes the paper attributes
to the TensorFlow Lite toolchain (§3.3):

  * ``strip_training_ops``  — removes DROPOUT / IDENTITY ("removing
    dropout and similar operations that are only useful during training"),
  * ``fold_constants``      — "folding constant expressions into fixed
    values",
  * ``quantize``            — post-training INT8 quantization with a
    representative dataset (Krishnamoorthi 2018), per-channel weights,
    int32 biases, calibrated activation ranges,
  * optional offline memory planning embedded as metadata (§4.4.2).
"""

from __future__ import annotations

import copy
from dataclasses import replace
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from . import quantize as Q
from .graph_builder import GraphBuilder, _BuilderPrepareCtx, _FakeOp, \
    _shape_inference_resolver
from .schema import OpCode, OpDef, QuantParams, TensorDef, TensorFlags

_PASSTHROUGH_OPS = {OpCode.DROPOUT, OpCode.IDENTITY}

# ops whose int8 path exists in the reference kernels
_QUANTIZABLE = {
    OpCode.CONV_2D, OpCode.DEPTHWISE_CONV_2D, OpCode.FULLY_CONNECTED,
    OpCode.ADD, OpCode.MUL, OpCode.SUB, OpCode.MAX_POOL_2D,
    OpCode.AVERAGE_POOL_2D, OpCode.RESHAPE, OpCode.MEAN, OpCode.SOFTMAX,
    OpCode.RELU, OpCode.RELU6, OpCode.LOGISTIC, OpCode.TANH,
    OpCode.CONCATENATION, OpCode.PAD, OpCode.TRANSPOSE,
}


# ---------------------------------------------------------------------------
# pass: strip training-only ops
# ---------------------------------------------------------------------------

def strip_training_ops(gb: GraphBuilder) -> GraphBuilder:
    """Remove DROPOUT/IDENTITY by rewiring consumers to the op's input."""
    alias: Dict[int, int] = {}

    def resolve(t: int) -> int:
        while t in alias:
            t = alias[t]
        return t

    new_ops: List[OpDef] = []
    for op in gb.ops:
        if op.opcode in _PASSTHROUGH_OPS:
            alias[op.outputs[0]] = op.inputs[0]
            continue
        new_ops.append(OpDef(
            op.opcode,
            tuple(resolve(t) if t >= 0 else t for t in op.inputs),
            op.outputs, dict(op.params)))
    gb2 = _clone(gb)
    gb2.ops = new_ops
    gb2.outputs = [resolve(t) for t in gb.outputs]
    for t in gb2.outputs:
        gb2.tensors[t].flags |= TensorFlags.IS_MODEL_OUTPUT
    return _garbage_collect(gb2)


# ---------------------------------------------------------------------------
# pass: constant folding
# ---------------------------------------------------------------------------

def fold_constants(gb: GraphBuilder) -> GraphBuilder:
    """Evaluate ops whose inputs are all const; bake results as consts."""
    import jax.numpy as jnp

    gb2 = _clone(gb)
    resolver = _shape_inference_resolver()
    changed = True
    while changed:
        changed = False
        remaining: List[OpDef] = []
        for op in gb2.ops:
            ins = [t for t in op.inputs if t >= 0]
            if ins and all(t in gb2.const_data for t in ins) \
                    and not any(gb2.tensors[t].is_variable for t in ins) \
                    and op.opcode != OpCode.QUANTIZE:
                reg = resolver.resolve(op.opcode)
                ctx = _BuilderPrepareCtx(gb2)
                prep = reg.prepare(ctx, op)
                from .interpreter import EvalContext
                ectx = EvalContext(
                    prep.op_data, prep.output_specs,
                    [gb2.tensors[t].quant for t in op.outputs])
                vals = [jnp.asarray(gb2.const_data[t]) if t >= 0 else None
                        for t in op.inputs]
                outs = reg.eval(ectx, op, vals)
                for t, v in zip(op.outputs, outs[:len(op.outputs)]):
                    gb2.const_data[t] = np.asarray(v)
                    gb2.tensors[t].flags |= TensorFlags.IS_CONST
                changed = True
            else:
                remaining.append(op)
        gb2.ops = remaining
    return _garbage_collect(gb2)


# ---------------------------------------------------------------------------
# pass: post-training INT8 quantization
# ---------------------------------------------------------------------------

def calibrate(gb: GraphBuilder,
              representative_dataset: Iterable[Sequence[np.ndarray]],
              ) -> Dict[int, Tuple[float, float]]:
    """Run the float graph over a representative dataset, recording
    min/max per tensor (the TFLite calibration step)."""
    import jax.numpy as jnp

    resolver = _shape_inference_resolver()
    ranges: Dict[int, Tuple[float, float]] = {}

    def note(t: int, v) -> None:
        v = np.asarray(v, np.float32)
        lo, hi = float(v.min()), float(v.max())
        if t in ranges:
            plo, phi = ranges[t]
            ranges[t] = (min(lo, plo), max(hi, phi))
        else:
            ranges[t] = (lo, hi)

    for sample in representative_dataset:
        env: Dict[int, np.ndarray] = dict(
            {t: gb.const_data[t] for t in gb.const_data})
        var_env: Dict[int, np.ndarray] = {
            i: np.zeros(t.shape, np.float32)
            for i, t in enumerate(gb.tensors) if t.is_variable}
        for pos, t in enumerate(gb.inputs):
            env[t] = np.asarray(sample[pos], np.float32)
            note(t, env[t])
        for op in gb.ops:
            reg = resolver.resolve(op.opcode)
            ctx = _BuilderPrepareCtx(gb)
            prep = reg.prepare(ctx, op)
            from .interpreter import EvalContext
            ectx = EvalContext(prep.op_data, prep.output_specs,
                               [gb.tensors[t].quant for t in op.outputs])
            vals = []
            for t in op.inputs:
                if t < 0:
                    vals.append(None)
                elif t in var_env:
                    vals.append(jnp.asarray(var_env[t]))
                else:
                    vals.append(jnp.asarray(env[t]))
            outs = reg.eval(ectx, op, vals)
            for t, v in zip(op.outputs, outs[:len(op.outputs)]):
                env[t] = np.asarray(v)
                note(t, env[t])
            for t, v in zip(prep.variable_updates,
                            outs[len(op.outputs):]):
                var_env[t] = np.asarray(v)
    return ranges


def quantize(gb: GraphBuilder,
             representative_dataset: Iterable[Sequence[np.ndarray]],
             float_io: bool = True) -> GraphBuilder:
    """Whole-graph post-training INT8 quantization."""
    for op in gb.ops:
        if op.opcode not in _QUANTIZABLE:
            raise NotImplementedError(
                f"op {op.name} has no int8 path; the exporter would need "
                f"a float fallback island (TFLite selective quantization)")
    ranges = calibrate(gb, representative_dataset)

    q = GraphBuilder(gb.name + "_int8")
    q.metadata = dict(gb.metadata)
    tmap: Dict[int, int] = {}

    def act_quant(t: int) -> QuantParams:
        if gb.ops and _producer_opcode(gb, t) == OpCode.SOFTMAX:
            return QuantParams(1.0 / 256.0, -128)    # TFLite convention
        lo, hi = ranges.get(t, (-1.0, 1.0))
        s, z = Q.choose_quant_params(lo, hi)
        return QuantParams(s, z)

    # tensors
    for i, t in enumerate(gb.tensors):
        if t.is_const:
            continue                                  # handled per-use
        qp = act_quant(i)
        nt = TensorDef(t.name, t.shape, "int8", t.flags & ~TensorFlags.NONE,
                       qp)
        q.tensors.append(nt)
        tmap[i] = len(q.tensors) - 1

    # weights/bias per consuming op (per-channel for conv/fc kernels)
    for op in gb.ops:
        new_ins: List[int] = []
        if op.opcode in (OpCode.CONV_2D, OpCode.DEPTHWISE_CONV_2D,
                         OpCode.FULLY_CONNECTED):
            x_t, w_t = op.inputs[0], op.inputs[1]
            b_t = op.inputs[2] if len(op.inputs) > 2 else None
            w = gb.const_data[w_t]
            ch_axis = (3 if op.opcode == OpCode.DEPTHWISE_CONV_2D else 0)
            wq, wscales = Q.quantize_weights_per_channel(w, ch_axis)
            wt = TensorDef(gb.tensors[w_t].name, w.shape, "int8",
                           TensorFlags.IS_CONST,
                           QuantParams(0.0, 0, wscales, ch_axis))
            q.tensors.append(wt)
            wq_idx = len(q.tensors) - 1
            q.const_data[wq_idx] = wq
            new_ins = [tmap[x_t], wq_idx]
            if b_t is not None and b_t >= 0:
                x_scale = q.tensors[tmap[x_t]].quant.scale
                bq = Q.quantize_bias(gb.const_data[b_t], x_scale, wscales)
                bt = TensorDef(gb.tensors[b_t].name, bq.shape, "int32",
                               TensorFlags.IS_CONST, QuantParams())
                q.tensors.append(bt)
                q.const_data[len(q.tensors) - 1] = bq
                new_ins.append(len(q.tensors) - 1)
        else:
            for t in op.inputs:
                if t < 0:
                    new_ins.append(t)
                elif t in gb.const_data:
                    c = gb.const_data[t]
                    s, z = Q.choose_quant_params(float(c.min()),
                                                 float(c.max()))
                    cq = Q.quantize_array(c, s, z)
                    ct = TensorDef(gb.tensors[t].name, c.shape, "int8",
                                   TensorFlags.IS_CONST, QuantParams(s, z))
                    q.tensors.append(ct)
                    q.const_data[len(q.tensors) - 1] = cq
                    new_ins.append(len(q.tensors) - 1)
                else:
                    new_ins.append(tmap[t])
        q.ops.append(OpDef(op.opcode, tuple(new_ins),
                           tuple(tmap[t] for t in op.outputs),
                           dict(op.params)))

    q.inputs = [tmap[t] for t in gb.inputs]
    q.outputs = [tmap[t] for t in gb.outputs]

    if float_io:
        _wrap_float_io(q, gb, ranges, tmap)
    return q


def _wrap_float_io(q: GraphBuilder, gb: GraphBuilder, ranges, tmap) -> None:
    """Insert QUANTIZE after float inputs and DEQUANTIZE before outputs,
    keeping the application ABI in float (TFLite float_io converters)."""
    new_inputs = []
    pre_ops: List[OpDef] = []
    for pos, t in enumerate(q.inputs):
        spec = q.tensors[t]
        fin = TensorDef(spec.name + "_f", spec.shape, "float32",
                        TensorFlags.IS_MODEL_INPUT)
        q.tensors.append(fin)
        fidx = len(q.tensors) - 1
        pre_ops.append(OpDef(OpCode.QUANTIZE, (fidx,), (t,), {}))
        q.tensors[t].flags &= ~TensorFlags.IS_MODEL_INPUT
        new_inputs.append(fidx)
    post_ops: List[OpDef] = []
    new_outputs = []
    for t in q.outputs:
        spec = q.tensors[t]
        fout = TensorDef(spec.name + "_f", spec.shape, "float32",
                         TensorFlags.IS_MODEL_OUTPUT)
        q.tensors.append(fout)
        fidx = len(q.tensors) - 1
        post_ops.append(OpDef(OpCode.DEQUANTIZE, (t,), (fidx,), {}))
        q.tensors[t].flags &= ~TensorFlags.IS_MODEL_OUTPUT
        new_outputs.append(fidx)
    q.ops = pre_ops + q.ops + post_ops
    q.inputs = new_inputs
    q.outputs = new_outputs


# ---------------------------------------------------------------------------
# utilities
# ---------------------------------------------------------------------------

def _producer_opcode(gb: GraphBuilder, t: int) -> Optional[int]:
    for op in gb.ops:
        if t in op.outputs:
            return op.opcode
    return None


def _clone(gb: GraphBuilder) -> GraphBuilder:
    gb2 = GraphBuilder(gb.name)
    gb2.tensors = [TensorDef(t.name, t.shape, t.dtype, t.flags, t.quant)
                   for t in gb.tensors]
    gb2.ops = [OpDef(o.opcode, o.inputs, o.outputs, dict(o.params))
               for o in gb.ops]
    gb2.const_data = dict(gb.const_data)
    gb2.inputs = list(gb.inputs)
    gb2.outputs = list(gb.outputs)
    gb2.metadata = dict(gb.metadata)
    return gb2


def _garbage_collect(gb: GraphBuilder) -> GraphBuilder:
    """Drop unreferenced tensors and reindex (keeps blobs small)."""
    live = set(gb.inputs) | set(gb.outputs)
    for op in gb.ops:
        live |= {t for t in op.inputs if t >= 0}
        live |= set(op.outputs)
    order = sorted(live)
    remap = {old: new for new, old in enumerate(order)}
    gb2 = GraphBuilder(gb.name)
    gb2.metadata = dict(gb.metadata)
    gb2.tensors = [gb.tensors[t] for t in order]
    gb2.const_data = {remap[t]: d for t, d in gb.const_data.items()
                      if t in remap}
    gb2.ops = [OpDef(o.opcode,
                     tuple(remap[t] if t >= 0 else t for t in o.inputs),
                     tuple(remap[t] for t in o.outputs), dict(o.params))
               for o in gb.ops]
    gb2.inputs = [remap[t] for t in gb.inputs]
    gb2.outputs = [remap[t] for t in gb.outputs]
    return gb2


# ---------------------------------------------------------------------------
# one-call export
# ---------------------------------------------------------------------------

def export(gb: GraphBuilder,
           representative_dataset=None,
           quantize_int8: bool = False,
           offline_plan: bool = False) -> bytes:
    """Figure-1 end-to-end: passes + serialization -> deployable blob."""
    gb = strip_training_ops(gb)
    gb = fold_constants(gb)
    if quantize_int8:
        if representative_dataset is None:
            raise ValueError("int8 export needs a representative dataset")
        gb = quantize(gb, representative_dataset)
    return gb.build(offline_plan=offline_plan)
