"""Two-stack arena allocator (paper §4.4.1, Figure 3).

The application hands the interpreter ONE contiguous memory arena.  All
allocation happens during initialization; nothing may allocate during
invoke.  Two stacks grow toward each other:

    +------------------------------------------------------------------+
    | head →  (nonpersistent / function-lifetime)     temp     ← tail  |
    |                                               (persistent)       |
    +------------------------------------------------------------------+

* ``head`` grows upward from offset 0: function-lifetime data — the
  memory-planner-compacted activation/scratch section, reusable between
  invocations (and between models under multitenancy, §4.5).
* ``tail`` grows downward from ``size``: interpreter-lifetime data —
  tensor runtime metadata, requant tables, variable tensors, the plan.
* the gap between the stacks doubles as a *temporary* allocation region
  used only while memory planning runs (paper: "we used the space in
  between the two stacks as temporary allocations when a model is in
  memory planning"); it must be reset before invoke.

When the two stack pointers cross we raise — the TFLM application-level
"arena too small" error.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

DEFAULT_ALIGN = 16


class ArenaOverflowError(MemoryError):
    """Head and tail stacks crossed: the supplied arena is too small."""


def align_up(n: int, a: int = DEFAULT_ALIGN) -> int:
    return (n + a - 1) & ~(a - 1)


def align_down(n: int, a: int = DEFAULT_ALIGN) -> int:
    return n & ~(a - 1)


@dataclass
class Allocation:
    """One recorded arena allocation: byte offset, size, and the tag
    that names what lives there (for the memory report)."""

    offset: int
    nbytes: int
    tag: str


@dataclass
class ArenaUsage:
    """Snapshot of arena occupancy: persistent (tail) and nonpersistent
    (head) bytes, planning-time temp high water, and capacity — the
    numbers behind the Table-2 memory split."""

    persistent: int
    nonpersistent: int
    temp_high_water: int
    total: int
    capacity: int


class TwoStackArena:
    """Byte-exact two-stack allocator over a fixed-size arena."""

    def __init__(self, size_bytes: int, alignment: int = DEFAULT_ALIGN):
        if size_bytes <= 0:
            raise ValueError("arena size must be positive")
        self.size = int(size_bytes)
        self.alignment = alignment
        self._head = 0                  # first free byte of the head stack
        self._tail = self.size          # one past last used byte of tail
        self._temp = 0                  # bytes currently allocated in temp
        self._temp_high_water = 0
        self._frozen = False
        self.head_allocs: List[Allocation] = []
        self.tail_allocs: List[Allocation] = []

    # ------------------------------------------------------------------
    def _check_cross(self, head: int, tail: int) -> None:
        if head + self._temp > tail:
            raise ArenaOverflowError(
                f"arena exhausted: head={head} + temp={self._temp} "
                f"crosses tail={tail} (capacity {self.size})")

    def allocate_persistent(self, nbytes: int, tag: str = "") -> int:
        """Tail stack: interpreter-lifetime. Returns the offset."""
        self._assert_not_frozen()
        nbytes = int(nbytes)
        new_tail = align_down(self._tail - nbytes, self.alignment)
        self._check_cross(self._head, new_tail)
        self._tail = new_tail
        self.tail_allocs.append(Allocation(new_tail, nbytes, tag))
        return new_tail

    def allocate_nonpersistent(self, nbytes: int, tag: str = "") -> int:
        """Head stack: function-lifetime. Returns the offset."""
        self._assert_not_frozen()
        off = align_up(self._head, self.alignment)
        self._check_cross(off + int(nbytes), self._tail)
        self._head = off + int(nbytes)
        self.head_allocs.append(Allocation(off, int(nbytes), tag))
        return off

    def reserve_nonpersistent_section(self, nbytes: int, tag: str = "plan") -> int:
        """Reserve the planner-compacted section as one head allocation."""
        return self.allocate_nonpersistent(nbytes, tag)

    # -- temp region (between the stacks; planning-time only) -----------
    def allocate_temp(self, nbytes: int) -> int:
        self._assert_not_frozen()
        off = align_up(self._head + self._temp, self.alignment)
        self._check_cross(self._head, self._tail)
        if off + nbytes > self._tail:
            raise ArenaOverflowError(
                f"temp allocation of {nbytes} bytes does not fit between "
                f"stacks (gap={self._tail - self._head})")
        self._temp = (off + nbytes) - self._head
        self._temp_high_water = max(self._temp_high_water, self._temp)
        return off

    def reset_temp(self) -> None:
        self._temp = 0

    # ------------------------------------------------------------------
    def freeze(self) -> None:
        """End of the init phase: no further allocation of any kind.

        The paper: "we ensure that allocations only occur during the
        interpreter's initialization phase".
        """
        if self._temp:
            raise RuntimeError("temp allocations outstanding at freeze()")
        self._frozen = True

    def _assert_not_frozen(self) -> None:
        if self._frozen:
            raise RuntimeError(
                "allocation after init phase is forbidden (paper §4.4.1)")

    @property
    def frozen(self) -> bool:
        return self._frozen

    # ------------------------------------------------------------------
    @property
    def head_used(self) -> int:
        return self._head

    @property
    def tail_used(self) -> int:
        return self.size - self._tail

    @property
    def free_bytes(self) -> int:
        return self._tail - self._head - self._temp

    def usage(self) -> ArenaUsage:
        return ArenaUsage(
            persistent=self.tail_used,
            nonpersistent=self.head_used,
            temp_high_water=self._temp_high_water,
            total=self.tail_used + self.head_used,
            capacity=self.size,
        )

    # -- multitenancy (§4.5) --------------------------------------------
    def fork_tenant(self) -> "TwoStackArena":
        """A second interpreter allocating from the SAME arena.

        Persistent (tail) allocations stack below the previous tenant's;
        the nonpersistent head section is SHARED — each tenant re-plans it
        from offset 0 and the effective requirement is the max over
        tenants (Figure 5).
        """
        child = TwoStackArena(self.size, self.alignment)
        child._tail = self._tail              # stack under our persistents
        child._head = 0                       # reuse the shared head region
        child._parent = self                  # type: ignore[attr-defined]
        return child

    def absorb_tenant(self, child: "TwoStackArena") -> None:
        """Commit a tenant's allocations back into the shared accounting."""
        self._tail = child._tail
        self.tail_allocs.extend(child.tail_allocs)
        self._head = max(self._head, child._head)
        self.head_allocs.extend(child.head_allocs)
        self._temp_high_water = max(self._temp_high_water,
                                    child._temp_high_water)
