"""Operator resolution (paper §4.1 OpResolver, §4.7–4.8 kernel specialization).

Two TFLM mechanisms are reproduced exactly:

1. **Selective linking.**  ``MicroMutableOpResolver`` starts empty; the
   application registers only the ops its model needs ("controls which
   operators link to the final binary, minimizing executable size").  Our
   size analogue is the *registration footprint* — unregistered ops are
   simply absent and resolving them raises, and the memory benchmark counts
   the bytes of registered implementations.

2. **Platform tags.**  Each opcode may have several implementations keyed
   by tag — ``"reference"`` (readable pure-jnp, the paper's reference
   kernels) and e.g. ``"pallas"`` (the TPU-optimized vendor-kernel
   analogue of CMSIS-NN, selected at build time via ``TAGS=...``).
   ``resolve(opcode)`` walks the tag priority list, so swapping in an
   optimized kernel requires no interpreter changes (§4.8).

The interpreter↔kernel boundary mirrors TFLM's C API: every kernel is a
(prepare, eval) pair.  ``prepare(ctx, op)`` runs once at init — it checks
shapes/dtypes, computes output specs, precomputes requant constants, and
requests scratch buffers from the arena.  ``eval(ctx, op, inputs)`` runs
inside the jitted invoke and must be a pure function of its inputs.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .schema import OP_NAMES, SERVING_OPCODES

REFERENCE_TAG = "reference"


@dataclass
class TensorSpec:
    """Shape + dtype of one tensor as the prepare phase resolves it."""

    shape: Tuple[int, ...]
    dtype: str


@dataclass
class PrepareResult:
    """What a kernel's prepare() tells the interpreter (TFLM: communicated
    through the context during the preparation phase, §4.1)."""
    output_specs: List[TensorSpec]
    scratch_nbytes: List[int] = field(default_factory=list)
    persistent_nbytes: int = 0          # requant tables etc. (tail stack)
    op_data: Any = None                 # opaque per-op baked constants
    variable_updates: List[int] = field(default_factory=list)
    # ^ tensor indices of variable tensors this op updates in place (e.g.
    #   SVDF state); eval returns their new values after its outputs.


@dataclass(frozen=True)
class OpRegistration:
    """One kernel implementation of one opcode under one vendor tag:
    its prepare/eval pair plus a code-size estimate (the Table-2
    linked-code analogue)."""

    opcode: int
    tag: str
    prepare: Callable[..., PrepareResult]
    eval: Callable[..., Sequence[Any]]
    # rough implementation footprint in bytes (code-size analogue used by
    # the Table-2 memory benchmark); defaults to the bytecode size.
    code_nbytes: int = 0

    @property
    def name(self) -> str:
        return f"{OP_NAMES.get(self.opcode, self.opcode)}[{self.tag}]"


class _Registry:
    """Global registry that vendor kernel libraries populate at import time
    (the analogue of dropping a CMSIS-NN subfolder into kernels/)."""

    def __init__(self) -> None:
        self._impls: Dict[Tuple[int, str], OpRegistration] = {}

    def register(self, opcode: int, tag: str,
                 prepare: Callable, eval_fn: Callable) -> OpRegistration:
        code = 0
        for fn in (prepare, eval_fn):
            co = getattr(fn, "__code__", None)
            if co is not None:
                code += len(co.co_code) + 4 * len(co.co_consts or ())
        reg = OpRegistration(opcode, tag, prepare, eval_fn, code)
        self._impls[(opcode, tag)] = reg
        return reg

    def lookup(self, opcode: int, tag: str) -> Optional[OpRegistration]:
        return self._impls.get((opcode, tag))

    def tags_for(self, opcode: int) -> List[str]:
        return [t for (oc, t) in self._impls if oc == opcode]

    def opcodes(self) -> List[int]:
        return sorted({oc for (oc, _) in self._impls})


GLOBAL_REGISTRY = _Registry()


def register_op(opcode: int, tag: str = REFERENCE_TAG):
    """Decorator used by kernel libraries::

        @register_op(OpCode.CONV_2D, tag="pallas")
        class PallasConv:
            @staticmethod
            def prepare(ctx, op): ...
            @staticmethod
            def eval(ctx, op, inputs): ...
    """
    def wrap(impl):
        prepare = getattr(impl, "prepare")
        eval_fn = getattr(impl, "eval")
        GLOBAL_REGISTRY.register(opcode, tag, prepare, eval_fn)
        return impl
    return wrap


class OpResolutionError(KeyError):
    """No registration for an opcode under the requested tag chain —
    the op was never linked in (TFLM's unresolved-op error)."""


def resolve_chain(opcode: int, tags: Sequence[str]) -> OpRegistration:
    """Walk the tag priority chain for one opcode (the §4.8 build-tag
    mechanism).  Shared by the per-model resolver below and by callers
    that resolve directly against the global registry."""
    for tag in tags:
        reg = GLOBAL_REGISTRY.lookup(opcode, tag)
        if reg is not None:
            return reg
    raise OpResolutionError(
        f"no implementation of {OP_NAMES.get(opcode, opcode)} for "
        f"tags {tuple(tags)}; available tags: "
        f"{GLOBAL_REGISTRY.tags_for(opcode)}")


class MicroMutableOpResolver:
    """The application-facing resolver: register exactly what you need.

    ``tags`` is the build-tag priority list, e.g. ``("pallas", "reference")``
    — the TFLM ``TAGS="cmsis-nn"`` analogue: optimized implementations
    shadow reference ones per-kernel, falling back when a platform does not
    provide one.
    """

    def __init__(self, tags: Sequence[str] = (REFERENCE_TAG,)):
        self.tags = tuple(tags)
        self._linked: Dict[int, OpRegistration] = {}

    def add(self, opcode: int) -> "MicroMutableOpResolver":
        self._linked[opcode] = resolve_chain(opcode, self.tags)
        return self

    def add_many(self, opcodes: Sequence[int]) -> "MicroMutableOpResolver":
        for oc in opcodes:
            self.add(oc)
        return self

    def resolve(self, opcode: int) -> OpRegistration:
        try:
            return self._linked[opcode]
        except KeyError:
            raise OpResolutionError(
                f"operator {OP_NAMES.get(opcode, opcode)} was not registered "
                f"with this resolver (TFLM: op not linked into the binary)")

    @property
    def linked_ops(self) -> List[OpRegistration]:
        return list(self._linked.values())

    def code_nbytes(self) -> int:
        """Registration footprint: the Table-2 'code size' analogue."""
        return sum(r.code_nbytes for r in self._linked.values())


class AllOpsResolver(MicroMutableOpResolver):
    """Convenience resolver linking every registered op (TFLM's
    ``AllOpsResolver`` — larger footprint, zero configuration)."""

    def __init__(self, tags: Sequence[str] = (REFERENCE_TAG,)):
        super().__init__(tags)
        for oc in GLOBAL_REGISTRY.opcodes():
            if oc in SERVING_OPCODES:
                continue        # pod-scale macro-ops: not micro kernels
            if any(GLOBAL_REGISTRY.lookup(oc, t) for t in tags):
                self.add(oc)
