"""Memory planners (paper §4.4.2, Figure 4).

Intermediate tensors are rectangles in (time × size) space: each buffer is
needed from just before the op that populates it until the last op that
reads it.  Compacting them is bin packing; TF Micro uses *first-fit
decreasing* (Garey et al., 1972): sort requirements by size descending and
place each at the lowest offset where it does not collide with any
already-placed buffer whose lifetime overlaps.

Planners provided:

* ``GreedyMemoryPlanner``  — first-fit decreasing (the paper's planner).
* ``LinearMemoryPlanner``  — no reuse; every buffer gets its own offset
  (the paper's "simplistic approach [that] works well for initial
  prototyping, but wastes memory"); the baseline in Figure 4a.
* ``OfflineMemoryPlanner`` — replays a precomputed offset array carried in
  model metadata (paper: "offline-planned tensor allocation").

All planners are pure Python over integer byte ranges — they run in the
interpreter init phase only, matching the paper's "more overhead during
model preparation ... benefit of model generality" trade-off.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .arena import DEFAULT_ALIGN, align_up


@dataclass(frozen=True)
class BufferRequest:
    """One rectangle: `nbytes` needed on [first_use, last_use] (op indices,
    inclusive)."""
    nbytes: int
    first_use: int
    last_use: int
    tag: str = ""

    def __post_init__(self):
        if self.nbytes < 0:
            raise ValueError("negative buffer size")
        if self.last_use < self.first_use:
            raise ValueError(f"lifetime ends before it starts: {self}")

    def overlaps_in_time(self, other: "BufferRequest") -> bool:
        return not (self.last_use < other.first_use
                    or other.last_use < self.first_use)


@dataclass
class MemoryPlan:
    """A planner's output: one byte offset per BufferRequest inside a
    ``total_bytes`` nonpersistent section, time-overlap safe
    (``validate()`` proves it)."""

    offsets: List[int]            # parallel to the request list
    total_bytes: int
    requests: List[BufferRequest]

    def validate(self) -> None:
        """No two time-overlapping buffers may overlap in address space."""
        n = len(self.requests)
        for i in range(n):
            ri, oi = self.requests[i], self.offsets[i]
            if oi + ri.nbytes > self.total_bytes:
                raise AssertionError(f"buffer {i} exceeds plan size")
            for j in range(i + 1, n):
                rj, oj = self.requests[j], self.offsets[j]
                if not ri.overlaps_in_time(rj):
                    continue
                if oi < oj + rj.nbytes and oj < oi + ri.nbytes:
                    raise AssertionError(
                        f"planned buffers {i} ({ri.tag}) and {j} ({rj.tag}) "
                        f"overlap in both time and space")

    def to_metadata(self) -> bytes:
        """Serialize offsets for embedding as model metadata (§4.4.2
        offline-planned tensor allocation)."""
        import struct

        out = struct.pack("<IQ", len(self.offsets), self.total_bytes)
        out += struct.pack(f"<{len(self.offsets)}q", *self.offsets)
        return out

    @staticmethod
    def offsets_from_metadata(raw: bytes) -> Tuple[List[int], int]:
        import struct

        n, total = struct.unpack_from("<IQ", raw, 0)
        offsets = list(struct.unpack_from(f"<{n}q", raw, 12))
        return offsets, total


class LinearMemoryPlanner:
    """No-reuse baseline (Figure 4a)."""

    name = "linear"

    def plan(self, requests: Sequence[BufferRequest],
             alignment: int = DEFAULT_ALIGN) -> MemoryPlan:
        offsets, cur = [], 0
        for r in requests:
            cur = align_up(cur, alignment)
            offsets.append(cur)
            cur += r.nbytes
        return MemoryPlan(offsets, cur, list(requests))


class GreedyMemoryPlanner:
    """First-fit decreasing over (time, address) rectangles (Figure 4b)."""

    name = "greedy_ffd"

    def plan(self, requests: Sequence[BufferRequest],
             alignment: int = DEFAULT_ALIGN) -> MemoryPlan:
        order = sorted(range(len(requests)),
                       key=lambda i: (-requests[i].nbytes,
                                      requests[i].first_use, i))
        offsets: List[Optional[int]] = [None] * len(requests)
        placed: List[int] = []          # indices already placed
        total = 0
        for i in order:
            r = requests[i]
            # Gather address intervals blocked by time-overlapping buffers.
            blockers = sorted(
                (offsets[j], offsets[j] + requests[j].nbytes)  # type: ignore
                for j in placed if r.overlaps_in_time(requests[j]))
            # First fit: lowest aligned offset with a big-enough gap.
            candidate = 0
            for lo, hi in blockers:
                if candidate + r.nbytes <= lo:
                    break
                candidate = max(candidate, align_up(hi, alignment))
            offsets[i] = candidate
            placed.append(i)
            total = max(total, candidate + r.nbytes)
        plan = MemoryPlan([int(o) for o in offsets], total, list(requests))
        plan.validate()
        return plan


class OfflineMemoryPlanner:
    """Replays a host-computed plan shipped in model metadata.

    Paper: "allows a more compact memory plan, gives memory-plan ownership
    and control to the end user, imposes less overhead on the MCU during
    initialization".
    """

    name = "offline"
    METADATA_KEY = "OfflineMemoryAllocation"

    def __init__(self, metadata: bytes):
        self._offsets, self._total = MemoryPlan.offsets_from_metadata(metadata)

    def plan(self, requests: Sequence[BufferRequest],
             alignment: int = DEFAULT_ALIGN) -> MemoryPlan:
        if len(requests) != len(self._offsets):
            raise ValueError(
                f"offline plan covers {len(self._offsets)} buffers but the "
                f"model needs {len(requests)}")
        plan = MemoryPlan(list(self._offsets), self._total, list(requests))
        plan.validate()                  # do not trust stale offline plans
        return plan


def select_planner(metadata: Dict[str, bytes], planner: Optional[object],
                   prefer_offline_plan: bool = True):
    """Planner choice for one model: an explicit planner wins; else the
    offline plan shipped in model metadata (§4.4.2) when preferred and
    present; else first-fit decreasing."""
    if planner is not None:
        return planner
    offline = metadata.get(OfflineMemoryPlanner.METADATA_KEY)
    if prefer_offline_plan and offline is not None:
        return OfflineMemoryPlanner(offline)
    return GreedyMemoryPlanner()


def plan_nonpersistent(op_inputs, op_outputs, planned_nbytes,
                       graph_inputs, graph_outputs, scratch, planner
                       ) -> Tuple[MemoryPlan, Dict[int, int], int]:
    """Plan a graph's nonpersistent arena section.

    Derives lifetimes for every planned intermediate tensor, runs the
    planner, and returns ``(plan, tensor_offset, scratch_bytes)``.
    Op-local scratch is always planned online, even under an offline
    tensor plan (TFLM: scratch comes from RequestScratchBufferInArena at
    prepare time); it packs into its own region above the tensors.
    """
    n_ops = len(op_inputs)
    tensor_requests, tensor_ids = lifetimes_from_graph(
        n_ops, op_inputs, op_outputs, planned_nbytes,
        graph_inputs, graph_outputs, None)
    scratch_requests, _ = lifetimes_from_graph(
        n_ops, [()] * n_ops, [()] * n_ops, {}, (), (), scratch)
    plan = planner.plan(tensor_requests)
    tensor_offset = {
        tid: plan.offsets[req_idx]
        for req_idx, tid in enumerate(tensor_ids) if tid >= 0}
    scratch_plan = GreedyMemoryPlanner().plan(scratch_requests) \
        if scratch_requests else None
    return plan, tensor_offset, (scratch_plan.total_bytes
                                 if scratch_plan else 0)


def lifetimes_from_graph(
    n_ops: int,
    op_inputs: Sequence[Sequence[int]],
    op_outputs: Sequence[Sequence[int]],
    tensor_nbytes: Dict[int, int],
    graph_inputs: Sequence[int],
    graph_outputs: Sequence[int],
    scratch: Optional[Dict[int, Sequence[int]]] = None,
) -> Tuple[List[BufferRequest], List[int]]:
    """Derive BufferRequests for every non-const intermediate tensor.

    Returns (requests, tensor_ids) — parallel lists.  Model inputs are live
    from op 0; model outputs are live through the final op (they must
    survive for the application to read, §4.1).  ``scratch`` maps op index
    -> list of scratch sizes requested by that op's prepare() — each lives
    only during its own op.
    """
    first: Dict[int, int] = {}
    last: Dict[int, int] = {}
    for t in graph_inputs:
        first[t] = 0
    for oi in range(n_ops):
        for t in op_outputs[oi]:
            first.setdefault(t, oi)
            last[t] = max(last.get(t, oi), oi)
        for t in op_inputs[oi]:
            if t < 0:
                continue
            if t in first:
                last[t] = max(last.get(t, oi), oi)
    for t in graph_outputs:
        if t in first:
            last[t] = n_ops - 1 if n_ops else 0
    requests, ids = [], []
    for t in sorted(first):
        if t not in tensor_nbytes:
            continue                      # const / variable: not planned here
        requests.append(BufferRequest(
            nbytes=tensor_nbytes[t],
            first_use=first[t],
            last_use=last.get(t, first[t]),
            tag=f"tensor{t}"))
        ids.append(t)
    if scratch:
        for oi, sizes in sorted(scratch.items()):
            for k, nb in enumerate(sizes):
                requests.append(BufferRequest(
                    nbytes=int(nb), first_use=oi, last_use=oi,
                    tag=f"scratch{oi}.{k}"))
                ids.append(-(oi * 1000 + k + 1))   # synthetic id for scratch
    return requests, ids
