"""GraphBuilder — the model-authoring front end feeding the exporter.

Plays the role of the TensorFlow/Keras training environment output in
Figure 1: users describe a model as a toposorted op graph; the exporter
(exporter.py) then applies conversion passes (constant folding, dropout
removal, post-training quantization) and serializes to µFB.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .memory_planner import GreedyMemoryPlanner, lifetimes_from_graph
from .schema import (MicroModel, OpCode, OpDef, QuantParams, TensorDef,
                     TensorFlags, serialize_model)


@dataclass(frozen=True)
class TensorRef:
    """Lightweight handle to a tensor being built: its index in the
    graph plus a back-reference for shape/dtype lookups."""

    index: int
    builder: "GraphBuilder" = field(repr=False, compare=False, hash=False)

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.builder.tensors[self.index].shape

    @property
    def dtype(self) -> str:
        return self.builder.tensors[self.index].dtype


class GraphBuilder:
    """Python-side model authoring API: declare inputs/consts/variables,
    chain ops (conv2d, fully_connected, svdf, ...), mark outputs — then
    ``export()`` serializes the graph into the µFB flatbuffer-analogue
    the interpreter loads."""

    def __init__(self, name: str = "model"):
        self.name = name
        self.tensors: List[TensorDef] = []
        self.ops: List[OpDef] = []
        self.const_data: Dict[int, np.ndarray] = {}
        self.inputs: List[int] = []
        self.outputs: List[int] = []
        self.metadata: Dict[str, bytes] = {}

    # ------------------------------------------------------------------
    def _add_tensor(self, name, shape, dtype, flags=TensorFlags.NONE,
                    quant: Optional[QuantParams] = None) -> TensorRef:
        t = TensorDef(name, tuple(int(d) for d in shape), dtype, flags,
                      quant or QuantParams())
        self.tensors.append(t)
        return TensorRef(len(self.tensors) - 1, self)

    def input(self, name: str, shape, dtype="float32",
              quant: Optional[QuantParams] = None) -> TensorRef:
        r = self._add_tensor(name, shape, dtype,
                             TensorFlags.IS_MODEL_INPUT, quant)
        self.inputs.append(r.index)
        return r

    def const(self, data: np.ndarray, name: str = "const",
              quant: Optional[QuantParams] = None) -> TensorRef:
        data = np.asarray(data)
        r = self._add_tensor(name, data.shape, data.dtype.name,
                             TensorFlags.IS_CONST, quant)
        self.const_data[r.index] = data
        return r

    def variable(self, name: str, shape, dtype="float32") -> TensorRef:
        return self._add_tensor(name, shape, dtype, TensorFlags.IS_VARIABLE)

    def mark_output(self, ref: TensorRef) -> TensorRef:
        self.tensors[ref.index].flags |= TensorFlags.IS_MODEL_OUTPUT
        self.outputs.append(ref.index)
        return ref

    # ------------------------------------------------------------------
    def _infer_and_add(self, opcode: int, inputs: Sequence[int],
                       params: Dict[str, Any], n_outputs: int = 1,
                       out_dtype: Optional[str] = None,
                       out_quant: Optional[QuantParams] = None
                       ) -> Union[TensorRef, List[TensorRef]]:
        """Run the registered prepare() to infer output shapes, then add
        the op + its output tensors."""
        from .op_resolver import AllOpsResolver
        op = OpDef(opcode, tuple(inputs), (), dict(params))
        resolver = _shape_inference_resolver()
        reg = resolver.resolve(opcode)
        ctx = _BuilderPrepareCtx(self)
        prep = reg.prepare(ctx, _FakeOp(opcode, tuple(inputs),
                                        tuple([-2] * n_outputs), params))
        outs = []
        for k, spec in enumerate(prep.output_specs):
            dt = out_dtype or spec.dtype
            r = self._add_tensor(f"{reg.name}.{len(self.ops)}.{k}",
                                 spec.shape, dt, quant=out_quant)
            outs.append(r)
        self.ops.append(OpDef(opcode, tuple(inputs),
                              tuple(r.index for r in outs), dict(params)))
        return outs[0] if n_outputs == 1 else outs

    # -- op sugar ---------------------------------------------------------
    def conv2d(self, x, w, b=None, stride=1, padding="SAME",
               dilation=1, activation="none", out_quant=None):
        s = (stride, stride) if isinstance(stride, int) else stride
        d = (dilation, dilation) if isinstance(dilation, int) else dilation
        ins = [x.index, w.index] + ([b.index] if b is not None else [])
        return self._infer_and_add(
            OpCode.CONV_2D, ins,
            dict(stride_h=s[0], stride_w=s[1], dilation_h=d[0],
                 dilation_w=d[1], padding=padding, activation=activation),
            out_quant=out_quant)

    def depthwise_conv2d(self, x, w, b=None, stride=1, padding="SAME",
                         activation="none", depth_multiplier=1,
                         out_quant=None):
        s = (stride, stride) if isinstance(stride, int) else stride
        ins = [x.index, w.index] + ([b.index] if b is not None else [])
        return self._infer_and_add(
            OpCode.DEPTHWISE_CONV_2D, ins,
            dict(stride_h=s[0], stride_w=s[1], padding=padding,
                 activation=activation, depth_multiplier=depth_multiplier),
            out_quant=out_quant)

    def fully_connected(self, x, w, b=None, activation="none",
                        out_quant=None):
        ins = [x.index, w.index] + ([b.index] if b is not None else [])
        return self._infer_and_add(OpCode.FULLY_CONNECTED, ins,
                                   dict(activation=activation),
                                   out_quant=out_quant)

    def svdf(self, x, w_feature, w_time, bias, state, rank=1,
             activation="relu"):
        ins = [x.index, w_feature.index, w_time.index,
               bias.index if bias is not None else -1, state.index]
        return self._infer_and_add(OpCode.SVDF, ins,
                                   dict(rank=rank, activation=activation))

    def add(self, a, b, activation="none", out_quant=None):
        return self._infer_and_add(OpCode.ADD, [a.index, b.index],
                                   dict(activation=activation),
                                   out_quant=out_quant)

    def mul(self, a, b, out_quant=None):
        return self._infer_and_add(OpCode.MUL, [a.index, b.index], {},
                                   out_quant=out_quant)

    def sub(self, a, b, out_quant=None):
        return self._infer_and_add(OpCode.SUB, [a.index, b.index], {},
                                   out_quant=out_quant)

    def max_pool2d(self, x, k=2, stride=None, padding="VALID",
                   out_quant=None):
        stride = stride or k
        return self._infer_and_add(
            OpCode.MAX_POOL_2D, [x.index],
            dict(filter_h=k, filter_w=k, stride_h=stride, stride_w=stride,
                 padding=padding), out_quant=out_quant)

    def avg_pool2d(self, x, k=2, stride=None, padding="VALID",
                   out_quant=None):
        stride = stride or k
        return self._infer_and_add(
            OpCode.AVERAGE_POOL_2D, [x.index],
            dict(filter_h=k, filter_w=k, stride_h=stride, stride_w=stride,
                 padding=padding), out_quant=out_quant)

    def reshape(self, x, new_shape, out_quant=None):
        return self._infer_and_add(OpCode.RESHAPE, [x.index],
                                   dict(new_shape=list(new_shape)),
                                   out_quant=out_quant)

    def transpose(self, x, perm):
        return self._infer_and_add(OpCode.TRANSPOSE, [x.index],
                                   dict(perm=list(perm)))

    def concat(self, xs, axis=-1, out_quant=None):
        return self._infer_and_add(OpCode.CONCATENATION,
                                   [x.index for x in xs], dict(axis=axis),
                                   out_quant=out_quant)

    def mean(self, x, axes, keepdims=False, out_quant=None):
        return self._infer_and_add(OpCode.MEAN, [x.index],
                                   dict(axes=list(axes), keepdims=keepdims),
                                   out_quant=out_quant)

    def softmax(self, x, beta=1.0, out_quant=None):
        return self._infer_and_add(OpCode.SOFTMAX, [x.index],
                                   dict(beta=beta), out_quant=out_quant)

    def unary(self, opcode, x, out_quant=None, **params):
        return self._infer_and_add(opcode, [x.index], params,
                                   out_quant=out_quant)

    def relu(self, x, out_quant=None):
        return self.unary(OpCode.RELU, x, out_quant)

    def dropout(self, x, rate=0.5):
        return self._infer_and_add(OpCode.DROPOUT, [x.index],
                                   dict(rate=rate))

    def identity(self, x):
        return self._infer_and_add(OpCode.IDENTITY, [x.index], {})

    def quantize(self, x, scale, zero_point):
        q = QuantParams(scale, zero_point)
        return self._infer_and_add(OpCode.QUANTIZE, [x.index], {},
                                   out_dtype="int8", out_quant=q)

    def dequantize(self, x):
        return self._infer_and_add(OpCode.DEQUANTIZE, [x.index], {},
                                   out_dtype="float32")

    def matmul(self, a, b, transpose_b=False):
        return self._infer_and_add(OpCode.MATMUL, [a.index, b.index],
                                   dict(transpose_b=transpose_b))

    def rms_norm(self, x, gamma, eps=1e-6):
        return self._infer_and_add(OpCode.RMS_NORM, [x.index, gamma.index],
                                   dict(eps=eps))

    def layer_norm(self, x, gamma, beta, eps=1e-5):
        return self._infer_and_add(
            OpCode.LAYER_NORM, [x.index, gamma.index, beta.index],
            dict(eps=eps))

    def gelu(self, x):
        return self.unary(OpCode.GELU, x)

    def silu(self, x):
        return self.unary(OpCode.SILU, x)

    def rope(self, x, base=10000.0):
        return self._infer_and_add(OpCode.ROPE, [x.index], dict(base=base))

    def attention(self, q, k, v, causal=True):
        return self._infer_and_add(
            OpCode.ATTENTION, [q.index, k.index, v.index],
            dict(causal=causal))

    def embedding(self, ids, table):
        return self._infer_and_add(OpCode.EMBEDDING_LOOKUP,
                                   [ids.index, table.index], {})

    # ------------------------------------------------------------------
    def build(self, offline_plan: bool = False) -> bytes:
        """Serialize to µFB.  With ``offline_plan=True``, a host-side
        memory plan is embedded as metadata (§4.4.2 offline-planned
        allocation)."""
        metadata = dict(self.metadata)
        if offline_plan:
            from .memory_planner import OfflineMemoryPlanner
            from .schema import dtype_itemsize

            nbytes = {}
            for i, t in enumerate(self.tensors):
                if not t.is_const and not t.is_variable:
                    n = 1
                    for d in t.shape:
                        n *= d
                    nbytes[i] = n * dtype_itemsize(t.dtype)
            # scratch must match what prepare() will request at init: we
            # conservatively replan without scratch (scratch is op-local
            # and planned online even under an offline tensor plan in TFLM)
            requests, _ = lifetimes_from_graph(
                len(self.ops), [op.inputs for op in self.ops],
                [op.outputs for op in self.ops], nbytes,
                self.inputs, self.outputs, None)
            plan = GreedyMemoryPlanner().plan(requests)
            metadata[OfflineMemoryPlanner.METADATA_KEY] = plan.to_metadata()
        return serialize_model(self.tensors, self.ops, self.inputs,
                               self.outputs, self.const_data, metadata)

    def build_model(self, **kw) -> MicroModel:
        return MicroModel(self.build(**kw))


# ---------------------------------------------------------------------------
# shape-inference plumbing reusing the reference kernels' prepare()
# ---------------------------------------------------------------------------

@dataclass
class _FakeOp:
    opcode: int
    inputs: Tuple[int, ...]
    outputs: Tuple[int, ...]
    params: Dict[str, Any]


class _BuilderPrepareCtx:
    def __init__(self, gb: GraphBuilder):
        self._gb = gb

    def tensor_spec(self, idx: int):
        from .op_resolver import TensorSpec
        t = self._gb.tensors[idx]
        return TensorSpec(t.shape, t.dtype)

    def quant(self, idx: int) -> QuantParams:
        if idx == -2:
            return QuantParams(1.0, 0)       # placeholder for outputs
        return self._gb.tensors[idx].quant

    def const_value(self, idx: int):
        return self._gb.const_data.get(idx)

    def is_const(self, idx: int) -> bool:
        return idx in self._gb.const_data


_CACHED_RESOLVER = None


def _shape_inference_resolver():
    global _CACHED_RESOLVER
    if _CACHED_RESOLVER is None:
        from . import micro_ops  # noqa: F401  (registers reference ops)
        from .op_resolver import AllOpsResolver
        _CACHED_RESOLVER = AllOpsResolver()
    return _CACHED_RESOLVER
