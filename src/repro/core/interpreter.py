"""MicroInterpreter (paper §4.1–4.2) — thin facade over the executor.

Life cycle, exactly as the paper describes:

  1. the application builds an OpResolver (which ops "link in"),
  2. supplies a contiguous memory arena,
  3. constructs the interpreter — ALL allocation happens now: the
     executor's AllocationPlan walks the op list once, each op's
     prepare() communicates its memory needs, the memory planner
     bin-packs the nonpersistent section, and the two-stack arena is
     frozen,
  4. the application writes inputs and calls invoke() — a blocking call
     into the executor's CompiledPlan: no allocation, no graph
     processing, just one jitted dispatch,
  5. outputs are read back from the arena.

The plan/trace/dispatch machinery itself lives in ``core/executor.py``
(AllocationPlan → CompiledPlan → dispatch) so the same compiled layer
also powers batched invoke (``InterpreterPool``) and the pod-scale
serving path.  This class only adds the paper's application API and the
multitenant arena-sharing construction (§4.5).

Constant tensors (weights) are NOT in the arena: they are zero-copy views
into the model blob, the analogue of TFLM reading weights from flash.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize as Q
from .arena import TwoStackArena
from .executor import (NODE_RUNTIME_NBYTES, TENSOR_RUNTIME_NBYTES,
                       AllocationPlan, ArenaPool, CompiledPlan, EvalContext,
                       InterpreterPool, OpPlan, PrepareContext,
                       SharedArenaState, _jnp_dtype, required_arena_size)
from .memory_planner import MemoryPlan
from .op_resolver import MicroMutableOpResolver, TensorSpec
from .schema import MicroModel


class MicroInterpreter:
    """Interpreter bound one-to-one to a model + arena (Figure 5)."""

    def __init__(
        self,
        model: MicroModel,
        op_resolver: MicroMutableOpResolver,
        arena_size_bytes: int,
        planner: Optional[object] = None,
        prefer_offline_plan: bool = True,
        shared: Optional[ArenaPool] = None,
        parent: Optional["MicroInterpreter"] = None,
    ):
        self.model = model
        self.resolver = op_resolver
        if parent is not None:
            # multitenant: stack persistents under the parent's (§4.5)
            self.arena = parent.arena.fork_tenant()
            self._shared = parent._shared
        else:
            self.arena = TwoStackArena(arena_size_bytes)
            self._shared = shared or ArenaPool()
        self._inputs: Dict[int, np.ndarray] = {}
        self._invoke_count = 0

        # phases 1+2: plan, then compile (all cost paid here, at init)
        self.alloc = AllocationPlan.build(
            model, op_resolver, self.arena, planner, prefer_offline_plan)
        self.compiled = CompiledPlan(self.alloc)
        self._variables: List[jnp.ndarray] = list(self.alloc.init_variables)
        self._shared.ensure(self.alloc.nonpersistent_nbytes)
        if parent is not None:
            parent.arena.absorb_tenant(self.arena)

    # ------------------------------------------------------------------
    # executor-layer views (kept for reporting and the benchmarks)
    # ------------------------------------------------------------------

    @property
    def planner_name(self) -> str:
        return self.alloc.planner_name

    @property
    def _specs(self) -> List[TensorSpec]:
        return self.alloc.specs

    @property
    def _op_plans(self) -> List[OpPlan]:
        return self.alloc.op_plans

    @property
    def _consts(self) -> List[jnp.ndarray]:
        return self.alloc.consts

    @property
    def _const_pos(self) -> Dict[int, int]:
        return self.alloc.const_pos

    @property
    def _var_pos(self) -> Dict[int, int]:
        return self.alloc.var_pos

    @property
    def _tensor_offset(self) -> Dict[int, int]:
        return self.alloc.tensor_offset

    @property
    def _plan(self) -> MemoryPlan:
        return self.alloc.plan

    @property
    def _jitted(self):
        """The one compiled invoke program (dispatch = a single call)."""
        return self.compiled.jitted

    # ------------------------------------------------------------------
    # application API (paper §4.1 steps 4–5)
    # ------------------------------------------------------------------

    def set_input(self, pos: int, value: np.ndarray) -> None:
        tid = self.model.inputs[pos]
        spec = self.alloc.specs[tid]
        value = np.asarray(value)
        if tuple(value.shape) != tuple(spec.shape):
            raise ValueError(f"input {pos}: shape {value.shape} != "
                             f"{spec.shape}")
        self._inputs[pos] = value.astype(_jnp_dtype(spec.dtype))

    def input_spec(self, pos: int) -> TensorSpec:
        return self.alloc.specs[self.model.inputs[pos]]

    def output_spec(self, pos: int) -> TensorSpec:
        return self.alloc.specs[self.model.outputs[pos]]

    def invoke(self) -> None:
        if len(self._inputs) != len(self.model.inputs):
            raise RuntimeError("not all inputs set")
        ins = tuple(jnp.asarray(self._inputs[p])
                    for p in range(len(self.model.inputs)))
        buf = self._shared.take()
        with Q.x64_scope():
            buf, variables, outs = self.compiled.jitted(
                buf, tuple(self._variables), tuple(self.alloc.consts), ins)
        buf.block_until_ready()
        # outputs are read inside the traced program — the arena stays
        # on device and is donated into the next invoke.  (Copying the
        # whole arena to host per call was measurable interpreter
        # overhead, the very thing §5.2 says must stay negligible.)
        self._outs = outs
        self._variables = list(variables)
        self._shared.put(buf)
        self._invoke_count += 1

    def output(self, pos: int) -> np.ndarray:
        return np.asarray(self._outs[pos])

    def reset_variable_tensors(self) -> None:
        self._variables = [jnp.zeros_like(v) for v in self._variables]

    # ------------------------------------------------------------------
    # reporting (Table 2 / §5.3)
    # ------------------------------------------------------------------

    def arena_used_bytes(self) -> Dict[str, int]:
        u = self.arena.usage()
        return {
            "persistent": u.persistent,
            "nonpersistent": u.nonpersistent,
            "temp_high_water": u.temp_high_water,
            "total": u.total,
            "capacity": u.capacity,
        }

    def memory_report(self) -> str:
        u = self.arena_used_bytes()
        lines = [
            f"arena capacity:      {u['capacity']:>10,} B",
            f"persistent (tail):   {u['persistent']:>10,} B",
            f"nonpersistent (head):{u['nonpersistent']:>10,} B",
            f"total used:          {u['total']:>10,} B",
            f"planner:             {self.planner_name} "
            f"({len(self.alloc.plan.requests)} buffers -> "
            f"{self.alloc.plan.total_bytes:,} B)",
            f"model blob (flash):  {self.model.nbytes():>10,} B",
            f"linked op code:      {self.resolver.code_nbytes():>10,} B",
        ]
        return "\n".join(lines)

    def memory_plan(self) -> MemoryPlan:
        return self.alloc.plan

    @staticmethod
    def required_arena_size(model: MicroModel,
                            op_resolver: MicroMutableOpResolver,
                            slack: int = 1024) -> int:
        return required_arena_size(model, op_resolver, slack)
