"""MicroInterpreter (paper §4.1–4.2).

Life cycle, exactly as the paper describes:

  1. the application builds an OpResolver (which ops "link in"),
  2. supplies a contiguous memory arena,
  3. constructs the interpreter — ALL allocation happens now: the op list
     is walked once, each op's prepare() communicates its memory needs,
     the memory planner bin-packs the nonpersistent section, and the
     two-stack arena is frozen,
  4. the application writes inputs and calls invoke() — a blocking call
     that loops over the topologically sorted op list; no allocation, no
     graph processing, just dispatch into kernel eval functions,
  5. outputs are read back from the arena.

JAX adaptation: the nonpersistent arena section is a real flat ``uint8``
device buffer.  Tensors are static-offset byte ranges; every eval's
outputs are bitcast and written back at their planned offsets.  The whole
invoke loop is traced ONCE into a single jitted program whose buffer is
donated — so steady-state invoke does no Python dispatch and allocates
nothing beyond the arena it was given (the malloc-free discipline).
Interpreter "overhead" is the trace+dispatch cost paid at init, matching
the paper's claim that run-time overhead stays out of the math.

Constant tensors (weights) are NOT in the arena: they are zero-copy views
into the model blob, the analogue of TFLM reading weights from flash.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize as Q
from .arena import ArenaOverflowError, TwoStackArena, align_up
from .memory_planner import (BufferRequest, GreedyMemoryPlanner,
                             MemoryPlan, OfflineMemoryPlanner,
                             lifetimes_from_graph)
from .op_resolver import (MicroMutableOpResolver, PrepareResult, TensorSpec)
from .schema import MicroModel, OpCode, QuantParams, TensorFlags

# TFLM persistent-arena runtime records (TfLiteTensor ≈ 64 B, node ≈ 48 B);
# we account the same way so Table-2 numbers are comparable.
TENSOR_RUNTIME_NBYTES = 64
NODE_RUNTIME_NBYTES = 48


def _itemsize(dtype: str) -> int:
    return 2 if dtype == "bfloat16" else np.dtype(dtype).itemsize


def _spec_nbytes(spec: TensorSpec) -> int:
    n = 1
    for d in spec.shape:
        n *= int(d)
    return n * _itemsize(spec.dtype)


def _jnp_dtype(name: str):
    return jnp.bfloat16 if name == "bfloat16" else jnp.dtype(name)


# ---------------------------------------------------------------------------
# contexts handed to kernel prepare()/eval() (the TFLM C-API analogue)
# ---------------------------------------------------------------------------

class PrepareContext:
    def __init__(self, interp: "MicroInterpreter"):
        self._it = interp

    def tensor_spec(self, idx: int) -> TensorSpec:
        return self._it._specs[idx]

    def quant(self, idx: int) -> QuantParams:
        return self._it.model.tensor(idx).quant

    def const_value(self, idx: int) -> Optional[np.ndarray]:
        t = self._it.model.tensor(idx)
        return self._it.model.const_data(idx) if t.is_const else None

    def is_const(self, idx: int) -> bool:
        return self._it.model.tensor(idx).is_const


class EvalContext:
    __slots__ = ("op_data", "_out_specs", "_out_quants")

    def __init__(self, op_data, out_specs, out_quants):
        self.op_data = op_data
        self._out_specs = out_specs
        self._out_quants = out_quants

    def output_shape(self, k: int) -> Tuple[int, ...]:
        return self._out_specs[k].shape

    def quant_of_output(self, k: int) -> QuantParams:
        return self._out_quants[k]


# ---------------------------------------------------------------------------
# shared arena buffer for multitenancy (§4.5)
# ---------------------------------------------------------------------------

class SharedArenaState:
    """Holds the one physical nonpersistent buffer multiple interpreters
    reuse between (non-concurrent) invocations."""

    def __init__(self) -> None:
        self.nbytes = 0
        self.buf: Optional[jnp.ndarray] = None

    def ensure(self, nbytes: int) -> None:
        if nbytes > self.nbytes:
            self.nbytes = int(nbytes)
            self.buf = jnp.zeros((self.nbytes,), jnp.uint8)

    def take(self) -> jnp.ndarray:
        assert self.buf is not None
        b, self.buf = self.buf, None
        return b

    def put(self, buf: jnp.ndarray) -> None:
        self.buf = buf


# ---------------------------------------------------------------------------

@dataclass
class _OpPlan:
    op: Any                               # schema.OpDef
    registration: Any                     # OpRegistration
    prep: PrepareResult
    eval_ctx: EvalContext


class MicroInterpreter:
    """Interpreter bound one-to-one to a model + arena (Figure 5)."""

    def __init__(
        self,
        model: MicroModel,
        op_resolver: MicroMutableOpResolver,
        arena_size_bytes: int,
        planner: Optional[object] = None,
        prefer_offline_plan: bool = True,
        shared: Optional[SharedArenaState] = None,
        parent: Optional["MicroInterpreter"] = None,
    ):
        self.model = model
        self.resolver = op_resolver
        if parent is not None:
            # multitenant: stack persistents under the parent's (§4.5)
            self.arena = parent.arena.fork_tenant()
            self._shared = parent._shared
        else:
            self.arena = TwoStackArena(arena_size_bytes)
            self._shared = shared or SharedArenaState()
        self._specs: List[TensorSpec] = []
        self._const_pos: Dict[int, int] = {}
        self._var_pos: Dict[int, int] = {}
        self._tensor_offset: Dict[int, int] = {}
        self._plan: Optional[MemoryPlan] = None
        self._op_plans: List[_OpPlan] = []
        self._inputs: Dict[int, np.ndarray] = {}
        self._invoke_count = 0
        self._allocate_and_prepare(planner, prefer_offline_plan)
        if parent is not None:
            parent.arena.absorb_tenant(self.arena)

    # ------------------------------------------------------------------
    # init phase (TFLM AllocateTensors)
    # ------------------------------------------------------------------

    def _allocate_and_prepare(self, planner, prefer_offline_plan) -> None:
        m = self.model
        # 0. initial specs from the serialized model
        for t in m.tensors:
            self._specs.append(TensorSpec(t.shape, t.dtype))

        # 1. persistent runtime records (tensor structs + node structs)
        self.arena.allocate_persistent(
            TENSOR_RUNTIME_NBYTES * len(m.tensors), "tensor_structs")
        self.arena.allocate_persistent(
            NODE_RUNTIME_NBYTES * len(m.operators), "node_structs")

        # 2. const tensors -> zero-copy views ("flash"); variables -> tail
        self._consts: List[jnp.ndarray] = []
        self._variables: List[jnp.ndarray] = []
        self._var_specs: List[TensorSpec] = []
        for i, t in enumerate(m.tensors):
            if t.is_const:
                self._const_pos[i] = len(self._consts)
                self._consts.append(jnp.asarray(m.const_data(i)))
            elif t.is_variable:
                self._var_pos[i] = len(self._variables)
                self.arena.allocate_persistent(t.nbytes, f"variable{i}")
                self._variables.append(
                    jnp.zeros(t.shape, _jnp_dtype(t.dtype)))
                self._var_specs.append(TensorSpec(t.shape, t.dtype))

        # 3. prepare each op in topological order
        pctx = PrepareContext(self)
        scratch: Dict[int, List[int]] = {}
        for oi, op in enumerate(m.operators):
            reg = self.resolver.resolve(op.opcode)
            # planning-time temp (paper: the between-stack temp region)
            self.arena.allocate_temp(256)
            prep = reg.prepare(pctx, op)
            self.arena.reset_temp()
            if prep.persistent_nbytes:
                self.arena.allocate_persistent(
                    prep.persistent_nbytes, f"opdata{oi}")
            assert len(prep.output_specs) == len(op.outputs), \
                f"{reg.name}: prepare produced {len(prep.output_specs)} " \
                f"specs for {len(op.outputs)} outputs"
            for t, spec in zip(op.outputs, prep.output_specs):
                declared = self._specs[t]
                if tuple(declared.shape) != tuple(spec.shape):
                    raise ValueError(
                        f"op {oi} ({reg.name}): computed output shape "
                        f"{spec.shape} != serialized {declared.shape}")
                self._specs[t] = spec
            if prep.scratch_nbytes:
                scratch[oi] = list(prep.scratch_nbytes)
            out_quants = [m.tensor(t).quant for t in op.outputs]
            ectx = EvalContext(prep.op_data,
                               [self._specs[t] for t in op.outputs],
                               out_quants)
            self._op_plans.append(_OpPlan(op, reg, prep, ectx))

        # 4. lifetimes + memory plan for the nonpersistent section
        planned_nbytes = {
            i: _spec_nbytes(self._specs[i])
            for i, t in enumerate(m.tensors)
            if not t.is_const and not t.is_variable}
        tensor_requests, tensor_ids = lifetimes_from_graph(
            len(m.operators),
            [op.inputs for op in m.operators],
            [op.outputs for op in m.operators],
            planned_nbytes, m.inputs, m.outputs, None)
        scratch_requests, _ = lifetimes_from_graph(
            len(m.operators), [()] * len(m.operators),
            [()] * len(m.operators), {}, (), (), scratch)
        if planner is None:
            offline = m.metadata.get(OfflineMemoryPlanner.METADATA_KEY)
            if prefer_offline_plan and offline is not None:
                planner = OfflineMemoryPlanner(offline)
            else:
                planner = GreedyMemoryPlanner()
        self.planner_name = getattr(planner, "name", type(planner).__name__)
        self._plan = planner.plan(tensor_requests)
        for req_idx, tid in enumerate(tensor_ids):
            if tid >= 0:
                self._tensor_offset[tid] = self._plan.offsets[req_idx]
        # op-local scratch is always planned online, even under an offline
        # tensor plan (TFLM: scratch comes from RequestScratchBufferInArena
        # at prepare time); it packs into its own region above the tensors.
        scratch_plan = GreedyMemoryPlanner().plan(scratch_requests) \
            if scratch_requests else None
        self._scratch_bytes = scratch_plan.total_bytes if scratch_plan else 0

        # 5. reserve the planned section on the head stack and freeze
        self.arena.reserve_nonpersistent_section(
            self._plan.total_bytes + self._scratch_bytes)
        self.arena.freeze()

        # 6. physical buffer (shared across tenants)
        self._shared.ensure(self._plan.total_bytes)

        # 7. trace + compile invoke
        self._jitted = jax.jit(self._execute, donate_argnums=(0, 1))

    # ------------------------------------------------------------------
    # arena byte-view helpers (static offsets; traced inside invoke)
    # ------------------------------------------------------------------

    def _read(self, buf: jnp.ndarray, tid: int):
        spec = self._specs[tid]
        off = self._tensor_offset[tid]
        nbytes = _spec_nbytes(spec)
        raw = jax.lax.slice(buf, (off,), (off + nbytes,))
        dt = _jnp_dtype(spec.dtype)
        item = _itemsize(spec.dtype)
        if item == 1:
            return jax.lax.bitcast_convert_type(raw, dt).reshape(spec.shape)
        arr = jax.lax.bitcast_convert_type(
            raw.reshape(nbytes // item, item), dt)
        return arr.reshape(spec.shape)

    def _write(self, buf: jnp.ndarray, tid: int, value) -> jnp.ndarray:
        spec = self._specs[tid]
        off = self._tensor_offset[tid]
        dt = _jnp_dtype(spec.dtype)
        value = value.astype(dt).reshape(-1)
        item = _itemsize(spec.dtype)
        if item == 1:
            raw = jax.lax.bitcast_convert_type(value, jnp.uint8)
        else:
            raw = jax.lax.bitcast_convert_type(value, jnp.uint8).reshape(-1)
        return jax.lax.dynamic_update_slice(buf, raw, (off,))

    # ------------------------------------------------------------------
    # the traced invoke body
    # ------------------------------------------------------------------

    def _execute(self, buf, variables, consts, inputs):
        # write model inputs into their planned arena slots
        for pos, tid in enumerate(self.model.inputs):
            buf = self._write(buf, tid, inputs[pos])
        variables = list(variables)
        for opp in self._op_plans:
            op = opp.op
            in_arrays = []
            for t in op.inputs:
                if t < 0:
                    in_arrays.append(None)
                elif t in self._const_pos:
                    in_arrays.append(consts[self._const_pos[t]])
                elif t in self._var_pos:
                    in_arrays.append(variables[self._var_pos[t]])
                else:
                    in_arrays.append(self._read(buf, t))
            outs = opp.registration.eval(opp.eval_ctx, op, in_arrays)
            n_out = len(op.outputs)
            for t, o in zip(op.outputs, outs[:n_out]):
                buf = self._write(buf, t, o)
            for t, v in zip(opp.prep.variable_updates, outs[n_out:]):
                variables[self._var_pos[t]] = v
        # read the model outputs inside the traced program: the host
        # then receives small per-output arrays instead of slicing (or
        # copying) the whole arena per invoke
        model_outs = tuple(self._read(buf, t) for t in self.model.outputs)
        return buf, tuple(variables), model_outs

    # ------------------------------------------------------------------
    # application API (paper §4.1 steps 4–5)
    # ------------------------------------------------------------------

    def set_input(self, pos: int, value: np.ndarray) -> None:
        tid = self.model.inputs[pos]
        spec = self._specs[tid]
        value = np.asarray(value)
        if tuple(value.shape) != tuple(spec.shape):
            raise ValueError(f"input {pos}: shape {value.shape} != "
                             f"{spec.shape}")
        self._inputs[pos] = value.astype(_jnp_dtype(spec.dtype))

    def input_spec(self, pos: int) -> TensorSpec:
        return self._specs[self.model.inputs[pos]]

    def output_spec(self, pos: int) -> TensorSpec:
        return self._specs[self.model.outputs[pos]]

    def invoke(self) -> None:
        if len(self._inputs) != len(self.model.inputs):
            raise RuntimeError("not all inputs set")
        ins = tuple(jnp.asarray(self._inputs[p])
                    for p in range(len(self.model.inputs)))
        buf = self._shared.take()
        with Q.x64_scope():
            buf, variables, outs = self._jitted(
                buf, tuple(self._variables), tuple(self._consts), ins)
        buf.block_until_ready()
        # outputs are read inside the traced program — the arena stays
        # on device and is donated into the next invoke.  (Copying the
        # whole arena to host per call was measurable interpreter
        # overhead, the very thing §5.2 says must stay negligible.)
        self._outs = outs
        self._variables = list(variables)
        self._shared.put(buf)
        self._invoke_count += 1

    def output(self, pos: int) -> np.ndarray:
        return np.asarray(self._outs[pos])

    def reset_variable_tensors(self) -> None:
        self._variables = [jnp.zeros_like(v) for v in self._variables]

    # ------------------------------------------------------------------
    # reporting (Table 2 / §5.3)
    # ------------------------------------------------------------------

    def arena_used_bytes(self) -> Dict[str, int]:
        u = self.arena.usage()
        return {
            "persistent": u.persistent,
            "nonpersistent": u.nonpersistent,
            "temp_high_water": u.temp_high_water,
            "total": u.total,
            "capacity": u.capacity,
        }

    def memory_report(self) -> str:
        u = self.arena_used_bytes()
        lines = [
            f"arena capacity:      {u['capacity']:>10,} B",
            f"persistent (tail):   {u['persistent']:>10,} B",
            f"nonpersistent (head):{u['nonpersistent']:>10,} B",
            f"total used:          {u['total']:>10,} B",
            f"planner:             {self.planner_name} "
            f"({len(self._plan.requests)} buffers -> "
            f"{self._plan.total_bytes:,} B)",
            f"model blob (flash):  {self.model.nbytes():>10,} B",
            f"linked op code:      {self.resolver.code_nbytes():>10,} B",
        ]
        return "\n".join(lines)

    def memory_plan(self) -> MemoryPlan:
        assert self._plan is not None
        return self._plan

    @staticmethod
    def required_arena_size(model: MicroModel,
                            op_resolver: MicroMutableOpResolver,
                            slack: int = 1024) -> int:
        probe = MicroInterpreter(model, op_resolver, 1 << 30)
        return align_up(probe.arena.usage().total + slack)
