"""Calibration-driven cost model for bucket & chunk sizing.

The paper's discipline is that resource-constrained inference replaces
runtime-dynamic decisions with offline, MEASURED, static configuration:
the memory planner lays the arena out before a single op runs.  This
module applies the same discipline to the two serving knobs that were
still hand-picked constants — the prefill ``BucketTable`` layout and
the ``prefill_chunk`` size:

  1. **calibrate** — a short deterministic calibration pass runs the
     engine's real compiled steps through the profiler's compile/step
     timer (``repro.core.profiler.measure_compile_and_step``),
     measuring, per candidate bucket length, the one-time prefill
     compile cost and the warm padded-step latency, and, per candidate
     chunk size, the warm chunked-prefill step cost;
  2. **solve** — a small dynamic program picks the bucket level set
     (min/max/granularity generalized to explicit levels) and the
     chunk size that minimize the workload's expected prefill latency:
     each level costs its trace overhead once plus a warm padded step
     per request it serves; padding waste pushes the solver toward
     finer tables, compile cost pushes it toward coarser ones.  An
     optional head-of-line bound (``max_dispatch_us``) models what
     chunked prefill is FOR — bounding how long one dispatch may
     monopolize the engine between decode steps — and makes the solver
     trade serial prefill cost for bounded per-dispatch blocking;
  3. **persist** — the result is a versioned ``CalibrationProfile``
     JSON (measurements included, wall-clock excluded) so engines can
     be constructed from a profile without re-measuring
     (``ServingEngine.from_profile``; ``MultiTenantHost(profile=...)``
     shares one profile's table across tenants).  When no profile
     exists, every surface falls back to today's hand-picked defaults.

Determinism contract: given the same seed and the same measurement
function, ``calibrate`` produces an identical profile (asserted in
tests/test_costmodel.py).  The default measurer reads wall clocks, so
two real calibration runs agree in distribution, not bit-for-bit —
inject ``measure=`` (any ``(kind, size) -> CompileStepTiming``
callable) for exact reproducibility or for solver-only experiments.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .executor import BucketTable
from .profiler import CompileStepTiming, measure_compile_and_step

PROFILE_VERSION = 1

# default on-disk location of the calibration-profile cache, keyed by
# model_key: <repo>/benchmarks/results/profiles/<key with / -> __>.json
DEFAULT_PROFILE_DIR = (pathlib.Path(__file__).resolve().parents[3]
                       / "benchmarks" / "results" / "profiles")

# default candidate chunk sizes offered to the solver (0 = chunking off)
DEFAULT_CHUNK_CANDIDATES = (0, 8, 16)
# floor for candidate bucket levels: below this, padding waste is noise
MIN_LEVEL = 4
# cap on measured candidate levels — calibration cost is one compile
# per candidate, so the pass stays seconds-scale
MAX_CANDIDATES = 12


def profile_model_key(cfg: Any, cache_len: int) -> str:
    """The identity a profile is calibrated FOR: model family + arch +
    cache capacity.  ``ServingEngine.from_profile`` refuses a profile
    whose key does not match (the measured costs would be someone
    else's); ``MultiTenantHost`` may still deliberately share one
    profile's bucket LAYOUT across tenants — see docs/SCHEDULING.md."""
    return f"{cfg.family}/{getattr(cfg, 'arch_id', '?')}/L{int(cache_len)}"


# ---------------------------------------------------------------------------
# measurements
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BucketCost:
    """Measured cost of one candidate bucket level: ``compile_us`` the
    cold first prefill at padded length ``length``, ``step_us`` the
    warm padded-step latency (the per-request price every prompt that
    lands in this bucket pays)."""

    length: int
    compile_us: float
    step_us: float

    @property
    def trace_overhead_us(self) -> float:
        """One-time cost the table pays when this level is first hit."""
        return max(self.compile_us - self.step_us, 0.0)


@dataclasses.dataclass(frozen=True)
class ChunkCost:
    """Measured cost of one candidate chunk size: ``step_us`` is one
    warm chunked-prefill dispatch (a prompt of m tokens pays
    ceil(m/chunk) of these), ``compile_us`` the cold first chunk —
    paid ONCE total because the start offset is a traced scalar."""

    chunk: int
    compile_us: float
    step_us: float

    @property
    def trace_overhead_us(self) -> float:
        """The chunk program's one-time trace cost."""
        return max(self.compile_us - self.step_us, 0.0)


@dataclasses.dataclass(frozen=True)
class DecodeCost:
    """Measured cost of the fused decode step at ``slots`` concurrent
    slots: ``step_us`` one warm batched dispatch (every active request
    advances one token for this price), ``compile_us`` the cold first
    dispatch — paid once per engine, since slot occupancy is a traced
    value."""

    slots: int
    compile_us: float
    step_us: float

    @property
    def trace_overhead_us(self) -> float:
        """The decode program's one-time trace cost."""
        return max(self.compile_us - self.step_us, 0.0)


@dataclasses.dataclass(frozen=True)
class BlockCost:
    """Measured cost of one candidate PAGED KV block size: ``step_us``
    one warm paged decode dispatch with ``block``-row blocks (the
    Pallas kernel's tile IS the block, so this is where a too-small
    block shows up as per-tile overhead), ``compile_us`` the cold
    first dispatch."""

    block: int
    compile_us: float
    step_us: float

    @property
    def trace_overhead_us(self) -> float:
        """The paged decode program's one-time trace cost."""
        return max(self.compile_us - self.step_us, 0.0)


@dataclasses.dataclass(frozen=True)
class LaneCost:
    """Measured cost of one BATCHED micro dispatch at ``lanes``
    concurrent lanes (``InterpreterPool.invoke`` advances every lane
    for one jitted dispatch): ``step_us`` the warm dispatch,
    ``compile_us`` the cold first one — paid once per lane count,
    since the batch axis is a shape."""

    lanes: int
    compile_us: float
    step_us: float

    @property
    def trace_overhead_us(self) -> float:
        """The pooled dispatch program's one-time trace cost."""
        return max(self.compile_us - self.step_us, 0.0)


@dataclasses.dataclass(frozen=True)
class ReplicaCost:
    """Modeled serving capacity of ``replicas`` engine replicas,
    priced from ONE measured fused decode dispatch: each replica
    advances ``slots`` tokens per ``step_us`` warm dispatch, and
    replicas run on DISJOINT device sets (data-parallel axis,
    serving/router.py), so capacity adds linearly while the per-tick
    latency floor stays a single dispatch."""

    replicas: int
    slots: int
    step_us: float

    @property
    def tokens_per_us(self) -> float:
        """Aggregate decode throughput of the replica set."""
        return self.replicas * self.slots / self.step_us


@dataclasses.dataclass(frozen=True)
class QuantCost:
    """Measured cost of the QUANTIZED fused decode step at ``slots``
    concurrent slots under one precision pair (``weight_dtype`` /
    ``kv_dtype``; ``"fp32"`` = that axis unquantized — the baseline
    row): ``step_us`` one warm dispatch, ``compile_us`` the cold
    first, and ``hbm_bytes`` the engine's RESIDENT footprint (the
    quantized weight tree plus the KV arena — the axis quantization
    exists to shrink; 0 when the measurement hook could not report
    it, e.g. an injected synthetic ``measure``)."""

    weight_dtype: str
    kv_dtype: str
    slots: int
    compile_us: float
    step_us: float
    hbm_bytes: int = 0

    @property
    def trace_overhead_us(self) -> float:
        """The quantized decode program's one-time trace cost."""
        return max(self.compile_us - self.step_us, 0.0)


class EngineMeasurer:
    """The default ``measure`` hook: times the REAL compiled serving
    steps of a fresh engine — ``("prefill", L)`` runs the one-shot
    prefill at padded length L cold then warm, ``("chunk", C)`` runs
    one chunked-prefill dispatch of C tokens, ``("decode", B)`` one
    fused decode dispatch at B slots, ``("decode_paged", BS)`` one
    paged decode dispatch at block size BS.  Token values come from a
    seeded rng (they cannot affect timing, only determinism of the
    recorded workload), and every call synchronizes on the result so
    async dispatch cannot leak device time out of the measurement."""

    def __init__(self, bundle: Any, params: Any, cache_len: int,
                 *, seed: int = 0, iters: int = 5):
        self.bundle = bundle
        self.params = params
        self.cache_len = int(cache_len)
        self.iters = int(iters)
        self.rng = np.random.default_rng(seed)
        self._engines: Dict[int, Any] = {}
        self._aux_engines: Dict[Tuple[str, int], Any] = {}

    def _engine(self, chunk: int):
        # lazy import: serving sits above core in the layering
        from repro.serving.engine import ServingEngine
        eng = self._engines.get(chunk)
        if eng is None:
            eng = ServingEngine(
                self.bundle, self.params, max_slots=1,
                cache_len=self.cache_len, prefill_buckets=False,
                prefill_chunk=chunk or None)
            self._engines[chunk] = eng
        return eng

    def _batch(self, toks) -> Dict[str, Any]:
        """The prefill batch for one measured prompt — a vlm bundle
        additionally needs its vision prefix (synthesized patch
        embeddings; only the shape matters for timing)."""
        import jax.numpy as jnp
        cfg = self.bundle.cfg
        batch: Dict[str, Any] = {"tokens": toks}
        if cfg.family == "vlm":
            batch["vision"] = jnp.asarray(self.rng.normal(
                0, 1, (1, cfg.n_vision_tokens, cfg.d_vision)
            ).astype(np.float32))
        return batch

    def __call__(self, kind: str, size: int) -> CompileStepTiming:
        import jax.numpy as jnp
        vocab = self.bundle.cfg.vocab
        toks = jnp.asarray(self.rng.integers(
            0, max(vocab - 2, 1), int(size)).astype(np.int32)[None])
        if kind == "prefill":
            eng = self._engine(0)
            batch = self._batch(toks)
            return measure_compile_and_step(
                lambda: eng._prefill((self.params, batch)),
                iters=self.iters)
        if kind == "chunk":
            eng = self._engine(int(size))
            cache1 = self.bundle.empty_cache(
                1, self.cache_len, self.bundle.cfg.jnp_dtype())
            if eng._recurrent_chunk:
                # the recurrent-state chunk op additionally takes the
                # chunk's true token count as a traced scalar
                args = (self.params, cache1, toks, jnp.int32(0),
                        jnp.int32(int(size)))
            else:
                args = (self.params, cache1, toks, jnp.int32(0))
            return measure_compile_and_step(
                lambda: eng._prefill_chunk(args), iters=self.iters)
        if kind == "decode":
            # one fused decode dispatch at `size` concurrent slots —
            # half-full caches so masking work is representative
            eng = self._aux(kind, int(size))
            b = int(size)
            cur = jnp.zeros((b, 1), jnp.int32)
            lens = jnp.full((b,), self.cache_len // 2, jnp.int32)
            return measure_compile_and_step(
                lambda: eng._decode((self.params, eng.cache, cur, lens)),
                iters=self.iters)
        if kind.startswith("decode_q:"):
            # quantized fused decode at `size` slots — the kind string
            # carries the precision pair ("decode_q:<weight>:<kv>",
            # "fp32" = that axis unquantized) so injected hooks keep
            # the flat (kind, size) measurement contract
            eng = self._aux(kind, int(size))
            b = int(size)
            cur = jnp.zeros((b, 1), jnp.int32)
            lens = jnp.full((b,), self.cache_len // 2, jnp.int32)
            return measure_compile_and_step(
                lambda: eng._decode((eng.params, eng.cache, cur, lens)),
                iters=self.iters)
        if kind == "decode_paged":
            # one paged decode dispatch with `size`-row KV blocks; the
            # engine's freshly-zeroed pool and garbage tables are fine
            # here — timing depends on shapes, not on which blocks the
            # tables point at
            eng = self._aux(kind, int(size))
            b = eng.max_slots
            cur = jnp.zeros((b, 1), jnp.int32)
            lens = jnp.full((b,), self.cache_len // 2, jnp.int32)
            return measure_compile_and_step(
                lambda: eng._decode((self.params, eng.kv_pool,
                                     eng.block_tables, cur, lens)),
                iters=self.iters)
        raise ValueError(f"unknown measurement kind {kind!r}")

    def _aux(self, kind: str, size: int):
        """Engines for the decode-side measurement kinds, keyed by
        (kind, size): ``decode`` wants a contiguous engine at `size`
        slots, ``decode_paged`` a 2-slot paged engine at block `size`."""
        from repro.serving.engine import ServingEngine
        eng = self._aux_engines.get((kind, size))
        if eng is None:
            if kind == "decode":
                eng = ServingEngine(
                    self.bundle, self.params, max_slots=size,
                    cache_len=self.cache_len, prefill_buckets=False)
            elif kind.startswith("decode_q:"):
                _, wd, kd = kind.split(":")
                eng = ServingEngine(
                    self.bundle, self.params, max_slots=size,
                    cache_len=self.cache_len, prefill_buckets=False,
                    weight_dtype=None if wd == "fp32" else wd,
                    kv_dtype=None if kd == "fp32" else kd)
            else:
                eng = ServingEngine(
                    self.bundle, self.params, max_slots=2,
                    cache_len=self.cache_len, prefill_buckets=False,
                    kv_block=size)
            self._aux_engines[(kind, size)] = eng
        return eng

    def hbm_bytes(self, kind: str, size: int) -> int:
        """Resident weight + KV bytes of the engine behind a
        decode-side measurement — the footprint axis of ``QuantCost``
        (built on demand if that measurement has not run yet)."""
        eng = self._aux(kind, int(size))
        return int(eng.param_bytes + eng.kv_bytes)


class MicroMeasurer:
    """The ``measure`` hook for the multi-lane micro path: ``("micro",
    B)`` times one REAL pooled dispatch (``InterpreterPool.invoke``)
    at B lanes, cold then warm — the cost landscape ``solve_lanes``
    picks the host's micro batch width from.  Lane inputs are seeded
    random frames (values cannot affect timing, only determinism of
    the recorded workload); ``invoke`` blocks on its arena buffer, so
    async dispatch cannot leak device time out of the measurement."""

    def __init__(self, model: Any, resolver: Any, *, seed: int = 0,
                 iters: int = 5):
        self.model = model
        self.resolver = resolver
        self.iters = int(iters)
        self.rng = np.random.default_rng(seed)

    def __call__(self, kind: str, size: int) -> CompileStepTiming:
        if kind != "micro":
            raise ValueError(
                f"MicroMeasurer prices batched micro dispatches only, "
                f"not {kind!r}")
        from .executor import InterpreterPool
        pool = InterpreterPool(self.model, self.resolver,
                               batch=int(size))
        for lane in range(pool.batch):
            for pos, tid in enumerate(pool.alloc.model.inputs):
                spec = pool.alloc.specs[tid]
                pool.set_input(lane, pos, self.rng.normal(
                    0, 1, spec.shape).astype(np.float32))
        return measure_compile_and_step(pool.invoke, iters=self.iters)


# ---------------------------------------------------------------------------
# the solver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SolveResult:
    """What the solver decided and why: the chosen bucket ``levels``
    and ``chunk`` size, the objective at the optimum
    (``expected_us``: total expected prefill latency over the
    workload, trace overheads included), the worst single dispatch the
    config can issue (``max_dispatch_us`` — the head-of-line number a
    bound constrains), how many prefill programs the workload will
    trace (``predicted_compiles``), and whether the head-of-line bound
    was met (``feasible``; without a bound, always True)."""

    levels: List[int]
    chunk: int
    expected_us: float
    max_dispatch_us: float
    predicted_compiles: int             # _prefill traces: the number
    feasible: bool                      # jit_cache_size(_prefill) ends
                                        # at (chunk program excluded —
                                        # that is chunk_compiles())


def _bucket_dp(plens: np.ndarray, cands: List[BucketCost],
               bound: Optional[float]) -> Optional[Tuple[
                   List[int], float, float, List[int]]]:
    """Pick the min-cost subset of candidate levels covering every
    prefill length in ``plens``: each chosen level pays its trace
    overhead once (if hit) plus a warm step per request it serves.
    Levels whose step exceeds ``bound`` are excluded.  Returns (levels,
    cost, max_step_us, hit_levels) — ``hit_levels`` are the levels at
    least one request actually pads into, i.e. the prefill programs
    the workload will trace — or None when ``plens`` cannot be covered
    (every allowed candidate is smaller than some length)."""
    if len(plens) == 0:
        return [], 0.0, 0.0, []
    cands = [c for c in cands
             if bound is None or c.step_us <= bound]
    cands = sorted(cands, key=lambda c: c.length)
    if not cands or cands[-1].length < int(plens.max()):
        return None
    xs = np.sort(plens)
    bounds = [0] + [int(np.searchsorted(xs, c.length, side="right"))
                    for c in cands]
    k = len(cands)
    INF = float("inf")
    best = [INF] * (k + 1)
    best[0] = 0.0
    back = [0] * (k + 1)
    for j in range(1, k + 1):
        for i in range(j):
            cnt = bounds[j] - bounds[i]
            seg = 0.0 if cnt == 0 else (
                cands[j - 1].trace_overhead_us
                + cnt * cands[j - 1].step_us)
            if best[i] + seg < best[j]:
                best[j] = best[i] + seg
                back[j] = i
    # the answer must cover max(plens): last chosen level is any c_j
    # >= max; walking back from the cheapest such j yields the table
    need = int(plens.max())
    j_opt = min((j for j in range(1, k + 1)
                 if cands[j - 1].length >= need),
                key=lambda j: best[j])
    levels, hit_costs = [], []
    j = j_opt
    while j > 0:
        i = back[j]
        if bounds[j] - bounds[i] > 0 or j == j_opt:
            levels.append(cands[j - 1].length)
            if bounds[j] - bounds[i] > 0:
                hit_costs.append(cands[j - 1])
        j = i
    levels.sort()
    max_step = max((c.step_us for c in hit_costs), default=0.0)
    return levels, best[j_opt], max_step, sorted(
        c.length for c in hit_costs)


def solve(prompt_lengths: Sequence[int], bucket_costs: Sequence[BucketCost],
          chunk_costs: Sequence[ChunkCost], *, cache_len: int,
          max_dispatch_us: Optional[float] = None,
          vis_tokens: int = 0) -> SolveResult:
    """Jointly choose the bucket table and chunk size minimizing the
    workload's expected prefill latency.

    For every chunk candidate (0 = chunking off), requests the engine
    WOULD chunk (prefill length > chunk and the chunked prompt —
    including the ``vis_tokens`` a vlm's vision prefix occupies —
    fits the cache, mirroring ``ServingEngine._chunk_eligible``) pay
    one warm PREFILL step at the chunk length (the engine's
    ``_start_chunked`` runs the first chunk through the ordinary
    prefill program) plus ceil(len/chunk)-1 warm chunk steps, with the
    chunk program's trace overhead charged once; the remaining
    requests go through the bucket DP.  The first-chunk prefill trace
    at shape (1, chunk) shares the jit cache with a bucket level of
    the same length, so ``predicted_compiles`` counts it only when no
    unchunked request hits that level (and ``expected_us`` charges its
    trace overhead under the same condition).  Among configurations
    meeting the head-of-line bound (every single dispatch <=
    ``max_dispatch_us``), the cheapest wins; when no configuration
    meets the bound, the one with the smallest worst dispatch wins
    (least-bad, flagged ``feasible=False``)."""
    plens = np.array([max(int(l) - 1, 0) for l in prompt_lengths],
                     dtype=np.int64)
    plens = plens[plens >= 1]      # single-token prompts skip prefill
    chunk_by = {int(c.chunk): c for c in chunk_costs}
    by_len = {c.length: c for c in bucket_costs}
    results: List[SolveResult] = []
    for chunk in sorted(set([0] + list(chunk_by))):
        if chunk == 0:
            chunked = np.zeros(len(plens), bool)
        else:
            n_chunks = -(-plens // chunk)
            chunked = (plens > chunk) \
                & (vis_tokens + n_chunks * chunk <= cache_len)
        cost = 0.0
        max_disp = 0.0
        compiles = 0
        if chunked.any():
            cc = chunk_by[chunk]
            # first chunk: the ordinary prefill program at length
            # `chunk` (measured as a bucket candidate when available)
            first = by_len.get(chunk)
            first_step = first.step_us if first is not None else cc.step_us
            n_first = int(chunked.sum())
            later = float((-(-plens[chunked] // chunk) - 1).sum())
            cost += n_first * first_step + later * cc.step_us
            cost += cc.trace_overhead_us        # the chunk program
            max_disp = max(max_disp, cc.step_us, first_step)
        dp = _bucket_dp(plens[~chunked], list(bucket_costs),
                        max_dispatch_us)
        if dp is None and max_dispatch_us is not None:
            # the bound excludes every covering table: fall back to
            # the unbounded optimum and flag it infeasible below —
            # a too-tight bound is reported, never an exception
            dp = _bucket_dp(plens[~chunked], list(bucket_costs), None)
        if dp is None:
            continue
        levels, dp_cost, dp_max, hit_levels = dp
        if not levels:              # every request chunked: the table
            levels = [min(c.length for c in bucket_costs)]  # still
        cost += dp_cost             # needs one level to exist
        max_disp = max(max_disp, dp_max)
        compiles += len(hit_levels)
        if chunked.any() and chunk not in hit_levels:
            # the (1, chunk) first-chunk prefill trace is NOT deduped
            # against a HIT bucket level: one more prefill program
            compiles += 1
            first = by_len.get(chunk)
            if first is not None:
                cost += first.trace_overhead_us
        feasible = (max_dispatch_us is None
                    or max_disp <= max_dispatch_us)
        results.append(SolveResult(
            levels=levels, chunk=chunk, expected_us=cost,
            max_dispatch_us=max_disp, predicted_compiles=compiles,
            feasible=feasible))
    if not results:
        raise ValueError(
            "no candidate configuration covers the workload — widen "
            "candidate_levels or raise max_dispatch_us")
    feas = [r for r in results if r.feasible]
    if feas:
        return min(feas, key=lambda r: (r.expected_us, len(r.levels),
                                        r.chunk))
    return min(results, key=lambda r: (r.max_dispatch_us, r.expected_us))


@dataclasses.dataclass(frozen=True)
class BlockSolveResult:
    """What the block solver decided and why: the chosen ``block``
    size, the expected ``admissible_slots`` the paged pool can hold at
    the reference HBM budget (vs. ``contiguous_slots``, the same
    budget spent on whole cache_len slabs), the ``mean_blocks`` a
    workload request actually needs, and the measured warm paged
    decode ``step_us`` at that block size (the tie-breaker)."""

    block: int
    admissible_slots: float
    contiguous_slots: int
    mean_blocks: float
    step_us: float


def solve_block_size(prompt_lengths: Sequence[int],
                     block_costs: Sequence[BlockCost], *,
                     cache_len: int, slots: int = 2,
                     new_tokens: int = 16,
                     vis_tokens: int = 0) -> BlockSolveResult:
    """Choose the paged-KV block size for a workload: at a reference
    HBM budget of ``slots`` contiguous cache_len slabs, a smaller
    block admits more concurrent requests (less tail waste, finer
    packing) but pays more per-tile kernel overhead (each block is one
    Pallas tile) — so the solver maximizes expected admissible slots
    and breaks ties on the MEASURED warm paged-decode step cost.

    Per request the engine reserves ceil(min(vis + (len-1) +
    new_tokens, cache_len) / block) blocks (``_blocks_needed``); one
    pool block is the garbage sink and never allocatable.  Candidates
    that do not divide ``cache_len`` are skipped (the engine requires
    an integral table)."""
    plens = np.array([max(int(l) - 1, 0) for l in prompt_lengths],
                     dtype=np.int64)
    plens = plens[plens >= 1]
    if len(plens) == 0:
        raise ValueError("prompt_lengths contains no multi-token "
                         "prompt — nothing to solve block size for")
    budget_rows = int(slots) * int(cache_len)
    best: Optional[BlockSolveResult] = None
    for c in sorted(block_costs, key=lambda c: c.block):
        bs = int(c.block)
        if bs <= 0 or cache_len % bs != 0:
            continue
        usable = budget_rows // bs - 1          # minus the garbage block
        if usable <= 0:
            continue
        need_rows = np.minimum(vis_tokens + plens + new_tokens, cache_len)
        need_blocks = -(-need_rows // bs)
        mean_blocks = float(need_blocks.mean())
        admissible = usable / mean_blocks
        cand = BlockSolveResult(
            block=bs, admissible_slots=round(admissible, 3),
            contiguous_slots=int(slots), mean_blocks=round(mean_blocks, 3),
            step_us=c.step_us)
        if best is None or (cand.admissible_slots, -cand.step_us) > \
                (best.admissible_slots, -best.step_us):
            best = cand
    if best is None:
        raise ValueError(
            f"no block candidate divides cache_len={cache_len} — offer "
            f"divisor block sizes (e.g. powers of two up to cache_len)")
    return best


@dataclasses.dataclass(frozen=True)
class LaneSolveResult:
    """What the lane solver decided and why: the chosen pooled batch
    width ``lanes``, the expected total dispatch time over the demand
    trace (``expected_us``, trace overhead included), the worst single
    dispatch (``max_dispatch_us``), and whether the head-of-line bound
    was met (``feasible``; without a bound, always True)."""

    lanes: int
    expected_us: float
    max_dispatch_us: float
    feasible: bool


def solve_lanes(demand: Sequence[int],
                lane_costs: Sequence[LaneCost], *,
                max_dispatch_us: Optional[float] = None
                ) -> LaneSolveResult:
    """Choose the micro pool's batch width from measured dispatch
    costs: a tick with ``d`` concurrent micro jobs needs ceil(d/B)
    pooled dispatches at width B, so wide lanes amortize fixed
    dispatch overhead while narrow lanes waste less on padding ticks
    (idle lanes still run on zeros — the dispatch is one program).
    Each width's trace overhead is charged once.  Among widths meeting
    the head-of-line bound (one dispatch <= ``max_dispatch_us``), the
    cheapest expected total wins; when none meets it, the least-bad
    worst dispatch wins, flagged ``feasible=False``."""
    ds = np.array([int(d) for d in demand], dtype=np.int64)
    ds = ds[ds >= 1]
    if len(ds) == 0:
        raise ValueError("demand contains no tick with micro jobs — "
                         "nothing to solve lane width for")
    if not lane_costs:
        raise ValueError("solve_lanes needs at least one measured "
                         "LaneCost candidate")
    results = []
    for c in sorted(lane_costs, key=lambda c: c.lanes):
        dispatches = -(-ds // int(c.lanes))
        cost = float(dispatches.sum()) * c.step_us + c.trace_overhead_us
        feasible = (max_dispatch_us is None
                    or c.step_us <= max_dispatch_us)
        results.append(LaneSolveResult(
            lanes=int(c.lanes), expected_us=round(cost, 3),
            max_dispatch_us=round(c.step_us, 3), feasible=feasible))
    feas = [r for r in results if r.feasible]
    if feas:
        return min(feas, key=lambda r: (r.expected_us, r.lanes))
    return min(results, key=lambda r: (r.max_dispatch_us, r.expected_us))


@dataclasses.dataclass(frozen=True)
class ReplicaSolveResult:
    """What the replica solver decided and why: the smallest replica
    count whose modeled aggregate decode throughput
    (``tokens_per_us``) meets ``target_tokens_per_us`` — or the
    largest candidate, flagged ``feasible=False``, when none does."""

    replicas: int
    slots: int
    step_us: float
    tokens_per_us: float
    target_tokens_per_us: float
    feasible: bool


def solve_replicas(target_tokens_per_us: float, decode: DecodeCost, *,
                   candidates: Sequence[int] = (1, 2, 4, 8)
                   ) -> ReplicaSolveResult:
    """Size the data-parallel replica set from one measured decode
    dispatch: each replica sustains ``slots/step_us`` tokens/µs and
    replicas add linearly (disjoint devices), so the smallest
    candidate count meeting the throughput target wins — replicas
    beyond it buy tail latency, not feasibility, and the replica-sweep
    benchmark (benchmarks/arrival_process.py) measures that tail."""
    cands = sorted({int(r) for r in candidates if int(r) >= 1})
    if not cands:
        raise ValueError("candidates must contain a positive count")
    if target_tokens_per_us <= 0:
        raise ValueError("target_tokens_per_us must be positive")
    best = None
    for r in cands:
        rc = ReplicaCost(replicas=r, slots=decode.slots,
                         step_us=decode.step_us)
        if rc.tokens_per_us >= target_tokens_per_us:
            best = (rc, True)
            break
        best = (rc, False)
    rc, feasible = best
    return ReplicaSolveResult(
        replicas=rc.replicas, slots=rc.slots, step_us=rc.step_us,
        tokens_per_us=round(rc.tokens_per_us, 6),
        target_tokens_per_us=float(target_tokens_per_us),
        feasible=feasible)


def solve_precision(candidates: Sequence[QuantCost], *,
                    max_step_us: Optional[float] = None,
                    hbm_budget_bytes: Optional[int] = None
                    ) -> QuantCost:
    """Pick the serving precision from measured quantized decode
    steps: among candidates within the latency bound and the HBM
    budget (each unbounded when None; a candidate with unreported
    ``hbm_bytes == 0`` never satisfies an explicit budget), the
    SMALLEST footprint wins, tie-broken by step time — quantization
    buys occupancy, so footprint is the objective and latency the
    constraint.  When nothing qualifies, the fastest candidate is
    returned (the infeasible-but-least-bad answer, mirroring
    ``solve_replicas``' feasible flag convention)."""
    cands = list(candidates)
    if not cands:
        raise ValueError("candidates must be non-empty")
    ok = [c for c in cands
          if (max_step_us is None or c.step_us <= max_step_us)
          and (hbm_budget_bytes is None
               or (c.hbm_bytes and c.hbm_bytes <= hbm_budget_bytes))]
    if not ok:
        return min(cands, key=lambda c: c.step_us)
    return min(ok, key=lambda c: (c.hbm_bytes or float("inf"),
                                  c.step_us))


# ---------------------------------------------------------------------------
# the profile (versioned JSON; measurements in, wall clock out)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CalibrationProfile:
    """A calibration pass, frozen: the solved configuration
    (``bucket_levels`` + ``prefill_chunk``), the raw measurements it
    was solved FROM, the workload it was solved FOR, and the identity
    of the model it measured (``model_key``).

    The JSON layout (``to_json``) is versioned; ``load`` refuses a
    version it does not understand instead of misreading it.  Nothing
    volatile (timestamps, hostnames) is stored, so the same seed and
    the same measurements produce byte-identical profiles — profiles
    are diffable artifacts, re-calibrated deliberately when the model,
    the hardware, or the workload changes (docs/SCHEDULING.md)."""

    model_key: str
    seed: int
    cache_len: int
    bucket_levels: List[int]
    prefill_chunk: int                       # 0 = chunking off
    expected_us: float
    default_expected_us: float
    max_dispatch_us: float
    predicted_compiles: int
    feasible: bool
    prompt_lengths: List[int]
    bucket_costs: List[BucketCost]
    chunk_costs: List[ChunkCost]
    meta: Dict[str, str]
    # paged-KV extension (defaulted: version-1 profiles without these
    # fields load unchanged — kv_block 0 means "paging not calibrated")
    kv_block: int = 0
    decode_costs: List[DecodeCost] = dataclasses.field(
        default_factory=list)
    block_costs: List[BlockCost] = dataclasses.field(
        default_factory=list)
    # batched-dispatch extension (defaulted, same load-compat rule):
    # micro_lanes 0 = lane width not calibrated, replicas 0 = replica
    # count not solved
    micro_lanes: int = 0
    lane_costs: List[LaneCost] = dataclasses.field(
        default_factory=list)
    replicas: int = 0
    replica_costs: List[ReplicaCost] = dataclasses.field(
        default_factory=list)
    # quantized-serving extension (defaulted, same load-compat rule):
    # empty = precision not calibrated
    quant_costs: List[QuantCost] = dataclasses.field(
        default_factory=list)
    version: int = PROFILE_VERSION

    def bucket_table(self) -> BucketTable:
        """The solved table, ready to hand to an engine — identical
        (``BucketTable.__eq__``) to ``BucketTable.from_levels`` of the
        profile's levels."""
        return BucketTable.from_levels(self.bucket_levels)

    def matches(self, cfg: Any, cache_len: int) -> bool:
        """Whether this profile was calibrated for exactly this model
        and cache capacity."""
        return self.model_key == profile_model_key(cfg, cache_len)

    def matches_backend(self) -> bool:
        """Whether this profile was MEASURED on the backend this
        process runs on.  Costs are hardware facts: a profile
        calibrated on one backend is someone else's cost landscape on
        another, so ``ServingEngine.from_profile`` refuses a mismatch
        the same way it refuses a foreign ``model_key``.  (A jax
        *version* drift is allowed — same hardware class, costs drift
        rather than change meaning — but ``meta["jax"]`` records it
        for the re-calibration decision; see docs/SCHEDULING.md.)"""
        import jax
        return self.meta.get("backend") == jax.default_backend()

    # -- (de)serialization -------------------------------------------

    def to_json(self) -> str:
        """The canonical, sorted-key JSON form (what ``save`` writes)."""
        d = dataclasses.asdict(self)
        return json.dumps(d, indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "CalibrationProfile":
        """Inverse of ``to_json``; raises on an unknown version."""
        d = json.loads(text)
        version = d.get("version")
        if version != PROFILE_VERSION:
            raise ValueError(
                f"calibration profile version {version!r} is not "
                f"supported (expected {PROFILE_VERSION}); re-calibrate")
        d["bucket_costs"] = [BucketCost(**c) for c in d["bucket_costs"]]
        d["chunk_costs"] = [ChunkCost(**c) for c in d["chunk_costs"]]
        d.setdefault("kv_block", 0)
        d["decode_costs"] = [DecodeCost(**c)
                             for c in d.get("decode_costs", [])]
        d["block_costs"] = [BlockCost(**c)
                            for c in d.get("block_costs", [])]
        d.setdefault("micro_lanes", 0)
        d.setdefault("replicas", 0)
        d["lane_costs"] = [LaneCost(**c)
                           for c in d.get("lane_costs", [])]
        d["replica_costs"] = [ReplicaCost(**c)
                              for c in d.get("replica_costs", [])]
        d["quant_costs"] = [QuantCost(**c)
                            for c in d.get("quant_costs", [])]
        return cls(**d)

    def save(self, path: str) -> str:
        """Write the profile JSON to ``path`` (returns ``path``)."""
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path

    @classmethod
    def load(cls, path: str) -> "CalibrationProfile":
        """Read a profile written by ``save``."""
        with open(path) as f:
            return cls.from_json(f.read())


# ---------------------------------------------------------------------------
# the on-disk profile cache (keyed by model_key)
# ---------------------------------------------------------------------------

def profile_cache_path(model_key: str,
                       cache_dir: Optional[Any] = None) -> str:
    """Where the cached profile for ``model_key`` lives: one JSON per
    key under ``benchmarks/results/profiles/`` (slashes flattened so
    the key stays a single filename)."""
    base = pathlib.Path(cache_dir) if cache_dir is not None \
        else DEFAULT_PROFILE_DIR
    return str(base / (model_key.replace("/", "__") + ".json"))


def save_cached_profile(profile: CalibrationProfile,
                        cache_dir: Optional[Any] = None) -> str:
    """Persist ``profile`` into the cache at its ``model_key`` slot
    (creating the cache directory if needed); returns the path."""
    path = profile_cache_path(profile.model_key, cache_dir)
    pathlib.Path(path).parent.mkdir(parents=True, exist_ok=True)
    return profile.save(path)


def load_cached_profile(model_key: str,
                        cache_dir: Optional[Any] = None
                        ) -> Optional[CalibrationProfile]:
    """The cached profile for ``model_key``, or None when absent —
    absence is the normal cold-cache case, so no exception.  A present
    but unreadable/foreign-version file DOES raise: silent fallback
    would hide a corrupted cache."""
    path = profile_cache_path(model_key, cache_dir)
    if not pathlib.Path(path).exists():
        return None
    return CalibrationProfile.load(path)


def _candidate_levels(plens: np.ndarray, cache_len: int,
                      explicit: Optional[Sequence[int]]
                      ) -> List[int]:
    """The bucket lengths worth measuring: the power-of-two ladder
    (today's default layout — so the solver can always reproduce the
    fallback) plus the workload's own distinct prefill lengths, capped
    at ``MAX_CANDIDATES`` by quantile subsampling."""
    if explicit is not None:
        cands = sorted({int(x) for x in explicit})
        if not cands:
            raise ValueError("candidate_levels must be non-empty")
        cands = [c for c in cands if c <= cache_len]
        if not cands:
            raise ValueError(
                f"every candidate level in {sorted(explicit)} exceeds "
                f"the usable cache room ({cache_len}) — the engine "
                f"would fall back to exact-length prefill for every "
                f"prompt, which is what calibration exists to prevent")
        return cands
    need = int(plens.max()) if len(plens) else MIN_LEVEL
    pow2 = []
    b = MIN_LEVEL
    while b <= cache_len:
        pow2.append(b)
        b <<= 1
    own = sorted({int(x) for x in plens if MIN_LEVEL <= x <= cache_len})
    room = max(2, MAX_CANDIDATES - len(pow2))
    if len(own) > room:
        qs = np.linspace(0, 100, room)
        own = sorted({int(np.percentile(own, q,
                                        method="higher")) for q in qs})
    cands = sorted(set(pow2) | set(own) | {min(need, cache_len)})
    return cands


def calibrate(bundle: Any, params: Any,
              prompt_lengths: Sequence[int], *,
              cache_len: int = 256, seed: int = 0,
              candidate_levels: Optional[Sequence[int]] = None,
              chunk_candidates: Sequence[int] = DEFAULT_CHUNK_CANDIDATES,
              max_dispatch_us: Optional[float] = None,
              iters: int = 5,
              decode_slots: Sequence[int] = (),
              block_candidates: Sequence[int] = (),
              new_tokens: int = 16,
              lane_candidates: Sequence[int] = (),
              lane_demand: Sequence[int] = (),
              micro: Optional[Tuple[Any, Any]] = None,
              replica_candidates: Sequence[int] = (),
              target_tokens_per_us: Optional[float] = None,
              quant_candidates: Sequence[Tuple[str, str]] = (),
              measure: Optional[Callable[[str, int],
                                         CompileStepTiming]] = None
              ) -> CalibrationProfile:
    """Run the calibration pass and solve for the serving config.

    Measures every candidate bucket level's (compile, padded-step)
    cost and every candidate chunk size's step cost through
    ``measure`` (default: ``EngineMeasurer`` timing the real compiled
    steps), then solves for the bucket levels and chunk size that
    minimize the expected prefill latency of ``prompt_lengths`` —
    reuse the arrival-process workload generators to sample these —
    and freezes everything into a ``CalibrationProfile``.

    ``max_dispatch_us`` bounds how long any single prefill dispatch
    may monopolize the engine (the head-of-line knob chunking exists
    for); ``measure`` injection makes the pass exactly reproducible
    (see the module docstring's determinism contract).

    The decode side is opt-in (both default empty, so injected
    measurement hooks written for the prefill-only contract keep
    working): ``decode_slots`` prices the fused decode step at each
    slot count (``("decode", B)``), and ``block_candidates`` prices
    the PAGED decode step at each block size (``("decode_paged",
    BS)``) then solves for the block size maximizing admissible
    concurrent slots at a reference HBM budget (``solve_block_size``
    with ``new_tokens`` reserved per request) — the solved size lands
    in ``profile.kv_block`` and ``ServingEngine.from_profile`` turns
    it on.

    Batched-dispatch calibration is opt-in the same way:
    ``lane_candidates`` prices the host's pooled micro dispatch at
    each lane count (``("micro", B)`` — supply ``micro=(model,
    resolver)`` so the default measurer can build real
    ``InterpreterPool``s, or inject ``measure``) and ``solve_lanes``
    over ``lane_demand`` (per-tick concurrent micro job counts;
    defaults to steady full demand at the widest candidate) lands in
    ``profile.micro_lanes``; ``replica_candidates`` models per-replica
    decode capacity from the measured fused decode step (requires
    ``decode_slots``) and, when ``target_tokens_per_us`` is given,
    ``solve_replicas`` lands the smallest sufficient replica count in
    ``profile.replicas``.

    ``quant_candidates`` prices the QUANTIZED fused decode step for
    each (weight_dtype, kv_dtype) precision pair — ``"fp32"`` on
    either axis means unquantized, so ``("fp32", "fp32")`` is the
    baseline row — at the largest ``decode_slots`` count (2 when
    unset), landing ``QuantCost`` rows (with the engine's resident
    HBM footprint, when the measurer can report it) in
    ``profile.quant_costs``; ``solve_precision`` picks a deployment
    precision from them."""
    plens = np.array([max(int(l) - 1, 0) for l in prompt_lengths],
                     dtype=np.int64)
    plens = plens[plens >= 1]
    if len(plens) == 0:
        raise ValueError("prompt_lengths contains no multi-token "
                         "prompt — nothing to calibrate")
    # lazy import: serving sits above core; by call time both exist
    from repro.serving.engine import (BUCKETED_FAMILIES,
                                      CHUNKED_FAMILIES)
    from repro.serving.errors import UnsupportedFamilyError
    calibratable = tuple(dict.fromkeys(BUCKETED_FAMILIES
                                       + CHUNKED_FAMILIES))
    if bundle.cfg.family not in calibratable:
        raise UnsupportedFamilyError(
            bundle.cfg.family, "bucket/chunk calibration (no bucketed "
            "or chunked prefill fast path to size)",
            supported=calibratable)
    injected = measure is not None
    if lane_candidates and not injected and micro is None:
        raise ValueError(
            "lane_candidates needs micro=(model, resolver) so the "
            "default measurer can build real InterpreterPools (or "
            "inject measure=)")
    if measure is None:
        measure = EngineMeasurer(bundle, params, cache_len, seed=seed,
                                 iters=iters)
    # a vlm's vision prefix occupies cache rows the prompt cannot use:
    # mirror the engine's `room` (bucket over-cap) and chunk-fit math
    vis = (int(getattr(bundle.cfg, "n_vision_tokens", 0))
           if bundle.cfg.family == "vlm" else 0)
    room = cache_len - vis
    cands = _candidate_levels(plens, room, candidate_levels)
    chunks = sorted({int(c) for c in chunk_candidates} - {0})
    # measure prefill at each chunk size too: the engine's FIRST chunk
    # runs through the ordinary prefill program at that length, so the
    # solver needs its cost (and it may double as a bucket level)
    cands = sorted(set(cands) | {c for c in chunks if c <= room})
    # also measure every level the DEFAULT pow2 table would hit on
    # this workload — NOT offered to the solver (explicit
    # candidate_levels stay authoritative), only priced, so the
    # solved-vs-default comparison below rests on measurements
    default_tbl = BucketTable(min_bucket=8, max_bucket=cache_len)
    default_levels = set()
    for m in np.unique(plens):
        lvl = default_tbl.fit(int(m))
        if lvl is not None and lvl <= room:
            default_levels.add(lvl)
    bucket_costs = []
    for L in sorted(set(cands) | default_levels):
        t = measure("prefill", L)
        bucket_costs.append(BucketCost(length=L, compile_us=t.compile_us,
                                       step_us=t.step_us))
    chunk_costs = []
    for C in chunks:
        t = measure("chunk", C)
        chunk_costs.append(ChunkCost(chunk=C, compile_us=t.compile_us,
                                     step_us=t.step_us))
    decode_costs = []
    for B in sorted({int(b) for b in decode_slots if int(b) >= 1}):
        t = measure("decode", B)
        decode_costs.append(DecodeCost(slots=B, compile_us=t.compile_us,
                                       step_us=t.step_us))
    block_costs = []
    for BS in sorted({int(b) for b in block_candidates
                      if int(b) >= 1 and cache_len % int(b) == 0}):
        t = measure("decode_paged", BS)
        block_costs.append(BlockCost(block=BS, compile_us=t.compile_us,
                                     step_us=t.step_us))
    kv_block = 0
    if block_costs:
        ref_slots = max(decode_slots) if decode_slots else 2
        kv_block = solve_block_size(
            prompt_lengths, block_costs, cache_len=cache_len,
            slots=ref_slots, new_tokens=new_tokens,
            vis_tokens=vis).block
    lane_costs: List[LaneCost] = []
    micro_lanes = 0
    lane_cands = sorted({int(b) for b in lane_candidates
                         if int(b) >= 1})
    if lane_cands:
        lane_measure = measure
        if not injected:
            # validated up front: micro is a (model, resolver) pair
            lane_measure = MicroMeasurer(*micro, seed=seed,
                                         iters=iters)
        for B in lane_cands:
            t = lane_measure("micro", B)
            lane_costs.append(LaneCost(lanes=B, compile_us=t.compile_us,
                                       step_us=t.step_us))
        demand = [int(d) for d in lane_demand] or [max(lane_cands)]
        micro_lanes = solve_lanes(
            demand, lane_costs,
            max_dispatch_us=max_dispatch_us).lanes
    replicas = 0
    replica_costs: List[ReplicaCost] = []
    rep_cands = sorted({int(r) for r in replica_candidates
                        if int(r) >= 1})
    if rep_cands:
        if not decode_costs:
            raise ValueError(
                "replica_candidates requires decode_slots — the "
                "per-replica tick is priced from the measured fused "
                "decode step")
        base = max(decode_costs, key=lambda c: c.slots)
        replica_costs = [ReplicaCost(replicas=r, slots=base.slots,
                                     step_us=base.step_us)
                         for r in rep_cands]
        if target_tokens_per_us is not None:
            replicas = solve_replicas(target_tokens_per_us, base,
                                      candidates=rep_cands).replicas
    quant_costs: List[QuantCost] = []
    if quant_candidates:
        q_slots = max([int(b) for b in decode_slots], default=2)
        hbm_hook = getattr(measure, "hbm_bytes", None)
        for wd, kd in dict.fromkeys((str(w), str(k))
                                    for w, k in quant_candidates):
            qk = f"decode_q:{wd}:{kd}"
            t = measure(qk, q_slots)
            quant_costs.append(QuantCost(
                weight_dtype=wd, kv_dtype=kd, slots=q_slots,
                compile_us=t.compile_us, step_us=t.step_us,
                hbm_bytes=int(hbm_hook(qk, q_slots))
                if hbm_hook else 0))
    solver_costs = [c for c in bucket_costs if c.length in set(cands)]
    best = solve(prompt_lengths, solver_costs, chunk_costs,
                 cache_len=cache_len, max_dispatch_us=max_dispatch_us,
                 vis_tokens=vis)
    # capacity guard: always keep one level at the largest measured
    # candidate, so a serving-time prompt LONGER than anything in the
    # calibration workload still buckets (one compile) instead of
    # silently falling back to exact-length retrace-per-length.  An
    # unhit level costs nothing — predicted_compiles and expected_us
    # are unchanged for the calibrated workload.
    levels = list(best.levels)
    cap = max(c.length for c in solver_costs)
    if levels[-1] < cap:
        levels.append(cap)
    best.levels = levels
    # the objective of today's hand-picked fallback (pow2 ladder from
    # 8, chunking off), evaluated on the SAME measurements — what
    # "beating the defaults" is measured against.  Every bucketed
    # default level was added to the candidate set above; over-room
    # lengths (the engine's exact-length fallback, one trace per
    # distinct length) interpolate from the nearest measured level
    by_len = {c.length: c for c in bucket_costs}
    default_cost = 0.0
    default_traced: Dict[int, float] = {}
    for m in plens:
        lvl = default_tbl.fit(int(m))
        if lvl is not None and lvl > room:
            lvl = None                  # engine over-cap: exact length
        want = lvl if lvl is not None else int(m)
        c = by_len.get(want)
        if c is not None:
            default_cost += c.step_us
            default_traced[want] = c.trace_overhead_us
        else:
            ref = min(bucket_costs,
                      key=lambda r: abs(r.length - want))
            default_cost += ref.step_us * want / ref.length
            default_traced[want] = ref.trace_overhead_us
    default_cost += sum(default_traced.values())
    import jax
    return CalibrationProfile(
        model_key=profile_model_key(bundle.cfg, cache_len),
        seed=int(seed), cache_len=int(cache_len),
        bucket_levels=list(best.levels),
        prefill_chunk=int(best.chunk),
        expected_us=round(float(best.expected_us), 3),
        default_expected_us=round(float(default_cost), 3),
        max_dispatch_us=round(float(best.max_dispatch_us), 3),
        predicted_compiles=int(best.predicted_compiles),
        feasible=bool(best.feasible),
        prompt_lengths=[int(x) for x in prompt_lengths],
        bucket_costs=bucket_costs, chunk_costs=chunk_costs,
        meta={"jax": jax.__version__,
              "backend": jax.default_backend()},
        kv_block=int(kv_block),
        decode_costs=decode_costs, block_costs=block_costs,
        micro_lanes=int(micro_lanes), lane_costs=lane_costs,
        replicas=int(replicas), replica_costs=replica_costs,
        quant_costs=quant_costs)
