"""Reference kernels (paper §4.7 — "simple operator-kernel implementations
designed for readability rather than performance").

Every op is a (prepare, eval) pair registered under the ``"reference"``
tag.  ``prepare`` runs once during interpreter init: it validates
shapes/dtypes, computes output specs, precomputes requantization constants
(which TFLM stores in the persistent arena), and requests scratch.
``eval`` is a pure jnp function executed inside the jitted invoke.

Quantized (INT8) paths follow the TFLM reference kernels: int32
accumulation, gemmlowp fixed-point requantization, quantized activation
clamps.  Lookup-table transcendentals (softmax/logistic/tanh) use a
dequant→float→requant reference instead of the int16 LUTs — a documented
deviation bounded by the quantization tolerance tests.

Conventions (TFLite layouts):
  CONV_2D            x: NHWC,  w: (O, KH, KW, I),    bias: (O,)
  DEPTHWISE_CONV_2D  x: NHWC,  w: (1, KH, KW, C*M),  bias: (C*M,)
  FULLY_CONNECTED    x: (..., K), w: (N, K),          bias: (N,)
  SVDF               x: (B, F), w_feat: (NF, F), w_time: (NF, T),
                     bias: (U,), state (variable): (B, NF*T)
"""

from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize as Q
from .op_resolver import PrepareResult, TensorSpec, register_op
from .schema import OpCode

# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

_ACT_RANGES_F32 = {
    "none": (-np.inf, np.inf),
    "relu": (0.0, np.inf),
    "relu6": (0.0, 6.0),
}


def _apply_activation_f32(x, activation: str):
    lo, hi = _ACT_RANGES_F32[activation]
    if lo == -np.inf and hi == np.inf:
        return x
    if hi == np.inf:
        return jnp.maximum(x, jnp.asarray(lo, x.dtype))
    return jnp.clip(x, jnp.asarray(lo, x.dtype), jnp.asarray(hi, x.dtype))


def _quantized_activation_range(activation: str, scale: float,
                                zero_point: int) -> Tuple[int, int]:
    """TFLM CalculateActivationRangeQuantized."""
    qmin, qmax = Q.INT8_MIN, Q.INT8_MAX
    if activation == "relu":
        qmin = max(qmin, zero_point + int(round(0.0 / scale)))
    elif activation == "relu6":
        qmin = max(qmin, zero_point + int(round(0.0 / scale)))
        qmax = min(qmax, zero_point + int(round(6.0 / scale)))
    return qmin, qmax


def _conv_padding(padding: str, in_size: int, k: int, stride: int,
                  dilation: int = 1) -> Tuple[int, int, int]:
    """Returns (pad_lo, pad_hi, out_size), TFLite SAME/VALID semantics."""
    eff_k = (k - 1) * dilation + 1
    if padding == "VALID":
        out = (in_size - eff_k) // stride + 1
        return 0, 0, out
    out = -(-in_size // stride)                     # ceil div
    total = max(0, (out - 1) * stride + eff_k - in_size)
    return total // 2, total - total // 2, out


def _spec(shape, dtype) -> TensorSpec:
    return TensorSpec(tuple(int(d) for d in shape), dtype)


def _nbytes(spec: TensorSpec) -> int:
    n = 1
    for d in spec.shape:
        n *= d
    item = 2 if spec.dtype == "bfloat16" else np.dtype(spec.dtype).itemsize
    return n * item


# ---------------------------------------------------------------------------
# CONV_2D
# ---------------------------------------------------------------------------

@register_op(OpCode.CONV_2D)
class Conv2D:
    """Standard 2-D convolution (NHWC x OHWI), float or per-channel int8
    with fused bias/activation — paper Table 1's flagship kernel.
    """

    @staticmethod
    def prepare(ctx, op):
        x = ctx.tensor_spec(op.inputs[0])
        w = ctx.tensor_spec(op.inputs[1])
        p = op.params
        sh, sw = p.get("stride_h", 1), p.get("stride_w", 1)
        dh, dw = p.get("dilation_h", 1), p.get("dilation_w", 1)
        pad = p.get("padding", "VALID")
        n, ih, iw, ic = x.shape
        oc, kh, kw, wic = w.shape
        assert wic == ic, f"conv channel mismatch {wic} != {ic}"
        _, _, oh = _conv_padding(pad, ih, kh, sh, dh)
        _, _, ow = _conv_padding(pad, iw, kw, sw, dw)
        out_spec = _spec((n, oh, ow, oc), x.dtype)
        op_data: Dict[str, Any] = {"act": p.get("activation", "none")}
        persistent = 0
        if x.dtype == "int8":
            xq, wq = ctx.quant(op.inputs[0]), ctx.quant(op.inputs[1])
            oq = ctx.quant(op.outputs[0])
            wscales = (wq.channel_scales if wq.is_per_channel
                       else np.array([wq.scale], np.float32))
            rs = Q.RequantSpec.build(xq.scale, wscales, oq.scale,
                                     xq.zero_point, oq.zero_point)
            qmin, qmax = _quantized_activation_range(
                op_data["act"], oq.scale, oq.zero_point)
            op_data.update(requant=rs, qmin=qmin, qmax=qmax)
            persistent = rs.nbytes()
        # im2col scratch, the TFLM conv scratch analogue
        scratch = [kh * kw * ic * oh * ow * 4]
        return PrepareResult([out_spec], scratch_nbytes=scratch,
                             persistent_nbytes=persistent, op_data=op_data)

    @staticmethod
    def eval(ctx, op, inputs):
        x, w = inputs[0], inputs[1]
        bias = inputs[2] if len(inputs) > 2 and inputs[2] is not None else None
        p = op.params
        sh, sw = p.get("stride_h", 1), p.get("stride_w", 1)
        dh, dw = p.get("dilation_h", 1), p.get("dilation_w", 1)
        pad = p.get("padding", "VALID")
        d = ctx.op_data
        dn = jax.lax.conv_dimension_numbers(x.shape, w.shape,
                                            ("NHWC", "OHWI", "NHWC"))
        if x.dtype == jnp.int8:
            rs: Q.RequantSpec = d["requant"]
            xs = x.astype(jnp.int32) - rs.input_zero_point
            acc = jax.lax.conv_general_dilated(
                xs, w.astype(jnp.int32), (sh, sw), pad,
                rhs_dilation=(dh, dw), dimension_numbers=dn,
                preferred_element_type=jnp.int32)
            if bias is not None:
                acc = acc + bias.astype(jnp.int32)
            out = Q.requantize(acc, rs.multiplier, rs.shift,
                               rs.output_zero_point, d["qmin"], d["qmax"])
            return [out]
        acc = jax.lax.conv_general_dilated(
            x, w, (sh, sw), pad, rhs_dilation=(dh, dw), dimension_numbers=dn)
        if bias is not None:
            acc = acc + bias
        return [_apply_activation_f32(acc, d["act"])]


# ---------------------------------------------------------------------------
# DEPTHWISE_CONV_2D
# ---------------------------------------------------------------------------

@register_op(OpCode.DEPTHWISE_CONV_2D)
class DepthwiseConv2D:
    """Depthwise 2-D convolution (channel multiplier layout), the
    MobileNet/VWW workhorse; float or per-channel int8.
    """

    @staticmethod
    def prepare(ctx, op):
        x = ctx.tensor_spec(op.inputs[0])
        w = ctx.tensor_spec(op.inputs[1])
        p = op.params
        sh, sw = p.get("stride_h", 1), p.get("stride_w", 1)
        pad = p.get("padding", "VALID")
        n, ih, iw, ic = x.shape
        one, kh, kw, oc = w.shape
        mult = p.get("depth_multiplier", oc // ic)
        assert oc == ic * mult
        _, _, oh = _conv_padding(pad, ih, kh, sh)
        _, _, ow = _conv_padding(pad, iw, kw, sw)
        out_spec = _spec((n, oh, ow, oc), x.dtype)
        op_data: Dict[str, Any] = {"act": p.get("activation", "none"),
                                   "mult": mult}
        persistent = 0
        if x.dtype == "int8":
            xq, wq = ctx.quant(op.inputs[0]), ctx.quant(op.inputs[1])
            oq = ctx.quant(op.outputs[0])
            wscales = (wq.channel_scales if wq.is_per_channel
                       else np.array([wq.scale], np.float32))
            rs = Q.RequantSpec.build(xq.scale, wscales, oq.scale,
                                     xq.zero_point, oq.zero_point)
            qmin, qmax = _quantized_activation_range(
                op_data["act"], oq.scale, oq.zero_point)
            op_data.update(requant=rs, qmin=qmin, qmax=qmax)
            persistent = rs.nbytes()
        return PrepareResult([out_spec], persistent_nbytes=persistent,
                             op_data=op_data)

    @staticmethod
    def eval(ctx, op, inputs):
        x, w = inputs[0], inputs[1]
        bias = inputs[2] if len(inputs) > 2 and inputs[2] is not None else None
        p = op.params
        sh, sw = p.get("stride_h", 1), p.get("stride_w", 1)
        pad = p.get("padding", "VALID")
        d = ctx.op_data
        ic = x.shape[-1]
        # TFLite DW layout (1,KH,KW,C*M) -> HWIO grouped conv w/ groups=ic
        kh, kw = w.shape[1], w.shape[2]
        w_hwio = w.reshape(kh, kw, ic, d["mult"]).transpose(3, 0, 1, 2)
        w_hwio = w_hwio.reshape(ic * d["mult"], kh, kw, 1)
        dn = jax.lax.conv_dimension_numbers(x.shape, w_hwio.shape,
                                            ("NHWC", "OHWI", "NHWC"))
        if x.dtype == jnp.int8:
            rs: Q.RequantSpec = d["requant"]
            xs = x.astype(jnp.int32) - rs.input_zero_point
            acc = jax.lax.conv_general_dilated(
                xs, w_hwio.astype(jnp.int32), (sh, sw), pad,
                dimension_numbers=dn, feature_group_count=ic,
                preferred_element_type=jnp.int32)
            if bias is not None:
                acc = acc + bias.astype(jnp.int32)
            out = Q.requantize(acc, rs.multiplier, rs.shift,
                               rs.output_zero_point, d["qmin"], d["qmax"])
            return [out]
        acc = jax.lax.conv_general_dilated(
            x, w_hwio, (sh, sw), pad, dimension_numbers=dn,
            feature_group_count=ic)
        if bias is not None:
            acc = acc + bias
        return [_apply_activation_f32(acc, d["act"])]


# ---------------------------------------------------------------------------
# FULLY_CONNECTED
# ---------------------------------------------------------------------------

@register_op(OpCode.FULLY_CONNECTED)
class FullyConnected:
    """Dense layer y = xW^T + b with optional fused activation; int8 path
    requantizes through the TFLite fixed-point scheme.
    """

    @staticmethod
    def prepare(ctx, op):
        x = ctx.tensor_spec(op.inputs[0])
        w = ctx.tensor_spec(op.inputs[1])
        n_out, k = w.shape
        assert x.shape[-1] == k, f"FC dim mismatch {x.shape} @ {w.shape}"
        out_spec = _spec(x.shape[:-1] + (n_out,), x.dtype)
        p = op.params
        op_data: Dict[str, Any] = {"act": p.get("activation", "none")}
        persistent = 0
        if x.dtype == "int8":
            xq, wq = ctx.quant(op.inputs[0]), ctx.quant(op.inputs[1])
            oq = ctx.quant(op.outputs[0])
            wscales = (wq.channel_scales if wq.is_per_channel
                       else np.array([wq.scale], np.float32))
            rs = Q.RequantSpec.build(xq.scale, wscales, oq.scale,
                                     xq.zero_point, oq.zero_point)
            qmin, qmax = _quantized_activation_range(
                op_data["act"], oq.scale, oq.zero_point)
            op_data.update(requant=rs, qmin=qmin, qmax=qmax)
            persistent = rs.nbytes()
        return PrepareResult([out_spec], persistent_nbytes=persistent,
                             op_data=op_data)

    @staticmethod
    def eval(ctx, op, inputs):
        x, w = inputs[0], inputs[1]
        bias = inputs[2] if len(inputs) > 2 and inputs[2] is not None else None
        d = ctx.op_data
        if x.dtype == jnp.int8:
            rs: Q.RequantSpec = d["requant"]
            xs = x.astype(jnp.int32) - rs.input_zero_point
            acc = jax.lax.dot_general(
                xs, w.astype(jnp.int32),
                (((x.ndim - 1,), (1,)), ((), ())),
                preferred_element_type=jnp.int32)
            if bias is not None:
                acc = acc + bias.astype(jnp.int32)
            out = Q.requantize(acc, rs.multiplier, rs.shift,
                               rs.output_zero_point, d["qmin"], d["qmax"])
            return [out]
        acc = jnp.einsum("...k,nk->...n", x, w)
        if bias is not None:
            acc = acc + bias
        return [_apply_activation_f32(acc, d["act"])]


# ---------------------------------------------------------------------------
# elementwise binary (ADD / SUB / MUL / MIN / MAX / SQUARED_DIFFERENCE)
# ---------------------------------------------------------------------------

def _broadcast_shape(a, b):
    return tuple(np.broadcast_shapes(tuple(a), tuple(b)))


def _binary_prepare(ctx, op):
    a = ctx.tensor_spec(op.inputs[0])
    b = ctx.tensor_spec(op.inputs[1])
    out_spec = _spec(_broadcast_shape(a.shape, b.shape), a.dtype)
    op_data: Dict[str, Any] = {"act": op.params.get("activation", "none")}
    persistent = 0
    if a.dtype == "int8":
        q1, q2 = ctx.quant(op.inputs[0]), ctx.quant(op.inputs[1])
        oq = ctx.quant(op.outputs[0])
        op_data.update(q1=(q1.scale, q1.zero_point),
                       q2=(q2.scale, q2.zero_point),
                       qo=(oq.scale, oq.zero_point))
        if op.opcode in (OpCode.ADD, OpCode.SUB):
            # TFLM quantized add: align on twice_max_input_scale, ls=20
            ls = 20
            twice_max = 2.0 * max(q1.scale, q2.scale)
            m1, s1 = Q.quantize_multiplier(q1.scale / twice_max)
            m2, s2 = Q.quantize_multiplier(q2.scale / twice_max)
            mo, so = Q.quantize_multiplier(
                twice_max / ((1 << ls) * oq.scale))
            op_data.update(ls=ls, m1=m1, s1=s1, m2=m2, s2=s2, mo=mo, so=so)
            persistent = 48
        elif op.opcode == OpCode.MUL:
            mo, so = Q.quantize_multiplier(q1.scale * q2.scale / oq.scale)
            op_data.update(mo=mo, so=so)
            persistent = 16
        qmin, qmax = _quantized_activation_range(
            op_data["act"], oq.scale, oq.zero_point)
        op_data.update(qmin=qmin, qmax=qmax)
    return PrepareResult([out_spec], persistent_nbytes=persistent,
                         op_data=op_data)


def _make_binary(opcode, f32_fn, int8_kind):
    class _Bin:
        @staticmethod
        def prepare(ctx, op):
            return _binary_prepare(ctx, op)

        @staticmethod
        def eval(ctx, op, inputs):
            a, b = inputs
            d = ctx.op_data
            if a.dtype == jnp.int8 and int8_kind == "addsub":
                s1z, s2z, (oscale, ozp) = d["q1"], d["q2"], d["qo"]
                x1 = (a.astype(jnp.int32) - s1z[1]) << d["ls"]
                x2 = (b.astype(jnp.int32) - s2z[1]) << d["ls"]
                x1 = Q.multiply_by_quantized_multiplier(x1, d["m1"], d["s1"])
                x2 = Q.multiply_by_quantized_multiplier(x2, d["m2"], d["s2"])
                raw = x1 - x2 if op.opcode == OpCode.SUB else x1 + x2
                out = Q.multiply_by_quantized_multiplier(
                    raw, d["mo"], d["so"]) + ozp
                return [jnp.clip(out, d["qmin"], d["qmax"]).astype(jnp.int8)]
            if a.dtype == jnp.int8 and int8_kind == "mul":
                (s1, z1), (s2, z2), (so_, zo) = d["q1"], d["q2"], d["qo"]
                raw = ((a.astype(jnp.int32) - z1)
                       * (b.astype(jnp.int32) - z2))
                out = Q.multiply_by_quantized_multiplier(
                    raw, d["mo"], d["so"]) + zo
                return [jnp.clip(out, d["qmin"], d["qmax"]).astype(jnp.int8)]
            if a.dtype == jnp.int8:
                (s1, z1), (s2, z2), (so_, zo) = d["q1"], d["q2"], d["qo"]
                fa = (a.astype(jnp.float32) - z1) * s1
                fb = (b.astype(jnp.float32) - z2) * s2
                out = jnp.round(f32_fn(fa, fb) / so_) + zo
                return [jnp.clip(out, Q.INT8_MIN, Q.INT8_MAX
                                 ).astype(jnp.int8)]
            return [_apply_activation_f32(f32_fn(a, b), d["act"])]
    _Bin.__name__ = f"Bin_{opcode}"
    register_op(opcode)(_Bin)
    return _Bin


_make_binary(OpCode.ADD, lambda a, b: a + b, "addsub")
_make_binary(OpCode.SUB, lambda a, b: a - b, "addsub")
_make_binary(OpCode.MUL, lambda a, b: a * b, "mul")
_make_binary(OpCode.MINIMUM, jnp.minimum, "float")
_make_binary(OpCode.MAXIMUM, jnp.maximum, "float")
_make_binary(OpCode.SQUARED_DIFFERENCE, lambda a, b: (a - b) ** 2, "float")


# ---------------------------------------------------------------------------
# pooling
# ---------------------------------------------------------------------------

def _pool_prepare(ctx, op):
    x = ctx.tensor_spec(op.inputs[0])
    p = op.params
    kh, kw = p.get("filter_h", 2), p.get("filter_w", 2)
    sh, sw = p.get("stride_h", kh), p.get("stride_w", kw)
    pad = p.get("padding", "VALID")
    n, ih, iw, c = x.shape
    _, _, oh = _conv_padding(pad, ih, kh, sh)
    _, _, ow = _conv_padding(pad, iw, kw, sw)
    return PrepareResult([_spec((n, oh, ow, c), x.dtype)],
                         op_data={"k": (kh, kw), "s": (sh, sw), "pad": pad})


@register_op(OpCode.MAX_POOL_2D)
class MaxPool2D:
    """Max pooling over NHWC windows via reduce_window; int8-safe (init
    is the int8 minimum, comparisons are exact).
    """

    prepare = staticmethod(_pool_prepare)

    @staticmethod
    def eval(ctx, op, inputs):
        (x,) = inputs
        kh, kw = ctx.op_data["k"]
        sh, sw = ctx.op_data["s"]
        pad = ctx.op_data["pad"]
        init = (jnp.iinfo(jnp.int8).min if x.dtype == jnp.int8
                else -jnp.inf)
        out = jax.lax.reduce_window(
            x, jnp.asarray(init, x.dtype), jax.lax.max,
            (1, kh, kw, 1), (1, sh, sw, 1), pad)
        return [out]


@register_op(OpCode.AVERAGE_POOL_2D)
class AvgPool2D:
    """Average pooling over NHWC windows; int8 accumulates in int32 and
    rounds back to the shared input/output scale.
    """

    prepare = staticmethod(_pool_prepare)

    @staticmethod
    def eval(ctx, op, inputs):
        (x,) = inputs
        kh, kw = ctx.op_data["k"]
        sh, sw = ctx.op_data["s"]
        pad = ctx.op_data["pad"]
        if x.dtype == jnp.int8:
            acc = jax.lax.reduce_window(
                x.astype(jnp.int32), jnp.int32(0), jax.lax.add,
                (1, kh, kw, 1), (1, sh, sw, 1), pad)
            cnt = jax.lax.reduce_window(
                jnp.ones(x.shape, jnp.int32), jnp.int32(0), jax.lax.add,
                (1, kh, kw, 1), (1, sh, sw, 1), pad)
            # rounding divide (TFLM: round-half-away-from-zero)
            out = jnp.where(acc >= 0, (acc + cnt // 2) // cnt,
                            -((-acc + cnt // 2) // cnt))
            return [jnp.clip(out, Q.INT8_MIN, Q.INT8_MAX).astype(jnp.int8)]
        acc = jax.lax.reduce_window(
            x, jnp.asarray(0, x.dtype), jax.lax.add,
            (1, kh, kw, 1), (1, sh, sw, 1), pad)
        cnt = jax.lax.reduce_window(
            jnp.ones(x.shape, x.dtype), jnp.asarray(0, x.dtype), jax.lax.add,
            (1, kh, kw, 1), (1, sh, sw, 1), pad)
        return [acc / cnt]


# ---------------------------------------------------------------------------
# shape / layout ops
# ---------------------------------------------------------------------------

@register_op(OpCode.RESHAPE)
class Reshape:
    """Shape-only view change (supports one -1 wildcard); no data
    movement beyond the reshape itself.
    """

    @staticmethod
    def prepare(ctx, op):
        x = ctx.tensor_spec(op.inputs[0])
        new_shape = list(op.params["new_shape"])
        n = int(np.prod(x.shape))
        if -1 in new_shape:
            i = new_shape.index(-1)
            rest = int(np.prod([d for d in new_shape if d != -1]))
            new_shape[i] = n // rest
        assert int(np.prod(new_shape)) == n
        return PrepareResult([_spec(new_shape, x.dtype)])

    @staticmethod
    def eval(ctx, op, inputs):
        shape = ctx.output_shape(0)
        return [inputs[0].reshape(shape)]


@register_op(OpCode.TRANSPOSE)
class Transpose:
    """Axis permutation by the serialized perm parameter."""

    @staticmethod
    def prepare(ctx, op):
        x = ctx.tensor_spec(op.inputs[0])
        perm = op.params["perm"]
        return PrepareResult([_spec([x.shape[p] for p in perm], x.dtype)])

    @staticmethod
    def eval(ctx, op, inputs):
        return [jnp.transpose(inputs[0], op.params["perm"])]


@register_op(OpCode.CONCATENATION)
class Concatenation:
    """Concatenate inputs along one axis; output spec sums that axis
    across the input specs.
    """

    @staticmethod
    def prepare(ctx, op):
        axis = op.params.get("axis", -1)
        specs = [ctx.tensor_spec(i) for i in op.inputs]
        shape = list(specs[0].shape)
        ax = axis % len(shape)
        shape[ax] = sum(s.shape[ax] for s in specs)
        return PrepareResult([_spec(shape, specs[0].dtype)])

    @staticmethod
    def eval(ctx, op, inputs):
        return [jnp.concatenate(inputs, axis=op.params.get("axis", -1))]


@register_op(OpCode.PAD)
class Pad:
    """Zero padding by per-axis (lo, hi) amounts from the serialized
    paddings parameter.
    """

    @staticmethod
    def prepare(ctx, op):
        x = ctx.tensor_spec(op.inputs[0])
        pads = op.params["paddings"]
        shape = [d + lo + hi for d, (lo, hi) in zip(x.shape, pads)]
        return PrepareResult([_spec(shape, x.dtype)])

    @staticmethod
    def eval(ctx, op, inputs):
        q = ctx.quant_of_output(0)
        cval = q.zero_point if inputs[0].dtype == jnp.int8 else 0
        return [jnp.pad(inputs[0], op.params["paddings"],
                        constant_values=cval)]


@register_op(OpCode.STRIDED_SLICE)
class StridedSlice:
    """Strided slicing with serialized begin/end/strides, shape computed
    at prepare time.
    """

    @staticmethod
    def prepare(ctx, op):
        x = ctx.tensor_spec(op.inputs[0])
        begin, end = op.params["begin"], op.params["end"]
        strides = op.params.get("strides", [1] * len(begin))
        shape = [max(0, -(-(e - b) // s))
                 for b, e, s in zip(begin, end, strides)]
        return PrepareResult([_spec(shape, x.dtype)])

    @staticmethod
    def eval(ctx, op, inputs):
        begin, end = op.params["begin"], op.params["end"]
        strides = op.params.get("strides", [1] * len(begin))
        return [jax.lax.slice(inputs[0], begin, end, strides)]


@register_op(OpCode.SPLIT)
class Split:
    """Even split along one axis into len(op.outputs) equal parts."""

    @staticmethod
    def prepare(ctx, op):
        x = ctx.tensor_spec(op.inputs[0])
        axis = op.params.get("axis", -1) % len(x.shape)
        n = len(op.outputs)
        assert x.shape[axis] % n == 0
        shape = list(x.shape)
        shape[axis] //= n
        return PrepareResult([_spec(shape, x.dtype) for _ in range(n)])

    @staticmethod
    def eval(ctx, op, inputs):
        axis = op.params.get("axis", -1)
        return list(jnp.split(inputs[0], len(op.outputs), axis=axis))


@register_op(OpCode.MEAN)
class Mean:
    """Mean reduction over the serialized axes (optionally keepdims);
    int8 reduces in float and requantizes to the output scale.
    """

    @staticmethod
    def prepare(ctx, op):
        x = ctx.tensor_spec(op.inputs[0])
        axes = tuple(a % len(x.shape) for a in op.params["axes"])
        keep = op.params.get("keepdims", False)
        shape = [d if i not in axes else 1
                 for i, d in enumerate(x.shape)]
        if not keep:
            shape = [d for i, d in enumerate(shape) if i not in axes]
        op_data = {}
        if x.dtype == "int8":
            xq, oq = ctx.quant(op.inputs[0]), ctx.quant(op.outputs[0])
            op_data = {"xq": (xq.scale, xq.zero_point),
                       "oq": (oq.scale, oq.zero_point)}
        return PrepareResult([_spec(shape, x.dtype)], op_data=op_data)

    @staticmethod
    def eval(ctx, op, inputs):
        (x,) = inputs
        axes = tuple(op.params["axes"])
        keep = op.params.get("keepdims", False)
        if x.dtype == jnp.int8:
            (xs, xz), (os_, oz) = ctx.op_data["xq"], ctx.op_data["oq"]
            f = (x.astype(jnp.float32) - xz) * xs
            m = jnp.mean(f, axis=axes, keepdims=keep)
            q = jnp.round(m / os_) + oz
            return [jnp.clip(q, Q.INT8_MIN, Q.INT8_MAX).astype(jnp.int8)]
        return [jnp.mean(x, axis=axes, keepdims=keep)]


# ---------------------------------------------------------------------------
# unary / activations
# ---------------------------------------------------------------------------

def _unary_prepare(ctx, op):
    x = ctx.tensor_spec(op.inputs[0])
    op_data = {}
    if x.dtype == "int8":
        xq, oq = ctx.quant(op.inputs[0]), ctx.quant(op.outputs[0])
        op_data = {"xq": (xq.scale, xq.zero_point),
                   "oq": (oq.scale, oq.zero_point)}
    return PrepareResult([_spec(x.shape, x.dtype)], op_data=op_data)


def _make_unary(opcode, f32_fn):
    class _Un:
        @staticmethod
        def prepare(ctx, op):
            return _unary_prepare(ctx, op)

        @staticmethod
        def eval(ctx, op, inputs):
            (x,) = inputs
            if x.dtype == jnp.int8:
                (xs, xz) = ctx.op_data["xq"]
                (os_, oz) = ctx.op_data["oq"]
                f = (x.astype(jnp.float32) - xz) * xs
                out = jnp.round(f32_fn(f) / os_) + oz
                return [jnp.clip(out, Q.INT8_MIN, Q.INT8_MAX
                                 ).astype(jnp.int8)]
            return [f32_fn(x)]
    _Un.__name__ = f"Unary_{opcode}"
    register_op(opcode)(_Un)
    return _Un


_make_unary(OpCode.RELU, lambda x: jnp.maximum(x, 0))
_make_unary(OpCode.RELU6, lambda x: jnp.clip(x, 0, 6))
_make_unary(OpCode.LOGISTIC, jax.nn.sigmoid)
_make_unary(OpCode.TANH, jnp.tanh)
_make_unary(OpCode.SILU, jax.nn.silu)
_make_unary(OpCode.GELU, jax.nn.gelu)
_make_unary(OpCode.RSQRT, jax.lax.rsqrt)
_make_unary(OpCode.EXP, jnp.exp)
_make_unary(OpCode.NEG, jnp.negative)
_make_unary(OpCode.LEAKY_RELU, lambda x: jnp.where(x >= 0, x, 0.01 * x))


@register_op(OpCode.SOFTMAX)
class Softmax:
    """Softmax along the last axis; int8 follows the TFLite convention
    (output scale 1/256, zero point -128).
    """

    @staticmethod
    def prepare(ctx, op):
        x = ctx.tensor_spec(op.inputs[0])
        op_data = {}
        if x.dtype == "int8":
            xq = ctx.quant(op.inputs[0])
            oq = ctx.quant(op.outputs[0])
            # TFLite convention: softmax output scale 1/256, zp -128
            op_data = {"xq": (xq.scale, xq.zero_point),
                       "oq": (oq.scale, oq.zero_point)}
        return PrepareResult([_spec(x.shape, x.dtype)], op_data=op_data)

    @staticmethod
    def eval(ctx, op, inputs):
        (x,) = inputs
        beta = op.params.get("beta", 1.0)
        if x.dtype == jnp.int8:
            (xs, xz), (os_, oz) = ctx.op_data["xq"], ctx.op_data["oq"]
            f = (x.astype(jnp.float32) - xz) * xs
            s = jax.nn.softmax(beta * f, axis=-1)
            out = jnp.round(s / os_) + oz
            return [jnp.clip(out, Q.INT8_MIN, Q.INT8_MAX).astype(jnp.int8)]
        return [jax.nn.softmax(jnp.asarray(beta, x.dtype) * x, axis=-1)]


@register_op(OpCode.IDENTITY)
class Identity:
    """Pass-through op (shape/dtype preserved) — the exporter's
    placeholder for folded or no-op nodes.
    """

    @staticmethod
    def prepare(ctx, op):
        x = ctx.tensor_spec(op.inputs[0])
        return PrepareResult([_spec(x.shape, x.dtype)])

    @staticmethod
    def eval(ctx, op, inputs):
        return [inputs[0]]


@register_op(OpCode.DROPOUT)
class Dropout(Identity):
    """Training-only op; the exporter strips it (§3.3).  If a model reaches
    the interpreter with DROPOUT intact, inference-mode semantics apply
    (identity)."""


# ---------------------------------------------------------------------------
# QUANTIZE / DEQUANTIZE
# ---------------------------------------------------------------------------

@register_op(OpCode.QUANTIZE)
class QuantizeOp:
    """float32 -> int8 affine quantization to the output tensor's (scale,
    zero_point), baked at prepare time.
    """

    @staticmethod
    def prepare(ctx, op):
        x = ctx.tensor_spec(op.inputs[0])
        oq = ctx.quant(op.outputs[0])
        return PrepareResult([_spec(x.shape, "int8")],
                             op_data={"oq": (oq.scale, oq.zero_point)})

    @staticmethod
    def eval(ctx, op, inputs):
        (x,) = inputs
        (s, z) = ctx.op_data["oq"]
        q = jnp.round(x / jnp.asarray(s, x.dtype)) + z
        return [jnp.clip(q, Q.INT8_MIN, Q.INT8_MAX).astype(jnp.int8)]


@register_op(OpCode.DEQUANTIZE)
class DequantizeOp:
    """int8 -> float32 affine dequantization from the input tensor's
    (scale, zero_point), baked at prepare time.
    """

    @staticmethod
    def prepare(ctx, op):
        x = ctx.tensor_spec(op.inputs[0])
        xq = ctx.quant(op.inputs[0])
        return PrepareResult([_spec(x.shape, "float32")],
                             op_data={"xq": (xq.scale, xq.zero_point)})

    @staticmethod
    def eval(ctx, op, inputs):
        (x,) = inputs
        (s, z) = ctx.op_data["xq"]
        return [(x.astype(jnp.float32) - z) * jnp.float32(s)]


# ---------------------------------------------------------------------------
# SVDF (the Google Hotword workhorse op)
# ---------------------------------------------------------------------------

@register_op(OpCode.SVDF)
class SVDF:
    """TFLite SVDF: rank-factored time-convolutional layer.

    inputs: x (B, F), w_feature (NF, F), w_time (NF, T), bias (U,) or -1,
            state variable (B, NF*T)
    params: rank; units = NF // rank; activation.
    """

    @staticmethod
    def prepare(ctx, op):
        x = ctx.tensor_spec(op.inputs[0])
        wf = ctx.tensor_spec(op.inputs[1])
        wt = ctx.tensor_spec(op.inputs[2])
        rank = op.params.get("rank", 1)
        nf, f = wf.shape
        _, t = wt.shape
        units = nf // rank
        assert x.shape[-1] == f
        out_spec = _spec((x.shape[0], units), x.dtype)
        return PrepareResult(
            [out_spec],
            op_data={"rank": rank, "units": units, "nf": nf, "t": t},
            variable_updates=[op.inputs[4]])

    @staticmethod
    def eval(ctx, op, inputs):
        x, wf, wt = inputs[0], inputs[1], inputs[2]
        bias = inputs[3]
        state = inputs[4]                       # (B, NF*T)
        d = ctx.op_data
        b = x.shape[0]
        nf, t, rank, units = d["nf"], d["t"], d["rank"], d["units"]
        st = state.reshape(b, nf, t)
        feat = x @ wf.T                         # (B, NF)
        st = jnp.concatenate([st[:, :, 1:], feat[:, :, None]], axis=2)
        out = jnp.einsum("bnt,nt->bn", st, wt)  # (B, NF)
        out = out.reshape(b, units, rank).sum(axis=2)
        if bias is not None:
            out = out + bias
        act = op.params.get("activation", "relu")
        out = _apply_activation_f32(out, act)
        return [out, st.reshape(b, nf * t)]


# ---------------------------------------------------------------------------
# transformer micro-path ops
# ---------------------------------------------------------------------------

@register_op(OpCode.MATMUL)
class MatMul:
    """General (optionally batched) matmul with broadcastable batch dims
    and a transpose_b flag — the pod-model building block.
    """

    @staticmethod
    def prepare(ctx, op):
        a = ctx.tensor_spec(op.inputs[0])
        b = ctx.tensor_spec(op.inputs[1])
        tb = op.params.get("transpose_b", False)
        n = b.shape[-2] if tb else b.shape[-1]
        k_b = b.shape[-1] if tb else b.shape[-2]
        assert a.shape[-1] == k_b, f"matmul mismatch {a.shape} x {b.shape}"
        if len(b.shape) == 2:
            shape = a.shape[:-1] + (n,)
        else:
            batch = _broadcast_shape(a.shape[:-2], b.shape[:-2])
            shape = batch + (a.shape[-2], n)
        return PrepareResult([_spec(shape, a.dtype)])

    @staticmethod
    def eval(ctx, op, inputs):
        a, b = inputs
        if op.params.get("transpose_b", False):
            b = jnp.swapaxes(b, -1, -2)
        return [a @ b]


@register_op(OpCode.BATCH_MATMUL)
class BatchMatMul(MatMul):
    """Alias of MatMul: explicitly batched contraction, same prepare/eval."""

    pass


@register_op(OpCode.RMS_NORM)
class RMSNorm:
    """Root-mean-square normalization with learned gain, computed in
    float32 and cast back.
    """

    @staticmethod
    def prepare(ctx, op):
        x = ctx.tensor_spec(op.inputs[0])
        return PrepareResult([_spec(x.shape, x.dtype)])

    @staticmethod
    def eval(ctx, op, inputs):
        x, gamma = inputs
        eps = op.params.get("eps", 1e-6)
        ms = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                      keepdims=True)
        y = x * jax.lax.rsqrt(ms + eps).astype(x.dtype)
        return [y * gamma]


@register_op(OpCode.LAYER_NORM)
class LayerNorm:
    """Layer normalization with learned gain and bias, computed in
    float32 and cast back.
    """

    @staticmethod
    def prepare(ctx, op):
        x = ctx.tensor_spec(op.inputs[0])
        return PrepareResult([_spec(x.shape, x.dtype)])

    @staticmethod
    def eval(ctx, op, inputs):
        x, gamma, beta = inputs
        eps = op.params.get("eps", 1e-5)
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
        return [y * gamma + beta]


@register_op(OpCode.ROPE)
class RoPE:
    """Rotary position embedding over (B, S, H, D) activations."""

    @staticmethod
    def prepare(ctx, op):
        x = ctx.tensor_spec(op.inputs[0])        # (B, S, H, D)
        return PrepareResult([_spec(x.shape, x.dtype)])

    @staticmethod
    def eval(ctx, op, inputs):
        (x,) = inputs
        base = op.params.get("base", 10000.0)
        b, s, h, dim = x.shape
        half = dim // 2
        pos = jnp.arange(s, dtype=jnp.float32)[:, None]
        inv = base ** (-jnp.arange(half, dtype=jnp.float32) / half)
        ang = pos * inv                            # (S, half)
        cos = jnp.cos(ang)[None, :, None, :].astype(x.dtype)
        sin = jnp.sin(ang)[None, :, None, :].astype(x.dtype)
        x1, x2 = x[..., :half], x[..., half:]
        return [jnp.concatenate(
            [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)]


@register_op(OpCode.ATTENTION)
class Attention:
    """Fused SDPA for the micro path: q,k,v (B, H, S, D) -> (B, H, S, D)."""

    @staticmethod
    def prepare(ctx, op):
        q = ctx.tensor_spec(op.inputs[0])
        return PrepareResult([_spec(q.shape, q.dtype)],
                             scratch_nbytes=[q.shape[1] * q.shape[2] ** 2 * 4])

    @staticmethod
    def eval(ctx, op, inputs):
        q, k, v = inputs
        causal = op.params.get("causal", True)
        scale = 1.0 / math.sqrt(q.shape[-1])
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * jnp.asarray(
            scale, q.dtype)
        if causal:
            s = q.shape[2]
            mask = jnp.tril(jnp.ones((s, s), bool))
            logits = jnp.where(mask, logits,
                               jnp.asarray(-1e30, logits.dtype))
        w = jax.nn.softmax(logits.astype(jnp.float32), axis=-1
                           ).astype(q.dtype)
        return [jnp.einsum("bhqk,bhkd->bhqd", w, v)]


@register_op(OpCode.EMBEDDING_LOOKUP)
class EmbeddingLookup:
    """Row gather from an embedding table: (ids) -> (ids.shape, d_model)."""

    @staticmethod
    def prepare(ctx, op):
        ids = ctx.tensor_spec(op.inputs[0])
        table = ctx.tensor_spec(op.inputs[1])
        return PrepareResult([_spec(ids.shape + (table.shape[1],),
                                    table.dtype)])

    @staticmethod
    def eval(ctx, op, inputs):
        ids, table = inputs
        return [jnp.take(table, ids, axis=0)]
