"""repro.core — the paper's contribution: TF-Micro-style interpreter,
arena, memory planner, op resolver, quantization, and export toolchain."""

from . import micro_ops  # registers the reference kernels on import
from . import quantize  # keep the module visible as repro.core.quantize
from .arena import ArenaOverflowError, TwoStackArena
from .costmodel import (BlockCost, BlockSolveResult, BucketCost,
                        CalibrationProfile, ChunkCost, DecodeCost,
                        EngineMeasurer, LaneCost, LaneSolveResult,
                        MicroMeasurer, ReplicaCost, ReplicaSolveResult,
                        SolveResult, calibrate, load_cached_profile,
                        profile_cache_path, profile_model_key,
                        save_cached_profile, solve, solve_block_size,
                        solve_lanes, solve_replicas)
from .exporter import export, fold_constants, strip_training_ops
from .exporter import quantize as quantize_graph
from .executor import (AllocationPlan, ArenaPool, BucketTable,
                       CompiledPlan, InterpreterPool, LaneCheckpoint,
                       LaneState, PagedKVPool,
                       RaggedInterpreterPool, SharedArenaState,
                       jit_cache_size)
from .graph_builder import GraphBuilder
from .interpreter import MicroInterpreter
from .memory_planner import (BufferRequest, GreedyMemoryPlanner,
                             LinearMemoryPlanner, MemoryPlan,
                             OfflineMemoryPlanner)
from .profiler import (CompileStepTiming, MicroProfiler, ProfileReport,
                       measure_compile_and_step)
from .op_resolver import (AllOpsResolver, MicroMutableOpResolver,
                          OpResolutionError, register_op)
from .schema import (MicroModel, OpCode, QuantParams, TensorDef,
                     TensorFlags, model_to_source, serialize_model)

__all__ = [
    "ArenaOverflowError", "TwoStackArena", "export", "fold_constants",
    "quantize", "quantize_graph", "strip_training_ops", "GraphBuilder",
    "MicroInterpreter", "AllocationPlan", "ArenaPool", "BucketTable",
    "CompiledPlan", "InterpreterPool", "LaneCheckpoint",
    "LaneState",
    "PagedKVPool", "RaggedInterpreterPool", "jit_cache_size",
    "SharedArenaState", "BufferRequest", "GreedyMemoryPlanner",
    "LinearMemoryPlanner", "MemoryPlan", "OfflineMemoryPlanner",
    "AllOpsResolver", "MicroMutableOpResolver", "OpResolutionError",
    "register_op", "MicroProfiler", "ProfileReport", "MicroModel", "OpCode", "QuantParams", "TensorDef",
    "TensorFlags", "model_to_source", "serialize_model",
    "BucketCost", "CalibrationProfile", "ChunkCost", "EngineMeasurer",
    "SolveResult", "calibrate", "profile_model_key", "solve",
    "BlockCost", "BlockSolveResult", "DecodeCost", "solve_block_size",
    "LaneCost", "LaneSolveResult", "MicroMeasurer", "ReplicaCost",
    "ReplicaSolveResult", "solve_lanes", "solve_replicas",
    "load_cached_profile", "profile_cache_path", "save_cached_profile",
    "CompileStepTiming", "measure_compile_and_step",
]
