"""INT8 quantization, bit-faithful to TFLite / TF Micro (paper §3.3).

Scheme (Krishnamoorthi 2018, as adopted by TFLite):

* activations: asymmetric per-tensor int8, real = scale * (q - zero_point)
* weights:     symmetric per-channel int8 (zero_point == 0)
* bias:        int32 with scale = input_scale * weight_scale
* requantization of int32 accumulators back to int8 uses a fixed-point
  multiplier: the real multiplier M = s_in * s_w / s_out is decomposed as
  M = M0 * 2^shift with M0 in [0.5, 1) stored as a Q31 int32, applied with
  gemmlowp's SaturatingRoundingDoublingHighMul + rounding right shift.

The jnp implementations run inside jitted kernels; numpy twins are used at
export time.  A property test asserts the fixed-point path matches float
scaling within 1 LSB.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

INT8_MIN, INT8_MAX = -128, 127
INT4_MIN, INT4_MAX = -8, 7
INT32_MIN, INT32_MAX = -(2 ** 31), 2 ** 31 - 1


def x64_scope():
    """Scoped x64 mode for the bit-exact gemmlowp integer math.

    The SaturatingRoundingDoublingHighMul requires a true 64-bit product.
    We scope x64 to the quantized trace only — the rest of the framework
    (models, dry-run) stays in default 32-bit mode so float literals do not
    silently widen.  TPU-native Pallas kernels instead requantize via f32
    scaling (see kernels/quant_matmul.py) because the MXU int8 pipeline has
    no 64-bit scalar path — a documented hardware adaptation.

    ``jax.enable_x64`` was removed from the top-level namespace in
    jax 0.4.x; the supported spelling is the context manager in
    ``jax.experimental``.
    """
    from jax.experimental import enable_x64
    return enable_x64(True)


# ---------------------------------------------------------------------------
# Scale / zero-point selection
# ---------------------------------------------------------------------------

def choose_quant_params(rmin: float, rmax: float,
                        narrow_range: bool = False) -> Tuple[float, int]:
    """Asymmetric int8 params covering [rmin, rmax] (must straddle 0)."""
    rmin, rmax = float(min(rmin, 0.0)), float(max(rmax, 0.0))
    qmin = INT8_MIN + (1 if narrow_range else 0)
    qmax = INT8_MAX
    if rmax == rmin:
        return 1.0, 0
    scale = (rmax - rmin) / (qmax - qmin)
    zp_real = qmin - rmin / scale
    zero_point = int(np.clip(round(zp_real), qmin, qmax))
    return scale, zero_point


def choose_symmetric_scale(data: np.ndarray) -> float:
    amax = float(np.max(np.abs(data))) if data.size else 0.0
    return (amax / INT8_MAX) if amax > 0 else 1.0


def quantize_array(data: np.ndarray, scale: float, zero_point: int,
                   dtype=np.int8) -> np.ndarray:
    q = np.round(data / scale) + zero_point
    info = np.iinfo(dtype)
    return np.clip(q, info.min, info.max).astype(dtype)


def dequantize_array(q: np.ndarray, scale: float, zero_point: int
                     ) -> np.ndarray:
    return (q.astype(np.float32) - zero_point) * np.float32(scale)


def quantize_weights_per_channel(
        w: np.ndarray, axis: int) -> Tuple[np.ndarray, np.ndarray]:
    """Symmetric per-channel int8 weights; returns (q, scales[C])."""
    moved = np.moveaxis(w, axis, 0)
    flat = moved.reshape(moved.shape[0], -1)
    amax = np.max(np.abs(flat), axis=1)
    scales = np.where(amax > 0, amax / INT8_MAX, 1.0).astype(np.float32)
    q = np.clip(np.round(flat / scales[:, None]), INT8_MIN, INT8_MAX)
    q = q.astype(np.int8).reshape(moved.shape)
    return np.moveaxis(q, 0, axis), scales


def quantize_bias(b: np.ndarray, input_scale: float,
                  weight_scales: np.ndarray) -> np.ndarray:
    s = np.asarray(input_scale, np.float64) * np.asarray(weight_scales,
                                                         np.float64)
    q = np.round(b.astype(np.float64) / s)
    return np.clip(q, INT32_MIN, INT32_MAX).astype(np.int32)


# ---------------------------------------------------------------------------
# Fixed-point requantization (gemmlowp semantics, as in TFLM)
# ---------------------------------------------------------------------------

def quantize_multiplier(real_multiplier: float) -> Tuple[int, int]:
    """Decompose M = M0 * 2^shift, M0 Q31 in [2^30, 2^31)."""
    if real_multiplier == 0.0:
        return 0, 0
    if real_multiplier < 0:
        raise ValueError("negative requant multiplier")
    m, shift = math.frexp(real_multiplier)     # m in [0.5, 1)
    q = int(round(m * (1 << 31)))
    if q == (1 << 31):                          # rounding overflow
        q //= 2
        shift += 1
    if shift < -31:                             # underflow to zero
        return 0, 0
    if shift > 30:
        raise ValueError(f"requant multiplier too large: {real_multiplier}")
    return q, shift


def _srdhm_np(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """SaturatingRoundingDoublingHighMul, numpy int64 emulation."""
    a = a.astype(np.int64)
    b = np.asarray(b, np.int64)
    overflow = np.logical_and(a == INT32_MIN, b == INT32_MIN)
    ab = a * b
    nudge = np.where(ab >= 0, (1 << 30), 1 - (1 << 30))
    q = ab + nudge
    # gemmlowp divides by 2^31 with C++ semantics (truncation toward
    # zero) — an arithmetic shift floors and is 1 off for negative odd
    # halves (found by hypothesis: acc=-1, M=0.75)
    result = np.sign(q) * (np.abs(q) >> 31)
    return np.where(overflow, INT32_MAX, result).astype(np.int32)


def _rdpot_np(x: np.ndarray, exponent: np.ndarray) -> np.ndarray:
    """RoundingDivideByPOT (round-half-away-from-zero), numpy."""
    x = x.astype(np.int64)
    exponent = np.asarray(exponent, np.int64)
    mask = (np.int64(1) << exponent) - 1
    remainder = x & mask
    threshold = (mask >> 1) + np.where(x < 0, 1, 0)
    return ((x >> exponent) + np.where(remainder > threshold, 1, 0)
            ).astype(np.int32)


def multiply_by_quantized_multiplier_np(x: np.ndarray, multiplier,
                                        shift) -> np.ndarray:
    """TFLM MultiplyByQuantizedMultiplier: x * M0 * 2^shift (numpy).

    ``multiplier``/``shift`` may be scalars or per-channel arrays that
    broadcast against ``x``.  The left shift happens in int32 (C wrapping
    semantics), exactly like the TFLM reference kernels.
    """
    shift = np.asarray(shift, np.int64)
    left = np.maximum(shift, 0)
    right = np.maximum(-shift, 0)
    xl = (x.astype(np.int64) << left).astype(np.int32)
    return _rdpot_np(_srdhm_np(xl, np.asarray(multiplier, np.int32)), right)


def _srdhm_jnp(a, b):
    a64 = a.astype(jnp.int64)
    b64 = jnp.asarray(b, jnp.int64)
    ab = a64 * b64
    nudge = jnp.where(ab >= 0, 1 << 30, 1 - (1 << 30))
    q = ab + nudge
    # truncate toward zero (gemmlowp C++ division), not floor
    result = jnp.sign(q) * (jnp.abs(q) >> 31)
    overflow = jnp.logical_and(a64 == INT32_MIN, b64 == INT32_MIN)
    return jnp.where(overflow, INT32_MAX, result).astype(jnp.int32)


def _rdpot_jnp(x, exponent):
    x64 = x.astype(jnp.int64)
    e = jnp.asarray(exponent, jnp.int64)
    mask = (jnp.int64(1) << e) - 1
    remainder = x64 & mask
    threshold = (mask >> 1) + jnp.where(x64 < 0, 1, 0)
    return ((x64 >> e) + jnp.where(remainder > threshold, 1, 0)
            ).astype(jnp.int32)


def multiply_by_quantized_multiplier(x, multiplier, shift):
    """jnp twin of the fixed-point requant (traceable).

    Matches the numpy twin bit-for-bit; ``multiplier``/``shift`` broadcast
    (scalar per-tensor or [C] per-channel).
    """
    shift = jnp.asarray(shift, jnp.int64)
    left = jnp.maximum(shift, 0)
    right = jnp.maximum(-shift, 0)
    xl = (x.astype(jnp.int64) << left).astype(jnp.int32)
    return _rdpot_jnp(_srdhm_jnp(xl, jnp.asarray(multiplier, jnp.int32)),
                      right)


def requantize(acc, multiplier, shift, output_zero_point,
               qmin: int = INT8_MIN, qmax: int = INT8_MAX):
    """int32 accumulator -> int8 output, TFLM semantics (jnp)."""
    scaled = multiply_by_quantized_multiplier(acc, multiplier, shift)
    out = scaled + output_zero_point
    return jnp.clip(out, qmin, qmax).astype(jnp.int8)


def requantize_np(acc: np.ndarray, multiplier: int, shift: int,
                  output_zero_point: int) -> np.ndarray:
    scaled = multiply_by_quantized_multiplier_np(acc, multiplier, shift)
    return np.clip(scaled + output_zero_point, INT8_MIN, INT8_MAX
                   ).astype(np.int8)


# ---------------------------------------------------------------------------
# Packed int4 (two nibbles per int8 byte, packed along the LAST axis)
# ---------------------------------------------------------------------------

def pack_int4(q) -> jnp.ndarray:
    """Pack signed int4 values (range [-8, 7], held in an int8 array)
    into bytes, two per byte along the LAST axis: ``byte = (hi << 4) |
    (lo & 0xF)`` with ``lo = q[..., 2i]`` and ``hi = q[..., 2i+1]``.
    The last axis must be even — padding is the caller's job, so the
    unpacked shape stays recoverable without a side channel."""
    q = jnp.asarray(q, jnp.int8)
    if q.shape[-1] % 2:
        raise ValueError(
            f"pack_int4 needs an even last axis, got {q.shape}")
    lo = q[..., 0::2]
    hi = q[..., 1::2]
    return ((hi.astype(jnp.int8) << 4)
            | (lo.astype(jnp.int8) & jnp.int8(0xF))).astype(jnp.int8)


def unpack_int4(packed) -> jnp.ndarray:
    """Inverse of :func:`pack_int4`: bytes back to signed int4 values
    (as int8), doubling the last axis.  Sign extension is arithmetic —
    ``(b << 4) >> 4`` recovers the low nibble, ``b >> 4`` the high —
    so the round-trip is exact for every value in [-8, 7]."""
    b = jnp.asarray(packed, jnp.int8)
    lo = (b << 4) >> 4                          # arithmetic shifts: int8
    hi = b >> 4
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*b.shape[:-1], b.shape[-1] * 2)


def pack_int4_np(q: np.ndarray) -> np.ndarray:
    """numpy twin of :func:`pack_int4` (export-time use)."""
    q = np.asarray(q, np.int8)
    if q.shape[-1] % 2:
        raise ValueError(
            f"pack_int4 needs an even last axis, got {q.shape}")
    lo = q[..., 0::2]
    hi = q[..., 1::2]
    return ((hi.astype(np.int8) << 4)
            | (lo.astype(np.int8) & np.int8(0xF))).astype(np.int8)


def unpack_int4_np(packed: np.ndarray) -> np.ndarray:
    """numpy twin of :func:`unpack_int4`."""
    b = np.asarray(packed, np.int8)
    lo = ((b << 4) >> 4).astype(np.int8)
    hi = (b >> 4).astype(np.int8)
    out = np.stack([lo, hi], axis=-1)
    return out.reshape(*b.shape[:-1], b.shape[-1] * 2)


# ---------------------------------------------------------------------------
# Symmetric per-head KV quantization (serving KV cache, docs/QUANTIZATION.md)
# ---------------------------------------------------------------------------

def quantize_kv_heads(x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric int8 quantization of a KV vector batch with one scale
    per head vector: the LAST axis is the head dim, every leading axis
    (layer, batch/page, head, position) keeps its own scale.  Returns
    ``(q int8, scales f32)`` with ``scales.shape == x.shape[:-1]``.
    All-zero vectors get scale 1.0 so dequant is exact (zeros)."""
    x = jnp.asarray(x, jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=-1)
    scales = jnp.where(amax > 0, amax / INT8_MAX, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scales[..., None]), INT8_MIN, INT8_MAX)
    return q.astype(jnp.int8), scales


def dequantize_kv_heads(q, scales) -> jnp.ndarray:
    """Inverse of :func:`quantize_kv_heads` (up to rounding)."""
    return q.astype(jnp.float32) * scales[..., None].astype(jnp.float32)


# ---------------------------------------------------------------------------
# Convenience record used by op prepare() functions
# ---------------------------------------------------------------------------

@dataclass
class RequantSpec:
    """Precomputed per-op requantization constants (persistent-arena data
    in TFLM: computed once at prepare time, paper §4.1)."""
    multiplier: np.ndarray      # int32, scalar or per-channel [C]
    shift: np.ndarray           # int32, scalar or per-channel [C]
    input_zero_point: int
    output_zero_point: int
    input_scale: float
    output_scale: float

    @staticmethod
    def build(input_scale: float, weight_scales: Union[float, np.ndarray],
              output_scale: float, input_zp: int, output_zp: int
              ) -> "RequantSpec":
        ws = np.atleast_1d(np.asarray(weight_scales, np.float64))
        mults, shifts = [], []
        for s in ws:
            m, sh = quantize_multiplier(float(input_scale) * float(s)
                                        / float(output_scale))
            mults.append(m)
            shifts.append(sh)
        return RequantSpec(
            multiplier=np.asarray(mults, np.int32),
            shift=np.asarray(shifts, np.int32),
            input_zero_point=int(input_zp),
            output_zero_point=int(output_zp),
            input_scale=float(input_scale),
            output_scale=float(output_scale),
        )

    def nbytes(self) -> int:
        return int(self.multiplier.nbytes + self.shift.nbytes + 16)
