"""Shared compile-once/execute-many execution layer (paper §4.1–4.2).

The paper's core discipline — pay ALL planning cost at init so that
steady-state invoke is pure dispatch — used to live fused inside
``MicroInterpreter``.  This module factors it into a three-phase
pipeline every execution surface (single-shot interpreter, batched
pool, pod-scale serving) builds on:

  1. **AllocationPlan** (plan): walk the op list once, run each
     kernel's prepare(), derive tensor lifetimes, bin-pack the
     nonpersistent arena section with the memory planner, and freeze
     the two-stack arena.  Nothing may allocate after this phase.

  2. **CompiledPlan** (compile): the arena read/bitcast/dispatch/write
     loop over the topologically sorted op list, traced ONCE into a
     jitted program with a donated arena buffer.  The same traced body
     is reused for **batched invoke**: ``jax.vmap`` over a leading
     batch axis turns one dispatch into B independent requests —
     consts broadcast, arena buffers and variable tensors carry the
     batch axis.

  3. **dispatch**: ``MicroInterpreter`` (a thin facade preserving the
     paper's application API) or ``InterpreterPool`` (batch-granularity
     serving) feed inputs in and read outputs back; per-invoke work is
     one jitted call.

**Arena pooling.**  ``ArenaPool`` generalizes the shared-arena idea of
§4.5: it owns the physical nonpersistent byte buffers — one single
buffer plus a small free list of stacked ``(B, nbytes)`` buffers per
batch size — and recycles them across invocations.  Because the jitted
programs donate their arena argument, steady state reuses the same
device memory every step: the pool allocates during warm-up only
(``alloc_count`` makes that observable and testable).  The free list is
``depth`` deep (default 2), which is the donation-aware double-buffer
contract: while wave N's donated dispatch is still computing on device,
wave N+1 can take the second buffer and stage its host inputs, so
host→device input staging overlaps device compute.

**Ragged dispatch.**  ``InterpreterPool`` advances B identical lockstep
lanes; ``RaggedInterpreterPool`` removes the lockstep restriction.  A
*lane table* (``LaneState`` rows: model-family bucket, per-request step
counter, active flag) drives one masked/vmapped dispatch per bucket:
lanes of the same bucket share one AllocationPlan/CompiledPlan, lanes
of different buckets run different models, and every lane carries its
own continuation state (variable tensors, step count).  Admission and
retirement happen between dispatches by flipping the active mask — the
mask is a *traced argument* of the masked program, so occupancy changes
never recompile.

Compile-once invariants (what the rest of the repo may rely on):

  * **traced once** — the arena read/bitcast/dispatch/write loop over
    the op list, per (batch size, exact/vmap, masked/unmasked) key.
    Tensor shapes, arena offsets, op_data, and the op list itself are
    baked in at trace time and can never change afterwards.
  * **donated** — the arena byte buffer(s) and the variable-tensor
    stack.  Steady state hands the same device memory back every step;
    the host must never hold a reference to a donated input after
    dispatch.
  * **may vary per call** — input values, variable *values*, and (for
    masked programs) the active-lane mask.  Everything else varying
    forces a retrace, which the freeze()-at-init discipline forbids.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize as Q
from .arena import TwoStackArena, align_up
from .memory_planner import MemoryPlan, plan_nonpersistent, select_planner
from .op_resolver import MicroMutableOpResolver, TensorSpec
from .schema import MicroModel, QuantParams

# TFLM persistent-arena runtime records (TfLiteTensor ≈ 64 B, node ≈ 48 B);
# we account the same way so Table-2 numbers are comparable.
TENSOR_RUNTIME_NBYTES = 64
NODE_RUNTIME_NBYTES = 48


def _itemsize(dtype: str) -> int:
    return 2 if dtype == "bfloat16" else np.dtype(dtype).itemsize


def _spec_nbytes(spec: TensorSpec) -> int:
    n = 1
    for d in spec.shape:
        n *= int(d)
    return n * _itemsize(spec.dtype)


def _jnp_dtype(name: str):
    return jnp.bfloat16 if name == "bfloat16" else jnp.dtype(name)


# ---------------------------------------------------------------------------
# length bucketing (compile-once across ragged sizes)
# ---------------------------------------------------------------------------

class BucketTable:
    """Size quantization shared by every surface that must not retrace
    on ragged sizes.

    ``bucket(n)`` maps a size to the smallest table *level* that holds
    it, so the set of distinct traced shapes is O(#levels) instead of
    O(#sizes).  The level layout comes from one of two places:

      * **geometric** (the default): levels are ``min_bucket``
        multiplied by ``granularity`` (default 2 — power-of-two
        buckets) until ``max_bucket``, the hand-picked layout every
        engine falls back to when no calibration profile exists;
      * **explicit** (``from_levels`` / ``from_spec``): an arbitrary
        ascending level list — what the calibration cost model
        (``repro.core.costmodel``) solves for from MEASURED per-bucket
        compile and step costs, persisted in a ``CalibrationProfile``.

    Two consumers share one table:

      * **bucketed prefill** — ``ServingEngine`` pads each prompt to its
        bucket and compiles the prefill step once per *bucket* instead
        of once per *length* (see docs/SCHEDULING.md for why padded
        rows cannot leak into decoded tokens);
      * **ragged lanes** — ``RaggedInterpreterPool.add_bucket`` can
        quantize lane counts through the same table so model buckets
        with nearby lane counts draw the same stacked ``(B, nbytes)``
        buffers from the shared ``ArenaPool`` free lists.

    ``hits`` counts how many times each bucket was actually chosen by
    ``bucket()`` — the observability hook the arrival-process benchmark
    and the no-retrace tests read.  Callers that may still reject the
    bucket (e.g. it does not fit their cache) probe with ``fit()``
    first, so a fallback never records a phantom bucket.  A size above
    ``max_bucket`` raises ``ValueError`` from ``bucket()``: capacity
    errors stay loud and immediate, like arena overflow.
    """

    def __init__(self, min_bucket: int = 16, max_bucket: int = 4096,
                 granularity: int = 2,
                 levels: Optional[Sequence[int]] = None):
        if levels is not None:
            if (min_bucket, max_bucket, granularity) != (16, 4096, 2):
                raise ValueError(
                    "pass either explicit levels or the geometric "
                    "(min_bucket, max_bucket, granularity) "
                    "parameters, not both — levels fully determine "
                    "the table")
            lv = [int(x) for x in levels]
            if not lv or sorted(set(lv)) != lv or lv[0] < 1:
                raise ValueError(
                    f"levels must be a non-empty strictly ascending "
                    f"sequence of positive ints, got {levels!r}")
        else:
            if min_bucket < 1 or max_bucket < min_bucket:
                raise ValueError((min_bucket, max_bucket))
            if granularity < 2 or int(granularity) != granularity:
                raise ValueError(
                    f"granularity must be an integer >= 2, got "
                    f"{granularity!r}")
            lv, b = [], int(min_bucket)
            while b <= max_bucket:
                lv.append(b)
                b *= int(granularity)
        self.levels: List[int] = lv
        self.min_bucket = lv[0]
        self.max_bucket = lv[-1]
        self.hits: Dict[int, int] = {}

    @classmethod
    def from_levels(cls, levels: Sequence[int]) -> "BucketTable":
        """A table with exactly these ascending levels — the layout a
        calibration profile's solver emits."""
        return cls(levels=levels)

    def spec(self) -> Dict[str, Any]:
        """JSON-serializable layout (``from_spec`` round-trips it
        bit-identically) — how a ``CalibrationProfile`` persists the
        solved table."""
        return {"levels": list(self.levels)}

    @classmethod
    def from_spec(cls, spec: Dict[str, Any]) -> "BucketTable":
        """Rebuild a table from ``spec()`` output (e.g. loaded from a
        calibration profile JSON)."""
        return cls(levels=spec["levels"])

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, BucketTable):
            return NotImplemented
        return self.levels == other.levels

    def __hash__(self) -> int:
        # levels are fixed at construction (only `hits` mutates), so
        # hashing by layout keeps tables usable as dict/set members
        # consistently with the layout equality above
        return hash(tuple(self.levels))

    def __repr__(self) -> str:
        return f"BucketTable(levels={self.levels})"

    def fit(self, n: int) -> Optional[int]:
        """Smallest table bucket holding ``n``, or None when ``n``
        exceeds ``max_bucket`` — records nothing."""
        if n < 1:
            raise ValueError(f"size must be >= 1, got {n}")
        for b in self.levels:
            if b >= n:
                return b
        return None

    def bucket(self, n: int) -> int:
        """Smallest table bucket holding ``n`` (and count the hit)."""
        b = self.fit(n)
        if b is None:
            raise ValueError(
                f"size {n} exceeds max_bucket {self.max_bucket}")
        self.hits[b] = self.hits.get(b, 0) + 1
        return b

    def buckets(self) -> List[int]:
        """Buckets hit so far, ascending — the table's live layout."""
        return sorted(self.hits)


# ---------------------------------------------------------------------------
# paged KV block accounting (compile-once across slot growth/shrink)
# ---------------------------------------------------------------------------

class PagedKVPool:
    """Host-side allocator for a pool of fixed-size physical KV blocks
    — the paged-KV analogue of ``ArenaPool``'s shared physical buffers
    (docs/ARCHITECTURE.md §8).

    The device arrays live elsewhere (the serving engine owns one
    ``(L, n_blocks, KH, block_size, dh)`` pool per K/V); this class
    owns only the *accounting*: which physical blocks are free, which
    are mapped into some slot's block table, and how many are
    **reserved** for admitted requests that have not grown into them
    yet.  The two-phase reserve/map split is what keeps mid-decode
    growth infallible: admission calls ``reserve(n)`` for the worst
    case the request can reach (prompt + decode budget, capped at the
    logical capacity), and every later ``map_block()`` debits that
    reservation — so once a request is admitted, its decode loop can
    never die of pool exhaustion, and admission control is a single
    ``can_reserve`` check.

    Block 0 is the **garbage sink**: it is never handed out, and every
    unmapped block-table entry points at it, so the jitted decode
    step's unconditional ring write for inactive/stale slots lands in
    a block nothing reads (the paged analogue of the masked pool's
    harmless masked-lane dispatch).  ``alloc_count`` counts map events
    for the no-allocation-after-warmup observability the arena pool
    established."""

    GARBAGE_BLOCK = 0

    def __init__(self, n_blocks: int, block_size: int):
        if n_blocks < 2:
            raise ValueError(
                f"need >= 2 physical blocks (one is the garbage "
                f"sink), got {n_blocks}")
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        # LIFO free list, block 0 (garbage) excluded; popping yields
        # ascending ids first for deterministic layouts in tests
        self._free: List[int] = list(range(self.n_blocks - 1, 0, -1))
        self._reserved = 0
        self.alloc_count = 0

    @property
    def usable_blocks(self) -> int:
        """Physical blocks that can ever be mapped (garbage excluded)."""
        return self.n_blocks - 1

    def free_blocks(self) -> int:
        """Blocks neither mapped nor promised to a reservation."""
        return len(self._free) - self._reserved

    def reserved_blocks(self) -> int:
        """Outstanding (reserved but not yet mapped) block count."""
        return self._reserved

    def can_reserve(self, n: int) -> bool:
        """Whether ``n`` more blocks can be promised right now — THE
        admission-control predicate."""
        return int(n) <= self.free_blocks()

    def reserve(self, n: int) -> None:
        """Promise ``n`` blocks to an admitted request.  Raises when
        the promise cannot be kept — callers gate on ``can_reserve``,
        so a failure here is an accounting bug, not load."""
        n = int(n)
        if n < 0:
            raise ValueError(f"cannot reserve {n} blocks")
        if not self.can_reserve(n):
            raise RuntimeError(
                f"reserve({n}): only {self.free_blocks()} of "
                f"{self.usable_blocks} usable blocks are unpromised")
        self._reserved += n

    def map_block(self) -> int:
        """Hand out one physical block against an existing reservation
        (infallible by the reserve/map contract).  Returns its id."""
        if self._reserved < 1:
            raise RuntimeError(
                "map_block() without a reservation — admission must "
                "reserve() the request's worst-case block count first")
        self._reserved -= 1
        self.alloc_count += 1
        return self._free.pop()

    def release(self, blocks: Sequence[int], *, reserved: int = 0) -> None:
        """Return mapped ``blocks`` to the free list and cancel
        ``reserved`` unused promises (a finished request rarely grew
        into its full worst case)."""
        reserved = int(reserved)
        if reserved < 0 or reserved > self._reserved:
            raise ValueError(
                f"release: {reserved} reserved vs {self._reserved} "
                f"outstanding")
        for b in blocks:
            b = int(b)
            if b == self.GARBAGE_BLOCK or not (0 < b < self.n_blocks):
                raise ValueError(f"release of invalid block id {b}")
            if b in self._free:
                raise ValueError(f"double release of block {b}")
            self._free.append(b)
        self._reserved -= reserved
        if len(self._free) > self.usable_blocks:
            raise RuntimeError("pool accounting corrupted")


def jit_cache_size(fn) -> int:
    """How many distinct programs a ``jax.jit``-wrapped callable has
    traced — THE trace-count hook behind every no-retrace assertion
    (tests) and compile-count benchmark row.  One entry per distinct
    (shape, dtype) signature seen, so a compile-once contract reads as
    ``jit_cache_size(fn) == 1`` no matter how many calls were made."""
    return fn._cache_size()


def pin_tree(tree, shardings):
    """Re-commit ``tree`` to ``shardings`` (a matching NamedSharding
    pytree, or one sharding for every leaf) — the placement leg of the
    compile-once contract on a mesh.

    The jit cache keys on input SHARDINGS as well as shapes: an
    eagerly-updated operand (a host-side ``.at[].set`` on a KV cache,
    a block-table row write) whose placement drifts from what the
    compiled step saw would silently retrace it.  Pinning after every
    eager mutation makes placement an init-time constant like shapes
    are — ``jax.device_put`` onto the sharding an array already has is
    a no-op, so the steady state pays nothing.  ``shardings=None`` is
    the single-device engine: identity."""
    if shardings is None:
        return tree
    return jax.device_put(tree, shardings)


@dataclass
class InflightStep:
    """One dispatched-but-unread device step — the deferred-readback
    record behind the async serving loop (docs/STREAMING.md).

    JAX dispatch is asynchronous: a jitted step returns device arrays
    that are *futures*, and only a host transfer (``np.asarray``)
    blocks on them.  An ``InflightStep`` pins everything the host will
    need to interpret those futures LATER — the token array still on
    device and a snapshot of which (slot, result, request) triples the
    step was dispatched for — so the host can dispatch step ``i+1``
    and then do step ``i``'s bookkeeping while the device computes.
    The snapshot matters: slot bookkeeping may change between dispatch
    and readback (a slot retires, a new request is admitted), and the
    tokens belong to the slots *as they were at dispatch*.

    ``host_fetch`` is the single blocking point: it materializes the
    tokens on host, at which moment the step is no longer in flight."""

    tokens: Any                 # device int32 tokens, one per slot (future)
    slots: List[Tuple[int, Any, Any]]   # (slot, result, request) at dispatch
    dispatch_s: float = 0.0     # host-side dispatch cost (for timings)

    def host_fetch(self) -> np.ndarray:
        """Block until the step's tokens are on host (the deferred
        ``jax.block_until_ready``) and return them as an np array."""
        return np.asarray(self.tokens)


# ---------------------------------------------------------------------------
# contexts handed to kernel prepare()/eval() (the TFLM C-API analogue)
# ---------------------------------------------------------------------------

class PrepareContext:
    """Init-phase context handed to each kernel's ``prepare()`` — the
    analogue of TFLM's ``TfLiteContext`` during AllocateTensors: tensor
    specs, quantization params, and const values, read-only."""

    def __init__(self, model: MicroModel, specs: List[TensorSpec]):
        self._model = model
        self._specs = specs

    def tensor_spec(self, idx: int) -> TensorSpec:
        return self._specs[idx]

    def quant(self, idx: int) -> QuantParams:
        return self._model.tensor(idx).quant

    def const_value(self, idx: int) -> Optional[np.ndarray]:
        t = self._model.tensor(idx)
        return self._model.const_data(idx) if t.is_const else None

    def is_const(self, idx: int) -> bool:
        return self._model.tensor(idx).is_const


class EvalContext:
    """Invoke-phase context handed to each kernel's ``eval()``: the
    ``op_data`` its prepare() baked plus output specs/quant params.
    Everything here is fixed at init — eval runs inside the trace."""

    __slots__ = ("op_data", "_out_specs", "_out_quants")

    def __init__(self, op_data, out_specs, out_quants):
        self.op_data = op_data
        self._out_specs = out_specs
        self._out_quants = out_quants

    def output_shape(self, k: int) -> Tuple[int, ...]:
        return self._out_specs[k].shape

    def quant_of_output(self, k: int) -> QuantParams:
        return self._out_quants[k]


@dataclass
class OpPlan:
    """One prepared op: its definition, resolved kernel registration,
    prepare() result, and the EvalContext eval() will receive."""

    op: Any                               # schema.OpDef
    registration: Any                     # OpRegistration
    prep: Any                             # PrepareResult
    eval_ctx: EvalContext


# ---------------------------------------------------------------------------
# phase 1: AllocationPlan
# ---------------------------------------------------------------------------

class AllocationPlan:
    """Everything the init phase decides: prepared ops, tensor specs,
    frozen arena layout, and the memory plan.  Immutable after build()."""

    def __init__(self) -> None:
        self.model: MicroModel = None           # type: ignore[assignment]
        self.resolver: MicroMutableOpResolver = None  # type: ignore
        self.arena: TwoStackArena = None        # type: ignore[assignment]
        self.specs: List[TensorSpec] = []
        self.const_pos: Dict[int, int] = {}
        self.var_pos: Dict[int, int] = {}
        self.tensor_offset: Dict[int, int] = {}
        self.consts: List[jnp.ndarray] = []
        self.init_variables: List[jnp.ndarray] = []
        self.var_specs: List[TensorSpec] = []
        self.op_plans: List[OpPlan] = []
        self.plan: MemoryPlan = None            # type: ignore[assignment]
        self.scratch_bytes = 0
        self.planner_name = ""

    @classmethod
    def build(cls, model: MicroModel, resolver: MicroMutableOpResolver,
              arena: TwoStackArena, planner: Optional[object] = None,
              prefer_offline_plan: bool = True) -> "AllocationPlan":
        self = cls()
        self.model, self.resolver, self.arena = model, resolver, arena
        m = model

        # 0. initial specs from the serialized model
        for t in m.tensors:
            self.specs.append(TensorSpec(t.shape, t.dtype))

        # 1. persistent runtime records (tensor structs + node structs)
        arena.allocate_persistent(
            TENSOR_RUNTIME_NBYTES * len(m.tensors), "tensor_structs")
        arena.allocate_persistent(
            NODE_RUNTIME_NBYTES * len(m.operators), "node_structs")

        # 2. const tensors -> zero-copy views ("flash"); variables -> tail
        for i, t in enumerate(m.tensors):
            if t.is_const:
                self.const_pos[i] = len(self.consts)
                self.consts.append(jnp.asarray(m.const_data(i)))
            elif t.is_variable:
                self.var_pos[i] = len(self.init_variables)
                arena.allocate_persistent(t.nbytes, f"variable{i}")
                self.init_variables.append(
                    jnp.zeros(t.shape, _jnp_dtype(t.dtype)))
                self.var_specs.append(TensorSpec(t.shape, t.dtype))

        # 3. prepare each op in topological order
        pctx = PrepareContext(m, self.specs)
        scratch: Dict[int, List[int]] = {}
        for oi, op in enumerate(m.operators):
            reg = resolver.resolve(op.opcode)
            # planning-time temp (paper: the between-stack temp region)
            arena.allocate_temp(256)
            prep = reg.prepare(pctx, op)
            arena.reset_temp()
            if prep.persistent_nbytes:
                arena.allocate_persistent(
                    prep.persistent_nbytes, f"opdata{oi}")
            assert len(prep.output_specs) == len(op.outputs), \
                f"{reg.name}: prepare produced {len(prep.output_specs)} " \
                f"specs for {len(op.outputs)} outputs"
            for t, spec in zip(op.outputs, prep.output_specs):
                declared = self.specs[t]
                if tuple(declared.shape) != tuple(spec.shape):
                    raise ValueError(
                        f"op {oi} ({reg.name}): computed output shape "
                        f"{spec.shape} != serialized {declared.shape}")
                self.specs[t] = spec
            if prep.scratch_nbytes:
                scratch[oi] = list(prep.scratch_nbytes)
            out_quants = [m.tensor(t).quant for t in op.outputs]
            ectx = EvalContext(prep.op_data,
                               [self.specs[t] for t in op.outputs],
                               out_quants)
            self.op_plans.append(OpPlan(op, reg, prep, ectx))

        # 4. lifetimes + memory plan for the nonpersistent section
        planned_nbytes = {
            i: _spec_nbytes(self.specs[i])
            for i, t in enumerate(m.tensors)
            if not t.is_const and not t.is_variable}
        planner = select_planner(m.metadata, planner, prefer_offline_plan)
        self.planner_name = getattr(planner, "name", type(planner).__name__)
        self.plan, self.tensor_offset, self.scratch_bytes = \
            plan_nonpersistent(
                [op.inputs for op in m.operators],
                [op.outputs for op in m.operators],
                planned_nbytes, m.inputs, m.outputs, scratch, planner)

        # 5. reserve the planned section on the head stack and freeze
        arena.reserve_nonpersistent_section(
            self.plan.total_bytes + self.scratch_bytes)
        arena.freeze()
        return self

    @property
    def nonpersistent_nbytes(self) -> int:
        """Physical bytes the pooled arena buffer must provide."""
        return self.plan.total_bytes


def required_arena_size(model: MicroModel,
                        resolver: MicroMutableOpResolver,
                        slack: int = 1024) -> int:
    """Probe build on a throwaway oversized arena to size the real one."""
    probe = TwoStackArena(1 << 30)
    AllocationPlan.build(model, resolver, probe)
    return align_up(probe.usage().total + slack)


def plan_model(model: MicroModel, resolver: MicroMutableOpResolver,
               arena_size_bytes: Optional[int] = None,
               planner: Optional[object] = None,
               prefer_offline_plan: bool = True,
               host_arena: Optional[TwoStackArena] = None
               ) -> AllocationPlan:
    """Build an AllocationPlan in a fresh self-sized arena, or — when
    ``host_arena`` is given — as a tenant of a shared arena (§4.5):
    persistents stack under the host's, the nonpersistent head section
    is shared (fork, build, absorb)."""
    if host_arena is not None:
        arena = host_arena.fork_tenant()
    else:
        if arena_size_bytes is None:
            arena_size_bytes = required_arena_size(model, resolver)
        arena = TwoStackArena(arena_size_bytes)
    alloc = AllocationPlan.build(model, resolver, arena, planner,
                                 prefer_offline_plan)
    if host_arena is not None:
        host_arena.absorb_tenant(arena)
    return alloc


# ---------------------------------------------------------------------------
# phase 2: CompiledPlan
# ---------------------------------------------------------------------------

class CompiledPlan:
    """The traced invoke body over a frozen AllocationPlan.

    ``jitted`` runs one request per dispatch (arena buffer donated);
    ``batched(B)`` vmaps the identical body over a leading batch axis so
    one jitted program advances B independent requests — the per-invoke
    Python/dispatch overhead amortizes over the batch.
    """

    def __init__(self, alloc: AllocationPlan):
        self.alloc = alloc
        self.jitted = jax.jit(self.execute, donate_argnums=(0, 1))
        self._batched: Dict[int, Any] = {}

    # -- arena byte-view helpers (static offsets; traced inside invoke) --

    def _read(self, buf: jnp.ndarray, tid: int):
        spec = self.alloc.specs[tid]
        off = self.alloc.tensor_offset[tid]
        nbytes = _spec_nbytes(spec)
        raw = jax.lax.slice(buf, (off,), (off + nbytes,))
        dt = _jnp_dtype(spec.dtype)
        item = _itemsize(spec.dtype)
        if item == 1:
            return jax.lax.bitcast_convert_type(raw, dt).reshape(spec.shape)
        arr = jax.lax.bitcast_convert_type(
            raw.reshape(nbytes // item, item), dt)
        return arr.reshape(spec.shape)

    def _write(self, buf: jnp.ndarray, tid: int, value) -> jnp.ndarray:
        spec = self.alloc.specs[tid]
        off = self.alloc.tensor_offset[tid]
        dt = _jnp_dtype(spec.dtype)
        value = value.astype(dt).reshape(-1)
        item = _itemsize(spec.dtype)
        if item == 1:
            raw = jax.lax.bitcast_convert_type(value, jnp.uint8)
        else:
            raw = jax.lax.bitcast_convert_type(value, jnp.uint8).reshape(-1)
        return jax.lax.dynamic_update_slice(buf, raw, (off,))

    # -- the traced invoke body -----------------------------------------

    def execute(self, buf, variables, consts, inputs):
        alloc = self.alloc
        # write model inputs into their planned arena slots
        for pos, tid in enumerate(alloc.model.inputs):
            buf = self._write(buf, tid, inputs[pos])
        variables = list(variables)
        for opp in alloc.op_plans:
            op = opp.op
            in_arrays = []
            for t in op.inputs:
                if t < 0:
                    in_arrays.append(None)
                elif t in alloc.const_pos:
                    in_arrays.append(consts[alloc.const_pos[t]])
                elif t in alloc.var_pos:
                    in_arrays.append(variables[alloc.var_pos[t]])
                else:
                    in_arrays.append(self._read(buf, t))
            outs = opp.registration.eval(opp.eval_ctx, op, in_arrays)
            n_out = len(op.outputs)
            for t, o in zip(op.outputs, outs[:n_out]):
                buf = self._write(buf, t, o)
            for t, v in zip(opp.prep.variable_updates, outs[n_out:]):
                variables[alloc.var_pos[t]] = v
        # read the model outputs inside the traced program: the host
        # then receives small per-output arrays instead of slicing (or
        # copying) the whole arena per invoke
        model_outs = tuple(self._read(buf, t)
                           for t in alloc.model.outputs)
        return buf, tuple(variables), model_outs

    def batched(self, batch: int, exact: bool = False):
        """One jitted program advancing ``batch`` independent requests.

        Arena buffers (axis 0 of ``(B, nbytes)``), variable tensors, and
        model inputs carry the batch axis; consts broadcast — weights
        stay single-copy "flash" views shared by every lane.

        Two lowerings of the same traced body:

        * ``exact=False`` (default): ``jax.vmap`` over the leading batch
          axis — the throughput path.  Integer (int8) models stay
          bit-exact, but batched float reductions may be reassociated by
          the backend (e.g. CPU gemm vs gemv), so float outputs can
          differ from single invokes in the last ulps.
        * ``exact=True``: the per-lane body is unrolled ``batch`` times
          inside one program — bit-identical to N sequential single
          invokes for every dtype, at the cost of program size.
        """
        key = (batch, exact)
        fn = self._batched.get(key)
        if fn is None:
            fn = jax.jit(self._batched_body(batch, exact),
                         donate_argnums=(0, 1))
            self._batched[key] = fn
        return fn

    def _batched_body(self, batch: int, exact: bool):
        """The unjitted B-lane body shared by ``batched`` and
        ``masked_batched`` — vmapped (throughput) or unrolled (exact)."""
        if exact:
            def unrolled(bufs, variables, consts, inputs):
                lanes = [self.execute(
                    bufs[i], tuple(v[i] for v in variables), consts,
                    tuple(x[i] for x in inputs))
                    for i in range(batch)]
                bs, vs, os = zip(*lanes)
                return (jnp.stack(bs),
                        tuple(jnp.stack(z) for z in zip(*vs)),
                        tuple(jnp.stack(z) for z in zip(*os)))
            return unrolled
        return jax.vmap(self.execute, in_axes=(0, 0, None, 0))

    def masked_batched(self, batch: int, exact: bool = False):
        """The ragged lowering: ``batched(batch)`` plus an active-lane
        mask argument.

        Signature: ``(bufs, variables, consts, inputs, mask) -> (bufs,
        variables, outs)`` where ``mask`` is a ``(batch,)`` bool array.
        Every lane's math runs every dispatch (the program is fixed),
        but an inactive lane's variable state is held: after the lane
        bodies run, ``where(mask, new, old)`` selects per lane, so idle
        lanes carry their continuation state unchanged across waves.

        Because the mask is a *traced argument* — not a Python constant —
        admitting or retiring lanes between dispatches changes only the
        mask value.  One compiled program per (batch, exact) covers
        every occupancy from 1 to batch: no recompilation, ever.

        Active lanes are bit-identical to the unmasked lowering: the
        selected "new" values are the same arrays ``batched`` returns,
        and for ``exact=True`` those are bit-identical to sequential
        single invokes.
        """
        key = (batch, exact, "masked")
        fn = self._batched.get(key)
        if fn is None:
            body = self._batched_body(batch, exact)

            def masked(bufs, variables, consts, inputs, mask):
                new_bufs, new_vars, outs = body(
                    bufs, variables, consts, inputs)
                def sel(new, old):
                    m = mask.reshape(mask.shape + (1,) * (new.ndim - 1))
                    return jnp.where(m, new, old)
                held = tuple(sel(n, o)
                             for n, o in zip(new_vars, variables))
                return new_bufs, held, outs

            fn = jax.jit(masked, donate_argnums=(0, 1))
            self._batched[key] = fn
        return fn


# ---------------------------------------------------------------------------
# arena buffer pooling (§4.5 grown up: one pool, many invocations)
# ---------------------------------------------------------------------------

class ArenaPool:
    """Owns the physical nonpersistent byte buffers that interpreters
    (and batched pools) recycle between non-concurrent invocations.

    Holds one single-request buffer plus a free list of stacked
    ``(B, nbytes)`` buffers per batch size.  Donated jitted programs
    hand the same device memory back every step, so after warm-up
    ``alloc_count`` must stay constant — the malloc-free steady state,
    observable.

    The free list is at most ``depth`` buffers deep (default 2): the
    donation-aware double buffer.  A dispatch's donated output buffer is
    ``put_batch`` back *as a future* — the host does not block on it —
    so while wave N still computes on device, wave N+1 (same size,
    another bucket, or the next wave of the same bucket) can
    ``take_batch`` the second buffer and stage its host inputs
    concurrently.  JAX's async dispatch tracks the data dependency; the
    pool only bounds how much physical memory may be in flight."""

    def __init__(self, depth: int = 2) -> None:
        self.nbytes = 0
        self.depth = max(1, int(depth))
        self.buf: Optional[jnp.ndarray] = None
        self._taken = False
        self._batched: Dict[int, List[jnp.ndarray]] = {}
        self.alloc_count = 0

    def _alloc(self, shape) -> jnp.ndarray:
        self.alloc_count += 1
        return jnp.zeros(shape, jnp.uint8)

    def ensure(self, nbytes: int) -> None:
        """Grow the pooled buffer size.  Buffers themselves are created
        lazily on first take — a batch-only pool never pays for a
        single-request buffer (and vice versa)."""
        if nbytes > self.nbytes:
            self.nbytes = int(nbytes)
            self.buf = None             # stale smaller buffers
            self._batched.clear()

    # -- single-request buffer (the §4.5 shared-arena contract) ---------
    def take(self) -> jnp.ndarray:
        assert self.nbytes > 0, "ensure() before take()"
        assert not self._taken, "buffer already taken (concurrent invoke?)"
        self._taken = True
        b, self.buf = self.buf, None
        if b is None:
            b = self._alloc((self.nbytes,))
        return b

    def put(self, buf: jnp.ndarray) -> None:
        self._taken = False
        self.buf = buf

    # -- batched buffers (free list = the double buffer) -----------------
    def take_batch(self, batch: int) -> jnp.ndarray:
        free = self._batched.get(batch)
        if free:
            return free.pop()
        return self._alloc((batch, self.nbytes))

    def put_batch(self, buf: jnp.ndarray) -> None:
        free = self._batched.setdefault(int(buf.shape[0]), [])
        if len(free) < self.depth:
            free.append(buf)


class SharedArenaState(ArenaPool):
    """Back-compat name: the single-buffer view of ArenaPool (§4.5)."""


# ---------------------------------------------------------------------------
# phase 3 (batched dispatch): InterpreterPool
# ---------------------------------------------------------------------------

class InterpreterPool:
    """B independent requests of ONE model advanced by one jitted dispatch.

    All lanes share one AllocationPlan (weights, op_data, memory plan)
    and one CompiledPlan; per-lane state is the batch axis of the pooled
    arena buffer and of the variable tensors.  The serving host uses
    this to serve micro-models at batch granularity.
    """

    def __init__(self, model: MicroModel,
                 op_resolver: MicroMutableOpResolver, batch: int,
                 arena_size_bytes: Optional[int] = None,
                 planner: Optional[object] = None,
                 prefer_offline_plan: bool = True,
                 host_arena: Optional[TwoStackArena] = None,
                 pool: Optional[ArenaPool] = None, exact: bool = False):
        if batch < 1:
            raise ValueError("batch must be >= 1")
        self.batch = batch
        self.exact = exact
        self.alloc = plan_model(model, op_resolver, arena_size_bytes,
                                planner, prefer_offline_plan, host_arena)
        self.compiled = CompiledPlan(self.alloc)
        self.pool = pool if pool is not None else ArenaPool()
        self.pool.ensure(self.alloc.nonpersistent_nbytes)
        # per-lane variable state, stacked on axis 0
        self._variables = tuple(
            jnp.broadcast_to(v, (batch,) + v.shape)
            for v in self.alloc.init_variables)
        self._inputs: List[Dict[int, np.ndarray]] = [
            {} for _ in range(batch)]
        self._outs: Optional[Tuple[jnp.ndarray, ...]] = None
        self._invoke_count = 0

    # ------------------------------------------------------------------
    def set_input(self, lane: int, pos: int, value: np.ndarray) -> None:
        tid = self.alloc.model.inputs[pos]
        spec = self.alloc.specs[tid]
        value = np.asarray(value)
        if tuple(value.shape) != tuple(spec.shape):
            raise ValueError(f"lane {lane} input {pos}: shape "
                             f"{value.shape} != {spec.shape}")
        self._inputs[lane][pos] = value.astype(_jnp_dtype(spec.dtype))

    def clear_inputs(self) -> None:
        self._inputs = [{} for _ in range(self.batch)]

    def _stacked_inputs(self) -> Tuple[jnp.ndarray, ...]:
        model = self.alloc.model
        n_in = len(model.inputs)
        for lane, lane_inputs in enumerate(self._inputs):
            # same contract as MicroInterpreter.invoke(), per lane; a
            # lane with NO inputs at all is idle and runs on zeros
            if lane_inputs and len(lane_inputs) != n_in:
                raise RuntimeError(f"lane {lane}: not all inputs set")
        stacked = []
        for pos in range(n_in):
            spec = self.alloc.specs[model.inputs[pos]]
            zero = np.zeros(spec.shape, _jnp_dtype(spec.dtype))
            lanes = [self._inputs[lane].get(pos, zero)
                     for lane in range(self.batch)]
            stacked.append(jnp.asarray(np.stack(lanes)))
        return tuple(stacked)

    def invoke(self) -> None:
        """Advance every lane by one invocation — ONE jitted dispatch."""
        ins = self._stacked_inputs()
        buf = self.pool.take_batch(self.batch)
        with Q.x64_scope():
            buf, variables, outs = self.compiled.batched(
                self.batch, self.exact)(
                buf, self._variables, tuple(self.alloc.consts), ins)
        buf.block_until_ready()
        self._outs = outs
        self._variables = variables
        self.pool.put_batch(buf)
        self._invoke_count += 1

    def output(self, lane: int, pos: int) -> np.ndarray:
        assert self._outs is not None, "invoke() first"
        return np.asarray(self._outs[pos][lane])

    def outputs(self, pos: int) -> np.ndarray:
        """All lanes' outputs, stacked on axis 0."""
        assert self._outs is not None, "invoke() first"
        return np.asarray(self._outs[pos])

    def reset_variable_tensors(self) -> None:
        self._variables = tuple(jnp.zeros_like(v) for v in self._variables)


# ---------------------------------------------------------------------------
# phase 3 (ragged dispatch): lane table + RaggedInterpreterPool
# ---------------------------------------------------------------------------

@dataclass
class LaneState:
    """One row of the ragged pool's lane table.

    ``bucket`` names the model family the lane belongs to, ``slot`` is
    its index on that bucket's stacked batch axis, ``uid`` identifies
    the request currently occupying the lane (None = free), ``step``
    counts dispatches completed for that request (the continuation
    counter), and ``active`` is the lane's bit in the dispatch mask.
    """

    bucket: str
    slot: int
    uid: Optional[int] = None
    step: int = 0
    active: bool = False


@dataclass
class LaneCheckpoint:
    """A lane's continuation state, captured HOST-SIDE so the lane can
    be freed and the request re-admitted later — the preemption
    primitive (docs/PREEMPTION.md).

    ``variables`` holds one np copy of each variable tensor's per-lane
    row (the KV/recurrent continuation state), ``step`` the dispatch
    counter, ``bucket``/``uid`` identify where it came from.  Nothing
    here is traced: snapshotting and restoring move VALUES between
    host and the stacked device arrays; the masked program, its active
    mask, and every shape stay exactly what init compiled, so a
    preempt/resume cycle can never retrace."""

    bucket: str
    uid: Optional[int]
    step: int
    variables: Tuple[np.ndarray, ...]


class _RaggedBucket:
    """Per-model-family state of a RaggedInterpreterPool: one shared
    AllocationPlan/CompiledPlan, the stacked per-lane variable state,
    staged inputs for the next wave, and that family's lane-table rows."""

    def __init__(self, name: str, alloc: AllocationPlan,
                 compiled: CompiledPlan, lanes: int, exact: bool):
        self.name = name
        self.alloc = alloc
        self.compiled = compiled
        self.lanes = lanes
        self.exact = exact
        self.table = [LaneState(bucket=name, slot=i) for i in range(lanes)]
        self.variables = tuple(
            jnp.broadcast_to(v, (lanes,) + v.shape)
            for v in alloc.init_variables)
        self.inputs: List[Dict[int, np.ndarray]] = [{} for _ in range(lanes)]
        self.outs: Optional[Tuple[jnp.ndarray, ...]] = None
        self.outs_host: Optional[List[np.ndarray]] = None
        self.dispatch_count = 0


class RaggedInterpreterPool:
    """Lanes at different models, steps, and lifecycles — one masked
    vmapped dispatch per model-family bucket.

    The lockstep ``InterpreterPool`` requires every lane to run the same
    model and start/finish together.  Here a *lane table* relaxes all of
    that:

      * **different models** — each bucket compiles its own plan once;
        buckets draw stacked arena buffers from ONE shared ``ArenaPool``
        (sized to the max requirement, §4.5 style);
      * **different steps** — every lane carries its own variable-tensor
        continuation state and step counter, so a lane on step 7 of a
        streaming request rides in the same dispatch as a lane on step 0;
      * **different lifecycles** — ``admit``/``retire`` flip the lane's
        bit in the active mask between dispatches.  The mask is a traced
        argument of ``CompiledPlan.masked_batched``, so occupancy
        changes NEVER recompile.

    Double buffering: ``dispatch()`` does not block on the device.  The
    outputs and carried variables are futures; the donated arena buffer
    goes back to the pool as a future too, so staging the next wave's
    host inputs overlaps the current wave's device compute.  Reading an
    ``output()`` is what synchronizes.
    """

    def __init__(self, pool: Optional[ArenaPool] = None, depth: int = 2):
        self.pool = pool if pool is not None else ArenaPool(depth=depth)
        self._buckets: Dict[str, _RaggedBucket] = {}

    # -- bucket construction (init-time; all compilation happens here) --

    def add_bucket(self, name: str, model: MicroModel,
                   resolver: MicroMutableOpResolver, lanes: int, *,
                   exact: bool = False,
                   arena_size_bytes: Optional[int] = None,
                   planner: Optional[object] = None,
                   prefer_offline_plan: bool = True,
                   host_arena: Optional[TwoStackArena] = None,
                   lane_buckets: Optional[BucketTable] = None) -> None:
        """Admit a model family with ``lanes`` lane slots.  Plans,
        compiles, and warms exactly once — admission/retirement later
        touch only the lane table.

        ``lane_buckets`` (optional) rounds ``lanes`` up through a shared
        ``BucketTable`` so model buckets with nearby lane counts compile
        for — and draw from the ``ArenaPool`` free lists of — the SAME
        stacked batch size; the extra lanes are ordinary free lanes."""
        if name in self._buckets:
            raise ValueError(f"bucket {name!r} already exists")
        if lanes < 1:
            raise ValueError("lanes must be >= 1")
        if lane_buckets is not None:
            lanes = lane_buckets.bucket(lanes)
        alloc = plan_model(model, resolver, arena_size_bytes, planner,
                           prefer_offline_plan, host_arena)
        self.pool.ensure(alloc.nonpersistent_nbytes)
        self._buckets[name] = _RaggedBucket(
            name, alloc, CompiledPlan(alloc), lanes, exact)

    # -- lane-table views ------------------------------------------------

    @property
    def lane_table(self) -> List[LaneState]:
        """Every lane of every bucket — the global lane table."""
        return [l for b in self._buckets.values() for l in b.table]

    def lanes(self, bucket: str) -> List[LaneState]:
        return self._buckets[bucket].table

    def free_lanes(self, bucket: str) -> List[int]:
        return [l.slot for l in self._buckets[bucket].table
                if not l.active]

    def occupancy(self) -> float:
        table = self.lane_table
        if not table:
            return 0.0
        return sum(l.active for l in table) / len(table)

    # -- admission / retirement (between dispatches; no recompilation) --

    def admit(self, bucket: str, uid: Optional[int] = None) -> int:
        """Claim a free lane for a new request: reset its continuation
        state to the model's initial variable values, zero its step
        counter, and set its mask bit.  Returns the lane slot."""
        b = self._buckets[bucket]
        for lane in b.table:
            if not lane.active:
                break
        else:
            raise RuntimeError(f"bucket {bucket!r}: no free lane")
        lane.active, lane.uid, lane.step = True, uid, 0
        if b.variables:
            b.variables = tuple(
                v.at[lane.slot].set(init) for v, init in
                zip(b.variables, b.alloc.init_variables))
        b.inputs[lane.slot] = {}
        return lane.slot

    def retire(self, bucket: str, slot: int) -> LaneState:
        """Free a lane mid-flight: clear its mask bit and staged inputs.
        The other lanes' continuation state is untouched and the next
        dispatch reuses the same compiled program."""
        b = self._buckets[bucket]
        lane = b.table[slot]
        lane.active = False
        lane.uid = None
        b.inputs[slot] = {}
        return lane

    # -- preemption: checkpoint / restore (host-side, never retraces) --

    def snapshot_lane(self, bucket: str, slot: int) -> LaneCheckpoint:
        """Capture an active lane's continuation state (variable-tensor
        rows + step counter) into a host-side ``LaneCheckpoint``.  The
        lane itself is untouched — pair with ``retire`` to preempt.
        Synchronizes on the lane's variable state (device → host copy),
        which is the checkpoint's entire cost; the masked program and
        its trace cache are not involved."""
        b = self._buckets[bucket]
        lane = b.table[slot]
        if not lane.active:
            raise RuntimeError(
                f"bucket {bucket!r} lane {slot} is not active")
        rows = tuple(np.asarray(v[slot]).copy() for v in b.variables)
        return LaneCheckpoint(bucket=bucket, uid=lane.uid,
                              step=lane.step, variables=rows)

    def restore_lane(self, ckpt: LaneCheckpoint,
                     slot: Optional[int] = None) -> int:
        """Re-admit a checkpointed continuation into a free lane of its
        bucket (any free lane by default, or ``slot``).  The lane's
        variable rows are set to the checkpoint's values and its step
        counter resumes where the snapshot left off, so the next
        dispatches are bit-identical to an uninterrupted run — lanes
        are independent under the vmapped/unrolled body, so the slot
        index and the other lanes' contents cannot perturb the math.
        Only the lane table and stacked values change: no recompile."""
        b = self._buckets[ckpt.bucket]
        if slot is None:
            free = self.free_lanes(ckpt.bucket)
            if not free:
                raise RuntimeError(
                    f"bucket {ckpt.bucket!r}: no free lane to restore")
            slot = free[0]
        lane = b.table[slot]
        if lane.active:
            raise RuntimeError(
                f"bucket {ckpt.bucket!r} lane {slot} is occupied")
        lane.active, lane.uid, lane.step = True, ckpt.uid, ckpt.step
        if b.variables:
            b.variables = tuple(
                v.at[slot].set(jnp.asarray(row))
                for v, row in zip(b.variables, ckpt.variables))
        b.inputs[slot] = {}
        return slot

    # -- per-wave input staging -----------------------------------------

    def set_input(self, bucket: str, slot: int, pos: int,
                  value: np.ndarray) -> None:
        b = self._buckets[bucket]
        if not b.table[slot].active:
            raise RuntimeError(
                f"bucket {bucket!r} lane {slot} is not active")
        tid = b.alloc.model.inputs[pos]
        spec = b.alloc.specs[tid]
        value = np.asarray(value)
        if tuple(value.shape) != tuple(spec.shape):
            raise ValueError(f"bucket {bucket!r} lane {slot} input {pos}: "
                             f"shape {value.shape} != {spec.shape}")
        b.inputs[slot][pos] = value.astype(_jnp_dtype(spec.dtype))

    def _stacked_inputs(self, b: _RaggedBucket) -> Tuple[jnp.ndarray, ...]:
        model = b.alloc.model
        n_in = len(model.inputs)
        for lane in b.table:
            if lane.active and len(b.inputs[lane.slot]) != n_in:
                raise RuntimeError(
                    f"bucket {b.name!r} lane {lane.slot}: not all "
                    f"inputs set for this wave")
        stacked = []
        for pos in range(n_in):
            spec = b.alloc.specs[model.inputs[pos]]
            zero = np.zeros(spec.shape, _jnp_dtype(spec.dtype))
            lanes = [b.inputs[slot].get(pos, zero)
                     for slot in range(b.lanes)]
            stacked.append(jnp.asarray(np.stack(lanes)))
        return tuple(stacked)

    # -- the ragged dispatch --------------------------------------------

    def dispatch(self) -> int:
        """Advance every bucket that has at least one active lane by one
        step — ONE masked jitted dispatch per such bucket.  Returns the
        number of lanes advanced.  Does not block on the device (see
        class docstring); inputs staged for this wave are consumed.

        Staging is validated for EVERY bucket before ANY bucket runs, so
        a staging error raises with no lane advanced — dispatch is
        atomic across buckets and safe to retry after restaging."""
        waves = []
        for b in self._buckets.values():
            mask = np.array([l.active for l in b.table])
            if mask.any():
                waves.append((b, mask, self._stacked_inputs(b)))
        advanced = 0
        for b, mask, ins in waves:
            buf = self.pool.take_batch(b.lanes)
            with Q.x64_scope():
                buf, variables, outs = b.compiled.masked_batched(
                    b.lanes, b.exact)(
                    buf, b.variables, tuple(b.alloc.consts), ins,
                    jnp.asarray(mask))
            b.outs = outs
            b.outs_host = None
            b.variables = variables
            self.pool.put_batch(buf)
            b.dispatch_count += 1
            b.inputs = [{} for _ in range(b.lanes)]
            for lane in b.table:
                if lane.active:
                    lane.step += 1
                    advanced += 1
        return advanced

    def output(self, bucket: str, slot: int, pos: int) -> np.ndarray:
        """Lane ``slot``'s model output ``pos`` from the last dispatch.
        This is the synchronization point of the double buffer.  The
        whole output stack transfers to host ONCE per wave (cached), so
        reading every active lane costs one device round-trip, not k."""
        return self.outputs(bucket, pos)[slot]

    def outputs(self, bucket: str, pos: int) -> np.ndarray:
        """All lanes' output ``pos`` from the last dispatch, stacked on
        axis 0 (inactive lanes hold garbage — consult the lane table)."""
        b = self._buckets[bucket]
        assert b.outs is not None, "dispatch() first"
        if b.outs_host is None:
            b.outs_host = [np.asarray(o) for o in b.outs]
        return b.outs_host[pos]
