"""Shared compile-once/execute-many execution layer (paper §4.1–4.2).

The paper's core discipline — pay ALL planning cost at init so that
steady-state invoke is pure dispatch — used to live fused inside
``MicroInterpreter``.  This module factors it into a three-phase
pipeline every execution surface (single-shot interpreter, batched
pool, pod-scale serving) builds on:

  1. **AllocationPlan** (plan): walk the op list once, run each
     kernel's prepare(), derive tensor lifetimes, bin-pack the
     nonpersistent arena section with the memory planner, and freeze
     the two-stack arena.  Nothing may allocate after this phase.

  2. **CompiledPlan** (compile): the arena read/bitcast/dispatch/write
     loop over the topologically sorted op list, traced ONCE into a
     jitted program with a donated arena buffer.  The same traced body
     is reused for **batched invoke**: ``jax.vmap`` over a leading
     batch axis turns one dispatch into B independent requests —
     consts broadcast, arena buffers and variable tensors carry the
     batch axis.

  3. **dispatch**: ``MicroInterpreter`` (a thin facade preserving the
     paper's application API) or ``InterpreterPool`` (batch-granularity
     serving) feed inputs in and read outputs back; per-invoke work is
     one jitted call.

**Arena pooling.**  ``ArenaPool`` generalizes the shared-arena idea of
§4.5: it owns the physical nonpersistent byte buffers — one single
buffer plus one stacked ``(B, nbytes)`` buffer per batch size — and
recycles them across invocations.  Because the jitted programs donate
their arena argument, steady state reuses the same device memory every
step: the pool allocates during warm-up only (``alloc_count`` makes
that observable and testable).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize as Q
from .arena import TwoStackArena, align_up
from .memory_planner import MemoryPlan, plan_nonpersistent, select_planner
from .op_resolver import MicroMutableOpResolver, TensorSpec
from .schema import MicroModel, QuantParams

# TFLM persistent-arena runtime records (TfLiteTensor ≈ 64 B, node ≈ 48 B);
# we account the same way so Table-2 numbers are comparable.
TENSOR_RUNTIME_NBYTES = 64
NODE_RUNTIME_NBYTES = 48


def _itemsize(dtype: str) -> int:
    return 2 if dtype == "bfloat16" else np.dtype(dtype).itemsize


def _spec_nbytes(spec: TensorSpec) -> int:
    n = 1
    for d in spec.shape:
        n *= int(d)
    return n * _itemsize(spec.dtype)


def _jnp_dtype(name: str):
    return jnp.bfloat16 if name == "bfloat16" else jnp.dtype(name)


# ---------------------------------------------------------------------------
# contexts handed to kernel prepare()/eval() (the TFLM C-API analogue)
# ---------------------------------------------------------------------------

class PrepareContext:
    def __init__(self, model: MicroModel, specs: List[TensorSpec]):
        self._model = model
        self._specs = specs

    def tensor_spec(self, idx: int) -> TensorSpec:
        return self._specs[idx]

    def quant(self, idx: int) -> QuantParams:
        return self._model.tensor(idx).quant

    def const_value(self, idx: int) -> Optional[np.ndarray]:
        t = self._model.tensor(idx)
        return self._model.const_data(idx) if t.is_const else None

    def is_const(self, idx: int) -> bool:
        return self._model.tensor(idx).is_const


class EvalContext:
    __slots__ = ("op_data", "_out_specs", "_out_quants")

    def __init__(self, op_data, out_specs, out_quants):
        self.op_data = op_data
        self._out_specs = out_specs
        self._out_quants = out_quants

    def output_shape(self, k: int) -> Tuple[int, ...]:
        return self._out_specs[k].shape

    def quant_of_output(self, k: int) -> QuantParams:
        return self._out_quants[k]


@dataclass
class OpPlan:
    op: Any                               # schema.OpDef
    registration: Any                     # OpRegistration
    prep: Any                             # PrepareResult
    eval_ctx: EvalContext


# ---------------------------------------------------------------------------
# phase 1: AllocationPlan
# ---------------------------------------------------------------------------

class AllocationPlan:
    """Everything the init phase decides: prepared ops, tensor specs,
    frozen arena layout, and the memory plan.  Immutable after build()."""

    def __init__(self) -> None:
        self.model: MicroModel = None           # type: ignore[assignment]
        self.resolver: MicroMutableOpResolver = None  # type: ignore
        self.arena: TwoStackArena = None        # type: ignore[assignment]
        self.specs: List[TensorSpec] = []
        self.const_pos: Dict[int, int] = {}
        self.var_pos: Dict[int, int] = {}
        self.tensor_offset: Dict[int, int] = {}
        self.consts: List[jnp.ndarray] = []
        self.init_variables: List[jnp.ndarray] = []
        self.var_specs: List[TensorSpec] = []
        self.op_plans: List[OpPlan] = []
        self.plan: MemoryPlan = None            # type: ignore[assignment]
        self.scratch_bytes = 0
        self.planner_name = ""

    @classmethod
    def build(cls, model: MicroModel, resolver: MicroMutableOpResolver,
              arena: TwoStackArena, planner: Optional[object] = None,
              prefer_offline_plan: bool = True) -> "AllocationPlan":
        self = cls()
        self.model, self.resolver, self.arena = model, resolver, arena
        m = model

        # 0. initial specs from the serialized model
        for t in m.tensors:
            self.specs.append(TensorSpec(t.shape, t.dtype))

        # 1. persistent runtime records (tensor structs + node structs)
        arena.allocate_persistent(
            TENSOR_RUNTIME_NBYTES * len(m.tensors), "tensor_structs")
        arena.allocate_persistent(
            NODE_RUNTIME_NBYTES * len(m.operators), "node_structs")

        # 2. const tensors -> zero-copy views ("flash"); variables -> tail
        for i, t in enumerate(m.tensors):
            if t.is_const:
                self.const_pos[i] = len(self.consts)
                self.consts.append(jnp.asarray(m.const_data(i)))
            elif t.is_variable:
                self.var_pos[i] = len(self.init_variables)
                arena.allocate_persistent(t.nbytes, f"variable{i}")
                self.init_variables.append(
                    jnp.zeros(t.shape, _jnp_dtype(t.dtype)))
                self.var_specs.append(TensorSpec(t.shape, t.dtype))

        # 3. prepare each op in topological order
        pctx = PrepareContext(m, self.specs)
        scratch: Dict[int, List[int]] = {}
        for oi, op in enumerate(m.operators):
            reg = resolver.resolve(op.opcode)
            # planning-time temp (paper: the between-stack temp region)
            arena.allocate_temp(256)
            prep = reg.prepare(pctx, op)
            arena.reset_temp()
            if prep.persistent_nbytes:
                arena.allocate_persistent(
                    prep.persistent_nbytes, f"opdata{oi}")
            assert len(prep.output_specs) == len(op.outputs), \
                f"{reg.name}: prepare produced {len(prep.output_specs)} " \
                f"specs for {len(op.outputs)} outputs"
            for t, spec in zip(op.outputs, prep.output_specs):
                declared = self.specs[t]
                if tuple(declared.shape) != tuple(spec.shape):
                    raise ValueError(
                        f"op {oi} ({reg.name}): computed output shape "
                        f"{spec.shape} != serialized {declared.shape}")
                self.specs[t] = spec
            if prep.scratch_nbytes:
                scratch[oi] = list(prep.scratch_nbytes)
            out_quants = [m.tensor(t).quant for t in op.outputs]
            ectx = EvalContext(prep.op_data,
                               [self.specs[t] for t in op.outputs],
                               out_quants)
            self.op_plans.append(OpPlan(op, reg, prep, ectx))

        # 4. lifetimes + memory plan for the nonpersistent section
        planned_nbytes = {
            i: _spec_nbytes(self.specs[i])
            for i, t in enumerate(m.tensors)
            if not t.is_const and not t.is_variable}
        planner = select_planner(m.metadata, planner, prefer_offline_plan)
        self.planner_name = getattr(planner, "name", type(planner).__name__)
        self.plan, self.tensor_offset, self.scratch_bytes = \
            plan_nonpersistent(
                [op.inputs for op in m.operators],
                [op.outputs for op in m.operators],
                planned_nbytes, m.inputs, m.outputs, scratch, planner)

        # 5. reserve the planned section on the head stack and freeze
        arena.reserve_nonpersistent_section(
            self.plan.total_bytes + self.scratch_bytes)
        arena.freeze()
        return self

    @property
    def nonpersistent_nbytes(self) -> int:
        """Physical bytes the pooled arena buffer must provide."""
        return self.plan.total_bytes


def required_arena_size(model: MicroModel,
                        resolver: MicroMutableOpResolver,
                        slack: int = 1024) -> int:
    """Probe build on a throwaway oversized arena to size the real one."""
    probe = TwoStackArena(1 << 30)
    AllocationPlan.build(model, resolver, probe)
    return align_up(probe.usage().total + slack)


# ---------------------------------------------------------------------------
# phase 2: CompiledPlan
# ---------------------------------------------------------------------------

class CompiledPlan:
    """The traced invoke body over a frozen AllocationPlan.

    ``jitted`` runs one request per dispatch (arena buffer donated);
    ``batched(B)`` vmaps the identical body over a leading batch axis so
    one jitted program advances B independent requests — the per-invoke
    Python/dispatch overhead amortizes over the batch.
    """

    def __init__(self, alloc: AllocationPlan):
        self.alloc = alloc
        self.jitted = jax.jit(self.execute, donate_argnums=(0, 1))
        self._batched: Dict[int, Any] = {}

    # -- arena byte-view helpers (static offsets; traced inside invoke) --

    def _read(self, buf: jnp.ndarray, tid: int):
        spec = self.alloc.specs[tid]
        off = self.alloc.tensor_offset[tid]
        nbytes = _spec_nbytes(spec)
        raw = jax.lax.slice(buf, (off,), (off + nbytes,))
        dt = _jnp_dtype(spec.dtype)
        item = _itemsize(spec.dtype)
        if item == 1:
            return jax.lax.bitcast_convert_type(raw, dt).reshape(spec.shape)
        arr = jax.lax.bitcast_convert_type(
            raw.reshape(nbytes // item, item), dt)
        return arr.reshape(spec.shape)

    def _write(self, buf: jnp.ndarray, tid: int, value) -> jnp.ndarray:
        spec = self.alloc.specs[tid]
        off = self.alloc.tensor_offset[tid]
        dt = _jnp_dtype(spec.dtype)
        value = value.astype(dt).reshape(-1)
        item = _itemsize(spec.dtype)
        if item == 1:
            raw = jax.lax.bitcast_convert_type(value, jnp.uint8)
        else:
            raw = jax.lax.bitcast_convert_type(value, jnp.uint8).reshape(-1)
        return jax.lax.dynamic_update_slice(buf, raw, (off,))

    # -- the traced invoke body -----------------------------------------

    def execute(self, buf, variables, consts, inputs):
        alloc = self.alloc
        # write model inputs into their planned arena slots
        for pos, tid in enumerate(alloc.model.inputs):
            buf = self._write(buf, tid, inputs[pos])
        variables = list(variables)
        for opp in alloc.op_plans:
            op = opp.op
            in_arrays = []
            for t in op.inputs:
                if t < 0:
                    in_arrays.append(None)
                elif t in alloc.const_pos:
                    in_arrays.append(consts[alloc.const_pos[t]])
                elif t in alloc.var_pos:
                    in_arrays.append(variables[alloc.var_pos[t]])
                else:
                    in_arrays.append(self._read(buf, t))
            outs = opp.registration.eval(opp.eval_ctx, op, in_arrays)
            n_out = len(op.outputs)
            for t, o in zip(op.outputs, outs[:n_out]):
                buf = self._write(buf, t, o)
            for t, v in zip(opp.prep.variable_updates, outs[n_out:]):
                variables[alloc.var_pos[t]] = v
        # read the model outputs inside the traced program: the host
        # then receives small per-output arrays instead of slicing (or
        # copying) the whole arena per invoke
        model_outs = tuple(self._read(buf, t)
                           for t in alloc.model.outputs)
        return buf, tuple(variables), model_outs

    def batched(self, batch: int, exact: bool = False):
        """One jitted program advancing ``batch`` independent requests.

        Arena buffers (axis 0 of ``(B, nbytes)``), variable tensors, and
        model inputs carry the batch axis; consts broadcast — weights
        stay single-copy "flash" views shared by every lane.

        Two lowerings of the same traced body:

        * ``exact=False`` (default): ``jax.vmap`` over the leading batch
          axis — the throughput path.  Integer (int8) models stay
          bit-exact, but batched float reductions may be reassociated by
          the backend (e.g. CPU gemm vs gemv), so float outputs can
          differ from single invokes in the last ulps.
        * ``exact=True``: the per-lane body is unrolled ``batch`` times
          inside one program — bit-identical to N sequential single
          invokes for every dtype, at the cost of program size.
        """
        key = (batch, exact)
        fn = self._batched.get(key)
        if fn is None:
            if exact:
                def unrolled(bufs, variables, consts, inputs):
                    lanes = [self.execute(
                        bufs[i], tuple(v[i] for v in variables), consts,
                        tuple(x[i] for x in inputs))
                        for i in range(batch)]
                    bs, vs, os = zip(*lanes)
                    return (jnp.stack(bs),
                            tuple(jnp.stack(z) for z in zip(*vs)),
                            tuple(jnp.stack(z) for z in zip(*os)))
                fn = jax.jit(unrolled, donate_argnums=(0, 1))
            else:
                fn = jax.jit(
                    jax.vmap(self.execute, in_axes=(0, 0, None, 0)),
                    donate_argnums=(0, 1))
            self._batched[key] = fn
        return fn


# ---------------------------------------------------------------------------
# arena buffer pooling (§4.5 grown up: one pool, many invocations)
# ---------------------------------------------------------------------------

class ArenaPool:
    """Owns the physical nonpersistent byte buffers that interpreters
    (and batched pools) recycle between non-concurrent invocations.

    Holds one single-request buffer plus one stacked ``(B, nbytes)``
    buffer per batch size.  Donated jitted programs hand the same device
    memory back every step, so after warm-up ``alloc_count`` must stay
    constant — the malloc-free steady state, observable."""

    def __init__(self) -> None:
        self.nbytes = 0
        self.buf: Optional[jnp.ndarray] = None
        self._taken = False
        self._batched: Dict[int, jnp.ndarray] = {}
        self.alloc_count = 0

    def _alloc(self, shape) -> jnp.ndarray:
        self.alloc_count += 1
        return jnp.zeros(shape, jnp.uint8)

    def ensure(self, nbytes: int) -> None:
        """Grow the pooled buffer size.  Buffers themselves are created
        lazily on first take — a batch-only pool never pays for a
        single-request buffer (and vice versa)."""
        if nbytes > self.nbytes:
            self.nbytes = int(nbytes)
            self.buf = None             # stale smaller buffers
            self._batched.clear()

    # -- single-request buffer (the §4.5 shared-arena contract) ---------
    def take(self) -> jnp.ndarray:
        assert self.nbytes > 0, "ensure() before take()"
        assert not self._taken, "buffer already taken (concurrent invoke?)"
        self._taken = True
        b, self.buf = self.buf, None
        if b is None:
            b = self._alloc((self.nbytes,))
        return b

    def put(self, buf: jnp.ndarray) -> None:
        self._taken = False
        self.buf = buf

    # -- batched buffers -------------------------------------------------
    def take_batch(self, batch: int) -> jnp.ndarray:
        buf = self._batched.pop(batch, None)
        if buf is None:
            buf = self._alloc((batch, self.nbytes))
        return buf

    def put_batch(self, buf: jnp.ndarray) -> None:
        self._batched[int(buf.shape[0])] = buf


class SharedArenaState(ArenaPool):
    """Back-compat name: the single-buffer view of ArenaPool (§4.5)."""


# ---------------------------------------------------------------------------
# phase 3 (batched dispatch): InterpreterPool
# ---------------------------------------------------------------------------

class InterpreterPool:
    """B independent requests of ONE model advanced by one jitted dispatch.

    All lanes share one AllocationPlan (weights, op_data, memory plan)
    and one CompiledPlan; per-lane state is the batch axis of the pooled
    arena buffer and of the variable tensors.  The serving host uses
    this to serve micro-models at batch granularity.
    """

    def __init__(self, model: MicroModel,
                 op_resolver: MicroMutableOpResolver, batch: int,
                 arena_size_bytes: Optional[int] = None,
                 planner: Optional[object] = None,
                 prefer_offline_plan: bool = True,
                 host_arena: Optional[TwoStackArena] = None,
                 pool: Optional[ArenaPool] = None, exact: bool = False):
        if batch < 1:
            raise ValueError("batch must be >= 1")
        self.batch = batch
        self.exact = exact
        if host_arena is not None:
            # tenant of a shared arena: persistents stack under the
            # host's, the nonpersistent head section is shared (§4.5)
            arena = host_arena.fork_tenant()
        else:
            if arena_size_bytes is None:
                arena_size_bytes = required_arena_size(model, op_resolver)
            arena = TwoStackArena(arena_size_bytes)
        self.alloc = AllocationPlan.build(model, op_resolver, arena,
                                          planner, prefer_offline_plan)
        if host_arena is not None:
            host_arena.absorb_tenant(arena)
        self.compiled = CompiledPlan(self.alloc)
        self.pool = pool if pool is not None else ArenaPool()
        self.pool.ensure(self.alloc.nonpersistent_nbytes)
        # per-lane variable state, stacked on axis 0
        self._variables = tuple(
            jnp.broadcast_to(v, (batch,) + v.shape)
            for v in self.alloc.init_variables)
        self._inputs: List[Dict[int, np.ndarray]] = [
            {} for _ in range(batch)]
        self._outs: Optional[Tuple[jnp.ndarray, ...]] = None
        self._invoke_count = 0

    # ------------------------------------------------------------------
    def set_input(self, lane: int, pos: int, value: np.ndarray) -> None:
        tid = self.alloc.model.inputs[pos]
        spec = self.alloc.specs[tid]
        value = np.asarray(value)
        if tuple(value.shape) != tuple(spec.shape):
            raise ValueError(f"lane {lane} input {pos}: shape "
                             f"{value.shape} != {spec.shape}")
        self._inputs[lane][pos] = value.astype(_jnp_dtype(spec.dtype))

    def clear_inputs(self) -> None:
        self._inputs = [{} for _ in range(self.batch)]

    def _stacked_inputs(self) -> Tuple[jnp.ndarray, ...]:
        model = self.alloc.model
        n_in = len(model.inputs)
        for lane, lane_inputs in enumerate(self._inputs):
            # same contract as MicroInterpreter.invoke(), per lane; a
            # lane with NO inputs at all is idle and runs on zeros
            if lane_inputs and len(lane_inputs) != n_in:
                raise RuntimeError(f"lane {lane}: not all inputs set")
        stacked = []
        for pos in range(n_in):
            spec = self.alloc.specs[model.inputs[pos]]
            zero = np.zeros(spec.shape, _jnp_dtype(spec.dtype))
            lanes = [self._inputs[lane].get(pos, zero)
                     for lane in range(self.batch)]
            stacked.append(jnp.asarray(np.stack(lanes)))
        return tuple(stacked)

    def invoke(self) -> None:
        """Advance every lane by one invocation — ONE jitted dispatch."""
        ins = self._stacked_inputs()
        buf = self.pool.take_batch(self.batch)
        with Q.x64_scope():
            buf, variables, outs = self.compiled.batched(
                self.batch, self.exact)(
                buf, self._variables, tuple(self.alloc.consts), ins)
        buf.block_until_ready()
        self._outs = outs
        self._variables = variables
        self.pool.put_batch(buf)
        self._invoke_count += 1

    def output(self, lane: int, pos: int) -> np.ndarray:
        assert self._outs is not None, "invoke() first"
        return np.asarray(self._outs[pos][lane])

    def outputs(self, pos: int) -> np.ndarray:
        """All lanes' outputs, stacked on axis 0."""
        assert self._outs is not None, "invoke() first"
        return np.asarray(self._outs[pos])

    def reset_variable_tensors(self) -> None:
        self._variables = tuple(jnp.zeros_like(v) for v in self._variables)
