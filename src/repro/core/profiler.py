"""Per-operator profiling hooks (paper §5.4, TFLM micro_profiler).

TFLM lets a developer instrument code sections and attribute cycles to
operators to find bottlenecks.  Our invoke is ONE fused jit call (the
dispatch is paid at trace time), so per-op attribution needs a separate
instrumented execution mode: ``MicroProfiler.profile(interp, ...)``
re-runs the op list eagerly (one jit per op, warmed), measuring wall
time per operator instance — the same numbers TFLM's hooks produce,
at the cost of losing cross-op fusion (reported alongside the fused
total so the fusion win is visible too).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import quantize as Q
from .schema import OpCode

_OP_NAMES = {v: k for k, v in vars(OpCode).items()
             if isinstance(v, int) and not k.startswith("_")}


@dataclasses.dataclass
class OpProfile:
    """Wall time and output size of one op in an eager profiling run."""

    index: int
    op_name: str
    wall_us: float
    out_bytes: int

    def line(self) -> str:
        return (f"  [{self.index:3d}] {self.op_name:20s} "
                f"{self.wall_us:9.1f} us  ({self.out_bytes} B out)")


@dataclasses.dataclass
class ProfileReport:
    """Per-op eager timings next to the fused jitted total — the
    paper's §4.6 profiler surface."""

    per_op: List[OpProfile]
    fused_total_us: float

    @property
    def eager_total_us(self) -> float:
        return sum(p.wall_us for p in self.per_op)

    def by_op_type(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for p in self.per_op:
            out[p.op_name] = out.get(p.op_name, 0.0) + p.wall_us
        return dict(sorted(out.items(), key=lambda kv: -kv[1]))

    def bottleneck(self) -> str:
        return next(iter(self.by_op_type()))

    def render(self) -> str:
        lines = ["per-operator profile (eager, per-op jit):"]
        lines += [p.line() for p in self.per_op]
        lines.append(f"  eager total: {self.eager_total_us:.1f} us   "
                     f"fused invoke: {self.fused_total_us:.1f} us   "
                     f"(fusion win "
                     f"{self.eager_total_us / max(self.fused_total_us, 1e-9):.2f}x)")
        lines.append("by op type (bottlenecks first):")
        for name, us in self.by_op_type().items():
            lines.append(f"  {name:20s} {us:9.1f} us")
        return "\n".join(lines)


@dataclasses.dataclass
class CompileStepTiming:
    """One calibration measurement: the COLD first call of a jitted
    program (trace + compile + run, ``compile_us``) next to its WARM
    steady-state cost (median of ``iters`` runs, ``step_us``).

    This is the measurement primitive the calibration cost model
    (``repro.core.costmodel``) builds on: a bucket's value is its warm
    padded-step latency, its price is the one-time compile it adds to
    the table — both sides of the solver's trade live in this pair."""

    compile_us: float
    step_us: float
    iters: int

    @property
    def trace_overhead_us(self) -> float:
        """What the first call paid beyond a warm step — the compile
        cost a bucket table charges per level it actually traces."""
        return max(self.compile_us - self.step_us, 0.0)


def measure_compile_and_step(fn, *args, iters: int = 5,
                             block=None) -> CompileStepTiming:
    """Time ``fn(*args)`` cold (first call = trace + compile + run) and
    warm (median of ``iters`` further calls) — the compile/step timer
    behind calibration.

    ``fn`` must not have been called with this signature before,
    otherwise the "cold" call is already warm and the measured compile
    cost collapses to a step cost.  ``block`` (default
    ``jax.block_until_ready``) synchronizes on the result so async
    dispatch cannot leak device time out of the measurement."""
    if block is None:
        block = jax.block_until_ready
    t0 = time.perf_counter()
    block(fn(*args))
    compile_us = (time.perf_counter() - t0) * 1e6
    times = []
    for _ in range(max(1, iters)):
        t0 = time.perf_counter()
        block(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return CompileStepTiming(compile_us=compile_us,
                             step_us=times[len(times) // 2],
                             iters=len(times))


class MicroProfiler:
    """Paper §5.4: instrument the interpreter's operator sequence."""

    @staticmethod
    def profile(interp, inputs: List[np.ndarray], *, warmup: int = 2,
                iters: int = 5) -> ProfileReport:
        model = interp.model
        # fused reference timing (the production invoke)
        def fused():
            for i, x in enumerate(inputs):
                interp.set_input(i, x)
            interp.invoke()
            interp.output(0)
        for _ in range(warmup):
            fused()
        t0 = time.perf_counter()
        for _ in range(iters):
            fused()
        fused_us = (time.perf_counter() - t0) / iters * 1e6

        # eager per-op execution over a value environment
        env: Dict[int, jnp.ndarray] = {}
        var_env = {t: jnp.zeros(interp._specs[t].shape, jnp.float32)
                   for t in interp._var_pos}
        for pos, tid in enumerate(model.inputs):
            env[tid] = jnp.asarray(
                np.asarray(inputs[pos],
                           dtype=np.dtype("float32")
                           if interp._specs[tid].dtype == "float32"
                           else None))
        profiles: List[OpProfile] = []
        with Q.x64_scope():
            for idx, opp in enumerate(interp._op_plans):
                op = opp.op
                vals = []
                for t in op.inputs:
                    if t < 0:
                        vals.append(None)
                    elif t in interp._const_pos:
                        vals.append(interp._consts[interp._const_pos[t]])
                    elif t in var_env and t not in env:
                        vals.append(var_env[t])
                    else:
                        vals.append(env[t])
                # jit can't take None: substitute and rebuild inside
                call_args = [a if a is not None else jnp.zeros(())
                             for a in vals]
                none_mask = [a is None for a in vals]
                fn = jax.jit(lambda *a, _opp=opp, _op=op,
                             _mask=tuple(none_mask):
                             _opp.registration.eval(
                                 _opp.eval_ctx, _op,
                                 [None if m else x
                                  for m, x in zip(_mask, a)]))
                for _ in range(warmup):
                    jax.block_until_ready(fn(*call_args))
                t0 = time.perf_counter()
                for _ in range(iters):
                    outs = fn(*call_args)
                    jax.block_until_ready(outs)
                us = (time.perf_counter() - t0) / iters * 1e6
                n_out = len(op.outputs)
                for t, o in zip(op.outputs, outs[:n_out]):
                    env[t] = o
                for t, v in zip(opp.prep.variable_updates, outs[n_out:]):
                    var_env[t] = v
                out_bytes = sum(int(np.prod(interp._specs[t].shape))
                                * 4 for t in op.outputs)
                profiles.append(OpProfile(
                    index=idx,
                    op_name=_OP_NAMES.get(op.opcode, str(op.opcode)),
                    wall_us=us, out_bytes=out_bytes))
        return ProfileReport(per_op=profiles, fused_total_us=fused_us)
