"""µFB — the µFlow portable model serialization format.

This is the JAX-port analogue of the TFLite FlatBuffer schema used by
TF Micro (paper §4.3).  Design goals copied from the paper:

  * a model is ONE contiguous binary blob ("memory-mapped representation"),
  * the accessor code reads tensor/op tables and constant buffers as
    zero-copy ``np.frombuffer`` views — no unpacking step,
  * operations are stored as a *topologically sorted list*, not a graph,
    so execution is "looping through the operation list in order",
  * the blob can be embedded as a Python source module (the paper converts
    FlatBuffers to C arrays for file-system-less targets),
  * arbitrary metadata (e.g. an offline memory plan, §4.4.2) rides along
    in a key/value metadata section.

Layout (little-endian):

    [Header][input idx table][output idx table][tensor table]
    [op table][string table][metadata table][buffer section (16B aligned)]

Operator *parameters* are stored as compact JSON bytes per op.  The paper
notes the serialized representation "requires a few code lines executed at
run time to convert from the serialized representation to the structure in
the underlying implementation" — the JSON decode at prepare time is exactly
that conversion cost, paid once at init, never during invoke.
"""

from __future__ import annotations

import json
import struct
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

MAGIC = b"UFB1"
VERSION = 3
BUFFER_ALIGN = 16
MAX_RANK = 8

# ---------------------------------------------------------------------------
# dtype coding
# ---------------------------------------------------------------------------

_DTYPE_CODES: Dict[str, int] = {
    "float32": 0,
    "int8": 1,
    "int32": 2,
    "uint8": 3,
    "bool": 4,
    "int16": 5,
    "float16": 6,
    "bfloat16": 7,
    "int64": 8,
}
_CODE_DTYPES = {v: k for k, v in _DTYPE_CODES.items()}


def dtype_code(dtype) -> int:
    name = np.dtype(dtype).name if str(dtype) != "bfloat16" else "bfloat16"
    if str(dtype) == "bfloat16":
        name = "bfloat16"
    try:
        return _DTYPE_CODES[name]
    except KeyError:
        raise ValueError(f"unsupported µFB dtype: {dtype!r}")


def code_dtype(code: int) -> str:
    return _CODE_DTYPES[code]


def dtype_itemsize(name: str) -> int:
    if name == "bfloat16":
        return 2
    return np.dtype(name).itemsize


# ---------------------------------------------------------------------------
# Opcodes (the subset of TFLite ops TF Micro-class models need, plus the
# transformer ops the pod path shares with the micro path)
# ---------------------------------------------------------------------------

class OpCode:
    """The serialized operator vocabulary (TFLite builtin-op analogue),
    including the pod-scale SERVING_* macro-ops."""

    CONV_2D = 0
    DEPTHWISE_CONV_2D = 1
    FULLY_CONNECTED = 2
    ADD = 3
    MUL = 4
    SUB = 5
    MAX_POOL_2D = 6
    AVERAGE_POOL_2D = 7
    RESHAPE = 8
    SOFTMAX = 9
    RELU = 10
    RELU6 = 11
    LOGISTIC = 12
    TANH = 13
    CONCATENATION = 14
    PAD = 15
    MEAN = 16
    QUANTIZE = 17
    DEQUANTIZE = 18
    SVDF = 19
    IDENTITY = 20
    DROPOUT = 21          # training-only; stripped by the exporter (§3.3)
    TRANSPOSE = 22
    MATMUL = 23
    RMS_NORM = 24
    LAYER_NORM = 25
    GELU = 26
    ROPE = 27
    ATTENTION = 28        # fused SDPA (micro-path transformer demo)
    SILU = 29
    EMBEDDING_LOOKUP = 30
    STRIDED_SLICE = 31
    SPLIT = 32
    BATCH_MATMUL = 33
    LEAKY_RELU = 34
    SQUARED_DIFFERENCE = 35
    RSQRT = 36
    EXP = 37
    NEG = 38
    MINIMUM = 39
    MAXIMUM = 40
    # serving macro-ops: the pod-scale engine resolves its compiled
    # prefill/decode steps through the same vendor-tag registry as the
    # micro kernels (§4.7–4.8), so TAGS=("pallas", "reference") swaps
    # optimized serving kernels in with no engine changes
    SERVING_PREFILL = 41
    SERVING_DECODE = 42
    SERVING_PREFILL_CHUNK = 43
    # paged-KV variants: same macro-ops over a physical block pool and
    # per-slot block tables instead of contiguous per-slot cache rows
    SERVING_DECODE_PAGED = 44
    SERVING_PREFILL_CHUNK_PAGED = 45
    # recurrent-state chunked prefill: the SSM/hybrid variant of
    # SERVING_PREFILL_CHUNK — a chunk boundary is a recurrent-state
    # checkpoint, so the carried (conv, ssd) state is a traced argument
    # alongside the chunk tokens and the true (unpadded) chunk length
    SERVING_PREFILL_CHUNK_STATE = 46
    # quantized serving: the same prefill/decode macro-ops over an
    # int8/int4 weight tree (and optionally an int8 KV cache) — the
    # quantization layout (weight dtype, KV dtype, paged-ness) rides
    # the OpDef params, so two opcodes cover the whole quantized matrix
    SERVING_PREFILL_Q = 47
    SERVING_DECODE_Q = 48


# Pod-scale macro-ops: resolvable through the tag chain but never part
# of a µFB graph, so AllOpsResolver must not link them (they would
# distort the Table-2 code-size accounting depending on import order).
SERVING_OPCODES = frozenset({OpCode.SERVING_PREFILL,
                             OpCode.SERVING_DECODE,
                             OpCode.SERVING_PREFILL_CHUNK,
                             OpCode.SERVING_DECODE_PAGED,
                             OpCode.SERVING_PREFILL_CHUNK_PAGED,
                             OpCode.SERVING_PREFILL_CHUNK_STATE,
                             OpCode.SERVING_PREFILL_Q,
                             OpCode.SERVING_DECODE_Q})


OP_NAMES = {v: k for k, v in vars(OpCode).items() if not k.startswith("_")}


# ---------------------------------------------------------------------------
# Tensor flags
# ---------------------------------------------------------------------------

class TensorFlags:
    """Bit flags classifying a tensor's storage class: const (flash),
    variable (persistent state), model input/output."""

    NONE = 0
    IS_CONST = 1          # weights/bias: data lives in the model blob (flash)
    IS_VARIABLE = 2       # persistent state (e.g. SVDF activation state)
    IS_MODEL_INPUT = 4
    IS_MODEL_OUTPUT = 8


@dataclass
class QuantParams:
    """TFLM-style quantization parameters (symmetric per-channel weights,
    asymmetric per-tensor activations)."""
    scale: float = 0.0
    zero_point: int = 0
    channel_scales: Optional[np.ndarray] = None   # float32[C] or None
    quantized_dimension: int = 0

    @property
    def is_quantized(self) -> bool:
        return self.scale != 0.0 or self.channel_scales is not None

    @property
    def is_per_channel(self) -> bool:
        return self.channel_scales is not None


@dataclass
class TensorDef:
    """Serialized tensor record: name, shape, dtype, storage-class
    flags, and quantization parameters."""

    name: str
    shape: Tuple[int, ...]
    dtype: str                       # numpy-style name, or "bfloat16"
    flags: int = TensorFlags.NONE
    quant: QuantParams = field(default_factory=QuantParams)
    # Filled by serialization for const tensors:
    buffer_offset: int = 0
    buffer_nbytes: int = 0

    @property
    def is_const(self) -> bool:
        return bool(self.flags & TensorFlags.IS_CONST)

    @property
    def is_variable(self) -> bool:
        return bool(self.flags & TensorFlags.IS_VARIABLE)

    @property
    def nbytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= int(d)
        return n * dtype_itemsize(self.dtype)


@dataclass
class OpDef:
    """Serialized operator record: opcode, input/output tensor indices
    (-1 marks an optional absent input), and builtin params."""

    opcode: int
    inputs: Tuple[int, ...]          # tensor indices; -1 == optional-absent
    outputs: Tuple[int, ...]
    params: Dict[str, Any] = field(default_factory=dict)

    @property
    def name(self) -> str:
        return OP_NAMES.get(self.opcode, f"OP_{self.opcode}")


# ---------------------------------------------------------------------------
# Serialization
# ---------------------------------------------------------------------------

_HEADER = struct.Struct(
    "<4sI"     # magic, version
    "IIII"     # n_tensors, n_ops, n_inputs, n_outputs
    "QQQQQQ"   # off: tensor_tbl, op_tbl, string_tbl, metadata_tbl, buffers, total
)

# fixed-size tensor record:
#   dtype u8 | rank u8 | flags u16 | quant_dim i32
#   shape i32[MAX_RANK]
#   buffer_offset u64 | buffer_nbytes u64
#   scale f64 | zero_point i32 | n_channel_scales u32
#   channel_scales_offset u64
#   name_offset u32 | name_len u32
_TENSOR_REC = struct.Struct("<BBHi" + "i" * MAX_RANK + "QQdiIQII")


def _align(n: int, a: int = BUFFER_ALIGN) -> int:
    return (n + a - 1) & ~(a - 1)


class ModelBuilderBuffers:
    """Accumulates the const-buffer section with alignment."""

    def __init__(self) -> None:
        self._chunks: List[bytes] = []
        self._size = 0

    def add(self, data: bytes) -> Tuple[int, int]:
        pad = _align(self._size) - self._size
        if pad:
            self._chunks.append(b"\0" * pad)
            self._size += pad
        off = self._size
        self._chunks.append(data)
        self._size += len(data)
        return off, len(data)

    def blob(self) -> bytes:
        return b"".join(self._chunks)


def serialize_model(
    tensors: Sequence[TensorDef],
    ops: Sequence[OpDef],
    inputs: Sequence[int],
    outputs: Sequence[int],
    const_data: Dict[int, np.ndarray],
    metadata: Optional[Dict[str, bytes]] = None,
) -> bytes:
    """Pack a model into a single µFB blob."""
    metadata = dict(metadata or {})
    bufs = ModelBuilderBuffers()

    # --- const buffers + per-channel scales ---
    tensor_channel_scale_off: Dict[int, int] = {}
    tensors = [TensorDef(t.name, tuple(int(d) for d in t.shape), t.dtype,
                         t.flags, t.quant, 0, 0) for t in tensors]
    for idx, t in enumerate(tensors):
        if idx in const_data:
            arr = const_data[idx]
            raw = np.ascontiguousarray(arr)
            if t.dtype == "bfloat16":
                raw = raw.view(np.uint8)
            off, n = bufs.add(raw.tobytes())
            t.buffer_offset, t.buffer_nbytes = off, n
            t.flags |= TensorFlags.IS_CONST
        if t.quant.channel_scales is not None:
            cs = np.asarray(t.quant.channel_scales, np.float32)
            off, _ = bufs.add(cs.tobytes())
            tensor_channel_scale_off[idx] = off

    # --- string table ---
    strings = bytearray()
    name_pos: List[Tuple[int, int]] = []
    for t in tensors:
        b = t.name.encode()
        name_pos.append((len(strings), len(b)))
        strings += b

    # --- op table (variable records) ---
    op_blob = bytearray()
    for op in ops:
        pbytes = json.dumps(op.params, sort_keys=True,
                            separators=(",", ":")).encode()
        op_blob += struct.pack("<HBBI", op.opcode, len(op.inputs),
                               len(op.outputs), len(pbytes))
        op_blob += struct.pack(f"<{len(op.inputs)}i", *op.inputs)
        op_blob += struct.pack(f"<{len(op.outputs)}i", *op.outputs)
        op_blob += pbytes

    # --- metadata table ---
    md_blob = bytearray()
    md_blob += struct.pack("<I", len(metadata))
    for k, v in sorted(metadata.items()):
        kb = k.encode()
        md_blob += struct.pack("<II", len(kb), len(v)) + kb + v

    # --- tensor table ---
    t_blob = bytearray()
    for idx, t in enumerate(tensors):
        shape = list(t.shape) + [0] * (MAX_RANK - len(t.shape))
        ncs = (len(t.quant.channel_scales)
               if t.quant.channel_scales is not None else 0)
        t_blob += _TENSOR_REC.pack(
            dtype_code(t.dtype), len(t.shape), t.flags,
            t.quant.quantized_dimension, *shape,
            t.buffer_offset, t.buffer_nbytes,
            float(t.quant.scale), int(t.quant.zero_point), ncs,
            tensor_channel_scale_off.get(idx, 0),
            name_pos[idx][0], name_pos[idx][1],
        )

    # --- assemble ---
    io_blob = struct.pack(f"<{len(inputs)}i", *inputs)
    io_blob += struct.pack(f"<{len(outputs)}i", *outputs)

    pos = _HEADER.size
    pos += len(io_blob)
    tensor_tbl_off = pos
    pos += len(t_blob)
    op_tbl_off = pos
    pos += len(op_blob)
    string_tbl_off = pos
    pos += len(strings)
    metadata_tbl_off = pos
    pos += len(md_blob)
    buffers_off = _align(pos)
    pad = buffers_off - pos
    buffer_blob = bufs.blob()
    total = buffers_off + len(buffer_blob)

    header = _HEADER.pack(
        MAGIC, VERSION, len(tensors), len(ops), len(inputs), len(outputs),
        tensor_tbl_off, op_tbl_off, string_tbl_off, metadata_tbl_off,
        buffers_off, total,
    )
    blob = b"".join([header, io_blob, bytes(t_blob), bytes(op_blob),
                     bytes(strings), bytes(md_blob), b"\0" * pad,
                     buffer_blob])
    assert len(blob) == total
    return blob


# ---------------------------------------------------------------------------
# Zero-copy model accessor
# ---------------------------------------------------------------------------

class MicroModel:
    """Zero-copy accessor over a µFB blob.

    Constant tensor data is exposed as ``np.frombuffer`` views into the blob
    — the analogue of TF Micro reading weights directly out of the
    memory-mapped FlatBuffer in flash, with no unpacking.
    """

    def __init__(self, blob: bytes):
        self._blob = blob
        (magic, version, n_tensors, n_ops, n_inputs, n_outputs,
         t_off, o_off, s_off, m_off, b_off, total) = _HEADER.unpack_from(blob, 0)
        if magic != MAGIC:
            raise ValueError("not a µFB model (bad magic)")
        if version != VERSION:
            raise ValueError(f"µFB version mismatch: {version} != {VERSION}")
        if total != len(blob):
            raise ValueError("truncated µFB blob")
        self.version = version
        pos = _HEADER.size
        self.inputs: Tuple[int, ...] = struct.unpack_from(
            f"<{n_inputs}i", blob, pos)
        pos += 4 * n_inputs
        self.outputs: Tuple[int, ...] = struct.unpack_from(
            f"<{n_outputs}i", blob, pos)
        self._t_off, self._o_off, self._s_off = t_off, o_off, m_off and s_off
        self._m_off, self._b_off = m_off, b_off
        self._n_tensors, self._n_ops = n_tensors, n_ops
        self._tensors: List[TensorDef] = []
        self._ops: List[OpDef] = []
        self._parse_tensors(s_off)
        self._parse_ops(o_off)
        self.metadata = self._parse_metadata(m_off)

    # -- parsing (init-phase only; invoke never touches the blob again) ----

    def _parse_tensors(self, s_off: int) -> None:
        blob = self._blob
        for i in range(self._n_tensors):
            rec = _TENSOR_REC.unpack_from(blob, self._t_off + i * _TENSOR_REC.size)
            (dcode, rank, flags, qdim) = rec[0:4]
            shape = tuple(rec[4:4 + rank])
            buffer_offset, buffer_nbytes = rec[4 + MAX_RANK: 6 + MAX_RANK]
            scale, zp, ncs, cs_off, name_off, name_len = rec[6 + MAX_RANK:]
            name = blob[s_off + name_off: s_off + name_off + name_len].decode()
            channel_scales = None
            if ncs:
                channel_scales = np.frombuffer(
                    blob, np.float32, count=ncs, offset=self._b_off + cs_off)
            q = QuantParams(scale, zp, channel_scales, qdim)
            self._tensors.append(TensorDef(
                name, shape, code_dtype(dcode), flags, q,
                buffer_offset, buffer_nbytes))

    def _parse_ops(self, o_off: int) -> None:
        blob, pos = self._blob, o_off
        for _ in range(self._n_ops):
            opcode, n_in, n_out, plen = struct.unpack_from("<HBBI", blob, pos)
            pos += 8
            ins = struct.unpack_from(f"<{n_in}i", blob, pos)
            pos += 4 * n_in
            outs = struct.unpack_from(f"<{n_out}i", blob, pos)
            pos += 4 * n_out
            params = json.loads(blob[pos:pos + plen].decode()) if plen else {}
            pos += plen
            self._ops.append(OpDef(opcode, ins, outs, params))

    def _parse_metadata(self, m_off: int) -> Dict[str, bytes]:
        blob, pos = self._blob, m_off
        (n,) = struct.unpack_from("<I", blob, pos)
        pos += 4
        md = {}
        for _ in range(n):
            klen, vlen = struct.unpack_from("<II", blob, pos)
            pos += 8
            k = blob[pos:pos + klen].decode()
            pos += klen
            md[k] = blob[pos:pos + vlen]
            pos += vlen
        return md

    # -- accessors ----------------------------------------------------------

    @property
    def tensors(self) -> List[TensorDef]:
        return self._tensors

    @property
    def operators(self) -> List[OpDef]:
        return self._ops

    def tensor(self, i: int) -> TensorDef:
        return self._tensors[i]

    def const_data(self, i: int) -> np.ndarray:
        """Zero-copy view of a const tensor's data inside the blob."""
        t = self._tensors[i]
        if not t.is_const:
            raise ValueError(f"tensor {i} ({t.name}) is not const")
        if t.dtype == "bfloat16":
            raw = np.frombuffer(self._blob, np.uint8, count=t.buffer_nbytes,
                                offset=self._b_off + t.buffer_offset)
            import ml_dtypes  # optional; fall back to uint16 container

            return raw.view(ml_dtypes.bfloat16).reshape(t.shape)
        arr = np.frombuffer(self._blob, np.dtype(t.dtype),
                            count=t.nbytes // dtype_itemsize(t.dtype),
                            offset=self._b_off + t.buffer_offset)
        return arr.reshape(t.shape)

    @property
    def blob(self) -> bytes:
        return self._blob

    def nbytes(self) -> int:
        return len(self._blob)

    def summary(self) -> str:
        lines = [f"µFB model: {self._n_tensors} tensors, {self._n_ops} ops, "
                 f"{len(self._blob)} bytes"]
        for i, op in enumerate(self._ops):
            lines.append(f"  [{i:3d}] {op.name:<18s} in={list(op.inputs)} "
                         f"out={list(op.outputs)}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# "C array" embedding (paper §4.3.1: convert model files into compilable
# source for file-system-less targets)
# ---------------------------------------------------------------------------

def model_to_source(blob: bytes, var_name: str = "g_model") -> str:
    """Render a µFB blob as an importable Python source module, the analogue
    of TFLM's xxd-style C-array embedding."""
    import base64

    b64 = base64.b64encode(blob).decode()
    chunks = [b64[i:i + 76] for i in range(0, len(b64), 76)]
    body = "\n".join(f'    "{c}"' for c in chunks)
    return (
        "# Auto-generated µFB model (paper §4.3.1 'C array' analogue).\n"
        "import base64\n\n"
        f"{var_name}_len = {len(blob)}\n"
        f"{var_name} = base64.b64decode(\n{body}\n)\n"
    )
