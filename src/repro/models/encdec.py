"""Whisper-large-v3 backbone [arXiv:2212.04356] — encoder-decoder
transformer with LayerNorm, GELU MLP, learned/sinusoidal positions, and
per-layer cross-attention.

The mel-spectrogram + conv frontend is a STUB per the assignment
carve-out: ``input_specs`` supplies precomputed frame embeddings
(B, n_audio_ctx, d_model) — the conv downsampling has already happened.

Whisper uses absolute positions (no RoPE): sinusoidal on the encoder,
learned on the decoder.  Attention has biases on q/v/out (not k).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import shard_act, shard_logits

from .common import (ModelConfig, cross_entropy_loss, dense_init,
                     layer_norm, split_keys)
from .lm import chunked_attention, padded_vocab

Params = Dict[str, Any]

DEC_MAX_POS = 8192          # learned decoder positions (ring past this)


def _init_attn(key, cfg: ModelConfig, dtype, L: int, cross: bool = False):
    d, h, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.dh
    ks = split_keys(key, 4)
    return {
        "wq": dense_init(ks[0], (L, d, h, dh), dtype=dtype),
        "bq": jnp.zeros((L, h, dh), dtype),
        "wk": dense_init(ks[1], (L, d, kh, dh), dtype=dtype),
        "wv": dense_init(ks[2], (L, d, kh, dh), dtype=dtype),
        "bv": jnp.zeros((L, kh, dh), dtype),
        "wo": dense_init(ks[3], (L, h, dh, d),
                         scale=1.0 / math.sqrt(h * dh), dtype=dtype),
        "bo": jnp.zeros((L, d), dtype),
    }


def _init_mlp_ln(key, cfg: ModelConfig, dtype, L: int):
    d, f = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 2)
    return {"wi": dense_init(ks[0], (L, d, f), dtype=dtype),
            "bi": jnp.zeros((L, f), dtype),
            "wo": dense_init(ks[1], (L, f, d),
                             scale=1.0 / math.sqrt(f), dtype=dtype),
            "bo": jnp.zeros((L, d), dtype)}


def _ln_pair(dtype, L, d):
    return jnp.ones((L, d), dtype), jnp.zeros((L, d), dtype)


def init_encdec(key, cfg: ModelConfig) -> Params:
    dtype = cfg.jnp_dtype()
    d = cfg.d_model
    vp = padded_vocab(cfg)
    ks = split_keys(key, 10)
    Le, Ld = cfg.n_encoder_layers, cfg.n_layers
    g1e, b1e = _ln_pair(dtype, Le, d)
    g2e, b2e = _ln_pair(dtype, Le, d)
    g1, b1 = _ln_pair(dtype, Ld, d)
    gx, bx = _ln_pair(dtype, Ld, d)
    g2, b2 = _ln_pair(dtype, Ld, d)
    return {
        "embed": dense_init(ks[0], (vp, d), scale=0.02, dtype=dtype),
        "dec_pos": dense_init(ks[1], (DEC_MAX_POS, d), scale=0.01,
                              dtype=dtype),
        "encoder": {
            "attn": _init_attn(ks[2], cfg, dtype, Le),
            "mlp": _init_mlp_ln(ks[3], cfg, dtype, Le),
            "ln1_g": g1e, "ln1_b": b1e, "ln2_g": g2e, "ln2_b": b2e,
        },
        "enc_final_g": jnp.ones((d,), dtype),
        "enc_final_b": jnp.zeros((d,), dtype),
        "decoder": {
            "attn": _init_attn(ks[4], cfg, dtype, Ld),
            "xattn": _init_attn(ks[5], cfg, dtype, Ld, cross=True),
            "mlp": _init_mlp_ln(ks[6], cfg, dtype, Ld),
            "ln1_g": g1, "ln1_b": b1, "lnx_g": gx, "lnx_b": bx,
            "ln2_g": g2, "ln2_b": b2,
        },
        "final_g": jnp.ones((d,), dtype),
        "final_b": jnp.zeros((d,), dtype),
        # whisper ties the output head to the token embedding
    }


def sinusoids(length: int, channels: int, dtype=jnp.float32):
    lt = math.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-lt * jnp.arange(channels // 2, dtype=jnp.float32))
    ang = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=1
                           ).astype(dtype)


def _qkv(p, x, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"]) + p["bq"]
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"]) + p["bv"]
    return q, k, v


def _mlp(p, x):
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"]) + p["bi"])
    return jnp.einsum("bsf,fd->bsd", h, p["wo"]) + p["bo"]


def _self_attn_full(p, cfg, x, *, causal: bool,
                    window: Optional[int] = None):
    q, k, v = _qkv(p, x, cfg)
    if causal:
        out = chunked_attention(q, k, v, cfg, window=window)
    else:   # encoder: bidirectional, S=1500 — direct einsum is fine
        g = cfg.n_heads // cfg.n_kv_heads
        b, s, h, dh = q.shape
        qg = q.reshape(b, s, cfg.n_kv_heads, g, dh)
        logits = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                            preferred_element_type=jnp.float32)
        w = jax.nn.softmax(logits / math.sqrt(dh), axis=-1).astype(v.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", w, v).reshape(b, s, h, dh)
    return jnp.einsum("bqhk,hkd->bqd", out, p["wo"]) + p["bo"]


def _cross_attn(p, cfg, x, enc_k, enc_v, *, chunk: int = 512):
    """x (B,S,D); enc_k/v (B,KH,T,dh).  Query-chunked so the (S,T)
    attention matrix never materializes beyond (chunk,T)."""
    b, s, _ = x.shape
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    g = h // kh
    q = (jnp.einsum("bsd,dhk->bshk", x, p["wq"]) + p["bq"]
         ).reshape(b, s, kh, g, dh)

    def attend(qc):
        logits = jnp.einsum("bqkgd,bktd->bkgqt", qc, enc_k,
                            preferred_element_type=jnp.float32)
        w = jax.nn.softmax(logits / math.sqrt(dh), axis=-1
                           ).astype(x.dtype)
        return jnp.einsum("bkgqt,bktd->bqkgd", w, enc_v)

    if s > chunk and s % chunk == 0:
        nc = s // chunk
        qs = q.reshape(b, nc, chunk, kh, g, dh).transpose(
            1, 0, 2, 3, 4, 5)
        _, outs = jax.lax.scan(
            jax.checkpoint(lambda c, qc: (c, attend(qc))), None, qs)
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, dh)
    else:
        out = attend(q).reshape(b, s, h, dh)
    return jnp.einsum("bqhk,hkd->bqd", out, p["wo"]) + p["bo"]


def encode(params: Params, cfg: ModelConfig, frames, *,
           remat: bool = False) -> jnp.ndarray:
    """frames (B, T, D) — stub frontend output.  Returns (B, T, D)."""
    b, t, d = frames.shape
    x = frames + sinusoids(t, d, frames.dtype)[None]
    enc = params["encoder"]

    def body(h, p_l):
        h = shard_act(h)
        a = _self_attn_full(p_l["attn"], cfg,
                            layer_norm(h, p_l["ln1_g"], p_l["ln1_b"]),
                            causal=False)
        h = h + a
        h = h + _mlp(p_l["mlp"], layer_norm(h, p_l["ln2_g"], p_l["ln2_b"]))
        return h, None

    fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(fn, x, enc)
    return layer_norm(x, params["enc_final_g"], params["enc_final_b"])


def _enc_kv(params: Params, cfg: ModelConfig, enc_out):
    """Precompute per-decoder-layer cross K/V: (L,B,KH,T,dh)."""
    xa = params["decoder"]["xattn"]

    def per_layer(wk, wv, bv):
        k = jnp.einsum("btd,dhk->bhtk", enc_out, wk)
        v = jnp.einsum("btd,dhk->bhtk", enc_out, wv) + bv[None, :, None]
        return k, v

    return jax.vmap(per_layer)(xa["wk"], xa["wv"], xa["bv"])


def _decoder_fwd(params, cfg, x, enc_k, enc_v, *,
                 window: Optional[int] = None, remat: bool = False):
    dec = params["decoder"]

    def body(h, layer_in):
        p_attn, p_x, p_mlp, l1g, l1b, lxg, lxb, l2g, l2b, ek, ev = layer_in
        h = shard_act(h)
        h = h + _self_attn_full(p_attn, cfg, layer_norm(h, l1g, l1b),
                                causal=True, window=window)
        h = h + _cross_attn(p_x, cfg, layer_norm(h, lxg, lxb), ek, ev)
        h = h + _mlp(p_mlp, layer_norm(h, l2g, l2b))
        return shard_act(h), None

    fn = jax.checkpoint(body) if remat else body
    xs = (dec["attn"], dec["xattn"], dec["mlp"], dec["ln1_g"], dec["ln1_b"],
          dec["lnx_g"], dec["lnx_b"], dec["ln2_g"], dec["ln2_b"],
          enc_k, enc_v)
    x, _ = jax.lax.scan(fn, x, xs)
    return layer_norm(x, params["final_g"], params["final_b"])


def _embed_dec(params, cfg, tokens, positions):
    x = jnp.take(params["embed"], tokens, axis=0)
    pos = jnp.take(params["dec_pos"], positions % DEC_MAX_POS, axis=0)
    return x + pos


def encdec_loss(params, cfg: ModelConfig, batch, *, remat: bool = True,
                data_shards: int = 16):
    """batch: frames (B,T,D), tokens (B,S), labels (B,S)."""
    enc_out = encode(params, cfg, batch["frames"], remat=remat)
    enc_k, enc_v = _enc_kv(params, cfg, enc_out)
    b, s = batch["tokens"].shape
    x = _embed_dec(params, cfg, batch["tokens"], jnp.arange(s)[None])
    h = _decoder_fwd(params, cfg, x, enc_k, enc_v, remat=remat)
    logits = shard_logits(jnp.einsum("bsd,vd->bsv", h, params["embed"]))
    mask = (batch["labels"] >= 0).astype(jnp.float32)
    labels = jnp.maximum(batch["labels"], 0)
    loss = cross_entropy_loss(logits, labels, mask)
    return loss, {"ce_loss": loss}


def encdec_prefill(params, cfg: ModelConfig, batch,
                   cache_len: Optional[int] = None, *,
                   window: Optional[int] = None, **_):
    """batch: frames (B,T,D) + tokens (B,S).  Returns logits + cache
    holding self-attn KV rings and the static cross K/V."""
    frames, tokens = batch["frames"], batch["tokens"]
    enc_out = encode(params, cfg, frames)
    enc_k, enc_v = _enc_kv(params, cfg, enc_out)
    b, s = tokens.shape
    c = cache_len or s
    x = _embed_dec(params, cfg, tokens, jnp.arange(s)[None])
    dec = params["decoder"]

    def to_cache(kk):
        kc = jnp.zeros((b, cfg.n_kv_heads, c, cfg.dh), kk.dtype)
        take = min(s, c)
        src = kk[:, s - take:].transpose(0, 2, 1, 3)
        if c >= s:
            return jax.lax.dynamic_update_slice(kc, src, (0, 0, 0, 0))
        pos = (jnp.arange(s - take, s) % c)
        return kc.at[:, :, pos].set(src)

    def body(h, layer_in):
        (p_attn, p_x, p_mlp, l1g, l1b, lxg, lxb, l2g, l2b,
         ek, ev) = layer_in
        xin = layer_norm(h, l1g, l1b)
        q, kk, vv = _qkv(p_attn, xin, cfg)
        att = chunked_attention(q, kk, vv, cfg, window=window)
        h = h + (jnp.einsum("bqhk,hkd->bqd", att, p_attn["wo"])
                 + p_attn["bo"])
        h = h + _cross_attn(p_x, cfg, layer_norm(h, lxg, lxb), ek, ev)
        h = h + _mlp(p_mlp, layer_norm(h, l2g, l2b))
        return h, (to_cache(kk), to_cache(vv))

    xs = (dec["attn"], dec["xattn"], dec["mlp"], dec["ln1_g"], dec["ln1_b"],
          dec["lnx_g"], dec["lnx_b"], dec["ln2_g"], dec["ln2_b"],
          enc_k, enc_v)
    x, (ks_, vs_) = jax.lax.scan(body, x, xs)
    x = layer_norm(x, params["final_g"], params["final_b"])
    logits = jnp.einsum("bsd,vd->bsv", x[:, -1:], params["embed"])[:, 0]
    return logits, {"k": ks_, "v": vs_, "cross_k": enc_k, "cross_v": enc_v}


def encdec_decode(params, cfg: ModelConfig, cache, tokens, lengths, **_):
    """One decode step.  cache: k/v (L,B,KH,C,dh) rings +
    cross_k/cross_v (L,B,KH,T,dh) static."""
    from .lm import decode_attention_block
    x = _embed_dec(params, cfg, tokens, lengths[:, None])
    dec = params["decoder"]

    def body(h, layer_in):
        (p_attn, p_x, p_mlp, l1g, l1b, lxg, lxb, l2g, l2b,
         ek, ev, ck, cv) = layer_in
        xin = layer_norm(h, l1g, l1b)
        # decode self-attention with biases: fold biases into projections
        pb = dict(p_attn)
        att, ck, cv = _decode_attn_bias(pb, cfg, xin, ck, cv, lengths)
        h = h + att
        h = h + _cross_attn(p_x, cfg, layer_norm(h, lxg, lxb), ek, ev)
        h = h + _mlp(p_mlp, layer_norm(h, l2g, l2b))
        return h, (ck, cv)

    xs = (dec["attn"], dec["xattn"], dec["mlp"], dec["ln1_g"], dec["ln1_b"],
          dec["lnx_g"], dec["lnx_b"], dec["ln2_g"], dec["ln2_b"],
          cache["cross_k"], cache["cross_v"], cache["k"], cache["v"])
    x, (ks_, vs_) = jax.lax.scan(body, x, xs)
    x = layer_norm(x, params["final_g"], params["final_b"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])[:, 0]
    return logits, {"k": ks_, "v": vs_, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"]}


def _decode_attn_bias(p, cfg: ModelConfig, x, cache_k, cache_v, lengths):
    """Biased-projection variant of lm.decode_attention_block."""
    b = x.shape[0]
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    g = h // kh
    c = cache_k.shape[2]
    q = (jnp.einsum("bsd,dhk->bshk", x, p["wq"]) + p["bq"])[:, 0]
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])[:, 0]
    v = (jnp.einsum("bsd,dhk->bshk", x, p["wv"]) + p["bv"])[:, 0]
    q = q.reshape(b, kh, g, dh)
    slot = (lengths % c).astype(jnp.int32)
    onehot = jax.nn.one_hot(slot, c, dtype=x.dtype)
    kc = cache_k * (1 - onehot)[:, None, :, None] \
        + k[:, :, None, :] * onehot[:, None, :, None]
    vc = cache_v * (1 - onehot)[:, None, :, None] \
        + v[:, :, None, :] * onehot[:, None, :, None]
    n_valid = jnp.minimum(lengths + 1, c)
    logits = jnp.einsum("bkgd,bkcd->bkgc", q, kc,
                        preferred_element_type=jnp.float32)
    logits = logits / math.sqrt(dh)
    pos = jnp.arange(c)[None, None, None, :]
    logits = jnp.where(pos < n_valid[:, None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgc,bkcd->bkgd", w, vc).reshape(b, 1, h, dh)
    y = jnp.einsum("bqhk,hkd->bqd", out, p["wo"]) + p["bo"]
    return y, kc, vc
