"""PaliGemma-3B backbone [arXiv:2407.07726]: Gemma-2B decoder consuming
a SigLIP vision prefix through a linear projector, with prefix-LM
masking (bidirectional attention over the image tokens + prompt).

The SigLIP ViT is a STUB per the assignment carve-out: ``input_specs``
supplies precomputed patch embeddings (B, n_vision_tokens, d_vision);
the in-model linear projector (d_vision -> d_model) and everything after
it is real.  Gemma details kept: GeGLU MLP, MQA (kv=1), RoPE, tied
embeddings, sqrt(d_model)-scaled token embeddings.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .common import ModelConfig, cross_entropy_loss, dense_init, split_keys
from .lm import (embed_tokens, init_lm, lm_backbone, lm_decode, lm_logits,
                 lm_prefill)

Params = Dict[str, Any]


def init_vlm(key, cfg: ModelConfig) -> Params:
    k1, k2 = split_keys(key, 2)
    params = init_lm(k1, cfg)
    params["projector"] = dense_init(
        k2, (cfg.d_vision, cfg.d_model),
        scale=1.0 / math.sqrt(cfg.d_vision), dtype=cfg.jnp_dtype())
    return params


def _embed_multimodal(params, cfg: ModelConfig, vision, tokens):
    """vision (B,P,d_vision) + tokens (B,S) -> (B,P+S,D)."""
    scale = math.sqrt(cfg.d_model)
    xt = embed_tokens(params, cfg, tokens) * scale
    xv = jnp.einsum("bpe,ed->bpd", vision.astype(xt.dtype),
                    params["projector"])
    return jnp.concatenate([xv, xt], axis=1)


def vlm_loss(params, cfg: ModelConfig, batch, *, remat: bool = True,
             data_shards: int = 16):
    """batch: vision (B,P,d_vision), tokens (B,S), labels (B,S).
    Loss only over the text positions (vision prefix has no labels)."""
    x = _embed_multimodal(params, cfg, batch["vision"], batch["tokens"])
    p = cfg.n_vision_tokens
    h, _ = lm_backbone(params, cfg, x, prefix_len=p, remat=remat,
                       data_shards=data_shards)
    logits = lm_logits(params, cfg, h[:, p:])
    mask = (batch["labels"] >= 0).astype(jnp.float32)
    labels = jnp.maximum(batch["labels"], 0)
    loss = cross_entropy_loss(logits, labels, mask)
    return loss, {"ce_loss": loss}


def vlm_prefill(params, cfg: ModelConfig, batch,
                cache_len: Optional[int] = None, *,
                window: Optional[int] = None, **_):
    """batch: vision (B,P,d_vision) + tokens (B,S).  The cache covers
    vision prefix + prompt (vision tokens occupy cache slots)."""
    xv = jnp.einsum("bpe,ed->bpd",
                    batch["vision"].astype(cfg.jnp_dtype()),
                    params["projector"])
    return lm_prefill(params, cfg, batch["tokens"], cache_len,
                      window=window, prefix_len=cfg.n_vision_tokens,
                      prefix_embed=xv, embed_scale=math.sqrt(cfg.d_model))


def vlm_decode(params, cfg: ModelConfig, cache, tokens, lengths, **_):
    """lengths are absolute positions *including* the vision prefix."""
    return lm_decode(params, cfg, cache, tokens, lengths,
                     embed_scale=math.sqrt(cfg.d_model))
