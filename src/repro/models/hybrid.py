"""Zamba2 hybrid family [arXiv:2411.15242]: a Mamba2 backbone with ONE
weight-tied shared attention+MLP block applied every
``shared_attn_every`` layers.

The Zamba trick: the shared block's parameters are used at every
application point but exist once — param memory stays SSM-sized while
the model gains periodic global attention.  Each *application* still
needs its own KV cache (activations differ per depth), so the decode
cache carries (n_apps, B, KH, C, dh).

Layer schedule for n_layers=38, every=6:
  [6 mamba] attn [6 mamba] attn ... (6 groups of 6) ... [2 mamba tail]
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, cross_entropy_loss, rms_norm, split_keys, \
    dense_init
from .lm import (_init_attn_block, _init_mlp, attention_block,
                 decode_attention_block, embed_tokens, lm_logits,
                 mlp_block, padded_vocab)
from .ssm import (init_ssm_block, mamba_block, mamba_decode_block,
                  ssm_empty_cache)

Params = Dict[str, Any]


def n_shared_apps(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_every


def _group_split(cfg: ModelConfig):
    every = cfg.shared_attn_every
    n_groups = cfg.n_layers // every
    tail = cfg.n_layers - n_groups * every
    return n_groups, every, tail


def init_hybrid_lm(key, cfg: ModelConfig) -> Params:
    dtype = cfg.jnp_dtype()
    vp = padded_vocab(cfg)
    ks = split_keys(key, 6)
    shared = {
        "ln1": jnp.ones((1, cfg.d_model), dtype),
        "ln2": jnp.ones((1, cfg.d_model), dtype),
        "attn": _init_attn_block(ks[2], cfg, dtype, 1),
        "mlp": _init_mlp(ks[3], cfg.d_model, cfg.d_ff, cfg.act, dtype,
                         lead=(1,)),
    }
    params: Params = {
        "embed": dense_init(ks[0], (vp, cfg.d_model), scale=0.02,
                            dtype=dtype),
        "blocks": init_ssm_block(ks[1], cfg, dtype, cfg.n_layers),
        "shared": jax.tree.map(lambda a: a[0], shared),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[4], (cfg.d_model, vp),
                                       scale=0.02, dtype=dtype)
    return params


def _shared_attn_fwd(shared: Params, cfg: ModelConfig, x, *,
                     window: Optional[int] = None):
    h = x + attention_block(shared["attn"], cfg,
                            rms_norm(x, shared["ln1"], cfg.norm_eps),
                            window=window)
    return h + mlp_block(shared["mlp"], cfg,
                         rms_norm(h, shared["ln2"], cfg.norm_eps))


def hybrid_backbone(params, cfg: ModelConfig, x, *, remat: bool = False,
                    window: Optional[int] = None):
    """Scan groups of ``every`` mamba layers; shared attn at boundaries."""
    n_groups, every, tail = _group_split(cfg)
    head = jax.tree.map(
        lambda a: a[:n_groups * every].reshape(n_groups, every, *a.shape[1:]),
        params["blocks"])
    tail_p = jax.tree.map(lambda a: a[n_groups * every:], params["blocks"])

    def mamba_stack(h, stacked):
        def inner(hh, p_l):
            return mamba_block(p_l, cfg, hh), None
        inner_fn = jax.checkpoint(inner) if remat else inner
        h, _ = jax.lax.scan(inner_fn, h, stacked)
        return h

    def group(h, p_group):
        h = mamba_stack(h, p_group)
        h = _shared_attn_fwd(params["shared"], cfg, h, window=window)
        return h, None

    x, _ = jax.lax.scan(group, x, head)
    if tail:
        x = mamba_stack(x, tail_p)
    return x


def hybrid_loss(params, cfg: ModelConfig, batch, *, remat: bool = True,
                data_shards: int = 16):
    x = embed_tokens(params, cfg, batch["tokens"])
    h = hybrid_backbone(params, cfg, x, remat=remat)
    logits = lm_logits(params, cfg, h)
    mask = (batch["labels"] >= 0).astype(jnp.float32)
    labels = jnp.maximum(batch["labels"], 0)
    loss = cross_entropy_loss(logits, labels, mask)
    return loss, {"ce_loss": loss}


def hybrid_empty_cache(cfg: ModelConfig, batch: int, cache_len: int,
                       dtype) -> Dict:
    cache = ssm_empty_cache(cfg, batch, dtype)
    apps = n_shared_apps(cfg)
    cache["attn_k"] = jnp.zeros(
        (apps, batch, cfg.n_kv_heads, cache_len, cfg.dh), dtype)
    cache["attn_v"] = jnp.zeros_like(cache["attn_k"])
    return cache


def hybrid_prefill(params, cfg: ModelConfig, tokens,
                   cache_len: Optional[int] = None, *,
                   window: Optional[int] = None, **_):
    """Prefill via teacher-forced decode-free pass capturing SSD state,
    conv tails and shared-block KV at each application point."""
    from .lm import _proj_qkv, chunked_attention
    from .ssm import ssm_prefill as _unused  # noqa: F401
    b, s = tokens.shape
    c = cache_len or s
    n_groups, every, tail = _group_split(cfg)
    x = embed_tokens(params, cfg, tokens)
    head = jax.tree.map(
        lambda a: a[:n_groups * every].reshape(n_groups, every,
                                               *a.shape[1:]),
        params["blocks"])
    tail_p = jax.tree.map(lambda a: a[n_groups * every:], params["blocks"])

    def mamba_capture(h, p_l):
        # reuse ssm_prefill body logic via mamba_block + state capture
        from .ssm import (_causal_conv, _split_proj, ssd_chunked)
        bb, ss, _ = h.shape
        k = cfg.ssm_conv
        xin = rms_norm(h, p_l["ln"], cfg.norm_eps)
        zxbcdt = jnp.einsum("bsd,de->bse", xin, p_l["in_proj"])
        z, xBC, dt = _split_proj(cfg, zxbcdt)
        conv_tail = jnp.pad(xBC, ((0, 0), (max(k - 1 - ss, 0), 0),
                                  (0, 0)))[:, -(k - 1):]
        xBC = _causal_conv(xBC, p_l["conv_w"], p_l["conv_b"])
        di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
        hh, ph = cfg.ssm_heads, cfg.ssm_head_dim
        xs = xBC[..., :di].reshape(bb, ss, hh, ph)
        Bm = xBC[..., di:di + g * n].reshape(bb, ss, g, n)
        Cm = xBC[..., di + g * n:].reshape(bb, ss, g, n)
        dtf = jax.nn.softplus(dt.astype(jnp.float32) + p_l["dt_bias"])
        A = -jnp.exp(p_l["A_log"])
        y, state = ssd_chunked(xs, dtf, A, Bm, Cm)
        y = y + xs * p_l["D"][None, None, :, None].astype(y.dtype)
        y = y.reshape(bb, ss, di)
        y = rms_norm(y * jax.nn.silu(z), p_l["norm"], cfg.norm_eps)
        return h + jnp.einsum("bse,ed->bsd", y, p_l["out_proj"]), \
            (conv_tail, state)

    def to_cache(kk):
        kc = jnp.zeros((b, cfg.n_kv_heads, c, cfg.dh), kk.dtype)
        take = min(s, c)
        src = kk[:, s - take:].transpose(0, 2, 1, 3)
        if c >= s:
            return jax.lax.dynamic_update_slice(kc, src, (0, 0, 0, 0))
        pos = (jnp.arange(s - take, s) % c)
        return kc.at[:, :, pos].set(src)

    def group(h, p_group):
        h, caps = jax.lax.scan(mamba_capture, h, p_group)
        sh = params["shared"]
        xin = rms_norm(h, sh["ln1"], cfg.norm_eps)
        q, kk, vv = _proj_qkv(sh["attn"], cfg, xin, jnp.arange(s))
        att = chunked_attention(q, kk, vv, cfg, window=window)
        h2 = h + jnp.einsum("bqhk,hkd->bqd", att, sh["attn"]["wo"])
        h2 = h2 + mlp_block(sh["mlp"], cfg,
                            rms_norm(h2, sh["ln2"], cfg.norm_eps))
        return h2, (caps, to_cache(kk), to_cache(vv))

    x, (caps, ks_, vs_) = jax.lax.scan(group, x, head)
    convs = caps[0].reshape(-1, *caps[0].shape[2:])
    states = caps[1].reshape(-1, *caps[1].shape[2:])
    if tail:
        x, (ct, st) = jax.lax.scan(mamba_capture, x, tail_p)
        convs = jnp.concatenate([convs, ct])
        states = jnp.concatenate([states, st])
    logits = lm_logits(params, cfg, x[:, -1:])[:, 0]
    return logits, {"conv": convs, "state": states,
                    "attn_k": ks_, "attn_v": vs_}


def hybrid_prefill_chunk(params, cfg: ModelConfig, cache, tokens, start,
                         n_real, *, window: Optional[int] = None, **_):
    """Advance a batch=1 hybrid cache by one right-padded chunk (the
    SERVING_PREFILL_CHUNK_STATE body for zamba2-style models).

    Mamba layers carry (conv, state) through ``mamba_chunk_block``
    with ``n_real`` masking the padded tail to exact no-ops; the
    shared attention block mirrors ``lm_prefill_chunk``'s traced-start
    chunk attention — the chunk's K/V land at absolute positions
    ``start..start+S`` and queries attend causally over the cache.
    Padded positions do write garbage K/V rows past the true prompt
    length, exactly like the final padded chunk on the dense path:
    the length-masked decode never attends them before the ring
    overwrites them (docs/PREEMPTION.md §4).  Both ``start`` and
    ``n_real`` are TRACED scalars, so one compiled program serves
    every chunk of every prompt.  Requires ``start + S <= cache_len``
    (no ring wrap) — the engine falls back to one-shot exact prefill
    past that.
    """
    import math as _math

    from .lm import _proj_qkv
    from .ssm import mamba_chunk_block
    b, s = tokens.shape
    n_groups, every, tail = _group_split(cfg)
    head_n = n_groups * every
    x = embed_tokens(params, cfg, tokens)
    head = jax.tree.map(
        lambda a: a[:head_n].reshape(n_groups, every, *a.shape[1:]),
        params["blocks"])
    tail_p = jax.tree.map(lambda a: a[head_n:], params["blocks"])
    conv_h = cache["conv"][:head_n].reshape(
        n_groups, every, *cache["conv"].shape[1:])
    state_h = cache["state"][:head_n].reshape(
        n_groups, every, *cache["state"].shape[1:])
    sh = params["shared"]
    positions = start + jnp.arange(s)
    g_rep = cfg.n_heads // cfg.n_kv_heads
    scale = 1.0 / _math.sqrt(cfg.dh)

    def mamba_step(h, layer_in):
        p_l, conv, state = layer_in
        h, conv, state = mamba_chunk_block(p_l, cfg, h, conv, state,
                                           n_real)
        return h, (conv, state)

    def attend_chunk(xin, ck, cv):
        # ck/cv (B,KH,C,dh): write the chunk's K/V at its absolute
        # positions, attend the chunk's queries over the cache
        c = ck.shape[2]
        q, kk, vv = _proj_qkv(sh["attn"], cfg, xin, positions)
        ck = jax.lax.dynamic_update_slice(
            ck, kk.transpose(0, 2, 1, 3).astype(ck.dtype),
            (0, 0, start, 0))
        cv = jax.lax.dynamic_update_slice(
            cv, vv.transpose(0, 2, 1, 3).astype(cv.dtype),
            (0, 0, start, 0))
        ks_ = ck.transpose(0, 2, 1, 3)                # (B,C,KH,dh)
        vs_ = cv.transpose(0, 2, 1, 3)
        kx = jnp.repeat(ks_, g_rep, axis=2) if g_rep > 1 else ks_
        vx = jnp.repeat(vs_, g_rep, axis=2) if g_rep > 1 else vs_
        kpos = jnp.arange(c)
        logits = jnp.einsum("bqhd,bshd->bhqs", q, kx,
                            preferred_element_type=jnp.float32) * scale
        mask = kpos[None, :] <= positions[:, None]
        if window is not None:
            mask = mask & (kpos[None, :] > positions[:, None] - window)
        logits = jnp.where(mask[None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(vx.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", w, vx)
        y = jnp.einsum("bqhk,hkd->bqd", out, sh["attn"]["wo"])
        return y, ck, cv

    def group(h, gin):
        p_group, conv_g, state_g, ck, cv = gin
        h, (conv_g, state_g) = jax.lax.scan(mamba_step, h,
                                            (p_group, conv_g, state_g))
        xin = rms_norm(h, sh["ln1"], cfg.norm_eps)
        att, ck, cv = attend_chunk(xin, ck, cv)
        h2 = h + att
        h2 = h2 + mlp_block(sh["mlp"], cfg,
                            rms_norm(h2, sh["ln2"], cfg.norm_eps))
        return h2, (conv_g, state_g, ck, cv)

    x, (conv_g, state_g, ks_, vs_) = jax.lax.scan(
        group, x, (head, conv_h, state_h, cache["attn_k"],
                   cache["attn_v"]))
    convs = conv_g.reshape(-1, *conv_g.shape[2:])
    states = state_g.reshape(-1, *state_g.shape[2:])
    if tail:
        x, (ct, st) = jax.lax.scan(
            mamba_step, x, (tail_p, cache["conv"][head_n:],
                            cache["state"][head_n:]))
        convs = jnp.concatenate([convs, ct])
        states = jnp.concatenate([states, st])
    return {"conv": convs, "state": states,
            "attn_k": ks_, "attn_v": vs_}


def hybrid_decode(params, cfg: ModelConfig, cache, tokens, lengths, *,
                  window: Optional[int] = None, **_):
    n_groups, every, tail = _group_split(cfg)
    x = embed_tokens(params, cfg, tokens)

    def mamba_step(h, layer_in):
        p_l, conv, state = layer_in
        h, conv, state = mamba_decode_block(p_l, cfg, h, conv, state)
        return h, (conv, state)

    head_n = n_groups * every
    head = jax.tree.map(
        lambda a: a[:head_n].reshape(n_groups, every, *a.shape[1:]),
        params["blocks"])
    tail_p = jax.tree.map(lambda a: a[head_n:], params["blocks"])
    conv_h = cache["conv"][:head_n].reshape(
        n_groups, every, *cache["conv"].shape[1:])
    state_h = cache["state"][:head_n].reshape(
        n_groups, every, *cache["state"].shape[1:])
    sh = params["shared"]

    def group(h, gin):
        p_group, conv_g, state_g, ck, cv = gin
        h, (conv_g, state_g) = jax.lax.scan(mamba_step, h,
                                            (p_group, conv_g, state_g))
        xin = rms_norm(h, sh["ln1"], cfg.norm_eps)
        att, ck, cv = decode_attention_block(sh["attn"], cfg, xin, ck, cv,
                                             lengths)
        h2 = h + att
        h2 = h2 + mlp_block(sh["mlp"], cfg,
                            rms_norm(h2, sh["ln2"], cfg.norm_eps))
        return h2, (conv_g, state_g, ck, cv)

    x, (conv_g, state_g, ks_, vs_) = jax.lax.scan(
        group, x, (head, conv_h, state_h, cache["attn_k"],
                   cache["attn_v"]))
    convs = conv_g.reshape(-1, *conv_g.shape[2:])
    states = state_g.reshape(-1, *state_g.shape[2:])
    if tail:
        x, (ct, st) = jax.lax.scan(
            mamba_step, x, (tail_p, cache["conv"][head_n:],
                            cache["state"][head_n:]))
        convs = jnp.concatenate([convs, ct])
        states = jnp.concatenate([states, st])
    logits = lm_logits(params, cfg, x)[:, 0]
    return logits, {"conv": convs, "state": states,
                    "attn_k": ks_, "attn_v": vs_}
